"""Deterministic fallback shim for ``hypothesis``.

The property tests in this repo use a small, fixed subset of the hypothesis
API (``given``/``settings`` and the ``integers``/``lists``/``sampled_from``
strategies). When the real package is unavailable (this container ships
without it), ``conftest.py`` installs this module as ``sys.modules
["hypothesis"]`` so the suite still runs: each ``@given`` test executes a
deterministic, seeded sample sweep instead of adaptive search. With the real
hypothesis installed (e.g. in CI), this file is inert.
"""

from __future__ import annotations

import inspect
import random
import sys
import types

_MAX_EXAMPLES_CAP = 25  # keep the fallback sweep cheap


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rnd: rnd.randint(min_value, max_value))


def sampled_from(options) -> _Strategy:
    opts = list(options)
    return _Strategy(lambda rnd: opts[rnd.randrange(len(opts))])


def lists(elements: _Strategy, *, min_size: int = 0, max_size: int = 10) -> _Strategy:
    def draw(rnd):
        n = rnd.randint(min_size, max_size)
        return [elements.draw(rnd) for _ in range(n)]

    return _Strategy(draw)


#: ``settings(...)`` kwargs the stub understands (a subset of the real
#: package's). Anything else raises: a kwarg silently swallowed here
#: would pass locally and then fail (or behave differently) in CI where
#: the real hypothesis runs. Kept in sync with the real package by
#: ``tests/test_hypothesis_stub.py``.
SETTINGS_KWARGS = (
    "max_examples",
    "deadline",
    "derandomize",
    "database",
    "phases",
    "print_blob",
    "report_multiple_bugs",
    "suppress_health_check",
    "verbosity",
    "stateful_step_count",
)


def settings(max_examples: int = 100, deadline=None, **kw):
    unknown = set(kw) - set(SETTINGS_KWARGS)
    if unknown:
        raise TypeError(
            f"hypothesis stub: unknown settings kwargs {sorted(unknown)} "
            f"(known: {list(SETTINGS_KWARGS)}) — if the real hypothesis "
            f"grew a new option, add it to SETTINGS_KWARGS in "
            f"tests/_hypothesis_stub.py so local stub runs cannot "
            f"silently diverge from CI"
        )

    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(**strategies):
    def deco(fn):
        def wrapper(*args, **kwargs):
            n = min(
                getattr(wrapper, "_stub_max_examples", 10), _MAX_EXAMPLES_CAP
            )
            rnd = random.Random(1234)
            for _ in range(n):
                drawn = {k: s.draw(rnd) for k, s in strategies.items()}
                fn(*args, **drawn, **kwargs)

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        # hide the strategy-drawn params from pytest's fixture resolution
        sig = inspect.signature(fn)
        remaining = [
            p for name, p in sig.parameters.items() if name not in strategies
        ]
        wrapper.__signature__ = sig.replace(parameters=remaining)
        wrapper._stub_max_examples = 10
        return wrapper

    return deco


def install() -> None:
    """Register this shim as the ``hypothesis`` package."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    strategies = types.ModuleType("hypothesis.strategies")
    strategies.integers = integers
    strategies.lists = lists
    strategies.sampled_from = sampled_from
    mod.strategies = strategies
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
