"""CP decomposition drivers (the paper's application context)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cp_als import cp_als, cp_gradient
from repro.core.krp import mttkrp_via_matmul
from repro.core.mttkrp import mttkrp
from repro.core.tensor import (
    random_low_rank_tensor,
    relative_error,
    tensor_from_factors,
)


def test_als_recovers_exact_low_rank():
    x, _ = random_low_rank_tensor(jax.random.PRNGKey(0), (12, 10, 8), 3)
    res = cp_als(x, 3, n_iters=60, key=jax.random.PRNGKey(1))
    assert res.final_fit > 0.999
    recon = tensor_from_factors(res.factors)
    assert float(relative_error(x, recon)) < 0.02


def test_als_fit_monotone_after_burnin():
    x, _ = random_low_rank_tensor(jax.random.PRNGKey(2), (10, 9, 8), 4)
    res = cp_als(x, 4, n_iters=25, key=jax.random.PRNGKey(3))
    fits = res.fits[3:]
    assert all(b >= a - 1e-3 for a, b in zip(fits, fits[1:]))


def test_als_dimension_tree_matches_plain():
    x, _ = random_low_rank_tensor(jax.random.PRNGKey(4), (8, 7, 6, 5), 2)
    plain = cp_als(x, 2, n_iters=8, key=jax.random.PRNGKey(5))
    tree = cp_als(
        x, 2, n_iters=8, key=jax.random.PRNGKey(5), use_dimension_tree=True
    )
    for a, b in zip(plain.fits, tree.fits):
        assert abs(a - b) < 5e-3


def test_als_with_matmul_baseline_backend():
    """Any MTTKRP backend must be pluggable: the explicit-KRP baseline gives
    the same decomposition."""
    x, _ = random_low_rank_tensor(jax.random.PRNGKey(6), (9, 8, 7), 2)
    a = cp_als(x, 2, n_iters=10, key=jax.random.PRNGKey(7), mttkrp_fn=mttkrp)
    b = cp_als(
        x, 2, n_iters=10, key=jax.random.PRNGKey(7),
        mttkrp_fn=mttkrp_via_matmul,
    )
    for fa, fb in zip(a.fits, b.fits):
        assert abs(fa - fb) < 5e-3


def test_gradient_driver_converges():
    x, _ = random_low_rank_tensor(jax.random.PRNGKey(8), (10, 8, 6), 2)
    res = cp_gradient(x, 2, n_iters=400, lr=0.03, key=jax.random.PRNGKey(9))
    assert res.final_fit > 0.95


def test_als_4way():
    x, _ = random_low_rank_tensor(jax.random.PRNGKey(10), (6, 5, 4, 7), 2)
    res = cp_als(x, 2, n_iters=40, key=jax.random.PRNGKey(11))
    assert res.final_fit > 0.99


def test_als_noisy_tensor_partial_fit():
    key = jax.random.PRNGKey(12)
    x, _ = random_low_rank_tensor(key, (14, 12, 10), 3)
    noise = 0.01 * jax.random.normal(jax.random.PRNGKey(13), x.shape)
    res = cp_als(x + noise, 3, n_iters=30, key=jax.random.PRNGKey(14))
    assert 0.9 < res.final_fit <= 1.0


def test_als_overdetermined_rank_ok():
    """Rank larger than the true rank must not blow up (ridge regularized)."""
    x, _ = random_low_rank_tensor(jax.random.PRNGKey(15), (8, 8, 8), 2)
    res = cp_als(x, 5, n_iters=15, key=jax.random.PRNGKey(16))
    assert np.isfinite(res.final_fit)
    assert res.final_fit > 0.98
