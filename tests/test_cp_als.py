"""CP decomposition drivers (the paper's application context)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cp_als import cp_als, cp_gradient
from repro.core.krp import mttkrp_via_matmul
from repro.core.mttkrp import mttkrp
from repro.core.tensor import (
    random_low_rank_tensor,
    relative_error,
    tensor_from_factors,
)


def test_als_recovers_exact_low_rank():
    x, _ = random_low_rank_tensor(jax.random.PRNGKey(0), (12, 10, 8), 3)
    res = cp_als(x, 3, n_iters=60, key=jax.random.PRNGKey(1))
    assert res.final_fit > 0.999
    recon = tensor_from_factors(res.factors, res.weights)
    assert float(relative_error(x, recon)) < 0.02


def test_als_weights_not_double_counted():
    """Regression: λ used to be folded into the last-updated factor AND
    returned in CPResult.weights, so reconstructing with weights scaled by
    λ twice.  Now the factors are column-normalized Kruskal form: applying
    weights exactly once reconstructs X; the old double-application leaves
    a large error."""
    x, _ = random_low_rank_tensor(jax.random.PRNGKey(20), (12, 10, 8), 3)
    res = cp_als(x, 3, n_iters=60, key=jax.random.PRNGKey(25))
    assert res.final_fit > 0.999
    # every factor is column-normalized (λ lives only in .weights)
    for f in res.factors:
        np.testing.assert_allclose(
            np.asarray(jnp.linalg.norm(f, axis=0)), 1.0, rtol=1e-4
        )
    once = tensor_from_factors(res.factors, res.weights)
    assert float(relative_error(x, once)) < 0.02
    assert float(relative_error(x, res.reconstruct())) < 0.02
    # the buggy convention (weights applied twice) must NOT reconstruct
    folded = [f for f in res.factors]
    folded[-1] = folded[-1] * res.weights
    twice = tensor_from_factors(folded, res.weights)
    assert float(relative_error(x, twice)) > 0.05


def test_als_fit_monotone_after_burnin():
    x, _ = random_low_rank_tensor(jax.random.PRNGKey(2), (10, 9, 8), 4)
    res = cp_als(x, 4, n_iters=25, key=jax.random.PRNGKey(3))
    fits = res.fits[3:]
    assert all(b >= a - 1e-3 for a, b in zip(fits, fits[1:]))


def test_als_dimension_tree_matches_plain():
    x, _ = random_low_rank_tensor(jax.random.PRNGKey(4), (8, 7, 6, 5), 2)
    plain = cp_als(x, 2, n_iters=8, key=jax.random.PRNGKey(5))
    tree = cp_als(
        x, 2, n_iters=8, key=jax.random.PRNGKey(5), use_dimension_tree=True
    )
    for a, b in zip(plain.fits, tree.fits):
        assert abs(a - b) < 5e-3


def test_als_with_matmul_baseline_backend():
    """Any MTTKRP backend must be pluggable: the explicit-KRP baseline gives
    the same decomposition."""
    x, _ = random_low_rank_tensor(jax.random.PRNGKey(6), (9, 8, 7), 2)
    a = cp_als(x, 2, n_iters=10, key=jax.random.PRNGKey(7), mttkrp_fn=mttkrp)
    b = cp_als(
        x, 2, n_iters=10, key=jax.random.PRNGKey(7),
        mttkrp_fn=mttkrp_via_matmul,
    )
    for fa, fb in zip(a.fits, b.fits):
        assert abs(fa - fb) < 5e-3


def test_distributed_path_rejects_unsupported_combinations():
    """The distributed branch fails loudly (before any mesh work) on
    options the sweep driver cannot honor, instead of silently ignoring
    them."""
    x = jnp.zeros((4, 4, 4))
    with pytest.raises(ValueError, match="mttkrp_fn"):
        cp_als(x, 2, distributed=True, mttkrp_fn=mttkrp)
    with pytest.raises(ValueError, match="use_dimension_tree"):
        cp_als(x, 2, distributed=True, use_dimension_tree=True)
    with pytest.raises(ValueError, match="tune=True is not supported"):
        cp_als(x, 2, distributed=True, backend="auto", tune=True)


def test_gradient_engine_parity():
    """Regression: cp_gradient used to hardcode the naive einsum MTTKRP and
    accept no engine knobs.  It now dispatches through the engine like
    cp_als: the Pallas backend (interpret mode on CPU) yields the same
    optimization trajectory as the einsum backend."""
    x, _ = random_low_rank_tensor(jax.random.PRNGKey(30), (8, 6, 5), 2)
    ein = cp_gradient(x, 2, n_iters=30, lr=0.05, key=jax.random.PRNGKey(31))
    pal = cp_gradient(
        x, 2, n_iters=30, lr=0.05, key=jax.random.PRNGKey(31),
        backend="pallas", interpret=True,
    )
    assert len(ein.fits) == len(pal.fits) > 0
    for a, b in zip(ein.fits, pal.fits):
        assert abs(a - b) < 1e-4, (a, b)


def test_gradient_driver_converges():
    x, _ = random_low_rank_tensor(jax.random.PRNGKey(8), (10, 8, 6), 2)
    res = cp_gradient(x, 2, n_iters=400, lr=0.03, key=jax.random.PRNGKey(9))
    assert res.final_fit > 0.95


def test_als_4way():
    x, _ = random_low_rank_tensor(jax.random.PRNGKey(10), (6, 5, 4, 7), 2)
    res = cp_als(x, 2, n_iters=40, key=jax.random.PRNGKey(11))
    assert res.final_fit > 0.99


def test_als_noisy_tensor_partial_fit():
    key = jax.random.PRNGKey(12)
    x, _ = random_low_rank_tensor(key, (14, 12, 10), 3)
    noise = 0.01 * jax.random.normal(jax.random.PRNGKey(13), x.shape)
    res = cp_als(x + noise, 3, n_iters=30, key=jax.random.PRNGKey(14))
    assert 0.9 < res.final_fit <= 1.0


def test_als_overdetermined_rank_ok():
    """Rank larger than the true rank must not blow up (ridge regularized)."""
    x, _ = random_low_rank_tensor(jax.random.PRNGKey(15), (8, 8, 8), 2)
    res = cp_als(x, 5, n_iters=15, key=jax.random.PRNGKey(16))
    assert np.isfinite(res.final_fit)
    assert res.final_fit > 0.98
