"""The hypothesis fallback shim: loud on unknown kwargs, in sync with CI.

The stub in ``tests/_hypothesis_stub.py`` stands in for the real
``hypothesis`` package when it isn't installed (this container). Two
failure modes are pinned here:

- A ``settings(...)`` kwarg the stub doesn't know must raise a loud
  ``TypeError`` instead of being silently swallowed — a swallowed kwarg
  would make a test pass locally and then behave differently in CI where
  the real package honours (or rejects) it.
- When the real hypothesis IS importable, every name in the stub's
  ``SETTINGS_KWARGS`` must be a real ``hypothesis.settings`` parameter,
  so the stub can never accept something CI would reject.
"""

import importlib.util
import os
import random

import pytest

# load by file path: tests/ is not a package, and when the real
# hypothesis is installed the stub is not on sys.path at all
_spec = importlib.util.spec_from_file_location(
    "_hypothesis_stub_under_test",
    os.path.join(os.path.dirname(__file__), "_hypothesis_stub.py"),
)
stub = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(stub)


def test_unknown_settings_kwarg_raises_type_error():
    with pytest.raises(TypeError, match="bogus_kwarg"):
        stub.settings(max_examples=5, bogus_kwarg=True)


def test_error_message_names_the_known_kwargs():
    with pytest.raises(TypeError, match="max_examples"):
        stub.settings(no_such_option=1)


def test_known_settings_kwargs_accepted():
    deco = stub.settings(
        max_examples=7, deadline=None, derandomize=True, print_blob=False
    )

    def fn():
        pass

    assert deco(fn)._stub_max_examples == 7


def test_given_draws_deterministic_examples():
    seen = []

    @stub.settings(max_examples=5)
    @stub.given(n=stub.integers(min_value=0, max_value=100))
    def prop(n):
        seen.append(n)

    prop()
    assert len(seen) == 5
    rnd = random.Random(1234)
    expected = [rnd.randint(0, 100) for _ in range(5)]
    assert seen == expected


def test_stub_kwargs_are_a_subset_of_real_hypothesis():
    """Stub-vs-real parity: the shim may know FEWER kwargs than the real
    package (new hypothesis options arrive upstream first) but never
    MORE — a stub-only kwarg would pass locally and explode in CI."""
    import sys

    real = sys.modules.get("hypothesis")
    # the conftest-installed shim has no __version__; the real package does
    if real is None or not hasattr(real, "__version__"):
        pytest.skip("real hypothesis not installed (stub is active)")
    import inspect

    params = set(inspect.signature(real.settings.__init__).parameters)
    unknown = set(stub.SETTINGS_KWARGS) - params
    assert not unknown, (
        f"stub SETTINGS_KWARGS not accepted by real hypothesis.settings: "
        f"{sorted(unknown)}"
    )
