"""The perf-trajectory gate: comparison logic and the committed
BENCH_*.json history itself."""

import glob
import json
import os

import pytest

from benchmarks.perf_gate import compare_bench, load_bench, main

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _row(name, us, derived=""):
    return {"name": name, "us_per_call": us, "derived": derived}


def _bench(tmp_path, fname, rows):
    path = tmp_path / fname
    path.write_text(json.dumps({"schema": 1, "results": rows}))
    return str(path)


def test_gate_passes_within_threshold():
    old = {"a": _row("a", 100.0), "b": _row("b", 50.0)}
    new = {"a": _row("a", 140.0), "b": _row("b", 30.0)}
    assert compare_bench(old, new, threshold=0.5) == []


def test_gate_flags_regression_beyond_threshold():
    old = {"a": _row("a", 100.0)}
    new = {"a": _row("a", 151.0)}
    vio = compare_bench(old, new, threshold=0.5)
    assert len(vio) == 1 and "a:" in vio[0] and "1.51x" in vio[0]


def test_gate_threshold_boundary_is_exclusive():
    old = {"a": _row("a", 100.0)}
    new = {"a": _row("a", 150.0)}  # exactly 1.5x with threshold 0.5: pass
    assert compare_bench(old, new, threshold=0.5) == []


def test_gate_ignores_non_matching_rows():
    """Benchmarks come and go; only continuing rows are gated."""
    old = {"gone": _row("gone", 1.0)}
    new = {"fresh": _row("fresh", 1e9)}
    assert compare_bench(old, new, threshold=0.5) == []


def test_gate_flags_error_rows_in_new():
    old = {}
    new = {"mod[ERROR]": _row("mod[ERROR]", 0.0, "boom")}
    vio = compare_bench(old, new, threshold=0.5)
    assert len(vio) == 1 and "errored" in vio[0] and "boom" in vio[0]


def test_gate_skips_zero_baseline():
    """Derived-only rows report 0 us_per_call; no baseline to regress."""
    old = {"tune_cache[entries]": _row("tune_cache[entries]", 0.0)}
    new = {"tune_cache[entries]": _row("tune_cache[entries]", 0.0)}
    assert compare_bench(old, new, threshold=0.5) == []


def test_gate_fused_speedup_floor():
    new = {
        "cp_als_sweep[48x48x48,R8]": _row(
            "cp_als_sweep[48x48x48,R8]", 100.0,
            "backend=einsum;fused_speedup=1.21x;fit_fused=0.99",
        ),
        "cp_als_sweep[96x96x96,R16]": _row(
            "cp_als_sweep[96x96x96,R16]", 100.0,
            "backend=einsum;fused_speedup=0.85x;fit_fused=0.99",
        ),
    }
    vio = compare_bench({}, new, min_fused_speedup=1.0)
    assert len(vio) == 1
    assert "96x96x96" in vio[0] and "0.85x" in vio[0]


def test_gate_require_fused_win():
    """--require-fused-win: at least one sweep row must beat 1x."""
    def sweep(name, s):
        return _row(name, 100.0, f"backend=einsum;fused_speedup={s}x")

    parity = {
        "cp_als_sweep[a]": sweep("cp_als_sweep[a]", "0.97"),
        "cp_als_sweep[b]": sweep("cp_als_sweep[b]", "0.95"),
    }
    vio = compare_bench({}, parity, min_fused_speedup=0.9,
                        require_fused_win=True)
    assert len(vio) == 1 and "no cp_als_sweep row beats" in vio[0]
    winning = dict(parity)
    winning["cp_als_sweep[c]"] = sweep("cp_als_sweep[c]", "1.21")
    assert compare_bench({}, winning, min_fused_speedup=0.9,
                         require_fused_win=True) == []


def test_gate_fused_speedup_requires_rows_and_field():
    vio = compare_bench({}, {}, min_fused_speedup=1.0)
    assert len(vio) == 1 and "unrecorded" in vio[0]
    new = {"cp_als_sweep[a]": _row("cp_als_sweep[a]", 1.0, "no field")}
    vio = compare_bench({}, new, min_fused_speedup=1.0)
    assert len(vio) == 1 and "lacks fused_speedup" in vio[0]


def test_main_exit_codes(tmp_path, capsys):
    old = _bench(tmp_path, "old.json", [_row("a", 100.0)])
    good = _bench(tmp_path, "good.json", [_row("a", 110.0)])
    bad = _bench(tmp_path, "bad.json", [_row("a", 1000.0)])
    assert main([old, good]) == 0
    assert "OK" in capsys.readouterr().out
    assert main([old, bad]) == 1
    assert "PERF REGRESSION" in capsys.readouterr().err
    assert main([old, str(tmp_path / "missing.json")]) == 2


def test_discovery_skips_gracefully_below_two_files(tmp_path, capsys):
    """A young repo (or a fresh fork) has no trajectory to hold yet:
    auto-discovery with fewer than two BENCH_*.json is a skip, not a
    failure."""
    assert main(["--bench-dir", str(tmp_path)]) == 0
    out = capsys.readouterr().out
    assert "skipped" in out and "0 BENCH_*.json" in out
    _bench(tmp_path, "BENCH_2025-01-01.json", [_row("a", 100.0)])
    assert main(["--bench-dir", str(tmp_path)]) == 0
    out = capsys.readouterr().out
    assert "skipped" in out and "1 BENCH_*.json" in out
    assert "BENCH_2025-01-01.json" in out


def test_discovery_compares_two_newest(tmp_path, capsys):
    _bench(tmp_path, "BENCH_2025-01-01.json", [_row("a", 1.0)])
    _bench(tmp_path, "BENCH_2025-02-01.json", [_row("a", 100.0)])
    _bench(tmp_path, "BENCH_2025-03-01.json", [_row("a", 110.0)])
    # the newest pair is 100 -> 110 us (within threshold); the stale
    # 1.0-us file would fail 110x over — proving it isn't compared
    assert main(["--bench-dir", str(tmp_path)]) == 0
    assert "OK" in capsys.readouterr().out


def test_discovery_catches_regression(tmp_path, capsys):
    _bench(tmp_path, "BENCH_2025-01-01.json", [_row("a", 100.0)])
    _bench(tmp_path, "BENCH_2025-02-01.json", [_row("a", 1000.0)])
    assert main(["--bench-dir", str(tmp_path)]) == 1
    assert "PERF REGRESSION" in capsys.readouterr().err


def test_discovery_ignores_non_bench_json(tmp_path, capsys):
    _bench(tmp_path, "results.json", [_row("a", 1.0)])
    _bench(tmp_path, "BENCH_1.json", [_row("a", 1.0)])
    assert main(["--bench-dir", str(tmp_path)]) == 0
    assert "skipped" in capsys.readouterr().out


def test_single_positional_is_usage_error(tmp_path, capsys):
    old = _bench(tmp_path, "BENCH_x.json", [_row("a", 1.0)])
    assert main([old]) == 2
    assert "both OLD and NEW" in capsys.readouterr().err


def test_load_bench_roundtrip(tmp_path):
    path = _bench(tmp_path, "b.json", [_row("x", 1.5, "d=1")])
    loaded = load_bench(path)
    assert loaded["x"]["us_per_call"] == 1.5


def test_committed_bench_history_gates_clean():
    """The two newest committed BENCH files must pass the gate — the same
    invariant CI enforces."""
    files = sorted(glob.glob(os.path.join(_REPO, "BENCH_*.json")))
    if len(files) < 2:
        pytest.skip("need two committed BENCH files")
    old, new = files[-2], files[-1]
    vio = compare_bench(load_bench(old), load_bench(new), threshold=0.5)
    assert vio == [], vio


def test_newest_committed_bench_has_fused_win():
    """The fused-sweep success metric is recorded in the newest committed
    BENCH file: every row within noise of parity, at least one a win —
    the same invariant CI's perf gate enforces."""
    files = sorted(glob.glob(os.path.join(_REPO, "BENCH_*.json")))
    newest = load_bench(files[-1])
    if not any(n.startswith("cp_als_sweep[") for n in newest):
        pytest.skip("newest BENCH predates the fused-sweep rows")
    vio = compare_bench({}, newest, min_fused_speedup=0.9,
                        require_fused_win=True)
    assert vio == [], vio
