"""Two-level-memory simulator: operational validation of the sequential
claims (Alg 1 / Alg 2 exact word counts, Eq 9 feasibility, bound respect)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import bounds
from repro.core.mttkrp import mttkrp
from repro.core.simulator import simulate_blocked, simulate_unblocked


def _ref(x, fs, mode):
    return np.asarray(
        mttkrp(jnp.asarray(x), [jnp.asarray(f) for f in fs], mode)
    )


@pytest.fixture()
def problem(rng):
    x = rng.standard_normal((6, 5, 4))
    fs = [rng.standard_normal((d, 3)) for d in x.shape]
    return x, fs


def test_unblocked_count_matches_formula_and_output(problem):
    x, fs = problem
    for mode in range(3):
        res = simulate_unblocked(x, fs, mode, mem=32)
        assert res.words == int(bounds.seq_unblocked_cost(x.shape, 3))
        np.testing.assert_allclose(res.output, _ref(x, fs, mode), rtol=1e-4, atol=1e-5)
        assert res.peak_fast_words <= 32


def test_blocked_count_within_formula_and_correct(problem):
    x, fs = problem
    for mem in (16, 32, 64, 128):
        b = bounds.best_block_size(x.shape, mem)
        for mode in range(3):
            res = simulate_blocked(x, fs, mode, mem, b)
            assert res.words <= bounds.seq_blocked_cost(x.shape, 3, b) + 1
            np.testing.assert_allclose(
                res.output, _ref(x, fs, mode), rtol=1e-4, atol=1e-5
            )
            # Eq (9): the simulator never exceeded fast memory
            assert res.peak_fast_words <= mem


def test_blocked_respects_lower_bounds(problem):
    x, fs = problem
    for mem in (16, 48):
        res = simulate_blocked(x, fs, 0, mem)
        lb = bounds.seq_lb(x.shape, 3, mem)
        assert res.words >= lb - 1e-9


def test_infeasible_block_rejected(problem):
    x, fs = problem
    with pytest.raises(ValueError):
        simulate_blocked(x, fs, 0, mem=16, block=4)  # 4^3+12 > 16


def test_capacity_enforced(problem):
    x, fs = problem
    with pytest.raises(ValueError):
        simulate_unblocked(x, fs, 0, mem=3)  # < N+2


@settings(max_examples=10, deadline=None)
@given(
    d1=st.integers(2, 6),
    d2=st.integers(2, 6),
    d3=st.integers(2, 6),
    rank=st.integers(1, 4),
    mem=st.integers(20, 200),
    seed=st.integers(0, 99),
)
def test_property_blocked_simulation(d1, d2, d3, rank, mem, seed):
    """For any shape/rank/memory: simulated count <= Eq(10), output correct,
    capacity respected, and >= the max lower bound."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((d1, d2, d3))
    fs = [rng.standard_normal((d, rank)) for d in x.shape]
    mode = seed % 3
    b = bounds.best_block_size(x.shape, mem)
    res = simulate_blocked(x, fs, mode, mem, b)
    assert res.words <= bounds.seq_blocked_cost(x.shape, rank, b) + 1
    assert res.peak_fast_words <= mem
    assert res.words >= bounds.seq_lb(x.shape, rank, mem) - 1e-9
    np.testing.assert_allclose(res.output, _ref(x, fs, mode), rtol=1e-4, atol=1e-5)


def test_blocking_reduces_words_measurably(rng):
    """The paper's point, measured: blocked moves far fewer words than
    unblocked once R(N+1) >> 1."""
    x = rng.standard_normal((12, 12, 12))
    fs = [rng.standard_normal((12, 8)) for _ in range(3)]
    mem = 260  # fits 6^3 + 18 block working set
    un = simulate_unblocked(x, fs, 0, mem)
    bl = simulate_blocked(x, fs, 0, mem)
    assert bl.words < un.words / 3
