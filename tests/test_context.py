"""ExecutionContext: eager validation, immutability, JSON round-trips, and
decision replay (two drivers given the same context resolve identical
plans — pallas dispatch counts included)."""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro import BlockPlan, Distribution, ExecutionContext, Memory
from repro.engine.context import PlanDecision, ProblemSpec
from repro.observe.metrics import PALLAS_DISPATCHES, registry
from repro.tune.cache import isolated_cache


def _dispatches() -> int:
    """Current pallas dispatch counter (the migrated global: bracket
    reads with before/after instead of resetting anything)."""
    return registry().counter(PALLAS_DISPATCHES)


@pytest.fixture()
def tuned_env():
    """Redirect the plan cache so context tests never touch the user's."""
    with isolated_cache() as path:
        yield path


def _problem(dims=(8, 6, 5), rank=3, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), dims)
    fs = [
        jax.random.normal(jax.random.PRNGKey(seed + k + 1), (d, rank))
        for k, d in enumerate(dims)
    ]
    return x, fs


# ---------------------------------------------------------------------------
# frozen-ness / hashability
# ---------------------------------------------------------------------------

def test_context_is_frozen():
    ctx = ExecutionContext.create(backend="pallas")
    with pytest.raises(dataclasses.FrozenInstanceError):
        ctx.backend = "einsum"


def test_context_is_hashable_and_value_equal():
    a = ExecutionContext.create(
        backend="pallas", memory=Memory.abstract(4096), interpret=True
    )
    b = ExecutionContext.create(
        backend="pallas", memory=Memory.abstract(4096), interpret=True
    )
    assert a == b and hash(a) == hash(b)
    assert a != ExecutionContext.create(backend="einsum")
    assert len({a, b}) == 1  # usable as a dict/set key (e.g. program cache)


def test_mesh_is_excluded_from_identity():
    # a mesh is a process-local device handle, not part of the value
    d1 = Distribution(grid=(2, 2, 2), mesh=None)
    d2 = Distribution(grid=(2, 2, 2), mesh=object())
    assert d1 == d2 and hash(d1) == hash(d2)


# ---------------------------------------------------------------------------
# eager validation (the single catalog)
# ---------------------------------------------------------------------------

def test_invalid_backend_rejected_at_construction():
    with pytest.raises(ValueError, match="unknown backend"):
        ExecutionContext.create(backend="gpu-magic")
    with pytest.raises(ValueError, match="unknown backend"):
        ExecutionContext(backend="gpu-magic")  # direct construction too


def test_tune_requires_auto_backend():
    with pytest.raises(ValueError, match="tune=True requires"):
        ExecutionContext.create(backend="einsum", tune=True)
    ExecutionContext.create(backend="auto", tune=True)  # fine


def test_tune_distributed_conflict_rejected_eagerly():
    with pytest.raises(ValueError, match="tune=True is not supported"):
        ExecutionContext.create(
            backend="auto", tune=True, distributed=True
        )


def test_bad_memory_type_rejected():
    with pytest.raises(ValueError, match="Memory"):
        ExecutionContext.create(memory=4096)


def test_bad_grid_rejected_eagerly():
    with pytest.raises(ValueError, match="positive ints"):
        ExecutionContext.create(grid=(0, 2))


def test_grid_extent_mismatch_rejected_at_resolution():
    with pytest.raises(ValueError, match="does not divide tensor extent"):
        ExecutionContext.for_problem((9, 8, 8), 2, grid=(2, 2, 2))


def test_infeasible_memory_rejected_at_resolution():
    # 3-word fast memory cannot hold any Eq-9 working set for a 3-way MTTKRP
    with pytest.raises(ValueError, match="Eq-9"):
        ExecutionContext.for_problem(
            (64, 64, 64), 64, backend="pallas", memory=Memory.abstract(3)
        )


def test_bad_out_dtype_rejected():
    with pytest.raises(ValueError, match="dtype"):
        ExecutionContext.create(out_dtype="notadtype")


# ---------------------------------------------------------------------------
# JSON round-trips
# ---------------------------------------------------------------------------

def test_roundtrip_plain():
    ctx = ExecutionContext.create(backend="einsum")
    assert ExecutionContext.from_json(ctx.to_json()) == ctx


def test_roundtrip_full_fields(tuned_env):
    ctx = ExecutionContext.for_problem(
        (8, 6, 5), 3, dtype=jnp.float32,
        backend="auto", memory=Memory.tpu_vmem(itemsize=4),
        out_dtype="float32", interpret=True,
        grid=None, distributed=True, procs=4, check_rep=False,
    )
    back = ExecutionContext.from_json(ctx.to_json())
    assert back == ctx and hash(back) == hash(ctx)
    # field-level: Memory, grid, dtype policy, decisions all survive
    assert back.memory == ctx.memory
    assert back.distribution.grid == ctx.distribution.grid
    assert back.distribution.check_rep is False
    assert back.out_dtype == "float32"
    assert back.problem == ProblemSpec((8, 6, 5), 3, "float32")
    assert back.decisions == ctx.decisions


def test_roundtrip_preserves_blockplan_exactly():
    plan = BlockPlan(16, (8, 128), 128, x_has_rank=True)
    ctx = ExecutionContext(
        backend="auto",
        problem=ProblemSpec((8, 6, 5), 3),
        decisions=(PlanDecision(0, "pallas", plan, "generic", None, True),),
    )
    back = ExecutionContext.from_json(ctx.to_json())
    assert back.decisions[0].plan == plan
    assert back.decisions[0].variant == "generic"
    assert back.decisions[0].cache_hit is True


def test_json_is_schema_versioned():
    d = json.loads(ExecutionContext.create().to_json())
    assert d["schema"] == "repro.ExecutionContext/1"
    d["schema"] = "repro.ExecutionContext/999"
    with pytest.raises(ValueError, match="schema"):
        ExecutionContext.from_dict(d)


def test_save_load_and_env_seed(tmp_path, monkeypatch):
    ctx = ExecutionContext.create(
        backend="pallas", memory=Memory.abstract(2048), interpret=True
    )
    p = tmp_path / "ctx.json"
    ctx.save(str(p))
    assert ExecutionContext.load(str(p)) == ctx
    # REPRO_CONTEXT as a file path seeds the default context ...
    monkeypatch.setenv("REPRO_CONTEXT", str(p))
    assert ExecutionContext.default() == ctx
    # ... and as inline JSON
    monkeypatch.setenv("REPRO_CONTEXT", ctx.to_json())
    assert ExecutionContext.default() == ctx
    monkeypatch.delenv("REPRO_CONTEXT")
    assert ExecutionContext.default() == ExecutionContext()


def test_env_seed_reaches_drivers(tmp_path, monkeypatch):
    """A REPRO_CONTEXT seed changes what a bare driver call runs."""
    x, fs = _problem()
    ctx = ExecutionContext.create(backend="pallas", interpret=True)
    p = tmp_path / "ctx.json"
    ctx.save(str(p))
    monkeypatch.setenv("REPRO_CONTEXT", str(p))
    before = _dispatches()
    out = repro.mttkrp(x, fs, 0)  # no ctx, no kwargs — seeded from env
    after = _dispatches()
    assert after == before + 1
    np.testing.assert_allclose(
        np.asarray(out),
        np.asarray(repro.mttkrp(x, fs, 0, ctx=ExecutionContext())),
        rtol=2e-4, atol=2e-4,
    )


# ---------------------------------------------------------------------------
# decision replay: same context -> byte-identical plan resolution
# ---------------------------------------------------------------------------

def test_for_problem_pins_auto_decisions(tuned_env):
    ctx = ExecutionContext.for_problem((8, 6, 5), 3, backend="auto")
    assert ctx.problem == ProblemSpec((8, 6, 5), 3, "float32")
    assert [d.mode for d in ctx.decisions] == [0, 1, 2]
    # the pinned decision is what decision_for replays — for this problem
    assert ctx.decision_for((8, 6, 5), 3, 1) == ctx.decisions[1]
    assert ctx.decision_for((9, 6, 5), 3, 1) is None  # different problem


def test_same_context_same_plans_and_dispatch_counts(tuned_env):
    """Two drivers handed the same (round-tripped) context produce
    byte-identical plan resolutions: the tuned pallas plan replays in
    both, with matching kernel dispatch counts."""
    dims, rank = (16, 8, 128), 4
    x, fs = _problem(dims, rank)
    # seed the cache with a pinned pallas winner for this problem
    from repro.tune.cache import CacheEntry, cache_key, default_cache, \
        plan_to_dict

    plan = BlockPlan(8, (8, 128), 128)
    mem = Memory.tpu_vmem(itemsize=4)
    cache = default_cache()
    for mode in range(3):
        perm = (dims[mode],) + tuple(
            s for k, s in enumerate(dims) if k != mode
        )
        cache.put(
            cache_key(perm, rank, mode, jnp.float32, mem),
            CacheEntry(
                backend="pallas", plan=plan_to_dict(plan),
                variant="generic",
            ),
        )
    ctx = ExecutionContext.for_problem(
        dims, rank, backend="auto", interpret=True
    )
    assert all(d.backend == "pallas" and d.cache_hit for d in ctx.decisions)
    assert all(d.plan == plan for d in ctx.decisions)
    ctx2 = ExecutionContext.from_json(ctx.to_json())
    assert ctx2.decisions == ctx.decisions

    def run(c):
        before = _dispatches()
        res = repro.cp_als(
            x, rank, n_iters=2, key=jax.random.PRNGKey(7), ctx=c
        )
        return _dispatches() - before, res

    n1, r1 = run(ctx)
    n2, r2 = run(ctx2)
    assert n1 == n2 and n1 == 2 * 3  # every sweep: one kernel per mode
    for f1, f2 in zip(r1.fits, r2.fits):
        assert f1 == f2
    # the replay does not depend on the cache anymore: clear it, rerun
    cache.clear()
    n3, r3 = run(ctx2)
    assert n3 == n1 and r3.fits == r1.fits


def test_decisions_replay_without_reresolving(tuned_env):
    """A context pinned by for_problem replays its decision even when the
    live cache would now say something else (the point: drivers replay,
    never re-derive)."""
    dims, rank = (8, 6, 5), 3
    x, fs = _problem(dims, rank)
    ctx = ExecutionContext.for_problem(
        dims, rank, backend="auto", interpret=True
    )
    # on CPU the miss path resolves to einsum for every mode
    assert all(d.backend == "einsum" for d in ctx.decisions)
    before = _dispatches()
    repro.mttkrp(x, fs, 0, ctx=ctx)
    assert _dispatches() == before  # replayed einsum, no kernel


def test_for_problem_with_tune_leaves_decisions_unpinned(tuned_env):
    """tune=True must NOT pin model-best decisions (that would silently
    skip the search forever): the first concrete call runs the empirical
    search and persists, later resolution replays the cache."""
    from repro.tune.cache import default_cache

    dims, rank = (8, 6, 5), 2
    x, fs = _problem(dims, rank)
    ctx = ExecutionContext.for_problem(
        dims, rank, backend="auto", tune=True, interpret=True
    )
    assert ctx.decisions == ()  # unpinned: the live path must tune
    assert len(default_cache()) == 0
    repro.mttkrp(x, fs, 0, ctx=ctx)  # first concrete call searches
    assert len(default_cache()) == 1  # ... and persisted a winner


def test_decision_replay_is_dtype_keyed(tuned_env):
    """A context resolved for float32 must not replay its plans on
    float64 data (the Eq-9 working set doubles)."""
    dims, rank = (8, 6, 5), 3
    ctx = ExecutionContext.for_problem(dims, rank, backend="auto")
    assert ctx.decision_for(dims, rank, 0, jnp.float32) is not None
    assert ctx.decision_for(dims, rank, 0, jnp.float64) is None


def test_plan_decision_rejects_unresolved_backend():
    """A decision is a RESOLVED choice; 'auto' (e.g. from a hand-edited
    context file) must fail loudly, not fall into the kernel path."""
    with pytest.raises(ValueError, match="concrete executor"):
        PlanDecision(0, "auto")
    d = ExecutionContext(
        backend="auto", problem=ProblemSpec((4, 4, 4), 2),
        decisions=(PlanDecision(0, "einsum"),),
    ).to_dict()
    d["decisions"][0]["backend"] = "auto"
    with pytest.raises(ValueError, match="concrete executor"):
        ExecutionContext.from_dict(d)


def test_default_is_memoized(monkeypatch):
    monkeypatch.delenv("REPRO_CONTEXT", raising=False)
    assert ExecutionContext.default() is ExecutionContext.default()
    ctx = ExecutionContext.create(backend="blocked_host")
    monkeypatch.setenv("REPRO_CONTEXT", ctx.to_json())
    seeded = ExecutionContext.default()
    assert seeded == ctx and ExecutionContext.default() is seeded


def test_engine_local_fn_rejects_ctx_plus_kwargs():
    from repro.distributed.mttkrp_parallel import engine_local_fn

    with pytest.raises(TypeError, match="not both"):
        engine_local_fn(ExecutionContext.create(), interpret=True)


def test_engine_local_fn_legacy_spellings_shim():
    """Both old spellings — positional string and backend= keyword —
    route through the deprecation shim."""
    import warnings

    from repro.distributed.mttkrp_parallel import engine_local_fn

    x, fs = _problem()
    for call in (
        lambda: engine_local_fn("einsum", True),
        lambda: engine_local_fn(backend="einsum", interpret=True),
    ):
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            fn = call()
        assert sum(
            wi.category is DeprecationWarning for wi in w
        ) == 1, [str(wi.message) for wi in w]
        assert fn(x, fs, 0).shape == (8, 3)


def test_tree_path_honors_out_dtype_policy():
    """contract_partial applies ctx.out_dtype like the plain path: the
    dimension-tree leaves come out in the policy dtype."""
    from repro.engine.tree import all_mode_mttkrp

    x, fs = _problem()
    ctx = ExecutionContext.create(backend="einsum", out_dtype="float16")
    plain = repro.mttkrp(x, fs, 0, ctx=ctx)
    tree = all_mode_mttkrp(x, fs, method="dimtree", ctx=ctx)
    assert plain.dtype == jnp.float16
    assert all(b.dtype == jnp.float16 for b in tree)


def test_cp_sweep_rejects_rank_axis_context():
    x, _ = _problem((8, 8, 8), 2)
    ctx = ExecutionContext.create(grid=(1, 1, 1), p0=2)
    with pytest.raises(ValueError, match="stationary"):
        repro.cp_als(x, 2, ctx=ctx)


def test_distributed_for_problem_pins_grid_not_plans(tuned_env):
    """Distributed contexts pin the grid but no per-mode plan decisions
    (engine work runs on per-shard shapes, so global-shape decisions
    could never replay)."""
    ctx = ExecutionContext.for_problem(
        (16, 16, 16), 4, backend="auto", distributed=True, procs=8
    )
    assert ctx.distribution.grid == (2, 2, 2)
    assert ctx.decisions == ()


# ---------------------------------------------------------------------------
# distribution resolution
# ---------------------------------------------------------------------------

def test_for_problem_resolves_grid_once():
    ctx = ExecutionContext.for_problem(
        (16, 16, 16), 4, distributed=True, procs=8
    )
    assert ctx.distribution.grid == (2, 2, 2)
    # and the resolution is part of the portable value
    back = ExecutionContext.from_json(ctx.to_json())
    assert back.distribution.grid == (2, 2, 2)


def test_local_view_strips_distribution():
    ctx = ExecutionContext.for_problem(
        (16, 16, 16), 4, backend="pallas", distributed=True, procs=8
    )
    loc = ctx.local()
    assert not loc.is_distributed and loc.backend == "pallas"
    assert ctx.is_distributed  # original untouched (immutable)


def test_build_mesh_requires_distribution():
    with pytest.raises(ValueError, match="non-distributed"):
        ExecutionContext.create().build_mesh()


def test_context_as_program_cache_key():
    """The practical payoff of hashability: contexts key compiled-program
    caches directly."""
    cache = {}
    for _ in range(3):
        c = ExecutionContext.create(
            backend="pallas", memory=Memory.abstract(1 << 14)
        )
        cache.setdefault(c, object())
    assert len(cache) == 1
