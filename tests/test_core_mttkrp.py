"""Correctness of every local MTTKRP implementation against the atomic
N-ary-multiply definition (Definition 2.1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.blocked import mttkrp_blocked
from repro.core.dimension_tree import all_mode_mttkrp_dimtree, dimtree_als_sweep
from repro.core.krp import khatri_rao, mttkrp_via_matmul
from repro.core.mttkrp import mttkrp, mttkrp_naive
from repro.core.tensor import (
    dematricize,
    matricize,
    tensor_from_factors,
)

DIMS_3WAY = [(4, 5, 6), (3, 3, 3), (8, 2, 7), (1, 5, 4)]
DIMS_4WAY = [(3, 4, 5, 2), (2, 2, 2, 2)]


def _mk(dims, rank, seed=0, dtype=jnp.float32):
    key = jax.random.PRNGKey(seed)
    kx, *kf = jax.random.split(key, len(dims) + 1)
    x = jax.random.normal(kx, dims, dtype)
    fs = [jax.random.normal(k, (d, rank), dtype) for k, d in zip(kf, dims)]
    return x, fs


@pytest.mark.parametrize("dims", DIMS_3WAY + DIMS_4WAY)
def test_einsum_matches_naive_definition(dims):
    x, fs = _mk(dims, 4)
    for mode in range(len(dims)):
        np.testing.assert_allclose(
            mttkrp(x, fs, mode),
            mttkrp_naive(x, fs, mode),
            rtol=2e-4,
            atol=2e-4,
        )


@pytest.mark.parametrize("dims", DIMS_3WAY + DIMS_4WAY)
def test_matmul_baseline_matches(dims):
    x, fs = _mk(dims, 3, seed=1)
    for mode in range(len(dims)):
        np.testing.assert_allclose(
            mttkrp(x, fs, mode),
            mttkrp_via_matmul(x, fs, mode),
            rtol=2e-4,
            atol=2e-4,
        )


@pytest.mark.parametrize("dims", DIMS_3WAY)
@pytest.mark.parametrize("block", [1, 2, 3, 4, 8])
def test_blocked_matches(dims, block):
    x, fs = _mk(dims, 5, seed=2)
    for mode in range(len(dims)):
        np.testing.assert_allclose(
            mttkrp_blocked(x, fs, mode, block),
            mttkrp(x, fs, mode),
            rtol=2e-4,
            atol=2e-4,
        )


@pytest.mark.parametrize("dims", DIMS_3WAY + DIMS_4WAY)
def test_dimension_tree_all_modes(dims):
    x, fs = _mk(dims, 3, seed=3)
    outs = all_mode_mttkrp_dimtree(x, fs)
    for mode in range(len(dims)):
        np.testing.assert_allclose(
            outs[mode], mttkrp(x, fs, mode), rtol=2e-4, atol=2e-4
        )


def test_dimtree_sweep_gauss_seidel_equivalence():
    """dimtree_als_sweep must deliver the MTTKRP each plain-ALS mode update
    would see (modes < n updated, modes >= n not)."""
    dims = (5, 4, 6, 3)
    x, fs = _mk(dims, 3, seed=4)
    fs_plain = [f + 0 for f in fs]
    seen = {}

    def update(mode, b):
        seen[mode] = b
        return fs_plain[mode] * 1.1  # some deterministic update

    fs_tree = [f + 0 for f in fs]
    dimtree_als_sweep(x, fs_tree, update)
    # replicate with plain ALS ordering
    cur = [f + 0 for f in fs]
    for mode in range(len(dims)):
        expected = mttkrp(x, cur, mode)
        np.testing.assert_allclose(seen[mode], expected, rtol=2e-3, atol=2e-3)
        cur[mode] = cur[mode] * 1.1


def test_khatri_rao_column_convention():
    """matricize(X, n) @ krp(others) == MTTKRP — the orderings must agree."""
    x, fs = _mk((3, 4, 5), 2, seed=5)
    for mode in range(3):
        others = [f for k, f in enumerate(fs) if k != mode]
        out = matricize(x, mode) @ khatri_rao(others)
        np.testing.assert_allclose(
            out, mttkrp(x, fs, mode), rtol=2e-4, atol=2e-4
        )


def test_matricize_roundtrip():
    x, _ = _mk((3, 4, 5, 2), 2, seed=6)
    for mode in range(4):
        np.testing.assert_allclose(
            dematricize(matricize(x, mode), mode, x.shape), x, rtol=1e-6
        )


def test_mttkrp_of_exact_cp_tensor():
    """For X = [[A]] exactly, MTTKRP(X) == A_n @ (hadamard of other grams)."""
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 3)
    fs = [jax.random.normal(k, (d, 3)) for k, d in zip(ks, (6, 5, 4))]
    x = tensor_from_factors(fs)
    for mode in range(3):
        gamma = jnp.ones((3, 3))
        for k in range(3):
            if k != mode:
                gamma = gamma * (fs[k].T @ fs[k])
        np.testing.assert_allclose(
            mttkrp(x, fs, mode), fs[mode] @ gamma, rtol=2e-3, atol=2e-3
        )


@settings(max_examples=25, deadline=None)
@given(
    dims=st.lists(st.integers(1, 6), min_size=2, max_size=4),
    rank=st.integers(1, 5),
    mode_seed=st.integers(0, 10_000),
)
def test_property_einsum_vs_matmul_any_shape(dims, rank, mode_seed):
    """Property: all implementations agree for arbitrary small shapes."""
    dims = tuple(dims)
    mode = mode_seed % len(dims)
    x, fs = _mk(dims, rank, seed=mode_seed)
    a = mttkrp(x, fs, mode)
    b = mttkrp_via_matmul(x, fs, mode)
    np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-4)


@settings(max_examples=15, deadline=None)
@given(
    rank=st.integers(1, 4),
    block=st.integers(1, 5),
    seed=st.integers(0, 10_000),
)
def test_property_blocked_any_block(rank, block, seed):
    x, fs = _mk((6, 5, 7), rank, seed=seed)
    mode = seed % 3
    np.testing.assert_allclose(
        mttkrp_blocked(x, fs, mode, block),
        mttkrp(x, fs, mode),
        rtol=5e-4,
        atol=5e-4,
    )


def test_mttkrp_is_differentiable():
    x, fs = _mk((4, 5, 6), 3, seed=8)

    def loss(f0):
        return jnp.sum(mttkrp(x, [f0] + fs[1:], 1) ** 2)

    g = jax.grad(loss)(fs[0])
    assert g.shape == fs[0].shape
    assert bool(jnp.all(jnp.isfinite(g)))


def test_dtypes():
    for dtype in (jnp.float32, jnp.bfloat16):
        x, fs = _mk((4, 4, 4), 2, seed=9, dtype=dtype)
        out = mttkrp(x, fs, 0)
        assert out.dtype == dtype
        assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))
