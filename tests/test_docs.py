"""Documentation integrity: every link in README.md and docs/*.md must
resolve, documented commands must reference real files, and the runnable
examples must actually run (slow lane; CI also smokes them directly).

This is the satellite program of the docs archetype: documented snippets
and paths rot silently unless something executable pins them.
"""

import os
import re
import subprocess
import sys

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

DOC_FILES = ["README.md", "ROADMAP.md"] + [
    os.path.join("docs", f)
    for f in sorted(os.listdir(os.path.join(ROOT, "docs")))
    if f.endswith(".md")
]

# [text](target) — excluding images; bare autolinks <http://...> are
# format-only (never fetched: CI must not depend on the network)
_LINK_RE = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")
_CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def _links(path):
    with open(os.path.join(ROOT, path)) as f:
        text = f.read()
    text = _CODE_FENCE_RE.sub("", text)  # don't parse code blocks as prose
    return _LINK_RE.findall(text)


def _doc_link_cases():
    cases = []
    for doc in DOC_FILES:
        for target in _links(doc):
            cases.append((doc, target))
    return cases


@pytest.mark.parametrize("doc,target", _doc_link_cases())
def test_markdown_link_resolves(doc, target):
    if target.startswith(("http://", "https://")):
        # external links: format check only (no network in CI)
        assert re.match(r"^https?://[\w.\-]+(/\S*)?$", target), (
            f"{doc}: malformed URL {target!r}"
        )
        return
    if target.startswith("#"):
        # intra-document anchor: the heading must exist
        with open(os.path.join(ROOT, doc)) as f:
            text = f.read()
        slugs = {
            re.sub(r"[^\w\- ]", "", h.strip().lower()).replace(" ", "-")
            for h in re.findall(r"^#+\s+(.*)$", text, re.MULTILINE)
        }
        assert target[1:] in slugs, (
            f"{doc}: anchor {target} matches no heading (have {slugs})"
        )
        return
    rel = target.split("#", 1)[0]
    base = os.path.dirname(os.path.join(ROOT, doc))
    resolved = os.path.normpath(os.path.join(base, rel))
    assert os.path.exists(resolved), (
        f"{doc}: link target {target!r} does not exist ({resolved})"
    )


def test_every_doc_has_links_to_check():
    """The checker must actually be exercising something — a refactor
    that moves the docs should fail loudly, not silently check nothing."""
    assert len(_doc_link_cases()) >= 5


def test_readme_documents_tier1_command_and_layout():
    with open(os.path.join(ROOT, "README.md")) as f:
        text = f.read()
    assert "python -m pytest -x -q" in text
    assert "docs/ARCHITECTURE.md" in text and "docs/API.md" in text
    # the seed-leftover quarantine is documented
    assert "train_lm" in text and "seed" in text.lower()
    # every repo-layout row names a real path
    for path in re.findall(r"`((?:src/repro|benchmarks|examples|docs|tests)[\w/._]*)`", text):
        assert os.path.exists(os.path.join(ROOT, path)), (
            f"README layout names missing path {path!r}"
        )


def test_api_doc_matches_public_surface():
    """docs/API.md must list exactly repro.__all__ (the same pin the
    API-stability gate enforces in code)."""
    sys.path.insert(0, os.path.join(ROOT, "src"))
    import repro

    with open(os.path.join(ROOT, "docs", "API.md")) as f:
        text = f.read()
    for name in repro.__all__:
        assert f'"{name}"' in text, (
            f"docs/API.md does not document repro.{name}"
        )


def _run_example(name, env_extra=None, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["REPRO_EX_TINY"] = "1"
    env.update(env_extra or {})
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "examples", name)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    return proc.stdout


@pytest.mark.slow
def test_quickstart_example_runs():
    out = _run_example("quickstart.py")
    assert "autotuner winner" in out and "round-trip OK" in out


@pytest.mark.slow
def test_tucker_example_runs():
    out = _run_example("tucker.py")
    assert "pinned multi_ttm decisions" in out
    assert "sweep-optimal grid" in out
