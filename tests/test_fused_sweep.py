"""The fused (mode-reuse) ALS sweep: the two-output pair kernel, the
Gauss-Seidel-exactness of the schedule, the sweep planner, the ``sweep=``
driver knob, and the ``kind="sweep"`` tune-cache path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cp_als import cp_als
from repro.core.tensor import random_low_rank_tensor
from repro.engine import Memory, mttkrp
from repro.engine.context import ExecutionContext
from repro.engine.plan import (
    choose_sweep_blocks,
    fused_pair_working_set_words,
)
from repro.engine.sweep import fused_als_sweep
from repro.kernels.sweep import (
    fused_pair_canonical_pallas,
    mttkrp_fused_pair_pallas,
)
from repro.tune import PlanCache, cache_key, isolated_cache
from repro.tune.search import resolve_sweep, tune_sweep


def _mk(dims, rank, seed=0, dtype=jnp.float32):
    key = jax.random.PRNGKey(seed)
    kx, *kf = jax.random.split(key, len(dims) + 1)
    x = jax.random.normal(kx, dims, dtype)
    fs = [jax.random.normal(k, (d, rank), dtype) for k, d in zip(kf, dims)]
    return x, fs


def _pair_oracle(x, factors):
    """B0 (full MTTKRP mode 0) and P' = X x_{N-1} A_{N-1} via einsum."""
    n = x.ndim
    b0 = mttkrp(x, factors, 0, backend="einsum")
    letters = "abcdefg"[:n]
    p = jnp.einsum(
        f"{letters},{letters[-1]}r->{letters[:-1]}r", x, factors[n - 1]
    )
    return b0, p


# ---------------------------------------------------------------------------
# The two-output pair kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dims,rank", [((16, 8, 8), 4), ((8, 8, 4, 8), 3)])
def test_fused_pair_kernel_matches_oracle(dims, rank):
    x, fs = _mk(dims, rank, seed=1)
    b0_ref, p_ref = _pair_oracle(x, fs)
    b0, p = fused_pair_canonical_pallas(x, fs[1:], interpret=True)
    np.testing.assert_allclose(
        np.asarray(b0), np.asarray(b0_ref), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(p), np.asarray(p_ref), rtol=1e-4, atol=1e-4
    )


def test_fused_pair_kernel_raw_blocked():
    """The raw kernel on aligned shapes, non-trivial grid in every axis."""
    dims, rank = (16, 8, 16), 8
    x, fs = _mk(dims, rank, seed=2)
    b0, p = mttkrp_fused_pair_pallas(
        x, fs[1:], block_i=8, block_contract=(4, 8), block_r=8,
        interpret=True,
    )
    b0_ref, p_ref = _pair_oracle(x, fs)
    np.testing.assert_allclose(
        np.asarray(b0), np.asarray(b0_ref), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(p), np.asarray(p_ref), rtol=1e-4, atol=1e-4
    )


def test_fused_pair_padding_path():
    """Ragged shapes go through the canonical wrapper's pad/unpad."""
    dims, rank = (13, 9, 17), 5
    x, fs = _mk(dims, rank, seed=3)
    b0, p = fused_pair_canonical_pallas(x, fs[1:], interpret=True)
    b0_ref, p_ref = _pair_oracle(x, fs)
    np.testing.assert_allclose(
        np.asarray(b0), np.asarray(b0_ref), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(p), np.asarray(p_ref), rtol=1e-4, atol=1e-4
    )


# ---------------------------------------------------------------------------
# Gauss-Seidel exactness of the fused schedule
# ---------------------------------------------------------------------------

def _als_update_closure(factors, rank, solve_dtype=jnp.float32):
    grams = [f.T @ f for f in factors]

    def update(mode, b):
        gamma = jnp.ones((rank, rank), solve_dtype)
        for k, g in enumerate(grams):
            if k != mode:
                gamma = gamma * g.astype(solve_dtype)
        ridge = 1e-5 * jnp.trace(gamma) / rank + 1e-12
        a = jnp.linalg.solve(
            gamma + ridge * jnp.eye(rank, dtype=solve_dtype),
            b.astype(solve_dtype).T,
        ).T.astype(b.dtype)
        grams[mode] = a.T @ a
        return a

    return update


@pytest.mark.parametrize("dims,rank", [((12, 10, 8), 4), ((8, 6, 5, 7), 3)])
@pytest.mark.parametrize("backend", ["einsum", "pallas"])
def test_fused_sweep_is_gauss_seidel_exact(dims, rank, backend):
    """One fused sweep == one per-mode sweep with the SAME update closure:
    every mode's MTTKRP sees exactly the factors sequential GS would."""
    x, fs0 = _mk(dims, rank, seed=4)
    ctx = ExecutionContext.create(backend=backend, interpret=True)

    ref = [f for f in fs0]
    upd = _als_update_closure(ref, rank)
    for it in range(2):
        for mode in range(len(dims)):
            ref[mode] = upd(mode, mttkrp(x, ref, mode, ctx=ctx))

    fused = [f for f in fs0]
    upd2 = _als_update_closure(fused, rank)
    for it in range(2):
        fused_als_sweep(x, fused, upd2, ctx=ctx)

    for k in range(len(dims)):
        np.testing.assert_allclose(
            np.asarray(fused[k]), np.asarray(ref[k]), rtol=1e-3, atol=1e-4
        )


def test_fused_sweep_matrix_fallback():
    """ndim < 3 falls back to the per-mode chain (nothing to reuse)."""
    x, fs0 = _mk((12, 9), 3, seed=5)
    ctx = ExecutionContext.create(backend="einsum")
    ref = [f for f in fs0]
    upd = _als_update_closure(ref, 3)
    for mode in range(2):
        ref[mode] = upd(mode, mttkrp(x, ref, mode, ctx=ctx))
    fused = [f for f in fs0]
    fused_als_sweep(x, fused, _als_update_closure(fused, 3), ctx=ctx)
    for k in range(2):
        np.testing.assert_allclose(
            np.asarray(fused[k]), np.asarray(ref[k]), rtol=1e-4, atol=1e-5
        )


# ---------------------------------------------------------------------------
# Sweep planner: the mode-reuse working set fits the budget
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("budget", [1 << 15, 1 << 17, 1 << 20])
def test_choose_sweep_blocks_fits_budget(budget):
    shape, rank = (64, 48, 96), 16
    mem = Memory(budget_bytes=budget)
    plan = choose_sweep_blocks(shape, rank, 4, memory=mem)
    ws = fused_pair_working_set_words(plan) * 4
    assert ws <= budget, (ws, budget, plan)
    # and the plan still tiles the (padded) problem
    for s, b in zip(plan.padded_shape(shape)[1:], plan.block_contract):
        assert s % b == 0


def test_fused_working_set_exceeds_single_mode():
    """The pair kernel keeps BOTH accumulators resident, so its working
    set strictly contains the single-MTTKRP one (the planner must budget
    for the P' tile too)."""
    from repro.engine.plan import choose_blocks

    shape, rank = (64, 48, 96), 16
    plan = choose_blocks(shape, rank, 4)
    assert fused_pair_working_set_words(plan) > plan.working_set_words()


# ---------------------------------------------------------------------------
# The cp_als sweep= knob
# ---------------------------------------------------------------------------

def test_cp_als_fused_matches_per_mode():
    dims, rank = (16, 14, 12), 4
    x, _ = random_low_rank_tensor(jax.random.PRNGKey(6), dims, rank)
    key = jax.random.PRNGKey(7)
    per = cp_als(x, rank, n_iters=10, key=key, sweep="per_mode")
    fus = cp_als(x, rank, n_iters=10, key=key, sweep="fused")
    for fp, ff in zip(per.fits, fus.fits):
        assert abs(fp - ff) < 1e-3, (per.fits, fus.fits)
    for k in range(3):
        np.testing.assert_allclose(
            np.asarray(fus.factors[k]), np.asarray(per.factors[k]),
            rtol=2e-3, atol=2e-4,
        )
    assert fus.final_fit > 0.999


def test_cp_als_sweep_knob_validation():
    x, _ = _mk((8, 8, 8), 3)
    with pytest.raises(ValueError, match="unknown sweep"):
        cp_als(x, 3, n_iters=1, sweep="bogus")
    with pytest.raises(ValueError, match="use_dimension_tree"):
        cp_als(x, 3, n_iters=1, sweep="fused", use_dimension_tree=True)
    ctx = ExecutionContext.create(distributed=True, procs=1)
    with pytest.raises(ValueError, match="distributed"):
        cp_als(x, 3, n_iters=1, sweep="fused", ctx=ctx)


def test_cp_als_sweep_dimtree_alias():
    """sweep="dimtree" is the explicit spelling of use_dimension_tree."""
    dims, rank = (12, 12, 12), 3
    x, _ = random_low_rank_tensor(jax.random.PRNGKey(8), dims, rank)
    key = jax.random.PRNGKey(9)
    a = cp_als(x, rank, n_iters=4, key=key, use_dimension_tree=True)
    b = cp_als(x, rank, n_iters=4, key=key, sweep="dimtree")
    for fa, fb in zip(a.fits, b.fits):
        assert abs(fa - fb) < 1e-6


# ---------------------------------------------------------------------------
# kind="sweep" tune-cache keys
# ---------------------------------------------------------------------------

def test_tune_sweep_persists_and_resolves():
    dims, rank = (24, 20, 16), 6
    x, _ = _mk(dims, rank, seed=10)
    mem = Memory.tpu_vmem(itemsize=x.dtype.itemsize)
    with isolated_cache() as path:
        cache = PlanCache(path)
        res = tune_sweep(x, rank, cache=cache, metric="traffic")
        assert res.winner.variant in ("fused", "per_mode")
        assert not res.cache_hit
        key = cache_key(dims, rank, -1, x.dtype, mem, kind="sweep")
        assert cache.get(key) is not None
        # second call is a cache hit (no re-measure): same resolution
        res2 = tune_sweep(x, rank, cache=cache, metric="traffic")
        assert res2.cache_hit and res2.winner.variant == res.winner.variant
        hit = resolve_sweep(dims, rank, x.dtype, cache=cache)
        assert hit.variant == res.winner.variant and hit.cache_hit
    # traffic model prefers fused for N>=3 (2 passes vs N)
    assert res.winner.variant == "fused"


def test_resolve_sweep_miss_defaults():
    with isolated_cache() as path:
        cache = PlanCache(path)
        miss = resolve_sweep((16, 16, 16), 4, jnp.float32, cache=cache)
        assert miss.variant == "fused" and not miss.cache_hit
        miss2 = resolve_sweep((16, 16), 4, jnp.float32, cache=cache)
        assert miss2.variant == "per_mode"


def test_cp_als_sweep_auto_converges():
    dims, rank = (16, 12, 10), 3
    x, _ = random_low_rank_tensor(jax.random.PRNGKey(11), dims, rank)
    with isolated_cache():
        res = cp_als(x, rank, n_iters=15, key=jax.random.PRNGKey(12),
                     sweep="auto")
    assert res.final_fit > 0.999
