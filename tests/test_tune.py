"""Autotuning subsystem: plan cache, empirical search, ``backend="auto"``,
calibration, and the ``choose_blocks`` degenerate-input regressions.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.engine import execute
from repro.engine.plan import BlockPlan, Memory, choose_blocks
from repro.kernels.ref import mttkrp_ref
from repro.tune.cache import (
    SCHEMA_VERSION,
    CacheEntry,
    PlanCache,
    cache_key,
    plan_from_dict,
    plan_to_dict,
)
from repro.tune.calibrate import calibrate, calibration_report
from repro.tune.search import (
    generate_candidates,
    resolve,
    search,
    tune_mttkrp,
)

# empirical searches + interpret-mode kernel measurement are slow on CPU
pytestmark = pytest.mark.slow


@pytest.fixture
def tuned_env(tmp_path, monkeypatch):
    """Isolated plan cache for everything that goes through default_cache."""
    path = str(tmp_path / "plans.json")
    monkeypatch.setenv("REPRO_TUNE_CACHE", path)
    return path


def _problem(dims=(16, 12, 8), rank=4, seed=0):
    key = jax.random.PRNGKey(seed)
    kx, *kf = jax.random.split(key, len(dims) + 1)
    x = jax.random.normal(kx, dims, jnp.float32)
    fs = [
        jax.random.normal(k, (d, rank), jnp.float32)
        for k, d in zip(kf, dims)
    ]
    return x, fs


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------

def test_plan_roundtrip_exact():
    plan = BlockPlan(24, (8, 120), 40, x_has_rank=True)
    assert plan_from_dict(plan_to_dict(plan)) == plan


def test_cache_persistence_roundtrip(tmp_path):
    path = str(tmp_path / "c.json")
    plan = BlockPlan(16, (8, 128), 64)
    key = cache_key((16, 12, 8), 4, 0, jnp.float32, Memory.tpu_vmem())
    c1 = PlanCache(path)
    c1.put(key, CacheEntry("pallas", plan_to_dict(plan), variant="generic",
                           score=12.5, walltime_us=12.5))
    c2 = PlanCache(path)  # fresh instance: must read from disk
    entry = c2.get(key)
    assert entry is not None
    assert entry.backend == "pallas"
    assert entry.variant == "generic"
    assert entry.to_plan() == plan  # exact BlockPlan reproduction


def test_cache_key_invalidation_on_memory_and_dtype():
    base = cache_key((16, 12, 8), 4, 0, jnp.float32, Memory.tpu_vmem())
    other_mem = cache_key(
        (16, 12, 8), 4, 0, jnp.float32,
        Memory.tpu_vmem(budget_bytes=1 << 20),
    )
    other_dtype = cache_key((16, 12, 8), 4, 0, jnp.bfloat16, Memory.tpu_vmem())
    other_kind = cache_key(
        (16, 12, 8), 4, 0, jnp.float32, Memory.tpu_vmem(), kind="partial"
    )
    assert len({base, other_mem, other_dtype, other_kind}) == 4


def test_cache_schema_version_invalidates(tmp_path):
    path = str(tmp_path / "c.json")
    c1 = PlanCache(path)
    c1.put("k", CacheEntry("einsum"))
    raw = json.load(open(path))
    raw["schema"] = SCHEMA_VERSION + 1
    json.dump(raw, open(path, "w"))
    c2 = PlanCache(path)
    assert c2.get("k") is None  # whole file invalidated
    assert len(c2) == 0
    c2.put("k2", CacheEntry("einsum"))  # and it can re-persist cleanly
    assert PlanCache(path).get("k2") is not None


@pytest.mark.parametrize(
    "content", [b"not json{{{", b"", b'{"schema": 1, "entries": 42}',
                b'[1, 2, 3]']
)
def test_cache_corrupted_file_recovers(tmp_path, content):
    path = str(tmp_path / "c.json")
    with open(path, "wb") as f:
        f.write(content)
    c = PlanCache(path)
    assert len(c) == 0  # never crashes
    c.put("k", CacheEntry("einsum"))
    assert PlanCache(path).get("k").backend == "einsum"


def test_corrupted_cache_falls_back_to_analytic(tmp_path, monkeypatch):
    path = str(tmp_path / "plans.json")
    with open(path, "w") as f:
        f.write("garbage")
    monkeypatch.setenv("REPRO_TUNE_CACHE", path)
    x, fs = _problem()
    out = execute.mttkrp(x, fs, 0, backend="auto")  # must not raise
    np.testing.assert_allclose(
        out, mttkrp_ref(x, fs, 0), rtol=5e-4, atol=5e-4
    )


# ---------------------------------------------------------------------------
# choose_blocks degenerate inputs (regression pins)
# ---------------------------------------------------------------------------

def test_choose_blocks_size1_output_mode_not_padded():
    plan = choose_blocks((1, 64, 64), 16)
    assert plan.block_i == 1  # not padded to a sublane tile
    assert plan.padded_shape((1, 64, 64))[0] == 1


def test_choose_blocks_size1_contract_mode_not_padded():
    plan = choose_blocks((64, 1, 64), 16)
    assert plan.block_contract[0] == 1
    assert plan.padded_shape((64, 1, 64))[1] == 1


def test_choose_blocks_small_rank_not_padded_to_lane():
    plan = choose_blocks((64, 64, 64), 4)
    assert plan.block_r == 4  # rank below the lane width: full extent
    ws_small = plan.working_set_words()
    ws_padded = BlockPlan(
        plan.block_i, plan.block_contract, 128
    ).working_set_words()
    assert ws_small < ws_padded  # no phantom 32x factor traffic


def test_choose_blocks_aligned_when_extent_allows():
    plan = choose_blocks((512, 512, 512), 256)
    assert plan.block_i % 8 == 0
    assert plan.block_r % 128 == 0
    assert plan.block_contract[-1] % 128 == 0


def test_choose_blocks_tiny_budget_still_feasible():
    """Before the fix the shrink loop bottomed out at alignment floors and
    returned Eq-9-infeasible plans for small memories."""
    mem = Memory.tpu_vmem(budget_bytes=32 * 1024)
    plan = choose_blocks((512, 512, 512), 256, memory=mem)
    assert plan.fits(mem)


@pytest.mark.parametrize("dims,rank", [((1, 32, 24), 4), ((24, 1, 16), 3),
                                       ((16, 12, 1), 5), ((1, 1, 8, 8), 2)])
def test_degenerate_plans_run_correctly(dims, rank):
    """Kernel correctness with the unpadded degenerate plans."""
    x, fs = _problem(dims, rank)
    plan = choose_blocks(dims, rank)
    out = execute.mttkrp(
        x, fs, 0, backend="pallas", plan=plan, interpret=True
    )
    np.testing.assert_allclose(
        out, mttkrp_ref(x, fs, 0), rtol=5e-4, atol=5e-4
    )


# ---------------------------------------------------------------------------
# candidate generation + search
# ---------------------------------------------------------------------------

def test_candidates_cover_executors_and_variants():
    cands = generate_candidates((16, 12, 8), 4, Memory.tpu_vmem())
    backends = {c.backend for c in cands}
    assert backends == {"einsum", "blocked_host", "pallas"}
    variants = {c.variant for c in cands if c.backend == "pallas"}
    assert variants == {"specialized", "generic"}  # both 3-way kernels
    plans = {c.plan for c in cands if c.backend == "pallas"}
    assert len(plans) > 1  # perturbed neighborhood, not just the analytic


def test_candidates_4way_generic_only():
    cands = generate_candidates((8, 8, 8, 8), 4, Memory.tpu_vmem())
    variants = {c.variant for c in cands if c.backend == "pallas"}
    assert variants == {"generic"}


def test_search_winner_is_fastest_measured(tuned_env):
    x, fs = _problem()
    res = search(x, fs, 0, interpret=True, reps=1, warmup=0)
    finite = [
        m for m in res.measurements
        if m.ok and np.isfinite(m.walltime_us)
    ]
    assert res.winner == min(finite, key=lambda m: m.walltime_us).candidate


def test_kernel_variant_generic_on_3way_correct():
    x, fs = _problem()
    out = execute.mttkrp(
        x, fs, 0, backend="pallas", kernel_variant="generic", interpret=True
    )
    np.testing.assert_allclose(
        out, mttkrp_ref(x, fs, 0), rtol=5e-4, atol=5e-4
    )


# ---------------------------------------------------------------------------
# backend="auto"
# ---------------------------------------------------------------------------

def test_auto_cold_falls_back_to_model_best(tuned_env):
    x, fs = _problem()
    r = resolve(x.shape, 4, 0, x.dtype, None)
    assert not r.cache_hit
    out = execute.mttkrp(x, fs, 0, backend="auto")
    np.testing.assert_allclose(
        out, mttkrp_ref(x, fs, 0), rtol=5e-4, atol=5e-4
    )


def test_auto_tune_persists_and_replays_exactly(tuned_env):
    x, fs = _problem()
    res = tune_mttkrp(x, fs, 0, interpret=True, reps=1, warmup=0)
    assert not res.cache_hit
    # warm: resolve reproduces the tuned configuration exactly, no search
    r = resolve(x.shape, 4, 0, x.dtype, None)
    assert r.cache_hit
    assert r.backend == res.winner.backend
    assert r.plan == res.winner.plan
    assert r.variant == res.winner.variant
    assert r.block == res.winner.block
    # a second tune call is a pure cache hit
    res2 = tune_mttkrp(x, fs, 0, interpret=True)
    assert res2.cache_hit and res2.winner == res.winner
    # and the entry survives a fresh cache instance reading the same file
    fresh = PlanCache(tuned_env)
    entry = fresh.get(r.key)
    assert entry is not None and entry.backend == res.winner.backend
    assert entry.to_plan() == res.winner.plan
    out = execute.mttkrp(x, fs, 0, backend="auto")
    np.testing.assert_allclose(
        out, mttkrp_ref(x, fs, 0), rtol=5e-4, atol=5e-4
    )


def test_auto_via_execute_tune_flag(tuned_env):
    x, fs = _problem((12, 10, 8), 3)
    out = execute.mttkrp(x, fs, 0, backend="auto", tune=True)
    np.testing.assert_allclose(
        out, mttkrp_ref(x, fs, 0), rtol=5e-4, atol=5e-4
    )
    r = resolve((12, 10, 8), 3, 0, x.dtype, None)
    assert r.cache_hit  # the tune flag persisted the winner


def test_auto_is_trace_safe(tuned_env):
    """resolve() under jit: static shapes only, no measurement attempted."""
    x, fs = _problem()

    @jax.jit
    def f(x, fs):
        return execute.mttkrp(x, tuple(fs), 0, backend="auto", tune=True)

    np.testing.assert_allclose(
        f(x, fs), mttkrp_ref(x, fs, 0), rtol=5e-4, atol=5e-4
    )


def test_auto_in_dimtree_and_cp_als(tuned_env):
    from repro.core.cp_als import cp_als
    from repro.core.tensor import random_low_rank_tensor
    from repro.engine.tree import all_mode_mttkrp

    x, fs = _problem()
    outs = all_mode_mttkrp(x, fs, method="dimtree", backend="auto")
    for m, b in enumerate(outs):
        np.testing.assert_allclose(
            b, mttkrp_ref(x, fs, m), rtol=5e-4, atol=5e-4
        )
    xt, _ = random_low_rank_tensor(jax.random.PRNGKey(0), (12, 10, 8), 3)
    res = cp_als(xt, 3, n_iters=8, backend="auto", tune=True)
    assert res.final_fit > 0.8


def test_cache_key_includes_platform():
    """Winners are platform-specific: a CPU-tuned entry must never be
    replayed on TPU (and vice versa)."""
    key = cache_key((16, 12, 8), 4, 0, jnp.float32, Memory.tpu_vmem())
    assert f"platform={jax.default_backend()}" in key


def test_traffic_metric_scores_are_modeled_bytes(tuned_env):
    x, fs = _problem()
    res = search(x, fs, 0, metric="traffic", interpret=True, reps=1,
                 warmup=0)
    for m in res.measurements:
        if m.candidate.backend == "pallas":
            assert m.score == float(m.modeled_bytes)
        elif np.isfinite(m.walltime_us):
            assert m.score == m.walltime_us


def test_tune_partial_persists_and_replays(tuned_env):
    from repro.tune.search import tune_partial

    x, fs = _problem((12, 10, 8), 3)
    res = tune_partial(x, fs, (0, 1, 2), (1, 2), False, interpret=True,
                       reps=1, warmup=0)
    assert not res.cache_hit
    assert res.key.startswith("partial|")
    res2 = tune_partial(x, fs, (0, 1, 2), (1, 2), False, interpret=True)
    assert res2.cache_hit and res2.winner == res.winner


def test_dimtree_auto_tune_writes_partial_entries(tuned_env):
    """cp_als(backend="auto", tune=True, use_dimension_tree=True) must
    actually tune the tree edges, not silently cache nothing."""
    from repro.core.cp_als import cp_als
    from repro.core.tensor import random_low_rank_tensor

    xt, _ = random_low_rank_tensor(jax.random.PRNGKey(0), (12, 10, 8), 3)
    res = cp_als(xt, 3, n_iters=4, backend="auto", tune=True,
                 use_dimension_tree=True)
    assert res.final_fit > 0.8
    partial_keys = [
        k for k in PlanCache(tuned_env).keys() if k.startswith("partial|")
    ]
    assert partial_keys  # the sweep persisted tuned tree edges
    # and the warm sweep replays them (resolve hits, same fit path)
    res2 = cp_als(xt, 3, n_iters=4, backend="auto",
                  use_dimension_tree=True)
    assert res2.final_fit == pytest.approx(res.final_fit, abs=1e-6)


def test_unknown_backend_message_mentions_auto():
    x, fs = _problem()
    with pytest.raises(ValueError, match="auto"):
        execute.mttkrp(x, fs, 0, backend="nope")


# ---------------------------------------------------------------------------
# calibration
# ---------------------------------------------------------------------------

def test_calibrate_requires_three_shapes(tuned_env):
    with pytest.raises(ValueError):
        calibrate([((8, 8, 8), 2)], persist=False)


def test_calibration_reports_model_vs_measured(tuned_env):
    cases = (((24, 20, 16), 4), ((32, 24, 16), 8), ((20, 16, 12, 8), 4))
    cal = calibrate(cases, reps=1)
    assert len(cal.rows) >= 3
    for r in cal.rows:
        assert r.model_bytes > 0 and r.measured_bytes > 0
        assert np.isfinite(r.traffic_rel_err)
        assert np.isfinite(r.predicted_us)
    report = calibration_report(cal)
    assert report.count("\n") >= 4  # header + fit + one line per shape
    assert "traffic_err" in report
    # persisted: a fresh cache instance can reload the coefficients
    from repro.tune.calibrate import load_calibration

    loaded = load_calibration(PlanCache(tuned_env))
    assert loaded is not None
    assert loaded.bandwidth_bytes_per_us == cal.bandwidth_bytes_per_us
    assert len(loaded.rows) == len(cal.rows)
