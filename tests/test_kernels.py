"""Pallas MTTKRP kernel vs pure-jnp oracle: shape/dtype sweeps (interpret
mode — kernel-body semantics executed on CPU), block-plan properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.ops import (
    VMEM_BUDGET,
    BlockPlan,
    choose_blocks,
    mttkrp_pallas,
    mttkrp_traffic_model,
)
from repro.kernels.ref import mttkrp_ref

# interpret-mode kernel sweeps dominate the suite's wall time
pytestmark = pytest.mark.slow

SHAPES_3 = [
    (8, 8, 8),
    (16, 4, 32),
    (5, 7, 9),          # nothing aligned
    (1, 3, 2),          # degenerate
    (130, 6, 200),      # crosses block boundaries
    (64, 64, 64),
]
SHAPES_4 = [(4, 5, 6, 3), (9, 3, 3, 10), (8, 8, 8, 8)]


def _mk(dims, rank, seed=0, dtype=jnp.float32):
    key = jax.random.PRNGKey(seed)
    kx, *kf = jax.random.split(key, len(dims) + 1)
    x = jax.random.normal(kx, dims, dtype)
    fs = [jax.random.normal(k, (d, rank), dtype) for k, d in zip(kf, dims)]
    return x, fs


@pytest.mark.parametrize("dims", SHAPES_3)
@pytest.mark.parametrize("rank", [1, 4, 16])
def test_kernel3_all_modes(dims, rank):
    x, fs = _mk(dims, rank)
    for mode in range(3):
        out = mttkrp_pallas(x, fs, mode, interpret=True)
        np.testing.assert_allclose(
            out, mttkrp_ref(x, fs, mode), rtol=2e-4, atol=2e-4
        )


@pytest.mark.parametrize("dims", SHAPES_4)
def test_kernel4_all_modes(dims):
    x, fs = _mk(dims, 5, seed=1)
    for mode in range(4):
        out = mttkrp_pallas(x, fs, mode, interpret=True)
        np.testing.assert_allclose(
            out, mttkrp_ref(x, fs, mode), rtol=2e-4, atol=2e-4
        )


@pytest.mark.parametrize(
    "dtype,rtol",
    [(jnp.float32, 2e-4), (jnp.bfloat16, 5e-2)],
)
def test_kernel_dtypes(dtype, rtol):
    x, fs = _mk((24, 16, 32), 8, seed=2, dtype=dtype)
    out = mttkrp_pallas(x, fs, 0, interpret=True)
    assert out.dtype == dtype
    np.testing.assert_allclose(
        out.astype(jnp.float32), mttkrp_ref(x, fs, 0), rtol=rtol, atol=rtol
    )


def test_kernel_explicit_plans():
    """Sweep explicit block plans (the kernel must be correct for any
    feasible tiling, not just the auto-chosen one)."""
    x, fs = _mk((32, 24, 40), 12, seed=3)
    for plan in [
        BlockPlan(8, (8, 128), 128),
        BlockPlan(16, (8, 128), 128),
        BlockPlan(32, (16, 128), 128),
        BlockPlan(128, (8, 256), 128),
    ]:
        out = mttkrp_pallas(x, fs, 0, interpret=True, plan=plan)
        np.testing.assert_allclose(
            out, mttkrp_ref(x, fs, 0), rtol=2e-4, atol=2e-4
        )


@settings(max_examples=20, deadline=None)
@given(
    d1=st.integers(1, 40),
    d2=st.integers(1, 24),
    d3=st.integers(1, 40),
    rank=st.integers(1, 20),
    seed=st.integers(0, 1000),
)
def test_property_kernel_any_shape(d1, d2, d3, rank, seed):
    x, fs = _mk((d1, d2, d3), rank, seed=seed)
    mode = seed % 3
    out = mttkrp_pallas(x, fs, mode, interpret=True)
    np.testing.assert_allclose(
        out, mttkrp_ref(x, fs, mode), rtol=5e-4, atol=5e-4
    )


@settings(max_examples=30, deadline=None)
@given(
    d1=st.integers(1, 4096),
    d2=st.integers(1, 4096),
    d3=st.integers(1, 4096),
    rank=st.integers(1, 2048),
)
def test_property_block_plan_fits_vmem(d1, d2, d3, rank):
    """Eq-9 analogue: the chosen working set always fits the VMEM budget and
    blocks respect TPU alignment floors — or cover the full (sub-unit)
    extent, in which case the padded array is its own size and alignment
    is moot (the degenerate-input fix)."""
    plan = choose_blocks((d1, d2, d3), rank)
    assert plan.working_set_words() * 4 <= VMEM_BUDGET
    assert plan.block_i % 8 == 0 or plan.block_i >= d1
    assert plan.block_r % 128 == 0 or plan.block_r >= rank


def test_traffic_model_tensor_dominated():
    """For small R the kernel is tensor-read dominated (reads X ~once),
    matching the paper's sequential analysis O(I + NIR/M^{1-1/N})."""
    dims, rank = (512, 512, 512), 64
    plan = choose_blocks(dims, rank)
    m = mttkrp_traffic_model(dims, rank, plan)
    x_bytes = 512 ** 3 * 4
    assert m["x_bytes"] == x_bytes  # exactly one pass (gr == 1)
    assert m["total_bytes"] < 1.5 * x_bytes


def test_traffic_model_rank_tiling():
    """Large R forces r-tiling: tensor re-read once per r-tile."""
    dims, rank = (256, 256, 256), 2048
    plan = choose_blocks(dims, rank)
    m = mttkrp_traffic_model(dims, rank, plan)
    gr = -(-2048 // plan.block_r)
    assert m["x_bytes"] == 256 ** 3 * 4 * gr


def test_kernel_zero_padding_exactness():
    """Padded rows/cols must not pollute real outputs (zeros in X kill any
    padded-factor garbage)."""
    x, fs = _mk((7, 7, 7), 3, seed=4)
    out = mttkrp_pallas(x, fs, 1, interpret=True)
    assert out.shape == (7, 3)
    np.testing.assert_allclose(out, mttkrp_ref(x, fs, 1), rtol=2e-4, atol=2e-4)


def test_kernel_jit_compatible():
    x, fs = _mk((16, 16, 16), 4, seed=5)

    @jax.jit
    def f(x, f1, f2):
        return mttkrp_pallas(x, [None, f1, f2], 0, interpret=True)

    out = f(x, fs[1], fs[2])
    np.testing.assert_allclose(out, mttkrp_ref(x, fs, 0), rtol=2e-4, atol=2e-4)
