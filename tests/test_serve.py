"""Serving layer: bucketing, padding exactness, convergence masks, spans.

Pins the contracts :mod:`repro.launch.serve` claims in its docstring:

- **Bucketing** — requests with equal tune-cache keys (padded shape,
  rank, dtype, memory model) land in ONE bucket and are executed by one
  batched call; anything that changes the key splits the bucket.
- **Padding exactness** — a zero-padded tensor with zero-padded initial
  factors evolves identically to the unpadded run under CP-ALS, so the
  cropped served result matches a direct :func:`repro.cp_als` call.
- **Per-element convergence masks** — a bucket mixing easy and hard
  tensors freezes the converged entries while the rest keep iterating.
- **Observability** — one ``serve_request`` span per request (with queue
  and execute phases) and one ``serve_bucket`` span per bucket.
- **ExecutionContext.compilation_cache** — validated, JSON round-tripped,
  and applied to JAX's persistent-cache config by
  ``ensure_compilation_cache()``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core.tensor import random_factors, random_low_rank_tensor
from repro.engine.context import ExecutionContext
from repro.launch.serve import (
    DecompositionServer,
    bucket_key,
    bucket_shape,
    pad_to_bucket,
)
from repro.observe.trace import Trace


def _ctx(**kw):
    kw.setdefault("backend", "einsum")
    return ExecutionContext.create(**kw)


# ---------------------------------------------------------------------------
# bucketing
# ---------------------------------------------------------------------------

def test_bucket_shape_rounds_up_to_quantum():
    assert bucket_shape((7, 6, 5)) == (8, 8, 8)
    assert bucket_shape((8, 3, 2)) == (8, 8, 8)
    assert bucket_shape((9, 8, 17), pad_to=8) == (16, 8, 24)
    assert bucket_shape((5, 4), pad_to=1) == (5, 4)
    with pytest.raises(ValueError):
        bucket_shape((4, 4), pad_to=0)


def test_equal_keys_share_a_bucket():
    # same padded shape + rank + dtype -> same bucket key
    k1 = bucket_key((7, 6, 5), 3, jnp.float32)
    k2 = bucket_key((8, 3, 2), 3, jnp.float32)
    assert k1 == k2
    # anything that changes the tune-cache identity splits the bucket
    assert bucket_key((7, 6, 5), 4, jnp.float32) != k1
    assert bucket_key((7, 6, 5), 3, jnp.float64) != k1
    assert bucket_key((9, 6, 5), 3, jnp.float32) != k1
    assert bucket_key((3, 3, 3), 3, jnp.float32, pad_to=4) != bucket_key(
        (3, 3, 3), 3, jnp.float32, pad_to=8
    )


def test_server_groups_equal_keys_into_one_batched_call():
    srv = DecompositionServer(_ctx(), n_iters=3, tol=0.0)
    key = jax.random.PRNGKey(0)
    for i, shape in enumerate([(7, 6, 5), (8, 3, 2), (5, 5, 5)]):
        key, k = jax.random.split(key)
        x, _ = random_low_rank_tensor(k, shape, 3)
        srv.submit(x, 3, request_id=f"r{i}")
    # a fourth request in a DIFFERENT bucket (rank changes the key)
    key, k = jax.random.split(key)
    x, _ = random_low_rank_tensor(k, (7, 6, 5), 2)
    srv.submit(x, 2, request_id="r3")
    assert len(srv) == 4
    results = srv.flush()
    assert len(srv) == 0
    assert set(results) == {"r0", "r1", "r2", "r3"}
    assert results["r0"].bucket == results["r1"].bucket == results["r2"].bucket
    assert results["r0"].batch == 3
    assert results["r3"].bucket != results["r0"].bucket
    assert results["r3"].batch == 1
    # results come back cropped to each request's own shape
    assert [tuple(f.shape) for f in results["r1"].factors] == [
        (8, 3), (3, 3), (2, 3)
    ]


def test_submit_rejects_vectors():
    srv = DecompositionServer(_ctx())
    with pytest.raises(ValueError, match=">=2-way"):
        srv.submit(jnp.ones((5,)), 2)


# ---------------------------------------------------------------------------
# padding exactness
# ---------------------------------------------------------------------------

def test_pad_to_bucket_round_trips():
    x = jax.random.normal(jax.random.PRNGKey(3), (7, 6, 5))
    p = pad_to_bucket(x, (8, 8, 8))
    assert p.shape == (8, 8, 8)
    # the original block survives untouched; the padding is exactly zero
    assert np.array_equal(np.asarray(p[:7, :6, :5]), np.asarray(x))
    assert float(jnp.abs(p[7:]).sum()) == 0.0
    assert float(jnp.abs(p[:, 6:]).sum()) == 0.0
    assert float(jnp.abs(p[:, :, 5:]).sum()) == 0.0
    # already at the bucket shape -> returned as-is
    assert pad_to_bucket(p, (8, 8, 8)) is p
    with pytest.raises(ValueError, match="cannot pad"):
        pad_to_bucket(x, (6, 6, 6))


def test_served_result_matches_direct_cp_als():
    """The whole pipeline — pad, batch, crop — is invisible: a served
    request equals a direct ``cp_als`` on the unpadded tensor with the
    same init (the server seeds request ``i`` of a fresh server with
    ``PRNGKey(i+1)`` on the element shape)."""
    shape, rank, n_iters, tol = (7, 6, 5), 3, 6, 1e-4
    x, _ = random_low_rank_tensor(jax.random.PRNGKey(7), shape, rank)
    x = x + 0.05 * jax.random.normal(jax.random.PRNGKey(8), shape)
    srv = DecompositionServer(_ctx(), n_iters=n_iters, tol=tol)
    srv.submit(x, rank, request_id="solo")
    served = srv.flush()["solo"]
    init = random_factors(jax.random.PRNGKey(1), shape, rank, x.dtype)
    direct = repro.cp_als(
        x, rank, n_iters=n_iters, init_factors=init, tol=tol,
        ctx=_ctx(),
    )
    # cp_als appends one fit per completed sweep, so len(fits) is its
    # sweep count; early break == convergence
    assert served.n_iters == len(direct.fits)
    assert served.converged == (len(direct.fits) < n_iters)
    np.testing.assert_allclose(
        np.asarray(served.weights), np.asarray(direct.weights),
        rtol=0, atol=1e-6,
    )
    for fs, fd in zip(served.factors, direct.factors):
        assert fs.shape == fd.shape
        np.testing.assert_allclose(
            np.asarray(fs), np.asarray(fd), rtol=0, atol=1e-6
        )
    assert served.fit == pytest.approx(float(direct.final_fit), abs=1e-6)


# ---------------------------------------------------------------------------
# per-element convergence masks
# ---------------------------------------------------------------------------

def test_convergence_mask_freezes_easy_requests():
    """One exactly-low-rank tensor (converges in a few sweeps) and one
    noise tensor (never converges) share a bucket: the easy entry stops
    iterating early while the hard one runs to the sweep cap."""
    shape, rank, n_iters = (8, 8, 8), 3, 25
    easy, _ = random_low_rank_tensor(jax.random.PRNGKey(11), shape, rank)
    hard = jax.random.normal(jax.random.PRNGKey(12), shape)
    srv = DecompositionServer(_ctx(), n_iters=n_iters, tol=1e-5)
    srv.submit(easy, rank, request_id="easy")
    srv.submit(hard, rank, request_id="hard")
    results = srv.flush()
    assert results["easy"].bucket == results["hard"].bucket
    assert results["easy"].converged
    assert results["easy"].n_iters < n_iters
    assert results["easy"].n_iters < results["hard"].n_iters
    assert results["easy"].fit == pytest.approx(1.0, abs=1e-4)
    # the frozen entry tracks its solo run (same PRNGKey(1) init).
    # Batched grams use a differently-ordered float32 reduction, so the
    # sweep where the fit delta crosses tol can shift by one — but the
    # converged answer is the same decomposition.
    init = random_factors(jax.random.PRNGKey(1), shape, rank, easy.dtype)
    solo = repro.cp_als(
        easy, rank, n_iters=n_iters, init_factors=init, tol=1e-5,
        ctx=_ctx(),
    )
    assert abs(results["easy"].n_iters - len(solo.fits)) <= 1
    np.testing.assert_allclose(
        np.asarray(results["easy"].weights), np.asarray(solo.weights),
        rtol=1e-4,
    )


# ---------------------------------------------------------------------------
# observability
# ---------------------------------------------------------------------------

def test_flush_records_one_span_per_request_and_bucket():
    srv = DecompositionServer(_ctx(observe=True), n_iters=3, tol=0.0)
    key = jax.random.PRNGKey(5)
    for i, shape in enumerate([(7, 6, 5), (6, 6, 5), (7, 6, 5)]):
        key, k = jax.random.split(key)
        srv.submit(jax.random.normal(k, shape), 3, request_id=f"r{i}")
    with Trace() as tr:
        results = srv.flush()
    reqs = [e for e in tr.events if e["kind"] == "serve_request"]
    buckets = [e for e in tr.events if e["kind"] == "serve_bucket"]
    assert len(reqs) == 3
    assert len(buckets) == 1
    assert {e["request_id"] for e in reqs} == {"r0", "r1", "r2"}
    for e in reqs:
        # both serving phases are reported, and they are sane
        assert e["queue_s"] >= 0.0
        assert e["execute_s"] > 0.0
        assert e["bucket"] == buckets[0]["bucket"]
        assert e["batch"] == 3
        assert e["cold"] is True
    assert buckets[0]["batch"] == 3
    assert buckets[0]["padded_shape"] == [8, 8, 8]
    # telemetry agrees with the returned results
    assert results["r0"].queue_s >= 0.0
    assert results["r0"].execute_s == pytest.approx(
        buckets[0]["execute_s"]
    )
    # a second flush of the same bucket is warm
    key, k = jax.random.split(key)
    srv.submit(jax.random.normal(k, (7, 6, 5)), 3, request_id="r4")
    with Trace() as tr2:
        srv.flush()
    (bucket2,) = (e for e in tr2.events if e["kind"] == "serve_bucket")
    assert bucket2["cold"] is False


def test_observed_capture_skips_unobserved_servers():
    # a capture="observed" trace only records ctx.observe=True calls
    srv = DecompositionServer(_ctx(observe=False), n_iters=2, tol=0.0)
    srv.submit(jax.random.normal(jax.random.PRNGKey(1), (6, 5, 4)), 2)
    with Trace(capture="observed") as tr:
        srv.flush()
    assert [e for e in tr.events if e["kind"].startswith("serve")] == []
    # and with no trace active at all, flushing records nothing anywhere
    srv2 = DecompositionServer(_ctx(observe=True), n_iters=2, tol=0.0)
    srv2.submit(jax.random.normal(jax.random.PRNGKey(2), (6, 5, 4)), 2)
    srv2.flush()  # must not raise


# ---------------------------------------------------------------------------
# compilation_cache context field
# ---------------------------------------------------------------------------

def test_compilation_cache_round_trips_and_validates(tmp_path):
    ctx = ExecutionContext.create(
        backend="einsum", compilation_cache=str(tmp_path / "cc")
    )
    back = ExecutionContext.from_json(ctx.to_json())
    assert back == ctx
    assert back.compilation_cache == str(tmp_path / "cc")
    # absent key in older payloads -> None (back-compat)
    d = ctx.to_dict()
    d.pop("compilation_cache")
    assert ExecutionContext.from_dict(d).compilation_cache is None
    with pytest.raises((TypeError, ValueError)):
        ExecutionContext.create(backend="einsum", compilation_cache=7)


def test_ensure_compilation_cache_points_jax_at_the_directory(tmp_path):
    cc = str(tmp_path / "cc")
    ctx = ExecutionContext.create(backend="einsum", compilation_cache=cc)
    prev = jax.config.jax_compilation_cache_dir
    try:
        assert ctx.ensure_compilation_cache() == cc
        import os

        assert os.path.isdir(cc)
        assert jax.config.jax_compilation_cache_dir == cc
        assert jax.config.jax_persistent_cache_min_compile_time_secs == 0.0
    finally:
        jax.config.update("jax_compilation_cache_dir", prev)
    # a context without the field is a no-op
    assert ExecutionContext.create(
        backend="einsum"
    ).ensure_compilation_cache() is None
