"""Fused intra-chunk SSD Pallas kernel vs oracle (§Perf Cell B follow-on)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.ssd_intra import (
    ssd_intra_pallas,
    ssd_intra_ref,
    traffic_model,
)

# interpret-mode kernel sweeps dominate the suite's wall time
pytestmark = pytest.mark.slow


def _mk(bcn, q, n, h, p, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    cc = jax.random.normal(ks[0], (bcn, q, n), dtype)
    bc = jax.random.normal(ks[1], (bcn, q, n), dtype)
    # realistic: cumulative log-decay is negative and decreasing in i
    cum = -jnp.cumsum(
        jax.nn.softplus(jax.random.normal(ks[2], (bcn, q, h))), axis=1
    ).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[3], (bcn, q, h))).astype(dtype)
    x = jax.random.normal(ks[4], (bcn, q, h, p), dtype)
    return cc, bc, cum, dt, x


@pytest.mark.parametrize(
    "bcn,q,n,h,p,hb",
    [
        (4, 16, 8, 8, 16, 4),
        (2, 32, 16, 8, 8, 8),
        (1, 8, 4, 16, 4, 8),
        (3, 64, 16, 4, 16, 2),
    ],
)
def test_kernel_matches_oracle(bcn, q, n, h, p, hb):
    args = _mk(bcn, q, n, h, p)
    got = ssd_intra_pallas(*args, head_block=hb, interpret=True)
    ref = ssd_intra_ref(*args)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref), rtol=2e-4, atol=2e-4
    )


def test_kernel_bf16():
    args = _mk(2, 16, 8, 8, 16, dtype=jnp.bfloat16)
    got = ssd_intra_pallas(*args, head_block=4, interpret=True)
    ref = ssd_intra_ref(*args)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32),
        rtol=5e-2, atol=5e-2,
    )


@settings(max_examples=10, deadline=None)
@given(
    q=st.sampled_from([8, 16, 32]),
    n=st.sampled_from([4, 8]),
    h=st.sampled_from([4, 8]),
    seed=st.integers(0, 100),
)
def test_property_any_shape(q, n, h, seed):
    args = _mk(2, q, n, h, 8, seed=seed)
    got = ssd_intra_pallas(*args, head_block=4, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ssd_intra_ref(*args)),
        rtol=5e-4, atol=5e-4,
    )


def test_causality():
    """Output at position i must not depend on inputs at j > i."""
    args = _mk(1, 16, 8, 4, 8, seed=7)
    cc, bc, cum, dt, x = args
    base = ssd_intra_pallas(cc, bc, cum, dt, x, head_block=4, interpret=True)
    x2 = x.at[:, 12:, :, :].set(123.0)  # perturb the tail
    out2 = ssd_intra_pallas(cc, bc, cum, dt, x2, head_block=4,
                            interpret=True)
    np.testing.assert_allclose(
        np.asarray(base[:, :12]), np.asarray(out2[:, :12]), rtol=1e-5
    )
    assert not np.allclose(np.asarray(base[:, 12:]), np.asarray(out2[:, 12:]))


def test_matches_model_ssd_intra_term():
    """The kernel computes exactly models/ssm.py's y_intra term."""

    # oracle comparison is structural: same formula, independent codepaths
    args = _mk(2, 8, 4, 4, 8, seed=11)
    got = ssd_intra_pallas(*args, head_block=4, interpret=True)
    assert got.shape == (2, 8, 4, 8)


def test_traffic_model_mamba2_shapes():
    """At mamba2 train shapes, the fused kernel cuts the intra-chunk HBM
    term >10x (the §Perf Cell B headline)."""
    m = traffic_model(bcn=16 * 64, q=256, n=128, h=80, p=64)
    assert m["ratio"] > 10
