"""Property-based differential suite for the batched engine path.

Every batched entry point is pinned to its unbatched oracle: for random
shapes / ranks / modes / backends, a leading-batch-axis call must equal
a Python loop of single calls to 1e-6. The amortization claims are
pinned too — the tune cache is consulted exactly once per batched
``backend="auto"`` call (not once per element), and the pallas dispatch
counter shows ONE kernel launch per batched call (vmap adds a grid
dimension; it does not loop launches).

Runs under the real ``hypothesis`` in CI and the deterministic stub
(``tests/_hypothesis_stub.py``) locally.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro
from repro.engine.batch import batched_choose_blocks
from repro.engine.plan import Memory, choose_blocks
from repro.observe.metrics import (
    PALLAS_DISPATCHES,
    TUNE_CACHE_HITS,
    TUNE_CACHE_MISSES,
)
from repro.observe import registry
from repro.tune.cache import isolated_cache

BACKENDS = ("einsum", "blocked_host", "pallas")

TOL = dict(rtol=1e-6, atol=1e-6)


def _ctx(backend):
    if backend == "pallas":
        return repro.ExecutionContext.create(
            backend="pallas", interpret=True, memory=Memory.abstract(2 ** 16)
        )
    return repro.ExecutionContext.create(backend=backend)


def _mk_batch(batch, dims, rank, seed=0):
    key = jax.random.PRNGKey(seed)
    kx, *kf = jax.random.split(key, len(dims) + 1)
    x = jax.random.normal(kx, (batch,) + dims)
    fs = [
        jax.random.normal(k, (batch, d, rank))
        for k, d in zip(kf, dims)
    ]
    return x, fs


# ---------------------------------------------------------------------------
# differential: batched == loop of single calls
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(
    batch=st.integers(1, 4),
    d0=st.integers(2, 7),
    d1=st.integers(2, 7),
    d2=st.integers(2, 7),
    rank=st.integers(1, 5),
    mode=st.integers(0, 2),
    backend=st.sampled_from(BACKENDS),
)
def test_batched_mttkrp_equals_loop(batch, d0, d1, d2, rank, mode, backend):
    dims = (d0, d1, d2)
    x, fs = _mk_batch(batch, dims, rank)
    ctx = _ctx(backend)
    out = repro.mttkrp(x, fs, mode, ctx=ctx)
    loop = jnp.stack([
        repro.mttkrp(x[b], [f[b] for f in fs], mode, ctx=ctx)
        for b in range(batch)
    ])
    assert out.shape == (batch, dims[mode], rank)
    np.testing.assert_allclose(out, loop, **TOL)


@settings(max_examples=8, deadline=None)
@given(
    batch=st.integers(1, 3),
    d0=st.integers(2, 6),
    d1=st.integers(2, 6),
    d2=st.integers(2, 6),
    keep=st.sampled_from([None, 0, 1, 2]),
    backend=st.sampled_from(BACKENDS),
)
def test_batched_multi_ttm_equals_loop(batch, d0, d1, d2, keep, backend):
    dims = (d0, d1, d2)
    key = jax.random.PRNGKey(1)
    kx, *km = jax.random.split(key, 4)
    x = jax.random.normal(kx, (batch,) + dims)
    mats = [
        None if k == keep
        else jax.random.normal(km[k], (batch, d, min(2, d)))
        for k, d in enumerate(dims)
    ]
    ctx = _ctx(backend)
    out = repro.multi_ttm(x, mats, keep=keep, ctx=ctx)
    loop = jnp.stack([
        repro.multi_ttm(
            x[b], [None if m is None else m[b] for m in mats],
            keep=keep, ctx=ctx,
        )
        for b in range(batch)
    ])
    np.testing.assert_allclose(out, loop, **TOL)


@settings(max_examples=6, deadline=None)
@given(
    batch=st.integers(1, 3),
    d0=st.integers(2, 6),
    d1=st.integers(2, 6),
    d2=st.integers(2, 6),
    rank=st.integers(1, 4),
    drop=st.integers(0, 2),
    backend=st.sampled_from(BACKENDS),
)
def test_batched_contract_partial_equals_loop(
    batch, d0, d1, d2, rank, drop, backend
):
    dims = (d0, d1, d2)
    x, fs = _mk_batch(batch, dims, rank, seed=2)
    shared = [f[0] for f in fs]
    ctx = _ctx(backend)
    out = repro.contract_partial(
        x, shared, (0, 1, 2), (drop,), False, ctx=ctx
    )
    loop = jnp.stack([
        repro.contract_partial(
            x[b], shared, (0, 1, 2), (drop,), False, ctx=ctx
        )
        for b in range(batch)
    ])
    np.testing.assert_allclose(out, loop, **TOL)


@settings(max_examples=4, deadline=None)
@given(
    batch=st.integers(1, 3),
    d0=st.integers(3, 6),
    d1=st.integers(3, 6),
    d2=st.integers(3, 6),
    rank=st.integers(1, 3),
    backend=st.sampled_from(("einsum", "blocked_host")),
)
def test_batched_cp_als_equals_loop(batch, d0, d1, d2, rank, backend):
    dims = (d0, d1, d2)
    from repro.core.tensor import random_factors

    x = jax.random.normal(jax.random.PRNGKey(3), (batch,) + dims)
    keys = jax.random.split(jax.random.PRNGKey(4), batch)
    inits = [
        jnp.stack(f) for f in zip(*[
            random_factors(k, dims, rank, x.dtype) for k in keys
        ])
    ]
    ctx = _ctx(backend)
    res = repro.cp_als_batched(
        x, rank, n_iters=3, init_factors=inits, ctx=ctx
    )
    for b in range(batch):
        single = repro.cp_als(
            x[b], rank, n_iters=3,
            init_factors=[f[b] for f in inits], ctx=ctx,
        )
        for k in range(3):
            np.testing.assert_allclose(
                res.factors[k][b], single.factors[k], rtol=1e-4, atol=1e-5
            )
        np.testing.assert_allclose(
            res.weights[b], single.weights, rtol=1e-4, atol=1e-5
        )
        np.testing.assert_allclose(
            res.fits[b], single.fits[-1], rtol=1e-5, atol=1e-5
        )


def test_batched_cp_als_pallas_backend_matches():
    dims, rank, batch = (6, 5, 4), 3, 3
    from repro.core.tensor import random_factors

    x = jax.random.normal(jax.random.PRNGKey(5), (batch,) + dims)
    keys = jax.random.split(jax.random.PRNGKey(6), batch)
    inits = [
        jnp.stack(f) for f in zip(*[
            random_factors(k, dims, rank, x.dtype) for k in keys
        ])
    ]
    ctx = _ctx("pallas")
    res = repro.cp_als_batched(
        x, rank, n_iters=3, init_factors=inits, ctx=ctx
    )
    ref = repro.cp_als_batched(
        x, rank, n_iters=3, init_factors=inits, ctx=_ctx("einsum")
    )
    for k in range(3):
        np.testing.assert_allclose(
            res.factors[k], ref.factors[k], rtol=1e-4, atol=1e-5
        )


def test_batched_tucker_equals_loop():
    dims, ranks, batch = (7, 6, 5), (3, 2, 2), 3
    x = jax.random.normal(jax.random.PRNGKey(7), (batch,) + dims)
    res = repro.tucker_hooi_batched(x, ranks, n_iters=3)
    for b in range(batch):
        single = repro.tucker_hooi(x[b], ranks, n_iters=3)
        np.testing.assert_allclose(
            res.core[b], single.core, rtol=1e-4, atol=1e-5
        )
        for k in range(3):
            np.testing.assert_allclose(
                res.factors[k][b], single.factors[k], rtol=1e-4, atol=1e-5
            )
        np.testing.assert_allclose(
            res.fits[b], single.fits[-1], rtol=1e-5, atol=1e-5
        )


def test_shared_factors_broadcast():
    # a shared (I_k, R) factor batches with in_axes=None: same answer as
    # replicating it per element
    batch, dims, rank = 3, (5, 4, 6), 2
    x, fs = _mk_batch(batch, dims, rank, seed=8)
    shared = [f[0] for f in fs]
    out = repro.mttkrp(x, shared, 1)
    tiled = repro.mttkrp(
        x, [jnp.broadcast_to(f, (batch,) + f.shape) for f in shared], 1
    )
    np.testing.assert_allclose(out, tiled, **TOL)


def test_batched_factor_shape_mismatch_raises():
    x, fs = _mk_batch(2, (4, 4, 4), 3, seed=9)
    bad = [fs[0], fs[1][:, :3], fs[2]]  # wrong extent on mode 1
    with pytest.raises(ValueError, match="batched call"):
        repro.mttkrp(x, bad, 0)


# ---------------------------------------------------------------------------
# amortization: cache hit once per bucket, one launch per batched call
# ---------------------------------------------------------------------------

def test_tune_cache_consulted_once_per_batched_call():
    from repro.tune.cache import CacheEntry, cache_key, default_cache

    batch, dims, rank = 4, (6, 5, 4), 3
    x, fs = _mk_batch(batch, dims, rank, seed=10)
    ctx = repro.ExecutionContext.create(backend="auto")
    with isolated_cache():
        reg = registry()
        before = reg.snapshot()
        repro.mttkrp(x, fs, 0, ctx=ctx)
        d1 = reg.delta(before)
        # ONE resolution for the whole batch: a single cache miss
        # (``resolve`` never persists a fallback), and never one lookup
        # per element
        assert d1.get(TUNE_CACHE_MISSES, 0) == 1, d1
        assert d1.get(TUNE_CACHE_HITS, 0) == 0, d1
        # ... against one consultation per element for the looped oracle
        before = reg.snapshot()
        for b in range(batch):
            repro.mttkrp(x[b], [f[b] for f in fs], 0, ctx=ctx)
        dloop = reg.delta(before)
        assert dloop.get(TUNE_CACHE_MISSES, 0) == batch, dloop
        # tune the bucket (a tuned entry is what ``serve`` amortizes);
        # the key is the *element* problem — batching never changes it
        key = cache_key(
            dims, rank, 0, x.dtype, Memory.tpu_vmem(itemsize=x.dtype.itemsize)
        )
        default_cache().put(key, CacheEntry(backend="einsum"), persist=False)
        before = reg.snapshot()
        repro.mttkrp(x, fs, 0, ctx=ctx)
        d2 = reg.delta(before)
        # the bucket is warm: exactly one hit, no new misses
        assert d2.get(TUNE_CACHE_HITS, 0) == 1, d2
        assert d2.get(TUNE_CACHE_MISSES, 0) == 0, d2


def test_one_pallas_launch_per_batched_call():
    batch, dims, rank = 5, (6, 5, 4), 3
    x, fs = _mk_batch(batch, dims, rank, seed=11)
    ctx = _ctx("pallas")
    reg = registry()
    before = reg.snapshot()
    repro.mttkrp(x, fs, 0, ctx=ctx)
    assert reg.delta(before).get(PALLAS_DISPATCHES, 0) == 1
    # the looped oracle launches B times — the amortization being claimed
    before = reg.snapshot()
    for b in range(batch):
        repro.mttkrp(x[b], [f[b] for f in fs], 0, ctx=ctx)
    assert reg.delta(before).get(PALLAS_DISPATCHES, 0) == batch
    # multi_ttm: same single-launch property
    mats = [
        jax.random.normal(jax.random.PRNGKey(k), (batch, d, 2))
        for k, d in enumerate(dims)
    ]
    before = reg.snapshot()
    repro.multi_ttm(x, mats, keep=None, ctx=ctx)
    assert reg.delta(before).get(PALLAS_DISPATCHES, 0) == 1


def test_batched_sweep_launch_count_scales_with_modes_not_batch():
    # a full batched CP sweep on the pallas backend: one launch per mode
    # per iteration, independent of B
    batch, dims, rank, iters = 4, (6, 5, 4), 2, 2
    x = jax.random.normal(jax.random.PRNGKey(12), (batch,) + dims)
    ctx = _ctx("pallas")
    reg = registry()
    before = reg.snapshot()
    repro.cp_als_batched(x, rank, n_iters=iters, ctx=ctx)
    n = reg.delta(before).get(PALLAS_DISPATCHES, 0)
    assert n == len(dims) * iters, n


# ---------------------------------------------------------------------------
# the plan is B-independent (the verify gate's dynamic counterpart)
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(
    batch=st.integers(1, 16),
    d0=st.integers(4, 64),
    d1=st.integers(4, 64),
    d2=st.integers(4, 64),
    rank=st.integers(1, 32),
)
def test_batched_plan_is_element_plan(batch, d0, d1, d2, rank):
    shape = (d0, d1, d2)
    mem = Memory.abstract(2 ** 14)
    assert batched_choose_blocks(
        batch, shape, rank, 4, memory=mem
    ) == choose_blocks(shape, rank, 4, memory=mem)


def test_batched_choose_blocks_rejects_bad_batch():
    with pytest.raises(ValueError, match="batch"):
        batched_choose_blocks(0, (4, 4, 4), 2, 4)
