"""Multi-TTM on the unified engine (arXiv:2207.10437): backends vs the
einsum oracle, the planner's bounds pins, the Tucker/HOOI driver, the
tune-cache ``kind="multi_ttm"`` path, and grid selection vs brute force.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import bounds
from repro.core.tensor import random_tucker_tensor
from repro.core.tucker import hosvd_init, ttm, tucker_hooi
from repro.distributed.grid_select import (
    brute_force_tucker,
    choose_tucker_grid,
    multi_ttm_sweep_words,
    select_tucker_grid,
)
from repro.engine.plan import (
    Memory,
    MultiTTMPlan,
    choose_multi_ttm_blocks,
    uniform_multi_ttm_plan,
)
from repro.tune.cache import isolated_cache
from repro.tune.search import resolve_multi_ttm, tune_multi_ttm

DIMS3, RANKS3 = (12, 10, 8), (4, 3, 2)
DIMS4, RANKS4 = (6, 5, 4, 7), (2, 3, 2, 3)


def _problem(dims, ranks, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), dims)
    mats = [
        jax.random.normal(jax.random.PRNGKey(seed + 1 + k), (d, r))
        for k, (d, r) in enumerate(zip(dims, ranks))
    ]
    return x, mats


def _oracle(x, mats, keep):
    """Direct per-mode tensordot chain (independent of the engine)."""
    out = x
    for k in range(x.ndim):
        if k == keep:
            continue
        out = ttm(out, mats[k], k)
    return out


# ---------------------------------------------------------------------------
# all backends match the oracle (3- and 4-way, every kept mode + core)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dims,ranks", [(DIMS3, RANKS3), (DIMS4, RANKS4)])
@pytest.mark.parametrize("backend", ["einsum", "blocked_host", "pallas"])
def test_multi_ttm_matches_oracle_all_keeps(dims, ranks, backend):
    x, mats = _problem(dims, ranks)
    ctx = repro.ExecutionContext.create(backend=backend, interpret=True)
    for keep in (None, *range(len(dims))):
        ref = _oracle(x, mats, keep)
        got = repro.multi_ttm(x, mats, keep, ctx=ctx)
        assert got.shape == ref.shape
        scale = float(jnp.max(jnp.abs(ref))) + 1e-30
        err = float(jnp.max(jnp.abs(got - ref)))
        assert err < 1e-6 * max(scale, 1.0) * 50, (keep, err)


def test_multi_ttm_output_mode_order():
    x, mats = _problem(DIMS3, RANKS3)
    assert repro.multi_ttm(x, mats, None).shape == RANKS3
    assert repro.multi_ttm(x, mats, 1).shape == (RANKS3[0], DIMS3[1], RANKS3[2])


def test_multi_ttm_kept_matrix_may_be_none():
    x, mats = _problem(DIMS3, RANKS3)
    ref = repro.multi_ttm(x, mats, 1)
    got = repro.multi_ttm(x, [mats[0], None, mats[2]], 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref))


def test_multi_ttm_validates_inputs():
    x, mats = _problem(DIMS3, RANKS3)
    with pytest.raises(ValueError, match="out of range"):
        repro.multi_ttm(x, mats, 3)
    with pytest.raises(ValueError, match="one matrix per tensor mode"):
        repro.multi_ttm(x, mats[:2])
    bad = [mats[0], jnp.zeros((DIMS3[1] + 1, 3)), mats[2]]
    with pytest.raises(ValueError, match="rows"):
        repro.multi_ttm(x, bad, None)
    with pytest.raises(ValueError, match="unknown backend"):
        repro.ExecutionContext.create(backend="nope")


def test_multi_ttm_pallas_explicit_plan_and_memory():
    x, mats = _problem(DIMS3, RANKS3)
    ref = repro.multi_ttm(x, mats, 0)
    plan = MultiTTMPlan(4, (5, 8), tuple(RANKS3[1:]))
    ctx = repro.ExecutionContext.create(backend="pallas", interpret=True)
    got = repro.multi_ttm(x, mats, 0, ctx=ctx, plan=plan)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-5
    )
    mem_ctx = repro.ExecutionContext.create(
        backend="pallas", interpret=True,
        memory=Memory.abstract(2048, itemsize=4),
    )
    got2 = repro.multi_ttm(x, mats, 0, ctx=mem_ctx)
    np.testing.assert_allclose(
        np.asarray(got2), np.asarray(ref), rtol=1e-5, atol=1e-5
    )


# ---------------------------------------------------------------------------
# planner pins against the bounds oracle
# ---------------------------------------------------------------------------

def test_uniform_plan_model_equals_bounds_oracle():
    for dims, ranks, mem in [
        ((16, 12, 10), (3, 4), 4096),
        ((32, 32, 32), (2, 2), 1024),
        ((8, 8, 8, 8), (2, 3, 2), 4096),
    ]:
        plan = uniform_multi_ttm_plan(dims, ranks, mem)
        b = plan.block_i
        assert int(plan.model_words(dims)) == int(
            bounds.multi_ttm_blocked_cost(dims, ranks, b)
        )
        assert bounds.multi_ttm_blocked_feasible_b(
            len(dims), ranks, b, mem
        )
        assert not bounds.multi_ttm_blocked_feasible_b(
            len(dims), ranks, b + 1, mem
        ) or b == 1


def test_working_set_matches_feasibility_oracle():
    ranks = (3, 4)
    for b in (1, 2, 4, 8):
        plan = MultiTTMPlan(b, (b, b), ranks)
        ws = plan.working_set_words()
        # the uniform-b Eq-9 analog counts exactly the same words
        assert bounds.multi_ttm_blocked_feasible_b(3, ranks, b, ws)
        assert not bounds.multi_ttm_blocked_feasible_b(3, ranks, b, ws - 1)


def test_choose_multi_ttm_blocks_fits_budget():
    mem = Memory.abstract(4096)
    plan = choose_multi_ttm_blocks((64, 48, 32), (4, 3), memory=mem)
    assert plan.fits(mem)
    assert plan.ranks == (4, 3)
    # degenerate extents never over-padded
    tiny = choose_multi_ttm_blocks((1, 4, 8), (2, 2), memory=mem)
    assert tiny.block_i == 1 and tiny.padded_shape((1, 4, 8)) == (1, 4, 8)


def test_traffic_model_consistency():
    plan = choose_multi_ttm_blocks(
        (32, 24, 16), (4, 3), memory=Memory.abstract(8192)
    )
    tm = plan.traffic_model((32, 24, 16))
    assert tm["total_bytes"] == (
        tm["x_bytes"] + tm["matrix_bytes"] + tm["out_bytes"]
    )
    assert tm["model_bytes"] == plan.model_words((32, 24, 16)) * 4
    assert tm["working_set_bytes"] == plan.working_set_words() * 4


def test_seq_lower_bounds_sane():
    dims, ranks = (32, 32, 32), (4, 4, 4)
    for mem in (256, 1024, 4096):
        lb = bounds.multi_ttm_seq_lb(dims, ranks, mem)
        assert lb >= 0
        # an upper bound can never beat the lower bound
        canon = dims  # kept-mode-first canonical: keep mode 0
        b = bounds.multi_ttm_best_block_size(canon, ranks[1:], mem)
        cost = bounds.multi_ttm_blocked_cost(canon, ranks[1:], b)
        assert cost >= bounds.multi_ttm_seq_lb(canon, ranks[1:], mem)
    # tighter memory => weaker-or-equal achievable cost, larger lb term
    lb_small = bounds.multi_ttm_seq_lb_memory(dims, ranks, 256)
    lb_big = bounds.multi_ttm_seq_lb_memory(dims, ranks, 4096)
    assert lb_small >= lb_big


def test_par_multi_ttm_cost_shrinks_with_grid():
    dims, ranks = (32, 32, 32), (4, 3, 2)
    c1 = bounds.par_multi_ttm_cost(dims, ranks, (1, 1, 1))
    c8 = bounds.par_multi_ttm_cost(dims, ranks, (2, 2, 2))
    assert c1 == 0.0  # one processor communicates nothing
    assert c8 > 0


# ---------------------------------------------------------------------------
# Tucker/HOOI driver
# ---------------------------------------------------------------------------

def test_tucker_hooi_recovers_exact_multilinear_rank():
    x, core, _ = random_tucker_tensor(
        jax.random.PRNGKey(3), (14, 12, 10), (4, 3, 2)
    )
    res = tucker_hooi(x, (4, 3, 2), n_iters=6)
    assert res.final_fit > 0.999, res.fits
    assert res.core.shape == (4, 3, 2)
    rec = res.reconstruct()
    err = float(jnp.linalg.norm(rec - x) / jnp.linalg.norm(x))
    assert err < 1e-3, err
    # factors orthonormal
    for f in res.factors:
        np.testing.assert_allclose(
            np.asarray(f.T @ f), np.eye(f.shape[1]), atol=1e-5
        )


def test_tucker_hooi_backend_parity():
    x, _, _ = random_tucker_tensor(
        jax.random.PRNGKey(4), (12, 10, 8), (3, 3, 2)
    )
    ref = tucker_hooi(x, (3, 3, 2), n_iters=4)
    for backend in ("blocked_host", "pallas"):
        ctx = repro.ExecutionContext.create(backend=backend, interpret=True)
        res = tucker_hooi(x, (3, 3, 2), n_iters=4, ctx=ctx)
        for a, b in zip(ref.fits, res.fits):
            assert abs(a - b) < 1e-4, (backend, ref.fits, res.fits)


def test_tucker_hooi_pallas_dispatches_kernel():
    from repro.observe.metrics import PALLAS_DISPATCHES, registry

    x, _, _ = random_tucker_tensor(
        jax.random.PRNGKey(5), (12, 10, 8), (3, 3, 2)
    )
    ctx = repro.ExecutionContext.create(backend="pallas", interpret=True)
    before = registry().counter(PALLAS_DISPATCHES)
    tucker_hooi(x, (3, 3, 2), n_iters=1, ctx=ctx)
    assert registry().counter(PALLAS_DISPATCHES) > before


def test_tucker_hooi_hosvd_only_and_tol():
    x, _, _ = random_tucker_tensor(
        jax.random.PRNGKey(6), (10, 10, 10), (3, 3, 3)
    )
    res0 = tucker_hooi(x, (3, 3, 3), n_iters=0)
    assert res0.core.shape == (3, 3, 3) and len(res0.fits) == 1
    res = tucker_hooi(x, (3, 3, 3), n_iters=20, tol=1e-6)
    assert len(res.fits) < 20  # converged early on an exact-rank tensor


def test_tucker_hooi_validates_ranks():
    x, _, _ = random_tucker_tensor(
        jax.random.PRNGKey(7), (8, 8, 8), (2, 2, 2)
    )
    with pytest.raises(ValueError, match="one rank per tensor mode"):
        tucker_hooi(x, (2, 2))
    with pytest.raises(ValueError, match="out of range"):
        tucker_hooi(x, (2, 9, 2))


def test_hosvd_init_orthonormal():
    x, _, _ = random_tucker_tensor(
        jax.random.PRNGKey(8), (10, 9, 8), (3, 2, 4)
    )
    for k, f in enumerate(hosvd_init(x, (3, 2, 4))):
        assert f.shape == (x.shape[k], (3, 2, 4)[k])
        np.testing.assert_allclose(
            np.asarray(f.T @ f), np.eye(f.shape[1]), atol=1e-5
        )


# ---------------------------------------------------------------------------
# tune cache: kind="multi_ttm"
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_tune_multi_ttm_persists_and_replays():
    x, mats = _problem(DIMS3, RANKS3, seed=9)
    with isolated_cache():
        res = tune_multi_ttm(x, mats, 0, interpret=True)
        assert "multi_ttm" in res.key and not res.cache_hit
        res2 = tune_multi_ttm(x, mats, 0, interpret=True)
        assert res2.cache_hit and res2.winner == res.winner
        # the auto path replays exactly what was persisted
        canon = (DIMS3[0],) + DIMS3[1:]
        r = resolve_multi_ttm(canon, RANKS3[1:], 0, jnp.float32, None)
        assert r.cache_hit and r.backend == res.winner.backend
        assert r.plan == res.winner.plan
        ctx = repro.ExecutionContext.create(backend="auto")
        out = repro.multi_ttm(x, mats, 0, ctx=ctx)
        np.testing.assert_allclose(
            np.asarray(out),
            np.asarray(repro.multi_ttm(x, mats, 0)),
            rtol=1e-4, atol=1e-4,
        )


@pytest.mark.slow
def test_tune_multi_ttm_through_engine_and_driver():
    x, mats = _problem(DIMS3, RANKS3, seed=10)
    with isolated_cache():
        ctx = repro.ExecutionContext.create(
            backend="auto", tune=True, interpret=True
        )
        repro.multi_ttm(x, mats, 1, ctx=ctx)
        from repro.tune.cache import default_cache

        keys = default_cache().keys()
        assert any("multi_ttm" in k and "mode=1" in k for k in keys), keys
        # idempotent: a second call replays, does not re-search
        repro.multi_ttm(x, mats, 1, ctx=ctx)
        assert default_cache().keys() == keys


def test_for_problem_pins_multi_ttm_decisions():
    with isolated_cache():
        ctx = repro.ExecutionContext.for_problem(
            DIMS3, RANKS3, backend="auto"
        )
        assert ctx.problem.rank == RANKS3 and ctx.problem.is_multi_ttm
        pinned_modes = sorted(d.mode for d in ctx.decisions)
        assert pinned_modes == [-1, 0, 1, 2]
        # JSON round-trip preserves the tuple rank and every decision
        ctx2 = repro.ExecutionContext.from_json(ctx.to_json())
        assert ctx2 == ctx and ctx2.decisions == ctx.decisions
        assert ctx2.problem.rank == RANKS3
        x, mats = _problem(DIMS3, RANKS3, seed=11)
        out = repro.multi_ttm(x, mats, 0, ctx=ctx2)
        np.testing.assert_allclose(
            np.asarray(out),
            np.asarray(repro.multi_ttm(x, mats, 0)),
            rtol=1e-4, atol=1e-4,
        )


def test_for_problem_tucker_rejects_bad_ranks():
    with pytest.raises(ValueError, match="one rank per tensor mode"):
        repro.ExecutionContext.for_problem((8, 8, 8), (2, 2))


def test_plan_decision_multi_ttm_roundtrip():
    from repro.engine.context import PlanDecision

    plan = MultiTTMPlan(8, (4, 4), (3, 2))
    d = PlanDecision(-1, "pallas", plan)
    d2 = PlanDecision.from_dict(d.to_dict())
    assert d2 == d and isinstance(d2.plan, MultiTTMPlan)


# ---------------------------------------------------------------------------
# grid selection: branch-and-bound pinned to brute force
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "dims,ranks",
    [
        ((16, 16, 16), (4, 3, 2)),
        ((24, 8, 12), (2, 2, 5)),
        ((8, 8, 6, 4), (2, 2, 2, 2)),
    ],
)
def test_select_tucker_grid_matches_brute_force(dims, ranks):
    for procs in (2, 4, 6, 8, 12, 16, 24, 36, 48, 64):
        for req in (False, True):
            a = select_tucker_grid(dims, ranks, procs, req)
            b = brute_force_tucker(dims, ranks, procs, req)
            assert (a is None) == (b is None), (procs, req)
            if a is not None:
                assert a.grid == b.grid, (procs, req, a, b)
                assert abs(a.words - b.words) < 1e-9


def test_choose_tucker_grid_always_succeeds():
    choice = choose_tucker_grid((16, 16, 16), (4, 3, 2), 8)
    assert choice.procs == 8
    assert all(16 % g == 0 for g in choice.grid)
    # odd extents: falls back to the largest usable processor count
    choice = choose_tucker_grid((7, 5, 3), (2, 2, 2), 8)
    assert choice.procs <= 8
    assert all(d % g == 0 for d, g in zip((7, 5, 3), choice.grid))


def test_multi_ttm_sweep_words_matches_term_sum():
    dims, ranks, grid = (16, 16, 16), (4, 3, 2), (2, 2, 2)
    procs = math.prod(grid)
    total = 0.0
    for k, (d, pk) in enumerate(zip(dims, grid)):
        rbar = math.prod(r for j, r in enumerate(ranks) if j != k)
        q = procs // pk
        total += (2 * (q - 1) / q + (pk - 1)) * (d // pk) * rbar
    assert abs(multi_ttm_sweep_words(dims, ranks, grid) - total) < 1e-9
