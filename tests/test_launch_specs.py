"""input_specs / mesh / cell-matrix structure for the dry-run launcher.

(The actual 256/512-device lowering runs via launch/dryrun.py subprocesses;
here we validate the zero-allocation spec machinery on 1 device.)
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.launch.specs import (
    cross_kv_struct,
    decode_token_struct,
    input_specs,
)
from repro.models.config import SHAPES


@pytest.mark.parametrize("arch", ARCH_NAMES)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_shapes(arch, shape):
    cfg = get_config(arch)
    rs = SHAPES[shape]
    specs = input_specs(arch, shape)
    if rs.kind in ("train", "prefill"):
        b = specs["batch"]
        if cfg.frontend != "none":
            assert b["embeds"].shape == (
                rs.global_batch, rs.seq_len, cfg.d_model
            )
            assert b["embeds"].dtype == jnp.bfloat16
        else:
            assert b["tokens"].shape == (rs.global_batch, rs.seq_len)
            assert b["tokens"].dtype == jnp.int32
        if cfg.is_encdec:
            assert b["dec_tokens"].shape == (
                rs.global_batch, cfg.max_target_len
            )
    else:
        assert specs["tokens"].shape == (rs.global_batch, 1)


def test_no_allocation():
    """Specs must be ShapeDtypeStructs, never real arrays."""
    specs = input_specs("nemotron-4-340b", "train_4k")
    for leaf in jax.tree.leaves(specs):
        assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_cross_kv_struct_whisper():
    cfg = get_config("whisper-tiny")
    k, v = cross_kv_struct(cfg, SHAPES["decode_32k"])
    assert k.shape == (128, 32768, cfg.n_kv_heads, cfg.hd)


def test_decode_token_struct():
    cfg = get_config("qwen2-1.5b")
    t = decode_token_struct(cfg, SHAPES["decode_32k"])
    assert t.shape == (128, 1) and t.dtype == jnp.int32


def test_production_mesh_shapes_documented():
    """make_production_mesh is a function (no import-time device init) and
    encodes the assigned meshes."""
    import inspect

    from repro.launch import mesh as mesh_mod

    src = inspect.getsource(mesh_mod.make_production_mesh)
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert "pod" in src and "data" in src and "model" in src


def test_dryrun_results_complete():
    """The committed dry-run matrix must cover all 80 cells, all ok/skip."""
    import glob
    import json
    import os

    d = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
    files = glob.glob(os.path.join(d, "*.json"))
    if len(files) < 80:
        pytest.skip("dry-run sweep not present in this checkout")
    recs = [json.load(open(p)) for p in files]
    assert len(recs) == 80
    assert all(r.get("status") in ("ok", "skipped") for r in recs)
    oks = [r for r in recs if r["status"] == "ok"]
    assert len(oks) == 64
    for r in oks:
        assert r["cost"]["flops"] > 0
        assert r["memory"]["peak_bytes_est"] > 0
