"""Substrate tests: optimizer math, schedules, data determinism/resume,
checkpoint atomicity/integrity/elastic restore, train loop fault tolerance,
straggler monitor."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_latest, save_checkpoint
from repro.checkpoint.manager import list_steps
from repro.configs import get_smoke
from repro.data import DataConfig, batch_iterator, synthetic_batch
from repro.optim import adamw_init, adamw_update, cosine_schedule
from repro.training import (
    LoopConfig,
    TrainLoop,
    build_train_step,
    init_train_state,
)


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------

def test_adamw_matches_reference_math():
    params = {"w": jnp.array([1.0, -2.0, 3.0]), "b": jnp.array([0.5])}
    grads = {"w": jnp.array([0.1, 0.2, -0.1]), "b": jnp.array([-0.3])}
    state = adamw_init(params)
    lr, b1, b2, eps, wd = 0.01, 0.9, 0.95, 1e-8, 0.1
    new_params, new_state, metrics = adamw_update(
        params, grads, state, lr, b1=b1, b2=b2, eps=eps, weight_decay=wd,
        clip_norm=1e9,
    )
    # reference numpy implementation
    for k in params:
        g = np.asarray(grads[k])
        m = (1 - b1) * g
        v = (1 - b2) * g ** 2
        mh = m / (1 - b1)
        vh = v / (1 - b2)
        expect = np.asarray(params[k]) - lr * (
            mh / (np.sqrt(vh) + eps) + wd * np.asarray(params[k])
        )
        np.testing.assert_allclose(np.asarray(new_params[k]), expect,
                                   rtol=1e-5)
    assert int(new_state.step) == 1


def test_adamw_clipping():
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.full((4,), 100.0)}
    state = adamw_init(params)
    _, _, metrics = adamw_update(params, grads, state, 0.1, clip_norm=1.0)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)
    assert float(metrics["clip_scale"]) == pytest.approx(1 / 200.0)


def test_adamw_bf16_moments():
    params = {"w": jnp.ones((8,), jnp.bfloat16)}
    grads = {"w": jnp.full((8,), 0.25, jnp.bfloat16)}
    state = adamw_init(params, moment_dtype=jnp.bfloat16)
    new_params, new_state, _ = adamw_update(params, grads, state, 0.01)
    assert new_state.m["w"].dtype == jnp.bfloat16
    assert new_params["w"].dtype == jnp.bfloat16


def test_cosine_schedule_shape():
    assert float(cosine_schedule(0, 1.0, 10, 100)) < 0.2
    peak = max(float(cosine_schedule(s, 1.0, 10, 100)) for s in range(100))
    assert peak == pytest.approx(1.0, abs=0.05)
    assert float(cosine_schedule(99, 1.0, 10, 100)) < 0.2


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------

def test_data_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=4, seed=7)
    b1 = synthetic_batch(cfg, 5)
    b2 = synthetic_batch(cfg, 5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    it = batch_iterator(cfg, start_step=5)
    step, b3 = next(it)
    assert step == 5
    np.testing.assert_array_equal(b1["tokens"], b3["tokens"])


def test_data_has_learnable_structure():
    """Bigram chain: the same token is followed by the same successor with
    probability >= structure."""
    cfg = DataConfig(vocab_size=50, seq_len=256, global_batch=8, seed=3,
                     structure=1.0)
    b = synthetic_batch(cfg, 0)
    toks = np.asarray(b["tokens"])
    successors = {}
    consistent = total = 0
    for row in toks:
        for a, bb in zip(row[:-1], row[1:]):
            if a in successors:
                total += 1
                consistent += successors[a] == bb
            successors[a] = bb
    assert total > 0 and consistent / total > 0.99


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2, seed=0)
    b = synthetic_batch(cfg, 1)
    np.testing.assert_array_equal(
        np.asarray(b["labels"])[:, :-1], np.asarray(b["tokens"])[:, 1:]
    )


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------

def _tree():
    return {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
        "step": jnp.asarray(3),
    }


def test_checkpoint_roundtrip():
    with tempfile.TemporaryDirectory() as td:
        tree = _tree()
        save_checkpoint(td, 3, tree)
        step, restored = restore_latest(td, tree)
        assert step == 3
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_checkpoint_integrity_check():
    with tempfile.TemporaryDirectory() as td:
        save_checkpoint(td, 1, _tree())
        # corrupt the arrays file
        path = os.path.join(td, "step_1", "arrays.npz")
        data = open(path, "rb").read()
        with open(path, "wb") as f:
            f.write(data[:len(data) // 2])
        with pytest.raises(Exception):
            restore_latest(td, _tree())


def test_checkpoint_keep_k_gc():
    with tempfile.TemporaryDirectory() as td:
        mgr = CheckpointManager(td, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, _tree())
        assert list_steps(td) == [3, 4]


def test_checkpoint_async_save():
    with tempfile.TemporaryDirectory() as td:
        mgr = CheckpointManager(td, keep=3)
        mgr.save_async(7, _tree())
        mgr.wait()
        step, restored = mgr.restore_latest(_tree())
        assert step == 7


def test_atomicity_no_partial_dirs():
    """A tmp dir left by a crashed save must not be listed as a step."""
    with tempfile.TemporaryDirectory() as td:
        os.makedirs(os.path.join(td, "step_9.tmp"))
        assert list_steps(td) == []


def test_elastic_restore_across_meshes():
    """Save sharded one way, restore re-sharded differently: subprocess
    creates 8 devices, saves with a (2,4) mesh sharding, restores onto
    (4,2) and at a different logical axis assignment."""
    import subprocess
    import sys

    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import tempfile
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import save_checkpoint, restore_latest

tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
from repro.compat import make_mesh
mesh_a = make_mesh((2, 4), ("data", "model"))
sharded = {"w": jax.device_put(tree["w"], NamedSharding(mesh_a, P("data", "model")))}
with tempfile.TemporaryDirectory() as td:
    save_checkpoint(td, 1, sharded)
    mesh_b = make_mesh((4, 2), ("data", "model"))
    spec_tree = {"w": P("model", "data")}
    step, restored = restore_latest(td, tree, mesh=mesh_b, spec_tree=spec_tree)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
    assert restored["w"].sharding.spec == P("model", "data")
print("ELASTIC_OK")
"""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(__file__), "..", "src"
    )
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=300, env=env,
    )
    assert proc.returncode == 0, proc.stderr
    assert "ELASTIC_OK" in proc.stdout


# --------------------------------------------------------------------------
# fault-tolerant loop
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_setup():
    from repro.models.sharding import NULL

    cfg = get_smoke("qwen2-1.5b")
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(build_train_step(cfg, NULL))
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4)
    return cfg, state, step, data


def test_loop_trains_and_checkpoints(tiny_setup):
    cfg, state, step, data = tiny_setup
    with tempfile.TemporaryDirectory() as td:
        loop = TrainLoop(
            step, data, LoopConfig(total_steps=8, ckpt_every=4, ckpt_dir=td)
        )
        state2, stats = loop.run(state)
        assert stats.steps_done == 8
        assert int(state2.step) == 8
        assert list_steps(td) == [4, 8]


def test_loop_recovers_from_failure(tiny_setup):
    cfg, state, step, data = tiny_setup
    with tempfile.TemporaryDirectory() as td:
        crashed = {"n": 0}

        def fail(s):
            if s == 6 and crashed["n"] == 0:
                crashed["n"] = 1
                raise RuntimeError("injected node failure")

        loop = TrainLoop(
            step, data, LoopConfig(total_steps=10, ckpt_every=5, ckpt_dir=td)
        )
        state2, stats = loop.run(state, fail_injector=fail)
        assert stats.restarts == 1
        assert int(state2.step) == 10  # resumed from step-5 ckpt, finished


def test_loop_gives_up_after_max_restarts(tiny_setup):
    cfg, state, step, data = tiny_setup
    with tempfile.TemporaryDirectory() as td:
        def always_fail(s):
            raise RuntimeError("hard failure")

        loop = TrainLoop(
            step, data,
            LoopConfig(total_steps=4, ckpt_every=2, ckpt_dir=td,
                       max_restarts=2),
        )
        with pytest.raises(RuntimeError):
            loop.run(state, fail_injector=always_fail)
        assert loop.stats.restarts == 3  # 2 allowed + the final raise


def test_loop_resumes_across_instances(tiny_setup):
    """Simulates full job restart: a NEW loop (new process semantics) picks
    up from the surviving checkpoint."""
    cfg, state, step, data = tiny_setup
    with tempfile.TemporaryDirectory() as td:
        loop1 = TrainLoop(
            step, data, LoopConfig(total_steps=6, ckpt_every=3, ckpt_dir=td)
        )
        loop1.run(state)
        loop2 = TrainLoop(
            step, data, LoopConfig(total_steps=9, ckpt_every=3, ckpt_dir=td)
        )
        state2, stats2 = loop2.run(state)
        assert int(state2.step) == 9
        assert stats2.steps_done == 3  # only 6->9 executed
