"""Per-architecture smoke tests (deliverable f): reduced same-family
configs, one forward + one train-grad + decode steps on CPU; shape and
finiteness assertions. Full configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, all_cells, get_config, get_smoke
from repro.models import (
    decode_step,
    forward,
    init_decode_state,
    init_params,
    loss_fn,
)

B, S, DS = 2, 16, 8


def _batch(cfg, key):
    batch = {}
    if cfg.frontend != "none":
        batch["embeds"] = jax.random.normal(
            key, (B, S, cfg.d_model), jnp.bfloat16
        )
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.is_encdec:
        batch["dec_tokens"] = jax.random.randint(
            key, (B, DS), 0, cfg.vocab_size
        )
        batch["dec_labels"] = jax.random.randint(
            key, (B, DS), 0, cfg.vocab_size
        )
    else:
        batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return batch


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_and_finite(name, key):
    cfg = get_smoke(name)
    params = init_params(key, cfg)
    batch = _batch(cfg, key)
    out, aux = forward(params, cfg, batch)
    s_out = DS if cfg.is_encdec else S
    assert out.shape == (B, s_out, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_grad_finite(name, key):
    cfg = get_smoke(name)
    params = init_params(key, cfg)
    batch = _batch(cfg, key)
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, batch)[0]
    )(params)
    assert np.isfinite(float(loss))
    for g in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_steps(name, key):
    cfg = get_smoke(name)
    params = init_params(key, cfg)
    state = init_decode_state(params, cfg, B, 32)
    cross = None
    if cfg.is_encdec:
        from repro.models.blocks import apply_stack
        from repro.models.layers import apply_norm
        from repro.models.model import _encoder_kv

        x = jax.random.normal(key, (B, S, cfg.d_model), jnp.bfloat16)
        pos = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
        enc, _ = apply_stack(params["encoder"], x, cfg, pos, causal=False)
        cross = _encoder_kv(cfg, apply_norm(params["enc_norm"], enc))
    tok = jnp.zeros((B, 1), jnp.int32)
    for _ in range(3):
        lg, state = decode_step(params, cfg, state, tok, cross_kv=cross)
        assert lg.shape == (B, 1, cfg.padded_vocab)
        assert bool(jnp.all(jnp.isfinite(lg.astype(jnp.float32))))
        tok = jnp.argmax(lg[:, -1:, :], axis=-1).astype(jnp.int32)


def test_decode_matches_forward_mamba2():
    """Recurrent decode must agree with the chunked parallel forward (SSD
    duality!) on a shared prefix."""
    cfg = get_smoke("mamba2-2.7b")
    key = jax.random.PRNGKey(3)
    params = init_params(key, cfg)
    toks = jax.random.randint(key, (1, 8), 0, cfg.vocab_size)
    out_par, _ = forward(params, cfg, {"tokens": toks})
    state = init_decode_state(params, cfg, 1, 16)
    outs = []
    for t in range(8):
        lg, state = decode_step(params, cfg, state, toks[:, t: t + 1])
        outs.append(lg[:, 0])
    out_seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(out_par, np.float32),
        np.asarray(out_seq, np.float32),
        rtol=0.12, atol=0.12,  # bf16 params, different contraction orders
    )


def test_decode_matches_forward_dense():
    """KV-cache decode must agree with the causal parallel forward."""
    cfg = get_smoke("qwen2-1.5b")
    key = jax.random.PRNGKey(4)
    params = init_params(key, cfg)
    toks = jax.random.randint(key, (1, 8), 0, cfg.vocab_size)
    out_par, _ = forward(params, cfg, {"tokens": toks})
    state = init_decode_state(params, cfg, 1, 16)
    outs = []
    for t in range(8):
        lg, state = decode_step(params, cfg, state, toks[:, t: t + 1])
        outs.append(lg[:, 0])
    out_seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(out_par, np.float32),
        np.asarray(out_seq, np.float32),
        rtol=0.1, atol=0.1,
    )


def test_published_param_counts():
    """Full configs must hit the published parameter counts (±6%)."""
    expected = {
        "mamba2-2.7b": 2.7e9,
        "olmoe-1b-7b": 6.9e9,
        "nemotron-4-340b": 340e9,
        "deepseek-coder-33b": 33e9,
        "yi-34b": 34.4e9,
        "qwen2-1.5b": 1.54e9,
        "jamba-v0.1-52b": 52e9,
        "qwen2-vl-72b": 72.7e9,
    }
    for name, target in expected.items():
        n = get_config(name).param_count()
        assert abs(n - target) / target < 0.06, (name, n, target)


def test_active_param_counts_moe():
    assert abs(get_config("olmoe-1b-7b").active_param_count() - 1.3e9) < 2e8
    assert (
        abs(get_config("jamba-v0.1-52b").active_param_count() - 12e9) < 1.5e9
    )


def test_cell_matrix_structure():
    cells = all_cells()
    assert len(cells) == 40
    skips = [c for c in cells if c[2] is not None]
    assert len(skips) == 8  # long_500k on the 8 full-attention archs
    for arch, shape, reason in skips:
        assert shape == "long_500k"
        assert arch not in ("mamba2-2.7b", "jamba-v0.1-52b")


def test_flash_attention_matches_plain():
    from repro.models.attention import attention, attention_prefill, init_attn

    cfg = get_smoke("qwen2-vl-72b")
    key = jax.random.PRNGKey(5)
    p = init_attn(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 32, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(32), (2, 32)).astype(jnp.int32)
    plain = attention(p, x, cfg, pos)
    flash, _ = attention_prefill(p, x, cfg, pos, q_chunk=8, kv_chunk=8)
    np.testing.assert_allclose(
        np.asarray(plain), np.asarray(flash), rtol=2e-3, atol=2e-3
    )


def test_moe_router_load_balance_loss_positive():
    from repro.models.moe import apply_moe, init_moe

    cfg = get_smoke("olmoe-1b-7b")
    key = jax.random.PRNGKey(6)
    p = init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 16, cfg.d_model), jnp.float32)
    y, aux = apply_moe(p, x, cfg)
    assert y.shape == x.shape
    assert float(aux) >= 1.0 - 1e-3  # >= 1 by Cauchy-Schwarz, == 1 balanced


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor >= 1 and uniform routing, most tokens survive."""
    from repro.models.moe import apply_moe, init_moe

    cfg = get_smoke("olmoe-1b-7b")
    key = jax.random.PRNGKey(7)
    p = init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(key, (4, 32, cfg.d_model), jnp.float32)
    y, _ = apply_moe(p, x, cfg, capacity_factor=2.0)
    # a dropped token yields an exactly-zero output row; at cf=2 with a
    # fresh random router drops should be rare
    zero_rows = float(
        jnp.mean(jnp.all(y.reshape(-1, cfg.d_model) == 0, axis=-1))
    )
    assert zero_rows < 0.2
