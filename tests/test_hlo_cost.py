"""Trip-count-aware HLO cost walker: the §Roofline foundations.

Validates (1) while-body scaling against layer-count sweeps, (2) agreement
with analytic 6ND FLOPs, (3) collective loop-scaling, (4) slice-aware
fusion byte accounting primitives.
"""

import jax
import jax.numpy as jnp
from dataclasses import replace

from repro.analysis.hlo_cost import (
    _shape_bytes,
    analyze_module,
    parse_computations,
)
from repro.configs import get_smoke
from repro.models import init_params, loss_fn


def _compile_loss(cfg, grad=False):
    params = jax.eval_shape(
        lambda k: init_params(k, cfg), jax.random.PRNGKey(0)
    )
    batch = {
        "tokens": jax.ShapeDtypeStruct((2, 32), jnp.int32),
        "labels": jax.ShapeDtypeStruct((2, 32), jnp.int32),
    }
    def fn(p, b):
        return loss_fn(p, cfg, b)[0]

    if grad:
        fn = jax.grad(fn)
    return jax.jit(fn).lower(params, batch).compile()


def test_shape_bytes():
    assert _shape_bytes("f32[2,3]{1,0}") == 24
    assert _shape_bytes("bf16[128]") == 256
    assert _shape_bytes("(f32[2], s32[4])") == 24
    assert _shape_bytes("pred[]") == 1


def test_flops_scale_linearly_with_layers():
    """XLA's raw cost_analysis does NOT scale with scan length; the walker
    must (this is the whole point)."""
    vals = {}
    for layers in (2, 8):
        cfg = replace(get_smoke("qwen2-1.5b"), n_layers=layers)
        co = _compile_loss(cfg)
        from repro.compat import cost_analysis

        raw = cost_analysis(co).get("flops", 0.0)
        walker = analyze_module(co.as_text()).flops
        vals[layers] = (raw, walker)
    raw_ratio = vals[8][0] / vals[2][0]
    walker_ratio = vals[8][1] / vals[2][1]
    assert raw_ratio < 1.5  # the known undercount
    assert 2.5 < walker_ratio < 4.5  # ~4x (embed/logits are fixed cost)


def test_train_flops_match_6nd_within_remat_slack():
    cfg = replace(get_smoke("qwen2-1.5b"), n_layers=4)
    co = _compile_loss(cfg, grad=True)
    walker = analyze_module(co.as_text()).flops
    d_tokens = 2 * 32
    analytic = 6 * cfg.param_count() * d_tokens
    # full remat -> ~8/6 of 6ND, plus attention; must land in [1.0, 2.0]
    assert 1.0 < walker / analytic < 2.0, walker / analytic


def test_collectives_scaled_by_trip_count():
    text = """
ENTRY %main (p: f32[64,8]) -> f32[64,8] {
  %p = f32[64,8]{1,0} parameter(0)
  %t = (s32[], f32[64,8]{1,0}) tuple(%c, %p)
  %w = (s32[], f32[64,8]{1,0}) while(%t), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %r = f32[64,8]{1,0} get-tuple-element(%w), index=1
}

%body (a: (s32[], f32[64,8])) -> (s32[], f32[64,8]) {
  %a = (s32[], f32[64,8]{1,0}) parameter(0)
  %x = f32[64,8]{1,0} get-tuple-element(%a), index=1
  %ar = f32[64,8]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %i = s32[] get-tuple-element(%a), index=0
  ROOT %out = (s32[], f32[64,8]{1,0}) tuple(%i, %ar)
}

%cond (a: (s32[], f32[64,8])) -> pred[] {
  %a = (s32[], f32[64,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%a), index=0
  %c5 = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %c5), direction=LT
}
"""
    mc = analyze_module(text)
    assert len(mc.collectives) == 1
    c = mc.collectives[0]
    assert c.count == 5
    assert c.operand_bytes == 64 * 8 * 4
    assert mc.collective_operand_bytes == 5 * 64 * 8 * 4


def test_parse_computations_tuple_params():
    text = """
%f (a: (s32[], f32[4])) -> f32[4] {
  %a = (s32[], f32[4]{0}) parameter(0)
  ROOT %x = f32[4]{0} get-tuple-element(%a), index=1
}
"""
    comps = parse_computations(text)
    assert "f" in comps
    assert len(comps["f"]) == 2


def test_dot_flops_with_contraction():
    text = """
ENTRY %main (a: f32[8,16], b: f32[16,4]) -> f32[8,4] {
  %a = f32[8,16]{1,0} parameter(0)
  %b = f32[16,4]{1,0} parameter(1)
  ROOT %d = f32[8,4]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    mc = analyze_module(text)
    assert mc.flops == 2 * 8 * 4 * 16


def test_decode_step_costs_scale_with_cache():
    """Walker bytes for decode must grow with the KV cache length (the
    memory-bound decode roofline depends on it)."""
    from repro.models import decode_step, init_decode_state

    cfg = replace(get_smoke("qwen2-1.5b"), n_layers=2)
    params = jax.eval_shape(
        lambda k: init_params(k, cfg), jax.random.PRNGKey(0)
    )
    vals = {}
    for cache_len in (64, 256):
        state = jax.eval_shape(
            lambda p: init_decode_state(p, cfg, 2, cache_len), params
        )
        tok = jax.ShapeDtypeStruct((2, 1), jnp.int32)
        co = (
            jax.jit(lambda p, s, t: decode_step(p, cfg, s, t))
            .lower(params, state, tok)
            .compile()
        )
        vals[cache_len] = analyze_module(co.as_text()).bytes
    assert vals[256] > 1.5 * vals[64]
