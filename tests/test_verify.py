"""repro.verify: each analyzer catches its seeded known-bad input, and
the shipped tree verifies clean (the ISSUE's acceptance criteria).

Five sections mirror the five analyzers:

* plans   — a hand-built Eq-9-infeasible BlockPlan is flagged; the
  planner sweep over the default lattice emits nothing.
* kernels — hand-built captures with a coverage gap / OOB origin /
  torn accumulation run / footprint mismatch are each flagged; the five
  shipped kernels verify clean with footprints equal to the planner's
  ``kernel_block_words`` claims, and *no kernel is executed* (the
  dispatch counter is untouched).
* lint    — one fixture per RV rule (RV101 is the PR-6 falsy-cache bug,
  verbatim shape), the waiver comment works, and ``lint_tree()`` over
  the installed package is empty.
* comm    — fast-lane subset of the byte lattice traces byte-exact on
  an AbstractMesh (no devices, no dispatches); seeded known-bad inputs
  (an extra traced collective, a two-cycle permutation, an off-by-one
  consumer, a shifted reduce-scatter schedule, a suboptimal grid
  choice) fire their rules.
* dtypes  — the shipped backends accumulate fp32 under
  ``compute_dtype=bfloat16``; a plain bf16 contraction fixture fires
  ``narrow-accumulator``.
"""

from repro.engine.plan import BlockPlan, Memory, MultiTTMPlan
from repro.observe.metrics import PALLAS_DISPATCHES
from repro.observe import load_trace, registry
from repro.verify import Finding
from repro.verify.comm import (
    check_cp_sweep,
    check_consumer_schedule,
    check_grid_selection,
    check_mttkrp_stationary,
    check_program_bytes,
    check_reduce_scatter_schedule,
    check_ring_permutation,
    check_ring_schedules,
    check_tucker_sweep,
    mttkrp_model_bytes,
    trace_collectives,
    verify_comm,
)
from repro.verify.dtypes import check_accumulation, verify_dtypes
from repro.verify.kernels import (
    KernelCapture,
    SpecCapture,
    check_capture,
    verify_kernels,
)
from repro.verify.lint import RULES, lint_source, lint_tree, rule_catalog
from repro.verify.plans import (
    check_batched_plans,
    check_block_plan,
    check_memory_itemsize,
    check_multi_ttm_plan,
    verify_plans,
)
from repro.verify.__main__ import main, run

VMEM = Memory.tpu_vmem(itemsize=4)


def _rules(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# plans
# ---------------------------------------------------------------------------

def test_eq9_infeasible_plan_is_flagged():
    """A block plan whose Eq-9 working set exceeds VMEM — while the
    all-ones plan fits — must be charged as infeasible-by-choice."""
    bad = BlockPlan(4096, (4096, 4096), 4096)
    assert not bad.fits(VMEM)
    fs = check_block_plan(bad, (8192, 8192, 8192), 4096, VMEM)
    assert "eq9-infeasible" in _rules(fs)


def test_eq9_not_charged_when_no_plan_fits():
    """A memory too small for even the all-ones plan is a property of
    the memory, not a planner bug: no finding."""
    tiny = Memory.abstract(2)
    plan = BlockPlan(1, (1, 1), 1)
    fs = check_block_plan(plan, (4, 4, 4), 2, tiny)
    assert "eq9-infeasible" not in _rules(fs)


def test_nonpositive_block_is_flagged():
    fs = check_block_plan(BlockPlan(0, (1, 1), 1), (4, 4, 4), 2, VMEM)
    assert _rules(fs) == {"nonpositive-block"}


def test_multi_ttm_infeasible_plan_is_flagged():
    bad = MultiTTMPlan(4096, (4096, 4096), (64, 64))
    assert not bad.fits(VMEM)
    fs = check_multi_ttm_plan(bad, (8192, 8192, 8192), (64, 64), VMEM)
    assert "eq9-infeasible" in _rules(fs)


def test_memory_itemsize_propagation_clean():
    assert check_memory_itemsize(VMEM) == []
    assert check_memory_itemsize(Memory.abstract(1000)) == []


def test_planner_sweep_is_clean():
    """Acceptance: choose_blocks / choose_sweep_blocks /
    choose_multi_ttm_blocks / best_uniform_block never emit a plan that
    fails any static check, across the whole default lattice."""
    assert verify_plans() == []


def test_batched_planner_sweep_is_clean():
    """batched_choose_blocks delegates to choose_blocks, so the batched
    plan equals the element plan (same blocks, same Eq-9 working set)
    for every B across the default lattice — by construction, and now
    by static proof."""
    assert check_batched_plans() == []


def test_batched_plan_divergence_is_flagged():
    """Known-bad fixture: a chooser that scales the rank tile with B
    (so the batched working set grows with the batch) is statically
    rejected for every B > 1."""
    from dataclasses import replace as _replace

    from repro.engine.plan import choose_blocks

    def bad_chooser(b, shape, rank, itemsize, memory=None):
        base = choose_blocks(shape, rank, itemsize, memory=memory)
        if b == 1:
            return base
        return _replace(base, block_r=base.block_r * b)

    findings = check_batched_plans(
        shapes=[(16, 14, 12)], ranks=[4], memories=[VMEM],
        batch_sizes=(1, 2, 4), chooser=bad_chooser,
    )
    assert len(findings) == 2  # B=2 and B=4 diverge; B=1 is the base
    assert all(f.rule == "batched-plan-divergence" for f in findings)
    assert all(f.analyzer == "plans" for f in findings)
    assert "B=2" in findings[0].detail or "B=2" in findings[0].subject


# ---------------------------------------------------------------------------
# kernels
# ---------------------------------------------------------------------------

def _out_capture(grid, block, operand, index_map):
    spec = SpecCapture(block, index_map, operand)
    return KernelCapture(
        grid=grid, out_specs=(spec,), out_dtypes=("float32",),
    )


def test_coverage_gap_is_flagged():
    # 4 output blocks, a 2-step grid: half the output is never written.
    cap = _out_capture((2,), (4,), (16,), lambda i: (i,))
    fs = check_capture(cap, kernel="fixture")
    assert "coverage-gap" in _rules(fs)


def test_oob_origin_is_flagged():
    cap = _out_capture((2,), (4,), (8,), lambda i: (i + 1,))
    fs = check_capture(cap, kernel="fixture")
    assert "oob-origin" in _rules(fs)


def test_torn_accumulation_run_is_flagged():
    # grid (2, 2), output indexed by the *inner* dim only: block (0,) is
    # visited at steps 0 and 2 — the revisit is non-consecutive, so the
    # block would be written back twice.
    cap = _out_capture((2, 2), (3,), (6,), lambda i, j: (j,))
    fs = check_capture(cap, kernel="fixture")
    assert "noncontiguous-revisit" in _rules(fs)


def test_block_divisibility_is_flagged():
    cap = _out_capture((2,), (3,), (8,), lambda i: (i,))
    fs = check_capture(cap, kernel="fixture")
    assert "block-divisibility" in _rules(fs)


def test_index_map_arity_mismatch_is_flagged():
    cap = _out_capture((2,), (4,), (8,), lambda i: (i, 0))
    fs = check_capture(cap, kernel="fixture")
    assert "index-map" in _rules(fs)


def test_footprint_mismatch_is_flagged():
    cap = _out_capture((2,), (4,), (8,), lambda i: (i,))
    fs = check_capture(cap, kernel="fixture", claimed_block_words=9999)
    assert "footprint-mismatch" in _rules(fs)


def test_acc_dtype_violation_is_flagged():
    spec = SpecCapture((4,), lambda i: (i,), (8,))
    cap = KernelCapture(
        grid=(2,), out_specs=(spec,), out_dtypes=("bfloat16",),
    )
    fs = check_capture(cap, kernel="fixture")
    assert "acc-dtype" in _rules(fs)


def test_shipped_kernels_verify_clean_without_executing():
    """Acceptance: every shipped Pallas kernel's BlockSpec footprint
    equals the planner's kernel_block_words claim, schedules cover the
    output with contiguous accumulation runs, accumulators are fp32 —
    and the analysis never dispatches a kernel."""
    before = registry().counter(PALLAS_DISPATCHES)
    findings, verdicts = verify_kernels()
    assert findings == []
    names = {v["name"] for v in verdicts}
    assert names == {
        "mttkrp3", "mttkrpn", "mttkrp_partial", "multi_ttm", "fused_pair",
    }
    for v in verdicts:
        assert v["agrees"], v
        assert v["findings"] == 0, v
        assert v["footprint_words"] == v["claimed_words"], v
        # the working set the planner quotes = BlockSpec tiles + scratch
        assert v["working_set_words"] >= v["claimed_words"], v
        # multi-block grids: the schedule checks actually exercise
        # accumulation runs, not single-block trivia
        assert len([g for g in v["grid"] if g > 1]) >= 2, v
    assert registry().counter(PALLAS_DISPATCHES) == before


# ---------------------------------------------------------------------------
# lint
# ---------------------------------------------------------------------------

def test_rv101_falsy_cache_fixture():
    # the PR-6 bug, verbatim shape: an *empty* PlanCache is falsy
    src = (
        "def save(cal, cache=None):\n"
        "    (cache or default_cache()).put_calibration(cal)\n"
    )
    fs = lint_source(src, "tune/fixture.py")
    assert _rules(fs) == {"RV101"}


def test_rv101_is_not_flagged_on_none_check():
    src = (
        "def save(cal, cache=None):\n"
        "    dest = default_cache() if cache is None else cache\n"
        "    dest.put_calibration(cal)\n"
    )
    assert lint_source(src, "tune/fixture.py") == []


def test_rv102_tracer_branch_fixture():
    src = (
        "import jax.numpy as jnp\n"
        "def f(x):\n"
        "    if jnp.sum(x) > 0:\n"
        "        return x\n"
        "    return -x\n"
    )
    fs = lint_source(src, "engine/fixture.py")
    assert _rules(fs) == {"RV102"}
    # dtype inspection is static under tracing: allowlisted
    safe = (
        "import jax.numpy as jnp\n"
        "def f(x):\n"
        "    if jnp.issubdtype(x.dtype, jnp.floating):\n"
        "        return x\n"
        "    return -x\n"
    )
    assert lint_source(safe, "engine/fixture.py") == []
    # outside the traced layers the same code is fine
    assert lint_source(src, "analysis/fixture.py") == []


def test_rv103_jax_in_pure_math_fixture():
    src = "import jax\n"
    fs = lint_source(src, "engine/plan.py")
    assert _rules(fs) == {"RV103"}
    assert lint_source(src, "engine/other.py") == []


def test_rv104_mutable_default_fixture():
    fs = lint_source("def f(x=[]):\n    return x\n", "core/fixture.py")
    assert _rules(fs) == {"RV104"}
    fs = lint_source(
        "def f(x=make()):\n    return x\n", "core/fixture.py"
    )
    assert _rules(fs) == {"RV104"}


def test_rv105_wallclock_fixture():
    src = "import time\ndef f():\n    return time.perf_counter()\n"
    fs = lint_source(src, "core/fixture.py")
    assert _rules(fs) == {"RV105"}
    # measurement layers and the dispatch layer's span timing are exempt
    assert lint_source(src, "tune/fixture.py") == []
    assert lint_source(src, "engine/execute.py") == []
    assert lint_source(src, "engine/sweep.py") == []


def test_rv106_shim_reintroduction_fixture():
    fs = lint_source(
        "def pallas_dispatch_count():\n    return 0\n",
        "engine/fixture.py",
    )
    assert _rules(fs) == {"RV106"}
    fs = lint_source(
        "from repro.engine.execute import pallas_dispatch_count\n",
        "analysis/fixture.py",
    )
    assert _rules(fs) == {"RV106"}


def test_rv107_raw_collective_outside_distributed_fixture():
    src = (
        "import jax\n"
        "def f(x):\n"
        "    return jax.lax.psum(x, 'i')\n"
    )
    fs = lint_source(src, "engine/fixture.py")
    assert _rules(fs) == {"RV107"}
    # distributed/ is the collective surface's sanctioned home
    assert lint_source(src, "distributed/fixture.py") == []
    # the from-import spelling is caught too
    imp = "from jax.lax import ppermute\n"
    assert _rules(lint_source(imp, "analysis/fixture.py")) == {"RV107"}
    assert lint_source(imp, "distributed/fixture.py") == []
    # non-collective lax usage outside distributed/ stays legal
    ok = "import jax\ndef f(x):\n    return jax.lax.exp(x)\n"
    assert lint_source(ok, "engine/fixture.py") == []


def test_rv108_axis_literal_fixture():
    src = "def axes():\n    return ('r', 'm0')\n"
    fs = lint_source(src, "distributed/fixture.py")
    assert _rules(fs) == {"RV108"} and len(fs) == 2
    # outside distributed/ the strings mean nothing mesh-related
    assert lint_source(src, "engine/fixture.py") == []
    # mesh.py is the axis-name home: the definitions live there
    assert lint_source(src, "distributed/mesh.py") == []
    # strings that aren't axis names are fine anywhere
    ok = "def f():\n    return ('ring', 'm10x')\n"
    assert lint_source(ok, "distributed/fixture.py") == []


def test_waiver_comment_suppresses_finding():
    src = (
        "import time\n"
        "def f():\n"
        "    return time.perf_counter()  # verify: allow=RV105\n"
    )
    assert lint_source(src, "core/fixture.py") == []
    # allow=all works too
    src_all = src.replace("allow=RV105", "allow=all")
    assert lint_source(src_all, "core/fixture.py") == []


def test_unparsable_module_is_a_finding():
    fs = lint_source("def broken(:\n", "core/fixture.py")
    assert [f.rule for f in fs] == ["syntax"]


def test_rule_catalog_lists_every_rule():
    cat = rule_catalog()
    for r in RULES:
        assert r.code in cat and r.name in cat


def test_lint_tree_is_clean():
    """Acceptance: the shipped package has zero lint findings."""
    assert lint_tree() == []


# ---------------------------------------------------------------------------
# comm: byte lattice (fast-lane subset), ring schedules, known-bad fixtures
# ---------------------------------------------------------------------------

def test_cp_sweep_point_is_byte_exact_both_overlaps():
    """Fast-lane single-process comm check: one CP lattice point traces
    byte-exact on the AbstractMesh in both overlap spellings, with no
    kernel dispatch (the nightly dist_worker proves the compiled HLO)."""
    before = registry().counter(PALLAS_DISPATCHES)
    for overlap in ("none", "ring"):
        fs, v = check_cp_sweep((8, 8, 8), 4, (2, 2, 2), overlap)
        assert fs == []
        assert v["agrees"] and v["measured_collective_bytes"] == int(
            v["modeled_words"] * v["itemsize"]
        )
        assert v["measured_collective_bytes"] >= int(
            v["lower_bound_words"] * v["itemsize"]
        )
        if overlap == "ring":
            # the ring spelling is all collective-permutes
            assert "collective-permute" in v["collectives"]
            assert "all-gather" not in v["collectives"]
        else:
            assert "all-gather" in v["collectives"]
    assert registry().counter(PALLAS_DISPATCHES) == before


def test_tucker_sweep_point_is_byte_exact():
    fs, v = check_tucker_sweep((16, 16, 16), (4, 3, 2), (2, 2, 2), "none")
    assert fs == [] and v["agrees"]
    assert v["measured_collective_bytes"] == int(
        v["modeled_words"] * v["itemsize"]
    )


def test_mttkrp_stationary_point_matches_eq12():
    dims, rank, grid, mode = (8, 8, 8), 4, (2, 2, 2), 1
    fs, v = check_mttkrp_stationary(dims, rank, grid, mode)
    assert fs == [] and v["agrees"]
    assert v["measured_collective_bytes"] == mttkrp_model_bytes(
        dims, rank, grid, mode
    )


def test_byte_model_mismatch_fires_on_extra_collective():
    """Known-bad program: a shard_map body with a collective the sweep
    model does not account for must be flagged, not absorbed."""
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.distributed.mesh import make_abstract_grid_mesh
    from repro.verify.comm import _sds

    mesh = make_abstract_grid_mesh((2, 2))
    fn = shard_map(
        lambda x: jax.lax.psum(x, ("m0", "m1")),
        mesh=mesh, in_specs=P("m0", "m1"), out_specs=P(),
    )
    summ = trace_collectives(fn, (_sds((8, 8)),), dict(mesh.shape))
    assert summ.ring_bytes > 0  # the psum was seen and costed
    fs = check_program_bytes("fixture", summ.ring_bytes, 0, 0)
    assert _rules(fs) == {"byte-model-mismatch"}


def test_below_lower_bound_fires():
    fs = check_program_bytes("fixture", 8, 8, 64)
    assert _rules(fs) == {"below-lower-bound"}


def test_shipped_ring_schedules_are_clean():
    for q in (1, 2, 3, 4, 5, 8):
        assert check_ring_schedules(q) == []


def test_two_cycle_permutation_is_flagged_as_deadlock():
    """The classic bug: stride-2 neighbor exchange on an even ring is
    two disjoint cycles — half the shards never circulate."""
    q = 4
    perm = [(i, (i + 2) % q) for i in range(q)]
    fs = check_ring_permutation(perm, q, "fixture")
    assert _rules(fs) == {"ring-deadlock"}
    assert "cycles" in fs[0].detail
    # a non-permutation (two sources, one destination) is also flagged
    fs = check_ring_permutation([(0, 1), (1, 1), (2, 3), (3, 0)], q, "f")
    assert _rules(fs) == {"ring-deadlock"}


def test_off_by_one_consumer_is_flagged():
    """A consumer reading the chunk one step early references data that
    has not arrived yet — a silent race on real async hardware."""
    fs = check_consumer_schedule(
        4, "fixture", source_fn=lambda me, t, q: (me - t - 1) % q
    )
    assert "read-before-arrival" in _rules(fs)


def test_wrong_reduce_scatter_schedule_is_flagged():
    """A sign-flipped chunk walk deposits the wrong blocks: processor j
    does not end up holding every contribution to block j."""
    fs = check_reduce_scatter_schedule(
        4, "fixture", chunk_fn=lambda me, t, q: (me + t + 1) % q
    )
    assert "ring-reduction-coverage" in _rules(fs)


def test_grid_suboptimal_fires_on_worse_choice(monkeypatch):
    import types

    import repro.distributed.grid_select as gs

    ref = gs.brute_force_stationary((8, 8, 8), 4, 8, mode=None)
    fake = types.SimpleNamespace(grid=(8, 1, 1), words=ref.words * 2 + 1)
    monkeypatch.setattr(
        gs, "select_stationary_grid", lambda *a, **k: fake
    )
    fs = check_grid_selection((8, 8, 8), 4, 8)
    assert _rules(fs) == {"grid-suboptimal"}


def test_verify_comm_subset_clean_without_executing():
    """A reduced lattice through the driver: zero findings, per-program
    verdicts byte-exact, dispatch counter untouched."""
    before = registry().counter(PALLAS_DISPATCHES)
    findings, verdicts = verify_comm(
        cp_cases=(((8, 8, 8), 4, (1, 2, 2)),),
        tucker_cases=(),
        mttkrp_cases=(((8, 8, 8), 4, (2, 2, 2), 0),),
        ring_sizes=(1, 2, 4),
    )
    assert findings == []
    byte_points = [v for v in verdicts
                   if "measured_collective_bytes" in v]
    assert len(byte_points) == 3  # cp x 2 overlaps + 1 mttkrp
    for v in byte_points:
        assert v["agrees"], v
    names = {v["name"] for v in verdicts}
    assert "ring_schedule" in names and "grid_selection" in names
    assert registry().counter(PALLAS_DISPATCHES) == before


# ---------------------------------------------------------------------------
# dtypes
# ---------------------------------------------------------------------------

def test_narrow_accumulator_fixture_fires():
    """A plain bf16 contraction (no preferred_element_type) accumulates
    narrow — exactly the blocked_host bug this analyzer caught."""
    import jax
    import jax.numpy as jnp

    def bad(a, b):
        return jnp.einsum("ij,jk->ik", a, b)

    def good(a, b):
        return jnp.einsum(
            "ij,jk->ik", a, b, preferred_element_type=jnp.float32
        )

    a = jax.ShapeDtypeStruct((4, 4), jnp.bfloat16)
    closed = jax.make_jaxpr(bad)(a, a)
    fs, sites = check_accumulation(closed, "fixture")
    assert sites and _rules(fs) == {"narrow-accumulator"}
    closed = jax.make_jaxpr(good)(a, a)
    fs, sites = check_accumulation(closed, "fixture")
    assert sites and fs == []


def test_verify_dtypes_clean_without_executing():
    """Acceptance: every backend accumulates fp32 under
    compute_dtype=bfloat16, proven by trace alone."""
    before = registry().counter(PALLAS_DISPATCHES)
    findings, verdicts = verify_dtypes()
    assert findings == []
    names = {v["name"] for v in verdicts}
    assert names == {
        "mttkrp/einsum", "mttkrp/blocked_host", "mttkrp/pallas",
        "multi_ttm/einsum", "multi_ttm/blocked_host", "multi_ttm/pallas",
    }
    for v in verdicts:
        assert v["agrees"] and v["narrow_accumulations"] == 0, v
        # the proof is vacuous unless accumulation sites were found
        assert v["accumulations"] > 0, v
    assert registry().counter(PALLAS_DISPATCHES) == before


# ---------------------------------------------------------------------------
# CLI + trace export
# ---------------------------------------------------------------------------

def test_finding_str_and_dict():
    f = Finding("lint", "RV101", "tune/x.py:3", "falsy or")
    assert str(f) == "[lint:RV101] tune/x.py:3: falsy or"
    assert f.to_dict()["rule"] == "RV101"


def test_cli_rules_exits_zero(capsys):
    assert main(["--rules"]) == 0
    out = capsys.readouterr().out
    assert "RV101" in out and "RV106" in out


def test_cli_unknown_analyzer_exits_two(capsys):
    assert main(["--only", "bogus"]) == 2


def test_cli_selectors_compose(monkeypatch):
    """--comm/--dtypes are selector shorthands; they union with --only
    and with each other (parsing only — the analyzers are stubbed)."""
    import repro.verify.__main__ as vm

    seen = {}

    def fake_run(only, trace_out=None):
        seen["only"] = only
        return [], []

    monkeypatch.setattr(vm, "run", fake_run)
    assert vm.main(["--comm", "--dtypes"]) == 0
    assert seen["only"] == ("comm", "dtypes")
    assert vm.main(["--only", "lint", "--comm"]) == 0
    assert seen["only"] == ("lint", "comm")
    assert vm.main(["--only", "comm", "--comm"]) == 0
    assert seen["only"] == ("comm",)  # no double-run
    assert vm.main([]) == 0
    assert seen["only"] == ("plans", "kernels", "lint", "comm", "dtypes")


def test_cli_dtypes_selector_end_to_end(capsys):
    assert main(["--dtypes"]) == 0
    out = capsys.readouterr().out
    assert "dtypes mttkrp/pallas" in out
    assert "0 finding(s) across dtypes" in out
    assert "6 dtype program(s)" in out


def test_cli_lint_clean_tree_exits_zero(capsys):
    assert main(["--only", "lint"]) == 0
    assert "0 finding(s)" in capsys.readouterr().out


def test_cli_findings_exit_one(monkeypatch, capsys):
    import repro.verify.lint as lint_mod

    monkeypatch.setattr(
        lint_mod, "lint_tree",
        lambda: [Finding("lint", "RV999", "x.py:1", "seeded")],
    )
    assert main(["--only", "lint"]) == 1
    assert "[lint:RV999]" in capsys.readouterr().out


def test_trace_export_schema(tmp_path):
    """--trace-out writes kind=static_verify events in the observe span
    schema, one per kernel verdict plus a summary row the report CLI
    can table (static_verify is in its DISPATCH_KINDS)."""
    from repro.observe.report import DISPATCH_KINDS, render_rows

    p = tmp_path / "verify.jsonl"
    findings, verdicts = run(("kernels",), trace_out=str(p))
    assert findings == []
    events = load_trace(str(p))
    sv = [e for e in events if e["kind"] == "static_verify"]
    assert len(sv) == len(verdicts) + 1  # one per kernel + summary
    summary = sv[-1]
    assert summary["name"] == "summary"
    assert summary["kernels_checked"] == len(verdicts)
    assert summary["kernels_agreeing"] == len(verdicts)
    assert summary["findings"] == 0
    assert "static_verify" in DISPATCH_KINDS
    rows, flagged = render_rows(events)
    assert len(rows) == len(sv) and flagged == 0


def test_comm_trace_export_carries_byte_columns(tmp_path):
    """--trace-out on the comm analyzer exports per-grid verdicts whose
    modeled/bound/measured columns the report CLI tables."""
    from repro.observe.report import render_rows

    p = tmp_path / "comm.jsonl"
    findings, verdicts = run(("comm",), trace_out=str(p))
    assert findings == []
    events = load_trace(str(p))
    sv = [e for e in events if e["kind"] == "static_verify"]
    assert len(sv) == len(verdicts) + 1
    byte_events = [
        e for e in sv if "measured_collective_bytes" in e
    ]
    assert len(byte_events) >= 16  # the full lattice, both overlaps
    for e in byte_events:
        assert e["measured_collective_bytes"] == int(
            e["modeled_words"] * e["itemsize"]
        )
    summary = sv[-1]
    assert summary["comm_points"] == len(verdicts)
    assert summary["findings"] == 0
    rows, flagged = render_rows(events)
    # byte-exact programs sit at exactly 1.00x model: nothing flags
    assert len(rows) == len(sv) and flagged == 0


def test_default_run_matches_cli_contract():
    """run() over all analyzers returns the same clean verdict the CI
    gate requires (python -m repro.verify exits 0 on this tree) —
    including the ISSUE's acceptance floor of >= 8 byte-exact lattice
    points per sweep family, in both overlap modes."""
    findings, verdicts = run()
    assert findings == []
    by: dict = {}
    for v in verdicts:
        by.setdefault(v["analyzer"], []).append(v)
    assert len(by["kernels"]) == 5
    assert len(by["dtypes"]) == 6
    cp = [v for v in by["comm"] if v["name"].startswith("cp_sweep")]
    tucker = [
        v for v in by["comm"] if v["name"].startswith("tucker_sweep")
    ]
    mttkrp = [
        v for v in by["comm"]
        if v["name"].startswith("mttkrp_stationary")
    ]
    assert len(cp) >= 8 and len(tucker) >= 8 and len(mttkrp) >= 4
    assert {v["overlap"] for v in cp} == {"none", "ring"}
    assert {v["overlap"] for v in tucker} == {"none", "ring"}
    for v in cp + tucker + mttkrp:
        assert v["measured_collective_bytes"] == int(
            v["modeled_words"] * v["itemsize"]
        ), v
