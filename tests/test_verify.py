"""repro.verify: each analyzer catches its seeded known-bad input, and
the shipped tree verifies clean (the ISSUE's acceptance criteria).

Three sections mirror the three analyzers:

* plans   — a hand-built Eq-9-infeasible BlockPlan is flagged; the
  planner sweep over the default lattice emits nothing.
* kernels — hand-built captures with a coverage gap / OOB origin /
  torn accumulation run / footprint mismatch are each flagged; the five
  shipped kernels verify clean with footprints equal to the planner's
  ``kernel_block_words`` claims, and *no kernel is executed* (the
  dispatch counter is untouched).
* lint    — one fixture per RV rule (RV101 is the PR-6 falsy-cache bug,
  verbatim shape), the waiver comment works, and ``lint_tree()`` over
  the installed package is empty.
"""

from repro.engine.plan import BlockPlan, Memory, MultiTTMPlan
from repro.observe.metrics import PALLAS_DISPATCHES
from repro.observe import load_trace, registry
from repro.verify import Finding
from repro.verify.kernels import (
    KernelCapture,
    SpecCapture,
    check_capture,
    verify_kernels,
)
from repro.verify.lint import RULES, lint_source, lint_tree, rule_catalog
from repro.verify.plans import (
    check_block_plan,
    check_memory_itemsize,
    check_multi_ttm_plan,
    verify_plans,
)
from repro.verify.__main__ import main, run

VMEM = Memory.tpu_vmem(itemsize=4)


def _rules(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# plans
# ---------------------------------------------------------------------------

def test_eq9_infeasible_plan_is_flagged():
    """A block plan whose Eq-9 working set exceeds VMEM — while the
    all-ones plan fits — must be charged as infeasible-by-choice."""
    bad = BlockPlan(4096, (4096, 4096), 4096)
    assert not bad.fits(VMEM)
    fs = check_block_plan(bad, (8192, 8192, 8192), 4096, VMEM)
    assert "eq9-infeasible" in _rules(fs)


def test_eq9_not_charged_when_no_plan_fits():
    """A memory too small for even the all-ones plan is a property of
    the memory, not a planner bug: no finding."""
    tiny = Memory.abstract(2)
    plan = BlockPlan(1, (1, 1), 1)
    fs = check_block_plan(plan, (4, 4, 4), 2, tiny)
    assert "eq9-infeasible" not in _rules(fs)


def test_nonpositive_block_is_flagged():
    fs = check_block_plan(BlockPlan(0, (1, 1), 1), (4, 4, 4), 2, VMEM)
    assert _rules(fs) == {"nonpositive-block"}


def test_multi_ttm_infeasible_plan_is_flagged():
    bad = MultiTTMPlan(4096, (4096, 4096), (64, 64))
    assert not bad.fits(VMEM)
    fs = check_multi_ttm_plan(bad, (8192, 8192, 8192), (64, 64), VMEM)
    assert "eq9-infeasible" in _rules(fs)


def test_memory_itemsize_propagation_clean():
    assert check_memory_itemsize(VMEM) == []
    assert check_memory_itemsize(Memory.abstract(1000)) == []


def test_planner_sweep_is_clean():
    """Acceptance: choose_blocks / choose_sweep_blocks /
    choose_multi_ttm_blocks / best_uniform_block never emit a plan that
    fails any static check, across the whole default lattice."""
    assert verify_plans() == []


# ---------------------------------------------------------------------------
# kernels
# ---------------------------------------------------------------------------

def _out_capture(grid, block, operand, index_map):
    spec = SpecCapture(block, index_map, operand)
    return KernelCapture(
        grid=grid, out_specs=(spec,), out_dtypes=("float32",),
    )


def test_coverage_gap_is_flagged():
    # 4 output blocks, a 2-step grid: half the output is never written.
    cap = _out_capture((2,), (4,), (16,), lambda i: (i,))
    fs = check_capture(cap, kernel="fixture")
    assert "coverage-gap" in _rules(fs)


def test_oob_origin_is_flagged():
    cap = _out_capture((2,), (4,), (8,), lambda i: (i + 1,))
    fs = check_capture(cap, kernel="fixture")
    assert "oob-origin" in _rules(fs)


def test_torn_accumulation_run_is_flagged():
    # grid (2, 2), output indexed by the *inner* dim only: block (0,) is
    # visited at steps 0 and 2 — the revisit is non-consecutive, so the
    # block would be written back twice.
    cap = _out_capture((2, 2), (3,), (6,), lambda i, j: (j,))
    fs = check_capture(cap, kernel="fixture")
    assert "noncontiguous-revisit" in _rules(fs)


def test_block_divisibility_is_flagged():
    cap = _out_capture((2,), (3,), (8,), lambda i: (i,))
    fs = check_capture(cap, kernel="fixture")
    assert "block-divisibility" in _rules(fs)


def test_index_map_arity_mismatch_is_flagged():
    cap = _out_capture((2,), (4,), (8,), lambda i: (i, 0))
    fs = check_capture(cap, kernel="fixture")
    assert "index-map" in _rules(fs)


def test_footprint_mismatch_is_flagged():
    cap = _out_capture((2,), (4,), (8,), lambda i: (i,))
    fs = check_capture(cap, kernel="fixture", claimed_block_words=9999)
    assert "footprint-mismatch" in _rules(fs)


def test_acc_dtype_violation_is_flagged():
    spec = SpecCapture((4,), lambda i: (i,), (8,))
    cap = KernelCapture(
        grid=(2,), out_specs=(spec,), out_dtypes=("bfloat16",),
    )
    fs = check_capture(cap, kernel="fixture")
    assert "acc-dtype" in _rules(fs)


def test_shipped_kernels_verify_clean_without_executing():
    """Acceptance: every shipped Pallas kernel's BlockSpec footprint
    equals the planner's kernel_block_words claim, schedules cover the
    output with contiguous accumulation runs, accumulators are fp32 —
    and the analysis never dispatches a kernel."""
    before = registry().counter(PALLAS_DISPATCHES)
    findings, verdicts = verify_kernels()
    assert findings == []
    names = {v["name"] for v in verdicts}
    assert names == {
        "mttkrp3", "mttkrpn", "mttkrp_partial", "multi_ttm", "fused_pair",
    }
    for v in verdicts:
        assert v["agrees"], v
        assert v["findings"] == 0, v
        assert v["footprint_words"] == v["claimed_words"], v
        # the working set the planner quotes = BlockSpec tiles + scratch
        assert v["working_set_words"] >= v["claimed_words"], v
        # multi-block grids: the schedule checks actually exercise
        # accumulation runs, not single-block trivia
        assert len([g for g in v["grid"] if g > 1]) >= 2, v
    assert registry().counter(PALLAS_DISPATCHES) == before


# ---------------------------------------------------------------------------
# lint
# ---------------------------------------------------------------------------

def test_rv101_falsy_cache_fixture():
    # the PR-6 bug, verbatim shape: an *empty* PlanCache is falsy
    src = (
        "def save(cal, cache=None):\n"
        "    (cache or default_cache()).put_calibration(cal)\n"
    )
    fs = lint_source(src, "tune/fixture.py")
    assert _rules(fs) == {"RV101"}


def test_rv101_is_not_flagged_on_none_check():
    src = (
        "def save(cal, cache=None):\n"
        "    dest = default_cache() if cache is None else cache\n"
        "    dest.put_calibration(cal)\n"
    )
    assert lint_source(src, "tune/fixture.py") == []


def test_rv102_tracer_branch_fixture():
    src = (
        "import jax.numpy as jnp\n"
        "def f(x):\n"
        "    if jnp.sum(x) > 0:\n"
        "        return x\n"
        "    return -x\n"
    )
    fs = lint_source(src, "engine/fixture.py")
    assert _rules(fs) == {"RV102"}
    # dtype inspection is static under tracing: allowlisted
    safe = (
        "import jax.numpy as jnp\n"
        "def f(x):\n"
        "    if jnp.issubdtype(x.dtype, jnp.floating):\n"
        "        return x\n"
        "    return -x\n"
    )
    assert lint_source(safe, "engine/fixture.py") == []
    # outside the traced layers the same code is fine
    assert lint_source(src, "analysis/fixture.py") == []


def test_rv103_jax_in_pure_math_fixture():
    src = "import jax\n"
    fs = lint_source(src, "engine/plan.py")
    assert _rules(fs) == {"RV103"}
    assert lint_source(src, "engine/other.py") == []


def test_rv104_mutable_default_fixture():
    fs = lint_source("def f(x=[]):\n    return x\n", "core/fixture.py")
    assert _rules(fs) == {"RV104"}
    fs = lint_source(
        "def f(x=make()):\n    return x\n", "core/fixture.py"
    )
    assert _rules(fs) == {"RV104"}


def test_rv105_wallclock_fixture():
    src = "import time\ndef f():\n    return time.perf_counter()\n"
    fs = lint_source(src, "core/fixture.py")
    assert _rules(fs) == {"RV105"}
    # measurement layers and the dispatch layer's span timing are exempt
    assert lint_source(src, "tune/fixture.py") == []
    assert lint_source(src, "engine/execute.py") == []
    assert lint_source(src, "engine/sweep.py") == []


def test_rv106_shim_reintroduction_fixture():
    fs = lint_source(
        "def pallas_dispatch_count():\n    return 0\n",
        "engine/fixture.py",
    )
    assert _rules(fs) == {"RV106"}
    fs = lint_source(
        "from repro.engine.execute import pallas_dispatch_count\n",
        "analysis/fixture.py",
    )
    assert _rules(fs) == {"RV106"}


def test_waiver_comment_suppresses_finding():
    src = (
        "import time\n"
        "def f():\n"
        "    return time.perf_counter()  # verify: allow=RV105\n"
    )
    assert lint_source(src, "core/fixture.py") == []
    # allow=all works too
    src_all = src.replace("allow=RV105", "allow=all")
    assert lint_source(src_all, "core/fixture.py") == []


def test_unparsable_module_is_a_finding():
    fs = lint_source("def broken(:\n", "core/fixture.py")
    assert [f.rule for f in fs] == ["syntax"]


def test_rule_catalog_lists_every_rule():
    cat = rule_catalog()
    for r in RULES:
        assert r.code in cat and r.name in cat


def test_lint_tree_is_clean():
    """Acceptance: the shipped package has zero lint findings."""
    assert lint_tree() == []


# ---------------------------------------------------------------------------
# CLI + trace export
# ---------------------------------------------------------------------------

def test_finding_str_and_dict():
    f = Finding("lint", "RV101", "tune/x.py:3", "falsy or")
    assert str(f) == "[lint:RV101] tune/x.py:3: falsy or"
    assert f.to_dict()["rule"] == "RV101"


def test_cli_rules_exits_zero(capsys):
    assert main(["--rules"]) == 0
    out = capsys.readouterr().out
    assert "RV101" in out and "RV106" in out


def test_cli_unknown_analyzer_exits_two(capsys):
    assert main(["--only", "bogus"]) == 2


def test_cli_lint_clean_tree_exits_zero(capsys):
    assert main(["--only", "lint"]) == 0
    assert "0 finding(s)" in capsys.readouterr().out


def test_cli_findings_exit_one(monkeypatch, capsys):
    import repro.verify.lint as lint_mod

    monkeypatch.setattr(
        lint_mod, "lint_tree",
        lambda: [Finding("lint", "RV999", "x.py:1", "seeded")],
    )
    assert main(["--only", "lint"]) == 1
    assert "[lint:RV999]" in capsys.readouterr().out


def test_trace_export_schema(tmp_path):
    """--trace-out writes kind=static_verify events in the observe span
    schema, one per kernel verdict plus a summary row the report CLI
    can table (static_verify is in its DISPATCH_KINDS)."""
    from repro.observe.report import DISPATCH_KINDS, render_rows

    p = tmp_path / "verify.jsonl"
    findings, verdicts = run(("kernels",), trace_out=str(p))
    assert findings == []
    events = load_trace(str(p))
    sv = [e for e in events if e["kind"] == "static_verify"]
    assert len(sv) == len(verdicts) + 1  # one per kernel + summary
    summary = sv[-1]
    assert summary["name"] == "summary"
    assert summary["kernels_checked"] == len(verdicts)
    assert summary["kernels_agreeing"] == len(verdicts)
    assert summary["findings"] == 0
    assert "static_verify" in DISPATCH_KINDS
    rows, flagged = render_rows(events)
    assert len(rows) == len(sv) and flagged == 0


def test_default_run_matches_cli_contract():
    """run() over all analyzers returns the same clean verdict the CI
    gate requires (python -m repro.verify exits 0 on this tree)."""
    findings, verdicts = run()
    assert findings == []
    assert len(verdicts) == 5
