"""Grid selection (distributed/grid_select.py) against brute force, and the
eager mesh/grid validation — all single-device (pure integer programs)."""

import math

import pytest

from repro.core.bounds import par_general_cost, par_stationary_cost
from repro.core.grid import optimal_grid
from repro.distributed.grid_select import (
    brute_force_general,
    brute_force_stationary,
    choose_cp_grid,
    select_general_grid,
    select_grid,
    select_stationary_grid,
    shardable,
    stationary_sweep_words,
)
from repro.distributed.mesh import make_grid_mesh, validate_grid

CASES_3WAY = [
    ((64, 64, 64), 16),
    ((256, 1024, 64), 8),
    ((48, 96, 32), 256),   # NR large: rank axis pays off for Alg 4
    ((128, 16, 16), 4),
]
CASES_4WAY = [
    ((32, 32, 32, 32), 8),
    ((64, 16, 48, 8), 96),
]
P_SWEEP = (2, 4, 6, 8, 12, 16, 24, 32, 48, 64)


@pytest.mark.parametrize("dims,rank", CASES_3WAY + CASES_4WAY)
def test_stationary_select_matches_brute_force(dims, rank):
    """The pruned Eq (12) search returns exactly the brute-force optimum,
    for single-mode and sweep objectives, P <= 64."""
    for procs in P_SWEEP:
        for mode in (0, len(dims) - 1, None):
            sel = select_stationary_grid(dims, rank, procs, mode)
            ref = brute_force_stationary(dims, rank, procs, mode)
            assert (sel is None) == (ref is None)
            if sel is None:
                continue
            assert sel.grid == ref.grid, (procs, mode)
            assert sel.words == ref.words
            assert math.prod(sel.grid) == procs


@pytest.mark.parametrize("dims,rank", CASES_3WAY + CASES_4WAY)
def test_general_select_matches_brute_force(dims, rank):
    """The pruned Eq (16) search over (P_0, grid) == brute force, P <= 64."""
    for procs in P_SWEEP:
        sel = select_general_grid(dims, rank, procs)
        ref = brute_force_general(dims, rank, procs)
        assert (sel is None) == (ref is None)
        if sel is None:
            continue
        assert (sel.p0, sel.grid) == (ref.p0, ref.grid), procs
        assert sel.words == ref.words
        assert sel.p0 * math.prod(sel.grid) == procs


def test_selected_costs_are_the_eq12_eq16_formulas():
    dims, rank, procs = (64, 64, 64), 16, 32
    s = select_stationary_grid(dims, rank, procs, mode=1)
    assert s.words == par_stationary_cost(dims, rank, s.grid, 1)
    g = select_general_grid(dims, rank, procs)
    assert g.words == par_general_cost(dims, rank, g.grid, g.p0, 0)
    sw = select_stationary_grid(dims, rank, procs, mode=None)
    assert sw.words == stationary_sweep_words(dims, rank, sw.grid)


def test_general_never_worse_and_consistent_with_core_optimal_grid():
    """Alg 4 with a free P_0 dominates Alg 3 (P_0=1 is in its search
    space), and the exhaustive search agrees with core.grid.optimal_grid's
    Eq (16) optimum wherever both are defined."""
    for dims, rank in CASES_3WAY:
        for procs in (4, 8, 16, 64):
            s = select_stationary_grid(dims, rank, procs, mode=0)
            g = select_general_grid(dims, rank, procs)
            assert g.words <= s.words + 1e-9
            p0, grid = optimal_grid(dims, rank, procs)
            assert g.words == pytest.approx(
                par_general_cost(dims, rank, grid, p0, 0), rel=0, abs=0
            )


def test_select_grid_auto_picks_cheaper():
    dims, procs = (64, 64, 64), 64
    # small NR: stationary regime
    auto = select_grid(dims, 4, procs, algorithm="auto", mode=0)
    stat = select_grid(dims, 4, procs, algorithm="stationary", mode=0)
    gen = select_grid(dims, 4, procs, algorithm="general", mode=0)
    assert auto.words == min(stat.words, gen.words)
    # large NR: the rank axis must win
    auto = select_grid(dims, 4096, procs, algorithm="auto", mode=0)
    assert auto.algorithm == "general" and auto.p0 > 1
    with pytest.raises(ValueError, match="stationary-only"):
        select_grid(dims, 4, procs, algorithm="general", mode=None)
    with pytest.raises(ValueError, match="unknown algorithm"):
        select_grid(dims, 4, procs, algorithm="nope")


def test_sweep_objective_beats_per_mode_sum_choice():
    """The sweep objective is the symmetric all-mode cost: the chosen grid
    minimizes sum-over-modes Eq (12), not any single mode's."""
    dims, rank = (256, 16, 16), 8
    sw = select_stationary_grid(dims, rank, 16, mode=None)
    total = lambda g: sum(  # noqa: E731
        par_stationary_cost(dims, rank, g, m) for m in range(3)
    )
    for other_mode in range(3):
        om = select_stationary_grid(dims, rank, 16, mode=other_mode)
        assert total(sw.grid) <= total(om.grid) + 1e-9


def test_shardable_and_choose_cp_grid():
    assert shardable((32, 32, 32), 4, (2, 2, 2))
    assert not shardable((32, 32, 30), 4, (2, 2, 2))  # 8 does not divide 30
    assert not shardable((32, 32, 32), 3, (2, 2, 1), p0=2)  # 2 !| R=3
    c = choose_cp_grid((32, 32, 32), 4, 8)
    assert c.grid == (2, 2, 2) and c.objective == "sweep"
    # no 8-processor grid shards (6,6,6) evenly -> falls back to 6 procs
    c = choose_cp_grid((6, 6, 6), 4, 8)
    assert c.procs == 6
    assert shardable((6, 6, 6), 4, c.grid)
    assert choose_cp_grid((5, 3, 2), 4, 1).grid == (1, 1, 1)


def test_validate_grid_errors():
    with pytest.raises(ValueError, match="does not divide tensor extent"):
        validate_grid((2, 2, 2), dims=(15, 16, 16))
    with pytest.raises(ValueError, match="uneven factor shards"):
        validate_grid((2, 2, 1), dims=(16, 16, 2))
    with pytest.raises(ValueError, match="3-way but the tensor is 2-way"):
        validate_grid((2, 2, 1), dims=(16, 16))
    with pytest.raises(ValueError, match="does not divide R"):
        validate_grid((1, 1, 1), p0=2, dims=(16, 16, 16), rank=3)
    # rank check must not require dims (regression: it was nested under it)
    with pytest.raises(ValueError, match="does not divide R"):
        validate_grid((2, 2, 1), p0=2, rank=3, check_devices=False)
    with pytest.raises(ValueError, match="positive ints"):
        validate_grid((2, 0, 1))
    with pytest.raises(ValueError, match="p0 must be"):
        validate_grid((2, 2), p0=0)


def test_make_grid_mesh_rejects_oversized_grid():
    """Eager device-count check (the main pytest session sees 1 device)."""
    with pytest.raises(ValueError, match="devices"):
        make_grid_mesh((2, 2), dims=(4, 4))


def test_make_grid_mesh_single_device_ok():
    mesh = make_grid_mesh((1, 1, 1), dims=(8, 8, 8), rank=4)
    assert mesh.axis_names == ("m0", "m1", "m2")
