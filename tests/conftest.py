"""Shared pytest fixtures.

NOTE: deliberately does NOT set --xla_force_host_platform_device_count —
smoke tests and benches must see 1 device. Multi-device distributed tests
spawn subprocesses (see tests/dist/).
"""

import os
import sys
import warnings

import numpy as np
import pytest

try:  # real hypothesis when available; deterministic shim otherwise
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    from _hypothesis_stub import install as _install_hypothesis_stub

    _install_hypothesis_stub()

warnings.filterwarnings(
    "ignore", message=".*dtype float64 requested.*", category=UserWarning
)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: heavy kernel/tune/distributed suites — PRs run the fast "
        "subset (-m 'not slow'); pushes to main and the nightly schedule "
        "run everything",
    )


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(1234)
