"""Shared pytest fixtures.

NOTE: deliberately does NOT set --xla_force_host_platform_device_count —
smoke tests and benches must see 1 device. Multi-device distributed tests
spawn subprocesses (see tests/dist/).
"""

import warnings

import numpy as np
import pytest

warnings.filterwarnings(
    "ignore", message=".*dtype float64 requested.*", category=UserWarning
)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(1234)
