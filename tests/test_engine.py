"""The unified MTTKRP execution engine: planner single-sourcing, Eq-10
regression, backend dispatch, kernel-backed dimension trees, and the exact
dimension-tree cost model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bounds
from repro.core.cp_als import cp_als
from repro.core.dimension_tree import (
    dimtree_flops,
    dimtree_intermediate_words,
    naive_all_mode_flops,
)
from repro.core.mttkrp import mttkrp as einsum_mttkrp
from repro.core.mttkrp import mttkrp_naive
from repro.engine import (
    BlockPlan,
    Memory,
    all_mode_mttkrp,
    best_uniform_block,
    choose_blocks,
    dimtree_als_sweep,
    mttkrp,
)
from repro.engine.plan import uniform_plan
from repro.kernels.ref import mttkrp_ref
from repro.observe.metrics import PALLAS_DISPATCHES, registry


def _dispatches() -> int:
    return registry().counter(PALLAS_DISPATCHES)


def _mk(dims, rank, seed=0, dtype=jnp.float32):
    key = jax.random.PRNGKey(seed)
    kx, *kf = jax.random.split(key, len(dims) + 1)
    x = jax.random.normal(kx, dims, dtype)
    fs = [jax.random.normal(k, (d, rank), dtype) for k, d in zip(kf, dims)]
    return x, fs


# --------------------------------------------------------------------------
# planner: single source of truth + Eq-10 regression
# --------------------------------------------------------------------------

def test_planner_is_single_sourced():
    """kernels.ops and repro.kernels re-export the engine planner objects —
    the logic exists in exactly one module."""
    from repro.engine import plan as engine_plan
    from repro.kernels import ops as kernel_ops

    assert kernel_ops.BlockPlan is engine_plan.BlockPlan
    assert kernel_ops.choose_blocks is engine_plan.choose_blocks
    assert kernel_ops.mttkrp_traffic_model is engine_plan.mttkrp_traffic_model


@pytest.mark.parametrize(
    "dims,rank,mem",
    [((24, 24, 24), 16, 512), ((16, 32, 64), 8, 1024), ((12, 12, 12, 12), 6, 4096)],
)
def test_eq10_regression_pins_bounds_formula(dims, rank, mem):
    """Satellite fix: a uniform-b plan's eq10 traffic must equal
    core.bounds.seq_blocked_cost exactly (the old model multiplied the
    block-count product by max(block) instead of summing per-mode factor
    traffic R*(N+1)*b)."""
    b = best_uniform_block(dims, Memory.abstract(mem))
    plan = BlockPlan(b, (b,) * (len(dims) - 1), rank)
    assert plan.eq10_words(dims, rank) == int(
        bounds.seq_blocked_cost(dims, rank, b)
    )
    # and the dict spelling agrees, in bytes
    m = plan.traffic_model(dims, rank, itemsize=4)
    assert m["eq10_bytes"] == plan.eq10_words(dims, rank) * 4
    # uniform_plan asserts the same identity internally
    uniform_plan(dims, rank, Memory.abstract(mem))


def test_eq10_heterogeneous_blocks_formula():
    """For per-mode blocks the generalized Eq-10 is I + prod ceil(I_k/b_k)
    * R * (sum_k b_k + b_out): factor loads per rank column plus output
    load+store."""
    dims, rank = (64, 32, 48), 4
    plan = BlockPlan(16, (8, 24), rank)
    nblocks = 4 * 4 * 2
    expect = 64 * 32 * 48 + nblocks * rank * ((16 + 8 + 24) + 16)
    assert plan.eq10_words(dims, rank) == expect


def test_memory_descriptor_drives_planning():
    """choose_blocks against a small explicit Memory must shrink blocks and
    still satisfy the Eq-9 working-set check for that memory."""
    small = Memory.tpu_vmem(budget_bytes=1024 * 1024)
    big = Memory.tpu_vmem()
    p_small = choose_blocks((512, 512, 512), 256, memory=small)
    p_big = choose_blocks((512, 512, 512), 256, memory=big)
    assert p_small.fits(small)
    assert p_small.working_set_words() < p_big.working_set_words()


def test_rank_augmented_working_set():
    """x_has_rank plans charge the tensor tile at bi*prod(bc)*br words."""
    plain = BlockPlan(8, (8,), 128)
    aug = BlockPlan(8, (8,), 128, x_has_rank=True)
    assert aug.working_set_words() - plain.working_set_words() == 8 * 8 * 127


# --------------------------------------------------------------------------
# executor: backends agree
# --------------------------------------------------------------------------

@pytest.mark.parametrize("dims", [(8, 7, 9), (6, 5, 4, 3)])
@pytest.mark.parametrize("backend", ["einsum", "blocked_host", "pallas"])
def test_backends_agree(dims, backend):
    x, fs = _mk(dims, 4, seed=1)
    for mode in range(len(dims)):
        out = mttkrp(x, fs, mode, backend=backend, interpret=True)
        np.testing.assert_allclose(
            out, mttkrp_ref(x, fs, mode), rtol=3e-4, atol=3e-4
        )


def test_unknown_backend_rejected():
    x, fs = _mk((4, 4, 4), 2)
    with pytest.raises(ValueError):
        mttkrp(x, fs, 0, backend="cuda")


# --------------------------------------------------------------------------
# kernels: 4-way / 5-way + padding (satellite coverage)
# --------------------------------------------------------------------------

@pytest.mark.parametrize(
    "dims", [(8, 8, 8, 8), (7, 5, 9, 3), (4, 4, 4, 4, 4), (5, 3, 4, 2, 3)]
)
def test_mttkrpn_4way_5way_vs_naive(dims):
    """4-/5-way kernel (interpret mode) vs the atomic-multiply oracle,
    including non-divisible shapes that exercise the padding path."""
    x, fs = _mk(dims, 5, seed=2)
    for mode in range(len(dims)):
        out = mttkrp(x, fs, mode, backend="pallas", interpret=True)
        ref = mttkrp_naive(x, fs, mode)
        np.testing.assert_allclose(out, ref, rtol=5e-4, atol=5e-4)


def test_explicit_plan_padding_path():
    """Blocks larger than (and non-divisible into) the dims force padding
    everywhere; zero padding must not pollute real outputs."""
    dims = (10, 6, 11, 3)
    x, fs = _mk(dims, 7, seed=3)
    plan = BlockPlan(8, (8, 128, 8), 128)
    out = mttkrp(x, fs, 2, backend="pallas", plan=plan, interpret=True)
    np.testing.assert_allclose(
        out, mttkrp_ref(x, fs, 2), rtol=5e-4, atol=5e-4
    )


# --------------------------------------------------------------------------
# kernel-backed dimension tree
# --------------------------------------------------------------------------

@pytest.mark.parametrize("dims", [(8, 7, 9), (6, 5, 4, 3), (4, 5, 3, 4, 3)])
def test_dimtree_pallas_all_modes(dims):
    x, fs = _mk(dims, 4, seed=4)
    before = _dispatches()
    outs = all_mode_mttkrp(x, fs, method="dimtree", backend="pallas",
                           interpret=True)
    # every tree edge must have gone through the kernels
    assert _dispatches() - before >= 2 * (len(dims) - 1)
    for mode in range(len(dims)):
        np.testing.assert_allclose(
            outs[mode], mttkrp_ref(x, fs, mode), rtol=5e-4, atol=5e-4
        )


def test_dimtree_pallas_sweep_gauss_seidel_order():
    """The kernel-backed sweep must deliver each mode's MTTKRP computed
    with modes < n already updated (plain-ALS Gauss-Seidel order)."""
    dims = (5, 4, 6, 3)
    x, fs = _mk(dims, 3, seed=5)
    seen = {}

    def update(mode, b):
        seen[mode] = b
        return fs[mode] * 1.1

    fs_tree = [f + 0 for f in fs]
    dimtree_als_sweep(x, fs_tree, update, backend="pallas", interpret=True)
    cur = [f + 0 for f in fs]
    for mode in range(len(dims)):
        expected = einsum_mttkrp(x, cur, mode)
        np.testing.assert_allclose(seen[mode], expected, rtol=2e-3, atol=2e-3)
        cur[mode] = cur[mode] * 1.1


def test_cp_als_dimtree_pallas_matches_plain():
    """Acceptance: dimtree ALS through the Pallas backend matches plain ALS
    to fp32 tolerance, and the pallas path is actually taken."""
    x, fs = _mk((8, 7, 6, 5), 2, seed=6)
    x = x / jnp.linalg.norm(x.reshape(-1))
    plain = cp_als(x, 2, n_iters=6, init_factors=fs)
    before = _dispatches()
    tree = cp_als(
        x, 2, n_iters=6, init_factors=fs, use_dimension_tree=True,
        backend="pallas", interpret=True,
    )
    assert _dispatches() > before  # kernel path taken
    for a, b in zip(plain.fits, tree.fits):
        assert abs(a - b) < 5e-3
    for fa, fb in zip(plain.factors, tree.factors):
        np.testing.assert_allclose(fa, fb, rtol=5e-3, atol=5e-3)


# --------------------------------------------------------------------------
# exact dimension-tree cost model (satellite fix)
# --------------------------------------------------------------------------

def test_dimtree_flops_exact_small_case():
    """Hand-computed N=3 cubical case: root (d,d,d) -> left child drops 2
    modes (cost d^3*R + d^2*R), right child drops 1 (d^3*R); the right
    child (d,d,R) then drops one mode twice (d^2*R each)."""
    d, r = 8, 4
    expect = (d**3 * r + d**2 * r) + d**3 * r + 2 * (d**2 * r)
    assert dimtree_flops((d, d, d), r) == expect


def test_dimtree_flops_drop_order_optimal():
    """Non-cubical dims: the model must drop the largest mode first (what
    einsum's 'optimal' path does), not average geometrically."""
    dims, r = (4, 100, 2), 3
    # root -> left: drop modes {100, 2}: largest first: 800R + 8R
    # root -> right: drop {4}: 800R ; right child (100, 2, R):
    #   drop {2}: 200R -> leaf (100, R); drop {100}: 200R -> leaf (2, R)
    expect = (800 + 8) * r + 800 * r + 200 * r + 200 * r
    assert dimtree_flops(dims, r) == expect


def test_dimtree_flops_beats_naive_and_is_exactish():
    for dims, rank in [((32, 32, 32), 8), ((16, 16, 16, 16), 4)]:
        tree = dimtree_flops(dims, rank)
        naive = naive_all_mode_flops(dims, rank)
        assert tree < naive
        # reuse ratio must be >= ~2 for these shapes
        assert naive / tree > 2.0


def test_dimtree_intermediate_words_counts_rank_axis():
    """Rank-augmented nodes hold prod(dims)*R words (the quantity the old
    geometric-mean model under-counted)."""
    d, r = 8, 4
    # root d^3 + two children d^2*R wait: N=3 children: left (d,) leaf? tree:
    # root (d,d,d): 1*d^3; left child (d,)*R; right child (d,d)*R; right's
    # leaves (d,)*R and (d,)*R
    expect = d**3 + d * r + d * d * r + d * r + d * r
    assert dimtree_intermediate_words((d, d, d), r) == expect


# --------------------------------------------------------------------------
# simulator + engine planner agree
# --------------------------------------------------------------------------

def test_simulator_uses_engine_block_selection(rng):
    from repro.core.simulator import simulate_blocked

    x = rng.standard_normal((6, 5, 4))
    fs = [rng.standard_normal((d, 3)) for d in x.shape]
    mem = 64
    b_engine = best_uniform_block(x.shape, Memory.abstract(mem))
    res = simulate_blocked(x, fs, 0, mem)
    assert res.words <= bounds.seq_blocked_cost(x.shape, 3, b_engine) + 1
