"""Distributed MTTKRP integration tests.

jax pins the device count at first init, so multi-device (8 host CPU
devices) checks run in one subprocess (tests/dist_worker.py); this module
asserts on its transcript. Single-device-checkable pieces (HLO parser,
compression math) run inline.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compression import (
    compression_ratio,
    cp_compressed_mean,
    init_compression_state,
    compressed_gradient,
    pick_3way_shape,
)
from repro.distributed.hlo import parse_collectives

_WORKER = os.path.join(os.path.dirname(__file__), "dist_worker.py")


@pytest.fixture(scope="module")
def dist_transcript():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, _WORKER],
        capture_output=True,
        text=True,
        timeout=1200,
        env=env,
    )
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    return proc.stdout


@pytest.mark.slow  # 8-device subprocess: compiles every shard_map program
@pytest.mark.parametrize(
    "name",
    [
        "alg3_numerics",
        "alg3_asymmetric_grid",
        "alg4_numerics",
        "alg4_4way",
        "comm_matches_eq12",
        "comm_matches_eq16",
        "stationary_tensor_never_moves",
        "cp_compressed_mean",
        "collective_only_factor_sized",
        "alg_pallas_local",
        "cp_sweep_matches_sequential",
        "cp_sweep_comm_beats_independent",
        "ring_overlap_sweep",
        "cp_auto_grid_driver",
        "cp_sweep_pallas_local",
        "context_roundtrip_reproduces_sweep",
        "multi_ttm_comm_matches_model",
        "tucker_sweep_comm_matches_model",
        "tucker_parallel_matches_sequential",
        "tucker_sweep_pallas_local",
    ],
)
def test_distributed_check(dist_transcript, name):
    assert f"PASS {name}" in dist_transcript


@pytest.mark.slow
def test_dist_worker_completed(dist_transcript):
    assert "ALL_DIST_OK" in dist_transcript


# ---------------------------------------------------------------------------
# Inline (single-device) pieces
# ---------------------------------------------------------------------------

def test_hlo_parser_brace_and_iota_groups():
    text = """
  %ag.1 = f32[64,8]{1,0} all-gather(%p.1), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  %p.1 = f32[16,8]{1,0} parameter(0)
"""
    # instruction order independent: parser resolves via the table it builds
    text = """
  %p.1 = f32[16,8]{1,0} parameter(0)
  %ag.1 = f32[64,8]{1,0} all-gather(%p.1), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  %ar.1 = f32[16,8]{1,0} all-reduce(%p.1), replica_groups=[2,4]<=[8], to_apply=%add
"""
    summ = parse_collectives(text)
    kinds = summ.by_kind()
    assert kinds["all-gather"]["count"] == 1
    assert kinds["all-reduce"]["count"] == 1
    ag = [o for o in summ.ops if o.kind == "all-gather"][0]
    assert ag.operand_bytes == 16 * 8 * 4
    assert ag.group_size == 4
    assert ag.ring_bytes == 3 * 16 * 8 * 4
    ar = [o for o in summ.ops if o.kind == "all-reduce"][0]
    assert ar.group_size == 4


def test_hlo_parser_ignores_done_ops():
    text = """
  %p = bf16[32]{0} parameter(0)
  %ags = bf16[128]{0} all-gather-start(%p), replica_groups={{0,1,2,3}}, dimensions={0}
  %agd = bf16[128]{0} all-gather-done(%ags)
"""
    summ = parse_collectives(text)
    assert len(summ.ops) == 1
    assert summ.ops[0].operand_bytes == 32 * 2


def test_pick_3way_shape():
    assert pick_3way_shape((128,)) == (128, 1, 1)
    assert pick_3way_shape((64, 32)) == (64, 32, 1)
    assert pick_3way_shape((8, 64, 32)) == (8, 64, 32)
    assert pick_3way_shape((8, 64, 32, 2)) == (8, 64, 64)


def test_compression_ratio_large():
    # the headline case: FFN weight gradient at rank 8, 1 sweep
    # words: 4096*14336 / ((4096+14336+1)*8) ≈ 398x
    assert compression_ratio((4096, 14336), 8, 1) > 350


def test_cp_compressed_mean_single_worker_equals_als():
    """With a single worker (no pmean partners) the compressor is plain
    CP-ALS — it must fit an exactly-low-rank 'gradient' essentially
    perfectly."""
    from repro.core.tensor import random_low_rank_tensor

    g, _ = random_low_rank_tensor(jax.random.PRNGKey(0), (16, 12, 4), 3)
    recon, factors = cp_compressed_mean(
        g, (), rank=3, sweeps=30, key=jax.random.PRNGKey(1)
    )
    err = float(
        jnp.linalg.norm(recon - g) / jnp.linalg.norm(g)
    )
    assert err < 0.05, err


def test_error_feedback_reduces_bias():
    """With error feedback, the accumulated compressed signal tracks the
    accumulated true gradient better than without."""
    key = jax.random.PRNGKey(2)
    shape = (24, 16)
    state = init_compression_state(key, shape, rank=2)
    true_sum = jnp.zeros(shape)
    fed_sum = jnp.zeros(shape)
    for step in range(12):
        g = jax.random.normal(jax.random.fold_in(key, step), shape)
        true_sum = true_sum + g
        approx, state = compressed_gradient(g, state, ())
        fed_sum = fed_sum + approx
    # residual carries whatever hasn't been transmitted yet:
    # fed_sum + residual == true_sum (exactness of error feedback)
    resid = state.residual.reshape(shape)
    np.testing.assert_allclose(
        np.asarray(fed_sum + resid), np.asarray(true_sum), rtol=1e-3,
        atol=1e-3,
    )
