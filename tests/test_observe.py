"""Observability layer: span schema round-trips, registry counters,
bounds-audit triples, the zero-overhead observe=False contract, and the
report CLI (ISSUE PR 7 acceptance)."""

import json

import jax
import jax.numpy as jnp
import pytest

import repro
from repro import ExecutionContext, Memory
from repro.observe import (
    SPAN_SCHEMA,
    Trace,
    audit_mttkrp,
    audit_multi_ttm,
    current_trace,
    load_trace,
    registry,
    summarize_events,
)
from repro.observe.metrics import (
    PALLAS_DISPATCHES,
    TUNE_CACHE_HITS,
    TUNE_CACHE_MISSES,
    MetricsRegistry,
)
from repro.observe.trace import BASE_FIELDS, should_record

DIMS, RANK = (8, 6, 5), 3  # the pinned 3-way problem


def _problem(dims=DIMS, rank=RANK, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), dims)
    fs = [
        jax.random.normal(jax.random.PRNGKey(seed + k + 1), (d, rank))
        for k, d in enumerate(dims)
    ]
    return x, fs


# ---------------------------------------------------------------------------
# Trace: recording, ring buffer, schema round-trip
# ---------------------------------------------------------------------------

def test_nothing_recorded_without_active_trace():
    x, fs = _problem()
    assert current_trace() is None
    # engine calls outside a Trace must not record anywhere
    repro.mttkrp(x, fs, 0, ctx=ExecutionContext.create(observe=True))
    assert current_trace() is None


def test_span_schema_and_jsonl_roundtrip(tmp_path):
    x, fs = _problem()
    ctx = ExecutionContext.create(observe=True)
    p = tmp_path / "trace.jsonl"
    with Trace(path=str(p)) as tr:
        repro.mttkrp(x, fs, 1, ctx=ctx)
        assert current_trace() is tr
    events = tr.events
    assert len(events) == 1
    e = events[0]
    for field in BASE_FIELDS:
        assert field in e
    assert e["schema"] == SPAN_SCHEMA
    assert e["kind"] == "mttkrp"
    assert e["shape"] == list(DIMS) and e["rank"] == RANK and e["mode"] == 1
    assert e["backend"] in ("einsum", "blocked_host", "pallas")
    assert e["modeled_words"] > 0
    assert e["lower_bound_words"] >= 0
    assert e["wall_time_us"] > 0
    assert "compute_dtype" in e and "out_dtype" in e
    # the JSONL round-trip is exact (events are pure JSON)
    back = load_trace(str(p))
    assert back == events


def test_trace_ring_buffer_evicts_and_counts():
    before = registry().counter("trace.events_dropped")
    with Trace(capacity=2) as tr:
        for i in range(5):
            tr.record("synthetic", i=i)
    assert len(tr) == 2
    assert [e["i"] for e in tr.events] == [3, 4]  # oldest evicted
    assert registry().counter("trace.events_dropped") == before + 3


def test_trace_validates_arguments():
    with pytest.raises(ValueError, match="capture"):
        Trace(capture="everything")
    with pytest.raises(ValueError, match="capacity"):
        Trace(capacity=0)


# ---------------------------------------------------------------------------
# Capture gating: observe=False / capture="observed" emit nothing
# ---------------------------------------------------------------------------

def test_capture_observed_requires_ctx_opt_in():
    x, fs = _problem()
    with Trace(capture="observed") as tr:
        repro.mttkrp(x, fs, 0, ctx=ExecutionContext.create(observe=False))
        assert len(tr) == 0  # not opted in: nothing recorded
        repro.mttkrp(x, fs, 0, ctx=ExecutionContext.create(observe=True))
        assert len(tr) == 1


def test_should_record_rejects_tracers():
    x, _ = _problem()

    recorded = []

    def probe(xx):
        recorded.append(should_record(True, xx))
        return xx * 2

    with Trace():
        jax.jit(probe)(x)  # traced: operands are tracers
        probe(x)           # eager: concrete
    assert recorded == [False, True]


def test_observe_flag_does_not_change_hlo():
    """The zero-overhead contract: compiled HLO is byte-identical with
    observe on or off (recording is driver-side only)."""
    x, fs = _problem()

    def lower_text(observe):
        ctx = ExecutionContext.create(observe=observe)

        def call(xx, *ffs):
            return repro.mttkrp(xx, list(ffs), 0, ctx=ctx)

        return jax.jit(call).lower(x, *fs).as_text()

    with Trace() as tr:
        on = lower_text(True)
        off = lower_text(False)
        assert len(tr) == 0  # nothing recorded while tracing either
    assert on == off


# ---------------------------------------------------------------------------
# ExecutionContext.observe: JSON round-trip, old artifacts load
# ---------------------------------------------------------------------------

def test_observe_field_roundtrips_and_defaults_off():
    ctx = ExecutionContext.create(observe=True)
    assert ctx.observe is True
    back = ExecutionContext.from_json(ctx.to_json())
    assert back == ctx and back.observe is True
    # pre-observability JSON (no "observe" key) still loads
    d = json.loads(ExecutionContext.create().to_json())
    d.pop("observe")
    assert ExecutionContext.from_dict(d).observe is False


# ---------------------------------------------------------------------------
# MetricsRegistry: counters match known dispatch counts per backend
# ---------------------------------------------------------------------------

def test_registry_counts_dispatches_per_backend():
    """One mttkrp per mode on the pinned problem: the pallas backend
    increments the dispatch counter once per call, the host backends not
    at all — measured with snapshots, never resets."""
    x, fs = _problem()
    for backend, per_call in (
        ("einsum", 0), ("blocked_host", 0), ("pallas", 1),
    ):
        ctx = ExecutionContext.create(backend=backend, interpret=True)
        before = registry().snapshot()
        for mode in range(len(DIMS)):
            repro.mttkrp(x, fs, mode, ctx=ctx)
        delta = registry().delta(before)
        expected = per_call * len(DIMS)
        assert delta.get(PALLAS_DISPATCHES, 0) == expected, (backend, delta)


def test_snapshots_do_not_interfere():
    """The reset footgun is gone: two interleaved measurements each see
    only their own increments."""
    reg = MetricsRegistry()
    snap_a = reg.snapshot()
    reg.inc("k")
    snap_b = reg.snapshot()
    reg.inc("k")
    assert reg.delta(snap_a) == {"k": 2}
    assert reg.delta(snap_b) == {"k": 1}
    assert snap_a.get("k", 0) == 0  # snapshots are immutable views


def test_registry_histograms_and_to_dict():
    reg = MetricsRegistry()
    reg.inc("c", 2)
    reg.set_gauge("g", 7.5)
    reg.observe("h", 1.0)
    reg.observe("h", 3.0)
    assert reg.histogram("h") == (1.0, 3.0)
    d = reg.to_dict()
    assert d["counters"] == {"c": 2}
    assert d["gauges"] == {"g": 7.5}
    assert d["histograms"]["h"] == {
        "count": 2, "sum": 4.0, "min": 1.0, "max": 3.0,
    }


def test_tune_cache_counters():
    from repro.tune.cache import isolated_cache
    from repro.tune.search import resolve

    with isolated_cache():
        before = registry().snapshot()
        resolve(DIMS, RANK, 0, jnp.float32)
        delta = registry().delta(before)
        assert delta.get(TUNE_CACHE_MISSES, 0) == 1
        assert TUNE_CACHE_HITS not in delta


def test_pallas_dispatch_count_shim_removed():
    """The deprecated shim is gone: the registry counter is the only
    spelling (repro.verify rule RV106 keeps it that way)."""
    import repro.engine
    import repro.engine.execute as execute

    assert not hasattr(execute, "pallas_dispatch_count")
    assert "pallas_dispatch_count" not in repro.engine.__all__
    with pytest.raises(ImportError):
        from repro.engine.execute import pallas_dispatch_count  # noqa: F401


# ---------------------------------------------------------------------------
# Acceptance: cp_als under observe=True — per-dispatch triples
# ---------------------------------------------------------------------------

def test_cp_als_trace_triples_match_plan_model(tmp_path):
    """The PR's acceptance check: one cp_als run on the pinned problem
    produces a JSONL trace whose every dispatch triple satisfies
    lower bound <= modeled Eq-10 words, with modeled_words exactly the
    BlockPlan's eq10_words for that dispatch."""
    from repro.core.bounds import seq_lb_memory
    from repro.engine.execute import _mode_first
    from repro.engine.plan import choose_blocks

    x, fs = _problem()
    ctx = ExecutionContext.create(observe=True)
    p = tmp_path / "cp_als.jsonl"
    with Trace(path=str(p)):
        repro.cp_als(x, RANK, n_iters=2, init_factors=fs, ctx=ctx)
    events = load_trace(str(p))
    dispatches = [e for e in events if e["kind"] == "mttkrp"]
    iters = [e for e in events if e["kind"] == "cp_als_iter"]
    assert len(dispatches) == 2 * len(DIMS)  # one per mode per sweep
    assert len(iters) == 2
    mem = Memory.tpu_vmem(itemsize=4)
    for e in dispatches:
        assert e["lower_bound_words"] <= e["modeled_words"]
        plan = choose_blocks(
            _mode_first(DIMS, e["mode"]), RANK, 4, memory=mem
        )
        assert e["modeled_words"] == int(plan.eq10_words(
            _mode_first(DIMS, e["mode"]), RANK
        ))
        assert e["lower_bound_words"] == max(
            seq_lb_memory(DIMS, RANK, mem.budget_words), 0.0
        )
    for k, e in enumerate(iters):
        assert e["it"] == k and 0.0 <= e["fit"] <= 1.0
        assert len(e["weights"]) == RANK
    assert iters[0]["fit_delta"] is None
    assert iters[1]["fit_delta"] is not None


def test_tucker_trace_events(tmp_path):
    x, _ = _problem()
    ctx = ExecutionContext.create(observe=True)
    with Trace() as tr:
        repro.tucker_hooi(x, (2, 2, 2), n_iters=1, ctx=ctx)
    kinds = [e["kind"] for e in tr.events]
    assert kinds.count("multi_ttm") == len(DIMS)
    assert kinds.count("tucker_iter") == 1
    mt = next(e for e in tr.events if e["kind"] == "multi_ttm")
    assert mt["lower_bound_words"] <= mt["modeled_words"]


# ---------------------------------------------------------------------------
# Bounds audit
# ---------------------------------------------------------------------------

def test_audit_mttkrp_triple_on_cpu():
    x, fs = _problem()
    with Trace() as tr:
        row = audit_mttkrp(x, fs, 0)
    assert row.measured_bytes >= row.lower_bound_bytes
    assert row.modeled_words > 0
    assert row.lower_bound_words >= 0
    assert row.measured_over_model is not None
    d = row.to_dict()
    assert d["modeled_bytes"] == row.modeled_words * row.itemsize
    audit_events = [e for e in tr.events if e["kind"] == "bounds_audit"]
    assert len(audit_events) == 1
    assert audit_events[0]["measured_bytes"] == row.measured_bytes


def test_audit_multi_ttm_triple_on_cpu():
    x, _ = _problem()
    mats = [
        jax.random.normal(jax.random.PRNGKey(10 + k), (d, 2))
        for k, d in enumerate(DIMS)
    ]
    row = audit_multi_ttm(x, mats, keep=None)
    assert row.measured_bytes >= row.lower_bound_bytes
    assert row.modeled_words > 0


# ---------------------------------------------------------------------------
# summarize_events + report CLI
# ---------------------------------------------------------------------------

def test_summarize_events_totals():
    events = [
        {"kind": "mttkrp", "modeled_words": 100, "itemsize": 4,
         "lower_bound_words": 10},
        {"kind": "bounds_audit", "modeled_words": 50, "itemsize": 4,
         "lower_bound_words": 0, "measured_bytes": 300.0},
    ]
    s = summarize_events(events)
    assert s["events"] == 2
    assert s["modeled_words"] == 150.0
    assert s["lower_bound_words"] == 10.0
    assert s["measured_bytes"] == 300.0
    assert s["optimality_ratio"] == pytest.approx(300.0 / 600.0)
    empty = summarize_events([])
    assert empty["measured_bytes"] is None
    assert empty["optimality_ratio"] is None


def test_report_cli_renders_table(tmp_path, capsys):
    from repro.observe.report import main as report_main

    x, fs = _problem()
    p = tmp_path / "trace.jsonl"
    with Trace(path=str(p)):
        repro.mttkrp(x, fs, 0, ctx=ExecutionContext.create(observe=True))
        audit_mttkrp(x, fs, 0)
    assert report_main([str(p)]) == 0
    out = capsys.readouterr().out
    assert "| kind |" in out and "mttkrp" in out and "bounds_audit" in out


def test_report_cli_empty_trace_fails(tmp_path):
    from repro.observe.report import main as report_main

    p = tmp_path / "empty.jsonl"
    p.write_text("")
    assert report_main([str(p)]) == 1  # empty table = broken pipeline
    assert report_main([str(tmp_path / "missing.jsonl")]) == 2


def test_report_cli_flags_excess_traffic(tmp_path, capsys):
    from repro.observe.report import main as report_main

    p = tmp_path / "hot.jsonl"
    e = {
        "schema": SPAN_SCHEMA, "seq": 0, "time_s": 0.0,
        "kind": "bounds_audit", "itemsize": 4, "modeled_words": 10,
        "lower_bound_words": 0, "measured_bytes": 400.0,
    }
    p.write_text(json.dumps(e) + "\n")
    assert report_main([str(p)]) == 0  # flagged but not strict
    assert "!" in capsys.readouterr().out
    assert report_main([str(p), "--strict"]) == 1
    assert report_main([str(p), "--strict", "--flag-factor", "20"]) == 0


# ---------------------------------------------------------------------------
# Benchmark stamping + perf gate traffic columns
# ---------------------------------------------------------------------------

def test_perf_gate_traffic_threshold():
    from benchmarks.perf_gate import compare_traffic

    old = {"row": {"name": "row", "us_per_call": 1.0,
                   "trace": {"modeled_words": 100.0,
                             "optimality_ratio": 1.0}}}
    new_ok = {"row": {"name": "row", "us_per_call": 1.0,
                      "trace": {"modeled_words": 110.0,
                                "optimality_ratio": 1.1}}}
    new_bad = {"row": {"name": "row", "us_per_call": 1.0,
                       "trace": {"modeled_words": 200.0,
                                 "optimality_ratio": 1.0}}}
    assert compare_traffic(old, new_ok, traffic_threshold=0.25) == []
    v = compare_traffic(old, new_bad, traffic_threshold=0.25)
    assert len(v) == 1 and "modeled_words" in v[0]
    # rows lacking a trace on either side are skipped
    assert compare_traffic(
        old, {"row": {"name": "row"}}, traffic_threshold=0.25
    ) == []


def test_repro_exports_trace():
    assert repro.Trace is Trace
    assert "Trace" in repro.__all__
