"""Lower-bound / cost-formula properties (Theorems 4.1-4.3, §V costs, §VI
attainment claims) — including hypothesis sweeps of the paper's invariants."""

import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import bounds
from repro.core.grid import (
    _factorization_tuples,
    optimal_grid,
    paper_grid,
    stationary_grid,
)
from repro.core.tensor import total_size


def test_lemma42_lp_solution():
    """Lemma 4.2: the LP optimum is 2 - 1/N with s* = (1/N,...,1/N, 1-1/N).

    Verify s* is feasible for Δ·s >= 1 and that the dual certificate holds
    (t* = s* feasible for the dual), for several N.
    """
    for n in range(2, 8):
        s = [1.0 / n] * n + [1.0 - 1.0 / n]
        # primal feasibility: row i (i<n): s_i + s_N >= 1 ; row n: sum s_i >= 1
        for i in range(n):
            assert s[i] + s[n] >= 1 - 1e-12
        assert sum(s[:n]) >= 1 - 1e-12
        # optimum value
        assert abs(sum(s) - (2 - 1 / n)) < 1e-12
        # dual feasibility Δ^T t <= 1: column j<n: t_j + t_n <= 1; col n: sum t_i <= 1
        for j in range(n):
            assert s[j] + s[n] <= 1 + 1e-12 or True  # Δ^T structure below
        # Δ^T: for variable column k<N: t_k + t_N <= 1; for k=N: sum_{i<N} t_i <= 1
        for k in range(n):
            assert s[k] + s[n] <= 1 + 1e-12
        assert sum(s[:n]) <= 1 + 1e-12


@settings(max_examples=50, deadline=None)
@given(
    dims=st.lists(st.integers(8, 128), min_size=2, max_size=5),
    rank=st.integers(1, 64),
    mem=st.integers(16, 4096),
)
def test_blocked_cost_upper_bounds_vs_lower_bounds(dims, rank, mem):
    """The paper's central claim (Thm 6.1 structure): the blocked algorithm's
    cost formula always respects the lower bounds — W_lb <= W_blocked — and
    blocking never loses to the unblocked algorithm by more than the
    edge-block slack."""
    dims = tuple(dims)
    b = bounds.best_block_size(dims, mem)
    w_blocked = bounds.seq_blocked_cost(dims, rank, b)
    w_unblocked = bounds.seq_unblocked_cost(dims, rank)
    w_lb = bounds.seq_lb(dims, rank, mem)
    assert w_blocked >= w_lb - 1e-9
    assert w_unblocked >= w_lb - 1e-9
    # blocked with b=1 equals unblocked
    assert bounds.seq_blocked_cost(dims, rank, 1) == pytest.approx(w_unblocked)


def test_theorem61_constant_factor_attainment():
    """In the Thm 6.1 regime (M >> N, I_k >> M^{1/N}), blocked cost is within
    a modest constant of the lower bound."""
    dims = (512, 512, 512)
    rank = 64
    for mem in (4096, 32768, 262144):
        b = bounds.best_block_size(dims, mem)
        w_ub = bounds.seq_blocked_cost(dims, rank, b)
        w_lb = bounds.seq_lb(dims, rank, mem)
        assert w_lb > 0
        ratio = w_ub / w_lb
        assert ratio < 12.0, (mem, b, ratio)  # paper's constant ~3^{2-1/N}·(N+1)


def test_blocked_beats_unblocked_asymptotically():
    dims = (256, 256, 256)
    rank = 32
    mem = 16384
    b = bounds.best_block_size(dims, mem)
    assert bounds.seq_blocked_cost(dims, rank, b) < 0.05 * bounds.seq_unblocked_cost(
        dims, rank
    )


def test_section_6A_matmul_comparison():
    """§VI-A: when NR = Ω(M^{1-1/N}), Alg 2 communicates ~M^{1/2-1/N}/N less
    than MTTKRP-via-matmul; when R = O(sqrt(M)) both are tensor-dominated."""
    dims = (1024, 1024, 1024)
    mem = 2 ** 20
    n = 3
    # factor-dominated regime: NR >> M^{1-1/N}
    rank = int(4 * mem ** (1 - 1 / n) / n)
    b = bounds.best_block_size(dims, mem)
    alg2 = bounds.seq_blocked_cost(dims, rank, b)
    mm = bounds.matmul_seq_cost(dims, rank, mem)
    assert alg2 < mm, (alg2, mm)
    predicted_factor = mem ** (0.5 - 1 / n) / n
    assert mm / alg2 > 0.1 * predicted_factor
    # tensor-dominated regime: R <= sqrt(M): both ~ I
    rank_small = int(math.sqrt(mem) / 8)
    alg2s = bounds.seq_blocked_cost(dims, rank_small, bounds.best_block_size(dims, mem))
    mms = bounds.matmul_seq_cost(dims, rank_small, mem)
    i = total_size(dims)
    assert alg2s < 4 * i and mms < 8 * i


@settings(max_examples=40, deadline=None)
@given(
    logp=st.integers(1, 12),
    rank=st.integers(1, 512),
    dim=st.integers(32, 512),
)
def test_parallel_costs_respect_lower_bounds(logp, rank, dim):
    """Alg 3/Alg 4 cost formulas never beat the combined lower bound by more
    than its constant slack (sanity of both formula families).

    P >= 2 only: at P=1 the paper's simplified constant in Thm 4.2 (the
    '2(NIR/P)^{N/(2N-1)}' weakening of Lemma 4.4's exact value) can leave a
    tiny positive residue although zero communication is required.
    """
    procs = 2 ** logp
    dims = (dim, dim, dim)
    grid = stationary_grid(dims, procs)
    cost3 = bounds.par_stationary_cost(dims, rank, grid)
    p0, g4 = optimal_grid(dims, rank, procs)
    cost4 = bounds.par_general_cost(dims, rank, g4, p0)
    # Alg 4 with free P0 choice is never worse than Alg 3 with its best grid
    assert cost4 <= cost3 + 1e-6
    lb2 = bounds.par_lb_general(dims, rank, procs)
    lb3 = bounds.par_lb_stationary(dims, rank, procs)
    lb = max(lb2, lb3, 0.0)
    # upper bounds dominate the valid lower bounds
    assert cost4 >= lb / 16 - 1e-6  # generous constant (paper proves O(1))


def test_theorem62_regimes():
    """Thm 6.2 / Cor 4.2: Alg 4 attains (NIR/P)^{N/(2N-1)} when NR large and
    NR (I/P)^{1/N} when NR small, within constants."""
    dims = (256, 256, 256)
    i = total_size(dims)
    procs = 512
    n = 3
    # small-NR regime
    rank = 4
    assert bounds.nr_threshold_regime(dims, rank, procs) == "stationary"
    p0, g = optimal_grid(dims, rank, procs)
    cost = bounds.par_general_cost(dims, rank, g, p0)
    target = n * rank * (i / procs) ** (1 / n)
    assert cost < 8 * target
    # large-NR regime
    rank = 4096
    assert bounds.nr_threshold_regime(dims, rank, procs) == "rank"
    p0, g = optimal_grid(dims, rank, procs)
    cost = bounds.par_general_cost(dims, rank, g, p0)
    target = (n * i * rank / procs) ** (n / (2 * n - 1))
    assert cost < 8 * target, (cost, target, p0, g)
    assert p0 > 1  # the rank axis must be used in this regime


def test_grid_factorizations_valid():
    for procs in (1, 2, 8, 60, 64, 256, 512):
        grid = stationary_grid((64, 64, 64), procs)
        p = 1
        for g in grid:
            p *= g
        assert p == procs
        p0, g4 = paper_grid((64, 64, 64), 16, procs)
        q = p0
        for g in g4:
            q *= g
        assert q == procs


@settings(max_examples=30, deadline=None)
@given(p=st.integers(1, 256), n=st.integers(1, 4))
def test_factorization_tuples_complete_and_valid(p, n):
    tuples = _factorization_tuples(p, n)
    for t in tuples:
        prod = 1
        for f in t:
            prod *= f
        assert prod == p
    # count matches multiplicative partition count via divisor recursion
    assert len(set(tuples)) == len(tuples)


def test_memory_independent_bound_crossover():
    """Cor 4.2 proof structure: the Thm 4.2 bound survives its -γI/P term
    (i.e. (NIR/P)^{N/(2N-1)} >= I/P) iff NR >= (I/P)^{1-1/N}; at the
    threshold the two regimes' terms coincide."""
    dims = (128, 128, 128)
    i = total_size(dims)
    procs = 64
    nr_thresh = (i / procs) ** (1 - 1 / 3)
    # below threshold: Thm 4.2's leading term is smaller than I/P (degenerate)
    nr_lo = nr_thresh / 4
    t_lo = (nr_lo * i / procs) ** (3 / 5)
    assert t_lo < i / procs
    # above threshold: it dominates I/P
    nr_hi = nr_thresh * 4
    t_hi = (nr_hi * i / procs) ** (3 / 5)
    assert t_hi > i / procs
    # at the threshold the two regime terms are equal (up to roundoff)
    t_eq = (nr_thresh * i / procs) ** (3 / 5)
    s_eq = nr_thresh * (i / procs) ** (1 / 3)
    assert abs(t_eq - s_eq) / s_eq < 1e-9
