"""The stable public API surface (`import repro`) and the deprecation shim.

Run in the CI fast lane as the API-stability gate: a PR that changes
``repro.__all__``, drops a docstring, or breaks the one-release
legacy-kwarg compatibility fails here before anything else.
"""

import warnings

import jax
import jax.numpy as jnp
import pytest

import repro

# the documented surface (docs/API.md) — change BOTH deliberately
DOCUMENTED_SURFACE = [
    "ExecutionContext",
    "Distribution",
    "Memory",
    "BlockPlan",
    "MultiTTMPlan",
    "mttkrp",
    "contract_partial",
    "multi_ttm",
    "cp_als",
    "cp_als_batched",
    "cp_gradient",
    "CPResult",
    "BatchedCPResult",
    "tucker_hooi",
    "tucker_hooi_batched",
    "TuckerResult",
    "BatchedTuckerResult",
    "select_grid",
    "select_tucker_grid",
    "Trace",
]


def _problem(dims=(6, 5, 4), rank=3):
    x = jax.random.normal(jax.random.PRNGKey(0), dims)
    fs = [
        jax.random.normal(jax.random.PRNGKey(k + 1), (d, rank))
        for k, d in enumerate(dims)
    ]
    return x, fs


# ---------------------------------------------------------------------------
# surface shape
# ---------------------------------------------------------------------------

def test_all_matches_documented_surface():
    assert list(repro.__all__) == DOCUMENTED_SURFACE


def test_every_export_exists_and_is_documented():
    for name in repro.__all__:
        obj = getattr(repro, name)  # raises AttributeError on a bad export
        assert obj.__doc__ and obj.__doc__.strip(), (
            f"repro.{name} has no docstring"
        )


def test_every_exported_callable_has_docstring():
    for name in repro.__all__:
        obj = getattr(repro, name)
        if callable(obj):
            assert obj.__doc__ and len(obj.__doc__.strip()) > 20, (
                f"repro.{name} is exported but under-documented"
            )


def test_package_has_version_and_module_doc():
    assert repro.__doc__ and "ExecutionContext" in repro.__doc__
    assert isinstance(repro.__version__, str) and repro.__version__


def test_multi_ttm_surface_is_documented():
    """Docstring-presence audit over the full new Multi-TTM/Tucker
    surface, one level below the frozen top-level exports."""
    from repro.core import bounds, tucker
    from repro.distributed import grid_select, tucker_parallel
    from repro.engine import execute, plan
    from repro.kernels import multi_ttm as multi_ttm_kernel
    from repro.tune import search

    audited = [
        execute.multi_ttm,
        plan.MultiTTMPlan,
        plan.choose_multi_ttm_blocks,
        plan.uniform_multi_ttm_plan,
        tucker.tucker_hooi,
        tucker.hosvd_init,
        tucker.ttm,
        tucker.TuckerResult,
        bounds.multi_ttm_seq_lb,
        bounds.multi_ttm_blocked_cost,
        bounds.par_multi_ttm_cost,
        grid_select.select_tucker_grid,
        grid_select.choose_tucker_grid,
        grid_select.multi_ttm_sweep_words,
        tucker_parallel.multi_ttm_stationary,
        tucker_parallel.build_tucker_sweep,
        tucker_parallel.tucker_hooi_parallel,
        multi_ttm_kernel.multi_ttm_keep_pallas,
        search.tune_multi_ttm,
        search.resolve_multi_ttm,
    ]
    from repro.observe import bounds_audit, metrics, trace

    audited += [
        trace.Trace,
        trace.summarize_events,
        metrics.MetricsRegistry,
        metrics.registry,
        bounds_audit.AuditRow,
        bounds_audit.audit_mttkrp,
        bounds_audit.audit_multi_ttm,
    ]
    for obj in audited:
        assert obj.__doc__ and len(obj.__doc__.strip()) > 20, (
            f"{obj.__module__}.{obj.__qualname__} is under-documented"
        )


# ---------------------------------------------------------------------------
# the deprecated-kwarg shim
# ---------------------------------------------------------------------------

def test_legacy_kwargs_emit_exactly_one_deprecation_warning():
    x, fs = _problem()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        repro.mttkrp(x, fs, 0, backend="einsum")
    dep = [wi for wi in w if wi.category is DeprecationWarning]
    assert len(dep) == 1, [str(wi.message) for wi in w]
    msg = str(dep[0].message)
    # the message must teach the new spelling
    assert "ExecutionContext.create" in msg
    assert "ctx=ctx" in msg
    assert "backend" in msg  # names the offending kwarg(s)


@pytest.mark.parametrize(
    "call",
    [
        lambda x, fs: repro.cp_als(x, 2, n_iters=1, backend="einsum"),
        lambda x, fs: repro.cp_gradient(x, 2, n_iters=1, backend="einsum"),
        lambda x, fs: repro.contract_partial(
            x, fs, (0, 1, 2), (2,), False, backend="einsum"
        ),
    ],
    ids=["cp_als", "cp_gradient", "contract_partial"],
)
def test_every_driver_shims_legacy_kwargs(call):
    x, fs = _problem()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        call(x, fs)
    dep = [wi for wi in w if wi.category is DeprecationWarning]
    assert len(dep) == 1
    assert "ExecutionContext" in str(dep[0].message)


def test_ctx_path_is_warning_free():
    x, fs = _problem()
    ctx = repro.ExecutionContext.create(backend="einsum")
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        repro.mttkrp(x, fs, 0, ctx=ctx)
        repro.cp_als(x, 2, n_iters=1, ctx=ctx)
        repro.cp_gradient(x, 2, n_iters=1, ctx=ctx)


def test_default_call_is_warning_free():
    x, fs = _problem()
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        repro.mttkrp(x, fs, 0)
        repro.cp_als(x, 2, n_iters=1)


def test_ctx_plus_legacy_kwargs_rejected():
    x, fs = _problem()
    ctx = repro.ExecutionContext.create()
    with pytest.raises(TypeError, match="not both"):
        repro.mttkrp(x, fs, 0, ctx=ctx, backend="einsum")


def test_legacy_and_ctx_paths_agree():
    x, fs = _problem()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = repro.mttkrp(x, fs, 0, backend="blocked_host")
    ctx = repro.ExecutionContext.create(backend="blocked_host")
    new = repro.mttkrp(x, fs, 0, ctx=ctx)
    assert jnp.allclose(legacy, new)


# ---------------------------------------------------------------------------
# the unified validator (one error catalog, actionable messages)
# ---------------------------------------------------------------------------

def test_unknown_backend_lists_valid_values():
    with pytest.raises(ValueError) as e:
        repro.ExecutionContext.create(backend="cuda")
    msg = str(e.value)
    for valid in ("einsum", "blocked_host", "pallas", "auto"):
        assert valid in msg


def test_driver_and_context_raise_the_same_backend_error():
    x, fs = _problem()
    with pytest.raises(ValueError) as via_ctx:
        repro.ExecutionContext.create(backend="nope")
    with warnings.catch_warnings(), pytest.raises(ValueError) as via_driver:
        warnings.simplefilter("ignore", DeprecationWarning)
        repro.mttkrp(x, fs, 0, backend="nope")
    assert str(via_ctx.value) == str(via_driver.value)
