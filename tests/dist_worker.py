"""Multi-device distributed checks, run in a subprocess with
--xla_force_host_platform_device_count=8 (jax locks device count at init, so
the main pytest session, which must see 1 device, cannot run these inline).

Each check prints 'PASS <name>'; the parent test asserts on the transcript.
"""

import os
import sys

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.compat import make_mesh, shard_map  # noqa: E402
from repro.core.bounds import par_general_cost, par_stationary_cost  # noqa: E402
from repro.core.mttkrp import mttkrp  # noqa: E402
from repro.core.tensor import random_factors, random_tensor  # noqa: E402
from repro.distributed import (  # noqa: E402
    make_grid_mesh,
    mttkrp_general,
    mttkrp_stationary,
    parse_collectives,
    place_inputs,
)
from repro.distributed import (  # noqa: E402
    build_cp_sweep,
    cp_als_parallel,
    place_cp_state,
    stationary_sweep_words,
)
from repro.distributed.compression import (  # noqa: E402
    cp_compressed_mean,
    compression_ratio,
)


def check_alg3_numerics():
    dims, rank = (8, 16, 24), 8
    x = random_tensor(jax.random.PRNGKey(0), dims)
    fs = random_factors(jax.random.PRNGKey(1), dims, rank)
    mesh = make_grid_mesh((2, 2, 2))
    for mode in range(3):
        f3 = mttkrp_stationary(mesh, mode, 3)
        xs, fl = place_inputs(mesh, x, fs, mode)
        out = f3(xs, *fl)
        ref = mttkrp(x, fs, mode)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-5
        )
    print("PASS alg3_numerics")


def check_alg3_asymmetric_grid():
    dims, rank = (16, 8, 8), 4
    x = random_tensor(jax.random.PRNGKey(2), dims)
    fs = random_factors(jax.random.PRNGKey(3), dims, rank)
    mesh = make_grid_mesh((4, 1, 2))
    for mode in range(3):
        f3 = mttkrp_stationary(mesh, mode, 3)
        xs, fl = place_inputs(mesh, x, fs, mode)
        out = f3(xs, *fl)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(mttkrp(x, fs, mode)),
            rtol=1e-4, atol=1e-5,
        )
    print("PASS alg3_asymmetric_grid")


def check_alg4_numerics():
    dims, rank = (8, 16, 24), 8
    x = random_tensor(jax.random.PRNGKey(4), dims)
    fs = random_factors(jax.random.PRNGKey(5), dims, rank)
    for p0, grid in [(2, (2, 2, 1)), (4, (2, 1, 1)), (8, (1, 1, 1))]:
        mesh = make_grid_mesh(grid, p0=p0)
        for mode in range(3):
            f4 = mttkrp_general(mesh, mode, 3)
            xs, fl = place_inputs(mesh, x, fs, mode, rank_axis=True)
            out = f4(xs, *fl)
            np.testing.assert_allclose(
                np.asarray(out), np.asarray(mttkrp(x, fs, mode)),
                rtol=1e-4, atol=1e-5,
            )
    print("PASS alg4_numerics")


def check_alg4_4way():
    dims, rank = (4, 8, 4, 8), 4
    x = random_tensor(jax.random.PRNGKey(6), dims)
    fs = random_factors(jax.random.PRNGKey(7), dims, rank)
    mesh = make_grid_mesh((2, 2, 1, 1), p0=2)
    for mode in range(4):
        f4 = mttkrp_general(mesh, mode, 4)
        xs, fl = place_inputs(mesh, x, fs, mode, rank_axis=True)
        out = f4(xs, *fl)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(mttkrp(x, fs, mode)),
            rtol=1e-4, atol=1e-5,
        )
    print("PASS alg4_4way")


def check_comm_matches_eq12():
    """Measured ring bytes from compiled HLO == Eq (12), exactly."""
    dims, rank = (8, 16, 24), 8
    x = random_tensor(jax.random.PRNGKey(0), dims)
    fs = random_factors(jax.random.PRNGKey(1), dims, rank)
    mesh = make_grid_mesh((2, 2, 2))
    for mode in range(3):
        f3 = mttkrp_stationary(mesh, mode, 3)
        xs, fl = place_inputs(mesh, x, fs, mode)
        co = f3.lower(xs, *fl).compile()
        measured = parse_collectives(co.as_text()).ring_bytes
        predicted = par_stationary_cost(dims, rank, (2, 2, 2), mode) * 4
        assert measured == predicted, (mode, measured, predicted)
    print("PASS comm_matches_eq12")


def check_comm_matches_eq16():
    dims, rank = (8, 16, 24), 8
    x = random_tensor(jax.random.PRNGKey(0), dims)
    fs = random_factors(jax.random.PRNGKey(1), dims, rank)
    p0, grid = 2, (2, 2, 1)
    mesh = make_grid_mesh(grid, p0=p0)
    for mode in range(3):
        f4 = mttkrp_general(mesh, mode, 3)
        xs, fl = place_inputs(mesh, x, fs, mode, rank_axis=True)
        co = f4.lower(xs, *fl).compile()
        measured = parse_collectives(co.as_text()).ring_bytes
        predicted = par_general_cost(dims, rank, grid, p0, mode) * 4
        assert measured == predicted, (mode, measured, predicted)
    print("PASS comm_matches_eq16")


def check_stationary_tensor_never_moves():
    """Alg 3's defining property: no collective touches tensor-sized data."""
    dims, rank = (16, 16, 16), 4
    x = random_tensor(jax.random.PRNGKey(0), dims)
    fs = random_factors(jax.random.PRNGKey(1), dims, rank)
    mesh = make_grid_mesh((2, 2, 2))
    f3 = mttkrp_stationary(mesh, 0, 3)
    xs, fl = place_inputs(mesh, x, fs, 0)
    co = f3.lower(xs, *fl).compile()
    summ = parse_collectives(co.as_text())
    local_tensor_bytes = (16 ** 3) // 8 * 4
    for op in summ.ops:
        assert op.operand_bytes < local_tensor_bytes, (
            op.kind, op.operand_bytes
        )
    print("PASS stationary_tensor_never_moves")


def check_cp_compressed_mean():
    """Compressed DP mean == CP-ALS of the true mean gradient."""
    from jax.sharding import PartitionSpec as P

    from repro.core.tensor import random_low_rank_tensor

    mesh = make_mesh((8,), ("dp",))
    dims, rank = (16, 12, 1), 6
    # worker-dependent gradients share a low-rank core (realistic: gradient
    # subspaces overlap across DP replicas) + per-worker perturbation
    base, _ = random_low_rank_tensor(jax.random.PRNGKey(8), dims, 3)
    delta, _ = random_low_rank_tensor(jax.random.PRNGKey(9), dims, 2)
    workers = jnp.stack(
        [base + i * 0.01 * delta for i in range(8)]
    )  # (8, *dims)
    g_mean = jnp.mean(workers, axis=0)  # rank <= 5 exactly

    def body(g):
        g = g.reshape(dims)
        recon, _ = cp_compressed_mean(
            g, ("dp",), rank=rank, sweeps=25, key=jax.random.PRNGKey(10)
        )
        return recon[None]

    f = jax.jit(
        shard_map(
            body, mesh=mesh, in_specs=P("dp", None, None, None),
            out_specs=P("dp", None, None, None), check_rep=False,
        )
    )
    recon_all = np.asarray(f(workers))
    # every worker must hold the SAME reconstruction (sync invariant)
    for i in range(1, 8):
        np.testing.assert_allclose(
            recon_all[i], recon_all[0], rtol=1e-5, atol=1e-6
        )
    # and it approximates the true mean well at adequate rank
    err = np.linalg.norm(recon_all[0] - g_mean) / np.linalg.norm(g_mean)
    assert err < 0.05, err
    # compression ratio sanity
    assert compression_ratio((4096, 14336), 8, 1) > 100
    print("PASS cp_compressed_mean")


def check_collective_only_factor_sized():
    """The compressed all-reduce must move only Σ I_k R words, never Π I_k."""
    from jax.sharding import PartitionSpec as P

    mesh = make_mesh((8,), ("dp",))
    dims, rank, sweeps = (32, 24, 1), 4, 2
    workers = random_tensor(jax.random.PRNGKey(11), (8,) + dims)

    def body(g):
        g = g.reshape(dims)
        recon, _ = cp_compressed_mean(
            g, ("dp",), rank=rank, sweeps=sweeps, key=jax.random.PRNGKey(0)
        )
        return recon[None]

    f = jax.jit(
        shard_map(
            body, mesh=mesh, in_specs=P("dp", None, None, None),
            out_specs=P("dp", None, None, None), check_rep=False,
        )
    )
    co = f.lower(workers).compile()
    summ = parse_collectives(co.as_text())
    full_bytes = 32 * 24 * 1 * 4
    for op in summ.ops:
        assert op.operand_bytes < full_bytes, (op.kind, op.operand_bytes)
    # paper-predicted total: sweeps * sum_k I_k * rank words (pmean operand)
    predicted_operand = sweeps * sum(dims) * rank * 4
    assert summ.operand_bytes == predicted_operand, (
        summ.operand_bytes, predicted_operand
    )
    print("PASS collective_only_factor_sized")


def check_alg3_pallas_local():
    """Alg 3 with the engine's Pallas backend for the per-shard MTTKRP:
    the collectives are unchanged and the local blocked kernel matches."""
    dims, rank = (16, 16, 24), 8
    x = random_tensor(jax.random.PRNGKey(20), dims)
    fs = random_factors(jax.random.PRNGKey(21), dims, rank)
    mesh = make_grid_mesh((2, 2, 2))
    for mode in range(3):
        f3 = mttkrp_stationary(mesh, mode, 3, backend="pallas",
                               interpret=True)
        xs, fl = place_inputs(mesh, x, fs, mode)
        np.testing.assert_allclose(
            np.asarray(f3(xs, *fl)), np.asarray(mttkrp(x, fs, mode)),
            rtol=1e-4, atol=1e-4,
        )
    mesh4 = make_grid_mesh((2, 2, 1), p0=2)
    f4 = mttkrp_general(mesh4, 0, 3, backend="pallas", interpret=True)
    xs, fl = place_inputs(mesh4, x, fs, 0, rank_axis=True)
    np.testing.assert_allclose(
        np.asarray(f4(xs, *fl)), np.asarray(mttkrp(x, fs, 0)),
        rtol=1e-4, atol=1e-4,
    )
    print("PASS alg_pallas_local")


def check_cp_sweep_matches_sequential():
    """The distributed ALS sweep (one shard_map program per sweep) is
    numerically the sequential Gauss-Seidel driver: same fits, same
    factors, same weights, to fp32 collective-reordering tolerance."""
    from repro.core.cp_als import cp_als
    from repro.core.tensor import random_low_rank_tensor

    dims, rank = (16, 16, 24), 4
    x, _ = random_low_rank_tensor(jax.random.PRNGKey(30), dims, rank)
    par = cp_als_parallel(
        x, rank, n_iters=8, key=jax.random.PRNGKey(31), grid=(2, 2, 2)
    )
    seq = cp_als(x, rank, n_iters=8, key=jax.random.PRNGKey(31))
    for fp, fs_ in zip(par.fits, seq.fits):
        assert abs(fp - fs_) < 1e-3, (fp, fs_)
    for k in range(3):
        np.testing.assert_allclose(
            np.asarray(par.factors[k]), np.asarray(seq.factors[k]),
            rtol=1e-3, atol=1e-4,
        )
    np.testing.assert_allclose(
        np.asarray(par.weights), np.asarray(seq.weights),
        rtol=1e-3, atol=1e-4,
    )
    assert par.final_fit > 0.999
    print("PASS cp_sweep_matches_sequential")


def check_cp_sweep_comm_beats_independent():
    """HLO-measured bytes of ONE distributed ALS sweep < the sum of N
    independent single-mode Eq (12) calls (the BHK amortization), and
    == the sweep cost model exactly."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.tensor import frob_norm
    from repro.distributed import make_grid_mesh

    dims, rank = (32, 32, 32), 4
    x = random_tensor(jax.random.PRNGKey(32), dims)
    fs = random_factors(jax.random.PRNGKey(33), dims, rank)
    for grid in ((2, 2, 2), (1, 2, 2)):
        procs = 1
        for g in grid:
            procs *= g
        mesh = make_grid_mesh(grid, dims=dims, rank=rank)
        sweep = build_cp_sweep(mesh, 3)
        xs, f_sh, blocks, grams = place_cp_state(mesh, x, fs)
        normx = jax.device_put(frob_norm(x), NamedSharding(mesh, P()))
        co = sweep.lower(xs, f_sh, blocks, grams, normx).compile()
        measured = parse_collectives(co.as_text()).ring_bytes
        independent = 0
        for mode in range(3):
            f3 = mttkrp_stationary(mesh, mode, 3)
            xsm, fl = place_inputs(mesh, x, fs, mode)
            independent += parse_collectives(
                f3.lower(xsm, *fl).compile().as_text()
            ).ring_bytes
        # the N independent calls cost exactly the Eq (12) sum ...
        eq12_sum = sum(
            par_stationary_cost(dims, rank, grid, m) for m in range(3)
        ) * 4
        assert independent == eq12_sum, (grid, independent, eq12_sum)
        # ... the sweep strictly beats it (factor gathers amortized) ...
        assert measured < independent, (grid, measured, independent)
        # ... and matches the sweep cost model exactly: the modeled factor
        # + Gram words plus the one scalar fit all-reduce (ring-truncated)
        predicted = stationary_sweep_words(dims, rank, grid) * 4 + int(
            2 * (procs - 1) / procs * 4
        )
        assert measured == predicted, (grid, measured, predicted)
    print("PASS cp_sweep_comm_beats_independent")


def check_ring_overlap_sweep():
    """overlap="ring": the sweep's per-factor all-gather/reduce-scatter
    become ppermute rings with chunked MTTKRP consumption — numerics match
    the monolithic-collective sweep, every factor collective is a
    collective-permute, and HLO-measured bytes equal the SAME
    stationary_sweep_words model exactly (the 2-collectives-per-factor
    traffic is preserved byte-for-byte)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.tensor import frob_norm
    from repro.engine.context import ExecutionContext

    dims, rank = (32, 32, 32), 4
    x = random_tensor(jax.random.PRNGKey(60), dims)
    fs = random_factors(jax.random.PRNGKey(61), dims, rank)
    for grid in ((2, 2, 2), (1, 2, 2)):
        procs = 1
        for g in grid:
            procs *= g
        ctx_ring = ExecutionContext.create(grid=grid, overlap="ring")
        # numerics: ring sweep == plain sweep (fp reordering tolerance)
        r_none = cp_als_parallel(x, rank, n_iters=4, init_factors=fs,
                                 grid=grid)
        r_ring = cp_als_parallel(x, rank, n_iters=4, init_factors=fs,
                                 ctx=ctx_ring)
        for k in range(3):
            np.testing.assert_allclose(
                np.asarray(r_ring.factors[k]), np.asarray(r_none.factors[k]),
                rtol=1e-3, atol=1e-4,
            )
        np.testing.assert_allclose(
            np.asarray(r_ring.weights), np.asarray(r_none.weights),
            rtol=1e-3, atol=1e-4,
        )
        for fp, fn_ in zip(r_ring.fits, r_none.fits):
            assert abs(fp - fn_) < 1e-3, (r_ring.fits, r_none.fits)
        # bytes: the ring spelling moves exactly the modeled words
        mesh = make_grid_mesh(grid, dims=dims, rank=rank)
        sweep = build_cp_sweep(mesh, 3, ctx=ctx_ring)
        xs, f_sh, blocks, grams = place_cp_state(mesh, x, fs)
        normx = jax.device_put(frob_norm(x), NamedSharding(mesh, P()))
        summ = parse_collectives(
            sweep.lower(xs, f_sh, blocks, grams, normx).compile().as_text()
        )
        predicted = stationary_sweep_words(dims, rank, grid) * 4 + int(
            2 * (procs - 1) / procs * 4
        )
        assert summ.ring_bytes == predicted, (
            grid, summ.ring_bytes, predicted
        )
        # every factor collective is now a ppermute hop; only the R x R
        # Gram / scalar fit all-reduces remain monolithic
        kinds = summ.by_kind()
        assert "all-gather" not in kinds and "reduce-scatter" not in kinds, (
            grid, kinds
        )
        assert kinds.get("collective-permute", {}).get("count", 0) > 0, kinds
    print("PASS ring_overlap_sweep")


def check_cp_auto_grid_driver():
    """cp_als(distributed=True): automatic Eq (12)-sweep-optimal grid
    selection end-to-end through the core driver entry."""
    from repro.core.cp_als import cp_als
    from repro.core.tensor import random_low_rank_tensor, relative_error
    from repro.core.tensor import tensor_from_factors
    from repro.distributed.grid_select import choose_cp_grid

    dims, rank = (16, 16, 16), 4
    choice = choose_cp_grid(dims, rank, len(jax.devices()))
    assert choice.procs == 8 and choice.grid == (2, 2, 2), choice
    x, _ = random_low_rank_tensor(jax.random.PRNGKey(34), dims, rank)
    res = cp_als(x, rank, n_iters=25, key=jax.random.PRNGKey(2),
                 distributed=True)
    assert res.final_fit > 0.999, res.fits
    recon = tensor_from_factors(res.factors, res.weights)
    assert float(relative_error(x, recon)) < 0.02
    print("PASS cp_auto_grid_driver")


def check_cp_sweep_pallas_local():
    """Sweep driver with the engine's Pallas backend for every per-shard
    local MTTKRP: collectives unchanged, numerics match sequential."""
    from repro.core.cp_als import cp_als
    from repro.core.tensor import random_low_rank_tensor

    dims, rank = (16, 16, 24), 4
    x, _ = random_low_rank_tensor(jax.random.PRNGKey(36), dims, rank)
    par = cp_als_parallel(
        x, rank, n_iters=5, key=jax.random.PRNGKey(37), grid=(2, 2, 2),
        backend="pallas", interpret=True,
    )
    seq = cp_als(x, rank, n_iters=5, key=jax.random.PRNGKey(37))
    for fp, fs_ in zip(par.fits, seq.fits):
        assert abs(fp - fs_) < 1e-3, (fp, fs_)
    print("PASS cp_sweep_pallas_local")


def check_context_roundtrip_reproduces_sweep():
    """A serialized ExecutionContext is a reproducible artifact: building
    the distributed sweep from ``from_json(to_json(ctx))`` emits the SAME
    program — identical HLO-measured collective bytes — and the pallas
    local path dispatches the same number of kernels per trace.  Also the
    observability no-overhead guarantee: ``observe=True`` lowers to HLO
    *identical* to ``observe=False`` (recording is driver-side only;
    nothing observability-related may enter the traced program)."""
    import dataclasses

    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import ExecutionContext
    from repro.core.tensor import frob_norm
    from repro.observe.metrics import PALLAS_DISPATCHES, registry

    dims, rank = (16, 16, 24), 4
    x = random_tensor(jax.random.PRNGKey(40), dims)
    fs = random_factors(jax.random.PRNGKey(41), dims, rank)
    ctx = ExecutionContext.for_problem(
        dims, rank, backend="pallas", interpret=True, distributed=True,
        procs=len(jax.devices()),
    )
    ctx2 = ExecutionContext.from_json(ctx.to_json())
    assert ctx2 == ctx and hash(ctx2) == hash(ctx)
    assert ctx2.distribution.grid == ctx.distribution.grid

    def measure(c, want_text=False):
        mesh = c.build_mesh(dims, rank)
        sweep = build_cp_sweep(mesh, 3, ctx=c)
        xs, f_sh, blocks, grams = place_cp_state(mesh, x, fs)
        normx = jax.device_put(frob_norm(x), NamedSharding(mesh, P()))
        before = registry().counter(PALLAS_DISPATCHES)
        lowered = sweep.lower(xs, f_sh, blocks, grams, normx)
        dispatches = registry().counter(PALLAS_DISPATCHES) - before
        text = lowered.compile().as_text()
        ring = parse_collectives(text).ring_bytes
        return (ring, dispatches, text) if want_text else (ring, dispatches)

    bytes1, disp1 = measure(ctx)
    bytes2, disp2 = measure(ctx2)
    assert bytes1 == bytes2, (bytes1, bytes2)
    assert disp1 == disp2 and disp1 > 0, (disp1, disp2)

    _, _, text_off = measure(
        dataclasses.replace(ctx, observe=False), want_text=True
    )
    _, _, text_on = measure(
        dataclasses.replace(ctx, observe=True), want_text=True
    )
    assert text_on == text_off, "observe=True changed the sweep HLO"
    print("PASS context_roundtrip_reproduces_sweep")


def check_multi_ttm_comm_matches_model():
    """Measured ring bytes of the stationary full-core Multi-TTM ==
    par_multi_ttm_cost, exactly (the Eq-12 analog for Tucker)."""
    from repro.core.bounds import par_multi_ttm_cost
    from repro.distributed.tucker_parallel import (
        multi_ttm_stationary,
        place_multi_ttm_inputs,
    )
    from repro.engine.execute import multi_ttm

    dims, ranks = (16, 16, 16), (4, 3, 2)
    x = random_tensor(jax.random.PRNGKey(50), dims)
    mats = [
        jax.random.normal(jax.random.PRNGKey(51 + k), (d, r))
        for k, (d, r) in enumerate(zip(dims, ranks))
    ]
    for grid in ((2, 2, 2), (1, 2, 4)):
        mesh = make_grid_mesh(grid)
        f = multi_ttm_stationary(mesh, 3)
        xs, ms = place_multi_ttm_inputs(mesh, x, mats)
        np.testing.assert_allclose(
            np.asarray(f(xs, *ms)), np.asarray(multi_ttm(x, mats, None)),
            rtol=1e-4, atol=1e-4,
        )
        measured = parse_collectives(
            f.lower(xs, *ms).compile().as_text()
        ).ring_bytes
        predicted = int(par_multi_ttm_cost(dims, ranks, grid) * 4)
        assert measured == predicted, (grid, measured, predicted)
    print("PASS multi_ttm_comm_matches_model")


def check_tucker_sweep_comm_matches_model():
    """HLO-measured bytes of ONE distributed HOOI sweep == the Multi-TTM
    sweep model (multi_ttm_sweep_words) exactly — per mode, one
    hyperslice all-reduce + one fiber all-gather of the partial Y^(k),
    and no factor collectives at all."""
    import math

    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.tensor import frob_norm
    from repro.core.tucker import hosvd_init
    from repro.distributed.grid_select import multi_ttm_sweep_words
    from repro.distributed.tucker_parallel import (
        build_tucker_sweep,
        place_tucker_state,
    )

    dims, ranks = (16, 16, 16), (4, 3, 2)
    x = random_tensor(jax.random.PRNGKey(52), dims)
    factors = hosvd_init(x, ranks)
    for grid in ((2, 2, 2), (1, 2, 4)):
        mesh = make_grid_mesh(grid)
        sweep = build_tucker_sweep(mesh, 3, ranks)
        xs, fs = place_tucker_state(mesh, x, factors)
        normx = jax.device_put(frob_norm(x), NamedSharding(mesh, P()))
        summ = parse_collectives(
            sweep.lower(xs, fs, normx).compile().as_text()
        )
        measured = summ.ring_bytes
        procs = math.prod(grid)
        # exact expected bytes, truncating per op like CollectiveOp
        expected = 0
        for k, (d, pk) in enumerate(zip(dims, grid)):
            rbar = math.prod(r for j, r in enumerate(ranks) if j != k)
            w_bytes = (d // pk) * rbar * 4
            q = procs // pk
            expected += int(2 * (q - 1) / q * w_bytes) + (pk - 1) * w_bytes
        assert measured == expected, (grid, measured, expected)
        # ... which is exactly the grid-selection objective in words
        assert expected == int(multi_ttm_sweep_words(dims, ranks, grid) * 4)
        # factors never travel: every gather/reduce operand is Y^(k)-sized
        for op in summ.ops:
            assert op.operand_bytes <= max(
                (d // pk) * math.prod(
                    r for j, r in enumerate(ranks) if j != k
                ) * 4
                for k, (d, pk) in enumerate(zip(dims, grid))
            ), (op.kind, op.operand_bytes)
    print("PASS tucker_sweep_comm_matches_model")


def check_tucker_parallel_matches_sequential():
    """The distributed HOOI sweep is numerically the sequential driver:
    same fits, same factors (deterministic eigh sign convention), same
    core, to fp32 collective-reordering tolerance — and the core-driver
    entry (tucker_hooi with a distributed context) selects the
    Multi-TTM-sweep-optimal grid automatically."""
    from repro.core.tensor import random_tucker_tensor
    from repro.core.tucker import tucker_hooi
    from repro.distributed.grid_select import choose_tucker_grid
    from repro.distributed.tucker_parallel import tucker_hooi_parallel
    from repro.engine.context import ExecutionContext

    dims, ranks = (16, 16, 16), (4, 3, 2)
    x, _, _ = random_tucker_tensor(jax.random.PRNGKey(53), dims, ranks)
    seq = tucker_hooi(x, ranks, n_iters=5)
    par = tucker_hooi_parallel(x, ranks, n_iters=5, grid=(2, 2, 2))
    for fs_, fp in zip(seq.fits, par.fits):
        assert abs(fs_ - fp) < 1e-3, (seq.fits, par.fits)
    for k in range(3):
        np.testing.assert_allclose(
            np.asarray(par.factors[k]), np.asarray(seq.factors[k]),
            rtol=1e-3, atol=1e-3,
        )
    np.testing.assert_allclose(
        np.asarray(par.core), np.asarray(seq.core), rtol=1e-3, atol=1e-3
    )
    assert par.final_fit > 0.999, par.fits
    # the unified driver entry: a distributed context routes here with
    # automatic grid selection
    choice = choose_tucker_grid(dims, ranks, len(jax.devices()))
    assert choice.procs == 8, choice
    ctx = ExecutionContext.create(distributed=True)
    res = tucker_hooi(x, ranks, n_iters=5, ctx=ctx)
    assert res.final_fit > 0.999, res.fits
    print("PASS tucker_parallel_matches_sequential")


def check_tucker_sweep_pallas_local():
    """Sweep driver with the engine's Pallas Kronecker kernel for every
    per-shard local Multi-TTM: numerics match the einsum-local sweep."""
    from repro.core.tensor import random_tucker_tensor
    from repro.distributed.tucker_parallel import tucker_hooi_parallel
    from repro.engine.context import ExecutionContext
    from repro.observe.metrics import PALLAS_DISPATCHES, registry

    dims, ranks = (16, 16, 24), (4, 3, 2)
    x, _, _ = random_tucker_tensor(jax.random.PRNGKey(54), dims, ranks)
    ctx = ExecutionContext.create(
        backend="pallas", interpret=True, distributed=True, grid=(2, 2, 2)
    )
    before = registry().counter(PALLAS_DISPATCHES)
    par = tucker_hooi_parallel(x, ranks, n_iters=4, ctx=ctx)
    assert registry().counter(PALLAS_DISPATCHES) > before
    ref = tucker_hooi_parallel(x, ranks, n_iters=4, grid=(2, 2, 2))
    for fp, fr in zip(par.fits, ref.fits):
        assert abs(fp - fr) < 1e-3, (par.fits, ref.fits)
    print("PASS tucker_sweep_pallas_local")


CHECKS = [
    check_alg3_numerics,
    check_alg3_asymmetric_grid,
    check_alg4_numerics,
    check_alg4_4way,
    check_comm_matches_eq12,
    check_comm_matches_eq16,
    check_stationary_tensor_never_moves,
    check_cp_compressed_mean,
    check_collective_only_factor_sized,
    check_alg3_pallas_local,
    check_cp_sweep_matches_sequential,
    check_cp_sweep_comm_beats_independent,
    check_ring_overlap_sweep,
    check_cp_auto_grid_driver,
    check_cp_sweep_pallas_local,
    check_context_roundtrip_reproduces_sweep,
    check_multi_ttm_comm_matches_model,
    check_tucker_sweep_comm_matches_model,
    check_tucker_parallel_matches_sequential,
    check_tucker_sweep_pallas_local,
]

if __name__ == "__main__":
    names = sys.argv[1:]
    for chk in CHECKS:
        if names and chk.__name__ not in names:
            continue
        chk()
    print("ALL_DIST_OK")
