"""The mixed-precision policy: ``compute_dtype`` casting with fp32
accumulation across all three backends, dtype-aware planning, and the
ExecutionContext serialization of the new knobs."""

import jax
import jax.numpy as jnp
import pytest

from repro.core.cp_als import cp_als
from repro.core.tensor import random_low_rank_tensor
from repro.engine import Memory, mttkrp
from repro.engine.context import Distribution, ExecutionContext
from repro.engine.execute import contract_partial, multi_ttm
from repro.engine.plan import choose_blocks, choose_sweep_blocks

BACKENDS = ["einsum", "blocked_host", "pallas"]


def _mk(dims, rank, seed=0):
    key = jax.random.PRNGKey(seed)
    kx, *kf = jax.random.split(key, len(dims) + 1)
    x = jax.random.normal(kx, dims, jnp.float32)
    fs = [jax.random.normal(k, (d, rank), jnp.float32)
          for k, d in zip(kf, dims)]
    return x, fs


# ---------------------------------------------------------------------------
# mttkrp under compute_dtype
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_mttkrp_bf16_policy(backend):
    """bf16 inputs, fp32 accumulation, output back in the input dtype."""
    dims, rank = (24, 16, 16), 8
    x, fs = _mk(dims, rank, seed=1)
    ref = mttkrp(x, fs, 1, ctx=ExecutionContext.create(backend="einsum"))
    ctx = ExecutionContext.create(
        backend=backend, interpret=True, compute_dtype="bfloat16"
    )
    out = mttkrp(x, fs, 1, ctx=ctx)
    assert out.dtype == jnp.float32  # transparent policy: caller dtype out
    rel = float(
        jnp.linalg.norm(out - ref) / (jnp.linalg.norm(ref) + 1e-30)
    )
    assert rel < 2e-2, (backend, rel)


def test_mttkrp_compute_dtype_explicit_out_dtype():
    """An explicit out_dtype still wins over the transparent default."""
    dims, rank = (16, 12, 8), 4
    x, fs = _mk(dims, rank, seed=2)
    ctx = ExecutionContext.create(
        backend="einsum", compute_dtype="bfloat16", out_dtype="bfloat16"
    )
    out = mttkrp(x, fs, 0, ctx=ctx)
    assert out.dtype == jnp.bfloat16


@pytest.mark.parametrize("backend", ["einsum", "pallas"])
def test_contract_partial_bf16_policy(backend):
    dims, rank = (16, 12, 10), 4
    x, fs = _mk(dims, rank, seed=3)
    ctx32 = ExecutionContext.create(backend="einsum")
    ref = contract_partial(x, fs, (0, 1, 2), (2,), False, ctx=ctx32)
    ctx = ExecutionContext.create(
        backend=backend, interpret=True, compute_dtype="bfloat16"
    )
    out = contract_partial(x, fs, (0, 1, 2), (2,), False, ctx=ctx)
    assert out.dtype == jnp.float32
    rel = float(
        jnp.linalg.norm(out - ref) / (jnp.linalg.norm(ref) + 1e-30)
    )
    assert rel < 2e-2, (backend, rel)


@pytest.mark.parametrize("backend", ["einsum", "pallas"])
def test_multi_ttm_bf16_policy(backend):
    dims, ranks = (16, 12, 10), (4, 3, 2)
    x, _ = _mk(dims, 4, seed=4)
    mats = [
        jax.random.normal(jax.random.PRNGKey(40 + k), (d, r), jnp.float32)
        for k, (d, r) in enumerate(zip(dims, ranks))
    ]
    ref = multi_ttm(x, mats, None, ctx=ExecutionContext.create(
        backend="einsum"))
    ctx = ExecutionContext.create(
        backend=backend, interpret=True, compute_dtype="bfloat16"
    )
    out = multi_ttm(x, mats, None, ctx=ctx)
    assert out.dtype == jnp.float32
    rel = float(
        jnp.linalg.norm(out - ref) / (jnp.linalg.norm(ref) + 1e-30)
    )
    assert rel < 3e-2, (backend, rel)


# ---------------------------------------------------------------------------
# end-to-end: CP-ALS under the policy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sweep", ["per_mode", "fused"])
def test_cp_als_bf16_converges(sweep):
    dims, rank = (16, 14, 12), 3
    x, _ = random_low_rank_tensor(jax.random.PRNGKey(5), dims, rank)
    ctx = ExecutionContext.create(compute_dtype="bfloat16")
    res = cp_als(x, rank, n_iters=12, key=jax.random.PRNGKey(6),
                 sweep=sweep, ctx=ctx)
    # bf16 MTTKRPs with fp32 Gram/solve still converge; the fit plateau
    # reflects bf16's ~3 significant digits, not a policy bug
    assert res.final_fit > 0.93, res.fits
    assert res.final_fit > res.fits[0] + 0.3
    for f in res.factors:
        assert f.dtype == jnp.float32


# ---------------------------------------------------------------------------
# dtype-aware planning
# ---------------------------------------------------------------------------

def test_memory_with_itemsize():
    mem = Memory.tpu_vmem(itemsize=4)
    half = mem.with_itemsize(2)
    assert half.itemsize == 2
    assert half.budget_bytes == mem.budget_bytes
    assert half.lane == mem.lane and half.sublane == mem.sublane
    assert mem.with_itemsize(4) is mem  # no-op returns the same object


def test_narrow_itemsize_admits_wider_blocks():
    """Same byte budget, 2-byte elements: the planner may hold at least
    as many words resident — blocks never shrink, and for a VMEM-bound
    problem they grow."""
    shape, rank = (256, 256, 256), 64
    mem4 = Memory(budget_bytes=1 << 17, itemsize=4)
    mem2 = mem4.with_itemsize(2)
    p4 = choose_blocks(shape, rank, 4, memory=mem4)
    p2 = choose_blocks(shape, rank, 2, memory=mem2)
    words4 = p4.working_set_words()
    words2 = p2.working_set_words()
    assert words2 >= words4
    s4 = choose_sweep_blocks(shape, rank, 4, memory=mem4)
    s2 = choose_sweep_blocks(shape, rank, 2, memory=mem2)
    from repro.engine.plan import fused_pair_working_set_words

    assert fused_pair_working_set_words(s2) >= fused_pair_working_set_words(
        s4
    )


# ---------------------------------------------------------------------------
# context knobs: validation + serialization
# ---------------------------------------------------------------------------

def test_compute_dtype_validation():
    ctx = ExecutionContext.create(compute_dtype="bfloat16")
    assert ctx.compute_dtype == "bfloat16"
    ctx16 = ExecutionContext.create(compute_dtype=jnp.float16)
    assert ctx16.compute_dtype == "float16"
    with pytest.raises(ValueError, match="compute_dtype"):
        ExecutionContext.create(compute_dtype="int32")
    with pytest.raises(ValueError, match="compute_dtype"):
        ExecutionContext.create(compute_dtype="not-a-dtype")


def test_overlap_validation():
    d = Distribution(overlap="ring")
    assert d.overlap == "ring"
    with pytest.raises(ValueError, match="overlap"):
        Distribution(overlap="bogus")


def test_context_roundtrip_compute_dtype_and_overlap():
    ctx = ExecutionContext.create(
        backend="einsum",
        compute_dtype="bfloat16",
        grid=(1, 2, 2),
        overlap="ring",
    )
    ctx2 = ExecutionContext.from_json(ctx.to_json())
    assert ctx2 == ctx and hash(ctx2) == hash(ctx)
    assert ctx2.compute_dtype == "bfloat16"
    assert ctx2.distribution.overlap == "ring"
    assert ctx2.distribution.grid == (1, 2, 2)
    # defaults stay default through the round-trip
    plain = ExecutionContext.create(backend="einsum")
    plain2 = ExecutionContext.from_json(plain.to_json())
    assert plain2.compute_dtype is None
    assert plain2 == plain
