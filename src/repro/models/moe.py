"""Mixture-of-Experts: top-k router + capacity-based dispatch.

Dispatch is FLOP-honest (only top_k × capacity_factor worth of expert
compute, never dense all-experts) and compile-friendly at 512 devices: token
routing uses sort/cumsum/scatter arithmetic with O(T·k) memory — no
(T, E, C) one-hot dispatch tensors.

Sharding policies (sharding.Sharding.moe):
  'expert' — experts sharded over 'tp' (EP); dispatch crosses the mesh via
             GSPMD-inserted all-to-all on the (E, C, D) buffers.
  'ffn'    — expert count kept local, per-expert FFN dim sharded over 'tp'
             (for n_experts % tp != 0, e.g. granite's 40 experts on 16).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import dense_init
from .sharding import NULL, Sharding


def init_moe(key, cfg: ArchConfig, dtype) -> dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], (d, e), dtype=jnp.float32),
        "wi": dense_init(ks[1], (e, d, f), in_axis=1, dtype=dtype),
        "wo": dense_init(ks[2], (e, f, d), in_axis=1, dtype=dtype),
    }
    if cfg.act == "silu_glu":
        p["wg"] = dense_init(ks[3], (e, d, f), in_axis=1, dtype=dtype)
    return p


def _expert_specs(sh: Sharding):
    """(wi_spec, wo_spec) under the active MoE policy."""
    if sh.moe == "expert":
        return ("tp", "fsdp", None), ("tp", None, "fsdp")
    return (None, "fsdp", "tp"), (None, "tp", "fsdp")


def apply_moe(
    p: dict,
    x: jax.Array,
    cfg: ArchConfig,
    sh: Sharding = NULL,
    capacity_factor: float = 1.25,
) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux_loss). Tokens over capacity are dropped
    (standard Switch/GShard semantics; capacity_factor=1.25 default)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xf = x.reshape(t, d)

    # ---- router (f32 for numerics)
    logits = xf.astype(jnp.float32) @ p["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # ---- load-balancing aux loss (Switch-style)
    me = jnp.mean(probs, axis=0)  # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_ids, e, dtype=jnp.float32), axis=1),
        axis=0,
    )
    aux = e * jnp.sum(me * ce)

    # ---- capacity assignment: position of each (token, slot) within its
    # expert's queue, computed with a cumsum over the flattened choices
    # capacity rounded up to 256 so the buffer's cap dim stays shardable
    cap = max((int(t * k * capacity_factor / e) + 255) // 256 * 256, 256)
    flat_expert = expert_ids.reshape(-1)  # (T*k,) row-major: token major
    onehot = jax.nn.one_hot(flat_expert, e, dtype=jnp.int32)  # (T*k, E)
    pos_in_expert = (jnp.cumsum(onehot, axis=0) - onehot) * onehot
    pos_in_expert = jnp.sum(pos_in_expert, axis=-1)  # (T*k,)
    keep = pos_in_expert < cap

    # ---- dispatch: gather tokens into (E, cap, D) buffers via scatter
    buf_idx = jnp.where(keep, flat_expert * cap + pos_in_expert, e * cap)
    token_idx = jnp.repeat(jnp.arange(t), k)
    dispatch = jnp.zeros((e * cap + 1,), jnp.int32).at[buf_idx].set(
        token_idx + 1, mode="drop"
    )[: e * cap]
    # dispatch[j] = 1 + token index occupying buffer slot j (0 = empty)
    xe = jnp.take(
        jnp.concatenate([jnp.zeros((1, d), xf.dtype), xf], axis=0),
        dispatch,
        axis=0,
    ).reshape(e, cap, d)
    cap_axis = "dp" if sh.moe_dispatch == "dp" else None
    xe = sh.constrain(
        xe, "tp" if sh.moe == "expert" else None, cap_axis, None
    )

    # ---- expert FFN (batched over experts)
    wi_spec, wo_spec = _expert_specs(sh)
    wi = sh.constrain(p["wi"], *wi_spec)
    wo = sh.constrain(p["wo"], *wo_spec)
    h = jnp.einsum("ecd,edf->ecf", xe, wi)
    if cfg.act == "silu_glu":
        wg = sh.constrain(p["wg"], *wi_spec)
        g = jnp.einsum("ecd,edf->ecf", xe, wg)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    else:
        h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(h.dtype)
    h = sh.constrain(
        h, "tp" if sh.moe == "expert" else None, cap_axis,
        "tp" if sh.moe == "ffn" else None,
    )
    ye = jnp.einsum("ecf,efd->ecd", h, wo)  # (E, cap, D)
    ye = sh.constrain(
        ye, "tp" if sh.moe == "expert" else None, cap_axis, None
    )

    # ---- combine: scatter-add expert outputs back to tokens, gate-weighted
    flat_ye = ye.reshape(e * cap, d)
    slot_of_choice = jnp.where(keep, flat_expert * cap + pos_in_expert, 0)
    y_choice = jnp.take(flat_ye, slot_of_choice, axis=0)  # (T*k, D)
    w = (gate_vals.reshape(-1) * keep).astype(y_choice.dtype)  # (T*k,)
    y = jnp.sum(
        (y_choice * w[:, None]).reshape(t, k, d), axis=1
    )
    y = y.reshape(b, s, d).astype(x.dtype)
    return sh.constrain(y, "dp", None, None), aux
