"""Top-level language models: decoder-only LM, encoder-decoder (whisper
backbone), with train forward, prefill, and decode-step entry points, plus
parameter PartitionSpec generation for the production meshes."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

from .blocks import (
    apply_stack,
    apply_stack_decode,
    init_stack,
    init_stack_cache,
)
from .config import ArchConfig
from .layers import (
    apply_norm,
    embed_tokens,
    embed_vectors,
    init_embedding,
    init_norm,
    logits as lm_logits,
)
from .sharding import NULL, Sharding

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_params(key, cfg: ArchConfig) -> dict:
    dtype = DTYPES[cfg.dtype]
    ks = jax.random.split(key, 4)
    params: dict[str, Any] = {
        "embed": init_embedding(ks[0], cfg, dtype),
        "final_norm": init_norm(cfg, dtype),
    }
    if cfg.is_encdec:
        params["encoder"] = init_stack(ks[1], cfg, dtype)
        params["enc_norm"] = init_norm(cfg, dtype)
        params["decoder"] = init_stack(
            ks[2], cfg, dtype, n_layers=cfg.dec_layers, cross_attn=True
        )
    else:
        params["blocks"] = init_stack(ks[1], cfg, dtype)
    return params


# --------------------------------------------------------------------------
# forward (train / prefill)
# --------------------------------------------------------------------------

def _inputs_to_hidden(params, cfg, batch, sh):
    if cfg.frontend != "none" or "embeds" in batch:
        return embed_vectors(batch["embeds"], sh)
    return embed_tokens(params["embed"], batch["tokens"], sh)


def forward(
    params: dict,
    cfg: ArchConfig,
    batch: dict,
    sh: Sharding = NULL,
    *,
    mode: str = "train",
    logits_positions: str = "all",  # all | last (prefill serves last only)
) -> tuple[jax.Array, jax.Array]:
    """-> (logits (B, S_dec, V), moe_aux). ``batch`` carries 'tokens' or
    'embeds' (+ 'dec_tokens' for enc-dec), 'positions' optional."""
    x = _inputs_to_hidden(params, cfg, batch, sh)
    b, s = x.shape[:2]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if cfg.is_encdec:
        enc, aux = apply_stack(
            params["encoder"], x, cfg, positions, sh, mode=mode,
            causal=False,
        )
        enc = apply_norm(params["enc_norm"], enc)
        # decoder: teacher-forced tokens
        dec_tokens = batch["dec_tokens"]
        y = embed_tokens(params["embed"], dec_tokens, sh)
        db, ds = y.shape[:2]
        dpos = jnp.broadcast_to(jnp.arange(ds, dtype=jnp.int32), (db, ds))
        # cross K/V from encoder output via each layer's xattn — computed
        # inside the layer from kv_override=(enc-derived K, V). We project
        # here once per layer inside the stack via kv_override of raw enc:
        # simplest faithful backbone: share one projection of enc states.
        x, aux2 = apply_stack(
            params["decoder"], y, cfg, dpos, sh, mode="train",
            causal=True, cross_kv=_encoder_kv(cfg, enc),
        )
        aux = aux + aux2
    else:
        x, aux = apply_stack(
            params["blocks"], x, cfg, positions, sh, mode=mode, causal=True
        )
    x = apply_norm(params["final_norm"], x)
    if logits_positions == "last":
        x = x[:, -1:, :]
    out = lm_logits(params["embed"], x, sh, vocab_size=cfg.vocab_size)
    return out, aux


def _encoder_kv(cfg: ArchConfig, enc: jax.Array):
    """Encoder hidden states reshaped as (B, S, n_kv, hd) K/V stand-ins.

    Backbone stub: cross-attention consumes encoder states directly as
    keys/values (per-layer K/V projections live in xattn's wk/wv applied to
    queries only in this simplified backbone — the x-attn K/V projection is
    folded into the encoder output, a standard inference-time fusion).
    """
    b, s, d = enc.shape
    kv = enc.reshape(b, s, cfg.n_kv_heads, d // cfg.n_kv_heads)
    if kv.shape[-1] != cfg.hd:
        kv = kv[..., : cfg.hd]
    return kv, kv


def loss_fn(
    params: dict,
    cfg: ArchConfig,
    batch: dict,
    sh: Sharding = NULL,
    aux_weight: float = 0.01,
) -> tuple[jax.Array, dict]:
    out, aux = forward(params, cfg, batch, sh, mode="train")
    labels = batch.get("dec_labels" if cfg.is_encdec else "labels")
    out = out.astype(jnp.float32)
    logz = jax.nn.logsumexp(out, axis=-1)
    gold = jnp.take_along_axis(out, labels[..., None], axis=-1)[..., 0]
    nll = jnp.mean(logz - gold)
    total = nll + aux_weight * aux
    return total, {"nll": nll, "aux": aux}


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------

def init_decode_state(
    params: dict, cfg: ArchConfig, batch: int, max_len: int
) -> dict:
    dtype = DTYPES[cfg.dtype]
    stack = params["decoder"] if cfg.is_encdec else params["blocks"]
    return {
        "caches": init_stack_cache(stack, cfg, batch, max_len, dtype),
    }


def decode_step(
    params: dict,
    cfg: ArchConfig,
    state: dict,
    tokens: jax.Array,  # (B, 1) int32
    sh: Sharding = NULL,
    cross_kv: tuple | None = None,
) -> tuple[jax.Array, dict]:
    """One serving step: next-token logits + updated caches."""
    x = embed_tokens(params["embed"], tokens, sh)
    stack = params["decoder"] if cfg.is_encdec else params["blocks"]
    x, caches = apply_stack_decode(
        stack, state["caches"], x, cfg, sh, cross_kv=cross_kv
    )
    x = apply_norm(params["final_norm"], x)
    out = lm_logits(params["embed"], x, sh, vocab_size=cfg.vocab_size)
    return out, {"caches": caches}


# --------------------------------------------------------------------------
# parameter partition specs
# --------------------------------------------------------------------------

def _leaf_spec(path, leaf, cfg: ArchConfig, sh: Sharding) -> P:
    names = [p.key for p in path if isinstance(p, DictKey)]
    in_stack = any(
        isinstance(p, SequenceKey) for p in path
    ) or names[0] in ("blocks", "encoder", "decoder")
    last = names[-1]
    parent = names[-2] if len(names) > 1 else ""
    head_tp = (
        sh.attn == "head_tp"
        and cfg.n_heads % max(sh.tp_size, 1) == 0
    )

    def mk(*dims):
        spec = sh.spec(*dims)
        if in_stack:
            return P(None, *spec)  # leading n_groups dim
        return spec

    if parent == "embed":
        return mk("tp", "fsdp") if last == "table" else mk("fsdp", "tp")
    if last in ("scale", "bias", "A_log", "D", "dt_bias", "norm_scale"):
        return mk(None)
    if parent in ("attn", "xattn"):
        if last in ("wq", "wk", "wv"):
            heads = cfg.n_heads if last == "wq" else cfg.n_kv_heads
            if head_tp and heads % max(sh.tp_size, 1) == 0:
                return mk("fsdp", "tp", None)
            return mk(("fsdp", "tp"), None, None)
        if last == "wo":
            if head_tp:
                return mk("tp", None, "fsdp")
            return mk(None, None, ("fsdp", "tp"))  # (H, hd, d): shard d
        return mk(None, None)  # biases (H, hd)
    if parent == "mlp":
        return mk("fsdp", "tp") if last in ("wi", "wg") else mk("tp", "fsdp")
    if parent == "moe":
        if last == "router":
            return mk("fsdp", None)
        if sh.moe == "expert":
            return (
                mk("tp", "fsdp", None) if last in ("wi", "wg")
                else mk("tp", None, "fsdp")
            )
        return (
            mk(None, "fsdp", "tp") if last in ("wi", "wg")
            else mk(None, "tp", "fsdp")
        )
    if parent == "ssm":
        if last in ("wz", "wx"):
            return mk("fsdp", "tp")
        if last == "wo":
            return mk("tp", "fsdp")
        if last in ("wB", "wC", "wdt"):
            return mk("fsdp", None)
        if last == "conv_w":
            return mk(None, None)
    return mk(*([None] * leaf.ndim)) if not in_stack else P(
        *([None] * leaf.ndim)
    )


def param_specs(params: dict, cfg: ArchConfig, sh: Sharding):
    """PartitionSpec pytree matching ``params`` (for jit in_shardings).

    Per-dim divisibility is enforced via sh.fit_spec (small models on big
    meshes back off to feasible axis prefixes)."""
    if sh.mesh is None:
        return jax.tree.map(lambda _: P(), params)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: sh.fit_spec(
            leaf.shape, _leaf_spec(path, leaf, cfg, sh)
        ),
        params,
    )


def cache_specs(state: dict, cfg: ArchConfig, sh: Sharding):
    """PartitionSpecs for decode caches: KV over (dp batch, sp seq);
    SSM state over (dp, tp heads). Type-driven (caches are typed tuples)."""
    from .attention import KVCache
    from .ssm import SSMCache

    if sh.mesh is None:
        return jax.tree.map(lambda _: P(), state)

    specs = []
    for c in state["caches"]:
        if isinstance(c, KVCache):
            specs.append(
                KVCache(
                    k=P(None, *sh.spec("dp", "sp", None, None)),
                    v=P(None, *sh.spec("dp", "sp", None, None)),
                    length=P(None),
                )
            )
        elif isinstance(c, SSMCache):
            specs.append(
                SSMCache(
                    conv=P(None, *sh.spec("dp", None, None)),
                    state=P(None, *sh.spec("dp", "tp", None, None)),
                    length=P(None),
                )
            )
        else:  # pragma: no cover
            specs.append(jax.tree.map(lambda _: P(), c))
    return {"caches": specs}
