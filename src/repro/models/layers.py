"""Shared neural layers: norms, rotary embeddings, token embedding/logits,
MLP variants. Pure functions over param dicts; f32 where numerically
sensitive, bf16 elsewhere (dtype policy from the config)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .sharding import NULL, Sharding


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------

def dense_init(key, shape, in_axis=-2, dtype=jnp.bfloat16):
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.bfloat16):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def init_norm(cfg: ArchConfig, dtype) -> dict:
    p = {"scale": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def apply_norm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(
            jnp.float32
        )
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return out.astype(dtype)


# --------------------------------------------------------------------------
# rotary embeddings (RoPE + M-RoPE)
# --------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, jnp.float32) / hd))


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float, mrope: bool = False
) -> jax.Array:
    """x: (..., S, H, hd); positions: (..., S) int32, or (..., S, 3) for
    M-RoPE (temporal/height/width sections — text uses identical triple,
    which reduces exactly to standard RoPE)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    if mrope:
        if positions.ndim == x.ndim - 2:  # text-only: expand to 3 sections
            positions = jnp.stack([positions] * 3, axis=-1)
        # split frequency bands into 3 sections (t/h/w), qwen2-vl style
        n = freqs.shape[0]
        s1, s2 = n // 3, 2 * n // 3
        section = jnp.concatenate(
            [
                jnp.zeros((s1,), jnp.int32),
                jnp.ones((s2 - s1,), jnp.int32),
                jnp.full((n - s2,), 2, jnp.int32),
            ]
        )
        pos = jnp.take_along_axis(
            positions.astype(jnp.float32),
            jnp.broadcast_to(
                section[None, None], positions.shape[:-1] + (n,)
            ).astype(jnp.int32),
            axis=-1,
        )  # (..., S, hd/2): per-band position
        angles = pos[..., None, :] * freqs  # (..., S, 1, hd/2)
    else:
        angles = positions[..., None, None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# embedding + logits
# --------------------------------------------------------------------------

def init_embedding(key, cfg: ArchConfig, dtype) -> dict:
    v = cfg.padded_vocab
    p = {"table": embed_init(key, (v, cfg.d_model), dtype)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(
            jax.random.fold_in(key, 1), (cfg.d_model, v), dtype=dtype
        )
    return p


def embed_tokens(p: dict, ids: jax.Array, sh: Sharding = NULL) -> jax.Array:
    table = sh.constrain(p["table"], "tp", "fsdp")
    out = jnp.take(table, ids, axis=0)
    return sh.constrain(out, "dp", None, None)


def embed_vectors(x: jax.Array, sh: Sharding = NULL) -> jax.Array:
    """Stub-frontend path: inputs are already (B, S, D) embeddings."""
    return sh.constrain(x, "dp", None, None)


def logits(
    p: dict, x: jax.Array, sh: Sharding = NULL, vocab_size: int | None = None
) -> jax.Array:
    head = p.get("head")
    if head is None:
        head = p["table"].T
    head = sh.constrain(head, "fsdp", "tp")
    out = jnp.einsum("bsd,dv->bsv", x, head)
    v_pad = head.shape[-1]
    if vocab_size is not None and vocab_size < v_pad:
        # mask padded vocab rows so softmax/argmax never see them
        mask = jnp.arange(v_pad) < vocab_size
        out = jnp.where(mask, out, jnp.asarray(-1e30, out.dtype))
    return sh.constrain(out, "dp", None, "tp")


# --------------------------------------------------------------------------
# MLP variants
# --------------------------------------------------------------------------

def init_mlp(key, cfg: ArchConfig, d_ff: int, dtype) -> dict:
    d = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.act == "silu_glu":
        return {
            "wi": dense_init(k1, (d, d_ff), dtype=dtype),
            "wg": dense_init(k2, (d, d_ff), dtype=dtype),
            "wo": dense_init(k3, (d_ff, d), dtype=dtype),
        }
    return {
        "wi": dense_init(k1, (d, d_ff), dtype=dtype),
        "wo": dense_init(k3, (d_ff, d), dtype=dtype),
    }


def apply_mlp(
    p: dict, x: jax.Array, cfg: ArchConfig, sh: Sharding = NULL
) -> jax.Array:
    wi = sh.constrain(p["wi"], "fsdp", "tp")
    wo = sh.constrain(p["wo"], "tp", "fsdp")
    h = jnp.einsum("bsd,df->bsf", x, wi)
    h = sh.constrain(h, "dp", None, "tp")
    if cfg.act == "silu_glu":
        wg = sh.constrain(p["wg"], "fsdp", "tp")
        g = jnp.einsum("bsd,df->bsf", x, wg)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    elif cfg.act == "sq_relu":
        h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(h.dtype)
    else:  # gelu
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(h.dtype)
    out = jnp.einsum("bsf,fd->bsd", h, wo)
    return sh.constrain(out, "dp", None, None)
