"""Mamba2 SSD (state-space duality) block: chunked parallel form for
train/prefill, O(1)-state recurrent form for decode.

Math (per head, head_dim P, state N):
    h_t = exp(Δ_t A) · h_{t-1} + Δ_t · B_t x_tᵀ      h ∈ R^{N×P}
    y_t = C_tᵀ h_t + D · x_t
Chunked SSD (chunk Q): intra-chunk quadratic term (C B^T ⊙ causal-decay
mask) X, plus inter-chunk state carried by a lax.scan — O(S·Q + S·N·P)
instead of O(S²) attention.

Jamba note (DESIGN.md §4): Jamba v0.1's Mamba-1 layers are realized with
the same SSD formulation at its dimensions (the selective-scan recurrence
is the P=1 special case; we use the head-grouped equivalent).

Sharding: heads over 'tp' (80/16=5 for mamba2-2.7b, 128/16=8 for jamba);
B/C are group-shared (ngroups=1) and replicated across tp.
"""

from __future__ import annotations

import os
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import dense_init
from .sharding import NULL, Sharding

# §Perf hillclimb lever (EXPERIMENTS.md): lean SSD — bf16 decay tensors +
# 3-operand einsums that avoid materializing the (B,nc,q,H,N) Δ-scaled
# factors. Off by default (baseline = paper-faithful einsum SSD).
_LEAN = os.environ.get("REPRO_SSD_LEAN") == "1"


class SSMCache(NamedTuple):
    conv: jax.Array   # (B, conv_w-1, conv_channels) rolling window
    state: jax.Array  # (B, H, N, P) ssm state
    length: jax.Array


def init_ssm(key, cfg: ArchConfig, dtype) -> dict:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    h, p_dim = cfg.ssm_heads, cfg.ssm_head_dim
    conv_ch = di + 2 * n
    ks = jax.random.split(key, 8)
    return {
        "wz": dense_init(ks[0], (d, di), dtype=dtype),
        "wx": dense_init(ks[1], (d, di), dtype=dtype),
        "wB": dense_init(ks[2], (d, n), dtype=dtype),
        "wC": dense_init(ks[3], (d, n), dtype=dtype),
        "wdt": dense_init(ks[4], (d, h), dtype=dtype),
        "conv_w": (jax.random.normal(ks[5], (cfg.ssm_conv, conv_ch),
                                     jnp.float32) * 0.1).astype(dtype),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm_scale": jnp.ones((di,), dtype),
        "wo": dense_init(ks[6], (di, d), dtype=dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv via shifted adds. x: (B, S, C); w: (W, C)."""
    width = w.shape[0]
    out = x * w[-1]
    for i in range(1, width):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1], :]
        out = out + shifted * w[-1 - i]
    return out


def _gated_norm(y: jax.Array, z: jax.Array, scale: jax.Array,
                eps: float = 1e-5) -> jax.Array:
    dtype = y.dtype
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(
        dtype
    )


def apply_ssm(
    p: dict, x: jax.Array, cfg: ArchConfig, sh: Sharding = NULL
) -> jax.Array:
    """Chunked SSD forward. x: (B, S, D) -> (B, S, D). S % chunk == 0."""
    b, s, d = x.shape
    h, pd, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    q = min(cfg.ssm_chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    z = jnp.einsum("bsd,de->bse", x, sh.constrain(p["wz"], "fsdp", "tp"))
    xin = jnp.einsum("bsd,de->bse", x, sh.constrain(p["wx"], "fsdp", "tp"))
    bmat = jnp.einsum("bsd,dn->bsn", x, p["wB"])
    cmat = jnp.einsum("bsd,dn->bsn", x, p["wC"])
    dt = jnp.einsum("bsd,dh->bsh", x, p["wdt"]).astype(jnp.float32)

    # causal depthwise conv over (x, B, C)
    conv_in = jnp.concatenate([xin, bmat, cmat], axis=-1)
    conv_out = jax.nn.silu(
        _causal_conv(conv_in, p["conv_w"]).astype(jnp.float32)
    ).astype(x.dtype)
    xin = conv_out[..., : cfg.d_inner]
    bmat = conv_out[..., cfg.d_inner: cfg.d_inner + n]
    cmat = conv_out[..., cfg.d_inner + n:]

    xh = xin.reshape(b, s, h, pd)
    xh = sh.constrain(xh, "dp", None, "tp", None)
    dt = jax.nn.softplus(dt + p["dt_bias"])  # (B, S, H)
    a = -jnp.exp(p["A_log"])  # (H,) negative
    log_decay = dt * a  # (B, S, H) log a_t, <= 0

    # chunk views (head dim sharded over tp so the (B,nc,q,q,H) intra-chunk
    # decay tensor below is partitioned, not replicated)
    xc = sh.constrain(xh.reshape(b, nc, q, h, pd), "dp", None, None, "tp",
                      None)
    bc = bmat.reshape(b, nc, q, n).astype(jnp.float32)
    cc = cmat.reshape(b, nc, q, n).astype(jnp.float32)
    dtc = sh.constrain(dt.reshape(b, nc, q, h), "dp", None, None, "tp")
    ld = sh.constrain(log_decay.reshape(b, nc, q, h), "dp", None, None, "tp")
    cum = jnp.cumsum(ld, axis=2)  # within-chunk cumulative log decay

    # ---- intra-chunk (quadratic in q): Y[i] += Σ_{j<=i} C_i·B_j decay Δ_j x_j
    gmat = jnp.einsum("bcin,bcjn->bcij", cc, bc)  # (B, nc, q, q)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,i,j,H)
    causal = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    if _LEAN:
        # Δ folded into X once ((B,nc,q,H,P) — same size as xc); decay kept
        # bf16; 3-operand einsums skip the (B,nc,q,q,H) w_ij f32 chain
        xc_dt = (xc.astype(jnp.float32) * dtc[..., None]).astype(x.dtype)
        y_intra = jnp.einsum(
            "bcij,bcijh,bcjhp->bcihp",
            gmat.astype(x.dtype),
            decay.astype(x.dtype),
            xc_dt,
            optimize="optimal",
        )
    else:
        w_ij = gmat[..., None] * decay * dtc[:, :, None, :, :]  # (B,nc,i,j,H)
        w_ij = sh.constrain(w_ij, "dp", None, None, None, "tp")
        y_intra = jnp.einsum(
            "bcijh,bcjhp->bcihp", w_ij.astype(x.dtype), xc
        )
    y_intra = sh.constrain(y_intra, "dp", None, None, "tp", None)

    # ---- chunk states: S_c = Σ_j decay_to_end_j Δ_j B_j x_jᵀ  (B,nc,H,N,P)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,nc,q,H)
    if _LEAN:
        s_c = jnp.einsum(
            "bcjn,bcjh,bcjhp->bchnp",
            bc.astype(x.dtype),
            decay_to_end.astype(x.dtype),
            xc_dt,
            optimize="optimal",
        )
    else:
        sb = bc[:, :, :, None, :] * (dtc * decay_to_end)[..., None]
        s_c = jnp.einsum("bcjhn,bcjhp->bchnp", sb.astype(x.dtype), xc)

    # ---- inter-chunk recurrence (scan over chunks)
    total = jnp.exp(cum[:, :, -1, :])  # (B, nc, H) full-chunk decay

    def step(hprev, inp):
        s_chunk, tot = inp  # (B,H,N,P), (B,H)
        hnew = hprev * tot[..., None, None] + s_chunk.astype(jnp.float32)
        return hnew, hprev

    h0 = jnp.zeros((b, h, n, pd), jnp.float32)
    _, h_before = jax.lax.scan(
        step, h0,
        (s_c.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)),
    )
    h_before = h_before.transpose(1, 0, 2, 3, 4)  # (B,nc,H,N,P) state entering chunk

    # ---- inter-chunk output: y += (C_i decay_from_start_i) · h_before
    decay_from_start = jnp.exp(cum)  # (B,nc,q,H)
    if _LEAN:
        y_inter = jnp.einsum(
            "bcin,bcih,bchnp->bcihp",
            cc.astype(x.dtype),
            decay_from_start.astype(x.dtype),
            h_before.astype(x.dtype),
            optimize="optimal",
        )
    else:
        cd = cc[:, :, :, None, :] * decay_from_start[..., None]
        y_inter = jnp.einsum(
            "bcihn,bchnp->bcihp", cd.astype(x.dtype),
            h_before.astype(x.dtype)
        )

    y = (y_intra + y_inter).reshape(b, s, h, pd)
    y = y + xh * p["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(b, s, cfg.d_inner)
    y = _gated_norm(y, z, p["norm_scale"])
    out = jnp.einsum("bse,ed->bsd", y, sh.constrain(p["wo"], "tp", "fsdp"))
    return sh.constrain(out, "dp", None, None)


def init_ssm_cache(cfg: ArchConfig, batch: int, dtype) -> SSMCache:
    conv_ch = cfg.d_inner + 2 * cfg.ssm_state
    return SSMCache(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
        state=jnp.zeros(
            (batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
            jnp.float32,
        ),
        length=jnp.zeros((), jnp.int32),
    )


def apply_ssm_decode(
    p: dict,
    x: jax.Array,
    cache: SSMCache,
    cfg: ArchConfig,
    sh: Sharding = NULL,
) -> tuple[jax.Array, SSMCache]:
    """Single-token recurrent step. x: (B, 1, D)."""
    b, one, d = x.shape
    h, pd, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z = jnp.einsum("bsd,de->bse", x, p["wz"])[:, 0]
    xin = jnp.einsum("bsd,de->bse", x, p["wx"])[:, 0]
    bvec = jnp.einsum("bsd,dn->bsn", x, p["wB"])[:, 0]
    cvec = jnp.einsum("bsd,dn->bsn", x, p["wC"])[:, 0]
    dt = jnp.einsum("bsd,dh->bsh", x, p["wdt"])[:, 0].astype(jnp.float32)

    conv_in = jnp.concatenate([xin, bvec, cvec], axis=-1)  # (B, C)
    window = jnp.concatenate([cache.conv, conv_in[:, None, :]], axis=1)
    w = p["conv_w"]  # (W, C)
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                          w.astype(jnp.float32))
    conv_out = jax.nn.silu(conv_out).astype(x.dtype)
    xin = conv_out[:, : cfg.d_inner]
    bvec = conv_out[:, cfg.d_inner: cfg.d_inner + n].astype(jnp.float32)
    cvec = conv_out[:, cfg.d_inner + n:].astype(jnp.float32)

    xh = xin.reshape(b, h, pd).astype(jnp.float32)
    dt = jax.nn.softplus(dt + p["dt_bias"])  # (B, H)
    decay = jnp.exp(dt * -jnp.exp(p["A_log"]))  # (B, H)
    state = cache.state * decay[..., None, None] + (
        bvec[:, None, :, None] * (dt[..., None] * xh)[:, :, None, :]
    )  # (B,H,N,P)
    y = jnp.einsum("bn,bhnp->bhp", cvec, state)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(b, cfg.d_inner).astype(x.dtype)
    y = _gated_norm(y, z, p["norm_scale"])
    out = jnp.einsum("be,ed->bd", y, p["wo"])[:, None, :]
    new_cache = SSMCache(window[:, 1:, :], state, cache.length + 1)
    return sh.constrain(out, "dp", None, None), new_cache
