"""GQA attention: train/prefill (full causal) and single-token decode with
KV cache. One implementation, two sharding policies (DESIGN.md §5):

  head_tp  — q/kv heads sharded over 'tp' (kv replicated when
             n_kv_heads < tp, the standard Megatron GQA treatment);
  context  — heads intact, *sequence* sharded over 'tp' for the attention
             math (context parallelism) — used when n_heads % tp != 0
             (yi-34b/deepseek 56H, granite 24H, qwen2 12H, whisper 6H on a
             16-way model axis).

Decode KV caches are sharded over the sequence axis ('sp'); the softmax and
PV contractions over the sharded axis lower to the flash-decoding pattern
(local max/sum + small cross-shard reductions) under GSPMD.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import apply_rope, dense_init
from .sharding import NULL, Sharding


class KVCache(NamedTuple):
    k: jax.Array  # (B, S_max, n_kv, hd)
    v: jax.Array  # (B, S_max, n_kv, hd)
    length: jax.Array  # () int32 — filled prefix length


def init_attn(key, cfg: ArchConfig, dtype) -> dict:
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, cfg.n_heads, hd), in_axis=0, dtype=dtype),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads, hd), in_axis=0, dtype=dtype),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads, hd), in_axis=0, dtype=dtype),
        "wo": dense_init(ks[3], (cfg.n_heads, hd, d), in_axis=0, dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads, hd), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads, hd), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads, hd), dtype)
    return p


def _proj_spec(sh: Sharding, heads: int):
    """Weight spec for (d, H, hd) projections under the active policy."""
    if sh.attn == "head_tp" and heads % max(sh.tp_size, 1) == 0:
        return ("fsdp", "tp", None)
    return (("fsdp", "tp"), None, None)  # context: fully FSDP, heads intact


def _qkv(p: dict, cfg: ArchConfig, x: jax.Array, sh: Sharding):
    wq = sh.constrain(p["wq"], *_proj_spec(sh, cfg.n_heads))
    wk = sh.constrain(p["wk"], *_proj_spec(sh, cfg.n_kv_heads))
    wv = sh.constrain(p["wv"], *_proj_spec(sh, cfg.n_kv_heads))
    q = jnp.einsum("bsd,dhk->bshk", x, wq)
    k = jnp.einsum("bsd,dhk->bshk", x, wk)
    v = jnp.einsum("bsd,dhk->bshk", x, wv)
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return q, k, v


def _act_specs(sh: Sharding, cfg: ArchConfig):
    """(q_spec, kv_spec) activation constraints for (B, S, H, hd)."""
    if sh.attn == "head_tp":
        q_spec = ("dp", None, "tp", None)
        kv_spec = (
            ("dp", None, "tp", None)
            if cfg.n_kv_heads % max(sh.tp_size, 1) == 0
            else ("dp", None, None, None)  # kv replicated across tp
        )
    else:  # context parallel: shard the sequence
        q_spec = ("dp", "sp", None, None)
        kv_spec = ("dp", None, None, None)
    return q_spec, kv_spec


def attention(
    p: dict,
    x: jax.Array,
    cfg: ArchConfig,
    positions: jax.Array,
    sh: Sharding = NULL,
    *,
    causal: bool = True,
    kv_override: tuple[jax.Array, jax.Array] | None = None,
) -> jax.Array:
    """Full (train/prefill) attention. x: (B, S, D) -> (B, S, D).

    ``kv_override`` supplies encoder K/V for cross-attention (no RoPE).
    """
    b, s, d = x.shape
    q, k, v = _qkv(p, cfg, x, sh)
    if kv_override is not None:
        k, v = kv_override
        causal = False
    else:
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope) \
        if kv_override is None else q
    q_spec, kv_spec = _act_specs(sh, cfg)
    q = sh.constrain(q, *q_spec)
    k = sh.constrain(k, *kv_spec)
    v = sh.constrain(v, *kv_spec)

    # expand KV to full heads (keeps the head axis TP-shardable even when
    # n_kv_heads < tp — the grouped (kv, g) form would force replication)
    groups = cfg.n_heads // max(cfg.n_kv_heads, 1)
    if groups > 1:
        k = jnp.repeat(k, groups, axis=2)
        v = jnp.repeat(v, groups, axis=2)
        k = sh.constrain(k, *q_spec)
        v = sh.constrain(v, *q_spec)
    scale = cfg.hd ** -0.5
    scores = jnp.einsum("bqhk,bshk->bhqs", q, k) * scale
    scores = scores.astype(jnp.float32)
    if causal:
        sk = k.shape[1]
        mask = positions[:, None, :, None] >= jnp.arange(sk)[
            None, None, None, :
        ]
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqs,bshk->bqhk", probs, v)
    wo = sh.constrain(
        p["wo"],
        *(("tp", None, "fsdp") if sh.attn == "head_tp"
          and cfg.n_heads % max(sh.tp_size, 1) == 0
          else (None, None, ("fsdp", "tp"))),
    )
    y = jnp.einsum("bshk,hkd->bsd", out, wo)
    return sh.constrain(y, "dp", None, None)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    positions: jax.Array,
    cfg: ArchConfig,
    *,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    causal: bool = True,
) -> jax.Array:
    """Double-blocked streaming-softmax attention (pure JAX, lax.scan).

    Used for long-sequence *prefill* (no-grad): per-step score blocks are
    (B, kv, g, q_chunk, kv_chunk) instead of (…, S, S) — memory O(S·chunk)
    not O(S²). q: (B, Sq, H, hd); k/v: (B, Sk, n_kv, hd).
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    groups = h // max(cfg.n_kv_heads, 1)
    scale = hd ** -0.5
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    nq, nk = sq // q_chunk, sk // kv_chunk
    assert sq % q_chunk == 0 and sk % kv_chunk == 0

    qg = q.reshape(b, nq, q_chunk, h, hd)
    kc = k.reshape(b, nk, kv_chunk, cfg.n_kv_heads, hd)
    vc = v.reshape(b, nk, kv_chunk, cfg.n_kv_heads, hd)
    pos_q = positions.reshape(b, nq, q_chunk)
    kv_pos = jnp.arange(sk).reshape(nk, kv_chunk)

    def q_step(_, qi):
        q_blk, posq = qi  # (b, qc, h, hd), (b, qc)

        def kv_step(carry, ki):
            m, denom, acc = carry
            k_blk, v_blk, posk = ki
            if groups > 1:  # expand KV per chunk (head axis TP-shardable)
                k_blk = jnp.repeat(k_blk, groups, axis=2)
                v_blk = jnp.repeat(v_blk, groups, axis=2)
            s = jnp.einsum("bqhk,bshk->bhqs", q_blk, k_blk) * scale
            s = s.astype(jnp.float32)
            if causal:
                mask = posq[:, None, :, None] >= posk[None, None, None, :]
                s = jnp.where(mask, s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            if causal:
                # fully-masked rows (kv block after q block) must contribute
                # exactly zero — exp(-1e30 - (-1e30)) would give 1
                p = p * mask.astype(p.dtype)
            corr = jnp.exp(m - m_new)
            denom_new = corr * denom + jnp.sum(p, axis=-1)
            pv = jnp.einsum(
                "bhqs,bshk->bqhk", p.astype(v_blk.dtype), v_blk
            ).astype(jnp.float32)
            acc_new = corr.transpose(0, 2, 1)[..., None] * acc + pv
            return (m_new, denom_new, acc_new), None

        m0 = jnp.full((b, h, q_chunk), -jnp.inf, jnp.float32)
        d0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, q_chunk, h, hd), jnp.float32)
        (m, denom, acc), _ = jax.lax.scan(
            kv_step, (m0, d0, a0),
            (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4),
             kv_pos),
        )
        out = acc / jnp.maximum(denom, 1e-30).transpose(0, 2, 1)[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(
        q_step, None,
        (qg.transpose(1, 0, 2, 3, 4), pos_q.transpose(1, 0, 2)),
    )
    # outs: (nq, b, qc, h, hd) -> (b, sq, h, hd)
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)


def attention_prefill(
    p: dict,
    x: jax.Array,
    cfg: ArchConfig,
    positions: jax.Array,
    sh: Sharding = NULL,
    *,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Prefill: flash attention + returns (output, (k, v)) for cache fill."""
    b, s, d = x.shape
    q, k, v = _qkv(p, cfg, x, sh)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope)
    q_spec, kv_spec = _act_specs(sh, cfg)
    q = sh.constrain(q, *q_spec)
    k = sh.constrain(k, *kv_spec)
    v = sh.constrain(v, *kv_spec)
    out = flash_attention(
        q, k, v, positions, cfg, q_chunk=q_chunk, kv_chunk=kv_chunk
    )
    wo = sh.constrain(
        p["wo"],
        *(("tp", None, "fsdp") if sh.attn == "head_tp"
          and cfg.n_heads % max(sh.tp_size, 1) == 0
          else (None, None, ("fsdp", "tp"))),
    )
    y = jnp.einsum("bshk,hkd->bsd", out, wo)
    return sh.constrain(y, "dp", None, None), (k, v)


def init_cache(
    cfg: ArchConfig, batch: int, max_len: int, dtype
) -> KVCache:
    shape = (batch, max_len, cfg.n_kv_heads, cfg.hd)
    return KVCache(
        k=jnp.zeros(shape, dtype),
        v=jnp.zeros(shape, dtype),
        length=jnp.zeros((), jnp.int32),
    )


def cache_spec(cfg: ArchConfig, sh: Sharding):
    """KV cache sharding: sequence-sharded ('sp') by default — the flash-
    decoding layout — falling back to head sharding when configured."""
    if sh.decode_cache == "heads" and cfg.n_kv_heads % max(sh.tp_size, 1) == 0:
        return ("dp", None, "tp", None)
    return ("dp", "sp", None, None)


def attention_decode(
    p: dict,
    x: jax.Array,
    cache: KVCache,
    cfg: ArchConfig,
    sh: Sharding = NULL,
) -> tuple[jax.Array, KVCache]:
    """One-token decode. x: (B, 1, D); cache holds `length` valid entries.

    The new K/V is written at position `length`; attention runs over the
    full cache with a validity mask (positions >= length masked out).
    """
    b, one, d = x.shape
    assert one == 1
    pos = jnp.full((b, 1), cache.length, jnp.int32)
    q, k_new, v_new = _qkv(p, cfg, x, sh)
    q = apply_rope(q, pos, cfg.rope_theta, cfg.mrope)
    k_new = apply_rope(k_new, pos, cfg.rope_theta, cfg.mrope)

    spec = cache_spec(cfg, sh)
    ck = sh.constrain(
        jax.lax.dynamic_update_slice(
            cache.k, k_new.astype(cache.k.dtype), (0, cache.length, 0, 0)
        ),
        *spec,
    )
    cv = sh.constrain(
        jax.lax.dynamic_update_slice(
            cache.v, v_new.astype(cache.v.dtype), (0, cache.length, 0, 0)
        ),
        *spec,
    )
    groups = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(b, 1, cfg.n_kv_heads, groups, cfg.hd)
    scale = cfg.hd ** -0.5
    scores = jnp.einsum("bqhgk,bshk->bhgqs", qg, ck) * scale
    scores = scores.astype(jnp.float32)
    valid = jnp.arange(ck.shape[1])[None, None, None, None, :] <= cache.length
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhgqs,bshk->bqhgk", probs, cv)
    out = out.reshape(b, 1, cfg.n_heads, cfg.hd)
    wo = p["wo"]
    y = jnp.einsum("bshk,hkd->bsd", out, wo)
    y = sh.constrain(y, "dp", None, None)
    return y, KVCache(ck, cv, cache.length + 1)
