"""Layer blocks + scan-over-layers stacking.

Layers are grouped into repeating *periods* (hybrid archs interleave
attention/SSM/MoE on a fixed pattern; dense archs have period 1). Parameters
for each period position are stacked across the n_layers/period repeats and
the stack is driven by lax.scan — HLO size stays one period regardless of
depth (96-layer nemotron compiles as fast as a 2-layer toy), which is what
makes 80 dry-run compiles on one CPU feasible and is standard practice at
scale (MaxText-style).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .attention import (
    attention,
    attention_decode,
    attention_prefill,
    init_attn,
    init_cache,
)
from .config import ArchConfig
from .layers import apply_mlp, apply_norm, init_mlp, init_norm
from .moe import apply_moe, init_moe
from .sharding import NULL, Sharding
from .ssm import (
    apply_ssm,
    apply_ssm_decode,
    init_ssm,
    init_ssm_cache,
)


def layer_kind(cfg: ArchConfig, layer: int) -> tuple[str, str]:
    """(mixer, ffn) kind for a layer index: ('attn'|'ssm', 'moe'|'mlp'|'')."""
    mixer = "attn" if cfg.is_attn_layer(layer) else "ssm"
    if cfg.is_moe_layer(layer):
        ffn = "moe"
    elif cfg.d_ff:
        ffn = "mlp"
    else:
        ffn = ""
    return mixer, ffn


def init_layer(key, cfg: ArchConfig, layer: int, dtype,
               cross_attn: bool = False) -> dict:
    mixer, ffn = layer_kind(cfg, layer)
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {"norm1": init_norm(cfg, dtype)}
    if mixer == "attn":
        p["attn"] = init_attn(ks[0], cfg, dtype)
    else:
        p["ssm"] = init_ssm(ks[0], cfg, dtype)
    if cross_attn:
        p["norm_x"] = init_norm(cfg, dtype)
        p["xattn"] = init_attn(ks[1], cfg, dtype)
    if ffn:
        p["norm2"] = init_norm(cfg, dtype)
        if ffn == "moe":
            p["moe"] = init_moe(ks[2], cfg, dtype)
        else:
            p["mlp"] = init_mlp(ks[2], cfg, cfg.d_ff, dtype)
    return p


def apply_layer(
    p: dict,
    x: jax.Array,
    cfg: ArchConfig,
    layer: int,
    positions: jax.Array,
    sh: Sharding = NULL,
    *,
    mode: str = "train",            # train | prefill
    causal: bool = True,
    cross_kv: tuple | None = None,  # encoder K/V for cross-attention
) -> tuple[jax.Array, jax.Array]:
    """Returns (x_out, moe_aux_loss)."""
    mixer, ffn = layer_kind(cfg, layer)
    h = apply_norm(p["norm1"], x)
    if mixer == "attn":
        if mode == "prefill":
            a, _ = attention_prefill(p["attn"], h, cfg, positions, sh)
        else:
            a = attention(p["attn"], h, cfg, positions, sh, causal=causal)
    else:
        a = apply_ssm(p["ssm"], h, cfg, sh)
    x = x + a
    if cross_kv is not None:
        hx = apply_norm(p["norm_x"], x)
        a = attention(
            p["xattn"], hx, cfg, positions, sh, kv_override=cross_kv
        )
        x = x + a
    aux = jnp.zeros((), jnp.float32)
    if ffn:
        h = apply_norm(p["norm2"], x)
        if ffn == "moe":
            f, aux = apply_moe(p["moe"], h, cfg, sh)
        else:
            f = apply_mlp(p["mlp"], h, cfg, sh)
        x = x + f
    return x, aux


# --------------------------------------------------------------------------
# stacked periods + scan
# --------------------------------------------------------------------------

def init_stack(key, cfg: ArchConfig, dtype, n_layers: int | None = None,
               cross_attn: bool = False) -> list:
    """Params for a stack of layers, grouped as period-position pytrees with
    leaves stacked over the n_groups repeats: params[pos][leaf] has leading
    dim n_groups."""
    n_layers = n_layers if n_layers is not None else cfg.n_layers
    period = cfg.block_period
    assert n_layers % period == 0, (n_layers, period)
    n_groups = n_layers // period
    positions = []
    for pos in range(period):
        reps = []
        for g in range(n_groups):
            layer = g * period + pos
            reps.append(
                init_layer(
                    jax.random.fold_in(key, layer), cfg, layer, dtype,
                    cross_attn=cross_attn,
                )
            )
        positions.append(
            jax.tree.map(lambda *ls: jnp.stack(ls), *reps)
            if n_groups > 1 else jax.tree.map(lambda a: a[None], reps[0])
        )
    return positions  # list (period) of pytrees with leading n_groups dim


def _remat(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def apply_stack(
    stack: dict,
    x: jax.Array,
    cfg: ArchConfig,
    positions: jax.Array,
    sh: Sharding = NULL,
    *,
    mode: str = "train",
    causal: bool = True,
    cross_kv: tuple | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Scan over layer groups. Returns (x, total_moe_aux)."""
    period = len(stack)

    def group_body(carry, group_params):
        h, aux = carry
        for pos in range(period):
            h, a = apply_layer(
                group_params[pos], h, cfg, pos, positions, sh,
                mode=mode, causal=causal, cross_kv=cross_kv,
            )
            aux = aux + a
        h = sh.constrain(
            h, "dp", "sp" if sh.sp_activations else None, None
        )
        return (h, aux), None

    body = _remat(group_body, cfg)
    (x, aux), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), stack
    )
    return x, aux


# --------------------------------------------------------------------------
# decode (stacked caches scanned alongside params)
# --------------------------------------------------------------------------

def init_stack_cache(
    stack: list, cfg: ArchConfig, batch: int, max_len: int, dtype,
) -> list:
    """Per period-position stacked caches (n_groups leading dim)."""
    n_groups = jax.tree.leaves(stack[0])[0].shape[0]
    caches = []
    for pos in range(cfg.block_period):
        mixer, _ = layer_kind(cfg, pos)
        if mixer == "attn":
            c = init_cache(cfg, batch, max_len, dtype)
        else:
            c = init_ssm_cache(cfg, batch, dtype)
        caches.append(
            jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a[None], (n_groups,) + a.shape
                ).copy(),
                c,
            )
        )
    return caches


def apply_stack_decode(
    stack: list,
    caches: list,
    x: jax.Array,
    cfg: ArchConfig,
    sh: Sharding = NULL,
    cross_kv: tuple | None = None,
) -> tuple[jax.Array, list]:
    """One-token decode through the stack. x: (B, 1, D)."""
    period = len(stack)

    def group_body(h, scanned):
        group_params, group_caches = scanned
        new_caches = []
        for pos in range(period):
            p = group_params[pos]
            cache = group_caches[pos]
            mixer, ffn = layer_kind(cfg, pos)
            hn = apply_norm(p["norm1"], h)
            if mixer == "attn":
                a, cache = attention_decode(p["attn"], hn, cache, cfg, sh)
            else:
                a, cache = apply_ssm_decode(p["ssm"], hn, cache, cfg, sh)
            h = h + a
            if cross_kv is not None and "xattn" in p:
                hx = apply_norm(p["norm_x"], h)
                a = attention(
                    p["xattn"], hx, cfg,
                    jnp.zeros((h.shape[0], 1), jnp.int32), sh,
                    kv_override=cross_kv,
                )
                h = h + a
            if ffn == "moe":
                f, _ = apply_moe(p["moe"], apply_norm(p["norm2"], h), cfg, sh)
                h = h + f
            elif ffn == "mlp":
                f = apply_mlp(p["mlp"], apply_norm(p["norm2"], h), cfg, sh)
                h = h + f
            new_caches.append(cache)
        return h, new_caches

    x, new_caches = jax.lax.scan(group_body, x, (stack, caches))
    return x, new_caches
