"""Architecture + run-shape configuration schema for the LM zoo."""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    qkv_bias: bool = False
    act: str = "silu_glu"       # silu_glu | sq_relu | gelu
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    tie_embeddings: bool = False
    rope_theta: float = 1e4
    mrope: bool = False         # qwen2-vl M-RoPE (3-section rotary)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0           # per-expert hidden dim (if MoE)
    moe_every: int = 1          # MoE in layers where (layer % moe_every)==moe_offset
    moe_offset: int = 0
    router_dtype: str = "float32"

    # SSM / Mamba2 (SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    attn_every: int = 0         # hybrid: attention at layers where
    attn_offset: int = 0        #   (layer % attn_every) == attn_offset

    # encoder-decoder (whisper-style)
    is_encdec: bool = False
    dec_layers: int = 0
    max_target_len: int = 448

    # modality frontend stub: inputs are precomputed frame/patch embeddings
    frontend: str = "none"      # none | audio_stub | vision_stub

    dtype: str = "bfloat16"
    remat: str = "full"         # full | dots | none
    scan_layers: bool = True

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding-table rows padded to 256 for clean vocab sharding
        (standard practice; logits beyond vocab_size are masked to -inf)."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def is_attn_layer(self, layer: int) -> bool:
        if self.family == "ssm":
            return False
        if self.attn_every:
            return layer % self.attn_every == self.attn_offset
        return True

    def is_moe_layer(self, layer: int) -> bool:
        if not self.n_experts:
            return False
        return layer % self.moe_every == self.moe_offset

    @property
    def block_period(self) -> int:
        """Length of the repeating layer pattern (scan unit)."""
        p = 1
        if self.attn_every:
            p = self.attn_every
        if self.n_experts:
            p = int(p * self.moe_every / math.gcd(p, self.moe_every))
        return p

    # -------------------------------------------------------- param counts
    def param_count(self) -> int:
        d, v = self.d_model, self.vocab_size
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        total += d  # final norm
        n_dec = self.dec_layers if self.is_encdec else 0
        for layer in range(self.n_layers):
            total += self._layer_params(layer)
        for layer in range(n_dec):
            total += self._layer_params(layer) + self._attn_params() + self.d_model
        return total

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.hd
        q = d * self.n_heads * hd
        kv = 2 * d * self.n_kv_heads * hd
        o = self.n_heads * hd * d
        b = (self.n_heads + 2 * self.n_kv_heads) * hd if self.qkv_bias else 0
        return q + kv + o + b

    def _mlp_params(self, d_ff: int) -> int:
        d = self.d_model
        mult = 3 if self.act == "silu_glu" else 2
        return mult * d * d_ff

    def _ssm_params(self) -> int:
        d, di, n = self.d_model, self.d_inner, self.ssm_state
        h = self.ssm_heads
        in_proj = d * (2 * di + 2 * n + h)  # z, x, B, C, dt
        conv = (di + 2 * n) * self.ssm_conv
        out = di * d
        extras = 3 * h  # A_log, D, dt_bias
        extras += di  # gated norm
        return in_proj + conv + out + extras

    def _layer_params(self, layer: int) -> int:
        total = 2 * self.d_model  # norms
        if self.is_attn_layer(layer):
            total += self._attn_params()
        elif self.family in ("ssm", "hybrid"):
            total += self._ssm_params()
        if self.is_moe_layer(layer):
            total += self.n_experts * self._mlp_params(self.moe_d_ff)
            total += self.d_model * self.n_experts  # router
        elif self.d_ff:
            total += self._mlp_params(self.d_ff)
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.param_count()
        total = self.param_count()
        for layer in range(self.n_layers):
            if self.is_moe_layer(layer):
                inactive = (self.n_experts - self.top_k) * self._mlp_params(
                    self.moe_d_ff
                )
                total -= inactive
        return total


@dataclass(frozen=True)
class RunShape:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]
    microbatch: int = 0  # 0 -> no gradient accumulation; else per-device
                         # batch is split into chunks of this many sequences


SHAPES: dict[str, RunShape] = {
    "train_4k": RunShape("train_4k", 4096, 256, "train"),
    "prefill_32k": RunShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": RunShape("decode_32k", 32768, 128, "decode"),
    "long_500k": RunShape("long_500k", 524288, 1, "decode"),
}
