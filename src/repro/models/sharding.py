"""Sharding policy: logical-axis resolution + activation constraints.

Logical axes:
  'dp'   data parallel      -> ('pod', 'data') multi-pod, ('data',) single
  'fsdp' param/opt sharding -> same mesh axes as dp (ZeRO over the DP group)
  'tp'   tensor parallel    -> 'model'
  'sp'   sequence/context   -> 'model' (shares the model axis; used for
                               attention in archs whose head counts don't
                               divide the TP degree, and for long decode
                               KV caches)

Per-arch attention policy:
  'head_tp'  shard q/kv heads over tp (requires n_heads % tp == 0)
  'context'  shard the sequence over tp for attention math (heads intact)

The policy object is explicit (no global state): models take it as an
argument; NULL (mesh=None) turns every constraint into a no-op so smoke
tests run on one device.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .config import ArchConfig


@dataclass(frozen=True)
class Sharding:
    mesh: Mesh | None = None
    dp: tuple[str, ...] = ("data",)
    tp: str | None = "model"
    attn: str = "head_tp"       # head_tp | context
    moe: str = "expert"         # expert | ffn
    decode_cache: str = "seq"   # seq | heads
    shard_batch: bool = True    # False for global_batch < dp (long_500k)
    sp_activations: bool = False  # Megatron-SP: shard layer-boundary
                                  # activations over 'sp' (seq) — shrinks
                                  # scan carries by tp_size
    moe_dispatch: str = "replicated"  # replicated | dp: sharding of the
                                      # (E, cap, D) dispatch buffers along
                                      # cap (hillclimb lever, §Perf)

    # ---------------------------------------------------------------- axes
    def _resolve(self, dim) -> object:
        if dim is None:
            return None
        if isinstance(dim, (tuple, list)):
            out = []
            for d in dim:
                r = self._resolve(d)
                if r is None:
                    continue
                out.extend(r if isinstance(r, tuple) else (r,))
            return tuple(out) if out else None
        if dim == "dp":
            if not self.shard_batch:
                return None
            return self.dp if len(self.dp) > 1 else self.dp[0]
        if dim == "fsdp":
            return self.dp if len(self.dp) > 1 else self.dp[0]
        if dim in ("tp", "sp"):
            return self.tp
        raise ValueError(f"unknown logical axis {dim!r}")

    def spec(self, *dims) -> P:
        return P(*[self._resolve(d) for d in dims])

    def fit_spec(self, shape, spec: P) -> P:
        """Drop trailing mesh axes per dim until the dim size divides the
        sharding (small models on big meshes: whisper's 384-wide dims can't
        split 256 ways — back off to the largest feasible prefix)."""
        if self.mesh is None:
            return spec
        out = []
        for size, part in zip(shape, tuple(spec) + (None,) * len(shape)):
            if part is None:
                out.append(None)
                continue
            axes = list(part) if isinstance(part, tuple) else [part]
            while axes:
                prod = 1
                for a in axes:
                    prod *= self.mesh.shape[a]
                if size % prod == 0:
                    break
                axes.pop()
            out.append(tuple(axes) if len(axes) > 1 else
                       (axes[0] if axes else None))
        return P(*out)

    def named(self, *dims) -> NamedSharding | None:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(*dims))

    def constrain(self, x: jax.Array, *dims) -> jax.Array:
        if self.mesh is None:
            return x
        spec = self.fit_spec(x.shape, self.spec(*dims))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec)
        )

    # ------------------------------------------------------------- helpers
    @property
    def tp_size(self) -> int:
        if self.mesh is None or self.tp is None:
            return 1
        return self.mesh.shape[self.tp]

    @property
    def dp_size(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.dp:
            n *= self.mesh.shape[a]
        return n


NULL = Sharding(mesh=None)


def attention_policy(cfg: ArchConfig, tp_size: int) -> str:
    """head_tp when the TP degree divides the head count, else context
    parallelism (see module docstring)."""
    if tp_size <= 1:
        return "head_tp"
    return "head_tp" if cfg.n_heads % tp_size == 0 else "context"


def moe_policy(cfg: ArchConfig, tp_size: int) -> str:
    """Expert parallelism when experts divide TP, else TP within experts."""
    if cfg.n_experts and cfg.n_experts % max(tp_size, 1) == 0:
        return "expert"
    return "ffn"


def make_policy(
    cfg: ArchConfig,
    mesh: Mesh | None,
    dp: tuple[str, ...] = ("data",),
    tp: str | None = "model",
    sp_activations: bool | None = None,
) -> Sharding:
    if mesh is None:
        return NULL
    tp_size = mesh.shape[tp] if tp else 1
    if sp_activations is None:
        # SSD's chunk scan needs the full local sequence; attention-family
        # archs take the Megatron-SP boundary for free
        sp_activations = cfg.family not in ("ssm", "hybrid")
    return Sharding(
        mesh=mesh,
        dp=dp,
        tp=tp,
        attn=attention_policy(cfg, tp_size),
        moe=moe_policy(cfg, tp_size),
        sp_activations=sp_activations,
    )
