"""LM model zoo: assigned architectures on a shared substrate."""

from .config import SHAPES, ArchConfig, RunShape
from .model import (
    decode_step,
    forward,
    init_decode_state,
    init_params,
    loss_fn,
    param_specs,
    cache_specs,
)
from .sharding import NULL, Sharding, make_policy

__all__ = [
    "SHAPES",
    "ArchConfig",
    "RunShape",
    "decode_step",
    "forward",
    "init_decode_state",
    "init_params",
    "loss_fn",
    "param_specs",
    "cache_specs",
    "NULL",
    "Sharding",
    "make_policy",
]
