"""Calibrate the analytic traffic model against this machine.

``BlockPlan.traffic_model`` / ``BlockPlan.eq10_words`` predict memory
traffic in the paper's machine-free units. On a real machine two things
differ: (1) the *achieved* traffic of the lowered program (XLA fusion
reorders and elides transfers) and (2) the constant factors relating
traffic to time (effective bandwidth, per-call overhead). This module
measures both:

  * **measured traffic** — the trip-count-aware HLO byte count of the
    compiled blocked schedule (:mod:`repro.analysis.hlo_cost`), the same
    walker the roofline analysis trusts;
  * **measured time** — synchronized wall time of the same executable;

then fits ``time_us ≈ overhead_us + model_bytes / bandwidth`` by least
squares across the calibration shapes. The resulting
:class:`Calibration` turns any plan's modeled bytes into a predicted
time (``predict_us``), and :func:`calibration_report` prints the
model-vs-measured traffic error per shape — the honesty check the
autotuner's model-based rankings rest on.

Coefficients persist in the plan cache's ``calibration`` section, so a
later session can score plans with this machine's constants without
re-measuring.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Sequence

import jax
import jax.numpy as jnp

from ..engine.plan import Memory, uniform_plan
from .cache import PlanCache, default_cache

# small enough to calibrate in seconds on CPU, large enough that the
# blocked schedule's traffic dominates fixed overheads
DEFAULT_CASES: tuple[tuple[tuple[int, ...], int], ...] = (
    ((48, 40, 32), 8),
    ((64, 48, 32), 16),
    ((96, 64, 48), 8),
    ((32, 24, 16, 12), 8),
)


@dataclass
class ShapeCalibration:
    """Model-vs-measured numbers for one calibration shape."""

    shape: tuple[int, ...]
    rank: int
    block: int
    model_bytes: int
    measured_bytes: int
    walltime_us: float
    predicted_us: float = float("nan")

    @property
    def traffic_rel_err(self) -> float:
        """(model - measured) / measured: the Eq-10 model's honesty."""
        if self.measured_bytes <= 0:
            return float("nan")
        return (self.model_bytes - self.measured_bytes) / self.measured_bytes

    @property
    def time_rel_err(self) -> float:
        if not self.walltime_us:
            return float("nan")
        return (self.predicted_us - self.walltime_us) / self.walltime_us


@dataclass
class Calibration:
    """Per-machine coefficients: ``time_us = overhead_us + bytes/bandwidth``."""

    bandwidth_bytes_per_us: float
    overhead_us: float
    rows: list[ShapeCalibration] = field(default_factory=list)
    backend: str = "cpu"

    def predict_us(self, model_bytes: float) -> float:
        return self.overhead_us + model_bytes / max(
            self.bandwidth_bytes_per_us, 1e-12
        )

    def to_dict(self) -> dict:
        return {
            "bandwidth_bytes_per_us": self.bandwidth_bytes_per_us,
            "overhead_us": self.overhead_us,
            "backend": self.backend,
            "jax": jax.__version__,
            "rows": [
                {
                    "shape": list(r.shape),
                    "rank": r.rank,
                    "block": r.block,
                    "model_bytes": r.model_bytes,
                    "measured_bytes": r.measured_bytes,
                    "walltime_us": r.walltime_us,
                    "predicted_us": r.predicted_us,
                }
                for r in self.rows
            ],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Calibration":
        cal = cls(
            float(d["bandwidth_bytes_per_us"]),
            float(d["overhead_us"]),
            backend=d.get("backend", "cpu"),
        )
        for r in d.get("rows", ()):
            cal.rows.append(
                ShapeCalibration(
                    tuple(r["shape"]), r["rank"], r["block"],
                    r["model_bytes"], r["measured_bytes"], r["walltime_us"],
                    r.get("predicted_us", float("nan")),
                )
            )
        return cal


def _measured_bytes(compiled) -> int:
    """Trip-count-aware byte count of a compiled executable (falls back to
    XLA's raw cost_analysis if the walker can't parse the module)."""
    try:
        from ..analysis.hlo_cost import analyze_module

        return int(analyze_module(compiled.as_text()).bytes)
    except Exception:  # pragma: no cover - parser drift safety
        from ..compat import cost_analysis

        return int(cost_analysis(compiled).get("bytes accessed", 0))


def _fit_affine(xs: Sequence[float], ys: Sequence[float]) -> tuple[float, float]:
    """Least-squares ``y ≈ a + b*x`` without numpy.linalg (tiny system)."""
    n = len(xs)
    mx = sum(xs) / n
    my = sum(ys) / n
    sxx = sum((x - mx) ** 2 for x in xs)
    if sxx <= 0:
        return my, 0.0
    b = sum((x - mx) * (y - my) for x, y in zip(xs, ys)) / sxx
    a = my - b * mx
    return a, b


def calibrate(
    cases: Sequence[tuple[Sequence[int], int]] = DEFAULT_CASES,
    *,
    memory: Memory | None = None,
    reps: int = 3,
    cache: PlanCache | None = None,
    persist: bool = True,
) -> Calibration:
    """Measure the blocked schedule on each case and fit the coefficients.

    Uses the ``blocked_host`` executor (Algorithm 2's schedule lowered
    through XLA) because its compiled HLO is byte-countable on every
    backend — the Pallas kernel's interpret-mode bytes are not the TPU's.
    Requires >= 3 cases so the affine fit and the per-shape error report
    are meaningful.
    """
    if len(cases) < 3:
        raise ValueError("calibration needs at least 3 shapes")
    from ..engine import execute as engine_execute  # call-time: layer cycle

    mem = memory or Memory.abstract(1 << 16)
    rows: list[ShapeCalibration] = []
    key = jax.random.PRNGKey(0)
    for dims, rank in cases:
        dims = tuple(dims)
        plan = uniform_plan(dims, rank, mem)
        b = plan.block_i
        model_bytes = int(plan.eq10_words(dims, rank)) * 4
        kx, *kf = jax.random.split(key, len(dims) + 1)
        x = jax.random.normal(kx, dims, jnp.float32)
        fs = tuple(
            jax.random.normal(k, (d, rank), jnp.float32)
            for k, d in zip(kf, dims)
        )

        from ..engine.context import ExecutionContext

        blocked_ctx = ExecutionContext.create(backend="blocked_host")

        def run(x, fs, _b=b):
            return engine_execute.mttkrp(x, fs, 0, ctx=blocked_ctx, block=_b)

        compiled = jax.jit(run).lower(x, fs).compile()
        measured = _measured_bytes(compiled)
        jax.block_until_ready(compiled(x, fs))  # warm
        best = float("inf")
        for _ in range(max(1, reps)):
            t0 = time.perf_counter()
            jax.block_until_ready(compiled(x, fs))
            best = min(best, (time.perf_counter() - t0) * 1e6)
        rows.append(
            ShapeCalibration(dims, rank, b, model_bytes, measured, best)
        )

    overhead, inv_bw = _fit_affine(
        [r.model_bytes for r in rows], [r.walltime_us for r in rows]
    )
    overhead = max(overhead, 0.0)
    bandwidth = (1.0 / inv_bw) if inv_bw > 0 else float("inf")
    cal = Calibration(bandwidth, overhead, rows, jax.default_backend())
    for r in rows:
        r.predicted_us = cal.predict_us(r.model_bytes)
    if persist:
        dest = default_cache() if cache is None else cache
        dest.put_calibration(cal.to_dict())
    return cal


def load_calibration(cache: PlanCache | None = None) -> Calibration | None:
    src = default_cache() if cache is None else cache
    d = src.get_calibration()
    return Calibration.from_dict(d) if d else None


def calibration_report(cal: Calibration) -> str:
    """Human-readable model-vs-measured table (one row per shape)."""
    lines = [
        f"calibration[{cal.backend}]: "
        f"bandwidth={cal.bandwidth_bytes_per_us:.1f} B/us, "
        f"overhead={cal.overhead_us:.1f} us",
        f"{'shape':>18} {'rank':>4} {'b':>4} {'model_MB':>9} "
        f"{'measured_MB':>11} {'traffic_err':>11} {'time_us':>9} "
        f"{'pred_us':>9} {'time_err':>9}",
    ]
    for r in cal.rows:
        terr = r.traffic_rel_err
        perr = r.time_rel_err
        lines.append(
            f"{'x'.join(map(str, r.shape)):>18} {r.rank:>4} {r.block:>4} "
            f"{r.model_bytes / 1e6:>9.3f} {r.measured_bytes / 1e6:>11.3f} "
            f"{terr:>+10.1%} {r.walltime_us:>9.1f} {r.predicted_us:>9.1f} "
            f"{perr if math.isfinite(perr) else float('nan'):>+8.1%}"
        )
    return "\n".join(lines)
