"""Autotuning subsystem: empirical plan search, persistent plan cache, and
a calibrated cost model behind ``backend="auto"``.

The analytic planner (:mod:`repro.engine.plan`) decides blocking from the
paper's Eq-9/Eq-10 model alone; on real hardware the model's constant
factors are off by machine-dependent amounts (the gap Hayashi et al. close
with empirical tuning). This package closes it in three parts:

    cache     — persistent on-disk JSON plan cache, keyed by the full
                problem descriptor (kind, shape, rank, mode, dtype,
                Memory, execution platform, jax version) with schema
                versioning and in-process memoization.
                ``REPRO_TUNE_CACHE`` overrides the path.
    search    — candidate generation (perturbed ``choose_blocks`` plans,
                the paper's uniform-b plan, both kernel variants, all
                three executors) and the measurement loop that times each
                candidate through ``engine.execute.mttkrp``.
    calibrate — fits per-machine bandwidth/overhead coefficients so
                ``BlockPlan.traffic_model`` predictions can be scored
                against measurements (model-vs-measured error report).

``engine.execute.mttkrp(..., backend="auto")`` resolves through
:func:`repro.tune.search.resolve`: cache hit → the tuned plan, exactly as
persisted; miss → the analytic model-best plan (plus ``tune=True`` to
search empirically and persist the winner).
"""

from .cache import (
    SCHEMA_VERSION,
    CacheEntry,
    PlanCache,
    cache_key,
    default_cache,
    isolated_cache,
    plan_from_dict,
    plan_to_dict,
)
from .calibrate import Calibration, calibrate, calibration_report
from .search import (  # NB: the search *function* stays module-qualified
    Candidate,         # (repro.tune.search.search) so the submodule name
    Measurement,       # isn't shadowed on the package
    TuneResult,
    generate_candidates,
    resolve,
    resolve_multi_ttm,
    tune_mttkrp,
    tune_multi_ttm,
    tune_partial,
)
from . import cache, calibrate, search  # noqa: F401  (submodule access)

__all__ = [
    "SCHEMA_VERSION",
    "CacheEntry",
    "PlanCache",
    "cache_key",
    "default_cache",
    "isolated_cache",
    "plan_from_dict",
    "plan_to_dict",
    "Calibration",
    "calibrate",
    "calibration_report",
    "Candidate",
    "Measurement",
    "TuneResult",
    "generate_candidates",
    "resolve",
    "resolve_multi_ttm",
    "tune_mttkrp",
    "tune_multi_ttm",
    "tune_partial",
    "search",  # the submodule (repro.tune.search)
]
