"""Persistent plan cache: tuned BlockPlans keyed by the full problem.

One JSON file holds every tuned decision on this machine. A cache entry
records *everything* ``engine.execute.mttkrp`` needs to replay the winner
without re-searching: backend, kernel variant, and the exact
:class:`~repro.engine.plan.BlockPlan` (round-tripped field-for-field, so a
warm cache reproduces the tuned plan bit-identically).

Keying
------
``cache_key`` folds in shape, rank, mode, dtype, the Memory descriptor
(budget/lane/sublane/itemsize), the contraction kind (full MTTKRP vs
rank-augmented partial), the execution platform (a winner measured on CPU
must never be replayed on TPU, and vice versa), and the jax version — a
change to any of these is a different tuning problem, so it simply
misses. ``SCHEMA_VERSION`` is part of the on-disk envelope: bumping it
(or loading a file written by a different version) invalidates the whole
file rather than risking stale plans.

Robustness
----------
A corrupted, truncated, or wrong-schema cache file must never take the
engine down: loads fall back to an empty cache (the caller then re-plans
analytically) and the next ``put`` rewrites the file atomically.

The path resolves, in order: explicit argument, ``REPRO_TUNE_CACHE`` env
var, ``~/.cache/repro-mttkrp/plans.json``.
"""

from __future__ import annotations

import contextlib
import json
import os
import tempfile
import time
from dataclasses import asdict, dataclass, field
from typing import Iterator, Sequence

import jax

from ..engine.plan import BlockPlan, Memory, MultiTTMPlan

SCHEMA_VERSION = 1
ENV_CACHE_PATH = "REPRO_TUNE_CACHE"
DEFAULT_CACHE_PATH = os.path.join(
    "~", ".cache", "repro-mttkrp", "plans.json"
)


def resolve_cache_path(path: str | None = None) -> str:
    """Explicit path > ``$REPRO_TUNE_CACHE`` > the default user cache."""
    if path is None:
        path = os.environ.get(ENV_CACHE_PATH) or DEFAULT_CACHE_PATH
    return os.path.expanduser(path)


# ---------------------------------------------------------------------------
# BlockPlan (de)serialization — exact round-trip
# ---------------------------------------------------------------------------

def plan_to_dict(plan: BlockPlan | MultiTTMPlan) -> dict:
    if isinstance(plan, MultiTTMPlan):
        return {
            "block_i": plan.block_i,
            "block_contract": list(plan.block_contract),
            "ranks": list(plan.ranks),
        }
    return {
        "block_i": plan.block_i,
        "block_contract": list(plan.block_contract),
        "block_r": plan.block_r,
        "x_has_rank": plan.x_has_rank,
    }


def plan_from_dict(d: dict) -> BlockPlan | MultiTTMPlan:
    if "ranks" in d:  # Multi-TTM plans carry the per-mode Tucker ranks
        return MultiTTMPlan(
            block_i=int(d["block_i"]),
            block_contract=tuple(int(c) for c in d["block_contract"]),
            ranks=tuple(int(r) for r in d["ranks"]),
        )
    return BlockPlan(
        block_i=int(d["block_i"]),
        block_contract=tuple(int(c) for c in d["block_contract"]),
        block_r=int(d["block_r"]),
        x_has_rank=bool(d.get("x_has_rank", False)),
    )


def memory_tag(memory: Memory) -> str:
    return (
        f"{memory.budget_bytes}:{memory.lane}:{memory.sublane}"
        f":{memory.itemsize}"
    )


def cache_key(
    shape: Sequence[int],
    rank: int | Sequence[int],
    mode: int,
    dtype,
    memory: Memory,
    *,
    kind: str = "mttkrp",
) -> str:
    """The tuning-problem identity; every field that changes the answer.

    ``rank`` is the CP rank (int) or — for ``kind="multi_ttm"`` — the
    tuple of per-mode Tucker ranks (tagged ``r1xr2x...``); ``mode`` is
    the output/kept mode (``-1`` = full Tucker core, no kept mode)."""
    shape_tag = "x".join(str(int(s)) for s in shape)
    if isinstance(rank, (tuple, list)):
        rank_tag = "x".join(str(int(r)) for r in rank)
    else:
        rank_tag = str(int(rank))
    return (
        f"{kind}|shape={shape_tag}|rank={rank_tag}|mode={int(mode)}"
        f"|dtype={jax.numpy.dtype(dtype).name}|mem={memory_tag(memory)}"
        f"|platform={jax.default_backend()}|jax={jax.__version__}"
    )


@dataclass
class CacheEntry:
    """One tuned decision: how to run this contraction, and why."""

    backend: str
    plan: dict | None = None  # plan_to_dict payload; None for einsum
    variant: str | None = None  # pallas kernel variant (specialized/generic)
    block: int | None = None  # blocked_host uniform block
    metric: str = "walltime"
    score: float = float("nan")  # winning score (us or modeled bytes)
    walltime_us: float = float("nan")
    modeled_bytes: int | None = None
    timestamp: float = 0.0
    meta: dict = field(default_factory=dict)

    def to_plan(self) -> BlockPlan | None:
        return plan_from_dict(self.plan) if self.plan is not None else None

    @classmethod
    def from_dict(cls, d: dict) -> "CacheEntry":
        known = {f for f in cls.__dataclass_fields__}
        return cls(**{k: v for k, v in d.items() if k in known})


class PlanCache:
    """On-disk JSON plan cache with in-process memoization.

    The file layout is a versioned envelope::

        {"schema": 1, "entries": {key: entry...}, "calibration": {...}}

    Loads are lazy and forgiving (any parse/schema problem yields an empty
    cache); writes go through a same-directory temp file + ``os.replace``
    so a crash mid-write can never leave a half-written cache behind.
    """

    def __init__(self, path: str | None = None):
        self.path = resolve_cache_path(path)
        self._entries: dict[str, CacheEntry] | None = None
        self._calibration: dict | None = None

    # -- load/store --------------------------------------------------------
    def _load(self) -> dict[str, CacheEntry]:
        if self._entries is not None:
            return self._entries
        entries: dict[str, CacheEntry] = {}
        calibration: dict | None = None
        try:
            with open(self.path) as f:
                raw = json.load(f)
            if (
                isinstance(raw, dict)
                and raw.get("schema") == SCHEMA_VERSION
                and isinstance(raw.get("entries"), dict)
            ):
                for k, v in raw["entries"].items():
                    try:
                        entries[k] = CacheEntry.from_dict(v)
                    except (TypeError, KeyError, ValueError):
                        continue  # skip one bad entry, keep the rest
                cal = raw.get("calibration")
                calibration = cal if isinstance(cal, dict) else None
            # wrong schema / shape: treated as empty (full invalidation)
        except (OSError, json.JSONDecodeError, UnicodeDecodeError):
            pass  # missing or corrupted file: start empty, never crash
        self._entries = entries
        self._calibration = calibration
        return entries

    def _flush(self) -> None:
        entries = self._load()
        payload = {
            "schema": SCHEMA_VERSION,
            "jax": jax.__version__,
            "entries": {k: asdict(e) for k, e in entries.items()},
        }
        if self._calibration is not None:
            payload["calibration"] = self._calibration
        d = os.path.dirname(self.path) or "."
        try:
            os.makedirs(d, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except OSError:
            pass  # read-only filesystem etc.: in-process cache still works

    # -- entries -----------------------------------------------------------
    def get(self, key: str) -> CacheEntry | None:
        return self._load().get(key)

    def put(self, key: str, entry: CacheEntry, persist: bool = True) -> None:
        if not entry.timestamp:
            entry.timestamp = time.time()
        self._load()[key] = entry
        if persist:
            self._flush()

    def invalidate(self, key: str) -> None:
        self._load().pop(key, None)
        self._flush()

    def clear(self) -> None:
        self._entries = {}
        self._calibration = None
        self._flush()

    def keys(self) -> list[str]:
        return sorted(self._load())

    def __len__(self) -> int:
        return len(self._load())

    # -- calibration section ----------------------------------------------
    def get_calibration(self) -> dict | None:
        self._load()
        return self._calibration

    def put_calibration(self, cal: dict) -> None:
        self._load()
        self._calibration = cal
        self._flush()


# process-wide default caches, one per resolved path (so tests can redirect
# via REPRO_TUNE_CACHE / monkeypatch and get a fresh instance)
_DEFAULT_CACHES: dict[str, PlanCache] = {}


def default_cache() -> PlanCache:
    path = resolve_cache_path()
    cache = _DEFAULT_CACHES.get(path)
    if cache is None:
        cache = _DEFAULT_CACHES[path] = PlanCache(path)
    return cache


@contextlib.contextmanager
def isolated_cache() -> Iterator[str]:
    """Redirect the default cache to a throwaway temp file for the scope
    (benchmarks and demos must never pollute the user's plan cache).
    Restores ``REPRO_TUNE_CACHE`` and removes the file on exit."""
    fd, tmp = tempfile.mkstemp(prefix="repro-tune-", suffix=".json")
    os.close(fd)
    prev = os.environ.get(ENV_CACHE_PATH)
    os.environ[ENV_CACHE_PATH] = tmp
    try:
        yield tmp
    finally:
        if prev is None:
            os.environ.pop(ENV_CACHE_PATH, None)
        else:
            os.environ[ENV_CACHE_PATH] = prev
        try:
            os.unlink(tmp)
        except OSError:
            pass
