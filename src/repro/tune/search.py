"""Empirical plan search: generate candidates, measure, pick a winner.

Candidate space (the knobs PR 1 unified behind the engine):

  * all three executors — ``einsum``, ``blocked_host`` (Eq-9 uniform
    blocking), ``pallas`` (the blocked VMEM/MXU kernels);
  * for ``pallas``, the analytic ``choose_blocks`` plan plus structured
    perturbations of it (each block dimension halved/doubled within the
    Eq-9 budget) and the paper's exact uniform-b plan;
  * for 3-way tensors, both kernel variants (the specialized
    ``mttkrp3`` schedule and the generic N-way kernel).

Measurement runs every candidate through the same
``engine.execute.mttkrp`` entry point the engine uses in production and
checks it against the einsum oracle, so a tuned winner is always a
correct configuration. Scoring:

  * ``metric="walltime"`` — min-of-reps wall time on the actual device
    (the TPU path).
  * ``metric="traffic"``  — the CPU fallback: interpret-mode wall time of
    a Pallas kernel says nothing about its TPU behavior, so kernel plans
    are ranked by their modeled HBM traffic (``BlockPlan.traffic_model``)
    and only the best-traffic plan is timed against the host executors.
  * ``metric="auto"``     — walltime on TPU, traffic elsewhere.

:func:`resolve` is the ``backend="auto"`` entry: cache hit returns the
persisted winner (exact :class:`BlockPlan` round-trip, no re-search);
miss returns the analytic model-best configuration. It is pure Python on
static shapes, so it also works at trace time (e.g. inside shard_map for
the distributed algorithms' local MTTKRPs).
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Sequence

import jax
import jax.numpy as jnp

from ..engine.context import ExecutionContext
from ..engine.plan import (
    BlockPlan,
    Memory,
    MultiTTMPlan,
    choose_blocks,
    choose_multi_ttm_blocks,
    uniform_plan,
)
from .cache import CacheEntry, PlanCache, cache_key, default_cache, plan_to_dict

KERNEL_VARIANTS = ("specialized", "generic")


def _is_concrete(x) -> bool:
    try:
        return not isinstance(x, jax.core.Tracer)
    except AttributeError:  # pragma: no cover - jax.core moved
        return hasattr(x, "addressable_data") or hasattr(x, "__array__")


@dataclass(frozen=True)
class Candidate:
    """One runnable configuration of the engine for a fixed problem."""

    backend: str
    plan: BlockPlan | None = None
    variant: str | None = None  # pallas 3-way kernel variant
    block: int | None = None  # blocked_host uniform block

    @property
    def label(self) -> str:
        if self.backend == "pallas" and self.plan is not None:
            p = self.plan
            v = f":{self.variant}" if self.variant else ""
            return (
                f"pallas{v}[{p.block_i}x"
                f"{'x'.join(map(str, p.block_contract))}xR{p.block_r}]"
            )
        if self.backend == "blocked_host" and self.block is not None:
            return f"blocked_host[b={self.block}]"
        return self.backend


@dataclass
class Measurement:
    candidate: Candidate
    walltime_us: float = float("nan")
    modeled_bytes: int | None = None
    score: float = float("inf")
    ok: bool = True
    error: str = ""


@dataclass
class TuneResult:
    key: str
    winner: Candidate
    measurements: list[Measurement] = field(default_factory=list)
    metric: str = "walltime"
    cache_hit: bool = False

    @property
    def best(self) -> Measurement:
        return next(
            m for m in self.measurements if m.candidate == self.winner
        )


# ---------------------------------------------------------------------------
# Candidate generation
# ---------------------------------------------------------------------------

def _clamp_plan(plan: BlockPlan, shape: Sequence[int], rank: int,
                memory: Memory) -> BlockPlan | None:
    """Keep a perturbed plan only if it is feasible and non-degenerate."""
    if plan.block_i < 1 or plan.block_r < 1:
        return None
    if any(c < 1 for c in plan.block_contract):
        return None
    if not plan.fits(memory):
        return None
    return plan


def _perturbations(base: BlockPlan, shape: Sequence[int], rank: int,
                   memory: Memory) -> list[BlockPlan]:
    """Halve/double each block dimension of the analytic plan (one axis at
    a time), keeping Eq-9-feasible results — the empirical neighborhood
    Hayashi et al. search instead of trusting the model's constants."""
    out: list[BlockPlan] = []
    axes = 2 + len(base.block_contract)  # i, r, c_0..c_{k-1}
    for axis in range(axes):
        for factor_num, factor_den in ((1, 2), (2, 1)):
            bi, br = base.block_i, base.block_r
            bc = list(base.block_contract)
            if axis == 0:
                bi = max(1, bi * factor_num // factor_den)
            elif axis == 1:
                br = max(1, br * factor_num // factor_den)
            else:
                d = axis - 2
                bc[d] = max(1, bc[d] * factor_num // factor_den)
            cand = _clamp_plan(
                BlockPlan(bi, tuple(bc), br, base.x_has_rank),
                shape, rank, memory,
            )
            if cand is not None:
                out.append(cand)
    return out


def candidate_plans(
    shape: Sequence[int],
    rank: int,
    memory: Memory,
    itemsize: int = 4,
    *,
    x_has_rank: bool = False,
    max_plans: int = 8,
) -> list[BlockPlan]:
    """The pallas plan candidates: analytic best, its perturbations, and
    the paper's exact uniform-b plan."""
    base = choose_blocks(
        shape, rank, itemsize, memory=memory, x_has_rank=x_has_rank
    )
    plans: list[BlockPlan] = [base]
    plans.extend(_perturbations(base, shape, rank, memory))
    up = uniform_plan(shape, rank, memory)
    up = BlockPlan(  # clamp the paper's uniform b to the actual extents
        min(up.block_i, shape[0]),
        tuple(min(b, s) for b, s in zip(up.block_contract, shape[1:])),
        min(up.block_r, rank),
        x_has_rank,
    )
    if _clamp_plan(up, shape, rank, memory) is not None:
        plans.append(up)
    seen: set[tuple] = set()
    unique: list[BlockPlan] = []
    for p in plans:
        sig = (p.block_i, p.block_contract, p.block_r, p.x_has_rank)
        if sig not in seen:
            seen.add(sig)
            unique.append(p)
    return unique[:max_plans]


def generate_candidates(
    shape: Sequence[int],
    rank: int,
    memory: Memory,
    itemsize: int = 4,
    *,
    backends: Sequence[str] = ("einsum", "blocked_host", "pallas"),
    max_plans: int = 8,
) -> list[Candidate]:
    """All executors x all plan candidates x (3-way) both kernel variants."""
    out: list[Candidate] = []
    n = len(shape)
    if "einsum" in backends:
        out.append(Candidate("einsum"))
    if "blocked_host" in backends:
        abstract = Memory.abstract(memory.budget_words)
        b = uniform_plan(shape, rank, abstract).block_i
        out.append(Candidate("blocked_host", block=b))
    if "pallas" in backends and n >= 3:
        variants = KERNEL_VARIANTS if n == 3 else ("generic",)
        for plan in candidate_plans(
            shape, rank, memory, itemsize, max_plans=max_plans
        ):
            for variant in variants:
                out.append(Candidate("pallas", plan=plan, variant=variant))
    return out


# ---------------------------------------------------------------------------
# Measurement
# ---------------------------------------------------------------------------

def _time_call(fn, warmup: int, reps: int) -> float:
    """Min-of-reps wall time in microseconds (device-synchronized)."""
    for _ in range(max(0, warmup)):
        jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, (time.perf_counter() - t0) * 1e6)
    return best


def _measure_one(
    cand: Candidate,
    call,
    *,
    reference: jax.Array | None = None,
    rtol: float = 5e-3,
    warmup: int = 1,
    reps: int = 3,
    modeled_bytes: int | None = None,
) -> Measurement:
    """Run, verify (against ``reference``), and time one candidate's
    ``call``. The shared core of full-MTTKRP and partial-contraction
    measurement; failures are recorded, never raised — a candidate that
    crashes or is wrong simply loses."""
    from ..observe.metrics import TUNE_CANDIDATES, registry

    registry().inc(TUNE_CANDIDATES)
    m = Measurement(cand, modeled_bytes=modeled_bytes)
    try:
        got = call()
        jax.block_until_ready(got)
        if reference is not None:
            err = float(jnp.max(jnp.abs(got - reference)))
            scale = float(jnp.max(jnp.abs(reference))) + 1e-30
            if not math.isfinite(err) or err > rtol * scale:
                m.ok = False
                m.error = f"maxerr={err:.3e} (scale {scale:.3e})"
                return m
        m.walltime_us = _time_call(call, warmup, reps)
    except Exception as e:  # noqa: BLE001 - any failing candidate loses
        m.ok = False
        m.error = f"{type(e).__name__}: {e}"
    return m


def _split_for_metric(
    cands: Sequence[Candidate], metric: str, tm_bytes
) -> tuple[list[Candidate], list[Candidate]]:
    """Under the traffic metric, pre-rank pallas candidates by their
    modeled bytes (``tm_bytes``) and time only the best of them against
    the non-pallas executors; returns (timed, modeled_only)."""
    if metric != "traffic":
        return list(cands), []
    pallas = sorted(
        (c for c in cands if c.backend == "pallas"), key=tm_bytes
    )
    rest = [c for c in cands if c.backend != "pallas"]
    return rest + pallas[:1], pallas[1:]


def measure_candidate(
    x: jax.Array,
    factors: Sequence[jax.Array],
    mode: int,
    cand: Candidate,
    *,
    interpret: bool | None = None,
    warmup: int = 1,
    reps: int = 3,
    reference: jax.Array | None = None,
    rtol: float = 5e-3,
) -> Measurement:
    """Time one candidate through ``engine.execute.mttkrp`` and verify it
    against the einsum oracle."""
    from ..engine import execute as engine_execute  # call-time: layer cycle

    rank = next(f.shape[1] for k, f in enumerate(factors) if k != mode)
    perm_shape = (x.shape[mode],) + tuple(
        s for k, s in enumerate(x.shape) if k != mode
    )
    modeled = None
    if cand.plan is not None:
        modeled = int(
            cand.plan.traffic_model(
                perm_shape, rank, x.dtype.itemsize
            )["total_bytes"]
        )

    cand_ctx = ExecutionContext.create(
        backend=cand.backend, interpret=interpret
    )

    def call():
        return engine_execute.mttkrp(
            x, factors, mode, ctx=cand_ctx, plan=cand.plan,
            block=cand.block, kernel_variant=cand.variant,
        )

    return _measure_one(
        cand, call, reference=reference, rtol=rtol, warmup=warmup,
        reps=reps, modeled_bytes=modeled,
    )


def _resolve_metric(metric: str) -> str:
    if metric == "auto":
        return "walltime" if jax.default_backend() == "tpu" else "traffic"
    if metric not in ("walltime", "traffic"):
        raise ValueError(f"unknown metric {metric!r}")
    return metric


def search(
    x: jax.Array,
    factors: Sequence[jax.Array],
    mode: int,
    *,
    ctx: ExecutionContext | None = None,
    memory: Memory | None = None,
    metric: str = "auto",
    interpret: bool | None = None,
    warmup: int = 1,
    reps: int = 3,
    max_plans: int = 8,
) -> TuneResult:
    """Measure the candidate space for one MTTKRP problem, return the winner.

    ``ctx`` supplies ``memory``/``interpret`` defaults (explicit arguments
    win). ``metric="traffic"`` (the CPU fallback) pre-ranks pallas plans
    by modeled traffic and times only the best one against the host
    executors; ``metric="walltime"`` times everything.
    """
    from ..observe import trace as _otrace
    from ..observe.metrics import TUNE_SEARCH_TIME_US, registry

    _search_t0 = time.perf_counter()
    if ctx is not None:
        memory = memory if memory is not None else ctx.memory
        interpret = interpret if interpret is not None else ctx.interpret
    metric = _resolve_metric(metric)
    perm_shape = (x.shape[mode],) + tuple(
        s for k, s in enumerate(x.shape) if k != mode
    )
    rank = next(f.shape[1] for k, f in enumerate(factors) if k != mode)
    mem = memory or Memory.tpu_vmem(itemsize=x.dtype.itemsize)
    key = cache_key(perm_shape, rank, mode, x.dtype, mem)
    cands = generate_candidates(
        perm_shape, rank, mem, x.dtype.itemsize, max_plans=max_plans
    )
    def tm_bytes(c):
        return int(
            c.plan.traffic_model(
                perm_shape, rank, x.dtype.itemsize
            )["total_bytes"]
        )

    timed, modeled_only = _split_for_metric(cands, metric, tm_bytes)

    from ..core.mttkrp import mttkrp as einsum_oracle

    reference = einsum_oracle(x, factors, mode)
    jax.block_until_ready(reference)
    measurements = [
        measure_candidate(
            x, factors, mode, c, interpret=interpret, warmup=warmup,
            reps=reps, reference=reference,
        )
        for c in timed
    ]
    measurements += [  # recorded for the report, not timed
        Measurement(c, modeled_bytes=tm_bytes(c)) for c in modeled_only
    ]
    ok = [m for m in measurements if m.ok and math.isfinite(m.walltime_us)]
    if not ok:
        raise RuntimeError(
            f"no candidate survived measurement for {key}: "
            + "; ".join(f"{m.candidate.label}: {m.error}" for m in measurements)
        )
    _assign_scores(measurements, metric)
    winner = min(ok, key=lambda m: m.walltime_us).candidate
    search_us = (time.perf_counter() - _search_t0) * 1e6
    registry().observe(TUNE_SEARCH_TIME_US, search_us)
    if _otrace.should_record(ctx.observe if ctx is not None else False):
        _otrace.record_event(
            "tune_search",
            shape=list(perm_shape),
            rank=int(rank),
            mode=int(mode),
            metric=metric,
            candidates=len(measurements),
            timed=len(timed),
            winner=winner.label,
            search_time_us=search_us,
        )
    return TuneResult(key, winner, measurements, metric)


def _assign_scores(measurements: list[Measurement], metric: str) -> None:
    """score = the quantity the ranking actually used for that candidate:
    modeled bytes for kernel plans under the traffic metric, wall time
    otherwise."""
    for m in measurements:
        if (
            metric == "traffic"
            and m.candidate.backend == "pallas"
            and m.modeled_bytes is not None
        ):
            m.score = float(m.modeled_bytes)
        else:
            m.score = m.walltime_us


def tune_mttkrp(
    x: jax.Array,
    factors: Sequence[jax.Array],
    mode: int,
    *,
    ctx: ExecutionContext | None = None,
    memory: Memory | None = None,
    cache: PlanCache | None = None,
    metric: str = "auto",
    interpret: bool | None = None,
    force: bool = False,
    persist: bool = True,
    **search_kwargs,
) -> TuneResult:
    """Search (unless already cached) and persist the winner.

    ``ctx`` supplies ``memory``/``interpret``/cache-handle defaults
    (explicit arguments win). Idempotent: a warm cache short-circuits to
    the stored entry, so a ``backend="auto", tune=True`` context in a
    loop searches exactly once.
    """
    if ctx is not None:
        memory = memory if memory is not None else ctx.memory
        interpret = interpret if interpret is not None else ctx.interpret
        cache = cache if cache is not None else ctx.plan_cache()
    cache = cache if cache is not None else default_cache()
    mem = memory or Memory.tpu_vmem(itemsize=x.dtype.itemsize)
    perm_shape = (x.shape[mode],) + tuple(
        s for k, s in enumerate(x.shape) if k != mode
    )
    rank = next(f.shape[1] for k, f in enumerate(factors) if k != mode)
    key = cache_key(perm_shape, rank, mode, x.dtype, mem)
    if not force:
        entry = cache.get(key)
        if entry is not None:
            winner = Candidate(
                entry.backend, plan=entry.to_plan(), variant=entry.variant,
                block=entry.block,
            )
            best = Measurement(
                winner, walltime_us=entry.walltime_us,
                modeled_bytes=entry.modeled_bytes, score=entry.score,
            )
            return TuneResult(
                key, winner, [best], entry.metric, cache_hit=True
            )
    result = search(
        x, factors, mode, memory=mem, metric=metric, interpret=interpret,
        **search_kwargs,
    )
    best = result.best
    w = result.winner
    cache.put(
        key,
        CacheEntry(
            backend=w.backend,
            plan=plan_to_dict(w.plan) if w.plan is not None else None,
            variant=w.variant,
            block=w.block,
            metric=result.metric,
            score=best.score,
            walltime_us=best.walltime_us,
            modeled_bytes=best.modeled_bytes,
            meta={"candidates": len(result.measurements)},
        ),
        persist=persist,
    )
    return result


# ---------------------------------------------------------------------------
# Partial contractions (dimension-tree edges)
# ---------------------------------------------------------------------------

def tune_partial(
    node: jax.Array,
    factors: Sequence[jax.Array],
    modes: Sequence[int],
    drop: Sequence[int],
    has_rank: bool,
    *,
    ctx: ExecutionContext | None = None,
    memory: Memory | None = None,
    cache: PlanCache | None = None,
    metric: str = "auto",
    interpret: bool | None = None,
    force: bool = False,
    persist: bool = True,
    warmup: int = 1,
    reps: int = 3,
    max_plans: int = 8,
) -> TuneResult:
    """Search + persist the winner for one dimension-tree edge
    (``kind="partial"`` cache entries — what ``contract_partial`` with
    ``backend="auto"`` resolves against).

    ``ctx`` supplies ``memory``/``interpret``/cache-handle defaults
    (explicit arguments win). Candidates: einsum vs the pallas partial
    kernels with the analytic plan and its perturbations. Same metric
    semantics as :func:`search`; idempotent like :func:`tune_mttkrp`.
    """
    from ..engine import execute as engine_execute  # call-time: layer cycle

    if ctx is not None:
        memory = memory if memory is not None else ctx.memory
        interpret = interpret if interpret is not None else ctx.interpret
        cache = cache if cache is not None else ctx.plan_cache()
    metric = _resolve_metric(metric)
    cache = cache if cache is not None else default_cache()
    mem = memory or Memory.tpu_vmem(itemsize=node.dtype.itemsize)
    modes = tuple(modes)
    drop = tuple(drop)
    keep = tuple(m for m in modes if m not in drop)
    pos = {m: i for i, m in enumerate(modes)}
    canon_shape = (
        math.prod(node.shape[pos[m]] for m in keep) if keep else 1,
    ) + tuple(node.shape[pos[m]] for m in drop)
    rank = factors[drop[0]].shape[1]
    key = cache_key(
        canon_shape, rank, 0, node.dtype, mem, kind="partial"
    )
    if not force:
        entry = cache.get(key)
        if entry is not None:
            winner = Candidate(entry.backend, plan=entry.to_plan())
            best = Measurement(
                winner, walltime_us=entry.walltime_us,
                modeled_bytes=entry.modeled_bytes, score=entry.score,
            )
            return TuneResult(
                key, winner, [best], entry.metric, cache_hit=True
            )

    cands = [Candidate("einsum")]
    if len(canon_shape) >= 3:
        cands += [
            Candidate("pallas", plan=p)
            for p in candidate_plans(
                canon_shape, rank, mem, node.dtype.itemsize,
                x_has_rank=has_rank, max_plans=max_plans,
            )
        ]

    def tm_bytes(c):
        return int(
            c.plan.traffic_model(
                canon_shape, rank, node.dtype.itemsize
            )["total_bytes"]
        )

    timed, modeled_only = _split_for_metric(cands, metric, tm_bytes)

    reference = engine_execute.contract_partial(
        node, factors, modes, drop, has_rank,
        ctx=ExecutionContext.create(backend="einsum"),
    )
    jax.block_until_ready(reference)

    def call_for(c):
        c_ctx = ExecutionContext.create(
            backend=c.backend, interpret=interpret
        )

        def call():
            return engine_execute.contract_partial(
                node, factors, modes, drop, has_rank, ctx=c_ctx,
                plan=c.plan,
            )

        return call

    measurements = [
        _measure_one(
            c, call_for(c), reference=reference, warmup=warmup, reps=reps,
            modeled_bytes=tm_bytes(c) if c.plan is not None else None,
        )
        for c in timed
    ]
    measurements += [
        Measurement(c, modeled_bytes=tm_bytes(c)) for c in modeled_only
    ]
    ok = [m for m in measurements if m.ok and math.isfinite(m.walltime_us)]
    if not ok:
        raise RuntimeError(f"no candidate survived measurement for {key}")
    _assign_scores(measurements, metric)
    winner = min(ok, key=lambda m: m.walltime_us)
    cache.put(
        key,
        CacheEntry(
            backend=winner.candidate.backend,
            plan=(
                plan_to_dict(winner.candidate.plan)
                if winner.candidate.plan is not None else None
            ),
            metric=metric,
            score=winner.score,
            walltime_us=winner.walltime_us,
            modeled_bytes=winner.modeled_bytes,
            meta={"candidates": len(measurements)},
        ),
        persist=persist,
    )
    return TuneResult(key, winner.candidate, measurements, metric)


# ---------------------------------------------------------------------------
# Multi-TTM (kind="multi_ttm" cache entries; engine.execute.multi_ttm)
# ---------------------------------------------------------------------------

def _multi_ttm_plan_candidates(
    canon_shape: Sequence[int],
    kernel_ranks: Sequence[int],
    memory: Memory,
    itemsize: int = 4,
    *,
    max_plans: int = 8,
) -> list[MultiTTMPlan]:
    """Analytic plan + halved/doubled per-axis perturbations (Eq-9-feasible
    only) — the Multi-TTM counterpart of :func:`candidate_plans` (the
    Tucker ranks are structural, never perturbed)."""
    base = choose_multi_ttm_blocks(
        canon_shape, kernel_ranks, itemsize, memory=memory
    )
    plans = [base]
    axes = 1 + len(base.block_contract)
    for axis in range(axes):
        for num, den in ((1, 2), (2, 1)):
            bi = base.block_i
            bc = list(base.block_contract)
            if axis == 0:
                bi = max(1, bi * num // den)
            else:
                bc[axis - 1] = max(1, bc[axis - 1] * num // den)
            cand = MultiTTMPlan(bi, tuple(bc), base.ranks)
            if cand.fits(memory):
                plans.append(cand)
    seen: set[tuple] = set()
    unique: list[MultiTTMPlan] = []
    for p in plans:
        sig = (p.block_i, p.block_contract)
        if sig not in seen:
            seen.add(sig)
            unique.append(p)
    return unique[:max_plans]


def tune_multi_ttm(
    x: jax.Array,
    matrices: Sequence[jax.Array],
    keep: int | None,
    *,
    ctx: ExecutionContext | None = None,
    memory: Memory | None = None,
    cache: PlanCache | None = None,
    metric: str = "auto",
    interpret: bool | None = None,
    force: bool = False,
    persist: bool = True,
    warmup: int = 1,
    reps: int = 3,
    max_plans: int = 8,
) -> TuneResult:
    """Search + persist the winner for one Multi-TTM problem
    (``kind="multi_ttm"`` cache entries — what ``multi_ttm`` with
    ``backend="auto"`` resolves against).

    Candidates: einsum, the uniform-b blocked_host schedule, and the
    blocked Kronecker kernel with the analytic plan and its
    perturbations. Same metric semantics as :func:`search`; idempotent
    like :func:`tune_mttkrp`.
    """
    from ..engine import execute as engine_execute  # call-time: layer cycle
    from ..core.bounds import multi_ttm_best_block_size

    if ctx is not None:
        memory = memory if memory is not None else ctx.memory
        interpret = interpret if interpret is not None else ctx.interpret
        cache = cache if cache is not None else ctx.plan_cache()
    metric = _resolve_metric(metric)
    cache = cache if cache is not None else default_cache()
    mem = memory or Memory.tpu_vmem(itemsize=x.dtype.itemsize)
    keep_key = -1 if keep is None else keep
    lead = 0 if keep is None else keep
    canon = (x.shape[lead],) + tuple(
        s for k, s in enumerate(x.shape) if k != lead
    )
    ranks = tuple(
        m.shape[1] for k, m in enumerate(matrices) if k != keep
    )
    kernel_ranks = ranks[1:] if keep is None else ranks
    key = cache_key(canon, ranks, keep_key, x.dtype, mem, kind="multi_ttm")
    if not force:
        entry = cache.get(key)
        if entry is not None:
            winner = Candidate(
                entry.backend, plan=entry.to_plan(), block=entry.block
            )
            best = Measurement(
                winner, walltime_us=entry.walltime_us,
                modeled_bytes=entry.modeled_bytes, score=entry.score,
            )
            return TuneResult(
                key, winner, [best], entry.metric, cache_hit=True
            )

    cands = [Candidate("einsum")]
    # kept-mode-first oracle convention: N dims pair with N-1 contracted
    # ranks (the lead mode plays the kept role for the full core)
    abstract_b = multi_ttm_best_block_size(
        canon, kernel_ranks, Memory.abstract(mem.budget_words).budget_words
    )
    cands.append(Candidate("blocked_host", block=abstract_b))
    if len(canon) >= 3:
        cands += [
            Candidate("pallas", plan=p)
            for p in _multi_ttm_plan_candidates(
                canon, kernel_ranks, mem, x.dtype.itemsize,
                max_plans=max_plans,
            )
        ]

    def tm_bytes(c):
        return int(
            c.plan.traffic_model(canon, x.dtype.itemsize)["total_bytes"]
        )

    timed, modeled_only = _split_for_metric(cands, metric, tm_bytes)

    reference = engine_execute.multi_ttm(
        x, matrices, keep,
        ctx=ExecutionContext.create(backend="einsum"),
    )
    jax.block_until_ready(reference)

    def call_for(c):
        c_ctx = ExecutionContext.create(
            backend=c.backend, interpret=interpret
        )

        def call():
            return engine_execute.multi_ttm(
                x, matrices, keep, ctx=c_ctx, plan=c.plan, block=c.block
            )

        return call

    measurements = [
        _measure_one(
            c, call_for(c), reference=reference, warmup=warmup, reps=reps,
            modeled_bytes=tm_bytes(c) if c.plan is not None else None,
        )
        for c in timed
    ]
    measurements += [
        Measurement(c, modeled_bytes=tm_bytes(c)) for c in modeled_only
    ]
    ok = [m for m in measurements if m.ok and math.isfinite(m.walltime_us)]
    if not ok:
        raise RuntimeError(f"no candidate survived measurement for {key}")
    _assign_scores(measurements, metric)
    winner = min(ok, key=lambda m: m.walltime_us)
    cache.put(
        key,
        CacheEntry(
            backend=winner.candidate.backend,
            plan=(
                plan_to_dict(winner.candidate.plan)
                if winner.candidate.plan is not None else None
            ),
            block=winner.candidate.block,
            metric=metric,
            score=winner.score,
            walltime_us=winner.walltime_us,
            modeled_bytes=winner.modeled_bytes,
            meta={"candidates": len(measurements)},
        ),
        persist=persist,
    )
    return TuneResult(key, winner.candidate, measurements, metric)


# ---------------------------------------------------------------------------
# backend="auto" resolution (cache hit -> tuned; miss -> model-best)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Resolved:
    """What ``backend='auto'`` decided for one problem."""

    backend: str
    plan: BlockPlan | None
    variant: str | None
    block: int | None
    cache_hit: bool
    key: str


def _count_cache(entry) -> None:
    """Tune-cache hit/miss telemetry (always-on, like the dispatch
    counter — registry reads are bracketed with snapshot()/delta())."""
    from ..observe.metrics import (
        TUNE_CACHE_HITS,
        TUNE_CACHE_MISSES,
        registry,
    )

    registry().inc(
        TUNE_CACHE_HITS if entry is not None else TUNE_CACHE_MISSES
    )


def resolve(
    shape: Sequence[int],
    rank: int,
    mode: int,
    dtype,
    memory: Memory | None = None,
    *,
    kind: str = "mttkrp",
    x_has_rank: bool = False,
    cache: PlanCache | None = None,
) -> Resolved:
    """Cache hit → the tuned configuration, exactly as persisted. Miss →
    the analytic model-best: pallas + ``choose_blocks`` on TPU, einsum on
    hosts (where interpret-mode kernels are strictly slower).

    Pure Python over static shapes — safe at trace time.
    """
    itemsize = jnp.dtype(dtype).itemsize
    mem = memory or Memory.tpu_vmem(itemsize=itemsize)
    key = cache_key(shape, rank, mode, dtype, mem, kind=kind)
    cache = cache if cache is not None else default_cache()
    entry = cache.get(key)
    _count_cache(entry)
    if entry is not None:
        return Resolved(
            entry.backend, entry.to_plan(), entry.variant, entry.block,
            True, key,
        )
    if jax.default_backend() == "tpu" and len(shape) >= 3:
        plan = choose_blocks(
            shape, rank, itemsize, memory=mem, x_has_rank=x_has_rank
        )
        return Resolved("pallas", plan, None, None, False, key)
    return Resolved("einsum", None, None, None, False, key)


def resolve_multi_ttm(
    canon_shape: Sequence[int],
    ranks: Sequence[int],
    keep_key: int,
    dtype,
    memory: Memory | None = None,
    *,
    cache: PlanCache | None = None,
) -> Resolved:
    """``backend="auto"`` resolution for one Multi-TTM problem
    (``kind="multi_ttm"``): cache hit → the tuned configuration exactly;
    miss → pallas + the analytic :func:`choose_multi_ttm_blocks` plan on
    TPU, einsum on hosts.  ``canon_shape`` is kept-mode-first;
    ``ranks`` are *all* contracted ranks (the problem identity);
    ``keep_key`` is the kept mode, or ``-1`` for the full core (whose
    kernel contracts the trailing modes only, so its plan uses
    ``ranks[1:]``).  Pure Python over static shapes — trace-safe.
    """
    itemsize = jnp.dtype(dtype).itemsize
    mem = memory or Memory.tpu_vmem(itemsize=itemsize)
    key = cache_key(
        canon_shape, tuple(ranks), keep_key, dtype, mem, kind="multi_ttm"
    )
    cache = cache if cache is not None else default_cache()
    entry = cache.get(key)
    _count_cache(entry)
    if entry is not None:
        return Resolved(
            entry.backend, entry.to_plan(), entry.variant, entry.block,
            True, key,
        )
    if jax.default_backend() == "tpu" and len(canon_shape) >= 3:
        kernel_ranks = tuple(ranks)[1:] if keep_key == -1 else tuple(ranks)
        plan = choose_multi_ttm_blocks(
            canon_shape, kernel_ranks, itemsize, memory=mem
        )
        return Resolved("pallas", plan, None, None, False, key)
    return Resolved("einsum", None, None, None, False, key)


# ---------------------------------------------------------------------------
# Sweep schedule (kind="sweep" cache entries; core.cp_als sweep="auto")
# ---------------------------------------------------------------------------

def _sweep_pass_bytes(shape: Sequence[int], rank: int, itemsize: int,
                      schedule: str) -> int:
    """Modeled streaming traffic of one ALS sweep's MTTKRP chain.

    ``per_mode`` re-reads the tensor once per mode (N passes).  ``fused``
    reads it twice (P' + the final full MTTKRP) and instead streams the
    rank-augmented partial ``P'`` once to write it and once per middle
    mode to contract it — the arXiv:1708.08976 mode-reuse trade."""
    n = len(shape)
    x_words = math.prod(shape)
    if schedule == "per_mode":
        return n * x_words * itemsize
    p_words = math.prod(shape[:-1]) * rank
    # 2 tensor passes + P' written once + P' read for B0 and each middle mode
    return (2 * x_words + p_words * (n - 1)) * itemsize


def tune_sweep(
    x: jax.Array,
    rank: int,
    *,
    ctx: ExecutionContext | None = None,
    factors: Sequence[jax.Array] | None = None,
    memory: Memory | None = None,
    cache: PlanCache | None = None,
    metric: str = "auto",
    interpret: bool | None = None,
    force: bool = False,
    persist: bool = True,
    warmup: int = 1,
    reps: int = 3,
    rtol: float = 5e-3,
) -> TuneResult:
    """Measure one ALS sweep's MTTKRP chain under the fused (mode-reuse)
    vs the per-mode schedule, persist the winner (``kind="sweep"`` cache
    entries — what ``cp_als(sweep="auto")`` resolves against).

    The chain runs with *fixed* factors, under which every fused-schedule
    B equals the corresponding full MTTKRP — so the fused candidate is
    verified against the per-mode chain, and the timing compares exactly
    the work the schedule changes (the Gram/solve/normalize part is
    identical either way). ``metric="walltime"`` times both chains;
    ``metric="traffic"`` (the CPU default) ranks by the modeled pass
    bytes (:func:`_sweep_pass_bytes`). Idempotent like
    :func:`tune_mttkrp`.
    """
    from dataclasses import replace as dc_replace

    from ..engine import execute as engine_execute  # call-time: layer cycle
    from ..engine.sweep import fused_als_sweep

    if ctx is not None:
        memory = memory if memory is not None else ctx.memory
        interpret = interpret if interpret is not None else ctx.interpret
        cache = cache if cache is not None else ctx.plan_cache()
    metric = _resolve_metric(metric)
    cache = cache if cache is not None else default_cache()
    mem = memory or Memory.tpu_vmem(itemsize=x.dtype.itemsize)
    key = cache_key(x.shape, rank, -1, x.dtype, mem, kind="sweep")
    if not force:
        entry = cache.get(key)
        if entry is not None:
            winner = Candidate(entry.backend, variant=entry.variant)
            best = Measurement(
                winner, walltime_us=entry.walltime_us,
                modeled_bytes=entry.modeled_bytes, score=entry.score,
            )
            return TuneResult(
                key, winner, [best], entry.metric, cache_hit=True
            )

    if factors is None:
        ks = jax.random.split(jax.random.PRNGKey(0), x.ndim)
        factors = [
            jax.random.normal(k, (s, rank), x.dtype)
            for k, s in zip(ks, x.shape)
        ]
    factors = list(factors)
    if ctx is None:
        measure_ctx = ExecutionContext.create(
            backend="auto", interpret=interpret,
        )
    else:
        # the chains replay the already-cached per-contraction decisions;
        # tune=False stops the per-mode searches from re-entering here
        measure_ctx = dc_replace(ctx.local(), tune=False)
    n = x.ndim

    def per_mode_chain():
        return [
            engine_execute.mttkrp(x, factors, m, ctx=measure_ctx)
            for m in range(n)
        ]

    def fused_chain():
        out: list[jax.Array] = []

        def keep(mode, b):
            out.append(b)
            return factors[mode]

        fs = list(factors)
        fused_als_sweep(x, fs, keep, ctx=measure_ctx)
        return out

    backend_tag = ctx.backend if ctx is not None else "auto"
    cands = {
        "per_mode": (Candidate(backend_tag, variant="per_mode"),
                     per_mode_chain),
        "fused": (Candidate(backend_tag, variant="fused"), fused_chain),
    }
    reference = per_mode_chain()
    jax.block_until_ready(reference)
    measurements: list[Measurement] = []
    for schedule, (cand, chain) in cands.items():
        modeled = _sweep_pass_bytes(
            x.shape, rank, x.dtype.itemsize, schedule
        )
        m = Measurement(cand, modeled_bytes=modeled)
        try:
            got = chain()
            jax.block_until_ready(got)
            for g, r in zip(got, reference):
                err = float(jnp.max(jnp.abs(g - r)))
                scale = float(jnp.max(jnp.abs(r))) + 1e-30
                if not math.isfinite(err) or err > rtol * scale:
                    raise AssertionError(
                        f"maxerr={err:.3e} (scale {scale:.3e})"
                    )
            if metric == "walltime":
                m.walltime_us = _time_call(chain, warmup, reps)
                m.score = m.walltime_us
            else:
                m.score = float(modeled)
        except Exception as e:  # noqa: BLE001 - a failing schedule loses
            m.ok = False
            m.error = f"{type(e).__name__}: {e}"
        measurements.append(m)
    ok = [m for m in measurements if m.ok and math.isfinite(m.score)]
    if not ok:
        raise RuntimeError(f"no sweep schedule survived measurement for {key}")
    winner = min(ok, key=lambda m: m.score)
    cache.put(
        key,
        CacheEntry(
            backend=backend_tag,
            variant=winner.candidate.variant,
            metric=metric,
            score=winner.score,
            walltime_us=winner.walltime_us,
            modeled_bytes=winner.modeled_bytes,
            meta={"candidates": len(measurements)},
        ),
        persist=persist,
    )
    return TuneResult(key, winner.candidate, measurements, metric)


def resolve_sweep(
    shape: Sequence[int],
    rank: int,
    dtype,
    memory: Memory | None = None,
    *,
    cache: PlanCache | None = None,
) -> Resolved:
    """``sweep="auto"`` resolution: cache hit → the tuned schedule
    (``variant`` is ``"fused"`` or ``"per_mode"``); miss → ``"fused"``
    for 3-way-and-up tensors (2 tensor passes strictly beat N in the
    pass model), ``"per_mode"`` below that (nothing to reuse). Pure
    Python over static shapes — trace-safe."""
    itemsize = jnp.dtype(dtype).itemsize
    mem = memory or Memory.tpu_vmem(itemsize=itemsize)
    key = cache_key(shape, rank, -1, dtype, mem, kind="sweep")
    cache = cache if cache is not None else default_cache()
    entry = cache.get(key)
    _count_cache(entry)
    if entry is not None:
        return Resolved(
            entry.backend, entry.to_plan(), entry.variant, entry.block,
            True, key,
        )
    variant = "fused" if len(shape) >= 3 else "per_mode"
    return Resolved("auto", None, variant, None, False, key)
