"""CP decomposition drivers: ALS and gradient-based (the paper's §II-A
application context — both are bottlenecked by MTTKRP).

``cp_als``  — alternating least squares with the standard Gram/Hadamard
normal-equations solve; the per-mode MTTKRP may run through any backend
(naive / einsum / blocked / Pallas kernel / distributed Alg 3/4), selected
by the :class:`~repro.engine.context.ExecutionContext` (or injected via
``mttkrp_fn``).

``cp_gradient`` — full-gradient descent (Adam) on 0.5*||X - [[A]]||_F^2 with
the analytic gradient  dL/dA_n = A_n * Γ_n - MTTKRP(X, A, n), Γ_n the
Hadamard product of the other Grams — again MTTKRP-bottlenecked.

Both use the efficient-fit identity
    ||X - recon||^2 = ||X||^2 - 2<B^(N-1), A^(N-1)> + 1^T (Γ ∘ A_N^T A_N) 1
so the full tensor is reconstructed only implicitly.

Configuration: both drivers take ``ctx: ExecutionContext`` — one object
carrying backend/memory/interpret/tune and the Distribution sub-config
(mesh/grid/procs). The legacy kwargs still work for one release through
the deprecation shim; all option validation (backend names, tune x
distributed, mttkrp_fn x distributed, ...) lives in
:mod:`repro.engine.context`, not here.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Callable, Sequence

import jax
import jax.numpy as jnp

from .tensor import frob_norm, random_factors

if TYPE_CHECKING:  # engine imports stay call-time-only (core <-> engine cycle)
    from ..engine.context import ExecutionContext

MttkrpFn = Callable[[jax.Array, Sequence[jax.Array], int], jax.Array]


@dataclass
class CPResult:
    """A Kruskal-form decomposition: column-normalized ``factors`` plus the
    column scales ``weights`` (λ).  The scales live ONLY here — they are
    never also folded into a factor, so reconstruction applies λ exactly
    once: ``tensor_from_factors(factors, weights)`` (or
    :meth:`reconstruct`)."""

    factors: list[jax.Array]
    weights: jax.Array
    fits: list[float] = field(default_factory=list)

    @property
    def final_fit(self) -> float:
        return self.fits[-1] if self.fits else float("nan")

    def reconstruct(self) -> jax.Array:
        from .tensor import tensor_from_factors

        return tensor_from_factors(self.factors, self.weights)


def _grams(factors: Sequence[jax.Array]) -> list[jax.Array]:
    return [f.T @ f for f in factors]


def _hadamard_except(grams: Sequence[jax.Array], skip: int) -> jax.Array:
    rank = grams[0].shape[0]
    out = jnp.ones((rank, rank), grams[0].dtype)
    for k, g in enumerate(grams):
        if k != skip:
            out = out * g
    return out


def _fit(normx: jax.Array, b_last: jax.Array, a_last: jax.Array,
         gram_had_all: jax.Array) -> jax.Array:
    """1 - ||X - recon|| / ||X|| via the inner-product identity."""
    inner = jnp.sum(b_last * a_last)
    norm_recon_sq = jnp.sum(gram_had_all)
    err_sq = jnp.maximum(normx**2 - 2 * inner + norm_recon_sq, 0.0)
    return 1.0 - jnp.sqrt(err_sq) / jnp.maximum(normx, 1e-30)


def cp_als(
    x: jax.Array,
    rank: int,
    n_iters: int = 20,
    key: jax.Array | None = None,
    init_factors: Sequence[jax.Array] | None = None,
    mttkrp_fn: MttkrpFn | None = None,
    use_dimension_tree: bool = False,
    tol: float = 0.0,
    *,
    sweep: str | None = None,
    ctx: "ExecutionContext | None" = None,
    backend=None,
    memory=None,
    interpret=None,
    tune=None,
    distributed=None,
    mesh=None,
    grid=None,
    procs=None,
) -> CPResult:
    """CP-ALS. One sweep = for each mode n: B = MTTKRP; solve the normal
    equations A_n = B (Γ_n)^+; column-normalize into weights λ.

    Every MTTKRP goes through the engine under ``ctx``: the backend
    selects einsum / blocked_host / pallas — or ``"auto"`` to resolve
    each contraction through the autotuner's plan cache (``ctx.tune``
    searches and persists on the first sweep's misses; later sweeps and
    runs replay the tuned plans). A custom ``mttkrp_fn`` (e.g. a
    distributed Alg 3/4 shard_map callable) overrides the engine for the
    plain path.

    ``sweep`` selects the sweep schedule: ``"per_mode"`` (the plain N-pass
    Gauss-Seidel chain), ``"dimtree"`` (binary dimension-tree reuse, same
    as ``use_dimension_tree=True``), ``"fused"`` (the arXiv:1708.08976
    mode-reuse schedule — 2 tensor passes per sweep, single-dispatch
    (B0, P') pair on the pallas backend; see
    :func:`repro.engine.sweep.fused_als_sweep`), or ``"auto"`` (resolve
    fused-vs-per-mode through the tune cache under ``kind="sweep"`` keys;
    ``ctx.tune`` measures both on the first call and persists the
    winner). All schedules are Gauss-Seidel exact. Default: derived from
    ``use_dimension_tree``.

    ``ctx.distribution`` (or the legacy ``distributed=True`` /
    ``mesh``/``grid``/``procs`` kwargs) runs the stationary-tensor sweep
    driver instead
    (:func:`repro.distributed.cp_als_parallel.cp_als_parallel`): X is
    block-distributed over an automatically selected Eq (12)-optimal
    processor grid and each sweep is one shard_map program whose local
    MTTKRPs still go through the engine backend."""
    from ..engine.context import (
        UNSET,
        check_driver_options,
        context_from_legacy,
    )

    legacy = {
        "backend": backend, "memory": memory, "interpret": interpret,
        "tune": tune, "distributed": distributed, "mesh": mesh,
        "grid": grid, "procs": procs,
    }
    ctx = context_from_legacy(
        "repro.cp_als", ctx,
        {k: (UNSET if v is None else v) for k, v in legacy.items()},
    )
    check_driver_options(
        ctx, mttkrp_fn=mttkrp_fn, use_dimension_tree=use_dimension_tree
    )
    if sweep is not None:
        if sweep not in ("per_mode", "dimtree", "fused", "auto"):
            raise ValueError(
                f"unknown sweep {sweep!r}; expected 'per_mode', 'dimtree', "
                f"'fused', or 'auto'"
            )
        if use_dimension_tree and sweep != "dimtree":
            raise ValueError(
                f"sweep={sweep!r} conflicts with use_dimension_tree=True "
                f"(pass only one of the two)"
            )
        if ctx.is_distributed and sweep != "per_mode":
            raise ValueError(
                f"sweep={sweep!r} is not supported on the distributed path "
                f"(the stationary sweep already amortizes factor gathers; "
                f"overlap='ring' is its comm/compute-overlap knob)"
            )
    if ctx.is_distributed:
        from ..distributed.cp_als_parallel import cp_als_parallel

        return cp_als_parallel(
            x, rank, n_iters, key=key, init_factors=init_factors,
            ctx=ctx, tol=tol,
        )
    n = x.ndim
    if init_factors is not None:
        factors = [jnp.asarray(f) for f in init_factors]
    else:
        key = key if key is not None else jax.random.PRNGKey(0)
        factors = random_factors(key, x.shape, rank, x.dtype)
    normx = frob_norm(x)
    grams = _grams(factors)
    fits: list[float] = []
    weights = jnp.ones((rank,), x.dtype)
    state: dict = {}

    def update(mode: int, b: jax.Array) -> jax.Array:
        nonlocal weights
        gamma = _hadamard_except(grams, mode)
        # solve A_n Γ = B  (Γ is PSD; ridge for rank-deficiency safety)
        solve_dtype = jnp.float32 if x.dtype != jnp.float64 else x.dtype
        gamma32 = gamma.astype(solve_dtype)
        # ridge scaled to f32 conditioning; essential when rank exceeds the
        # true tensor rank (Γ singular)
        ridge = 1e-5 * jnp.trace(gamma32) / rank + 1e-12
        a_new = jnp.linalg.solve(
            gamma32 + ridge * jnp.eye(rank, dtype=solve_dtype),
            b.astype(solve_dtype).T,
        ).T.astype(x.dtype)
        # column normalization
        lam = jnp.maximum(jnp.linalg.norm(a_new, axis=0), 1e-30)
        a_new = a_new / lam
        weights = lam.astype(x.dtype)
        grams[mode] = a_new.T @ a_new
        state.update(b_last=b, a_last=a_new * weights, g_last=mode)
        return a_new

    from ..engine import execute as engine_execute
    from ..engine.sweep import fused_als_sweep
    from ..engine.tree import dimtree_als_sweep

    if mttkrp_fn is None:
        def mttkrp_fn(t, fs, mode):
            return engine_execute.mttkrp(t, fs, mode, ctx=ctx)

    schedule = sweep if sweep is not None else (
        "dimtree" if use_dimension_tree else "per_mode"
    )
    if schedule == "auto":
        from ..tune.search import _is_concrete, resolve_sweep, tune_sweep

        if ctx.tune and _is_concrete(x):
            tune_sweep(
                x, rank, ctx=ctx, memory=ctx.memory,
                interpret=ctx.interpret, cache=ctx.plan_cache(),
            )
        schedule = resolve_sweep(
            x.shape, rank, x.dtype, ctx.memory, cache=ctx.plan_cache()
        ).variant

    from ..observe import trace as _otrace

    for it in range(n_iters):
        if schedule == "dimtree":
            dimtree_als_sweep(x, factors, update, ctx=ctx)
        elif schedule == "fused":
            fused_als_sweep(x, factors, update, ctx=ctx)
        else:
            for mode in range(n):
                factors[mode] = update(mode, mttkrp_fn(x, factors, mode))
        gram_full = _hadamard_except(grams, -1) * jnp.outer(weights, weights)
        b_last, a_last = state["b_last"], state["a_last"]
        fit = float(_fit(normx, b_last, a_last, gram_full))
        fits.append(fit)
        delta = abs(fits[-1] - fits[-2]) if it > 0 else None
        converged = bool(tol and it > 0 and delta < tol)
        # float(_fit) above forces concreteness, so this loop never runs
        # under a jax trace — no tracer guard needed here.
        if _otrace.should_record(ctx.observe):
            _otrace.record_event(
                "cp_als_iter",
                shape=list(x.shape),
                rank=int(rank),
                schedule=schedule,
                it=it,
                fit=fit,
                fit_delta=delta,
                weights=[float(w) for w in weights],
                converged=converged,
            )
        if converged:
            break
    # Kruskal form: factors stay column-normalized, λ is returned ONLY in
    # CPResult.weights.  (It used to be folded into the last-updated factor
    # *and* returned, so reconstructing with weights scaled by λ twice.)
    return CPResult(factors, weights, fits)


def cp_gradient(
    x: jax.Array,
    rank: int,
    n_iters: int = 200,
    lr: float = 0.05,
    key: jax.Array | None = None,
    mttkrp_fn: MttkrpFn | None = None,
    *,
    ctx: "ExecutionContext | None" = None,
    backend=None,
    memory=None,
    interpret=None,
    tune=None,
) -> CPResult:
    """Gradient-based CP (Adam on the analytic MTTKRP gradient).

    Engine parity with :func:`cp_als`: every MTTKRP goes through
    ``engine.execute.mttkrp`` under the same ``ctx``
    (backend/memory/interpret/tune). An explicit ``mttkrp_fn`` still
    overrides."""
    from ..engine.context import UNSET, context_from_legacy

    legacy = {
        "backend": backend, "memory": memory, "interpret": interpret,
        "tune": tune,
    }
    ctx = context_from_legacy(
        "repro.cp_gradient", ctx,
        {k: (UNSET if v is None else v) for k, v in legacy.items()},
    )
    n = x.ndim
    if mttkrp_fn is None:
        from ..engine import execute as engine_execute

        def mttkrp_fn(t, fs, mode):
            return engine_execute.mttkrp(t, fs, mode, ctx=ctx)
    key = key if key is not None else jax.random.PRNGKey(0)
    factors = random_factors(key, x.shape, rank, x.dtype)
    normx = frob_norm(x)
    m = [jnp.zeros_like(f) for f in factors]
    v = [jnp.zeros_like(f) for f in factors]
    b1, b2, eps = 0.9, 0.999, 1e-8
    fits: list[float] = []
    for it in range(1, n_iters + 1):
        grams = _grams(factors)
        grads = []
        for mode in range(n):
            b = mttkrp_fn(x, factors, mode)
            gamma = _hadamard_except(grams, mode)
            grads.append(factors[mode] @ gamma - b)
        for k in range(n):
            m[k] = b1 * m[k] + (1 - b1) * grads[k]
            v[k] = b2 * v[k] + (1 - b2) * jnp.square(grads[k])
            mhat = m[k] / (1 - b1**it)
            vhat = v[k] / (1 - b2**it)
            factors[k] = factors[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
        if it % 10 == 0 or it == n_iters:
            grams = _grams(factors)
            b = mttkrp_fn(x, factors, n - 1)
            gram_full = _hadamard_except(grams, -1)
            fits.append(float(_fit(normx, b, factors[n - 1], gram_full)))
    return CPResult(factors, jnp.ones((rank,), x.dtype), fits)
