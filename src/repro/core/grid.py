"""Processor-grid choosers for Algorithms 3 and 4.

The paper prescribes (§V-C3, §V-D3, Thm 6.2):

  * Alg 3:  P_k ≈ I_k / (I/P)^{1/N}            (no rank axis, P_0 = 1)
  * Alg 4:  P_0 ≈ (NR)^{N/(2N-1)} / (I/P)^{(N-1)/(2N-1)},
            P_k ≈ I_k / (I·P_0/P)^{1/N}

subject to integrality and ``P_0 · Π P_k = P``. We provide:

  * ``paper_grid``      — the paper's prescription, rounded to a feasible
                          integer factorization (nearest divisors).
  * ``optimal_grid``    — exact minimizer of the Eq (16) cost over all
                          divisor tuples of P (beyond-paper: an exhaustive
                          integer search instead of the asymptotic rule; it
                          can only be <= the paper grid's cost).

Both return ``(p0, (p1, ..., pN))``.
"""

from __future__ import annotations

import math
from functools import lru_cache
from typing import Sequence

from .bounds import par_general_cost
from .tensor import total_size


@lru_cache(maxsize=None)
def _divisors(p: int) -> tuple[int, ...]:
    out = [d for d in range(1, p + 1) if p % d == 0]
    return tuple(out)


def _factorization_tuples(p: int, n: int) -> list[tuple[int, ...]]:
    """All ordered tuples (f_1..f_n) of positive ints with prod = p."""
    if n == 1:
        return [(p,)]
    out = []
    for d in _divisors(p):
        for rest in _factorization_tuples(p // d, n - 1):
            out.append((d,) + rest)
    return out


def _nearest_grid(dims: Sequence[int], target: Sequence[float], p: int) -> tuple[int, ...]:
    """Feasible integer grid with prod = p closest (log-distance) to target."""
    n = len(dims)
    best, best_err = None, float("inf")
    for cand in _factorization_tuples(p, n):
        if any(c > d for c, d in zip(cand, dims)):
            continue
        err = sum(
            (math.log(c) - math.log(max(t, 1e-9))) ** 2
            for c, t in zip(cand, target)
        )
        if err < best_err:
            best, best_err = cand, err
    if best is None:  # fall back: allow P_k > I_k (degenerate but valid)
        for cand in _factorization_tuples(p, n):
            err = sum(
                (math.log(c) - math.log(max(t, 1e-9))) ** 2
                for c, t in zip(cand, target)
            )
            if err < best_err:
                best, best_err = cand, err
    return best


def paper_grid(
    dims: Sequence[int], rank: int, procs: int, allow_rank_axis: bool = True
) -> tuple[int, tuple[int, ...]]:
    """The paper's asymptotic prescription, rounded to integer divisors."""
    n = len(dims)
    i = total_size(dims)
    if allow_rank_axis:
        p0_target = (n * rank) ** (n / (2 * n - 1)) / (
            (i / procs) ** ((n - 1) / (2 * n - 1))
        )
    else:
        p0_target = 1.0
    # round P0 to the nearest divisor of P, clamped to [1, min(P, R)]
    p0 = min(
        _divisors(procs), key=lambda d: abs(math.log(d) - math.log(max(p0_target, 1.0)))
    )
    p0 = max(1, min(p0, rank, procs))
    while procs % p0 != 0:
        p0 -= 1
    rest = procs // p0
    targets = [d / (i * p0 / procs) ** (1 / n) for d in dims]
    grid = _nearest_grid(dims, targets, rest)
    return p0, grid


def optimal_grid(
    dims: Sequence[int], rank: int, procs: int, mode: int = 0
) -> tuple[int, tuple[int, ...]]:
    """Exhaustive minimizer of the Alg-4 cost Eq (16) over divisor tuples.

    Beyond-paper refinement: the asymptotic rule ignores constant factors and
    integrality; for modest P an exact search is cheap (P <= 4096 has <= a few
    thousand divisor tuples for N <= 4) and strictly dominates.
    """
    n = len(dims)
    best, best_cost = None, float("inf")
    for p0 in _divisors(procs):
        if p0 > rank:
            continue
        for cand in _factorization_tuples(procs // p0, n):
            if any(c > d for c, d in zip(cand, dims)):
                continue
            c = par_general_cost(dims, rank, cand, p0, mode)
            if c < best_cost:
                best, best_cost = (p0, cand), c
    if best is None:
        return paper_grid(dims, rank, procs)
    return best


def stationary_grid(dims: Sequence[int], procs: int) -> tuple[int, ...]:
    """Alg 3 grid (P0=1): P_k ≈ I_k/(I/P)^{1/N}, rounded feasibly."""
    n = len(dims)
    i = total_size(dims)
    targets = [d / (i / procs) ** (1 / n) for d in dims]
    return _nearest_grid(dims, targets, procs)
