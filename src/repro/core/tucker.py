"""Tucker decomposition drivers: HOSVD initialization and HOOI sweeps.

The Tucker/HOSVD workload is the second MTTKRP-class kernel the engine
serves (arXiv:2207.10437): every HOOI mode update is a Multi-TTM

    Y^(k) = X x_{j != k} A_j^T        (the kept-mode partial contraction)

followed by a small eigendecomposition of the unfolding Gram, and the
core is the full contraction ``G = X x_1 A_1^T ... x_N A_N^T``.  Both
run through :func:`repro.engine.execute.multi_ttm` under one
:class:`~repro.engine.context.ExecutionContext`, so the backend
(einsum / blocked_host / the Pallas Kronecker kernel / ``"auto"``) and
memory budget are chosen exactly once — the same contract the CP drivers
follow.

Fit uses the orthonormal-factor identity
``||X - [[G; A_1..A_N]]||^2 = ||X||^2 - ||G||^2``, so the full tensor is
never reconstructed during iteration.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Sequence

import jax
import jax.numpy as jnp

from .tensor import frob_norm

if TYPE_CHECKING:  # engine imports stay call-time-only (core <-> engine cycle)
    from ..engine.context import ExecutionContext


@dataclass
class TuckerResult:
    """A Tucker decomposition: ``core`` of shape ``(R_1, ..., R_N)`` and
    orthonormal ``factors`` (``A_k`` of shape ``(I_k, R_k)``, columns
    orthonormal), plus the per-sweep ``fits``."""

    core: jax.Array
    factors: list[jax.Array]
    fits: list[float] = field(default_factory=list)

    @property
    def final_fit(self) -> float:
        return self.fits[-1] if self.fits else float("nan")

    @property
    def ranks(self) -> tuple[int, ...]:
        return tuple(self.core.shape)

    def reconstruct(self) -> jax.Array:
        """Full tensor ``G x_1 A_1 ... x_N A_N``."""
        out = self.core
        for k, a in enumerate(self.factors):
            out = ttm(out, a, k, transpose=False)
        return out


def ttm(
    x: jax.Array, a: jax.Array, mode: int, transpose: bool = True
) -> jax.Array:
    """Single tensor-times-matrix: contract tensor mode ``mode`` with
    ``a`` — ``A^T`` applied (``transpose=True``, extent ``I_k -> R_k``,
    the Multi-TTM building block) or ``A`` applied (``transpose=False``,
    ``R_k -> I_k``, reconstruction direction)."""
    axes = ((mode,), (0,) if transpose else (1,))
    out = jnp.tensordot(x, a, axes=axes)
    # tensordot appends the matrix's free axis; rotate it back into place
    return jnp.moveaxis(out, -1, mode)


def _fix_signs(v: jax.Array) -> jax.Array:
    """Deterministic eigenvector sign convention: the largest-magnitude
    entry of every column is made positive (eigh's signs are arbitrary;
    pinning them keeps sequential and distributed sweeps bit-comparable)."""
    idx = jnp.argmax(jnp.abs(v), axis=0)
    signs = jnp.sign(v[idx, jnp.arange(v.shape[1])])
    return v * jnp.where(signs == 0, 1.0, signs)


def _leading_eigvecs(gram: jax.Array, r: int) -> jax.Array:
    """Top-``r`` eigenvectors of a PSD Gram (ascending eigh, reversed),
    with the deterministic sign convention."""
    _, v = jnp.linalg.eigh(gram.astype(jnp.float32))
    return _fix_signs(v[:, ::-1][:, :r])


def _unfold_rows(z: jax.Array, mode: int) -> jax.Array:
    """Mode-``mode``-rows unfolding ``(I_mode, prod rest)`` (row-Gram
    ordering is irrelevant as long as it is consistent)."""
    return jnp.moveaxis(z, mode, 0).reshape(z.shape[mode], -1)


def hosvd_init(
    x: jax.Array, ranks: Sequence[int], dtype=jnp.float32
) -> list[jax.Array]:
    """HOSVD factors: the top-``R_k`` left singular vectors of every
    unfolding ``X_(k)``, via the ``I_k x I_k`` Gram eigendecomposition."""
    factors = []
    for k, r in enumerate(ranks):
        xm = _unfold_rows(x, k)
        gram = xm @ xm.T
        factors.append(_leading_eigvecs(gram, int(r)).astype(x.dtype))
    return factors


def _check_ranks(shape: Sequence[int], ranks: Sequence[int]) -> tuple[int, ...]:
    ranks = tuple(int(r) for r in ranks)
    if len(ranks) != len(shape):
        raise ValueError(
            f"Tucker ranks {ranks} must give one rank per tensor mode "
            f"({len(shape)} for shape {tuple(shape)})"
        )
    for k, (r, d) in enumerate(zip(ranks, shape)):
        if not 1 <= r <= d:
            raise ValueError(
                f"Tucker rank R_{k}={r} out of range [1, I_{k}={d}]"
            )
    return ranks


def tucker_hooi(
    x: jax.Array,
    ranks: Sequence[int],
    n_iters: int = 10,
    *,
    ctx: "ExecutionContext | None" = None,
    init_factors: Sequence[jax.Array] | None = None,
    tol: float = 0.0,
) -> TuckerResult:
    """Tucker decomposition by HOOI (higher-order orthogonal iteration).

    One sweep = for each mode k: ``Y = multi_ttm(x, factors, keep=k)``,
    then ``A_k`` = top-``R_k`` eigenvectors of ``Y_(k) Y_(k)^T``.  Every
    Multi-TTM goes through the engine under ``ctx`` (einsum /
    blocked_host / the Pallas Kronecker kernel, or ``"auto"`` to resolve
    each contraction through the tune cache's ``kind="multi_ttm"``
    entries — a context pinned via
    ``ExecutionContext.for_problem(shape, ranks)`` replays its stored
    decisions).  A distributed context routes to the stationary-tensor
    sweep driver
    (:func:`repro.distributed.tucker_parallel.tucker_hooi_parallel`): X
    is block-distributed over a Multi-TTM-sweep-optimal processor grid
    and each sweep is one shard_map program.

    Initialization is HOSVD (``init_factors`` overrides).  ``tol`` stops
    early when the fit improvement between sweeps falls below it.
    Returns a :class:`TuckerResult` (orthonormal factors, core, fits).
    """
    from ..engine.context import ExecutionContext

    if ctx is None:
        ctx = ExecutionContext.default()
    ranks = _check_ranks(x.shape, ranks)
    if ctx.is_distributed:
        from ..distributed.tucker_parallel import tucker_hooi_parallel

        return tucker_hooi_parallel(
            x, ranks, n_iters, ctx=ctx, init_factors=init_factors, tol=tol
        )
    from ..engine import execute as engine_execute

    n = x.ndim
    if init_factors is not None:
        factors = [jnp.asarray(f) for f in init_factors]
    else:
        factors = hosvd_init(x, ranks)
    normx = frob_norm(x)
    fits: list[float] = []
    if n_iters < 1:  # HOSVD only: just project onto the initial factors
        core = engine_execute.multi_ttm(x, factors, keep=None, ctx=ctx)
        err_sq = jnp.maximum(normx**2 - frob_norm(core) ** 2, 0.0)
        fits.append(
            float(1.0 - jnp.sqrt(err_sq) / jnp.maximum(normx, 1e-30))
        )
        return TuckerResult(core, factors, fits)
    from ..observe import trace as _otrace

    for it in range(n_iters):
        for k in range(n):
            y = engine_execute.multi_ttm(x, factors, keep=k, ctx=ctx)
            ym = _unfold_rows(y, k)
            factors[k] = _leading_eigvecs(ym @ ym.T, ranks[k]).astype(x.dtype)
        # the core falls out of the last mode update: contract mode N-1
        # of its Y with the fresh A_{N-1} (no extra pass over X)
        core = ttm(y, factors[n - 1], n - 1)
        err_sq = jnp.maximum(normx**2 - frob_norm(core) ** 2, 0.0)
        fit = float(1.0 - jnp.sqrt(err_sq) / jnp.maximum(normx, 1e-30))
        fits.append(fit)
        delta = abs(fits[-1] - fits[-2]) if it > 0 else None
        converged = bool(tol and it > 0 and delta < tol)
        # float(...) above forces concreteness: never inside a jax trace.
        if _otrace.should_record(ctx.observe):
            _otrace.record_event(
                "tucker_iter",
                shape=list(x.shape),
                ranks=list(ranks),
                it=it,
                fit=fit,
                fit_delta=delta,
                converged=converged,
            )
        if converged:
            break
    return TuckerResult(core, factors, fits)
