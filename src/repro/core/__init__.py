"""Paper core: communication-optimal MTTKRP — algorithms, bounds, CP drivers.

Rouse, Ballard, Knight, "Communication Lower Bounds for Matricized Tensor
Times Khatri-Rao Product" (CS.DC 2017).
"""

from .mttkrp import mttkrp, mttkrp_naive, mttkrp_all_modes
from .krp import khatri_rao, mttkrp_via_matmul
from .blocked import mttkrp_blocked
from .cp_als import cp_als, cp_gradient, CPResult
from .tucker import tucker_hooi, hosvd_init, ttm, TuckerResult
from .dimension_tree import all_mode_mttkrp_dimtree, dimtree_als_sweep
from . import bounds, grid, simulator, tensor

__all__ = [
    "dimtree_als_sweep",
    "mttkrp",
    "mttkrp_naive",
    "mttkrp_all_modes",
    "khatri_rao",
    "mttkrp_via_matmul",
    "mttkrp_blocked",
    "cp_als",
    "cp_gradient",
    "CPResult",
    "tucker_hooi",
    "hosvd_init",
    "ttm",
    "TuckerResult",
    "all_mode_mttkrp_dimtree",
    "bounds",
    "grid",
    "simulator",
    "tensor",
]
