"""Every communication lower bound and algorithm cost formula in the paper.

All functions count *words* (the paper's unit); callers multiply by
``dtype.itemsize`` for bytes. Dimensions are 0-based tuples ``dims = (I_1,
..., I_N)``; ``I = prod(dims)``; ``R`` is the CP rank; ``M`` the fast/local
memory in words; ``P`` the processor count.

Paper map
---------
=====================  =====================================================
``seq_lb_memory``       Theorem 4.1  (Eq 4 / Eq 21)
``seq_lb_trivial``      Fact 4.1     (Eq 5 / Eq 22)
``par_lb_memory``       Corollary 4.1
``par_lb_general``      Theorem 4.2  (Eq 29)
``par_lb_stationary``   Theorem 4.3  (Eq 30)
``par_lb_combined``     Corollary 4.2 (sum form, cubical tensors)
``seq_unblocked_cost``  §V-A upper bound  W <= I + IR(N+1)
``seq_blocked_cost``    §V-B Eq (10) / Eq (19)
``blocked_feasible_b``  Eq (9)/(20):  b^N + N b <= M
``best_block_size``     largest feasible b (the paper picks b ≈ (αM)^{1/N})
``par_stationary_cost`` §V-C3 Eq (12)  (Alg 3)
``par_general_cost``    §V-D3 Eq (16)/(28)  (Alg 4)
``matmul_seq_cost``     §VI-A baseline  O(I + IR/sqrt(M))
``matmul_par_cost``     §VI-B baseline (rectangular matmul, small/large P)
=====================  =====================================================

Multi-TTM (the Tucker/HOSVD kernel, arXiv:2207.10437) has its own section
below: ``multi_ttm_seq_lb_*`` (the HBL memory bound and the trivial I/O
bound), ``multi_ttm_{un,}blocked_cost`` + ``multi_ttm_blocked_feasible_b``
(the Eq-9/Eq-10 analogs the engine's ``MultiTTMPlan`` is pinned against),
and ``par_multi_ttm_cost`` (the stationary-tensor parallel cost).
"""

from __future__ import annotations

import math
from typing import Sequence

from .tensor import total_size


# --------------------------------------------------------------------------
# Sequential lower bounds
# --------------------------------------------------------------------------

def seq_lb_memory(dims: Sequence[int], rank: int, mem: int) -> float:
    """Theorem 4.1: W >= N·I·R / 3^(2-1/N) / M^(1-1/N) - M."""
    n = len(dims)
    i = total_size(dims)
    return n * i * rank / (3 ** (2 - 1 / n)) / (mem ** (1 - 1 / n)) - mem


def seq_lb_trivial(dims: Sequence[int], rank: int, mem: int) -> float:
    """Fact 4.1: W >= I + sum_k I_k R - 2M (must touch all inputs/outputs)."""
    return total_size(dims) + sum(dims) * rank - 2 * mem


def seq_lb(dims: Sequence[int], rank: int, mem: int) -> float:
    """max of the two sequential bounds (never negative)."""
    return max(
        seq_lb_memory(dims, rank, mem), seq_lb_trivial(dims, rank, mem), 0.0
    )


# --------------------------------------------------------------------------
# Parallel lower bounds
# --------------------------------------------------------------------------

def par_lb_memory(dims: Sequence[int], rank: int, procs: int, mem: int) -> float:
    """Corollary 4.1: per-processor words >= Thm4.1 numerator / P."""
    n = len(dims)
    i = total_size(dims)
    return n * i * rank / (3 ** (2 - 1 / n)) / (procs * mem ** (1 - 1 / n)) - mem


def par_lb_general(
    dims: Sequence[int],
    rank: int,
    procs: int,
    gamma: float = 1.0,
    delta: float = 1.0,
) -> float:
    """Theorem 4.2 (Eq 29): 2(NIR/P)^{N/(2N-1)} - γI/P - δ Σ I_k R / P."""
    n = len(dims)
    i = total_size(dims)
    return (
        2 * (n * i * rank / procs) ** (n / (2 * n - 1))
        - gamma * i / procs
        - delta * sum(dims) * rank / procs
    )


def par_lb_stationary(
    dims: Sequence[int],
    rank: int,
    procs: int,
    gamma: float = 1.0,
    delta: float = 1.0,
) -> float:
    """Theorem 4.3 (Eq 30)."""
    n = len(dims)
    i = total_size(dims)
    term_a = (
        math.sqrt(2 / (3 * gamma)) * n * rank * (i / procs) ** (1 / n)
        - delta * sum(dims) * rank / procs
    )
    term_b = gamma * i / (2 * procs)
    return min(term_a, term_b)


def par_lb_combined(dims: Sequence[int], rank: int, procs: int) -> float:
    """Corollary 4.2 asymptotic form (sum of the two regimes' bounds).

    Stated for cubical tensors; we evaluate the sum form with unit constants
    as the reference lower-bound curve for the benchmarks.
    """
    n = len(dims)
    i = total_size(dims)
    return (n * i * rank / procs) ** (n / (2 * n - 1)) + n * rank * (
        i / procs
    ) ** (1 / n)


def nr_threshold_regime(dims: Sequence[int], rank: int, procs: int) -> str:
    """Which Cor 4.2 regime applies: 'rank' when NR > (I/P)^{1-1/N} (Thm 4.2
    dominates, Alg 4 with P0>1 needed) else 'stationary' (Alg 3 optimal)."""
    n = len(dims)
    i = total_size(dims)
    return "rank" if n * rank > (i / procs) ** (1 - 1 / n) else "stationary"


# --------------------------------------------------------------------------
# Sequential algorithm costs (upper bounds)
# --------------------------------------------------------------------------

def seq_unblocked_cost(dims: Sequence[int], rank: int) -> float:
    """§V-A: Algorithm 1 cost W <= I + I·R·(N+1)."""
    n = len(dims)
    i = total_size(dims)
    return i + i * rank * (n + 1)


def seq_blocked_cost(dims: Sequence[int], rank: int, block: int) -> float:
    """§V-B Eq (10)/(19): I + prod_k ceil(I_k/b) · R(N+1)·b."""
    n = len(dims)
    i = total_size(dims)
    nblocks = 1
    for d in dims:
        nblocks *= math.ceil(d / block)
    return i + nblocks * rank * (n + 1) * block


def blocked_feasible_b(n: int, block: int, mem: int) -> bool:
    """Eq (9)/(20): b^N + N·b <= M."""
    return block ** n + n * block <= mem


def best_block_size(dims: Sequence[int], mem: int) -> int:
    """Largest b with b^N + Nb <= M (paper: b ≈ (αM)^{1/N}); at least 1."""
    n = len(dims)
    b = max(1, int(mem ** (1.0 / n)))
    while b > 1 and not blocked_feasible_b(n, b, mem):
        b -= 1
    while blocked_feasible_b(n, b + 1, mem):
        b += 1
    return max(1, b)


def matmul_seq_cost(dims: Sequence[int], rank: int, mem: int, mode: int = 0) -> float:
    """§VI-A: MTTKRP via comm-optimal matmul: O(I + IR/sqrt(M)).

    (I_n x I/I_n) @ (I/I_n x R); classic matmul bound 2*prod/sqrt(M) plus
    touching inputs/outputs once. KRP formation cost (sum_{k!=n} I_k R reads,
    I/I_n * R writes) is charged: the explicit KRP must be written to slow
    memory when it exceeds M.
    """
    i = total_size(dims)
    i_n = dims[mode]
    other = i // i_n
    krp_form = sum(d for k, d in enumerate(dims) if k != mode) * rank + other * rank
    mm = 2.0 * i * rank / math.sqrt(mem) + i + other * rank + i_n * rank
    return krp_form + mm


# --------------------------------------------------------------------------
# Parallel algorithm costs (upper bounds)
# --------------------------------------------------------------------------

def par_stationary_cost(
    dims: Sequence[int], rank: int, grid: Sequence[int], mode: int = 0
) -> float:
    """§V-C3 Eq (12): per-processor words for Algorithm 3.

    sum_k (P/P_k - 1) * w_k, where w_k = max_p nnz(A_p^{(k)}) = I_k R / P for
    the load-balanced block-row distribution (factor k's rows are spread over
    the whole hyperslice of P/P_k processors, each holding I_k/P_k rows / the
    (P/P_k)-fold partition => I_k R / P entries each).
    """
    procs = 1
    for g in grid:
        procs *= g
    total = 0.0
    for k, (d, pk) in enumerate(zip(dims, grid)):
        w = math.ceil(d / pk) * rank / (procs // pk)
        total += (procs / pk - 1) * w
    return total


def par_general_cost(
    dims: Sequence[int],
    rank: int,
    grid: Sequence[int],
    p0: int,
    mode: int = 0,
) -> float:
    """§V-D3 Eq (16)/(28): per-processor words for Algorithm 4.

    (P0-1)*nnz(X_p) + sum_k (P/(P0 Pk) - 1) * w_k with the load-balanced
    distribution nnz(X_p)=I/P, w_k = I_k/P_k * R/P0 / (P/(P_k P0)).
    """
    procs = p0
    for g in grid:
        procs *= g
    i = total_size(dims)
    total = (p0 - 1) * (i / procs)
    for k, (d, pk) in enumerate(zip(dims, grid)):
        slice_sz = procs / (p0 * pk)
        w = math.ceil(d / pk) * math.ceil(rank / p0) / slice_sz
        total += (slice_sz - 1) * w
    return total


# --------------------------------------------------------------------------
# Multi-TTM (Tucker/HOSVD kernel) bounds and costs — arXiv:2207.10437
# --------------------------------------------------------------------------
#
# Multi-TTM contracts an N-way tensor X (I_1 x ... x I_N) with matrices
# A^(k) (I_k x R_k) along every mode (the Tucker core G = X x_1 A_1^T ...
# x_N A_N^T) or along every mode but one (the HOOI workhorse
# Y^(k) = X x_{j != k} A_j^T).  Al Daas, Ballard, Grigori, Kumar & Rouse
# (arXiv:2207.10437) prove the analogous communication lower bounds and
# optimal algorithms; the functions below are the repo's oracle for them,
# in the same canonical form the engine plans: ``dims`` are the tensor
# extents of the *contraction problem* (kept mode first), ``ranks`` are
# the small dimensions R_d of the contracted modes only.

def multi_ttm_seq_lb_memory(
    dims: Sequence[int], ranks: Sequence[int], mem: int
) -> float:
    """Memory-dependent sequential Multi-TTM lower bound (HBL form).

    The atomic computation is a (N + k)-dimensional loop nest of
    I * R = prod(dims) * prod(ranks) multiplies; the HBL/Loomis-Whitney
    exponents covering every loop index with the tensor (s=1/2), the
    output (s=1/2), and each matrix (s=1/2) give per-segment ops
    <= (2M)^{(k+2)/2} for k contracted modes, hence
    W >= I*R*M / (2M)^{(k+2)/2} - M (the arXiv:2207.10437 Sec. 3
    argument; for k = 1 this is the classical matmul bound
    I*R / (2M)^{1/2} up to the additive M)."""
    k = len(ranks)
    ops = total_size(dims) * total_size(ranks)
    return ops * mem / (2 * mem) ** ((k + 2) / 2) - mem


def multi_ttm_seq_lb_trivial(
    dims: Sequence[int], ranks: Sequence[int], mem: int
) -> float:
    """Trivial Multi-TTM I/O bound: touch X once, every matrix once, and
    the output once — W >= I + sum_d C_d R_d + I_keep * prod(ranks) - 2M
    (``dims[0]`` is the kept mode; ``dims[1:]`` pair with ``ranks``)."""
    mats = sum(c * r for c, r in zip(dims[1:], ranks))
    out = dims[0] * total_size(ranks)
    return total_size(dims) + mats + out - 2 * mem


def multi_ttm_seq_lb(
    dims: Sequence[int], ranks: Sequence[int], mem: int
) -> float:
    """max of the two sequential Multi-TTM bounds (never negative)."""
    return max(
        multi_ttm_seq_lb_memory(dims, ranks, mem),
        multi_ttm_seq_lb_trivial(dims, ranks, mem),
        0.0,
    )


def multi_ttm_unblocked_cost(
    dims: Sequence[int], ranks: Sequence[int]
) -> float:
    """Unblocked Multi-TTM upper bound (Algorithm-1 analog): per tensor
    entry, read one row of each matrix (sum_d R_d) and update the output
    subrow (2 * prod(ranks)): W <= I + I*(sum R_d + 2 prod R_d)."""
    i = total_size(dims)
    return i + i * (sum(ranks) + 2 * total_size(ranks))


def multi_ttm_blocked_cost(
    dims: Sequence[int], ranks: Sequence[int], block: int
) -> float:
    """Blocked Multi-TTM cost (the Eq-10 analog, arXiv:2207.10437 Sec. 5).

    One pass over the tensor, plus per b^N block: the matrix subblocks
    (b rows of each contracted matrix, b * sum R_d words) and one
    load+store of the output subblock (2 * b * prod R_d — the kept-mode
    rows of this block times the full Kronecker rank):
    W = I + prod_k ceil(I_k/b) * b * (sum R_d + 2 prod R_d)."""
    i = total_size(dims)
    nblocks = 1
    for d in dims:
        nblocks *= math.ceil(d / block)
    return i + nblocks * block * (sum(ranks) + 2 * total_size(ranks))


def multi_ttm_blocked_feasible_b(
    ndim: int, ranks: Sequence[int], block: int, mem: int
) -> bool:
    """Eq-9 analog for Multi-TTM: the blocked working set
    b^N (tensor tile) + b*sum R_d (matrix tiles) + b^{N-1}*prod R_d
    (Kronecker weight block) + b*prod R_d (output tile) must fit in M."""
    r = 1
    for x in ranks:
        r *= x
    ws = (
        block ** ndim
        + block * sum(ranks)
        + block ** (ndim - 1) * r
        + block * r
    )
    return ws <= mem


def multi_ttm_best_block_size(
    dims: Sequence[int], ranks: Sequence[int], mem: int
) -> int:
    """Largest uniform b feasible per :func:`multi_ttm_blocked_feasible_b`
    (at least 1 — callers check feasibility of the b=1 working set)."""
    n = len(dims)
    b = max(1, int(mem ** (1.0 / n)))
    while b > 1 and not multi_ttm_blocked_feasible_b(n, ranks, b, mem):
        b -= 1
    while multi_ttm_blocked_feasible_b(n, ranks, b + 1, mem):
        b += 1
    return max(1, b)


def par_multi_ttm_cost(
    dims: Sequence[int], ranks: Sequence[int], grid: Sequence[int]
) -> float:
    """Per-processor words of the stationary-tensor parallel Multi-TTM
    computing the full core on an N-way grid (arXiv:2207.10437 Sec. 5
    specialized to our X-stationary distribution): gather each matrix's
    block-rows over its mode hyperslice (the Eq-12-shaped terms), then
    all-reduce the local partial core (2(P-1)/P * prod R_k words)."""
    procs = 1
    for g in grid:
        procs *= g
    total = 0.0
    for d, pk, r in zip(dims, grid, ranks):
        w = math.ceil(d / pk) * r / (procs // pk)
        total += (procs / pk - 1) * w
    core = 1
    for r in ranks:
        core *= r
    return total + 2 * (procs - 1) / procs * core


def matmul_par_cost(dims: Sequence[int], rank: int, procs: int) -> float:
    """§VI-B: comm-optimal rectangular matmul cost for X_(n) @ KRP.

    Uses the Demmel et al. [10] three-regime model for multiplying
    (I_n x K) @ (K x R), K = I/I_n, with the paper's extreme cases:
    one large dimension (P <= K/max(I_n,R)... simplified): cost I^{1/N} R for
    small P; (I R / P)^{2/3} for large P; plus the (ignored by the paper,
    also ignored here) KRP formation communication.
    """
    i = total_size(dims)
    i_n = dims[0]
    small_p = i_n * rank  # one-large-dim regime: communicate the small matrices
    large_p = (i * rank / procs) ** (2 / 3)
    # The applicable regime is the cheaper valid one; the paper compares
    # extremes, we return the min as the strongest baseline.
    return max(min(small_p, large_p), i / procs)  # must at least read tensor


__all__ = [n for n in dir() if not n.startswith("_")]
