"""Exact two-level-memory simulator for the paper's sequential model.

The paper's sequential machine (§II-C) has a fast memory of M words and an
unbounded slow memory; communication = loads + stores. Algorithms 1 and 2
specify their loads/stores explicitly, so we *execute* them, counting every
word moved and checking that the fast-memory capacity constraint is never
violated. This is the operational validation of:

  * the Alg 1 cost  W <= I + I·R·(N+1)                   (§V-A)
  * the Alg 2 cost  W <= I + Π⌈I_k/b⌉·R·(N+1)·b          (Eq 10)
  * the feasibility condition  b^N + N·b <= M             (Eq 9)
  * the lower bounds (the simulated counts must respect Thm 4.1 / Fact 4.1).

Arithmetic is done with NumPy on the block/vector granularity the pseudocode
implies; the counters are word-exact (edge blocks counted at true size).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Sequence

import numpy as np



@dataclass
class SimResult:
    loads: int
    stores: int
    peak_fast_words: int
    mem: int
    output: np.ndarray

    @property
    def words(self) -> int:
        return self.loads + self.stores


class _FastMemory:
    """Counts resident words and enforces the capacity M."""

    def __init__(self, mem: int):
        self.mem = mem
        self.resident = 0
        self.peak = 0

    def acquire(self, words: int) -> None:
        self.resident += words
        self.peak = max(self.peak, self.resident)
        if self.resident > self.mem:
            raise MemoryError(
                f"fast memory overflow: {self.resident} > M={self.mem}"
            )

    def release(self, words: int) -> None:
        self.resident -= words
        assert self.resident >= 0


def _resolve_mem(mem, ctx) -> int:
    """The simulated fast-memory size M (words): explicit ``mem`` wins;
    else ``ctx.memory`` (an :class:`~repro.engine.plan.Memory`, whose
    word budget is the paper's abstract M)."""
    if mem is not None:
        if ctx is not None and ctx.memory is not None:
            raise ValueError(
                "pass either mem= or a ctx with a Memory, not both"
            )
        return int(mem)
    if ctx is not None and ctx.memory is not None:
        return ctx.memory.budget_words
    raise ValueError(
        "no fast-memory size: pass mem=M (words) or a ctx built with "
        "ExecutionContext.create(memory=Memory.abstract(M))"
    )


def simulate_unblocked(
    x: np.ndarray, factors: Sequence[np.ndarray], mode: int,
    mem: int | None = None, *, ctx=None,
) -> SimResult:
    """Algorithm 1 (§V-A), executed with explicit load/store counting.

    Per tensor element: 1 load of X(i); per (i, r): N-1 factor loads, one
    load and one store of B. The R-loop arithmetic is vectorized but the
    counters follow the pseudocode exactly.
    """
    mem = _resolve_mem(mem, ctx)
    n = x.ndim
    rank = next(f.shape[1] for k, f in enumerate(factors) if k != mode)
    if mem < n + 2:
        raise ValueError("M must be at least N+2 for Algorithm 1")
    fm = _FastMemory(mem)
    out = np.zeros((x.shape[mode], rank), dtype=np.float64)
    loads = stores = 0
    others = [k for k in range(n) if k != mode]
    for idx in itertools.product(*(range(s) for s in x.shape)):
        fm.acquire(1)  # load X(i)
        loads += 1
        xi = float(x[idx])
        # vectorized over r; counters per pseudocode
        prod = np.ones(rank)
        for k in others:
            prod *= factors[k][idx[k], :]
        out[idx[mode], :] += xi * prod
        loads += rank * (len(others) + 1)  # A^(k) loads + B load, each r
        stores += rank  # B store, each r
        # transient residency: x + (N-1) factor scalars + B scalar
        fm.acquire(len(others) + 2)
        fm.release(len(others) + 2)
        fm.release(1)
    return SimResult(loads, stores, fm.peak, mem, out)


def simulate_blocked(
    x: np.ndarray,
    factors: Sequence[np.ndarray],
    mode: int,
    mem: int | None = None,
    block: int | None = None,
    *,
    ctx=None,
) -> SimResult:
    """Algorithm 2 (§V-B), executed with explicit load/store counting.

    Blocks every tensor mode by ``block`` (chosen per Eq 9 if None). Per
    block: load the subtensor once; for each r, load the N-1 factor
    subvectors and load+store the output subvector. Fast-memory residency is
    tracked at true (edge-aware) sizes and must satisfy Eq (9). The M-word
    budget comes from ``mem`` or from ``ctx.memory`` (see
    :func:`_resolve_mem`).
    """
    mem = _resolve_mem(mem, ctx)
    n = x.ndim
    dims = x.shape
    rank = next(f.shape[1] for k, f in enumerate(factors) if k != mode)
    # block selection goes through the engine planner (call-time import:
    # core <-> engine cycle)
    from ..engine.plan import Memory, best_uniform_block, uniform_block_feasible

    fast = Memory.abstract(mem)
    if block is None:
        block = best_uniform_block(dims, fast)
    if not uniform_block_feasible(n, block, fast):
        raise ValueError(f"block {block} infeasible for M={mem} (Eq 9)")
    fm = _FastMemory(mem)
    out = np.zeros((dims[mode], rank), dtype=np.float64)
    loads = stores = 0
    others = [k for k in range(n) if k != mode]

    ranges = [range(0, d, block) for d in dims]
    # einsum spec for the in-block MTTKRP
    letters = "abcdefghijklmnop"
    spec = (
        letters[:n]
        + ","
        + ",".join(f"{letters[k]}z" for k in others)
        + f"->{letters[mode]}z"
    )
    for starts in itertools.product(*ranges):
        slc = tuple(
            slice(s, min(s + block, d)) for s, d in zip(starts, dims)
        )
        blk = x[slc].astype(np.float64)
        blk_words = blk.size
        fm.acquire(blk_words)  # load block of X
        loads += blk_words
        bsl = slc[mode]
        blens = [slc[k].stop - slc[k].start for k in range(n)]
        for r in range(rank):
            # load factor subvectors
            vecs = []
            vec_words = 0
            for k in others:
                v = factors[k][slc[k], r].astype(np.float64)
                vecs.append(v)
                vec_words += v.size
            fm.acquire(vec_words)
            loads += vec_words
            # load output subvector
            fm.acquire(blens[mode])
            loads += blens[mode]
            contrib = np.einsum(spec, blk, *[v[:, None] for v in vecs])
            out[bsl, r] += contrib[:, 0]
            # store output subvector
            stores += blens[mode]
            fm.release(blens[mode] + vec_words)
        fm.release(blk_words)
    return SimResult(loads, stores, fm.peak, mem, out)
