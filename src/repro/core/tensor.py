"""Dense tensor utilities shared by the MTTKRP/CP core.

Conventions
-----------
* An ``N``-way tensor is a ``jnp.ndarray`` of shape ``(I_1, ..., I_N)``.
* Factor matrices ``A^(k)`` have shape ``(I_k, R)``.
* ``mode`` indices are 0-based throughout the code (the paper is 1-based).
* Matricization ``X_(n)`` follows the Kolda/Bader convention: the mode-``n``
  fibers become columns, with the remaining modes ordered
  ``(0, ..., n-1, n+1, ..., N-1)`` varying fastest-to-slowest in
  *column-major (Fortran) order* over the remaining axes, i.e.
  ``X_(n)[i_n, j]`` with ``j = sum_{k != n} i_k * prod_{m<k, m != n} I_m``.
"""

from __future__ import annotations

import math
from functools import reduce
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


def matricize(x: jax.Array, mode: int) -> jax.Array:
    """Mode-``mode`` matricization ``X_(n)`` of shape ``(I_n, I/I_n)``.

    Uses the Kolda/Bader column ordering (remaining modes vary with the
    earliest mode fastest).
    """
    n = x.ndim
    if not 0 <= mode < n:
        raise ValueError(f"mode {mode} out of range for {n}-way tensor")
    # Move `mode` to the front; remaining axes keep their relative order.
    perm = (mode,) + tuple(k for k in range(n) if k != mode)
    xt = jnp.transpose(x, perm)
    # Fortran ordering over the trailing axes == reverse axes then C-ravel.
    xt = jnp.transpose(
        xt, (0,) + tuple(range(n - 1, 0, -1))
    )
    return xt.reshape(x.shape[mode], -1)


def dematricize(xm: jax.Array, mode: int, shape: Sequence[int]) -> jax.Array:
    """Inverse of :func:`matricize`."""
    shape = tuple(shape)
    n = len(shape)
    rest = tuple(k for k in range(n) if k != mode)
    # matricize produced axes (mode, reversed(rest))
    inter = (shape[mode],) + tuple(shape[k] for k in reversed(rest))
    xt = xm.reshape(inter)
    xt = jnp.transpose(xt, (0,) + tuple(range(n - 1, 0, -1)))
    # now axes are (mode,) + rest ; invert the original permutation
    perm = (mode,) + rest
    inv = [0] * n
    for pos, axis in enumerate(perm):
        inv[axis] = pos
    return jnp.transpose(xt, inv)


def tensor_from_factors(
    factors: Sequence[jax.Array], weights: jax.Array | None = None
) -> jax.Array:
    """Reconstruct the full tensor from CP factors: sum of rank-1 outer products.

    ``factors[k]`` has shape ``(I_k, R)``; result has shape ``(I_1, ..., I_N)``.
    ``weights`` (λ, shape ``(R,)``) scales each rank-1 term once — pass
    ``CPResult.weights`` for decompositions in normalized Kruskal form.
    """
    n = len(factors)
    if n < 2:
        raise ValueError("need at least 2 factors")
    subs = []
    letters = "abcdefghijklmnopqrstuvw"
    for k in range(n):
        subs.append(f"{letters[k]}z")
    ops = list(factors)
    if weights is not None:
        subs.append("z")
        ops.append(weights)
    spec = ",".join(subs) + "->" + letters[:n]
    return jnp.einsum(spec, *ops)


def frob_norm(x: jax.Array) -> jax.Array:
    return jnp.sqrt(jnp.sum(jnp.square(x.astype(jnp.float32))))


def relative_error(x: jax.Array, y: jax.Array) -> jax.Array:
    return frob_norm(x - y) / jnp.maximum(frob_norm(x), 1e-30)


def total_size(dims: Sequence[int]) -> int:
    """I = prod(I_k)."""
    return int(reduce(lambda a, b: a * b, dims, 1))


def random_tensor(key: jax.Array, dims: Sequence[int], dtype=jnp.float32) -> jax.Array:
    return jax.random.normal(key, tuple(dims), dtype=dtype)


def random_factors(
    key: jax.Array, dims: Sequence[int], rank: int, dtype=jnp.float32
) -> list[jax.Array]:
    keys = jax.random.split(key, len(dims))
    return [
        jax.random.normal(k, (d, rank), dtype=dtype) / math.sqrt(rank)
        for k, d in zip(keys, dims)
    ]


def random_low_rank_tensor(
    key: jax.Array, dims: Sequence[int], rank: int, dtype=jnp.float32
) -> tuple[jax.Array, list[jax.Array]]:
    """An exactly rank-``rank`` tensor together with its generating factors."""
    factors = random_factors(key, dims, rank, dtype)
    return tensor_from_factors(factors), factors


def random_tucker_tensor(
    key: jax.Array,
    dims: Sequence[int],
    ranks: Sequence[int],
    dtype=jnp.float32,
) -> tuple[jax.Array, jax.Array, list[jax.Array]]:
    """An exact multilinear-rank-``ranks`` tensor ``G x_1 A_1 ... x_N A_N``
    with orthonormal factors; returns ``(tensor, core, factors)``."""
    dims = tuple(dims)
    ranks = tuple(ranks)
    keys = jax.random.split(key, len(dims) + 1)
    core = jax.random.normal(keys[0], ranks, dtype=dtype)
    factors = []
    for k, (d, r) in enumerate(zip(dims, ranks)):
        q, _ = jnp.linalg.qr(jax.random.normal(keys[k + 1], (d, r), dtype))
        factors.append(q.astype(dtype))
    out = core
    for k, a in enumerate(factors):
        out = jnp.moveaxis(jnp.tensordot(out, a, axes=((k,), (1,))), -1, k)
    return out, core, factors


def np_matricize(x: np.ndarray, mode: int) -> np.ndarray:
    """NumPy twin of :func:`matricize` (used by the sequential simulator)."""
    n = x.ndim
    perm = (mode,) + tuple(k for k in range(n) if k != mode)
    xt = np.transpose(x, perm)
    return xt.reshape(x.shape[mode], -1, order="F")
