"""Algorithm 2 (sequential blocked MTTKRP/Multi-TTM) as structured JAX.

This is the host-level, jit-compatible expression of the paper's blocked
loop order: iterate over b x ... x b tensor blocks, and for each block
contract against the corresponding factor subvectors, accumulating into the
output subvector. On TPU the same structure is realized by the Pallas kernel
(``repro.kernels.mttkrp3``) with VMEM playing the role of fast memory; this
version documents the schedule and serves as a mid-level oracle.

Requires each I_k to be divisible by the block size (pad otherwise) so the
block decomposition is a pure reshape.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from .mttkrp import mttkrp

_L = "abcdefghijklmnop"


def _pad_to_multiple(x: jax.Array, block: int) -> jax.Array:
    pads = [(0, (-d) % block) for d in x.shape]
    if any(p[1] for p in pads):
        x = jnp.pad(x, pads)
    return x


def mttkrp_blocked(
    x: jax.Array,
    factors: Sequence[jax.Array],
    mode: int,
    block: int,
    f32_acc: bool = False,
) -> jax.Array:
    """Blocked MTTKRP with Algorithm 2's loop order, expressed as einsum.

    The tensor is decomposed into blocks; block coordinates become explicit
    contraction indices, so XLA sees exactly the blocked schedule:

        B[n_blk, n_in, r] += X[blk..., in...] * prod_k A_k[k_blk, k_in, r]

    ``f32_acc=True`` forces fp32 accumulation (the engine sets it whenever
    a ``compute_dtype`` policy casts the operands to a narrow type).
    """
    n = x.ndim
    dims = x.shape
    rank = next(f.shape[1] for k, f in enumerate(factors) if k != mode)
    xp = _pad_to_multiple(x, block)
    # reshape to interleaved (blk, in) axes
    newshape = []
    for d in xp.shape:
        newshape += [d // block, block]
    xb = xp.reshape(newshape)
    # einsum: tensor axes pairs (B_k, b_k); factors (B_k, b_k, z)
    t_sub = "".join(_L[2 * k] + _L[2 * k + 1] for k in range(n))
    f_subs, f_ops = [], []
    for k in range(n):
        if k == mode:
            continue
        fk = factors[k]
        fp = jnp.pad(fk, ((0, (-fk.shape[0]) % block), (0, 0)))
        f_ops.append(fp.reshape(fp.shape[0] // block, block, rank))
        f_subs.append(_L[2 * k] + _L[2 * k + 1] + "z")
    out_sub = _L[2 * mode] + _L[2 * mode + 1] + "z"
    spec = ",".join([t_sub] + f_subs) + "->" + out_sub
    kw = {"preferred_element_type": jnp.float32} if f32_acc else {}
    out = jnp.einsum(spec, xb, *f_ops, optimize="optimal", **kw)
    out = out.reshape(-1, rank)
    return out[: dims[mode], :]


def multi_ttm_blocked(
    x: jax.Array,
    matrices: Sequence[jax.Array],
    keep: int | None,
    block: int,
    f32_acc: bool = False,
) -> jax.Array:
    """Blocked Multi-TTM with the Algorithm-2 loop order, as an einsum.

    The tensor modes are decomposed into uniform ``block``-sized blocks
    whose coordinates become explicit contraction indices, so XLA sees
    exactly the blocked schedule of ``core.bounds.multi_ttm_blocked_cost``.
    ``matrices[k]`` is ``(I_k, R_k)``; mode ``keep`` (if not None) is left
    uncontracted and its matrix ignored.  Output modes keep their tensor
    positions: ``(R_1, ..., I_keep, ..., R_N)``.  ``f32_acc=True`` forces
    fp32 accumulation under a narrow ``compute_dtype`` policy.
    """
    n = x.ndim
    dims = x.shape
    xp = _pad_to_multiple(x, block)
    newshape = []
    for d in xp.shape:
        newshape += [d // block, block]
    xb = xp.reshape(newshape)
    t_sub = "".join(_L[2 * k] + _L[2 * k + 1] for k in range(n))
    rank_l = "ABCDEFGH"
    f_subs, f_ops, out_sub = [], [], ""
    for k in range(n):
        if k == keep:
            out_sub += _L[2 * k] + _L[2 * k + 1]
            continue
        mk = matrices[k]
        mp = jnp.pad(mk, ((0, (-mk.shape[0]) % block), (0, 0)))
        f_ops.append(mp.reshape(mp.shape[0] // block, block, mk.shape[1]))
        f_subs.append(_L[2 * k] + _L[2 * k + 1] + rank_l[k])
        out_sub += rank_l[k]
    spec = ",".join([t_sub] + f_subs) + "->" + out_sub
    kw = {"preferred_element_type": jnp.float32} if f32_acc else {}
    out = jnp.einsum(spec, xb, *f_ops, optimize="optimal", **kw)
    if keep is not None:
        # the kept mode contributes its (blk, in) axis pair at position
        # `keep` (every earlier mode contributes one rank axis): merge the
        # pair and slice the padding off
        shape = out.shape
        merged = (
            shape[:keep] + (shape[keep] * shape[keep + 1],)
            + shape[keep + 2:]
        )
        out = out.reshape(merged)
        out = jax.lax.slice_in_dim(out, 0, dims[keep], axis=keep)
    return out


def mttkrp_blocked_reference_check(
    x: jax.Array, factors: Sequence[jax.Array], mode: int, block: int
) -> jax.Array:
    """abs-max discrepancy between blocked and direct MTTKRP (for tests)."""
    a = mttkrp_blocked(x, factors, mode, block)
    b = mttkrp(x, factors, mode)
    return jnp.max(jnp.abs(a - b))
