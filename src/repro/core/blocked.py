"""Algorithm 2 (sequential blocked MTTKRP) as a structured JAX computation.

This is the host-level, jit-compatible expression of the paper's blocked
loop order: iterate over b x ... x b tensor blocks, and for each block
contract against the corresponding factor subvectors, accumulating into the
output subvector. On TPU the same structure is realized by the Pallas kernel
(``repro.kernels.mttkrp3``) with VMEM playing the role of fast memory; this
version documents the schedule and serves as a mid-level oracle.

Requires each I_k to be divisible by the block size (pad otherwise) so the
block decomposition is a pure reshape.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from .mttkrp import mttkrp

_L = "abcdefghijklmnop"


def _pad_to_multiple(x: jax.Array, block: int) -> jax.Array:
    pads = [(0, (-d) % block) for d in x.shape]
    if any(p[1] for p in pads):
        x = jnp.pad(x, pads)
    return x


def mttkrp_blocked(
    x: jax.Array,
    factors: Sequence[jax.Array],
    mode: int,
    block: int,
) -> jax.Array:
    """Blocked MTTKRP with Algorithm 2's loop order, expressed as einsum.

    The tensor is decomposed into blocks; block coordinates become explicit
    contraction indices, so XLA sees exactly the blocked schedule:

        B[n_blk, n_in, r] += X[blk..., in...] * prod_k A_k[k_blk, k_in, r]
    """
    n = x.ndim
    dims = x.shape
    rank = next(f.shape[1] for k, f in enumerate(factors) if k != mode)
    xp = _pad_to_multiple(x, block)
    # reshape to interleaved (blk, in) axes
    newshape = []
    for d in xp.shape:
        newshape += [d // block, block]
    xb = xp.reshape(newshape)
    # einsum: tensor axes pairs (B_k, b_k); factors (B_k, b_k, z)
    t_sub = "".join(_L[2 * k] + _L[2 * k + 1] for k in range(n))
    f_subs, f_ops = [], []
    for k in range(n):
        if k == mode:
            continue
        fk = factors[k]
        fp = jnp.pad(fk, ((0, (-fk.shape[0]) % block), (0, 0)))
        f_ops.append(fp.reshape(fp.shape[0] // block, block, rank))
        f_subs.append(_L[2 * k] + _L[2 * k + 1] + "z")
    out_sub = _L[2 * mode] + _L[2 * mode + 1] + "z"
    spec = ",".join([t_sub] + f_subs) + "->" + out_sub
    out = jnp.einsum(spec, xb, *f_ops, optimize="optimal")
    out = out.reshape(-1, rank)
    return out[: dims[mode], :]


def mttkrp_blocked_reference_check(
    x: jax.Array, factors: Sequence[jax.Array], mode: int, block: int
) -> jax.Array:
    """abs-max discrepancy between blocked and direct MTTKRP (for tests)."""
    a = mttkrp_blocked(x, factors, mode, block)
    b = mttkrp(x, factors, mode)
    return jnp.max(jnp.abs(a - b))
