"""Khatri-Rao product and the MTTKRP-via-matrix-multiplication baseline.

The paper (§III-B, §VI) compares its communication-optimal algorithms against
the straightforward approach: matricize the tensor, form the Khatri-Rao
product (KRP) of the non-target factors explicitly, and multiply:

    B^(n) = X_(n) @ krp({A^(k)}_{k != n})        # (I_n, I/I_n) @ (I/I_n, R)

This file implements that baseline faithfully (it is the thing the paper's
algorithms beat) plus its communication-cost model for the comparison
benchmarks.
"""

from __future__ import annotations

from typing import Sequence

import jax

from .tensor import matricize


def khatri_rao(matrices: Sequence[jax.Array]) -> jax.Array:
    """Column-wise Khatri-Rao product.

    ``matrices[k]`` has shape ``(I_k, R)``; result has shape ``(prod I_k, R)``
    with the *first* matrix's index varying fastest (matching the
    :func:`repro.core.tensor.matricize` column convention, so that
    ``matricize(X, n) @ khatri_rao([A_k for k != n])`` equals the MTTKRP).
    """
    if len(matrices) == 0:
        raise ValueError("need at least one matrix")
    rank = matrices[0].shape[1]
    for m in matrices:
        if m.shape[1] != rank:
            raise ValueError("rank mismatch in khatri_rao")
    # Build with the first matrix fastest: accumulate right-to-left.
    out = matrices[-1]
    for m in reversed(matrices[:-1]):
        # out: (J, R), m: (I, R) -> (J*I, R) with m's index fastest.
        out = (out[:, None, :] * m[None, :, :]).reshape(-1, rank)
    return out


def mttkrp_via_matmul(
    x: jax.Array, factors: Sequence[jax.Array], mode: int
) -> jax.Array:
    """The explicit-KRP matmul baseline (paper §III-B).

    Communication-inefficient at scale because the KRP matrix is treated as a
    general (I/I_n, R) matrix although it has only sum_{k != n} I_k * R
    degrees of freedom.
    """
    xm = matricize(x, mode)
    k = khatri_rao([f for i, f in enumerate(factors) if i != mode])
    return xm @ k
