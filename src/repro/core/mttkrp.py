"""Local (single-device) MTTKRP implementations.

Definition 2.1 of the paper:

    B^(n)(i_n, r) = sum_{i : i[n] = i_n} X(i) * prod_{k != n} A^(k)(i_k, r)

``mttkrp_naive`` keeps the N-ary multiplies atomic (the paper's arithmetic
model); ``mttkrp`` is the production einsum path (breaks atomicity, as
licensed by §V-C3 — same communication, fewer operations, MXU-friendly).
All functions are jit-compatible and differentiable.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

_LETTERS = "abcdefghijklmnopqrstuvw"


def _einsum_spec(ndim: int, mode: int) -> str:
    """e.g. ndim=3, mode=1 -> 'abc,az,cz->bz'."""
    tens = _LETTERS[:ndim]
    ins = [tens]
    for k in range(ndim):
        if k != mode:
            ins.append(f"{_LETTERS[k]}z")
    return ",".join(ins) + f"->{_LETTERS[mode]}z"


def mttkrp(
    x: jax.Array, factors: Sequence[jax.Array], mode: int
) -> jax.Array:
    """Production MTTKRP via a single einsum contraction.

    Args:
      x: the ``N``-way tensor ``(I_1, ..., I_N)``.
      factors: ``N`` factor matrices ``(I_k, R)``. ``factors[mode]`` is
        ignored (may be ``None``), matching the paper's definition.
      mode: the output mode ``n``.

    Returns:
      ``B^(n)`` of shape ``(I_mode, R)``.
    """
    ndim = x.ndim
    if not 0 <= mode < ndim:
        raise ValueError(f"mode {mode} out of range")
    ins = [f for k, f in enumerate(factors) if k != mode]
    spec = _einsum_spec(ndim, mode)
    return jnp.einsum(spec, x, *ins, optimize="optimal")


def mttkrp_naive(
    x: jax.Array, factors: Sequence[jax.Array], mode: int
) -> jax.Array:
    """Atomic N-ary-multiply MTTKRP (the paper's arithmetic model).

    Materializes the rank-1-weighted tensor per rank column via explicit
    broadcasting so every loop iteration (i_1..i_N, r) performs one N-ary
    product — no factoring through the sums. O(N·I·R) multiplies. Reference
    oracle only; memory O(I) per rank column via scan.
    """
    ndim = x.ndim
    rank = next(f.shape[1] for k, f in enumerate(factors) if k != mode)

    def one_rank(r):
        prod = x
        for k in range(ndim):
            if k == mode:
                continue
            shape = [1] * ndim
            shape[k] = x.shape[k]
            prod = prod * factors[k][:, r].reshape(shape)
        # sum over all modes except `mode`
        axes = tuple(k for k in range(ndim) if k != mode)
        return jnp.sum(prod, axis=axes)

    cols = [one_rank(r) for r in range(rank)]
    return jnp.stack(cols, axis=1)


def mttkrp_all_modes(
    x: jax.Array, factors: Sequence[jax.Array]
) -> list[jax.Array]:
    """MTTKRP in every mode (the CP-ALS inner loop), no reuse."""
    return [mttkrp(x, factors, n) for n in range(x.ndim)]
