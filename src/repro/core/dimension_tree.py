"""Dimension-tree multi-mode MTTKRP (paper §VII outlook; Phan et al. [13]).

CP-ALS needs the MTTKRP in *every* mode each sweep. Computing them
independently costs N separate O(N*I*R) contractions; a dimension tree
shares partial contractions: split the mode set in half, contract the
tensor once with each half's factors, and recurse. Asymptotically ~2
tensor-sized contractions per sweep instead of N, with the same
communication pattern per contraction (each partial contraction is itself
MTTKRP-like and is blocked / distributed by the same machinery).

The tree execution lives in :mod:`repro.engine.tree` — each partial
contraction is planned and dispatched through the engine's backends
(einsum or the blocked Pallas kernels). This module keeps the historical
entry points plus the analytic flop models.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Sequence

import jax

if TYPE_CHECKING:  # engine imports stay call-time-only (core <-> engine cycle)
    from ..engine.context import ExecutionContext


def all_mode_mttkrp_dimtree(
    x: jax.Array,
    factors: Sequence[jax.Array],
    *,
    ctx: "ExecutionContext | None" = None,
    backend=None,
    memory=None,
    interpret=None,
) -> list[jax.Array]:
    """All-mode MTTKRP via a binary dimension tree.

    Returns ``[B^(0), ..., B^(N-1)]`` identical (up to roundoff) to
    ``[mttkrp(x, factors, n) for n in range(N)]`` with ~half the flops for
    N=3,4 and asymptotically fewer for larger N. ``ctx.backend='pallas'``
    runs every partial contraction on the blocked kernels.
    """
    from ..engine.context import UNSET, context_from_legacy
    from ..engine.tree import all_mode_mttkrp

    ctx = context_from_legacy(
        "repro.core.all_mode_mttkrp_dimtree", ctx,
        {
            "backend": backend if backend is not None else UNSET,
            "memory": memory if memory is not None else UNSET,
            "interpret": interpret if interpret is not None else UNSET,
        },
    )
    return all_mode_mttkrp(x, factors, method="dimtree", ctx=ctx)


def dimtree_als_sweep(
    x: jax.Array,
    factors: list[jax.Array],
    update_fn,
    *,
    ctx: "ExecutionContext | None" = None,
    backend=None,
    memory=None,
    interpret=None,
) -> None:
    """One ALS sweep with dimension-tree reuse, *exactly* matching the
    Gauss-Seidel order of plain ALS (see :mod:`repro.engine.tree` for the
    ordering argument). ``factors`` is updated in place."""
    from ..engine.context import UNSET, context_from_legacy
    from ..engine.tree import dimtree_als_sweep as engine_sweep

    ctx = context_from_legacy(
        "repro.core.dimtree_als_sweep", ctx,
        {
            "backend": backend if backend is not None else UNSET,
            "memory": memory if memory is not None else UNSET,
            "interpret": interpret if interpret is not None else UNSET,
        },
    )
    engine_sweep(x, factors, update_fn, ctx=ctx)


def dimtree_flops(dims: Sequence[int], rank: int) -> int:
    """Exact multiply-add count of one dimension-tree sweep.

    Each einsum contraction pairs the dropped factors one at a time; a
    pairing that drops mode ``m`` from a node with remaining mode sizes
    ``cur`` costs ``prod(cur) * R`` multiply-adds (every surviving
    element-and-rank pair sums over ``m``) — whether the rank axis is
    already materialized on the node (elementwise along r) or appears with
    this first pairing. Volumes shrink *exactly* per the dims dropped, not
    by a geometric-mean model. Compare against naive all-mode MTTKRP:
    ``N * (N-1) * I * R`` multiply-adds.
    """
    total = 0

    def contract_cost(sizes: tuple[int, ...], drop: tuple[int, ...]) -> int:
        # `sizes` are the node's mode sizes in order; `drop` indexes into
        # it. Each pairing costs prod(remaining)*R multiply-adds regardless
        # of whether the rank axis is already materialized; the drop ORDER
        # does matter, and einsum's 'optimal' path drops the largest mode
        # first (shrinking the node fastest minimizes the rest).
        cost = 0
        cur = list(sizes)
        for s in sorted((sizes[m] for m in drop), reverse=True):
            vol = 1
            for c in cur:
                vol *= c
            cost += vol * rank
            cur.remove(s)
        return cost

    def rec(sizes: tuple[int, ...]):
        nonlocal total
        if len(sizes) == 1:
            return
        half = max(1, len(sizes) // 2)
        total += contract_cost(sizes, tuple(range(half, len(sizes))))
        total += contract_cost(sizes, tuple(range(half)))
        rec(sizes[:half])
        rec(sizes[half:])

    rec(tuple(dims))
    return total


def dimtree_intermediate_words(dims: Sequence[int], rank: int) -> int:
    """Total words of every internal tree node (the reuse working set).

    Rank-augmented nodes hold ``prod(dims) * R`` words — the quantity the
    old geometric-mean model under-counted; the root holds ``prod(dims)``.
    """
    total = 0

    def rec(sizes: tuple[int, ...], has_rank: bool):
        nonlocal total
        vol = 1
        for s in sizes:
            vol *= s
        total += vol * (rank if has_rank else 1)
        if len(sizes) == 1:
            return
        half = max(1, len(sizes) // 2)
        rec(sizes[:half], True)
        rec(sizes[half:], True)

    rec(tuple(dims), False)
    return total


def naive_all_mode_flops(dims: Sequence[int], rank: int) -> int:
    """N independent MTTKRPs, each N-1 pairwise contractions of I*R."""
    n = len(dims)
    vol = 1
    for d in dims:
        vol *= d
    return n * (n - 1) * vol * rank
