"""Dimension-tree multi-mode MTTKRP (paper §VII outlook; Phan et al. [13]).

CP-ALS needs the MTTKRP in *every* mode each sweep. Computing them
independently costs N separate O(N·I·R) contractions; a dimension tree
shares partial contractions: split the mode set in half, contract the tensor
once with each half's factors, and recurse. Asymptotically ~2 tensor-sized
contractions per sweep instead of N, with the same communication pattern per
contraction (each partial contraction is itself MTTKRP-like and is blocked /
distributed by the same machinery).
"""

from __future__ import annotations

from typing import Dict, Sequence

import jax
import jax.numpy as jnp

_L = "abcdefghijklmnopqrstuvw"
_RANK = "z"


def all_mode_mttkrp_dimtree(
    x: jax.Array, factors: Sequence[jax.Array]
) -> list[jax.Array]:
    """All-mode MTTKRP via a binary dimension tree.

    Returns ``[B^(0), ..., B^(N-1)]`` identical (up to roundoff) to
    ``[mttkrp(x, factors, n) for n in range(N)]`` with ~half the flops for
    N=3,4 and asymptotically fewer for larger N.
    """
    n = x.ndim
    results: Dict[int, jax.Array] = {}

    def contract(node, modes, drop, has_rank):
        sub_in = "".join(_L[m] for m in modes) + (_RANK if has_rank else "")
        ops = [node]
        subs = [sub_in]
        for m in drop:
            ops.append(factors[m])
            subs.append(_L[m] + _RANK)
        keep = tuple(m for m in modes if m not in drop)
        sub_out = "".join(_L[m] for m in keep) + _RANK
        return jnp.einsum(",".join(subs) + "->" + sub_out, *ops,
                          optimize="optimal")

    def solve(node, modes, has_rank):
        if len(modes) == 1:
            results[modes[0]] = node
            return
        half = max(1, len(modes) // 2)
        left, right = modes[:half], modes[half:]
        solve(contract(node, modes, right, has_rank), left, True)
        solve(contract(node, modes, left, has_rank), right, True)

    solve(x, tuple(range(n)), False)
    return [results[m] for m in range(n)]


def dimtree_als_sweep(
    x: jax.Array,
    factors: list[jax.Array],
    update_fn,
) -> None:
    """One ALS sweep with dimension-tree reuse, *exactly* matching the
    Gauss-Seidel order of plain ALS.

    ``update_fn(mode, b)`` receives the MTTKRP result for ``mode`` computed
    with all modes < mode already updated, must return the new factor, and
    may maintain its own side state (grams, weights). ``factors`` is updated
    in place. Key ordering property: a node's partial for its *left* child is
    contracted with right-child factors (not yet updated — correct), and the
    partial for its *right* child is contracted with left-child factors
    *after* they were updated — so every leaf sees exactly the factors plain
    ALS would use, while sharing the upper-tree contractions.
    """

    def contract(node, modes, drop, has_rank):
        sub_in = "".join(_L[m] for m in modes) + (_RANK if has_rank else "")
        ops, subs = [node], [sub_in]
        for m in drop:
            ops.append(factors[m])
            subs.append(_L[m] + _RANK)
        keep = tuple(m for m in modes if m not in drop)
        sub_out = "".join(_L[m] for m in keep) + _RANK
        return jnp.einsum(",".join(subs) + "->" + sub_out, *ops,
                          optimize="optimal")

    def solve(node, modes, has_rank):
        if len(modes) == 1:
            mode = modes[0]
            factors[mode] = update_fn(mode, node)
            return
        half = max(1, len(modes) // 2)
        left, right = modes[:half], modes[half:]
        solve(contract(node, modes, right, has_rank), left, True)
        solve(contract(node, modes, left, has_rank), right, True)

    solve(x, tuple(range(x.ndim)), False)


def dimtree_flops(dims: Sequence[int], rank: int) -> int:
    """Modeled multiply-add count of one dimension-tree sweep.

    Each contract-away of modes D from a node of volume V (pairing the
    factors one at a time, rank-R throughout) costs sum of intermediate
    volumes; we count the dominant first-step term V*R per dropped factor
    applied to the shrinking node. Compare against naive all-mode MTTKRP:
    N * (N-1) * I * R multiply-adds.
    """
    total = 0

    def contract_cost(sizes: tuple[int, ...], drop_count: int, has_rank: bool) -> int:
        cost = 0
        vol = 1
        for s in sizes:
            vol *= s
        # drop factors one at a time; node volume shrinks after each
        for _ in range(drop_count):
            cost += vol * rank
            # dropping one mode divides volume by that mode's size; use the
            # geometric mean as the model (exact per-order cost is computed
            # by XLA; this model is for the reuse ratio benchmark)
            vol = int(vol ** ((len(sizes) - 1) / len(sizes))) if len(sizes) > 1 else vol
        return cost

    def rec(sizes: tuple[int, ...], has_rank: bool):
        nonlocal total
        if len(sizes) == 1:
            return
        half = max(1, len(sizes) // 2)
        left, right = sizes[:half], sizes[half:]
        total += contract_cost(sizes, len(right), has_rank)
        total += contract_cost(sizes, len(left), has_rank)
        rec(left, True)
        rec(right, True)

    rec(tuple(dims), False)
    return total


def naive_all_mode_flops(dims: Sequence[int], rank: int) -> int:
    """N independent MTTKRPs, each N-1 pairwise contractions of I*R."""
    n = len(dims)
    vol = 1
    for d in dims:
        vol *= d
    return n * (n - 1) * vol * rank
