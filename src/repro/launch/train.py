"""Training launcher.

Local (this container) runs a real training job on a small model with the
full substrate: sharded step (1 device: NULL policy), deterministic data,
async checkpointing, restart recovery, straggler monitoring.

    PYTHONPATH=src python -m repro.launch.train \
        --arch qwen2-1.5b --smoke --steps 200 --batch 8 --seq 128

On a fleet the same entry point runs under multi-host jax.distributed with
``--mesh single_pod|multi_pod`` (mesh construction + sharded jit are the
same code paths the dry-run proves out at 256/512 devices).
"""

from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", choices=["none", "debug"], default="none")
    ap.add_argument("--d-model", type=int, default=0,
                    help="override width (e.g. ~100M model for examples)")
    ap.add_argument("--layers", type=int, default=0)
    args = ap.parse_args()

    import dataclasses

    import jax

    from ..configs import get_config, get_smoke
    from ..data import DataConfig
    from ..models.sharding import NULL, make_policy
    from ..optim.schedule import cosine_schedule
    from ..training import LoopConfig, TrainLoop, init_train_state
    from ..training.steps import build_train_step
    from .mesh import make_debug_mesh

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if args.d_model:
        cfg = dataclasses.replace(cfg, d_model=args.d_model)
    if args.layers:
        cfg = dataclasses.replace(cfg, n_layers=args.layers)

    if args.mesh == "debug":
        mesh = make_debug_mesh()
        sh = make_policy(cfg, mesh)
    else:
        sh = NULL

    key = jax.random.PRNGKey(0)
    state = init_train_state(key, cfg)
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"arch={cfg.name} params={n_params:,} devices={len(jax.devices())}")

    def lr_fn(s):
        return cosine_schedule(s, args.lr, 20, args.steps)
    step = jax.jit(
        build_train_step(
            cfg, sh, microbatches=args.microbatches, lr_fn=lr_fn
        )
    )
    data_cfg = DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=0,
    )
    loop = TrainLoop(
        step, data_cfg,
        LoopConfig(
            total_steps=args.steps, ckpt_every=args.ckpt_every,
            ckpt_dir=args.ckpt_dir,
        ),
    )
    t0 = time.time()
    state, stats = loop.run(state)
    dt = time.time() - t0
    print(
        f"done: {stats.steps_done} steps in {dt:.1f}s "
        f"({dt / max(stats.steps_done, 1):.3f}s/step), "
        f"loss {stats.losses[0]:.4f} -> {stats.losses[-1]:.4f}, "
        f"restarts={stats.restarts} stragglers={stats.stragglers}"
    )
    return stats


if __name__ == "__main__":
    main()
