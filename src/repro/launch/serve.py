"""Decomposition-as-a-service: bucket, pad, batch — one plan per bucket.

The serving layer on top of the batched engine
(:mod:`repro.engine.batch`). Incoming requests (one tensor each, a CP
rank, a dtype) are **bucketed** by their tune-cache key: extents are
rounded up to the bucket quantum (``pad_to``), and every request whose
padded shape / rank / dtype / memory model agree lands in the same
bucket. A flush pads each request to its bucket's plan shape, stacks
the bucket into one ``(B, I_0, ..., I_{N-1})`` array, and runs ONE
:func:`~repro.engine.batch.cp_als_batched` call per bucket — one plan
resolution, one compiled program, one kernel launch per contraction for
all B requests. This is the same amortization the paper's Eq 9/10 make
for factor traffic, applied one level up: plan choice, autotune lookup,
and XLA compilation are paid once per bucket, not once per request.

Padding is exact, not approximate: a zero-padded tensor with zero-padded
initial factors evolves *identically* to the unpadded run under CP-ALS
(padded MTTKRP rows are zero, so padded factor rows stay zero and
contribute nothing to any Gram), so cropping the result recovers the
unpadded answer bit-for-bit. ``tests/test_serve.py`` pins this.

Warm starts persist across processes through JAX's compilation cache:
an :class:`~repro.engine.context.ExecutionContext` with
``compilation_cache=<dir>`` makes the server call
``ensure_compilation_cache()`` before its first flush, so a second
server process serving the same buckets reloads every compiled program
from disk (``benchmarks/serve.py`` measures the cold/warm split).

CLI demo (synthetic workload, prints req/s)::

    PYTHONPATH=src python -m repro.launch.serve \
        --requests 16 --shape 12x10x8 --rank 4 --cache-dir /tmp/srv
"""

from __future__ import annotations

import argparse
import time
import uuid
from dataclasses import dataclass, field
from typing import Sequence

import jax
import jax.numpy as jnp

from ..engine.context import ExecutionContext
from ..engine.plan import Memory
from ..observe import trace as _otrace

#: Default bucket quantum: extents round up to the next multiple.
DEFAULT_PAD_TO = 8


def bucket_shape(
    shape: Sequence[int], pad_to: int = DEFAULT_PAD_TO
) -> tuple[int, ...]:
    """The plan shape a request's tensor is padded to: each extent
    rounded up to the next multiple of ``pad_to``, so nearby shapes
    share one bucket (and therefore one plan and one compiled
    program)."""
    if pad_to < 1:
        raise ValueError(f"pad_to must be >= 1, got {pad_to}")
    return tuple(-(-int(s) // pad_to) * pad_to for s in shape)


def bucket_key(
    shape: Sequence[int],
    rank: int,
    dtype,
    *,
    memory: Memory | None = None,
    pad_to: int = DEFAULT_PAD_TO,
) -> str:
    """The bucket identity: the tune-cache key of the *padded* problem
    (``kind="serve"``), so two requests share a bucket exactly when the
    engine would resolve them to the same tuned plan."""
    from ..tune.cache import cache_key  # lazy: launch <-> tune layering

    mem = memory or Memory.abstract(2 ** 20)
    return cache_key(
        bucket_shape(shape, pad_to), rank, 0, dtype, mem, kind="serve"
    )


def pad_to_bucket(x: jax.Array, padded: Sequence[int]) -> jax.Array:
    """Zero-pad ``x`` up to the bucket's plan shape (exact for CP-ALS:
    see the module docstring's invariance argument)."""
    if tuple(x.shape) == tuple(padded):
        return x
    widths = [(0, int(p) - int(s)) for s, p in zip(x.shape, padded)]
    if any(w[1] < 0 for w in widths):
        raise ValueError(
            f"cannot pad shape {tuple(x.shape)} down to {tuple(padded)}"
        )
    return jnp.pad(x, widths)


@dataclass
class Request:
    """One queued decomposition request."""

    request_id: str
    x: jax.Array
    rank: int
    key: str  # bucket key
    enqueued_at: float = field(default_factory=time.perf_counter)


@dataclass
class ServeResult:
    """One served decomposition: the cropped per-request CP result plus
    the serving telemetry (bucket, batch size, queue/execute seconds,
    whether this flush compiled the bucket's program cold)."""

    request_id: str
    factors: list[jax.Array]
    weights: jax.Array
    fit: float
    n_iters: int
    converged: bool
    bucket: str
    batch: int
    queue_s: float
    execute_s: float
    cold: bool


class DecompositionServer:
    """The request queue + batched executor.

    ``submit()`` enqueues a tensor; ``flush()`` groups the queue into
    buckets (equal :func:`bucket_key` → one bucket), pads within each
    bucket to the bucket's plan shape, executes ONE
    :func:`~repro.engine.batch.cp_als_batched` call per bucket, and
    returns cropped per-request :class:`ServeResult` values. Per-element
    convergence masks mean a bucket mixing easy and hard tensors stops
    updating the easy ones as soon as they converge.

    With ``ctx.observe`` on and an active :class:`repro.observe.Trace`,
    every flush records one ``serve_request`` span per request (queue
    and execute phase seconds) and one ``serve_bucket`` span per bucket
    (batch size, padded shape, cold/warm).
    """

    def __init__(
        self,
        ctx: ExecutionContext | None = None,
        *,
        pad_to: int = DEFAULT_PAD_TO,
        n_iters: int = 20,
        tol: float = 1e-4,
    ):
        self.ctx = ctx or ExecutionContext.default()
        self.pad_to = int(pad_to)
        self.n_iters = int(n_iters)
        self.tol = float(tol)
        self._queue: list[Request] = []
        self._seen_buckets: set[str] = set()
        self._seed = 0
        # point XLA's persistent cache at the context's directory BEFORE
        # the first compile, so warm-start processes reload from disk
        self.ctx.ensure_compilation_cache()

    def __len__(self) -> int:
        return len(self._queue)

    def submit(
        self, x: jax.Array, rank: int, request_id: str | None = None
    ) -> str:
        """Enqueue one tensor for CP decomposition; returns the request
        id (generated when not given). Nothing executes until
        :meth:`flush`."""
        if x.ndim < 2:
            raise ValueError(
                f"serve requests are >=2-way tensors, got shape "
                f"{tuple(x.shape)}"
            )
        rid = request_id if request_id is not None else uuid.uuid4().hex
        key = bucket_key(
            x.shape, rank, x.dtype, memory=self.ctx.memory,
            pad_to=self.pad_to,
        )
        self._queue.append(Request(rid, x, int(rank), key))
        return rid

    def flush(self) -> dict[str, ServeResult]:
        """Execute the queue: one batched call per bucket; returns
        ``{request_id: ServeResult}`` and empties the queue."""
        from ..core.tensor import random_factors
        from ..engine.batch import cp_als_batched

        queue, self._queue = self._queue, []
        buckets: dict[str, list[Request]] = {}
        for req in queue:
            buckets.setdefault(req.key, []).append(req)
        out: dict[str, ServeResult] = {}
        for key, reqs in buckets.items():
            t_exec0 = time.perf_counter()
            cold = key not in self._seen_buckets
            self._seen_buckets.add(key)
            padded = bucket_shape(reqs[0].x.shape, self.pad_to)
            rank = reqs[0].rank
            dtype = reqs[0].x.dtype
            xs = jnp.stack(
                [pad_to_bucket(r.x.astype(dtype), padded) for r in reqs]
            )
            # per-request random inits on the ELEMENT shape, zero-padded
            # to the bucket shape: the padding-invariance contract
            inits = []
            for r in reqs:
                self._seed += 1
                fs = random_factors(
                    jax.random.PRNGKey(self._seed), r.x.shape, rank, dtype
                )
                inits.append([
                    jnp.zeros((p, rank), dtype).at[: f.shape[0]].set(f)
                    for f, p in zip(fs, padded)
                ])
            init_factors = [
                jnp.stack([init[k] for init in inits])
                for k in range(len(padded))
            ]
            res = cp_als_batched(
                xs, rank, n_iters=self.n_iters,
                init_factors=init_factors, tol=self.tol, ctx=self.ctx,
            )
            jax.block_until_ready(res.weights)
            t_exec1 = time.perf_counter()
            execute_s = t_exec1 - t_exec0
            if _otrace.should_record(self.ctx.observe):
                _otrace.record_event(
                    "serve_bucket",
                    bucket=key,
                    batch=len(reqs),
                    padded_shape=list(padded),
                    rank=rank,
                    cold=cold,
                    execute_s=execute_s,
                )
            for b, r in enumerate(reqs):
                out[r.request_id] = sr = ServeResult(
                    request_id=r.request_id,
                    factors=[
                        f[b, : r.x.shape[k]]
                        for k, f in enumerate(res.factors)
                    ],
                    weights=res.weights[b],
                    fit=float(res.fits[b]),
                    n_iters=int(res.n_iters[b]),
                    converged=bool(res.converged[b]),
                    bucket=key,
                    batch=len(reqs),
                    queue_s=t_exec0 - r.enqueued_at,
                    execute_s=execute_s,
                    cold=cold,
                )
                if _otrace.should_record(self.ctx.observe):
                    _otrace.record_event(
                        "serve_request",
                        request_id=r.request_id,
                        bucket=key,
                        batch=sr.batch,
                        shape=list(r.x.shape),
                        rank=rank,
                        queue_s=sr.queue_s,
                        execute_s=sr.execute_s,
                        fit=sr.fit,
                        n_iters=sr.n_iters,
                        converged=sr.converged,
                        cold=cold,
                    )
        return out


def _parse_shape(s: str) -> tuple[int, ...]:
    return tuple(int(t) for t in s.split("x"))


def main(argv: list[str] | None = None) -> int:
    """Synthetic-workload demo: enqueue ``--requests`` random low-rank
    tensors (shapes jittered below ``--shape`` so several element shapes
    share each bucket), flush once, and print bucket stats and req/s."""
    ap = argparse.ArgumentParser(prog="repro.launch.serve", description=__doc__)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--shape", type=_parse_shape, default=(12, 10, 8))
    ap.add_argument("--rank", type=int, default=4)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--tol", type=float, default=1e-4)
    ap.add_argument("--pad-to", type=int, default=DEFAULT_PAD_TO)
    ap.add_argument(
        "--cache-dir", default=None,
        help="persistent XLA compilation cache directory (warm starts)",
    )
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from ..core.tensor import random_low_rank_tensor

    ctx = ExecutionContext.create(
        backend="auto", compilation_cache=args.cache_dir
    )
    server = DecompositionServer(
        ctx, pad_to=args.pad_to, n_iters=args.iters, tol=args.tol
    )
    key = jax.random.PRNGKey(args.seed)
    for i in range(args.requests):
        key, k1, k2 = jax.random.split(key, 3)
        # jitter extents down by up to pad_to-1: same bucket, mixed shapes
        jit = jax.random.randint(
            k1, (len(args.shape),), 0, max(args.pad_to, 2)
        )
        shape = tuple(
            max(int(s) - int(j), 2) for s, j in zip(args.shape, jit)
        )
        x, _ = random_low_rank_tensor(k2, shape, args.rank)
        server.submit(x, args.rank, request_id=f"req{i}")
    t0 = time.perf_counter()
    results = server.flush()
    dt = time.perf_counter() - t0
    n_buckets = len({r.bucket for r in results.values()})
    print(
        f"served {len(results)} request(s) in {dt * 1e3:.1f} ms "
        f"({len(results) / dt:.1f} req/s) across {n_buckets} bucket(s)"
    )
    for rid in sorted(results, key=lambda r: int(r[3:])):
        r = results[rid]
        print(
            f"  {rid}: fit={r.fit:.4f} iters={r.n_iters} "
            f"converged={r.converged} batch={r.batch} "
            f"{'cold' if r.cold else 'warm'}"
        )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
