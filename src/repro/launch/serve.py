"""Serving launcher: batched decode against a KV cache.

Local demo (CPU, reduced config):

    PYTHONPATH=src python -m repro.launch.serve \
        --arch qwen2-1.5b --smoke --batch 4 --prompt-len 16 --gen 32

Serves batched requests through prefill (flash attention) + step decode —
the same code paths the dry-run lowers at production shapes/meshes.
"""

from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from ..configs import get_config, get_smoke
    from ..models import decode_step, init_decode_state, init_params

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    b, pl = args.batch, args.prompt_len
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (b, pl), 0, cfg.vocab_size
    )

    max_len = pl + args.gen + 1
    state = init_decode_state(params, cfg, b, max_len)

    # prefill by stepping the prompt through decode (keeps the cache exact;
    # a production server uses the chunked prefill path + cache handoff)
    step = jax.jit(lambda p, s, t: decode_step(p, cfg, s, t))
    t0 = time.time()
    logits = None
    for t in range(pl):
        logits, state = step(params, state, prompts[:, t: t + 1])
    prefill_t = time.time() - t0

    tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, state = step(params, state, tok)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, -1, :] / args.temperature
            )[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        out_tokens.append(tok)
    decode_t = time.time() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"arch={cfg.name} batch={b}")
    print(f"prefill: {pl} toks in {prefill_t:.2f}s")
    print(
        f"decode: {args.gen} toks in {decode_t:.2f}s "
        f"({decode_t / max(args.gen - 1, 1) * 1000:.1f} ms/tok)"
    )
    print("sample generation (token ids):", gen[0, :16].tolist())
    return gen


if __name__ == "__main__":
    main()
