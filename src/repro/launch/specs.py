"""ShapeDtypeStruct stand-ins for every model input — the dry-run lowers
against these (weak-type-correct, shardable, zero allocation)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..models import ArchConfig, RunShape
from ..models.config import SHAPES
from ..models.model import DTYPES


def batch_struct(cfg: ArchConfig, shape: RunShape) -> dict:
    """Training/prefill batch ShapeDtypeStructs."""
    b, s = shape.global_batch, shape.seq_len
    dtype = DTYPES[cfg.dtype]
    out = {}
    if cfg.frontend != "none":
        out["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), dtype)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cfg.is_encdec:
        t = cfg.max_target_len
        out["dec_tokens"] = jax.ShapeDtypeStruct((b, t), jnp.int32)
        out["dec_labels"] = jax.ShapeDtypeStruct((b, t), jnp.int32)
    else:
        out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return out


def decode_token_struct(cfg: ArchConfig, shape: RunShape):
    return jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)


def cross_kv_struct(cfg: ArchConfig, shape: RunShape):
    """Whisper decode: encoder K/V stand-in (B, S_enc, kv, hd)."""
    dtype = DTYPES[cfg.dtype]
    return (
        jax.ShapeDtypeStruct(
            (shape.global_batch, shape.seq_len, cfg.n_kv_heads, cfg.hd),
            dtype,
        ),
    ) * 2


def input_specs(arch: str, shape_name: str) -> dict:
    """Public entry: all input structs for an (arch, shape) cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind in ("train", "prefill"):
        return {"batch": batch_struct(cfg, shape)}
    return {"tokens": decode_token_struct(cfg, shape)}
