import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

For each (arch × shape × mesh) cell: build the production-sharded
train_step / prefill_step / serve_step, ``.lower()`` it against
ShapeDtypeStruct inputs (zero allocation — params come from
jax.eval_shape), ``.compile()``, and record

  * ``compiled.memory_analysis()``  (per-device bytes — proves it fits),
  * ``compiled.cost_analysis()``    (FLOPs / bytes for §Roofline),
  * the collective schedule (kinds, counts, bytes) parsed from the HLO,

into ``results/dryrun/<arch>__<shape>__<mesh>.json``.

Usage:
  python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
  python -m repro.launch.dryrun --arch ... --shape ... --multipod
  python -m repro.launch.dryrun --all [--force]     # subprocess per cell

NOTE: the XLA_FLAGS line above must run before ANY jax-importing import —
do not reorder. Smoke tests and benchmarks never import this module.
"""

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import subprocess    # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from functools import partial  # noqa: E402

RESULTS_DIR = os.environ.get(
    "REPRO_DRYRUN_DIR",
    os.path.join(os.path.dirname(__file__), "..", "..", "..", "results",
                 "dryrun"),
)


# ---------------------------------------------------------------------------
# per-(arch, shape) launch settings (memory tuning knobs)
# ---------------------------------------------------------------------------

MICROBATCHES = {  # desired microbatch count for train_4k (clamped per mesh)
    "nemotron-4-340b": 16,
    "qwen2-vl-72b": 16,
    "yi-34b": 16,
    "deepseek-coder-33b": 16,
    "jamba-v0.1-52b": 16,
    "mamba2-2.7b": 8,
    "olmoe-1b-7b": 8,
    "granite-moe-3b-a800m": 4,
    "qwen2-1.5b": 4,
    "whisper-tiny": 2,
}

BF16_OPT_ARCHS = {  # bf16 Adam moments + bf16 grad accumulation (DESIGN §5)
    "nemotron-4-340b",
    "qwen2-vl-72b",
}


def pick_microbatches(arch: str, global_batch: int, dp_size: int) -> int:
    want = MICROBATCHES.get(arch, 4)
    mb = min(want, max(global_batch // dp_size, 1))
    while mb > 1 and (global_batch % mb or (global_batch // mb) % dp_size):
        mb -= 1
    return max(mb, 1)


# ---------------------------------------------------------------------------
# cell runner
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             mb_override: int | None = None,
             policy_overrides: dict | None = None):
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..configs import cell_is_skipped, get_config
    from ..models import (
        cache_specs,
        init_decode_state,
        init_params,
        param_specs,
    )
    from ..models.config import SHAPES
    from ..models.model import decode_step, forward
    from ..models.sharding import make_policy
    from ..training.steps import (
        batch_specs,
        build_train_step,
        init_train_state,
        train_state_specs,
    )
    from .mesh import dp_axes, make_production_mesh
    from .specs import batch_struct, cross_kv_struct, decode_token_struct

    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}"
    out_path = os.path.join(out_dir, f"{cell_id}.json")
    os.makedirs(out_dir, exist_ok=True)

    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "devices": 512 if multi_pod else 256,
    }
    skip = cell_is_skipped(arch, shape_name)
    if skip:
        record.update(status="skipped", reason=skip)
        with open(out_path, "w") as f:
            json.dump(record, f, indent=1)
        print(f"SKIP {cell_id}: {skip}")
        return record

    cfg = get_config(arch)
    if os.environ.get("REPRO_SSM_CHUNK"):
        cfg = dataclasses.replace(
            cfg, ssm_chunk=int(os.environ["REPRO_SSM_CHUNK"])
        )
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    sh = make_policy(cfg, mesh, dp=dp_axes(multi_pod))
    if policy_overrides:
        coerced = {}
        for k, v in policy_overrides.items():
            if v in ("0", "1", "true", "false", "True", "False"):
                v = v in ("1", "true", "True")
            coerced[k] = v
        sh = dataclasses.replace(sh, **coerced)
    dp_size = sh.dp_size
    if shape.global_batch % dp_size:
        sh = dataclasses.replace(sh, shard_batch=False)
    record.update(
        attn_policy=sh.attn, moe_policy=sh.moe,
        shard_batch=sh.shard_batch,
        params=cfg.param_count(),
        active_params=cfg.active_param_count(),
    )
    key = jax.random.PRNGKey(0)
    def to_sh(tree):
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s), tree,
            is_leaf=lambda x: isinstance(x, P),
        )
    t0 = time.time()

    if shape.kind == "train":
        mb = mb_override or pick_microbatches(arch, shape.global_batch, dp_size)
        bf16_opt = arch in BF16_OPT_ARCHS
        record.update(microbatches=mb, bf16_opt=bf16_opt)
        state_structs = jax.eval_shape(
            partial(
                init_train_state, cfg=cfg,
                moment_dtype=jnp.bfloat16 if bf16_opt else jnp.float32,
            ),
            key,
        )
        sspecs = train_state_specs(state_structs, cfg, sh)
        bstructs = batch_struct(cfg, shape)
        bspecs = batch_specs(cfg, sh)
        step = build_train_step(
            cfg, sh, microbatches=mb,
            accum_dtype=jnp.bfloat16 if bf16_opt else jnp.float32,
            opt_math_dtype=jnp.bfloat16 if bf16_opt else jnp.float32,
        )
        fn = jax.jit(
            step,
            in_shardings=(to_sh(sspecs), to_sh(bspecs)),
            out_shardings=(to_sh(sspecs), None),
            donate_argnums=(0,),
        )
        lowered = fn.lower(state_structs, bstructs)
        n_tokens = shape.global_batch * shape.seq_len
        record["model_flops"] = 6 * cfg.active_param_count() * n_tokens

    elif shape.kind == "prefill":
        params_structs = jax.eval_shape(partial(init_params, cfg=cfg), key)
        pspecs = param_specs(params_structs, cfg, sh)
        bstructs = batch_struct(cfg, shape)
        bspecs = batch_specs(cfg, sh)

        def prefill_step(params, batch):
            out, _ = forward(
                params, cfg, batch, sh, mode="prefill",
                logits_positions="last",
            )
            return out

        fn = jax.jit(
            prefill_step,
            in_shardings=(to_sh(pspecs), to_sh(bspecs)),
        )
        lowered = fn.lower(params_structs, bstructs)
        n_tokens = shape.global_batch * shape.seq_len
        record["model_flops"] = 2 * cfg.active_param_count() * n_tokens

    else:  # decode
        params_structs = jax.eval_shape(partial(init_params, cfg=cfg), key)
        pspecs = param_specs(params_structs, cfg, sh)
        state_structs = jax.eval_shape(
            lambda p: init_decode_state(p, cfg, shape.global_batch,
                                        shape.seq_len),
            params_structs,
        )
        cspecs = cache_specs(state_structs, cfg, sh)
        tok_struct = decode_token_struct(cfg, shape)
        tok_sharding = NamedSharding(mesh, sh.spec("dp", None))
        extra_structs, extra_shardings = (), ()
        if cfg.is_encdec:
            extra_structs = (cross_kv_struct(cfg, shape),)
            kv_sh = NamedSharding(mesh, sh.spec("dp", "sp", None, None))
            extra_shardings = ((kv_sh, kv_sh),)

        def serve_step(params, state, tokens, *extra):
            cross = extra[0] if extra else None
            return decode_step(params, cfg, state, tokens, sh,
                               cross_kv=cross)

        fn = jax.jit(
            serve_step,
            in_shardings=(
                to_sh(pspecs), to_sh(cspecs), tok_sharding,
                *extra_shardings,
            ),
            out_shardings=(None, to_sh(cspecs)),
            donate_argnums=(1,),
        )
        lowered = fn.lower(
            params_structs, state_structs, tok_struct, *extra_structs
        )
        record["model_flops"] = (
            2 * cfg.active_param_count() * shape.global_batch
        )

    record["lower_s"] = round(time.time() - t0, 2)
    t1 = time.time()
    compiled = lowered.compile()
    record["compile_s"] = round(time.time() - t1, 2)

    ma = compiled.memory_analysis()
    record["memory"] = {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "code_bytes": ma.generated_code_size_in_bytes,
        "peak_bytes_est": (
            ma.argument_size_in_bytes
            + ma.output_size_in_bytes
            + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes
        ),
    }
    from ..compat import cost_analysis

    ca = cost_analysis(compiled)
    record["cost_raw"] = {  # XLA's own numbers (while bodies counted ONCE)
        "flops": ca.get("flops", 0.0),
        "bytes_accessed": ca.get("bytes accessed", 0.0),
        "transcendentals": ca.get("transcendentals", 0.0),
    }
    # trip-count-aware walk of the compiled module (per-device totals)
    import gzip

    from ..analysis.hlo_cost import analyze_module

    hlo_text = compiled.as_text()
    with gzip.open(
        os.path.join(out_dir, f"{cell_id}.hlo.txt.gz"), "wt"
    ) as zf:
        zf.write(hlo_text)
    mc = analyze_module(hlo_text)
    record["cost"] = {
        "flops": mc.flops,
        "bytes_accessed": mc.bytes,
    }
    record["collectives"] = {
        "operand_bytes": mc.collective_operand_bytes,
        "ring_bytes": mc.collective_ring_bytes,
        "by_kind": mc.collectives_by_kind(),
        "count": int(sum(c.count for c in mc.collectives)),
    }
    record["status"] = "ok"
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    mem_gb = record["memory"]["peak_bytes_est"] / 2 ** 30
    print(
        f"OK {cell_id}: compile={record['compile_s']}s "
        f"mem/dev={mem_gb:.2f}GiB flops={record['cost']['flops']:.3g} "
        f"coll={record['collectives']['count']}"
    )
    return record


# ---------------------------------------------------------------------------
# sweep driver (subprocess per cell: isolates compile memory)
# ---------------------------------------------------------------------------

def sweep(out_dir: str, force: bool = False, multipod_only: bool = False,
          cells=None):
    from ..configs import all_cells

    todo = cells or [
        (a, s) for a, s, _ in all_cells()
    ]
    results = []
    for multi_pod in ([True] if multipod_only else [False, True]):
        mesh_name = "2x16x16" if multi_pod else "16x16"
        for arch, shape_name in todo:
            out_path = os.path.join(
                out_dir, f"{arch}__{shape_name}__{mesh_name}.json"
            )
            if not force and os.path.exists(out_path):
                with open(out_path) as f:
                    rec = json.load(f)
                if rec.get("status") in ("ok", "skipped"):
                    print(f"CACHED {arch}__{shape_name}__{mesh_name}")
                    results.append(rec)
                    continue
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", arch, "--shape", shape_name, "--out", out_dir,
            ]
            if multi_pod:
                cmd.append("--multipod")
            proc = subprocess.run(
                cmd, capture_output=True, text=True, timeout=3600
            )
            if proc.returncode != 0:
                err = {
                    "arch": arch, "shape": shape_name, "mesh": mesh_name,
                    "status": "error",
                    "stderr": proc.stderr[-4000:],
                }
                with open(out_path, "w") as f:
                    json.dump(err, f, indent=1)
                print(f"ERROR {arch}__{shape_name}__{mesh_name}")
                print(proc.stderr[-1500:])
                results.append(err)
            else:
                print(proc.stdout.strip().splitlines()[-1])
                with open(out_path) as f:
                    results.append(json.load(f))
    ok = sum(1 for r in results if r.get("status") == "ok")
    sk = sum(1 for r in results if r.get("status") == "skipped")
    er = sum(1 for r in results if r.get("status") == "error")
    print(f"\nsweep done: {ok} ok, {sk} skipped, {er} error")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--mb", type=int, default=None,
                    help="override train microbatch count")
    ap.add_argument("--policy", action="append", default=[],
                    help="Sharding field override key=val (hillclimb)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=os.path.normpath(RESULTS_DIR))
    args = ap.parse_args()
    if args.all:
        sweep(args.out, force=args.force)
    else:
        try:
            run_cell(args.arch, args.shape, args.multipod, args.out,
                     mb_override=args.mb,
                     policy_overrides=dict(
                         kv.split("=", 1) for kv in args.policy
                     ))
        except Exception:
            traceback.print_exc()
            sys.exit(1)


if __name__ == "__main__":
    main()
