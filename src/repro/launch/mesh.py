"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run sets the placeholder
device count before first jax init.
"""

from __future__ import annotations

from ..compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single-pod v5e 16x16 (256 chips) or 2-pod 2x16x16 (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def dp_axes(multi_pod: bool = False) -> tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


def make_debug_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for 8-host-device tests."""
    return make_mesh((n_data, n_model), ("data", "model"))
