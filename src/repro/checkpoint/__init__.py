"""Fault-tolerant checkpointing: atomic step dirs, async save, keep-k GC,
integrity manifest, elastic (mesh-agnostic) restore."""

from .manager import CheckpointManager, restore_latest, save_checkpoint

__all__ = ["CheckpointManager", "restore_latest", "save_checkpoint"]
