"""Checkpoint manager.

Design for 1000+ nodes (DESIGN.md §5), realized with local-filesystem
primitives (a deployment swaps the .npz writer for a parallel object-store
writer; every other property is layout-independent):

* **Atomicity**: write to ``step_<n>.tmp/``, fsync, rename to ``step_<n>/``
  — a crash mid-save never corrupts the latest checkpoint.
* **Integrity**: manifest.json holds per-array shapes/dtypes + a checksum;
  restore verifies before trusting.
* **Elasticity**: arrays are saved as *logical* (fully-assembled) tensors +
  the PartitionSpec they were trained under. Restore re-shards to ANY mesh
  (different device count, pod count, axis sizes) via device_put with the
  new mesh's NamedSharding — checkpoints are mesh-agnostic by construction.
* **Async save**: `save_async` snapshots to host memory then writes on a
  background thread, overlapping I/O with the next training steps.
* **GC**: keep-last-k with never-delete-unverified semantics.

Pytree layout is serialized by flattening with path strings, so any nested
dict/list/NamedTuple state (params, optimizer moments, data step) round-
trips without a schema.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding


# --------------------------------------------------------------------------
# pytree <-> flat dict
# --------------------------------------------------------------------------

def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        flat[key] = leaf
    return flat


def _unflatten_into(template, flat: dict[str, Any]):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


# --------------------------------------------------------------------------
# save / restore
# --------------------------------------------------------------------------

def _checksum(arrays: dict[str, np.ndarray]) -> str:
    h = hashlib.sha256()
    for k in sorted(arrays):
        h.update(k.encode())
        a = arrays[k]
        h.update(str(a.shape).encode())
        h.update(str(a.dtype).encode())
        # sampled content hash (hashing TBs fully would serialize the save)
        flat = a.reshape(-1)
        probe = flat[:: max(1, flat.size // 4096)]
        h.update(np.ascontiguousarray(probe).tobytes())
    return h.hexdigest()


def save_checkpoint(directory: str, step: int, tree, extra: dict | None = None):
    """Synchronous atomic save of a pytree at `step`."""
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    arrays = {}
    for k, v in flat.items():
        a = np.asarray(jax.device_get(v))
        if a.dtype == jnp.bfloat16:
            arrays[k] = a.view(np.uint16)
        else:
            arrays[k] = a
    bf16_keys = [
        k for k, v in flat.items()
        if hasattr(v, "dtype") and v.dtype == jnp.bfloat16
    ]
    tmp = os.path.join(directory, f"step_{step}.tmp")
    final = os.path.join(directory, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "checksum": _checksum(arrays),
        "bf16_keys": bf16_keys,
        "extra": extra or {},
        "leaves": {
            k: {"shape": list(np.shape(a)), "dtype": str(a.dtype)}
            for k, a in arrays.items()
        },
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def list_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name.split("_")[1]))
            except ValueError:
                continue
    return sorted(out)


def restore_latest(
    directory: str,
    template,
    mesh=None,
    spec_tree=None,
    step: int | None = None,
):
    """Restore into `template`'s structure, re-sharded onto `mesh` per
    `spec_tree` (elastic: the mesh need not match the saving mesh).

    Returns (step, tree) or (None, None) when no checkpoint exists.
    """
    steps = list_steps(directory)
    if not steps:
        return None, None
    step = step if step is not None else steps[-1]
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    if _checksum(arrays) != manifest["checksum"]:
        raise IOError(f"checkpoint {path} failed integrity check")
    bf16 = set(manifest.get("bf16_keys", []))
    flat = {}
    spec_flat = _flatten(spec_tree) if spec_tree is not None else {}
    for k, a in arrays.items():
        if k in bf16:
            a = a.view(jnp.bfloat16)
        if mesh is not None and k in spec_flat:
            flat[k] = jax.device_put(a, NamedSharding(mesh, spec_flat[k]))
        else:
            flat[k] = jnp.asarray(a)
    tree = _unflatten_into(template, flat)
    return manifest["step"], tree


class CheckpointManager:
    """Async save + keep-k GC around the primitives above."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.saved_steps: list[int] = list_steps(directory)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, step: int, tree, extra: dict | None = None):
        """Snapshot to host now; write + GC on a background thread."""
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            save_checkpoint(self.directory, step, host_tree, extra)
            self.saved_steps = list_steps(self.directory)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save(self, step: int, tree, extra: dict | None = None):
        save_checkpoint(self.directory, step, tree, extra)
        self.saved_steps = list_steps(self.directory)
        self._gc()

    def _gc(self):
        steps = list_steps(self.directory)
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s}"),
                ignore_errors=True,
            )
        self.saved_steps = list_steps(self.directory)

    def restore_latest(self, template, mesh=None, spec_tree=None):
        self.wait()
        return restore_latest(self.directory, template, mesh, spec_tree)
