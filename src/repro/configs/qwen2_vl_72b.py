"""qwen2-vl-72b — VLM backbone with M-RoPE; vision frontend STUBBED
(input_specs supply precomputed patch embeddings). [arXiv:2409.12191; hf]
80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064."""

from dataclasses import replace

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    act="silu_glu",
    rope_theta=1e6,
    mrope=True,
    frontend="vision_stub",
)


def smoke() -> ArchConfig:
    return replace(
        CONFIG,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
    )
