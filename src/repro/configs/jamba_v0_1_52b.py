"""jamba-v0.1-52b — hybrid Mamba+attention 1:7 interleave with MoE 16e top-2.
[arXiv:2403.19887; hf] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.

Layer pattern (period 8): attention at position 4, Mamba elsewhere; MoE FFN
at odd positions (16 MoE layers total), dense FFN at even positions.
Jamba's Mamba-1 layers are realized with the SSD formulation at Jamba's
dimensions (d_state=16) — see DESIGN.md §4.
"""

from dataclasses import replace

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    head_dim=128,
    act="silu_glu",
    n_experts=16,
    top_k=2,
    moe_d_ff=14336,
    moe_every=2,
    moe_offset=1,
    attn_every=8,
    attn_offset=4,
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=256,
)


def smoke() -> ArchConfig:
    return replace(
        CONFIG,
        n_layers=8,            # one full period
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
        n_experts=4,
        top_k=2,
        moe_d_ff=64,
        ssm_state=8,
        ssm_head_dim=16,
        ssm_chunk=8,
    )
