"""mamba2-2.7b — SSD (state-space duality), attention-free.
[arXiv:2405.21060] 64L d_model=2560 d_ff=0 vocab=50280 ssm_state=128."""

from dataclasses import replace

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=256,
    tie_embeddings=True,
)


def smoke() -> ArchConfig:
    return replace(
        CONFIG,
        n_layers=2,
        d_model=64,
        vocab_size=128,
        ssm_state=16,
        ssm_head_dim=16,
        ssm_chunk=8,
    )
