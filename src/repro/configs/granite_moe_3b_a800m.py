"""granite-moe-3b-a800m — MoE, 40 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base]
32L d_model=1536 24H (GQA kv=8) expert d_ff=512 vocab=49155."""

from dataclasses import replace

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=0,
    vocab_size=49155,
    head_dim=64,
    n_experts=40,            # 40 % 16 != 0 -> 'ffn' MoE sharding policy
    top_k=8,
    moe_d_ff=512,
    moe_every=1,
)


def smoke() -> ArchConfig:
    return replace(
        CONFIG,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        vocab_size=128,
        n_experts=5,
        top_k=2,
        moe_d_ff=32,
    )
