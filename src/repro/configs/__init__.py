"""Architecture registry: ``get_config(name)`` / ``get_smoke(name)``.

One module per assigned architecture with the exact published sizes
(see the per-file source citations), plus reduced same-family smoke
configs for CPU tests.
"""

from __future__ import annotations

import importlib

from repro.models.config import SHAPES, ArchConfig, RunShape

ARCH_MODULES = {
    "mamba2-2.7b": "mamba2_2_7b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "nemotron-4-340b": "nemotron_4_340b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "yi-34b": "yi_34b",
    "qwen2-1.5b": "qwen2_1_5b",
    "whisper-tiny": "whisper_tiny",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "qwen2-vl-72b": "qwen2_vl_72b",
}

ARCH_NAMES = tuple(ARCH_MODULES)

# long_500k requires sub-quadratic sequence handling: run for SSM/hybrid
# only; skip (documented, DESIGN.md §4) for pure full-attention archs.
LONG_CONTEXT_ARCHS = ("mamba2-2.7b", "jamba-v0.1-52b")


def _module(name: str):
    if name not in ARCH_MODULES:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(ARCH_MODULES)}"
        )
    return importlib.import_module(f"repro.configs.{ARCH_MODULES[name]}")


def get_config(name: str) -> ArchConfig:
    return _module(name).CONFIG


def get_smoke(name: str) -> ArchConfig:
    return _module(name).smoke()


def get_shape(name: str) -> RunShape:
    return SHAPES[name]


def cell_is_skipped(arch: str, shape: str) -> str | None:
    """Returns the skip reason for a (arch, shape) cell, or None if it runs."""
    if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return (
            "long_500k needs sub-quadratic sequence mixing; "
            f"{arch} is pure full-attention (DESIGN.md §4)"
        )
    return None


def all_cells() -> list[tuple[str, str, str | None]]:
    """All 40 (arch, shape, skip_reason) cells."""
    return [
        (a, s, cell_is_skipped(a, s))
        for a in ARCH_NAMES
        for s in SHAPES
    ]
