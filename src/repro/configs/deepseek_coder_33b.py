"""deepseek-coder-33b — dense llama-arch GQA. [arXiv:2401.14196; hf]
62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256."""

from dataclasses import replace

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,              # 56 % 16 != 0 -> context-parallel attention
    n_kv_heads=8,
    d_ff=19200,
    vocab_size=32256,
    head_dim=128,
    act="silu_glu",
    rope_theta=1e5,
)


def smoke() -> ArchConfig:
    return replace(
        CONFIG,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
    )
