"""whisper-tiny — encoder-decoder audio backbone, conv frontend STUBBED
(input_specs supply precomputed frame embeddings). [arXiv:2212.04356]
4L enc + 4L dec, d_model=384 6H d_ff=1536 vocab=51865."""

from dataclasses import replace

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,
    d_model=384,
    n_heads=6,               # 6 % 16 != 0 -> context-parallel attention
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    head_dim=64,
    act="gelu",
    norm="layernorm",
    is_encdec=True,
    dec_layers=4,
    max_target_len=448,
    frontend="audio_stub",
    tie_embeddings=True,
)


def smoke() -> ArchConfig:
    return replace(
        CONFIG,
        n_layers=2,
        dec_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
        max_target_len=16,
    )
