"""olmoe-1b-7b — MoE, 64 experts top-8. [arXiv:2409.02060; hf]
16L d_model=2048 16H (GQA kv=16) expert d_ff=1024 vocab=50304."""

from dataclasses import replace

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=0,                  # every FFN is MoE
    vocab_size=50304,
    n_experts=64,
    top_k=8,
    moe_d_ff=1024,
    moe_every=1,
    rope_theta=1e4,
)


def smoke() -> ArchConfig:
    return replace(
        CONFIG,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        vocab_size=128,
        n_experts=8,
        top_k=2,
        moe_d_ff=32,
    )
