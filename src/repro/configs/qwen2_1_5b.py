"""qwen2-1.5b — dense GQA with QKV bias. [arXiv:2407.10671; hf]
28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936."""

from dataclasses import replace

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-1.5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,              # 12 % 16 != 0 -> context-parallel attention
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=True,
    act="silu_glu",
    rope_theta=1e6,
    tie_embeddings=True,
)


def smoke() -> ArchConfig:
    return replace(
        CONFIG,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
    )
