"""Optimizer substrate: AdamW with ZeRO-shardable f32 moments, global-norm
clipping, LR schedules."""

from .adamw import AdamWState, adamw_init, adamw_update, opt_state_specs
from .schedule import cosine_schedule, linear_warmup

__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "opt_state_specs",
    "cosine_schedule",
    "linear_warmup",
]
