"""AdamW, hand-rolled for explicit sharding control.

Moments are f32 and inherit the parameter's PartitionSpec (they live fully
sharded under FSDP — ZeRO-style: with params sharded over ('data','model')
axes the optimizer state adds 8 bytes/param spread over the whole mesh).
bf16 params are updated through an f32 side computation (no separate master
copy: update math runs in f32 from the f32 moments and the bf16 param is
re-rounded — adequate at these LRs and halves optimizer memory; flip
``keep_master=True`` for exact fp32-master semantics).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any          # pytree like params, f32
    v: Any          # pytree like params, f32
    master: Any     # f32 params pytree or None


def adamw_init(
    params, keep_master: bool = False, moment_dtype=jnp.float32
) -> AdamWState:
    """moment_dtype=bf16 halves optimizer memory (used for the >=300B
    archs to fit v5e HBM — the 8-bit-Adam-style tradeoff, DESIGN.md §5)."""
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, moment_dtype), params
    )
    master = (
        jax.tree.map(lambda p: p.astype(jnp.float32), params)
        if keep_master
        else None
    )
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=zeros,
        v=jax.tree.map(jnp.copy, zeros),
        master=master,
    )


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(tree)
        )
    )


def adamw_update(
    params,
    grads,
    state: AdamWState,
    lr: jax.Array | float,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
    math_dtype=None,
):
    """Returns (new_params, new_state, metrics).

    ``math_dtype``: update arithmetic precision (default f32). bf16 halves
    the f32-upcast temporaries for the >=300B archs (8-bit-Adam-style
    memory/precision tradeoff, DESIGN.md §5).
    """
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-12))

    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    mdt = math_dtype or jnp.float32

    def upd_math(p, g, m, v, master):
        g = g.astype(mdt) * scale.astype(mdt)
        mdtype = m.dtype
        m_new = b1 * m.astype(mdt) + (1 - b1) * g
        v_new = b2 * v.astype(mdt) + (1 - b2) * jnp.square(g)
        mh = (m_new / c1).astype(jnp.float32)
        vh = (v_new / c2).astype(jnp.float32)
        base = (
            master if master is not None else p.astype(jnp.float32)
        ) if mdt == jnp.float32 else p.astype(mdt)
        delta = (mh / (jnp.sqrt(vh) + eps)).astype(mdt) + (
            weight_decay * base
        ).astype(mdt)
        new_master = (base.astype(mdt) - (lr * delta).astype(mdt))
        return (
            new_master.astype(p.dtype),
            m_new.astype(mdtype),
            v_new.astype(mdtype),
            new_master if master is not None else None,
        )

    upd = upd_math

    leaves_p, treedef = jax.tree.flatten(params)
    leaves_g = treedef.flatten_up_to(grads)
    leaves_m = treedef.flatten_up_to(state.m)
    leaves_v = treedef.flatten_up_to(state.v)
    leaves_ma = (
        treedef.flatten_up_to(state.master)
        if state.master is not None
        else [None] * len(leaves_p)
    )
    out = [
        upd(p, g, m, v, ma)
        for p, g, m, v, ma in zip(
            leaves_p, leaves_g, leaves_m, leaves_v, leaves_ma
        )
    ]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_master = (
        treedef.unflatten([o[3] for o in out])
        if state.master is not None
        else None
    )
    metrics = {"grad_norm": gnorm, "clip_scale": scale}
    return (
        new_params,
        AdamWState(step, new_m, new_v, new_master),
        metrics,
    )


def opt_state_specs(param_spec_tree, keep_master: bool = False) -> AdamWState:
    """Moments inherit the param specs (fully sharded, ZeRO-style)."""
    from jax.sharding import PartitionSpec as P

    return AdamWState(
        step=P(),
        m=param_spec_tree,
        v=jax.tree.map(lambda s: s, param_spec_tree),
        master=(
            jax.tree.map(lambda s: s, param_spec_tree) if keep_master else None
        ),
    )
