"""Process-local metrics: counters, gauges, and histograms for the engine.

The repo's instrumentation used to be a bare module-global int in
``engine.execute`` (``_pallas_dispatches``) plus ad-hoc test plumbing.
This module replaces that with one :class:`MetricsRegistry` — a small,
dependency-free (no jax import) process-local registry every layer
writes to:

``engine.pallas_dispatches``        counter — kernel-path contractions
``tune.cache_hits`` / ``_misses``   counters — plan-cache resolution
``tune.candidates_measured``        counter — autotune measurements run
``tune.search_time_us``             histogram — per-search wall time
``distributed.sweep_collective_bytes``
                                    histogram — HLO-measured bytes of one
                                    distributed ALS/HOOI sweep program
``trace.events_dropped``            counter — ring-buffer evictions

Reads are *snapshot-based*: measure a code region with

    before = registry().snapshot()
    ...work...
    delta = registry().delta(before)     # {"engine.pallas_dispatches": 3}

instead of the old reset-the-global-between-measurements footgun (two
interleaved measurements used to corrupt each other; snapshots are
immutable, so they cannot).

The old ``repro.engine.execute.pallas_dispatch_count()`` shim has been
removed; the registry is the only spelling (a ``repro.verify`` lint rule,
RV106, forbids reintroducing it).
"""

from __future__ import annotations

import threading
from types import MappingProxyType
from typing import Mapping

#: Canonical metric names (importable so call sites cannot typo them).
PALLAS_DISPATCHES = "engine.pallas_dispatches"
TUNE_CACHE_HITS = "tune.cache_hits"
TUNE_CACHE_MISSES = "tune.cache_misses"
TUNE_CANDIDATES = "tune.candidates_measured"
TUNE_SEARCH_TIME_US = "tune.search_time_us"
SWEEP_COLLECTIVE_BYTES = "distributed.sweep_collective_bytes"
TRACE_EVENTS_DROPPED = "trace.events_dropped"


class MetricsRegistry:
    """Counters / gauges / histograms behind one lock.

    Counters are monotone (``inc``), gauges are last-write-wins
    (``set_gauge``), histograms keep the raw observations (``observe``;
    summarized on export — the series here are short: one entry per
    search / sweep, not per request).  All methods are thread-safe and
    cheap enough to stay on even when nothing reads them — matching the
    always-on behavior of the old pallas dispatch global.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: dict[str, float] = {}
        self._gauges: dict[str, float] = {}
        self._hists: dict[str, list[float]] = {}

    # -- writes --------------------------------------------------------------
    def inc(self, name: str, value: float = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + value

    def set_gauge(self, name: str, value: float) -> None:
        with self._lock:
            self._gauges[name] = value

    def observe(self, name: str, value: float) -> None:
        with self._lock:
            self._hists.setdefault(name, []).append(value)

    # -- reads ---------------------------------------------------------------
    def counter(self, name: str) -> float:
        """Current value of one counter (0 if never incremented)."""
        with self._lock:
            return self._counters.get(name, 0)

    def gauge(self, name: str) -> float | None:
        with self._lock:
            return self._gauges.get(name)

    def histogram(self, name: str) -> tuple[float, ...]:
        """The raw observations recorded under ``name`` (a copy)."""
        with self._lock:
            return tuple(self._hists.get(name, ()))

    def snapshot(self) -> Mapping[str, float]:
        """An immutable point-in-time view of every counter.

        This is how a measurement brackets a code region — two concurrent
        measurements each hold their own snapshot, so neither can clobber
        the other (the reset-between-measurements footgun the old global
        had)."""
        with self._lock:
            return MappingProxyType(dict(self._counters))

    def delta(self, before: Mapping[str, float]) -> dict[str, float]:
        """Counter increments since ``before`` (a :meth:`snapshot`);
        zero-delta names are omitted."""
        now = self.snapshot()
        out: dict[str, float] = {}
        for name, value in now.items():
            d = value - before.get(name, 0)
            if d:
                out[name] = d
        return out

    def to_dict(self) -> dict:
        """Export everything (histograms summarized) — the shape the
        trace exporter and benchmark rows embed."""
        with self._lock:
            hists = {
                name: {
                    "count": len(vals),
                    "sum": sum(vals),
                    "min": min(vals) if vals else None,
                    "max": max(vals) if vals else None,
                }
                for name, vals in self._hists.items()
            }
            return {
                "counters": dict(self._counters),
                "gauges": dict(self._gauges),
                "histograms": hists,
            }

    def reset(self) -> None:
        """Clear everything. For test isolation only — measurement code
        must bracket with :meth:`snapshot`/:meth:`delta` instead."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()


_REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-wide registry every engine layer writes to."""
    return _REGISTRY
