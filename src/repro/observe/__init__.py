"""repro.observe: tracing, metrics, and bound-aware auditing.

The observability layer threaded through every engine dispatch (see
``docs/ARCHITECTURE.md`` § Observability):

* :class:`~repro.observe.trace.Trace` — context-manager span recorder
  (ring buffer + JSONL export + profiler annotations), gated by
  ``ExecutionContext.observe`` / the trace's ``capture`` policy.
* :class:`~repro.observe.metrics.MetricsRegistry` (via
  :func:`~repro.observe.metrics.registry`) — process-local counters /
  gauges / histograms; the one home of the kernel-dispatch counter
  (the old ``pallas_dispatch_count()`` shim is gone), read via
  snapshot-based deltas.
* :mod:`~repro.observe.bounds_audit` — measured-bytes / modeled-words /
  lower-bound triples per compiled dispatch (the paper's claim as a
  runtime metric).
* ``python -m repro.observe.report`` — markdown dispatch table with
  model / measured / bound columns from a JSONL trace.
"""

from .bounds_audit import AuditRow, audit_mttkrp, audit_multi_ttm
from .metrics import MetricsRegistry, registry
from .trace import (
    SPAN_SCHEMA,
    Trace,
    current_trace,
    load_trace,
    record_event,
    should_record,
    summarize_events,
)

__all__ = [
    "Trace",
    "MetricsRegistry",
    "registry",
    "AuditRow",
    "audit_mttkrp",
    "audit_multi_ttm",
    "SPAN_SCHEMA",
    "current_trace",
    "load_trace",
    "record_event",
    "should_record",
    "summarize_events",
]
