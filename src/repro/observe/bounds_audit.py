"""The optimality-ratio auditor: measured bytes vs model vs lower bound.

The paper's headline claim is a *triple* — what a blocked MTTKRP
actually moves, what the Eq (10) blocked model says it should move, and
what Theorem 4.1 says it *must* move.  This module renders that triple
as a runtime metric: for any jitted engine call it compiles the program,
walks the HLO with the existing analyzers
(:func:`repro.analysis.hlo_cost.analyze_module` for memory traffic,
:func:`repro.distributed.hlo.parse_collectives` for collectives) and
emits one :class:`AuditRow` per dispatch with

    measured_bytes   — HLO fusion-boundary bytes of the compiled program
    modeled_words    — ``BlockPlan.eq10_words`` (Eq 10) /
                       ``MultiTTMPlan.model_words``
    lower_bound_words— ``seq_lb_memory`` (Thm 4.1) /
                       ``multi_ttm_seq_lb_memory``, clamped at 0

plus the two ratios that summarize them (``measured / modeled`` — how
honest the model is; ``modeled / bound`` — how close to optimal the
schedule is).  Rows are also recorded into the active
:class:`~repro.observe.trace.Trace` (kind ``"bounds_audit"``), so the
report CLI can table them next to ordinary dispatch spans.
"""

from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import Sequence

from .trace import record_event


@dataclass(frozen=True)
class AuditRow:
    """One dispatch's measured / modeled / lower-bound triple (bytes are
    HLO-measured; words are dtype-free model counts)."""

    name: str
    itemsize: int
    measured_bytes: float
    modeled_words: float
    lower_bound_words: float

    @property
    def modeled_bytes(self) -> float:
        return self.modeled_words * self.itemsize

    @property
    def lower_bound_bytes(self) -> float:
        return self.lower_bound_words * self.itemsize

    @property
    def measured_over_model(self) -> float | None:
        """How far above the blocked model the compiled program runs
        (1.0 = the model is exact; None when the model is degenerate)."""
        if self.modeled_bytes <= 0:
            return None
        return self.measured_bytes / self.modeled_bytes

    @property
    def model_over_bound(self) -> float | None:
        """The optimality ratio: modeled traffic over the Thm-4.1 floor
        (None when the bound clamps to 0 — small problems fit in fast
        memory and the bound says nothing)."""
        if self.lower_bound_bytes <= 0:
            return None
        return self.modeled_bytes / self.lower_bound_bytes

    def to_dict(self) -> dict:
        d = asdict(self)
        d["modeled_bytes"] = self.modeled_bytes
        d["lower_bound_bytes"] = self.lower_bound_bytes
        d["measured_over_model"] = self.measured_over_model
        d["model_over_bound"] = self.model_over_bound
        return d


def _audit_compiled(
    compiled,
    *,
    name: str,
    itemsize: int,
    modeled_words: float,
    lower_bound_words: float,
) -> AuditRow:
    """Walk one compiled program's HLO and build (+record) the row."""
    from ..analysis.hlo_cost import analyze_compiled

    cost = analyze_compiled(compiled)
    row = AuditRow(
        name=name,
        itemsize=int(itemsize),
        measured_bytes=float(cost.bytes),
        modeled_words=float(modeled_words),
        lower_bound_words=float(lower_bound_words),
    )
    record_event(
        "bounds_audit",
        name=name,
        itemsize=row.itemsize,
        measured_bytes=row.measured_bytes,
        modeled_words=row.modeled_words,
        lower_bound_words=row.lower_bound_words,
        measured_over_model=row.measured_over_model,
        model_over_bound=row.model_over_bound,
        measured_collective_bytes=float(cost.collective_ring_bytes),
    )
    return row


def audit_mttkrp(
    x,
    factors: Sequence,
    mode: int,
    *,
    ctx=None,
) -> AuditRow:
    """Compile ``mttkrp(x, factors, mode, ctx=ctx)`` under jit and audit
    it: measured HLO bytes vs the Eq-10 blocked model vs the Thm-4.1
    memory-dependent lower bound (both evaluated against ``ctx.memory``,
    defaulting to the resolver's TPU-VMEM budget)."""
    import jax

    from ..core.bounds import seq_lb_memory
    from ..engine.context import ExecutionContext
    from ..engine.execute import _mode_first, mttkrp
    from ..engine.plan import Memory, choose_blocks

    if ctx is None:
        ctx = ExecutionContext.default()
    rank = next(f.shape[1] for k, f in enumerate(factors) if k != mode)
    itemsize = x.dtype.itemsize
    mem = ctx.memory or Memory.tpu_vmem(itemsize=itemsize)
    plan = choose_blocks(
        _mode_first(x.shape, mode), rank, itemsize, memory=mem
    )
    modeled = plan.eq10_words(_mode_first(x.shape, mode), rank)
    lb = max(seq_lb_memory(x.shape, rank, mem.budget_words), 0.0)

    def call(xx, *fs):
        return mttkrp(xx, list(fs), mode, ctx=ctx)

    compiled = jax.jit(call).lower(x, *factors).compile()
    return _audit_compiled(
        compiled,
        name=f"mttkrp[shape={tuple(x.shape)},rank={rank},mode={mode}]",
        itemsize=itemsize,
        modeled_words=modeled,
        lower_bound_words=lb,
    )


def audit_multi_ttm(
    x,
    matrices: Sequence,
    keep: int | None = None,
    *,
    ctx=None,
) -> AuditRow:
    """The Multi-TTM analog of :func:`audit_mttkrp`: measured HLO bytes
    vs ``MultiTTMPlan.model_words`` vs ``multi_ttm_seq_lb_memory``."""
    import jax

    from ..core.bounds import multi_ttm_seq_lb_memory
    from ..engine.context import ExecutionContext
    from ..engine.execute import _keep_first, multi_ttm
    from ..engine.plan import Memory, choose_multi_ttm_blocks

    if ctx is None:
        ctx = ExecutionContext.default()
    ranks = tuple(
        m.shape[1] for k, m in enumerate(matrices) if k != keep
    )
    itemsize = x.dtype.itemsize
    mem = ctx.memory or Memory.tpu_vmem(itemsize=itemsize)
    canon = _keep_first(x.shape, 0 if keep is None else keep)
    kernel_ranks = ranks[1:] if keep is None else ranks
    plan = choose_multi_ttm_blocks(canon, kernel_ranks, itemsize, memory=mem)
    modeled = plan.model_words(canon)
    lb = max(
        multi_ttm_seq_lb_memory(x.shape, ranks, mem.budget_words), 0.0
    )

    def call(xx, *ms):
        ms = list(ms)
        if keep is not None:
            ms.insert(keep, None)
        return multi_ttm(xx, ms, keep, ctx=ctx)

    concrete = [m for k, m in enumerate(matrices) if k != keep]
    compiled = jax.jit(call).lower(x, *concrete).compile()
    return _audit_compiled(
        compiled,
        name=(
            f"multi_ttm[shape={tuple(x.shape)},ranks={ranks},keep={keep}]"
        ),
        itemsize=itemsize,
        modeled_words=modeled,
        lower_bound_words=lb,
    )
