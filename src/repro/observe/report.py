"""Render a JSONL trace into a markdown dispatch table.

Usage:
    PYTHONPATH=src python -m repro.observe.report TRACE.jsonl \\
        [--flag-factor 2.0] [--strict] [--kinds mttkrp,multi_ttm,...]

Reads a trace exported by :class:`repro.observe.trace.Trace` and prints
one markdown table row per dispatch-like event, with the model /
measured / bound columns the paper's claims live in:

| # | kind | problem | backend | model (words) | bound (words) | measured (bytes) | x model | flag |

``x model`` is measured bytes over modeled bytes (events without a
measured side — ordinary dispatch spans — show ``-``; collective-sweep
and bounds-audit events have one).  Any event whose measured traffic
exceeds its model by more than ``--flag-factor`` (default 2.0) is
flagged ``!``; ``--strict`` turns flags into exit status 1.

Exit status: 0 = table rendered; 1 = empty table (nothing dispatch-like
in the trace — the CI smoke treats that as a broken pipeline) or, with
``--strict``, at least one flagged row; 2 = unreadable input.
"""

from __future__ import annotations

import argparse
import sys

#: Event kinds that are dispatch-like (one engine contraction or one
#: measured sweep/audit) and hence rows in the report.
DISPATCH_KINDS = (
    "mttkrp",
    "contract_partial",
    "multi_ttm",
    "fused_pair",
    "cp_sweep_collectives",
    "tucker_sweep_collectives",
    "bounds_audit",
    "static_verify",
)


def _problem(e: dict) -> str:
    shape = e.get("shape")
    rank = e.get("rank", e.get("ranks"))
    mode = e.get("mode", e.get("keep"))
    grid = e.get("grid")
    bits = []
    if shape is not None:
        bits.append("x".join(str(s) for s in shape))
    if rank is not None:
        bits.append(f"r={rank}")
    if mode is not None:
        bits.append(f"m={mode}")
    if grid is not None:
        bits.append(f"g={'x'.join(str(g) for g in grid)}")
    return " ".join(bits) or e.get("name", "-")


def _fmt(v, digits: int = 0) -> str:
    if v is None:
        return "-"
    if digits:
        return f"{float(v):.{digits}f}"
    return f"{float(v):,.0f}"


def render_rows(
    events: list[dict],
    *,
    flag_factor: float = 2.0,
    kinds: tuple[str, ...] = DISPATCH_KINDS,
) -> tuple[list[str], int]:
    """Markdown table lines for the dispatch-like events; returns
    ``(lines, flagged_count)``. Empty list = nothing dispatch-like."""
    rows: list[str] = []
    flagged = 0
    for e in events:
        kind = e.get("kind")
        if kind not in kinds:
            continue
        modeled = e.get("modeled_words")
        bound = e.get("lower_bound_words")
        measured = e.get(
            "measured_bytes", e.get("measured_collective_bytes")
        )
        itemsize = float(e.get("itemsize", 4))
        ratio = None
        if measured is not None and modeled:
            ratio = float(measured) / (float(modeled) * itemsize)
        flag = ""
        if ratio is not None and ratio > flag_factor:
            flag = "!"
            flagged += 1
        rows.append(
            f"| {e.get('seq', '-')} | {kind} | {_problem(e)} "
            f"| {e.get('backend', '-')} "
            f"| {_fmt(modeled)} | {_fmt(bound)} | {_fmt(measured)} "
            f"| {_fmt(ratio, 2) if ratio is not None else '-'} "
            f"| {flag} |"
        )
    return rows, flagged


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.observe.report", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("trace", help="JSONL trace file (Trace(path=...))")
    ap.add_argument(
        "--flag-factor", type=float, default=2.0,
        help="flag rows whose measured bytes exceed modeled bytes by "
        "this factor (default 2.0)",
    )
    ap.add_argument(
        "--strict", action="store_true",
        help="exit 1 when any row is flagged",
    )
    ap.add_argument(
        "--kinds", default=None,
        help=f"comma-separated event kinds to table "
        f"(default: {','.join(DISPATCH_KINDS)})",
    )
    args = ap.parse_args(argv)
    from .trace import load_trace

    try:
        events = load_trace(args.trace)
    except (OSError, ValueError) as e:
        print(f"report: cannot read {args.trace}: {e}", file=sys.stderr)
        return 2
    kinds = (
        tuple(k.strip() for k in args.kinds.split(",") if k.strip())
        if args.kinds else DISPATCH_KINDS
    )
    rows, flagged = render_rows(
        events, flag_factor=args.flag_factor, kinds=kinds
    )
    if not rows:
        print(
            f"report: no dispatch events in {args.trace} "
            f"({len(events)} events total; kinds={kinds})",
            file=sys.stderr,
        )
        return 1
    print(
        "| # | kind | problem | backend | model (words) | bound (words) "
        "| measured (bytes) | x model | flag |"
    )
    print("|---|------|---------|---------|---------------|---------------"
          "|------------------|---------|------|")
    for r in rows:
        print(r)
    print(
        f"\n{len(rows)} dispatch(es), {flagged} flagged "
        f"(> {args.flag_factor}x model), {len(events)} events total."
    )
    if args.strict and flagged:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
