"""Structured tracing: span events for every engine dispatch.

A :class:`Trace` is a context manager that captures *span events* — one
dict per engine dispatch / driver iteration / distributed sweep — into an
in-memory ring buffer, with a JSONL exporter (one event per line, stable
schema) and ``jax.named_scope`` / ``jax.profiler.TraceAnnotation``
annotations so observed dispatches are visible in TPU profiler traces::

    ctx = repro.ExecutionContext.create(observe=True)
    with repro.Trace(path="run.jsonl") as t:
        repro.cp_als(x, rank=8, ctx=ctx)
    t.events                    # the recorded span dicts
    # run.jsonl: one JSON object per line, schema repro.observe.Span/1

Every event carries ``schema`` / ``seq`` / ``time_s`` / ``kind`` plus
kind-specific fields.  Engine dispatch events (``kind`` in ``mttkrp`` /
``contract_partial`` / ``multi_ttm`` / ``fused_pair``) record the
resolved backend, the block plan, the modeled traffic in words
(``BlockPlan.eq10_words`` / ``MultiTTMPlan.model_words`` — the paper's
Eq (10) and its Multi-TTM analog), the memory-dependent sequential lower
bound (``seq_lb_memory``, clamped at 0), the dtype policy, and the
dispatch wall time.  Driver events (``cp_als_iter`` / ``tucker_iter``)
record per-iteration fit / λ / convergence; distributed sweep events
(``cp_sweep_collectives`` / ``tucker_sweep_collectives``) record
HLO-measured collective bytes next to the sweep cost model.

Gating — the zero-overhead contract
-----------------------------------
Nothing is recorded unless a ``Trace`` is active (entering one pushes it
on a process-local stack).  While one is active:

* ``capture="all"`` (default): every engine call records events — an
  explicit ``with Trace():`` block is itself the opt-in.
* ``capture="observed"``: only calls whose
  ``ExecutionContext.observe`` is True record — per-context opt-in for
  tracing one workload inside a larger program.

Recording is *driver-side only*: when the operands are jax tracers (the
call is being traced into a jit/shard_map program) nothing runs — no
event, no annotation — so compiled HLO is byte-identical with observe
on or off, and shard_map sweep bodies stay collective-clean.
"""

from __future__ import annotations

import json
import time
from collections import deque
from contextlib import contextmanager
from typing import Any, Iterable

from .metrics import TRACE_EVENTS_DROPPED, registry

SPAN_SCHEMA = "repro.observe.Span/1"

#: Keys every event carries, in emission order (the round-trip contract
#: tests pin; kind-specific fields follow these).
BASE_FIELDS = ("schema", "seq", "time_s", "kind")

_ACTIVE: list["Trace"] = []


class Trace:
    """Record engine span events while active; export them as JSONL.

    ``capacity`` bounds the in-memory ring buffer (oldest events are
    evicted, counted under the ``trace.events_dropped`` metric);
    ``path`` exports the buffer as JSONL on clean exit;
    ``capture`` is ``"all"`` (record every engine call) or
    ``"observed"`` (record only ``ExecutionContext.observe=True`` calls);
    ``annotate`` wraps observed dispatches in ``jax.named_scope`` +
    ``jax.profiler.TraceAnnotation`` so they appear in profiler traces.
    """

    def __init__(
        self,
        capacity: int = 4096,
        *,
        path: str | None = None,
        capture: str = "all",
        annotate: bool = True,
    ) -> None:
        if capture not in ("all", "observed"):
            raise ValueError(
                f"capture must be 'all' (every engine call records while "
                f"this trace is active) or 'observed' (only "
                f"ExecutionContext.observe=True calls), got {capture!r}"
            )
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.path = path
        self.capture = capture
        self.annotate = annotate
        self._buf: deque[dict] = deque(maxlen=self.capacity)
        self._seq = 0

    # -- context management --------------------------------------------------
    def __enter__(self) -> "Trace":
        _ACTIVE.append(self)
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        _ACTIVE.remove(self)
        if self.path is not None and exc_type is None:
            self.export(self.path)

    # -- recording -----------------------------------------------------------
    def record(self, kind: str, **fields: Any) -> dict:
        """Append one span event (ring-buffered) and return it."""
        if len(self._buf) == self._buf.maxlen:
            registry().inc(TRACE_EVENTS_DROPPED)
        event = {
            "schema": SPAN_SCHEMA,
            "seq": self._seq,
            "time_s": time.time(),
            "kind": kind,
        }
        event.update(fields)
        self._seq += 1
        self._buf.append(event)
        return event

    @property
    def events(self) -> list[dict]:
        """The buffered span events, oldest first (a copy)."""
        return list(self._buf)

    def __len__(self) -> int:
        return len(self._buf)

    # -- export --------------------------------------------------------------
    def export(self, path: str) -> int:
        """Write the buffer as JSONL (one event per line); returns the
        number of events written."""
        events = self.events
        with open(path, "w") as f:
            for e in events:
                f.write(json.dumps(e, sort_keys=True) + "\n")
        return len(events)


def current_trace() -> Trace | None:
    """The innermost active :class:`Trace`, or None."""
    return _ACTIVE[-1] if _ACTIVE else None


def load_trace(path: str) -> list[dict]:
    """Read a JSONL trace file back into its list of span events."""
    out: list[dict] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


# ---------------------------------------------------------------------------
# The wiring helpers the engine layers call
# ---------------------------------------------------------------------------

def _is_tracer(*arrays: Any) -> bool:
    import jax

    return any(isinstance(a, jax.core.Tracer) for a in arrays)


def should_record(ctx_observe: bool, *arrays: Any) -> bool:
    """One cheap gate for every wiring site: is a trace active, does its
    capture policy admit this call, and are the operands concrete (under
    jit/shard_map tracing nothing may run)?"""
    t = current_trace()
    if t is None:
        return False
    if t.capture == "observed" and not ctx_observe:
        return False
    return not _is_tracer(*arrays)


def record_event(kind: str, **fields: Any) -> dict | None:
    """Record into the active trace (no-op without one)."""
    t = current_trace()
    if t is None:
        return None
    return t.record(kind, **fields)


@contextmanager
def annotated(name: str):
    """``jax.named_scope`` + profiler annotation around one observed
    dispatch — only entered when the active trace asks for annotations
    (and never under tracing; see :func:`should_record`)."""
    t = current_trace()
    if t is None or not t.annotate:
        yield
        return
    import jax

    with jax.named_scope(name), jax.profiler.TraceAnnotation(name):
        yield


def summarize_events(events: Iterable[dict]) -> dict:
    """Aggregate a span-event stream into the summary benchmark rows
    embed: event count, total modeled words, total measured bytes (when
    any event carries them), total lower-bound words, and the
    measured-bytes / modeled-bytes optimality ratio when both sides are
    known."""
    n = 0
    modeled_words = 0.0
    modeled_bytes = 0.0
    measured_bytes = 0.0
    lower_bound_words = 0.0
    have_measured = False
    for e in events:
        n += 1
        mw = e.get("modeled_words")
        if mw is not None:
            modeled_words += float(mw)
            itemsize = float(e.get("itemsize", 4))
            modeled_bytes += float(mw) * itemsize
        lb = e.get("lower_bound_words")
        if lb is not None:
            lower_bound_words += float(lb)
        mb = e.get("measured_bytes")
        if mb is not None:
            measured_bytes += float(mb)
            have_measured = True
    summary = {
        "events": n,
        "modeled_words": modeled_words,
        "lower_bound_words": lower_bound_words,
        "measured_bytes": measured_bytes if have_measured else None,
        "optimality_ratio": (
            measured_bytes / modeled_bytes
            if have_measured and modeled_bytes > 0 else None
        ),
    }
    return summary
