"""``python -m repro.verify`` — the static verification gate.

Runs the three analyzers (plan verifier, kernel static analyzer, repo
lint) and exits nonzero on any finding, so CI can gate on it::

    PYTHONPATH=src python -m repro.verify             # all analyzers
    PYTHONPATH=src python -m repro.verify --only lint  # subset
    PYTHONPATH=src python -m repro.verify --rules      # lint catalog
    PYTHONPATH=src python -m repro.verify --trace-out v.jsonl

``--trace-out`` records one ``kind="static_verify"`` span event per
kernel verdict plus one summary event, in the standard
``repro.observe.Span/1`` schema, so ``python -m repro.observe.report``
tables static verdicts next to measured bounds-audit rows.

Exit status: 0 = clean; 1 = at least one finding; 2 = bad usage.
"""

from __future__ import annotations

import argparse
import sys

from . import Finding

ANALYZERS = ("plans", "kernels", "lint")


def run(
    only: tuple[str, ...] = ANALYZERS,
    trace_out: str | None = None,
) -> tuple[list[Finding], list[dict]]:
    """Run the selected analyzers; returns (findings, kernel verdicts)
    and optionally exports the verdicts as a JSONL trace."""
    findings: list[Finding] = []
    verdicts: list[dict] = []
    if "plans" in only:
        from .plans import verify_plans

        findings += verify_plans()
    if "kernels" in only:
        from .kernels import verify_kernels

        kf, verdicts = verify_kernels()
        findings += kf
    if "lint" in only:
        from .lint import lint_tree

        findings += lint_tree()
    if trace_out is not None:
        from ..observe.trace import Trace, record_event

        with Trace(path=trace_out):
            for v in verdicts:
                record_event("static_verify", **v)
            record_event(
                "static_verify",
                name="summary",
                analyzers=list(only),
                findings=len(findings),
                kernels_checked=len(verdicts),
                kernels_agreeing=sum(1 for v in verdicts if v["agrees"]),
            )
    return findings, verdicts


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.verify", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument(
        "--only", default=None,
        help=f"comma-separated analyzers to run "
        f"(default: {','.join(ANALYZERS)})",
    )
    ap.add_argument(
        "--rules", action="store_true",
        help="print the lint rule catalog (markdown) and exit",
    )
    ap.add_argument(
        "--trace-out", default=None, metavar="FILE",
        help="write kernel verdicts as kind=static_verify JSONL span "
        "events (repro.observe schema)",
    )
    args = ap.parse_args(argv)

    if args.rules:
        from .lint import rule_catalog

        print(rule_catalog())
        return 0

    only = tuple(ANALYZERS)
    if args.only:
        only = tuple(a.strip() for a in args.only.split(",") if a.strip())
        bad = [a for a in only if a not in ANALYZERS]
        if bad:
            print(
                f"verify: unknown analyzer(s) {bad}; "
                f"choose from {ANALYZERS}", file=sys.stderr,
            )
            return 2

    findings, verdicts = run(only, trace_out=args.trace_out)
    for f in findings:
        print(f)
    for v in verdicts:
        mark = "ok" if v["agrees"] and not v["findings"] else "FAIL"
        print(
            f"kernel {v['name']}: grid={tuple(v['grid'])} "
            f"footprint={v['footprint_words']}w "
            f"claim={v['claimed_words']}w [{mark}]"
        )
    print(
        f"verify: {len(findings)} finding(s) across "
        f"{', '.join(only)}; {len(verdicts)} kernel(s) checked"
    )
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
