"""``python -m repro.verify`` — the static verification gate.

Runs the five analyzers (plan verifier, kernel static analyzer, repo
lint, communication verifier, dtype-flow analyzer) and exits nonzero on
any finding, so CI can gate on it::

    PYTHONPATH=src python -m repro.verify              # all analyzers
    PYTHONPATH=src python -m repro.verify --only lint  # subset
    PYTHONPATH=src python -m repro.verify --comm --dtypes  # selectors
    PYTHONPATH=src python -m repro.verify --rules      # lint catalog
    PYTHONPATH=src python -m repro.verify --trace-out v.jsonl

``--comm`` / ``--dtypes`` are shorthand selectors for the distributed
analyzers (equivalent to ``--only comm,dtypes``); they compose with
each other and with ``--only``.

``--trace-out`` records one ``kind="static_verify"`` span event per
verdict (kernel, per-grid comm point, dtype program) plus one summary
event, in the standard ``repro.observe.Span/1`` schema, so
``python -m repro.observe.report`` tables static verdicts — including
the per-grid modeled/bound/measured byte columns — next to measured
bounds-audit rows.

Exit status: 0 = clean; 1 = at least one finding; 2 = bad usage.
"""

from __future__ import annotations

import argparse
import sys

from . import Finding

ANALYZERS = ("plans", "kernels", "lint", "comm", "dtypes")


def run(
    only: tuple[str, ...] = ANALYZERS,
    trace_out: str | None = None,
) -> tuple[list[Finding], list[dict]]:
    """Run the selected analyzers; returns (findings, verdicts) and
    optionally exports the verdicts as a JSONL trace. Every verdict
    dict carries an ``"analyzer"`` key (``"kernels"`` / ``"comm"`` /
    ``"dtypes"``)."""
    findings: list[Finding] = []
    verdicts: list[dict] = []
    if "plans" in only:
        from .plans import verify_plans

        findings += verify_plans()
    if "kernels" in only:
        from .kernels import verify_kernels

        kf, kv = verify_kernels()
        findings += kf
        verdicts += [{"analyzer": "kernels", **v} for v in kv]
    if "lint" in only:
        from .lint import lint_tree

        findings += lint_tree()
    if "comm" in only:
        from .comm import verify_comm

        cf, cv = verify_comm()
        findings += cf
        verdicts += cv
    if "dtypes" in only:
        from .dtypes import verify_dtypes

        df, dv = verify_dtypes()
        findings += df
        verdicts += dv
    if trace_out is not None:
        from ..observe.trace import Trace, record_event

        kernel_vs = [v for v in verdicts if v["analyzer"] == "kernels"]
        with Trace(path=trace_out):
            for v in verdicts:
                record_event("static_verify", **v)
            record_event(
                "static_verify",
                name="summary",
                analyzers=list(only),
                findings=len(findings),
                kernels_checked=len(kernel_vs),
                kernels_agreeing=sum(
                    1 for v in kernel_vs if v["agrees"]
                ),
                comm_points=sum(
                    1 for v in verdicts if v["analyzer"] == "comm"
                ),
                dtype_programs=sum(
                    1 for v in verdicts if v["analyzer"] == "dtypes"
                ),
            )
    return findings, verdicts


def _print_verdict(v: dict) -> None:
    mark = "ok" if v["agrees"] and not v.get("findings") else "FAIL"
    if v["analyzer"] == "kernels":
        print(
            f"kernel {v['name']}: grid={tuple(v['grid'])} "
            f"footprint={v['footprint_words']}w "
            f"claim={v['claimed_words']}w [{mark}]"
        )
    elif v["analyzer"] == "comm":
        if "measured_collective_bytes" in v:
            print(
                f"comm {v['name']}: shape={tuple(v['shape'])} "
                f"grid={tuple(v['grid'])} "
                f"bytes={v['measured_collective_bytes']} "
                f"model={v['modeled_words']}w "
                f"lb={v['lower_bound_words']}w [{mark}]"
            )
        else:
            print(f"comm {v['name']}: [{mark}]")
    else:  # dtypes
        print(
            f"dtypes {v['name']}: "
            f"{v['accumulations']} accumulation(s), "
            f"{v['narrow_accumulations']} narrow [{mark}]"
        )


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.verify", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument(
        "--only", default=None,
        help=f"comma-separated analyzers to run "
        f"(default: {','.join(ANALYZERS)})",
    )
    ap.add_argument(
        "--comm", action="store_true",
        help="run the AOT communication verifier (selector shorthand)",
    )
    ap.add_argument(
        "--dtypes", action="store_true",
        help="run the dtype-flow analyzer (selector shorthand)",
    )
    ap.add_argument(
        "--rules", action="store_true",
        help="print the lint rule catalog (markdown) and exit",
    )
    ap.add_argument(
        "--trace-out", default=None, metavar="FILE",
        help="write verdicts as kind=static_verify JSONL span "
        "events (repro.observe schema)",
    )
    args = ap.parse_args(argv)

    if args.rules:
        from .lint import rule_catalog

        print(rule_catalog())
        return 0

    selected: list[str] = []
    if args.only:
        selected += [
            a.strip() for a in args.only.split(",") if a.strip()
        ]
    if args.comm and "comm" not in selected:
        selected.append("comm")
    if args.dtypes and "dtypes" not in selected:
        selected.append("dtypes")
    bad = [a for a in selected if a not in ANALYZERS]
    if bad:
        print(
            f"verify: unknown analyzer(s) {bad}; "
            f"choose from {ANALYZERS}", file=sys.stderr,
        )
        return 2
    only = tuple(selected) if selected else tuple(ANALYZERS)

    findings, verdicts = run(only, trace_out=args.trace_out)
    for f in findings:
        print(f)
    for v in verdicts:
        _print_verdict(v)
    by = {
        a: sum(1 for v in verdicts if v["analyzer"] == a)
        for a in ("kernels", "comm", "dtypes")
    }
    print(
        f"verify: {len(findings)} finding(s) across "
        f"{', '.join(only)}; {by['kernels']} kernel(s), "
        f"{by['comm']} comm point(s), {by['dtypes']} dtype program(s)"
    )
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
