"""Dtype-flow analyzer: prove the mixed-precision policy statically.

PR 6's ``compute_dtype`` policy promises: operands may stream in a
narrow type (bf16 — the bandwidth win), but *accumulation stays fp32 on
every backend* (``_cast_compute``'s contract). Until now that was a
numerics test (loose tolerances hide a bf16 accumulator on small
shapes); here it becomes a structural proof: trace each backend under
``compute_dtype=bfloat16`` with ``jax.make_jaxpr`` (nothing executes)
and walk every ``dot_general`` / ``reduce_sum`` equation in the jaxpr —
any contraction or reduction that consumes a narrow operand must
produce a wide (fp32/fp64) result, i.e. carry
``preferred_element_type=float32`` (einsum paths) or an fp32
``acc_dtype`` accumulator (the Pallas kernels).

This analyzer found a real bug on arrival: the ``blocked_host`` backend
passed bf16-cast operands to a plain einsum (no
``preferred_element_type``), accumulating in bf16 — fixed by threading
``f32_acc`` through ``core.blocked``.

The engine paths (einsum / blocked_host) are traced through
``repro.engine.execute`` so the policy *wiring* is verified, not just
the kernels; the Pallas backend is traced at the ``kernels.ops`` layer
(same kernels the engine dispatches to, minus the dispatch-counter side
effect) so the whole analyzer provably executes nothing.
"""

from __future__ import annotations

from typing import Any

from . import Finding

#: Narrow compute dtypes: accumulating in these loses mantissa on every
#: partial-sum step.
NARROW_DTYPES = frozenset({"bfloat16", "float16"})

#: Wide accumulator dtypes the policy requires.
WIDE_DTYPES = frozenset({"float32", "float64"})

#: Jaxpr primitives that accumulate: contractions and sum-reductions.
ACCUMULATING_PRIMS = ("dot_general", "reduce_sum")


def _walk(jaxpr: Any, hits: list[dict]) -> None:
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim in ACCUMULATING_PRIMS:
            ins = [
                str(v.aval.dtype) for v in eqn.invars
                if hasattr(v.aval, "dtype")
            ]
            outs = [
                str(v.aval.dtype) for v in eqn.outvars
                if hasattr(v.aval, "dtype")
            ]
            hits.append({"prim": prim, "in": ins, "out": outs})
        for val in eqn.params.values():
            for sub in (val if isinstance(val, (tuple, list)) else (val,)):
                if hasattr(sub, "jaxpr"):
                    _walk(sub.jaxpr, hits)
                elif hasattr(sub, "eqns"):
                    _walk(sub, hits)


def accumulation_sites(closed_jaxpr: Any) -> list[dict]:
    """Every dot_general/reduce_sum in the (closed) jaxpr, recursively,
    as ``{"prim", "in": [dtypes], "out": [dtypes]}`` records."""
    hits: list[dict] = []
    _walk(getattr(closed_jaxpr, "jaxpr", closed_jaxpr), hits)
    return hits


def check_accumulation(closed_jaxpr: Any, subject: str) -> \
        tuple[list[Finding], list[dict]]:
    """The rule: a narrow-input accumulation must have a wide output.

    Returns ``(findings, sites)`` — sites for the verdict's evidence.
    """
    sites = accumulation_sites(closed_jaxpr)
    findings: list[Finding] = []
    for s in sites:
        if any(d in NARROW_DTYPES for d in s["in"]) and any(
            d in NARROW_DTYPES for d in s["out"]
        ):
            findings.append(Finding(
                "dtypes", "narrow-accumulator", subject,
                f"{s['prim']} consumes {s['in']} and accumulates into "
                f"{s['out']}: the compute_dtype policy requires fp32 "
                f"accumulation (preferred_element_type / acc_dtype)",
            ))
    return findings, sites


def _sds(shape: tuple[int, ...], dtype: str):
    import jax
    import jax.numpy as jnp

    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def _trace_program(name: str, fn: Any, args: tuple) -> \
        tuple[list[Finding], dict]:
    import jax

    closed = jax.make_jaxpr(fn)(*args)
    findings, sites = check_accumulation(closed, name)
    verdict = {
        "analyzer": "dtypes", "name": name,
        "compute_dtype": "bfloat16",
        "accumulations": len(sites),
        "narrow_accumulations": len(findings),
        "agrees": not findings, "findings": len(findings),
    }
    return findings, verdict


def verify_dtypes() -> tuple[list[Finding], list[dict]]:
    """Trace MTTKRP and Multi-TTM under ``compute_dtype=bfloat16`` on
    every backend and prove fp32 accumulation throughout."""
    import jax.numpy as jnp

    from ..engine.context import ExecutionContext
    from ..engine.execute import mttkrp, multi_ttm
    from ..kernels import ops as kernel_ops
    from ..observe.metrics import PALLAS_DISPATCHES, registry

    dispatches_before = registry().counter(PALLAS_DISPATCHES)
    dims, rank, ranks = (8, 8, 8), 4, (4, 3, 2)
    x32 = _sds(dims, "float32")
    facs32 = tuple(_sds((d, rank), "float32") for d in dims)
    mats32 = tuple(_sds((d, r), "float32") for d, r in zip(dims, ranks))

    findings: list[Finding] = []
    verdicts: list[dict] = []
    for backend in ("einsum", "blocked_host"):
        ctx = ExecutionContext.create(
            backend=backend, compute_dtype="bfloat16"
        )
        f, v = _trace_program(
            f"mttkrp/{backend}",
            lambda x, fs, c=ctx: mttkrp(x, fs, 0, ctx=c),
            (x32, facs32),
        )
        findings += f
        verdicts.append(v)
        f, v = _trace_program(
            f"multi_ttm/{backend}",
            lambda x, ms, c=ctx: multi_ttm(x, ms, keep=0, ctx=c),
            (x32, mats32),
        )
        findings += f
        verdicts.append(v)

    # Pallas: trace the kernels the engine dispatches to, at the ops
    # layer (the _cast_compute wiring is already proven by the two
    # backends above; calling ops directly keeps the dispatch counter
    # untouched). Operands arrive pre-cast, exactly as the policy
    # delivers them; the kernels must still accumulate fp32.
    x16 = _sds(dims, "bfloat16")
    facs16 = tuple(_sds((d, rank), "bfloat16") for d in dims)
    mats16 = tuple(
        _sds((d, r), "bfloat16") for d, r in zip(dims[1:], ranks[1:])
    )
    f, v = _trace_program(
        "mttkrp/pallas",
        lambda x, fs: kernel_ops.mttkrp_pallas(
            x, fs, 0, interpret=True, out_dtype=jnp.float32
        ),
        (x16, facs16),
    )
    findings += f
    verdicts.append(v)
    f, v = _trace_program(
        "multi_ttm/pallas",
        lambda x, ms: kernel_ops.multi_ttm_canonical_pallas(
            x, ms, interpret=True, out_dtype=jnp.float32
        ),
        (x16, mats16),
    )
    findings += f
    verdicts.append(v)

    dispatches_after = registry().counter(PALLAS_DISPATCHES)
    if dispatches_after != dispatches_before:
        findings.append(Finding(
            "dtypes", "kernel-executed", "verify_dtypes",
            f"the engine's Pallas dispatch counter moved "
            f"({dispatches_before} -> {dispatches_after}) during static "
            f"analysis: something executed instead of tracing",
        ))
    return findings, verdicts
