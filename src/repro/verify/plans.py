"""Static plan verification: pure arithmetic over plan objects, no arrays.

Every check here is a statement the paper makes about a *plan* — not
about an execution — so it can be proven by evaluating the plan's own
methods against a :class:`~repro.engine.plan.Memory` descriptor:

* **Eq 9 (working set)** — ``plan.working_set_words() * itemsize`` must
  fit ``memory.budget_bytes``.  A plan is only *charged* as infeasible
  when a feasible plan exists at all (the all-ones plan fits); a memory
  too small for any plan is a property of the memory, not a planner bug.
* **Decomposition** — ``working_set_words == kernel_block_words +
  weight_scratch_words``: the split the static kernel analyzer
  (:mod:`repro.verify.kernels`) pins BlockSpec footprints against.
* **Padding/divisibility** — ``padded_shape`` must be the minimal
  block-multiple cover of the shape, and ``grid`` must tile it exactly.
* **Eq 10 vs Thm 4.1** — a feasible plan's modeled traffic
  (``eq10_words`` / ``model_words``) can never undercut the sequential
  memory-dependent lower bound (``seq_lb_memory`` /
  ``multi_ttm_seq_lb_memory``, clamped at 0).
* **Itemsize propagation** — ``Memory.with_itemsize`` re-describes the
  same physical bytes: ``budget_bytes`` invariant, ``budget_words``
  scaling as ``bytes // itemsize``.

:func:`verify_plans` sweeps a shape x rank x Memory x itemsize lattice
through ``choose_blocks`` / ``choose_sweep_blocks`` /
``choose_multi_ttm_blocks`` / ``best_uniform_block`` and applies the
checks to every emitted plan.
"""

from __future__ import annotations

import math
from typing import Sequence

from ..core.bounds import multi_ttm_seq_lb_memory, seq_lb_memory
from ..engine.plan import (
    BlockPlan,
    Memory,
    MultiTTMPlan,
    best_uniform_block,
    choose_blocks,
    choose_multi_ttm_blocks,
    choose_sweep_blocks,
    fused_pair_kernel_block_words,
    fused_pair_working_set_words,
    uniform_block_feasible,
)
from . import Finding

#: The default verification lattice: shapes cover 3-/4-way, degenerate
#: (sub-alignment) extents, and MXU-sized problems; memories cover real
#: VMEM at two itemsizes plus abstract word budgets from starved to ample.
DEFAULT_SHAPES: tuple[tuple[int, ...], ...] = (
    (24, 10, 12),
    (64, 64, 64),
    (128, 32, 8),
    (7, 5, 3),
    (200, 3, 130),
    (16, 8, 6, 4),
)
DEFAULT_RANKS: tuple[int, ...] = (1, 4, 16, 64)
DEFAULT_MEMORIES: tuple[Memory, ...] = (
    Memory.tpu_vmem(itemsize=4),
    Memory.tpu_vmem(itemsize=2),
    Memory.abstract(100),
    Memory.abstract(512),
    Memory.abstract(4096),
    Memory.abstract(2 ** 16),
)


def _subject(kind: str, plan: object, shape: Sequence[int], extra: str = "") -> str:
    return f"{kind}[shape={tuple(shape)}{extra}] {plan!r}"


def check_block_plan(
    plan: BlockPlan,
    shape: Sequence[int],
    rank: int,
    memory: Memory,
) -> list[Finding]:
    """All static checks for one :class:`BlockPlan` against one Memory."""
    out: list[Finding] = []
    sub = _subject("BlockPlan", plan, shape, f",rank={rank}")

    blocks = plan.blocks_per_mode()
    if plan.block_r < 1 or any(b < 1 for b in blocks):
        out.append(Finding(
            "plans", "nonpositive-block", sub,
            f"block sizes must be >= 1, got {blocks} / br={plan.block_r}",
        ))
        return out  # everything below divides by the blocks

    # Eq 9: only charge infeasibility when a feasible plan exists at all.
    if not plan.fits(memory):
        minimal = BlockPlan(
            1, (1,) * len(plan.block_contract), 1, plan.x_has_rank
        )
        if minimal.fits(memory):
            out.append(Finding(
                "plans", "eq9-infeasible", sub,
                f"working set {plan.working_set_words()} words exceeds "
                f"budget {memory.budget_words} words while the all-ones "
                f"plan fits (Eq 9 violated by choice, not by necessity)",
            ))

    # working-set decomposition (the kernel analyzer's pin).
    ws = plan.working_set_words()
    parts = plan.kernel_block_words() + plan.weight_scratch_words()
    if ws != parts:
        out.append(Finding(
            "plans", "ws-decomposition", sub,
            f"working_set_words()={ws} != kernel_block_words + "
            f"weight_scratch_words = {parts}",
        ))

    # padding: minimal block-multiple cover.
    padded = plan.padded_shape(shape)
    for d, (s, p, b) in enumerate(zip(shape, padded, blocks)):
        if p % b != 0 or p < s or p - s >= b:
            out.append(Finding(
                "plans", "padding", sub,
                f"mode {d}: padded extent {p} is not the minimal "
                f"multiple of block {b} covering {s}",
            ))

    # grid: exact tiling of the padded problem (plus the rank tile).
    grid = plan.grid(shape, rank)
    r_pad = math.ceil(rank / plan.block_r) * plan.block_r
    want = (r_pad // plan.block_r,) + tuple(
        p // b for p, b in zip(padded, blocks)
    )
    if grid != want or any(g < 1 for g in grid):
        out.append(Finding(
            "plans", "grid", sub,
            f"grid {grid} does not tile padded shape {padded} "
            f"(+rank {rank}->{r_pad}); expected {want}",
        ))

    # Eq 10 >= Thm 4.1 (only meaningful for plans that satisfy Eq 9).
    if plan.fits(memory):
        lb = max(seq_lb_memory(shape, rank, memory.budget_words), 0.0)
        eq10 = plan.eq10_words(shape, rank)
        if eq10 < lb:
            out.append(Finding(
                "plans", "eq10-below-bound", sub,
                f"modeled traffic {eq10} words undercuts the Thm-4.1 "
                f"sequential lower bound {lb:.0f} words at "
                f"M={memory.budget_words}",
            ))
    return out


def check_sweep_plan(
    plan: BlockPlan,
    shape: Sequence[int],
    rank: int,
    memory: Memory,
) -> list[Finding]:
    """Checks for a fused-pair sweep plan: everything a plain plan must
    satisfy, plus the *fused* working set (B^(0) and P tiles resident
    together) fitting the budget, with the same decomposition pin."""
    out = check_block_plan(plan, shape, rank, memory)
    sub = _subject("SweepPlan", plan, shape, f",rank={rank}")
    fused = fused_pair_working_set_words(plan)
    if fused * memory.itemsize > memory.budget_bytes:
        minimal = BlockPlan(1, (1,) * len(plan.block_contract), 1)
        if fused_pair_working_set_words(minimal) * memory.itemsize \
                <= memory.budget_bytes:
            out.append(Finding(
                "plans", "eq9-infeasible-fused", sub,
                f"fused working set {fused} words exceeds budget "
                f"{memory.budget_words} words while the all-ones plan fits",
            ))
    parts = fused_pair_kernel_block_words(plan) + plan.weight_scratch_words()
    if fused != parts:
        out.append(Finding(
            "plans", "ws-decomposition", sub,
            f"fused_pair_working_set_words={fused} != "
            f"fused_pair_kernel_block_words + weight_scratch_words = {parts}",
        ))
    return out


def check_multi_ttm_plan(
    plan: MultiTTMPlan,
    shape: Sequence[int],
    ranks: Sequence[int],
    memory: Memory,
) -> list[Finding]:
    """All static checks for one :class:`MultiTTMPlan` (the Eq-9/Eq-10
    analogs of arXiv:2207.10437) against one Memory."""
    out: list[Finding] = []
    sub = _subject("MultiTTMPlan", plan, shape, f",ranks={tuple(ranks)}")

    blocks = plan.blocks_per_mode()
    if any(b < 1 for b in blocks) or any(r < 1 for r in plan.ranks):
        out.append(Finding(
            "plans", "nonpositive-block", sub,
            f"block sizes/ranks must be >= 1, got {blocks} / {plan.ranks}",
        ))
        return out

    if not plan.fits(memory):
        minimal = MultiTTMPlan(
            1, (1,) * len(plan.block_contract), plan.ranks
        )
        if minimal.fits(memory):
            out.append(Finding(
                "plans", "eq9-infeasible", sub,
                f"working set {plan.working_set_words()} words exceeds "
                f"budget {memory.budget_words} words while the all-ones "
                f"plan fits",
            ))

    ws = plan.working_set_words()
    parts = plan.kernel_block_words() + plan.weight_scratch_words()
    if ws != parts:
        out.append(Finding(
            "plans", "ws-decomposition", sub,
            f"working_set_words()={ws} != kernel_block_words + "
            f"weight_scratch_words = {parts}",
        ))

    padded = plan.padded_shape(shape)
    for d, (s, p, b) in enumerate(zip(shape, padded, blocks)):
        if p % b != 0 or p < s or p - s >= b:
            out.append(Finding(
                "plans", "padding", sub,
                f"mode {d}: padded extent {p} is not the minimal "
                f"multiple of block {b} covering {s}",
            ))

    grid = plan.grid(shape)
    want = tuple(p // b for p, b in zip(padded, blocks))
    if grid != want or any(g < 1 for g in grid):
        out.append(Finding(
            "plans", "grid", sub,
            f"grid {grid} does not tile padded shape {padded}; "
            f"expected {want}",
        ))

    if plan.fits(memory):
        lb = max(
            multi_ttm_seq_lb_memory(shape, ranks, memory.budget_words), 0.0
        )
        model = plan.model_words(shape)
        if model < lb:
            out.append(Finding(
                "plans", "eq10-below-bound", sub,
                f"modeled traffic {model} words undercuts the Multi-TTM "
                f"sequential lower bound {lb:.0f} words at "
                f"M={memory.budget_words}",
            ))
    return out


def check_memory_itemsize(memory: Memory) -> list[Finding]:
    """Dtype-aware itemsize propagation: ``with_itemsize`` re-describes
    the same physical budget — bytes invariant, words = bytes // size."""
    out: list[Finding] = []
    for itemsize in (1, 2, 4, 8):
        m2 = memory.with_itemsize(itemsize)
        if m2.budget_bytes != memory.budget_bytes:
            out.append(Finding(
                "plans", "itemsize-propagation", repr(memory),
                f"with_itemsize({itemsize}) changed budget_bytes "
                f"{memory.budget_bytes} -> {m2.budget_bytes}",
            ))
        if m2.budget_words != memory.budget_bytes // itemsize:
            out.append(Finding(
                "plans", "itemsize-propagation", repr(memory),
                f"with_itemsize({itemsize}).budget_words = "
                f"{m2.budget_words}, expected "
                f"{memory.budget_bytes // itemsize}",
            ))
    return out


def check_batched_plans(
    shapes: Sequence[Sequence[int]] = DEFAULT_SHAPES,
    ranks: Sequence[int] = DEFAULT_RANKS,
    memories: Sequence[Memory] = DEFAULT_MEMORIES,
    batch_sizes: Sequence[int] = (1, 2, 4, 8),
    chooser=None,
) -> list[Finding]:
    """Rule ``batched-plan-divergence``: batching never changes the plan.

    The batched dispatch vmaps the element contraction, so the batch
    axis is a kernel grid dimension — no block spans two elements, and
    the per-instance Eq-9 working set is exactly the element working
    set.  Therefore for every ``B`` the batched planner
    (:func:`repro.engine.batch.batched_choose_blocks`, or an injected
    ``chooser(B, shape, rank, itemsize, memory=...)``) must return a
    plan EQUAL to the ``B``-independent element plan, with identical
    ``working_set_words``.  A chooser that scales blocks or working set
    with ``B`` is statically rejected here.
    """
    if chooser is None:
        from ..engine.batch import batched_choose_blocks  # lazy: layering

        chooser = batched_choose_blocks
    findings: list[Finding] = []
    for shape in shapes:
        shape = tuple(shape)
        for memory in memories:
            itemsize = memory.itemsize
            for rank in ranks:
                base = choose_blocks(shape, rank, itemsize, memory=memory)
                for b in batch_sizes:
                    plan = chooser(b, shape, rank, itemsize, memory=memory)
                    subject = _subject(
                        "batched", plan, shape, f"B={b},rank={rank}"
                    )
                    if plan != base:
                        findings.append(Finding(
                            "plans", "batched-plan-divergence", subject,
                            f"batched plan at B={b} diverged from the "
                            f"element plan: {plan.blocks_per_mode()} != "
                            f"{base.blocks_per_mode()} "
                            f"(batching is vmap over the "
                            f"element contraction; the block choice "
                            f"must be B-independent)",
                        ))
                        continue
                    if plan.working_set_words() != \
                            base.working_set_words():
                        findings.append(Finding(
                            "plans", "batched-plan-divergence", subject,
                            f"batched working set at B={b} is "
                            f"{plan.working_set_words()}w, expected the "
                            f"B-independent {base.working_set_words()}w",
                        ))
    return findings


def _tucker_ranks(shape: Sequence[int]) -> tuple[int, ...]:
    return tuple(min(4, max(1, s // 2)) for s in shape[1:])


def verify_plans(
    shapes: Sequence[Sequence[int]] = DEFAULT_SHAPES,
    ranks: Sequence[int] = DEFAULT_RANKS,
    memories: Sequence[Memory] = DEFAULT_MEMORIES,
) -> list[Finding]:
    """Sweep the planners over the lattice and statically check every
    emitted plan (pure arithmetic — no arrays are ever built)."""
    findings: list[Finding] = []
    for memory in memories:
        findings += check_memory_itemsize(memory)
    for shape in shapes:
        shape = tuple(shape)
        for memory in memories:
            itemsize = memory.itemsize
            for rank in ranks:
                plan = choose_blocks(
                    shape, rank, itemsize, memory=memory
                )
                findings += check_block_plan(plan, shape, rank, memory)
                aug = choose_blocks(
                    shape, rank, itemsize, memory=memory, x_has_rank=True
                )
                findings += check_block_plan(aug, shape, rank, memory)
                sweep = choose_sweep_blocks(
                    shape, rank, itemsize, memory=memory
                )
                findings += check_sweep_plan(sweep, shape, rank, memory)
                b = best_uniform_block(shape, memory)
                if b >= 1 and not uniform_block_feasible(
                    len(shape), b, memory
                ):
                    findings.append(Finding(
                        "plans", "uniform-infeasible",
                        f"uniform[shape={shape},rank={rank}] b={b}",
                        f"best_uniform_block returned b={b} but Eq 9 "
                        f"rejects it at M={memory.budget_words}",
                    ))
            tranks = _tucker_ranks(shape)
            tplan = choose_multi_ttm_blocks(
                shape, tranks, itemsize, memory=memory
            )
            findings += check_multi_ttm_plan(tplan, shape, tranks, memory)
    findings += check_batched_plans(shapes, ranks, memories)
    return findings
