"""Repo-specific AST lint: the bug classes this codebase has shipped.

Each rule encodes one *incident*, not a style preference:

* **RV101 falsy-or-default** — ``cache or default_cache()`` silently
  replaces an *empty* ``PlanCache``/``MetricsRegistry`` (they define
  ``__len__``, so emptiness is falsy) with a fresh default — the PR-6
  bug.  Spell it ``x if x is not None else default()``.
* **RV102 tracer-branch** — a Python ``if``/``while`` on a value that
  may be a jax tracer inside the ``engine/``/``kernels/`` hot paths
  raises ``TracerBoolConversionError`` under jit; predicates that are
  static (dtype inspection) are allowlisted.
* **RV103 jax-in-pure-math** — ``core/bounds.py``, ``engine/plan.py``
  and ``distributed/grid_select.py`` are the trace-free equation layer;
  importing ``jax`` there would let tracers leak into the paper's
  arithmetic (and break the mypy gate that types exactly these files).
* **RV104 mutable-default** — ``def f(x=[])`` / ``def f(x=make())``
  share one instance across calls (ruff's B006/B008, kept here so the
  fixture-backed regression test exists even without ruff installed).
* **RV105 wallclock** — ``time.*``/``datetime.now``/``random.*`` calls
  outside the measurement layers (``tune``, ``observe``, ``launch``,
  ``training``, ``checkpoint``, ``data``) make the numeric layers
  nondeterministic; span timing in ``engine/execute.py`` and
  ``engine/sweep.py`` is the one sanctioned exception.
* **RV106 dispatch-count-shim** — ``pallas_dispatch_count`` was removed
  (PR 7 deprecated it for one release); the counter lives in
  ``MetricsRegistry``.  Defining or importing the old name anywhere in
  ``src/`` reintroduces a dead API.
* **RV107 raw-collective** — ``lax.ppermute``/``all_gather``/``psum``/
  ``psum_scatter``/``all_to_all`` calls outside ``distributed/``: every
  collective must go through ``distributed/ring.py`` or the sweep
  builders, or the static communication verifier
  (``repro.verify.comm``) cannot account its bytes and the sweep models
  silently under-count.
* **RV108 axis-literal** — a hard-coded mesh-axis string (``"r"`` or
  ``"m<k>"``) inside ``distributed/`` instead of ``mesh.RANK_AXIS`` /
  ``mesh.mode_axis(k)``: a literal survives an axis rename and then
  shards on a nonexistent axis at trace time (``mesh.py`` itself is the
  constants' home and exempt).

A finding on a line carrying ``# verify: allow=<code>`` (or
``allow=all``) is waived — the waiver is part of the diff, so
exceptions are reviewable.
"""

from __future__ import annotations

import ast
import re
from dataclasses import dataclass
from pathlib import Path
from typing import Iterable, Sequence

from . import Finding


@dataclass(frozen=True)
class Rule:
    """One lint rule: stable code, short name, what it catches and why."""

    code: str
    name: str
    summary: str


RULES: tuple[Rule, ...] = (
    Rule(
        "RV101", "falsy-or-default",
        "`x or default()` on a cache/registry object: emptiness is falsy "
        "(they define __len__), so an empty instance is silently replaced "
        "by a fresh default (the PR-6 PlanCache bug). Use "
        "`x if x is not None else default()`.",
    ),
    Rule(
        "RV102", "tracer-branch",
        "Python `if`/`while` on a possibly-traced jnp/jax value inside "
        "engine/ or kernels/: raises TracerBoolConversionError under jit. "
        "Static predicates (dtype inspection) are allowlisted.",
    ),
    Rule(
        "RV103", "jax-in-pure-math",
        "jax/jnp import in the pure equation layer (core/bounds.py, "
        "engine/plan.py, distributed/grid_select.py): these modules must "
        "stay trace-free, array-free, and fully typed.",
    ),
    Rule(
        "RV104", "mutable-default",
        "Mutable or call-valued default argument (list/dict/set literal "
        "or constructor call): one shared instance across all calls.",
    ),
    Rule(
        "RV105", "wallclock",
        "time/datetime/random call outside the measurement layers: the "
        "numeric/planning layers must be deterministic. Span timing in "
        "engine/execute.py + engine/sweep.py is the sanctioned exception.",
    ),
    Rule(
        "RV106", "dispatch-count-shim",
        "pallas_dispatch_count was removed; the dispatch counter is "
        "repro.observe.metrics.registry().counter('engine."
        "pallas_dispatches'). Do not reintroduce the shim.",
    ),
    Rule(
        "RV107", "raw-collective",
        "Raw lax collective (ppermute/all_gather/psum/psum_scatter/"
        "all_to_all/pshuffle) outside distributed/: route it through "
        "distributed/ring.py or the sweep builders so the static "
        "communication verifier can account its bytes.",
    ),
    Rule(
        "RV108", "axis-literal",
        "Hard-coded mesh-axis string ('r' or 'm<k>') in distributed/ "
        "instead of mesh.RANK_AXIS / mesh.mode_axis(k): literals "
        "survive axis renames and fail at trace time. mesh.py (the "
        "constants' home) is exempt.",
    ),
)

#: RV101: left operand names that look like stateful containers.
_CONTAINERISH = ("cache", "registry", "buf", "trace")

#: RV102: jnp/jax attributes whose results are static Python values even
#: on traced operands (dtype/shape inspection, backend queries).
_STATIC_SAFE_ATTRS = frozenset({
    "dtype", "issubdtype", "result_type", "promote_types", "finfo",
    "iinfo", "isscalar", "ndim", "shape", "size", "itemsize",
    "canonicalize_dtype", "default_backend", "devices", "device_count",
})

#: RV102 scope: packages whose code runs under jit tracing.
_TRACED_DIRS = ("engine", "kernels")

#: RV103 scope: the pure equation layer (paths relative to src/repro).
PURE_MODULES = frozenset({
    "core/bounds.py", "engine/plan.py", "distributed/grid_select.py",
})

#: RV105: sanctioned nondeterminism — measurement/IO layers and the span
#: timing inside the dispatch layer.
_WALLCLOCK_DIRS = (
    "tune", "observe", "launch", "training", "checkpoint", "data",
    "benchmarks",
)
_WALLCLOCK_FILES = frozenset({"engine/execute.py", "engine/sweep.py"})
_WALLCLOCK_CALLS = frozenset({
    ("time", "time"), ("time", "perf_counter"), ("time", "monotonic"),
    ("time", "process_time"), ("time", "time_ns"),
    ("time", "perf_counter_ns"), ("datetime", "now"),
    ("datetime", "utcnow"), ("datetime", "today"),
    ("random", "random"), ("random", "randint"), ("random", "choice"),
    ("random", "shuffle"), ("random", "uniform"), ("random", "seed"),
})

#: RV107: collective primitives that must stay inside distributed/.
_COLLECTIVE_NAMES = frozenset({
    "ppermute", "all_gather", "psum", "pmean", "psum_scatter",
    "all_to_all", "pshuffle",
})
#: RV107 home: the one package allowed to spell collectives.
_COLLECTIVE_DIR = "distributed"

#: RV108: axis-name literal shapes, and the module housing the
#: constants (exempt — it *defines* them).
_AXIS_LITERAL_RE = re.compile(r"^(r|m\d+)$")
_AXIS_HOME = "distributed/mesh.py"


def rule_catalog() -> str:
    """The rule catalog as a markdown table (printed by ``--rules`` and
    into the CI job summary)."""
    lines = ["| code | name | what it catches |", "|------|------|-----|"]
    for r in RULES:
        lines.append(f"| {r.code} | {r.name} | {r.summary} |")
    return "\n".join(lines)


def _attr_chain(node: ast.AST) -> tuple[str, ...]:
    """`a.b.c` -> ("a", "b", "c"); empty when the root is not a Name."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return ()


def _name_of(node: ast.AST) -> str:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return ""


def _in_dirs(relpath: str, dirs: Sequence[str]) -> bool:
    top = relpath.split("/", 1)[0]
    return top in dirs


def _jnp_call_in(test: ast.AST) -> ast.Call | None:
    """First jnp/jax call in the subtree that is not a static-safe
    attribute access; None when the test is trace-safe."""
    for node in ast.walk(test):
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        if len(chain) >= 2 and chain[0] in ("jnp", "jax", "lax"):
            if chain[-1] not in _STATIC_SAFE_ATTRS:
                return node
    return None


def lint_source(src: str, relpath: str) -> list[Finding]:
    """Run every rule over one module's source.  ``relpath`` is the
    path relative to ``src/repro`` (posix separators) — several rules
    are scoped by layer."""
    try:
        tree = ast.parse(src, filename=relpath)
    except SyntaxError as e:
        return [Finding("lint", "syntax", relpath, f"unparsable: {e}")]
    lines = src.splitlines()
    findings: list[Finding] = []

    def waived(lineno: int, code: str) -> bool:
        if 1 <= lineno <= len(lines):
            text = lines[lineno - 1]
            if "verify: allow=" in text:
                allowed = text.split("verify: allow=", 1)[1].split()[0]
                return code in allowed.split(",") or allowed == "all"
        return False

    def emit(code: str, node: ast.AST, detail: str) -> None:
        lineno = getattr(node, "lineno", 0)
        if not waived(lineno, code):
            findings.append(Finding(
                "lint", code, f"{relpath}:{lineno}", detail,
            ))

    pure = relpath in PURE_MODULES
    traced = _in_dirs(relpath, _TRACED_DIRS)
    clock_ok = (
        _in_dirs(relpath, _WALLCLOCK_DIRS) or relpath in _WALLCLOCK_FILES
    )
    in_distributed = _in_dirs(relpath, (_COLLECTIVE_DIR,))
    axis_scoped = in_distributed and relpath != _AXIS_HOME

    for node in ast.walk(tree):
        # RV101 -------------------------------------------------------
        if isinstance(node, ast.BoolOp) and isinstance(node.op, ast.Or):
            left = node.values[0]
            lname = _name_of(left).lower()
            if any(c in lname for c in _CONTAINERISH) and any(
                isinstance(v, ast.Call) for v in node.values[1:]
            ):
                emit(
                    "RV101", node,
                    f"`{_name_of(left)} or <call>` treats an EMPTY "
                    f"{_name_of(left)} as absent (it defines __len__); "
                    f"use `{_name_of(left)} if {_name_of(left)} is not "
                    f"None else <call>`",
                )
        # RV102 -------------------------------------------------------
        if traced and isinstance(node, (ast.If, ast.While, ast.IfExp)):
            call = _jnp_call_in(node.test)
            if call is not None:
                chain = ".".join(_attr_chain(call.func))
                emit(
                    "RV102", node,
                    f"branching on `{chain}(...)`: under jit this value "
                    f"is a tracer and bool() raises; hoist the decision "
                    f"or use lax.cond",
                )
        # RV103 -------------------------------------------------------
        if pure and isinstance(node, (ast.Import, ast.ImportFrom)):
            mods = (
                [a.name for a in node.names]
                if isinstance(node, ast.Import)
                else [node.module or ""]
            )
            for m in mods:
                if m == "jax" or m.startswith("jax."):
                    emit(
                        "RV103", node,
                        f"`import {m}` in the pure equation layer; this "
                        f"module must stay trace-free",
                    )
        # RV104 -------------------------------------------------------
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            args = node.args
            for default in list(args.defaults) + [
                d for d in args.kw_defaults if d is not None
            ]:
                if isinstance(default, (ast.List, ast.Dict, ast.Set)):
                    emit(
                        "RV104", default,
                        f"mutable default argument in `{node.name}`: one "
                        f"instance is shared across every call",
                    )
                elif isinstance(default, ast.Call):
                    emit(
                        "RV104", default,
                        f"call-valued default argument in `{node.name}`: "
                        f"evaluated once at def time, shared across calls",
                    )
        # RV105 -------------------------------------------------------
        if not clock_ok and isinstance(node, ast.Call):
            chain = _attr_chain(node.func)
            if len(chain) >= 2 and (chain[-2], chain[-1]) in \
                    _WALLCLOCK_CALLS:
                emit(
                    "RV105", node,
                    f"`{'.'.join(chain)}()` outside the measurement "
                    f"layers: this layer must be deterministic",
                )
        # RV106 -------------------------------------------------------
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node.name == "pallas_dispatch_count":
            emit(
                "RV106", node,
                "pallas_dispatch_count was removed; use "
                "repro.observe.metrics.registry()",
            )
        if isinstance(node, ast.ImportFrom) and any(
            a.name == "pallas_dispatch_count" for a in node.names
        ):
            emit(
                "RV106", node,
                "importing the removed pallas_dispatch_count shim",
            )
        # RV107 -------------------------------------------------------
        if not in_distributed:
            if isinstance(node, ast.Call):
                chain = _attr_chain(node.func)
                if chain and chain[-1] in _COLLECTIVE_NAMES and (
                    "lax" in chain or chain[0] == "jax"
                ):
                    emit(
                        "RV107", node,
                        f"`{'.'.join(chain)}(...)` outside distributed/: "
                        f"collectives must go through distributed/ring.py "
                        f"or the sweep builders so repro.verify.comm can "
                        f"account their bytes",
                    )
            if isinstance(node, ast.ImportFrom) and \
                    (node.module or "").startswith("jax.lax"):
                for a in node.names:
                    if a.name in _COLLECTIVE_NAMES:
                        emit(
                            "RV107", node,
                            f"importing collective `{a.name}` from "
                            f"jax.lax outside distributed/",
                        )
        # RV108 -------------------------------------------------------
        if axis_scoped and isinstance(node, ast.Constant) and \
                isinstance(node.value, str) and \
                _AXIS_LITERAL_RE.match(node.value):
            emit(
                "RV108", node,
                f"hard-coded mesh-axis literal '{node.value}': use "
                f"mesh.RANK_AXIS / mesh.mode_axis(k) so axis renames "
                f"stay one-line changes",
            )
    return findings


def iter_module_paths(root: Path) -> Iterable[tuple[Path, str]]:
    """Yield ``(path, relpath)`` for every Python module under the
    package root (``src/repro``), relpath posix-style."""
    for path in sorted(root.rglob("*.py")):
        yield path, path.relative_to(root).as_posix()


def lint_tree(root: Path | None = None) -> list[Finding]:
    """Lint every module of the installed ``repro`` package (or an
    explicit package root)."""
    if root is None:
        root = Path(__file__).resolve().parent.parent
    findings: list[Finding] = []
    for path, relpath in iter_module_paths(Path(root)):
        findings += lint_source(path.read_text(), relpath)
    return findings
