"""Static Pallas kernel analysis: prove BlockSpec properties, run nothing.

The analyzer never executes a kernel body.  It monkeypatches
``pallas_call`` with a recorder that captures ``(grid, in_specs,
out_specs, out_shape, operand shapes)`` and returns zeros of the output
aval, then traces each shipped kernel wrapper under ``jax.eval_shape``
— so the capture costs one abstract trace, no FLOPs, no memory traffic.

Because every grid dimension and every BlockSpec index map in this repo
is *static* (plain Python over grid indices), the maps can be evaluated
concretely over the full grid.  That turns schedule claims into theorems
checked by enumeration:

* **coverage** — every output block is visited; no block origin is out
  of bounds (origins are in block units, Pallas "blocked indexing");
* **write-once** — an output block's visits form one contiguous run in
  grid iteration order (last dimension innermost), i.e. the
  output-stationary accumulation completes before the block is written
  back, and each block is written back exactly once;
* **footprint** — the summed VMEM words of all BlockSpec tiles equal the
  planner's :meth:`~repro.engine.plan.BlockPlan.kernel_block_words`
  claim (the BlockSpec share of the Eq-9 working set; the in-kernel
  weight scratch is ``weight_scratch_words``);
* **accumulator dtype** — the kernel output aval stays fp32 even when
  the inputs are bf16 (the mixed-precision policy's invariant).
"""

from __future__ import annotations

import itertools
import math
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator, Sequence

from . import Finding


@dataclass(frozen=True)
class SpecCapture:
    """One captured BlockSpec: static block shape + its index map."""

    block_shape: tuple[int, ...]
    index_map: Callable[..., tuple[int, ...]]
    operand_shape: tuple[int, ...]


@dataclass
class KernelCapture:
    """Everything one ``pallas_call`` declared, captured without running."""

    grid: tuple[int, ...]
    in_specs: tuple[SpecCapture, ...] = ()
    out_specs: tuple[SpecCapture, ...] = ()
    out_dtypes: tuple[Any, ...] = ()
    name: str = "pallas_call"
    extras: dict = field(default_factory=dict)

    @property
    def block_footprint_words(self) -> int:
        """Summed VMEM words of every operand + output tile — the
        BlockSpec share of the Eq-9 working set."""
        return sum(
            math.prod(s.block_shape)
            for s in self.in_specs + self.out_specs
        )


@contextmanager
def capture_pallas_calls() -> Iterator[list[KernelCapture]]:
    """Patch ``jax.experimental.pallas.pallas_call`` with a recorder that
    returns zeros of the declared output aval (trace under
    ``jax.eval_shape`` so nothing materializes).  Yields the capture
    list; restores the real ``pallas_call`` on exit."""
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    records: list[KernelCapture] = []
    real = pl.pallas_call

    def fake_pallas_call(
        kernel: Callable,
        *,
        grid: Sequence[int],
        in_specs: Sequence[Any],
        out_specs: Any,
        out_shape: Any,
        **kwargs: Any,
    ) -> Callable:
        outs = out_shape if isinstance(out_shape, (tuple, list)) \
            else (out_shape,)
        ospecs = out_specs if isinstance(out_specs, (tuple, list)) \
            else (out_specs,)

        def runner(*operands: Any) -> Any:
            records.append(KernelCapture(
                grid=tuple(int(g) for g in grid),
                in_specs=tuple(
                    SpecCapture(
                        tuple(int(b) for b in s.block_shape),
                        s.index_map,
                        tuple(int(d) for d in op.shape),
                    )
                    for s, op in zip(in_specs, operands)
                ),
                out_specs=tuple(
                    SpecCapture(
                        tuple(int(b) for b in s.block_shape),
                        s.index_map,
                        tuple(int(d) for d in o.shape),
                    )
                    for s, o in zip(ospecs, outs)
                ),
                out_dtypes=tuple(
                    jnp.dtype(o.dtype).name for o in outs
                ),
                name=getattr(kernel, "__name__", repr(kernel)),
            ))
            zeros = tuple(jnp.zeros(o.shape, o.dtype) for o in outs)
            return zeros if isinstance(out_shape, (tuple, list)) \
                else zeros[0]

        return runner

    pl.pallas_call = fake_pallas_call
    try:
        yield records
    finally:
        pl.pallas_call = real


def _iter_grid(grid: tuple[int, ...]) -> Iterator[tuple[int, ...]]:
    # Pallas iterates the grid row-major: last dimension innermost.
    return itertools.product(*(range(g) for g in grid))


def _check_spec(
    cap: KernelCapture,
    spec: SpecCapture,
    *,
    kernel: str,
    role: str,
    require_coverage: bool,
) -> list[Finding]:
    """Evaluate one spec's index map over the full grid: in-bounds block
    origins always; for outputs additionally coverage + contiguous
    visit runs (accumulate-then-write-once)."""
    out: list[Finding] = []
    sub = f"{kernel}:{role}"
    nblocks = []
    for d, (extent, b) in enumerate(zip(spec.operand_shape, spec.block_shape)):
        if b < 1 or extent % b != 0:
            out.append(Finding(
                "kernels", "block-divisibility", sub,
                f"dim {d}: block {b} does not tile the (padded) operand "
                f"extent {extent}",
            ))
            return out
        nblocks.append(extent // b)

    visits: dict[tuple[int, ...], list[int]] = {}
    for step, idx in enumerate(_iter_grid(cap.grid)):
        try:
            origin = tuple(int(v) for v in spec.index_map(*idx))
        except Exception as e:  # non-static or arity-broken index map
            out.append(Finding(
                "kernels", "index-map", sub,
                f"index map failed on grid index {idx}: {e!r}",
            ))
            return out
        if len(origin) != len(spec.block_shape):
            out.append(Finding(
                "kernels", "index-map", sub,
                f"index map returned {len(origin)} coords for a "
                f"{len(spec.block_shape)}-dim block at grid index {idx}",
            ))
            return out
        if any(not 0 <= o < n for o, n in zip(origin, nblocks)):
            out.append(Finding(
                "kernels", "oob-origin", sub,
                f"grid index {idx} maps to block origin {origin} outside "
                f"the {tuple(nblocks)} block grid",
            ))
            return out
        visits.setdefault(origin, []).append(step)

    if require_coverage:
        missing = [
            o for o in itertools.product(*(range(n) for n in nblocks))
            if o not in visits
        ]
        if missing:
            out.append(Finding(
                "kernels", "coverage-gap", sub,
                f"{len(missing)} of {math.prod(nblocks)} output blocks "
                f"never written (first missing: {missing[0]})",
            ))
        for origin, steps in visits.items():
            if steps[-1] - steps[0] != len(steps) - 1:
                out.append(Finding(
                    "kernels", "noncontiguous-revisit", sub,
                    f"output block {origin} is revisited at "
                    f"non-consecutive grid steps (first gap after step "
                    f"{steps[0]}): the accumulation run is torn, so the "
                    f"block is written back more than once",
                ))
                break
    return out


def check_capture(
    cap: KernelCapture,
    *,
    kernel: str,
    claimed_block_words: int | None = None,
    expect_acc_dtype: str = "float32",
) -> list[Finding]:
    """All static checks for one captured ``pallas_call``."""
    out: list[Finding] = []
    if any(g < 1 for g in cap.grid):
        out.append(Finding(
            "kernels", "grid", kernel, f"degenerate grid {cap.grid}",
        ))
        return out
    for i, spec in enumerate(cap.in_specs):
        out += _check_spec(
            cap, spec, kernel=kernel, role=f"in[{i}]",
            require_coverage=False,
        )
    for i, spec in enumerate(cap.out_specs):
        out += _check_spec(
            cap, spec, kernel=kernel, role=f"out[{i}]",
            require_coverage=True,
        )
    for i, dt in enumerate(cap.out_dtypes):
        if dt != expect_acc_dtype:
            out.append(Finding(
                "kernels", "acc-dtype", f"{kernel}:out[{i}]",
                f"accumulator dtype is {dt}, policy requires "
                f"{expect_acc_dtype}",
            ))
    if claimed_block_words is not None:
        got = cap.block_footprint_words
        if got != claimed_block_words:
            out.append(Finding(
                "kernels", "footprint-mismatch", kernel,
                f"BlockSpec footprint {got} words != planner claim "
                f"{claimed_block_words} words "
                f"(kernel_block_words)",
            ))
    return out


# ---------------------------------------------------------------------------
# The shipped-kernel catalog
# ---------------------------------------------------------------------------

def _capture_one(fn: Callable, *args: Any) -> KernelCapture:
    """Trace ``fn(*args)`` under ``jax.eval_shape`` with the recorder
    patched in; exactly one ``pallas_call`` must fire."""
    import jax

    with capture_pallas_calls() as records:
        jax.eval_shape(fn, *args)
    if len(records) != 1:
        raise AssertionError(
            f"expected exactly one pallas_call, captured {len(records)}"
        )
    return records[0]


def kernel_cases() -> list[dict]:
    """One entry per shipped Pallas kernel: a traceable wrapper call on a
    bf16 problem sized to give a multi-block grid, plus the planner's
    ``kernel_block_words`` claim the captured footprint must equal.

    The 3-way case routes through ``choose_blocks`` against a small
    *abstract* memory (the production path); the others pin explicit
    block sizes chosen so every grid dimension — including the innermost
    contraction sweeps — has more than one block, exercising the
    coverage and accumulation-run checks for real."""
    import jax
    import jax.numpy as jnp

    from ..engine.plan import (
        BlockPlan,
        Memory,
        MultiTTMPlan,
        choose_blocks,
        fused_pair_kernel_block_words,
    )
    from ..kernels import ops, sweep

    def sds(*shape: int) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(shape, jnp.bfloat16)

    cases: list[dict] = []

    shape, rank = (24, 10, 12), 7
    plan3 = choose_blocks(shape, rank, memory=Memory.abstract(768))
    cases.append({
        "name": "mttkrp3",
        "fn": lambda x, a, b: ops.mttkrp_canonical_pallas(
            x, [a, b], plan=plan3, interpret=True
        ),
        "args": (sds(*shape), sds(shape[1], rank), sds(shape[2], rank)),
        "claim": plan3.kernel_block_words(),
        "plan": plan3,
    })

    shape4, rank4 = (8, 4, 5, 6), 5
    plan4 = BlockPlan(4, (2, 5, 3), 2)
    cases.append({
        "name": "mttkrpn",
        "fn": lambda x, f1, f2, f3: ops.mttkrp_canonical_pallas(
            x, [f1, f2, f3], plan=plan4, interpret=True, variant="generic"
        ),
        "args": (
            sds(*shape4),
            sds(shape4[1], rank4), sds(shape4[2], rank4),
            sds(shape4[3], rank4),
        ),
        "claim": plan4.kernel_block_words(),
        "plan": plan4,
    })

    pshape, prank = (12, 4, 6), 5
    pplan = BlockPlan(6, (2, 3), 2, x_has_rank=True)
    cases.append({
        "name": "mttkrp_partial",
        "fn": lambda node, f1, f2: ops.mttkrp_partial_canonical_pallas(
            node, [f1, f2], plan=pplan, interpret=True
        ),
        "args": (
            sds(*pshape, prank),
            sds(pshape[1], prank), sds(pshape[2], prank),
        ),
        "claim": pplan.kernel_block_words(),
        "plan": pplan,
    })

    tshape, tranks = (16, 6, 10), (3, 2)
    tplan = MultiTTMPlan(8, (3, 5), tranks)
    cases.append({
        "name": "multi_ttm",
        "fn": lambda x, m1, m2: ops.multi_ttm_canonical_pallas(
            x, [m1, m2], plan=tplan, interpret=True
        ),
        "args": (
            sds(*tshape),
            sds(tshape[1], tranks[0]), sds(tshape[2], tranks[1]),
        ),
        "claim": tplan.kernel_block_words(),
        "plan": tplan,
    })

    sshape, srank = (12, 6, 8), 5
    splan = BlockPlan(4, (3, 4), 2)
    cases.append({
        "name": "fused_pair",
        "fn": lambda x, f1, f2: sweep.fused_pair_canonical_pallas(
            x, [f1, f2], plan=splan, interpret=True
        ),
        "args": (
            sds(*sshape), sds(sshape[1], srank), sds(sshape[2], srank),
        ),
        "claim": fused_pair_kernel_block_words(splan),
        "plan": splan,
    })
    return cases


def verify_kernels() -> tuple[list[Finding], list[dict]]:
    """Statically verify every shipped Pallas kernel.

    Returns ``(findings, verdicts)`` — one verdict dict per kernel with
    the captured grid, the BlockSpec footprint, the planner claim, and
    whether they agree; suitable for ``kind="static_verify"`` trace
    events.  No kernel is ever executed (the capture runs under
    ``jax.eval_shape`` with ``pallas_call`` replaced)."""
    findings: list[Finding] = []
    verdicts: list[dict] = []
    for case in kernel_cases():
        name = case["name"]
        try:
            cap = _capture_one(case["fn"], *case["args"])
        except Exception as e:
            findings.append(Finding(
                "kernels", "capture-failed", name,
                f"tracing the wrapper under eval_shape failed: {e!r}",
            ))
            continue
        fs = check_capture(
            cap, kernel=name, claimed_block_words=case["claim"],
        )
        findings += fs
        verdicts.append({
            "name": name,
            "grid": list(cap.grid),
            "footprint_words": cap.block_footprint_words,
            "claimed_words": case["claim"],
            "working_set_words": case["claim"]
            + case["plan"].weight_scratch_words(),
            "agrees": cap.block_footprint_words == case["claim"],
            "findings": len(fs),
        })
    return findings, verdicts
