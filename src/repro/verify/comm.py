"""AOT communication verifier: prove the distributed layer's collective
bytes, ring schedules, and grid choices without running anything.

The dynamic twin of this analyzer is ``tests/dist_worker.py``: spawn real
processes, compile the shard_map sweeps on a real mesh, and count
collective bytes in the HLO. That proof is strong but slow and lives
outside the fast lane. This module gets the same byte-exactness
statically: every distributed program is traced with ``jax.make_jaxpr``
on a device-free :class:`jax.sharding.AbstractMesh` (no compilation, no
devices, no processes) and its jaxpr is walked for collective primitives
by :func:`repro.distributed.hlo.jaxpr_collectives`. Per-shard avals in
the jaxpr carry the same "w = local words" sizes as SPMD HLO, so
``ring_bytes`` is directly comparable to the paper's §V-C3 models.

Three rule families:

* **Byte model** — for every lattice point (shape x rank x grid x
  overlap), the traced ring bytes of the CP sweep must equal
  ``stationary_sweep_words`` x itemsize (+ the fit scalar's all-reduce),
  the Tucker sweep must equal ``multi_ttm_sweep_words`` x itemsize, and
  single-mode ``mttkrp_stationary`` must equal Eq (12)
  (``par_stationary_cost``) x itemsize — *to the byte*, in both
  ``overlap="none"`` and ``overlap="ring"`` spellings. Each must also
  sit at or above the paper's parallel lower bounds (Thm 4.2/4.3,
  clamped at zero — the lattice shapes are small enough that the
  asymptotic bounds can go negative).
* **Ring schedule** — :mod:`repro.distributed.ring` exposes its schedule
  as pure integer functions (``ring_perm`` / ``arrival_source`` /
  ``reduce_chunk_index``); this analyzer simulates the actual
  ``ppermute`` dataflow for every ring size and proves: the permutation
  is a single q-cycle (deadlock-freedom), the runtime's provenance
  arithmetic matches the simulated arrivals (so the overlap consumers
  in ``cp_als_parallel`` slice the chunk that actually arrived), no
  chunk is read before its arrival step, every buffer slot is written
  exactly once, the arrivals union covers the gathered factor exactly,
  and the reduce-scatter ring deposits block ``j`` on processor ``j``
  with every contribution counted once.
* **Grid selection** — ``select_stationary_grid`` / ``select_tucker_grid``
  must return brute-force-optimal grids (same objective value) on the
  lattice, promoting the PR-3/PR-5 pin tests to a verifier rule.

Nothing here executes a kernel: the analyzer asserts the engine's
Pallas dispatch counter is untouched end to end.
"""

from __future__ import annotations

import math
from typing import Callable, Sequence

from . import Finding

#: The f32 lattice itemsize every byte check uses.
ITEMSIZE = 4

#: CP-sweep lattice: (dims, rank, grid). Grid axes are chosen so every
#: per-collective byte term is integral — per-op int() truncation then
#: equals the global model's, and equality is exact, not approximate.
CP_CASES: tuple[tuple[tuple[int, ...], int, tuple[int, ...]], ...] = (
    ((8, 8, 8), 4, (2, 2, 2)),
    ((8, 8, 8), 4, (1, 2, 2)),
    ((16, 8, 8), 4, (4, 2, 1)),
    ((8, 8, 8, 8), 4, (1, 2, 2, 2)),
    ((8, 8, 8, 8), 4, (2, 2, 1, 2)),
)

#: Tucker-sweep lattice: (dims, ranks, grid).
TUCKER_CASES: tuple[
    tuple[tuple[int, ...], tuple[int, ...], tuple[int, ...]], ...
] = (
    ((16, 16, 16), (4, 3, 2), (2, 2, 2)),
    ((16, 16, 16), (4, 3, 2), (1, 2, 4)),
    ((16, 16, 16), (4, 3, 2), (4, 2, 1)),
    ((8, 8, 8, 8), (2, 2, 2, 2), (2, 2, 1, 2)),
)

#: Single-mode Alg-3 lattice: (dims, rank, grid, mode).
MTTKRP_CASES: tuple[
    tuple[tuple[int, ...], int, tuple[int, ...], int], ...
] = (
    ((8, 8, 8), 4, (2, 2, 2), 0),
    ((8, 8, 8), 4, (2, 2, 2), 1),
    ((8, 8, 8), 4, (2, 2, 2), 2),
    ((16, 8, 8), 4, (4, 2, 1), 0),
)

OVERLAPS = ("none", "ring")

#: Ring sizes the schedule verifier proves (q=1 is the degenerate
#: no-communication ring; primes and composites both appear).
RING_SIZES = (1, 2, 3, 4, 5, 6, 7, 8)

#: Grid-selection cases pinned against brute force: (dims, rank, procs).
GRID_SELECT_CASES = (
    ((8, 8, 8), 4, 8),
    ((16, 8, 8), 4, 8),
    ((16, 16, 8), 4, 4),
)
TUCKER_SELECT_CASES = (
    ((16, 16, 16), (4, 3, 2), 8),
    ((8, 8, 8, 8), (2, 2, 2, 2), 8),
)


# --------------------------------------------------------------------------
# Byte models (pure arithmetic; must mirror the builders exactly)
# --------------------------------------------------------------------------

def cp_sweep_model_bytes(
    dims: Sequence[int], rank: int, grid: Sequence[int],
    itemsize: int = ITEMSIZE, compute_fit: bool = True,
) -> int:
    """Expected ring bytes of one ``build_cp_sweep`` program: the BHK
    sweep model (``stationary_sweep_words``) times itemsize, plus the fit
    scalar's all-reduce (one float over all P processors)."""
    from ..distributed.grid_select import stationary_sweep_words

    b = int(stationary_sweep_words(dims, rank, grid) * itemsize)
    if compute_fit:
        p = math.prod(grid)
        b += int(2 * (p - 1) / p * itemsize)
    return b


def tucker_sweep_model_bytes(
    dims: Sequence[int], ranks: Sequence[int], grid: Sequence[int],
    itemsize: int = ITEMSIZE,
) -> int:
    """Expected ring bytes of one ``build_tucker_sweep`` program."""
    from ..distributed.grid_select import multi_ttm_sweep_words

    return int(multi_ttm_sweep_words(dims, ranks, grid) * itemsize)


def mttkrp_model_bytes(
    dims: Sequence[int], rank: int, grid: Sequence[int], mode: int,
    itemsize: int = ITEMSIZE,
) -> int:
    """Expected ring bytes of one single-mode Alg-3 call: Eq (12)."""
    from ..core.bounds import par_stationary_cost

    return int(par_stationary_cost(dims, rank, grid, mode) * itemsize)


def parallel_lb_bytes(
    dims: Sequence[int], rank: int, procs: int, itemsize: int = ITEMSIZE,
) -> int:
    """Clamped Thm 4.2/4.3 lower bound in bytes: the larger of the
    general and stationary-variant bounds, floored at zero (on the small
    lattice shapes the asymptotic expressions can go negative — the
    paper's bounds are meaningful once memory terms dominate)."""
    from ..core.bounds import par_lb_general, par_lb_stationary

    lb = max(
        0.0,
        par_lb_general(dims, rank, procs),
        par_lb_stationary(dims, rank, procs),
    )
    return int(lb * itemsize)


# --------------------------------------------------------------------------
# Tracing (no devices, no compilation, no execution)
# --------------------------------------------------------------------------

def trace_collectives(fn: Callable, args: Sequence, grid_axes: dict):
    """``jax.make_jaxpr`` the program on abstract args and account its
    collectives. Returns a :class:`repro.distributed.hlo
    .CollectiveSummary`; the per-shard avals make ``ring_bytes`` the
    per-processor link traffic of the §V-C3 model."""
    import jax

    from ..distributed.hlo import jaxpr_collectives

    closed = jax.make_jaxpr(fn)(*args)
    return jaxpr_collectives(closed, grid_axes)


def check_program_bytes(
    subject: str,
    measured_bytes: int,
    model_bytes: int,
    lb_bytes: int,
) -> list[Finding]:
    """The two byte rules: traced == model (exactly) and traced >= the
    clamped parallel lower bound."""
    findings: list[Finding] = []
    if measured_bytes != model_bytes:
        findings.append(Finding(
            "comm", "byte-model-mismatch", subject,
            f"traced collective ring bytes {measured_bytes} != sweep-model "
            f"{model_bytes} (the program's collectives drifted from the "
            f"paper's cost model)",
        ))
    if measured_bytes < lb_bytes:
        findings.append(Finding(
            "comm", "below-lower-bound", subject,
            f"traced collective ring bytes {measured_bytes} < clamped "
            f"parallel lower bound {lb_bytes} (the byte accounting must "
            f"be wrong: no schedule beats Thm 4.2/4.3)",
        ))
    return findings


def _sds(shape: Sequence[int], dtype: str = "float32"):
    import jax
    import jax.numpy as jnp

    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def check_cp_sweep(
    dims: tuple[int, ...], rank: int, grid: tuple[int, ...], overlap: str,
) -> tuple[list[Finding], dict]:
    """Trace one CP sweep on an abstract mesh and run the byte rules."""
    from ..distributed.cp_als_parallel import build_cp_sweep
    from ..distributed.mesh import make_abstract_grid_mesh
    from ..engine.context import ExecutionContext

    ctx = ExecutionContext.create(
        backend="einsum", grid=grid, overlap=overlap
    )
    mesh = make_abstract_grid_mesh(grid)
    fn = build_cp_sweep(mesh, len(dims), ctx=ctx)
    # arguments are GLOBAL shapes: x, row-sharded factors, gathered
    # blocks (sharded by m{k} rows only), replicated Grams, the norm
    args = (
        _sds(dims),
        tuple(_sds((d, rank)) for d in dims),
        tuple(_sds((d, rank)) for d in dims),
        tuple(_sds((rank, rank)) for _ in dims),
        _sds(()),
    )
    summ = trace_collectives(fn, args, dict(mesh.shape))
    procs = math.prod(grid)
    model = cp_sweep_model_bytes(dims, rank, grid)
    lb = parallel_lb_bytes(dims, rank, procs)
    subject = f"cp_sweep dims={dims} rank={rank} grid={grid} " \
              f"overlap={overlap}"
    findings = check_program_bytes(subject, summ.ring_bytes, model, lb)
    if overlap == "ring":
        # the ring spelling must contain no monolithic gather/scatter
        mono = [k for k in summ.by_kind() if k in
                ("all-gather", "reduce-scatter")]
        if mono:
            findings.append(Finding(
                "comm", "ring-not-chunked", subject,
                f"overlap='ring' program still emits monolithic {mono} "
                f"(the ppermute spelling regressed)",
            ))
    verdict = {
        "analyzer": "comm", "name": f"cp_sweep/{overlap}",
        "shape": list(dims), "rank": rank, "grid": list(grid),
        "overlap": overlap, "procs": procs, "itemsize": ITEMSIZE,
        "modeled_words": model / ITEMSIZE,
        "lower_bound_words": lb / ITEMSIZE,
        "measured_collective_bytes": summ.ring_bytes,
        "collectives": {k: v["count"] for k, v in summ.by_kind().items()},
        "agrees": not findings, "findings": len(findings),
    }
    return findings, verdict


def check_tucker_sweep(
    dims: tuple[int, ...], ranks: tuple[int, ...], grid: tuple[int, ...],
    overlap: str,
) -> tuple[list[Finding], dict]:
    """Trace one Tucker/HOOI sweep on an abstract mesh; byte rules."""
    from ..distributed.mesh import make_abstract_grid_mesh
    from ..distributed.tucker_parallel import build_tucker_sweep
    from ..engine.context import ExecutionContext

    ctx = ExecutionContext.create(
        backend="einsum", grid=grid, overlap=overlap
    )
    mesh = make_abstract_grid_mesh(grid)
    fn = build_tucker_sweep(mesh, len(dims), ranks, ctx=ctx)
    args = (
        _sds(dims),
        tuple(_sds((d, r)) for d, r in zip(dims, ranks)),
        _sds(()),
    )
    summ = trace_collectives(fn, args, dict(mesh.shape))
    model = tucker_sweep_model_bytes(dims, ranks, grid)
    # no parallel Multi-TTM lower bound is implemented in core/bounds.py
    # (arXiv:2207.10437's parallel case); the clamped bound is 0 — the
    # byte-equality rule is the binding one here.
    lb = 0
    subject = f"tucker_sweep dims={dims} ranks={ranks} grid={grid} " \
              f"overlap={overlap}"
    findings = check_program_bytes(subject, summ.ring_bytes, model, lb)
    verdict = {
        "analyzer": "comm", "name": f"tucker_sweep/{overlap}",
        "shape": list(dims), "rank": list(ranks), "grid": list(grid),
        "overlap": overlap, "procs": math.prod(grid),
        "itemsize": ITEMSIZE,
        "modeled_words": model / ITEMSIZE,
        "lower_bound_words": lb / ITEMSIZE,
        "measured_collective_bytes": summ.ring_bytes,
        "collectives": {k: v["count"] for k, v in summ.by_kind().items()},
        "agrees": not findings, "findings": len(findings),
    }
    return findings, verdict


def check_mttkrp_stationary(
    dims: tuple[int, ...], rank: int, grid: tuple[int, ...], mode: int,
) -> tuple[list[Finding], dict]:
    """Trace one single-mode Alg-3 program; Eq (12) byte rules."""
    from ..distributed.mesh import make_abstract_grid_mesh
    from ..distributed.mttkrp_parallel import mttkrp_stationary
    from ..engine.context import ExecutionContext

    ctx = ExecutionContext.create(backend="einsum", grid=grid)
    mesh = make_abstract_grid_mesh(grid)
    fn = mttkrp_stationary(mesh, mode, len(dims), ctx=ctx)
    args = (_sds(dims),) + tuple(
        _sds((d, rank)) for k, d in enumerate(dims) if k != mode
    )
    summ = trace_collectives(fn, args, dict(mesh.shape))
    procs = math.prod(grid)
    model = mttkrp_model_bytes(dims, rank, grid, mode)
    lb = parallel_lb_bytes(dims, rank, procs)
    subject = f"mttkrp_stationary dims={dims} rank={rank} grid={grid} " \
              f"mode={mode}"
    findings = check_program_bytes(subject, summ.ring_bytes, model, lb)
    verdict = {
        "analyzer": "comm", "name": f"mttkrp_stationary/m{mode}",
        "shape": list(dims), "rank": rank, "grid": list(grid),
        "overlap": "none", "procs": procs, "itemsize": ITEMSIZE,
        "modeled_words": model / ITEMSIZE,
        "lower_bound_words": lb / ITEMSIZE,
        "measured_collective_bytes": summ.ring_bytes,
        "collectives": {k: v["count"] for k, v in summ.by_kind().items()},
        "agrees": not findings, "findings": len(findings),
    }
    return findings, verdict


# --------------------------------------------------------------------------
# Ring-schedule verifier (pure integer simulation; no jax at all)
# --------------------------------------------------------------------------

def check_ring_permutation(
    perm: Sequence[tuple[int, int]], q: int, subject: str,
) -> list[Finding]:
    """Deadlock-freedom: the ppermute pairs must form one q-cycle.

    A permutation that splits into multiple cycles (or maps two sources
    to one destination) would deadlock a rendezvous ring or silently
    drop a shard — the classic two-cycle bug this fixture class seeds.
    """
    findings: list[Finding] = []
    srcs = [s for s, _ in perm]
    dsts = [d for _, d in perm]
    if sorted(srcs) != list(range(q)) or sorted(dsts) != list(range(q)):
        findings.append(Finding(
            "comm", "ring-deadlock", subject,
            f"ppermute pairs are not a permutation of 0..{q - 1}: "
            f"srcs={sorted(srcs)} dsts={sorted(dsts)}",
        ))
        return findings
    nxt = dict(perm)
    seen = {0}
    node = 0
    for _ in range(q - 1):
        node = nxt[node]
        seen.add(node)
    if len(seen) != q:
        findings.append(Finding(
            "comm", "ring-deadlock", subject,
            f"permutation {list(perm)} splits into multiple cycles "
            f"(cycle through 0 visits only {len(seen)}/{q} shards): a "
            f"ring schedule built on it never sees every chunk",
        ))
    return findings


def simulate_ring_arrivals(
    q: int, perm: Sequence[tuple[int, int]] | None = None,
) -> list[list[int]]:
    """Origin labels under the actual ppermute dataflow:
    ``arrivals[t][me]`` is which processor's shard ``me`` holds after
    ``t`` ring steps (step 0 = its own)."""
    from ..distributed.ring import ring_perm

    perm = ring_perm(q) if perm is None else perm
    recv_from = {dst: src for src, dst in perm}
    hold = list(range(q))
    arrivals = [list(hold)]
    for _ in range(1, q):
        hold = [hold[recv_from[me]] for me in range(q)]
        arrivals.append(list(hold))
    return arrivals


def check_gather_schedule(q: int, subject: str) -> list[Finding]:
    """Prove the runtime's provenance arithmetic against the simulated
    dataflow, plus write-once and exact coverage of the gathered factor."""
    from ..distributed.ring import arrival_source

    findings: list[Finding] = []
    arrivals = simulate_ring_arrivals(q)
    for me in range(q):
        got = [arrivals[t][me] for t in range(q)]
        for t in range(q):
            want = arrival_source(me, t, q)
            if got[t] != want:
                findings.append(Finding(
                    "comm", "ring-schedule-mismatch", subject,
                    f"proc {me} step {t}: simulated arrival is from "
                    f"{got[t]} but arrival_source says {want} — the "
                    f"consumers would slice the wrong tensor chunk",
                ))
        if len(set(got)) != q:
            findings.append(Finding(
                "comm", "ring-coverage", subject,
                f"proc {me}: arrivals {got} do not cover every source "
                f"exactly once (the assembled factor has holes or "
                f"double-written slots)",
            ))
    return findings


def check_assembly(q: int, subject: str) -> list[Finding]:
    """Prove ``ring_assemble``'s reverse-stack + roll lands every
    arrival at its source's tiled position (write-once + coverage of
    the gathered buffer)."""
    from ..distributed.ring import arrival_source

    findings: list[Finding] = []
    for me in range(q):
        parts = [arrival_source(me, t, q) for t in range(q)]
        stacked = parts[::-1]
        shift = (me + 1) % q
        assembled = [stacked[(i - shift) % q] for i in range(q)]
        if assembled != list(range(q)):
            findings.append(Finding(
                "comm", "ring-assembly", subject,
                f"proc {me}: assembled block order {assembled} != tiled "
                f"order {list(range(q))} — ring_all_gather would not "
                f"match lax.all_gather(tiled=True)",
            ))
    return findings


def check_consumer_schedule(
    q: int,
    subject: str,
    source_fn: Callable[[int, int, int], int] | None = None,
) -> list[Finding]:
    """The overlap consumer's contract: at step ``t`` it contracts the
    chunk from ``source_fn(me, t, q)``. That chunk physically arrives at
    step ``(me - source) mod q``, so the consumer must never reference a
    source whose arrival step exceeds ``t`` (a read-before-arrival race
    on real async hardware), and over all steps must consume every
    source exactly once."""
    from ..distributed.ring import arrival_source

    source_fn = arrival_source if source_fn is None else source_fn
    findings: list[Finding] = []
    for me in range(q):
        consumed: list[int] = []
        for t in range(q):
            src = source_fn(me, t, q)
            arrival_step = (me - src) % q
            if arrival_step > t:
                findings.append(Finding(
                    "comm", "read-before-arrival", subject,
                    f"proc {me} step {t}: consumes chunk from source "
                    f"{src}, which only arrives at step {arrival_step}",
                ))
            consumed.append(src)
        if len(set(consumed)) != q:
            findings.append(Finding(
                "comm", "ring-coverage", subject,
                f"proc {me}: consumer touches sources {consumed} — not "
                f"every chunk of the gathered factor exactly once",
            ))
    return findings


def check_reduce_scatter_schedule(
    q: int,
    subject: str,
    chunk_fn: Callable[[int, int, int], int] | None = None,
) -> list[Finding]:
    """Simulate the reduce-scatter ring's contribution sets: after q-1
    forward hops, processor ``j`` must hold block ``j`` with every
    processor's contribution counted exactly once."""
    from ..distributed.ring import reduce_chunk_index

    chunk_fn = reduce_chunk_index if chunk_fn is None else chunk_fn
    findings: list[Finding] = []
    acc: list[set[tuple[int, int]]] = [
        {(me, chunk_fn(me, 0, q))} for me in range(q)
    ]
    for t in range(1, q):
        moved = [acc[(me - 1) % q] for me in range(q)]
        nxt: list[set[tuple[int, int]]] = []
        for me in range(q):
            contrib = (me, chunk_fn(me, t, q))
            if contrib in moved[me]:
                findings.append(Finding(
                    "comm", "ring-write-once", subject,
                    f"proc {me} step {t}: chunk {contrib[1]} folded in "
                    f"twice — the reduced block double-counts a term",
                ))
            nxt.append(moved[me] | {contrib})
        acc = nxt
    for j in range(q):
        want = {(p, j) for p in range(q)}
        if acc[j] != want:
            findings.append(Finding(
                "comm", "ring-reduction-coverage", subject,
                f"proc {j} ends with contributions {sorted(acc[j])} != "
                f"every processor's block-{j} chunk exactly once",
            ))
    return findings


def check_ring_schedules(q: int) -> list[Finding]:
    """All ring-schedule rules for one ring size."""
    from ..distributed.ring import ring_perm

    subject = f"ring q={q}"
    findings = check_ring_permutation(ring_perm(q), q, subject)
    findings += check_gather_schedule(q, subject)
    findings += check_assembly(q, subject)
    findings += check_consumer_schedule(q, subject)
    findings += check_reduce_scatter_schedule(q, subject)
    return findings


# --------------------------------------------------------------------------
# Grid selection vs brute force
# --------------------------------------------------------------------------

def check_grid_selection(
    dims: tuple[int, ...], rank: int, procs: int,
) -> list[Finding]:
    """The branch-and-bound CP grid must match exhaustive search."""
    from ..distributed.grid_select import (
        brute_force_stationary,
        select_stationary_grid,
    )

    subject = f"select_stationary_grid dims={dims} rank={rank} P={procs}"
    sel = select_stationary_grid(dims, rank, procs, mode=None)
    ref = brute_force_stationary(dims, rank, procs, mode=None)
    if (sel is None) != (ref is None):
        return [Finding(
            "comm", "grid-suboptimal", subject,
            f"feasibility disagrees: select={sel} brute={ref}",
        )]
    if sel is not None and ref is not None and not math.isclose(
        sel.words, ref.words, rel_tol=0.0, abs_tol=1e-9
    ):
        return [Finding(
            "comm", "grid-suboptimal", subject,
            f"selected grid {sel.grid} costs {sel.words} words but brute "
            f"force finds {ref.grid} at {ref.words}",
        )]
    return []


def check_tucker_grid_selection(
    dims: tuple[int, ...], ranks: tuple[int, ...], procs: int,
) -> list[Finding]:
    """The Tucker grid chooser must match exhaustive search."""
    from ..distributed.grid_select import (
        brute_force_tucker,
        select_tucker_grid,
    )

    subject = f"select_tucker_grid dims={dims} ranks={ranks} P={procs}"
    sel = select_tucker_grid(dims, ranks, procs)
    ref = brute_force_tucker(dims, ranks, procs)
    if (sel is None) != (ref is None):
        return [Finding(
            "comm", "grid-suboptimal", subject,
            f"feasibility disagrees: select={sel} brute={ref}",
        )]
    if sel is not None and ref is not None and not math.isclose(
        sel.words, ref.words, rel_tol=0.0, abs_tol=1e-9
    ):
        return [Finding(
            "comm", "grid-suboptimal", subject,
            f"selected grid {sel.grid} costs {sel.words} words but brute "
            f"force finds {ref.grid} at {ref.words}",
        )]
    return []


# --------------------------------------------------------------------------
# Driver
# --------------------------------------------------------------------------

def verify_comm(
    cp_cases: Sequence = CP_CASES,
    tucker_cases: Sequence = TUCKER_CASES,
    mttkrp_cases: Sequence = MTTKRP_CASES,
    ring_sizes: Sequence[int] = RING_SIZES,
) -> tuple[list[Finding], list[dict]]:
    """Run the full lattice. Returns ``(findings, verdicts)`` — one
    verdict dict per traced program (trace-schema-ready: the report CLI
    tables ``modeled_words`` / ``lower_bound_words`` /
    ``measured_collective_bytes`` per grid) plus one summary verdict
    each for the ring-schedule and grid-selection rule families."""
    from ..observe.metrics import PALLAS_DISPATCHES, registry

    dispatches_before = registry().counter(PALLAS_DISPATCHES)
    findings: list[Finding] = []
    verdicts: list[dict] = []
    for dims, rank, grid in cp_cases:
        for overlap in OVERLAPS:
            f, v = check_cp_sweep(dims, rank, grid, overlap)
            findings += f
            verdicts.append(v)
    for dims, ranks, grid in tucker_cases:
        for overlap in OVERLAPS:
            f, v = check_tucker_sweep(dims, ranks, grid, overlap)
            findings += f
            verdicts.append(v)
    for dims, rank, grid, mode in mttkrp_cases:
        f, v = check_mttkrp_stationary(dims, rank, grid, mode)
        findings += f
        verdicts.append(v)

    ring_findings: list[Finding] = []
    for q in ring_sizes:
        ring_findings += check_ring_schedules(q)
    findings += ring_findings
    verdicts.append({
        "analyzer": "comm", "name": "ring_schedule",
        "ring_sizes": list(ring_sizes),
        "agrees": not ring_findings, "findings": len(ring_findings),
    })

    grid_findings: list[Finding] = []
    for dims, rank, procs in GRID_SELECT_CASES:
        grid_findings += check_grid_selection(dims, rank, procs)
    for dims, ranks, procs in TUCKER_SELECT_CASES:
        grid_findings += check_tucker_grid_selection(dims, ranks, procs)
    findings += grid_findings
    verdicts.append({
        "analyzer": "comm", "name": "grid_selection",
        "cases": len(GRID_SELECT_CASES) + len(TUCKER_SELECT_CASES),
        "agrees": not grid_findings, "findings": len(grid_findings),
    })

    dispatches_after = registry().counter(PALLAS_DISPATCHES)
    if dispatches_after != dispatches_before:
        findings.append(Finding(
            "comm", "kernel-executed", "verify_comm",
            f"the engine's Pallas dispatch counter moved "
            f"({dispatches_before} -> {dispatches_after}) during static "
            f"analysis: something executed instead of tracing",
        ))
    return findings, verdicts
