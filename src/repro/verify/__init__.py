"""Static verification: prove the paper's invariants without running anything.

Five analyzers, one :class:`Finding` currency, one CLI
(``python -m repro.verify``):

* :mod:`repro.verify.plans` — pure arithmetic over
  :class:`~repro.engine.plan.BlockPlan` / ``MultiTTMPlan`` objects: Eq-9
  working-set feasibility, block-divisibility/padding consistency,
  dtype-aware itemsize propagation, and the Eq-10-vs-Thm-4.1 sandwich,
  swept over a shape x rank x Memory lattice so ``choose_blocks`` /
  ``choose_multi_ttm_blocks`` / ``choose_sweep_blocks`` are proven never
  to emit an infeasible plan.
* :mod:`repro.verify.kernels` — captures every Pallas kernel's grid +
  BlockSpecs by monkeypatching ``pallas_call`` under ``jax.eval_shape``
  (the kernel body never executes), then evaluates the index maps over
  the full grid to prove output coverage, in-bounds block origins,
  accumulation-run contiguity, fp32 accumulator dtype, and that the VMEM
  block footprint equals the planner's
  :meth:`~repro.engine.plan.BlockPlan.kernel_block_words` claim.
* :mod:`repro.verify.lint` — AST rules encoding repo-specific bug
  classes (the PR-6 falsy-``PlanCache`` bug, tracer-unsafe branching,
  jax imports in the pure-math modules, mutable defaults, wall-clock
  calls in deterministic layers, reintroduction of the removed
  ``pallas_dispatch_count`` shim, raw collectives outside
  ``distributed/``, hard-coded mesh-axis literals).
* :mod:`repro.verify.comm` — the AOT communication verifier: traces
  every distributed shard_map program on a device-free
  ``AbstractMesh`` over a shape x rank x grid lattice and proves the
  collective ring bytes equal the §V-C3 sweep models to the byte (and
  sit above the clamped Thm 4.2/4.3 parallel lower bounds), that the
  ``ppermute`` ring schedules are deadlock-free single cycles with
  exact-coverage, write-once, read-at-or-after-arrival chunk flow, and
  that grid selection matches brute force — zero processes, zero
  kernel executions.
* :mod:`repro.verify.dtypes` — the dtype-flow analyzer: walks each
  backend's jaxpr under ``compute_dtype=bfloat16`` and proves every
  ``dot_general``/``reduce_sum`` that consumes a narrow operand
  accumulates into fp32 (the PR-6 mixed-precision policy as a
  structural invariant).

This is the *static* half of the observability story: the dynamic half
(:mod:`repro.observe.bounds_audit`) measures compiled HLO; this package
proves what can be proven before compilation, and its verdicts ride the
same trace schema (``kind="static_verify"``) so the report CLI tables
them next to measured audit rows.
"""

from __future__ import annotations

from dataclasses import asdict, dataclass


@dataclass(frozen=True)
class Finding:
    """One static-analysis violation: which analyzer, which rule, where.

    ``analyzer`` is ``"plans"`` / ``"kernels"`` / ``"lint"`` /
    ``"comm"`` / ``"dtypes"``; ``rule`` is
    the stable rule code (e.g. ``"eq9-infeasible"``, ``"RV101"``,
    ``"byte-model-mismatch"``);
    ``subject`` names the object (a plan/kernel description or a
    ``file:line`` location); ``detail`` is the human-readable evidence.
    """

    analyzer: str
    rule: str
    subject: str
    detail: str

    def to_dict(self) -> dict:
        """Plain-dict form for JSONL trace events and test assertions."""
        return asdict(self)

    def __str__(self) -> str:
        return f"[{self.analyzer}:{self.rule}] {self.subject}: {self.detail}"


__all__ = ["Finding"]
