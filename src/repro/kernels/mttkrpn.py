"""Generic N-way Pallas MTTKRP kernel (N >= 3) — same schedule as mttkrp3.

The grid is (r, i, c_1, ..., c_{N-1}) with the contraction tiles innermost:
the output tile O(bi, br) stays VMEM-resident across the whole contraction
sweep (output-stationary, Algorithm 2's reuse), the tensor is streamed once
per r-tile, and the rank-structured weight block

    W[(c_1..c_{N-1}), r] = Π_k A_k(c_k, r)

is built in VMEM by chained broadcasts (the Khatri-Rao structure — never
materialized in HBM). See mttkrp3.py for the full TPU-adaptation rationale;
this module generalizes it to arbitrary order for 4-/5-way tensors.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    def _compiler_params(n_contract: int):
        sem = ("parallel", "parallel") + ("arbitrary",) * n_contract
        if hasattr(pltpu, "CompilerParams"):
            return pltpu.CompilerParams(dimension_semantics=sem)
        return pltpu.TPUCompilerParams(dimension_semantics=sem)  # pragma: no cover
except Exception:  # pragma: no cover
    def _compiler_params(n_contract: int):
        return None


def _kernel(*refs, n_contract: int, acc_dtype):
    x_ref = refs[0]
    f_refs = refs[1 : 1 + n_contract]
    o_ref = refs[1 + n_contract]

    first_contract_step = pl.program_id(2) == 0
    for d in range(1, n_contract):
        first_contract_step &= pl.program_id(2 + d) == 0

    @pl.when(first_contract_step)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    br = f_refs[0].shape[1]
    # chained outer product over the contraction tile dims
    w = f_refs[0][...].astype(acc_dtype)  # (b1, br)
    for f in f_refs[1:]:
        ft = f[...].astype(acc_dtype)  # (bd, br)
        w = (w[:, None, :] * ft[None, :, :]).reshape(-1, br)
    bi = x_ref.shape[0]
    xm = x_ref[...].reshape(bi, -1)
    o_ref[...] += jax.lax.dot_general(
        xm, w, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=acc_dtype,
    )


def _partial_kernel(*refs, n_contract: int, acc_dtype):
    """Rank-augmented partial contraction (dimension-tree internal node):

        O(i, r) += sum_c X(i, c_1..c_k, r) * prod_d A_d(c_d, r)

    Same output-stationary schedule as :func:`_kernel`, but the tensor tile
    carries the rank axis, so the weight block combines elementwise along r
    (a VPU reduce, not an MXU matmul)."""
    x_ref = refs[0]
    f_refs = refs[1 : 1 + n_contract]
    o_ref = refs[1 + n_contract]

    first_contract_step = pl.program_id(2) == 0
    for d in range(1, n_contract):
        first_contract_step &= pl.program_id(2 + d) == 0

    @pl.when(first_contract_step)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    br = f_refs[0].shape[1]
    w = f_refs[0][...].astype(acc_dtype)  # (b1, br)
    for f in f_refs[1:]:
        ft = f[...].astype(acc_dtype)  # (bd, br)
        w = (w[:, None, :] * ft[None, :, :]).reshape(-1, br)
    bi = x_ref.shape[0]
    xm = x_ref[...].astype(acc_dtype).reshape(bi, -1, br)
    o_ref[...] += jnp.sum(xm * w[None, :, :], axis=1)


def mttkrp_partial_pallas(
    x: jax.Array,
    factors: Sequence[jax.Array],
    *,
    block_i: int,
    block_contract: Sequence[int],
    block_r: int,
    interpret: bool = False,
    acc_dtype=jnp.float32,
) -> jax.Array:
    """Canonical rank-augmented partial MTTKRP: ``x`` is ``(I, C_1..C_k,
    R)`` (a dimension-tree node that already carries the rank axis),
    ``factors`` are the k dropped factors ``(C_d, R)``. Pre-padded inputs
    required; returns ``(I, R)`` in ``acc_dtype``."""
    nc = x.ndim - 2
    assert len(factors) == nc and len(block_contract) == nc
    i_sz = x.shape[0]
    r_sz = x.shape[-1]
    for d, f in enumerate(factors):
        assert f.shape == (x.shape[1 + d], r_sz)
        assert x.shape[1 + d] % block_contract[d] == 0
    assert i_sz % block_i == 0 and r_sz % block_r == 0

    grid = (
        r_sz // block_r,
        i_sz // block_i,
    ) + tuple(x.shape[1 + d] // block_contract[d] for d in range(nc))

    def x_map(r, i, *cs):
        return (i,) + cs + (r,)

    def f_map_for(d):
        def f_map(r, i, *cs):
            return (cs[d], r)
        return f_map

    def o_map(r, i, *cs):
        return (i, r)

    in_specs = [
        pl.BlockSpec(
            (block_i,) + tuple(block_contract) + (block_r,), x_map
        )
    ] + [
        pl.BlockSpec((block_contract[d], block_r), f_map_for(d))
        for d in range(nc)
    ]
    kernel = functools.partial(
        _partial_kernel, n_contract=nc, acc_dtype=acc_dtype
    )
    kwargs = {}
    cp = _compiler_params(nc)
    if cp is not None and not interpret:
        kwargs["compiler_params"] = cp
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_i, block_r), o_map),
        out_shape=jax.ShapeDtypeStruct((i_sz, r_sz), acc_dtype),
        interpret=interpret,
        **kwargs,
    )(x, *factors)


def mttkrpn_pallas(
    x: jax.Array,
    factors: Sequence[jax.Array],
    *,
    block_i: int,
    block_contract: Sequence[int],
    block_r: int,
    interpret: bool = False,
    acc_dtype=jnp.float32,
) -> jax.Array:
    """Canonical mode-0 N-way MTTKRP. ``factors`` are the N-1 non-output
    factors in tensor-axis order (axes 1..N-1). Pre-padded inputs required."""
    n = x.ndim
    nc = n - 1
    assert len(factors) == nc and len(block_contract) == nc
    i_sz = x.shape[0]
    r_sz = factors[0].shape[1]
    for d, f in enumerate(factors):
        assert f.shape == (x.shape[1 + d], r_sz)
        assert x.shape[1 + d] % block_contract[d] == 0
    assert i_sz % block_i == 0 and r_sz % block_r == 0

    grid = (
        r_sz // block_r,
        i_sz // block_i,
    ) + tuple(x.shape[1 + d] // block_contract[d] for d in range(nc))

    def x_map(r, i, *cs):
        return (i,) + cs

    def f_map_for(d):
        def f_map(r, i, *cs):
            return (cs[d], r)
        return f_map

    def o_map(r, i, *cs):
        return (i, r)

    in_specs = [
        pl.BlockSpec((block_i,) + tuple(block_contract), x_map)
    ] + [
        pl.BlockSpec((block_contract[d], block_r), f_map_for(d))
        for d in range(nc)
    ]
    kernel = functools.partial(_kernel, n_contract=nc, acc_dtype=acc_dtype)
    kwargs = {}
    cp = _compiler_params(nc)
    if cp is not None and not interpret:
        kwargs["compiler_params"] = cp
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_i, block_r), o_map),
        out_shape=jax.ShapeDtypeStruct((i_sz, r_sz), acc_dtype),
        interpret=interpret,
        **kwargs,
    )(x, *factors)
