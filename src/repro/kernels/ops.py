"""jit'd public wrappers for the Pallas MTTKRP kernels.

Handles: mode canonicalization (transpose output mode to axis 0), TPU-
alignment padding, VMEM-budget block-size selection (the Eq-9 analogue
``working_set(blocks) <= VMEM``), kernel dispatch (3-way specialized /
N-way generic), un-padding, and dtype policy (f32 accumulation).

``interpret=None`` auto-selects: real Mosaic lowering on TPU backends,
interpret mode elsewhere (this container validates on CPU).
"""

from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp

from .mttkrp3 import mttkrp3_pallas
from .mttkrpn import mttkrpn_pallas

LANE = 128
SUBLANE = 8
VMEM_BYTES = 16 * 2 ** 20  # v5e per-core VMEM
VMEM_BUDGET = VMEM_BYTES // 2  # leave headroom for double-buffering


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class BlockPlan:
    block_i: int
    block_contract: tuple[int, ...]
    block_r: int

    def working_set_words(self, itemsize: int = 4) -> int:
        """VMEM words held per grid step (Eq 9 analogue): X tile + factor
        tiles + KRP block + output tile."""
        prod_c = math.prod(self.block_contract)
        x_tile = self.block_i * prod_c
        f_tiles = sum(c * self.block_r for c in self.block_contract)
        krp = prod_c * self.block_r
        out = self.block_i * self.block_r
        return x_tile + f_tiles + krp + out


def choose_blocks(
    shape: Sequence[int],
    rank: int,
    itemsize: int = 4,
    vmem_budget: int = VMEM_BUDGET,
) -> BlockPlan:
    """Pick TPU-aligned block sizes fitting the VMEM budget.

    Strategy (mirrors the paper's b ≈ (αM)^{1/N} with TPU alignment): output
    mode and rank tiles start at MXU-friendly 128; the minor contraction dim
    at 128 (lane), other contraction dims at 8 (sublane); then shrink the
    largest contributor until the working set fits.
    """
    n = len(shape)
    bi = min(_round_up(shape[0], SUBLANE), 128)
    br = min(_round_up(rank, LANE), 512)
    bc = []
    for d in range(1, n):
        if d == n - 1:  # minor dim: lane-aligned
            bc.append(min(_round_up(shape[d], LANE), 128))
        else:
            bc.append(min(_round_up(shape[d], SUBLANE), 8))
    plan = BlockPlan(bi, tuple(bc), br)
    # shrink until it fits (keep alignment floors)
    while plan.working_set_words() * itemsize > vmem_budget:
        if plan.block_r > LANE:
            plan = BlockPlan(plan.block_i, plan.block_contract, plan.block_r // 2)
        elif plan.block_i > SUBLANE:
            plan = BlockPlan(plan.block_i // 2, plan.block_contract, plan.block_r)
        else:
            bc = list(plan.block_contract)
            grew = False
            for d in range(len(bc) - 1):  # shrink non-minor contraction dims
                if bc[d] > SUBLANE:
                    bc[d] //= 2
                    grew = True
                    break
            if not grew:
                if bc and bc[-1] > LANE:
                    bc[-1] //= 2
                else:
                    break  # minimal plan; accept
            plan = BlockPlan(plan.block_i, tuple(bc), plan.block_r)
    return plan


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def mttkrp_pallas(
    x: jax.Array,
    factors: Sequence[jax.Array],
    mode: int,
    *,
    interpret: bool | None = None,
    plan: BlockPlan | None = None,
    out_dtype=None,
) -> jax.Array:
    """MTTKRP for any mode via the Pallas blocked kernel.

    Drop-in for :func:`repro.core.mttkrp.mttkrp` (f32 accumulation). The
    tensor is transposed so ``mode`` is axis 0; inputs are zero-padded to
    block multiples (zero tensor padding contributes nothing; padded output
    rows are sliced away).
    """
    interpret = _auto_interpret() if interpret is None else interpret
    n = x.ndim
    if n < 3:
        raise ValueError("pallas kernel supports N >= 3 (use core.mttkrp)")
    perm = (mode,) + tuple(k for k in range(n) if k != mode)
    xp = jnp.transpose(x, perm)
    fs = [factors[k] for k in perm[1:]]
    rank = fs[0].shape[1]
    out_rows = x.shape[mode]

    if plan is None:
        plan = choose_blocks(xp.shape, rank, x.dtype.itemsize)
    # pad to block multiples
    tgt = [_round_up(xp.shape[0], plan.block_i)] + [
        _round_up(xp.shape[1 + d], plan.block_contract[d])
        for d in range(n - 1)
    ]
    r_pad = _round_up(rank, plan.block_r)
    xp = jnp.pad(xp, [(0, t - s) for t, s in zip(tgt, xp.shape)])
    fs = [
        jnp.pad(f, ((0, tgt[1 + d] - f.shape[0]), (0, r_pad - rank)))
        for d, f in enumerate(fs)
    ]
    if n == 3:
        out = mttkrp3_pallas(
            xp, fs[0], fs[1],
            block_i=plan.block_i,
            block_j=plan.block_contract[0],
            block_k=plan.block_contract[1],
            block_r=plan.block_r,
            interpret=interpret,
        )
    else:
        out = mttkrpn_pallas(
            xp, fs,
            block_i=plan.block_i,
            block_contract=plan.block_contract,
            block_r=plan.block_r,
            interpret=interpret,
        )
    out = out[:out_rows, :rank]
    return out.astype(out_dtype or x.dtype)


def mttkrp_traffic_model(
    shape: Sequence[int], rank: int, plan: BlockPlan, itemsize: int = 4
) -> dict:
    """Modeled HBM<->VMEM traffic of the kernel (bytes), mirroring the
    BlockSpec fetch rules: a block is re-fetched when its mapped index
    changes between consecutive grid steps.

    Grid (3-way): (i, r, j, k), k innermost. X fetched every step; factor k
    every step; factor j once per k-sweep; O written once per (i, r).
    """
    n = len(shape)
    padded = [_round_up(shape[0], plan.block_i)] + [
        _round_up(shape[1 + d], plan.block_contract[d]) for d in range(n - 1)
    ]
    r_pad = _round_up(rank, plan.block_r)
    gi = padded[0] // plan.block_i
    gr = r_pad // plan.block_r
    gc = [padded[1 + d] // plan.block_contract[d] for d in range(n - 1)]
    steps = gi * gr * math.prod(gc)
    x_bytes = steps * plan.block_i * math.prod(plan.block_contract) * itemsize
    f_bytes = 0
    # factor d re-fetched when (c_d, r) changes; c_d sweeps with all inner
    # dims constant-free: fetches = gi*gr*prod(gc[:d+1])
    run = gi * gr
    for d in range(n - 1):
        run *= gc[d]
        f_bytes += run * plan.block_contract[d] * plan.block_r * itemsize
    o_bytes = gi * gr * plan.block_i * plan.block_r * itemsize
    total = x_bytes + f_bytes + o_bytes
    # the paper's ideal (Eq 10-style, words -> bytes)
    i_total = math.prod(shape)
    ideal = (i_total + math.prod(
        math.ceil(shape[d] / ([plan.block_i] + list(plan.block_contract))[d])
        for d in range(n)
    ) * rank * (n + 1) * max([plan.block_i] + list(plan.block_contract))) * itemsize
    return {
        "x_bytes": x_bytes,
        "factor_bytes": f_bytes,
        "out_bytes": o_bytes,
        "total_bytes": total,
        "eq10_bytes": ideal,
        "steps": steps,
        "working_set_bytes": plan.working_set_words() * itemsize,
    }


@functools.partial(jax.jit, static_argnames=("mode", "interpret"))
def mttkrp_pallas_jit(x, factors, mode: int, interpret: bool | None = None):
    return mttkrp_pallas(x, tuple(factors), mode, interpret=interpret)
