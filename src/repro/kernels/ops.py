"""jit'd public wrappers for the Pallas MTTKRP kernels.

Handles: mode canonicalization (transpose output mode to axis 0), TPU-
alignment padding, kernel dispatch (3-way specialized / N-way generic /
rank-augmented partial), un-padding, and dtype policy (f32 accumulation).

Block planning and the traffic models live in :mod:`repro.engine.plan` —
the single source of truth — and are re-exported here for back-compat
(``from repro.kernels.ops import choose_blocks`` keeps working).

``interpret=None`` auto-selects: real Mosaic lowering on TPU backends,
interpret mode elsewhere (this container validates on CPU).
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from ..engine.plan import (  # noqa: F401  (re-exported planner API)
    LANE,
    SUBLANE,
    VMEM_BUDGET,
    VMEM_BYTES,
    BlockPlan,
    MultiTTMPlan,
    choose_blocks,
    choose_multi_ttm_blocks,
    mttkrp_traffic_model,
)
from .mttkrp3 import mttkrp3_pallas
from .mttkrpn import mttkrp_partial_pallas, mttkrpn_pallas
from .multi_ttm import multi_ttm_keep_pallas


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def mttkrp_canonical_pallas(
    xp: jax.Array,
    fs: Sequence[jax.Array],
    *,
    plan: BlockPlan | None = None,
    interpret: bool | None = None,
    out_dtype=None,
    variant: str | None = None,
) -> jax.Array:
    """Mode-0-canonical MTTKRP through the blocked kernels.

    ``xp`` is the (already transposed) tensor with the output mode at axis
    0; ``fs`` are the N-1 factors for axes 1..N-1 in order. Pads to the
    plan's block multiples (zero tensor padding contributes nothing; padded
    output rows/columns are sliced away), dispatches the 3-way specialized
    or N-way generic kernel, and un-pads.

    ``variant`` pins the kernel for 3-way tensors: ``"specialized"`` (the
    default, :func:`mttkrp3_pallas`) or ``"generic"`` (the N-way kernel) —
    the autotuner measures both. N > 3 always uses the generic kernel.
    """
    if variant not in (None, "specialized", "generic"):
        raise ValueError(f"unknown kernel variant {variant!r}")
    interpret = _auto_interpret() if interpret is None else interpret
    n = xp.ndim
    rank = fs[0].shape[1]
    out_rows = xp.shape[0]
    if plan is None:
        plan = choose_blocks(xp.shape, rank, xp.dtype.itemsize)
    tgt = plan.padded_shape(xp.shape)
    r_pad = _round_up(rank, plan.block_r)
    xp = jnp.pad(xp, [(0, t - s) for t, s in zip(tgt, xp.shape)])
    fs = [
        jnp.pad(f, ((0, tgt[1 + d] - f.shape[0]), (0, r_pad - rank)))
        for d, f in enumerate(fs)
    ]
    if n == 3 and variant != "generic":
        out = mttkrp3_pallas(
            xp, fs[0], fs[1],
            block_i=plan.block_i,
            block_j=plan.block_contract[0],
            block_k=plan.block_contract[1],
            block_r=plan.block_r,
            interpret=interpret,
        )
    else:
        out = mttkrpn_pallas(
            xp, fs,
            block_i=plan.block_i,
            block_contract=plan.block_contract,
            block_r=plan.block_r,
            interpret=interpret,
        )
    out = out[:out_rows, :rank]
    return out.astype(out_dtype) if out_dtype is not None else out


def mttkrp_pallas(
    x: jax.Array,
    factors: Sequence[jax.Array],
    mode: int,
    *,
    interpret: bool | None = None,
    plan: BlockPlan | None = None,
    out_dtype=None,
    variant: str | None = None,
) -> jax.Array:
    """MTTKRP for any mode via the Pallas blocked kernel.

    Drop-in for :func:`repro.core.mttkrp.mttkrp` (f32 accumulation). The
    tensor is transposed so ``mode`` is axis 0, then dispatched through
    :func:`mttkrp_canonical_pallas`.
    """
    n = x.ndim
    if n < 3:
        raise ValueError("pallas kernel supports N >= 3 (use core.mttkrp)")
    perm = (mode,) + tuple(k for k in range(n) if k != mode)
    xp = jnp.transpose(x, perm)
    fs = [factors[k] for k in perm[1:]]
    return mttkrp_canonical_pallas(
        xp, fs, plan=plan, interpret=interpret,
        out_dtype=out_dtype or x.dtype, variant=variant,
    )


def mttkrp_partial_canonical_pallas(
    node: jax.Array,
    fs: Sequence[jax.Array],
    *,
    plan: BlockPlan | None = None,
    interpret: bool | None = None,
    out_dtype=None,
) -> jax.Array:
    """Rank-augmented partial contraction (dimension-tree internal node).

    ``node`` is ``(I, C_1..C_k, R)`` — kept modes flattened into axis 0,
    dropped modes next, rank last; ``fs`` are the k dropped factors
    ``(C_d, R)``. Pads, runs :func:`mttkrp_partial_pallas`, un-pads.
    """
    interpret = _auto_interpret() if interpret is None else interpret
    rank = node.shape[-1]
    out_rows = node.shape[0]
    if plan is None:
        plan = choose_blocks(
            node.shape[:-1], rank, node.dtype.itemsize, x_has_rank=True
        )
    tgt = plan.padded_shape(node.shape[:-1])
    r_pad = _round_up(rank, plan.block_r)
    node = jnp.pad(
        node,
        [(0, t - s) for t, s in zip(tgt, node.shape[:-1])]
        + [(0, r_pad - rank)],
    )
    fs = [
        jnp.pad(f, ((0, tgt[1 + d] - f.shape[0]), (0, r_pad - rank)))
        for d, f in enumerate(fs)
    ]
    out = mttkrp_partial_pallas(
        node, fs,
        block_i=plan.block_i,
        block_contract=plan.block_contract,
        block_r=plan.block_r,
        interpret=interpret,
    )
    out = out[:out_rows, :rank]
    return out.astype(out_dtype) if out_dtype is not None else out


def multi_ttm_canonical_pallas(
    xp: jax.Array,
    mats: Sequence[jax.Array],
    *,
    plan: MultiTTMPlan | None = None,
    interpret: bool | None = None,
    out_dtype=None,
) -> jax.Array:
    """Kept-mode-first Multi-TTM through the blocked Kronecker kernel.

    ``xp`` is the (already transposed) tensor with the kept mode at axis
    0; ``mats`` are the k contracted-mode matrices ``(C_d, R_d)`` for
    axes 1..k in order. Pads the tensor modes to the plan's block
    multiples (zero padding contributes nothing; padded output rows are
    sliced away — the R_d are never padded), runs
    :func:`repro.kernels.multi_ttm.multi_ttm_keep_pallas`, and un-pads.
    Returns the flattened ``(I, prod R_d)`` result.
    """
    interpret = _auto_interpret() if interpret is None else interpret
    ranks = tuple(m.shape[1] for m in mats)
    out_rows = xp.shape[0]
    if plan is None:
        plan = choose_multi_ttm_blocks(xp.shape, ranks, xp.dtype.itemsize)
    tgt = plan.padded_shape(xp.shape)
    xp = jnp.pad(xp, [(0, t - s) for t, s in zip(tgt, xp.shape)])
    mats = [
        jnp.pad(m, ((0, tgt[1 + d] - m.shape[0]), (0, 0)))
        for d, m in enumerate(mats)
    ]
    out = multi_ttm_keep_pallas(
        xp, mats,
        block_i=plan.block_i,
        block_contract=plan.block_contract,
        interpret=interpret,
    )
    out = out[:out_rows]
    return out.astype(out_dtype) if out_dtype is not None else out


@functools.partial(jax.jit, static_argnames=("mode", "interpret"))
def mttkrp_pallas_jit(x, factors, mode: int, interpret: bool | None = None):
    return mttkrp_pallas(x, tuple(factors), mode, interpret=interpret)
