"""Pure-jnp oracles for the Pallas MTTKRP kernels."""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

_L = "abcdefghijklmnop"


def mttkrp_ref(
    x: jax.Array, factors: Sequence[jax.Array], mode: int
) -> jax.Array:
    """Reference MTTKRP: single einsum in f32 accumulation.

    ``factors`` has N entries; ``factors[mode]`` is ignored. Output is f32
    (the kernels accumulate in f32 regardless of input dtype).
    """
    n = x.ndim
    ins = [f.astype(jnp.float32) for k, f in enumerate(factors) if k != mode]
    spec = (
        _L[:n]
        + ","
        + ",".join(f"{_L[k]}z" for k in range(n) if k != mode)
        + f"->{_L[mode]}z"
    )
    return jnp.einsum(spec, x.astype(jnp.float32), *ins, optimize="optimal")


def mttkrp3_ref(
    x: jax.Array, a: jax.Array, b: jax.Array
) -> jax.Array:
    """Canonical mode-0 3-way oracle: O(i,r) = sum_jk X(i,j,k) A(j,r) B(k,r)."""
    return mttkrp_ref(x, [None, a, b], 0)
