"""Pallas kernel: fused intra-chunk SSD contraction (Mamba2).

§Perf Cell B (EXPERIMENTS.md) showed the einsum-SSD is memory-bound because
XLA materializes the (B, nc, q, q, H) decay-weighted score chain in HBM for
every layer × microbatch. This kernel is the scoped fix — the same blocking
discipline as the mttkrp3 kernel (Algorithm 2's "form the structured factor
in fast memory, never in HBM"):

    Y_intra[c, i, h, :] = Σ_{j<=i}  (C_c[i]·B_c[j]) · exp(cum[i,h]-cum[j,h])
                                   · Δ_c[j,h] · X_c[j, h, :]

Per grid cell (one (batch·chunk) × one head-block) everything — the (q, q)
Gram matrix, the causal decay mask, the Δ weighting — is built in VMEM and
consumed immediately by MXU matmuls; HBM traffic is exactly the operand
tiles + the output tile (vs ~3 extra (q,q,H)-sized round-trips for the
einsum path — a ~2.5× cut of the dominant T_mem term at mamba2's shapes).

Forward only (inference prefill / building block for a custom-VJP train
path); validated against the pure-jnp oracle in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_intra_kernel(cc_ref, bc_ref, cum_ref, dt_ref, x_ref, o_ref,
                      *, acc_dtype):
    """One (batch-chunk, head-block) cell.

    cc_ref/bc_ref: (q, N)       chunk C / B (group-shared across heads)
    cum_ref/dt_ref: (q, Hb)     per-head cumulative log-decay / Δ
    x_ref: (q, Hb, P)           Δ-unweighted inputs
    o_ref: (q, Hb, P)           intra-chunk outputs
    """
    q = cc_ref.shape[0]
    hb = cum_ref.shape[1]
    cc = cc_ref[...].astype(acc_dtype)
    bc = bc_ref[...].astype(acc_dtype)
    # (q, q) Gram matrix on the MXU — stays in VMEM
    g = jax.lax.dot_general(
        cc, bc, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=acc_dtype,
    )
    rows = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    causal = rows >= cols
    cum = cum_ref[...].astype(acc_dtype)  # (q, Hb)
    dt = dt_ref[...].astype(acc_dtype)
    for h in range(hb):  # head loop: Hb small (<= 8), unrolled
        seg = cum[:, h][:, None] - cum[None, :, h]  # (q, q)
        w = jnp.where(causal, g * jnp.exp(seg), 0.0) * dt[None, :, h]
        xh = x_ref[:, h, :].astype(acc_dtype)  # (q, P)
        yh = jax.lax.dot_general(
            w, xh, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=acc_dtype,
        )
        o_ref[:, h, :] = yh.astype(o_ref.dtype)


def ssd_intra_pallas(
    cc: jax.Array,    # (BC, q, N)   BC = batch * n_chunks
    bc: jax.Array,    # (BC, q, N)
    cum: jax.Array,   # (BC, q, H)
    dt: jax.Array,    # (BC, q, H)
    x: jax.Array,     # (BC, q, H, P)
    *,
    head_block: int = 8,
    interpret: bool = False,
    acc_dtype=jnp.float32,
) -> jax.Array:
    """Fused intra-chunk SSD. Returns (BC, q, H, P) in x.dtype."""
    bcn, q, n = cc.shape
    h, p = x.shape[2], x.shape[3]
    assert cum.shape == (bcn, q, h) and dt.shape == (bcn, q, h)
    hb = min(head_block, h)
    assert h % hb == 0
    grid = (bcn, h // hb)
    kernel = functools.partial(_ssd_intra_kernel, acc_dtype=acc_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, q, n), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((None, q, n), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((None, q, hb), lambda b, j: (b, 0, j)),
            pl.BlockSpec((None, q, hb), lambda b, j: (b, 0, j)),
            pl.BlockSpec((None, q, hb, p), lambda b, j: (b, 0, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, q, hb, p), lambda b, j: (b, 0, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bcn, q, h, p), x.dtype),
        interpret=interpret,
    )(cc, bc, cum, dt, x)


def ssd_intra_ref(cc, bc, cum, dt, x) -> jax.Array:
    """Pure-jnp oracle (the einsum path from models/ssm.py, f32)."""
    g = jnp.einsum("bin,bjn->bij", cc.astype(jnp.float32),
                   bc.astype(jnp.float32))
    seg = cum.astype(jnp.float32)[:, :, None, :] - cum.astype(
        jnp.float32
    )[:, None, :, :]
    q = cc.shape[1]
    causal = jnp.tril(jnp.ones((q, q), bool))
    w = jnp.where(causal[None, :, :, None], g[..., None] * jnp.exp(seg), 0.0)
    w = w * dt.astype(jnp.float32)[:, None, :, :]
    return jnp.einsum(
        "bijh,bjhp->bihp", w, x.astype(jnp.float32)
    ).astype(x.dtype)


def traffic_model(bcn: int, q: int, n: int, h: int, p: int,
                  itemsize: int = 2) -> dict:
    """HBM bytes: kernel (operands+output once) vs einsum path (which also
    round-trips g (q,q), decay (q,q,H) and w (q,q,H) through HBM)."""
    operands = bcn * (2 * q * n + 2 * q * h + q * h * p) * itemsize
    out = bcn * q * h * p * itemsize
    kernel = operands + out
    einsum_extra = bcn * (q * q + 3 * q * q * h) * 4  # f32 chain
    return {
        "kernel_bytes": kernel,
        "einsum_bytes": kernel + einsum_extra,
        "ratio": (kernel + einsum_extra) / kernel,
    }
