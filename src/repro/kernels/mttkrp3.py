"""Pallas TPU kernel: blocked 3-way MTTKRP — the TPU-native Algorithm 2.

Paper mapping (§V-B → TPU)
--------------------------
Algorithm 2 streams b×b×b tensor blocks through fast memory while holding
the corresponding factor subvectors, giving traffic I + Π⌈I_k/b⌉·R(N+1)b.
On TPU, fast memory is VMEM and the compute unit is the 128×128 MXU, so we
adapt (DESIGN.md §3):

* the tensor block is a (bi, bj, bk) VMEM tile (HBM→VMEM via BlockSpec);
* the N-ary multiplies are *restructured* (atomicity broken, as §V-C3
  licenses) into an MXU contraction: the Khatri-Rao block
  W[(j,k), r] = A(j,r)·B(k,r) is formed **in VMEM** from bj·br + bk·br
  words — never materialized in HBM (this is precisely the paper's "the KRP
  has few parameters" insight) — and the tile update is one matmul
      O(bi×br) += X(bi × bj·bk) @ W(bj·bk × br);
* the output tile O(bi, br) is *output-stationary*: the grid iterates the
  contraction dims (j, k) innermost so O accumulates in VMEM across the
  whole (j, k) sweep and is written back once per (i, r) tile — Algorithm
  2's reuse of the B^{(n)} subvector.

Traffic per (i,r,j,k) grid step: X tile (once per (j,k) per (i,r)... the
i-grid re-reads X for every r-tile, matching the R-loop of Algorithm 2) +
factor tiles; totals match seq_blocked_cost with b_n=bi, R-tiling, i.e.
   bytes ≈ I·(R/br) + Π(I_k/b_k)·(bj·br + bk·br + bi·br)
— the kernel's analytic model in ops.mttkrp3_traffic_model.

Mode handling: the wrapper canonicalizes to mode 0 by transposing the
tensor (one HBM pass, fused by XLA where possible).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU compiler params are only importable with a TPU-capable jaxlib
    from jax.experimental.pallas import tpu as pltpu

    if hasattr(pltpu, "CompilerParams"):
        _COMPILER_PARAMS = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary", "arbitrary")
        )
    else:  # pragma: no cover - older naming
        _COMPILER_PARAMS = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary", "arbitrary")
        )
except Exception:  # pragma: no cover
    _COMPILER_PARAMS = None


def _mttkrp3_kernel(x_ref, a_ref, b_ref, o_ref, *, acc_dtype):
    """One grid step: O[i-tile, r-tile] += X[i,j,k] @ KRP(A[j], B[k]).

    Refs (all VMEM tiles):
      x_ref: (bi, bj, bk)   tensor block
      a_ref: (bj, br)       mode-1 factor tile
      b_ref: (bk, br)       mode-2 factor tile
      o_ref: (bi, br)       output tile, accumulated across the (j,k) grid
    """
    j = pl.program_id(2)
    k = pl.program_id(3)

    @pl.when((j == 0) & (k == 0))
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    bi, bj, bk = x_ref.shape
    br = a_ref.shape[1]
    # Form the Khatri-Rao block in VMEM: W[(j,k), r] = A(j,r) * B(k,r).
    w = (
        a_ref[...].astype(acc_dtype)[:, None, :]
        * b_ref[...].astype(acc_dtype)[None, :, :]
    ).reshape(bj * bk, br)
    # Matricize the tensor tile and hit the MXU.
    xm = x_ref[...].reshape(bi, bj * bk)
    o_ref[...] += jax.lax.dot_general(
        xm,
        w,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=acc_dtype,
    )


def mttkrp3_pallas(
    x: jax.Array,
    a: jax.Array,
    b: jax.Array,
    *,
    block_i: int = 128,
    block_j: int = 8,
    block_k: int = 128,
    block_r: int = 128,
    interpret: bool = False,
    acc_dtype=jnp.float32,
) -> jax.Array:
    """Canonical mode-0 3-way MTTKRP: O(i,r) = Σ_jk X(i,j,k)A(j,r)B(k,r).

    Inputs must be pre-padded to multiples of the block sizes (the ops.py
    wrapper does this). Output is ``acc_dtype`` of shape (I, R).
    """
    i_sz, j_sz, k_sz = x.shape
    r_sz = a.shape[1]
    assert a.shape == (j_sz, r_sz) and b.shape == (k_sz, r_sz)
    assert i_sz % block_i == 0 and j_sz % block_j == 0
    assert k_sz % block_k == 0 and r_sz % block_r == 0

    grid = (
        i_sz // block_i,
        r_sz // block_r,
        j_sz // block_j,
        k_sz // block_k,
    )
    kernel = functools.partial(_mttkrp3_kernel, acc_dtype=acc_dtype)
    kwargs = {}
    if _COMPILER_PARAMS is not None and not interpret:
        kwargs["compiler_params"] = _COMPILER_PARAMS
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (block_i, block_j, block_k), lambda i, r, j, k: (i, j, k)
            ),
            pl.BlockSpec((block_j, block_r), lambda i, r, j, k: (j, r)),
            pl.BlockSpec((block_k, block_r), lambda i, r, j, k: (k, r)),
        ],
        out_specs=pl.BlockSpec((block_i, block_r), lambda i, r, j, k: (i, r)),
        out_shape=jax.ShapeDtypeStruct((i_sz, r_sz), acc_dtype),
        interpret=interpret,
        **kwargs,
    )(x, a, b)
