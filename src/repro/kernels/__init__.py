"""Pallas TPU kernels for the paper's compute hot-spots.

``mttkrp3``/``mttkrpn`` — the blocked MTTKRP (Algorithm 2 adapted to VMEM +
MXU); ``multi_ttm`` — the blocked Kronecker-weight Multi-TTM (the
Tucker/HOSVD kernel, arXiv:2207.10437); ``ssd_intra`` — the fused
intra-chunk SSD contraction (same blocking discipline, §Perf Cell B). ``ops`` wraps with mode canonicalization,
padding, and VMEM-budget block planning; ``ref`` holds the jnp oracles.
All validated in interpret mode on CPU; compiled via Mosaic on TPU.
"""

from .ops import (
    BlockPlan,
    MultiTTMPlan,
    choose_blocks,
    choose_multi_ttm_blocks,
    mttkrp_canonical_pallas,
    mttkrp_pallas,
    mttkrp_partial_canonical_pallas,
    multi_ttm_canonical_pallas,
)
from .ref import mttkrp_ref
from .ssd_intra import ssd_intra_pallas, ssd_intra_ref

__all__ = [
    "BlockPlan",
    "MultiTTMPlan",
    "choose_blocks",
    "choose_multi_ttm_blocks",
    "mttkrp_canonical_pallas",
    "mttkrp_pallas",
    "mttkrp_partial_canonical_pallas",
    "multi_ttm_canonical_pallas",
    "mttkrp_ref",
    "ssd_intra_pallas",
    "ssd_intra_ref",
]
