"""Blocked Pallas Multi-TTM kernel (the Tucker/HOSVD workhorse).

Computes the canonical kept-mode-first Multi-TTM

    O(i, r_1..r_k) = sum_c X(i, c_1..c_k) * prod_d A_d(c_d, r_d)

with the same output-stationary schedule as the MTTKRP kernels
(:mod:`repro.kernels.mttkrpn`): grid (i, c_1..c_k) with the contraction
tiles innermost, the output tile O(bi, prod R_d) VMEM-resident across the
whole contraction sweep, the tensor streamed once, and the *Kronecker*
weight block

    W[(c_1..c_k), (r_1..r_k)] = prod_d A_d(c_d, r_d)

built in VMEM by chained outer products — the rank-structured analog of
the MTTKRP kernels' Khatri-Rao weight (separate small rank axes here,
one shared rank axis there), never materialized in HBM.  The Tucker
ranks are kept whole per tile (they are the small dimensions of the
problem); only the tensor modes are blocked, planned by
:class:`repro.engine.plan.MultiTTMPlan`.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    def _compiler_params(n_contract: int):
        sem = ("parallel",) + ("arbitrary",) * n_contract
        if hasattr(pltpu, "CompilerParams"):
            return pltpu.CompilerParams(dimension_semantics=sem)
        return pltpu.TPUCompilerParams(dimension_semantics=sem)  # pragma: no cover
except Exception:  # pragma: no cover
    def _compiler_params(n_contract: int):
        return None


def _kernel(*refs, n_contract: int, acc_dtype):
    x_ref = refs[0]
    m_refs = refs[1 : 1 + n_contract]
    o_ref = refs[1 + n_contract]

    first_contract_step = pl.program_id(1) == 0
    for d in range(1, n_contract):
        first_contract_step &= pl.program_id(1 + d) == 0

    @pl.when(first_contract_step)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    # chained Kronecker product over the contraction tiles:
    # w rows follow the C-order flattening of (c_1..c_k), columns the
    # C-order flattening of (r_1..r_k) — both match the x/out reshapes
    w = m_refs[0][...].astype(acc_dtype)  # (b1, R1)
    for f in m_refs[1:]:
        ft = f[...].astype(acc_dtype)  # (bd, Rd)
        pc, pr = w.shape
        w = (w[:, None, :, None] * ft[None, :, None, :]).reshape(
            pc * ft.shape[0], pr * ft.shape[1]
        )
    bi = x_ref.shape[0]
    xm = x_ref[...].reshape(bi, -1)
    o_ref[...] += jax.lax.dot_general(
        xm, w, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=acc_dtype,
    )


def multi_ttm_keep_pallas(
    x: jax.Array,
    matrices: Sequence[jax.Array],
    *,
    block_i: int,
    block_contract: Sequence[int],
    interpret: bool = False,
    acc_dtype=jnp.float32,
) -> jax.Array:
    """Canonical kept-mode-first Multi-TTM: ``x`` is ``(I, C_1..C_k)``,
    ``matrices`` are the k contracted-mode matrices ``(C_d, R_d)``.
    Pre-padded tensor-mode extents required (the R_d are never padded);
    returns the flattened ``(I, prod R_d)`` in ``acc_dtype``."""
    nc = x.ndim - 1
    assert len(matrices) == nc and len(block_contract) == nc
    i_sz = x.shape[0]
    ranks = tuple(m.shape[1] for m in matrices)
    for d, m in enumerate(matrices):
        assert m.shape[0] == x.shape[1 + d]
        assert x.shape[1 + d] % block_contract[d] == 0
    assert i_sz % block_i == 0
    prod_r = 1
    for r in ranks:
        prod_r *= r

    grid = (i_sz // block_i,) + tuple(
        x.shape[1 + d] // block_contract[d] for d in range(nc)
    )

    def x_map(i, *cs):
        return (i,) + cs

    def m_map_for(d):
        def m_map(i, *cs):
            return (cs[d], 0)
        return m_map

    def o_map(i, *cs):
        return (i, 0)

    in_specs = [
        pl.BlockSpec((block_i,) + tuple(block_contract), x_map)
    ] + [
        pl.BlockSpec((block_contract[d], ranks[d]), m_map_for(d))
        for d in range(nc)
    ]
    kernel = functools.partial(_kernel, n_contract=nc, acc_dtype=acc_dtype)
    kwargs = {}
    cp = _compiler_params(nc)
    if cp is not None and not interpret:
        kwargs["compiler_params"] = cp
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_i, prod_r), o_map),
        out_shape=jax.ShapeDtypeStruct((i_sz, prod_r), acc_dtype),
        interpret=interpret,
        **kwargs,
    )(x, *matrices)
