"""Fused-sweep Pallas kernel: the Gauss-Seidel sweep's opening pair in ONE
pallas_call.

A CP-ALS sweep needs, per mode, one MTTKRP — and the per-mode chain re-reads
the tensor N times. The shared-memory MTTKRP paper (Hayashi et al.,
arXiv:1708.08976) shows the chain has inter-mode reuse: every mode's MTTKRP
except the last shares the contraction ``X x_{N-1} A^(N-1)`` with the
*pre-sweep* factor values, so one tensor pass can produce both

    B^(0)(i, r)            = sum_{c_1..c_{N-1}} X(i, c..) prod_d A_d(c_d, r)
    P(i, c_1..c_{N-2}, r)  = sum_{c_{N-1}}      X(i, c..) A_{N-1}(c_{N-1}, r)

without breaking Gauss-Seidel order (both consume only pre-sweep factors;
modes 1..N-2 then contract P against already-updated factors, and mode N-1
runs a fresh full MTTKRP — see :mod:`repro.engine.sweep` for the schedule).

This kernel computes the (B^(0), P) pair as a two-output ``pallas_call``
with the exact output-stationary layout of :mod:`repro.kernels.mttkrpn`:
grid ``(r, i, c_1..c_{N-1})`` with the contraction tiles innermost, the
X tile loaded ONCE per grid step and consumed by both accumulators —
B^(0) against the chained Khatri-Rao weight block (MXU), P against the
last factor tile alone (MXU). Both outputs stay VMEM-resident across
their contraction revisits (B^(0) across all contraction steps; P across
the innermost ``c_{N-1}`` sweep, the only grid dim its index map drops).
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .mttkrpn import _compiler_params


def _fused_pair_kernel(*refs, n_contract: int, acc_dtype):
    x_ref = refs[0]
    f_refs = refs[1 : 1 + n_contract]
    b0_ref = refs[1 + n_contract]
    p_ref = refs[2 + n_contract]

    first_contract_step = pl.program_id(2) == 0
    for d in range(1, n_contract):
        first_contract_step &= pl.program_id(2 + d) == 0

    @pl.when(first_contract_step)
    def _zero_b0():
        b0_ref[...] = jnp.zeros_like(b0_ref)

    # P's block map keeps (i, c_1..c_{N-2}, r): the block is revisited only
    # across the innermost c_{N-1} sweep, so it zeroes when that dim wraps
    @pl.when(pl.program_id(2 + n_contract - 1) == 0)
    def _zero_p():
        p_ref[...] = jnp.zeros_like(p_ref)

    br = f_refs[0].shape[1]
    bi = x_ref.shape[0]
    # chained outer product over the contraction tile dims (Khatri-Rao)
    w = f_refs[0][...].astype(acc_dtype)  # (b1, br)
    for f in f_refs[1:]:
        ft = f[...].astype(acc_dtype)  # (bd, br)
        w = (w[:, None, :] * ft[None, :, :]).reshape(-1, br)
    xm = x_ref[...].reshape(bi, -1)
    b0_ref[...] += jax.lax.dot_general(
        xm, w, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=acc_dtype,
    )
    # same X tile, second consumer: contract only the last axis with A_{N-1}
    bc_last = f_refs[-1].shape[0]
    xr = x_ref[...].reshape(-1, bc_last)  # (bi*prod(bc[:-1]), bc_last)
    p = jax.lax.dot_general(
        xr, f_refs[-1][...], dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=acc_dtype,
    )
    p_ref[...] += p.reshape(p_ref.shape)


def mttkrp_fused_pair_pallas(
    x: jax.Array,
    factors: Sequence[jax.Array],
    *,
    block_i: int,
    block_contract: Sequence[int],
    block_r: int,
    interpret: bool = False,
    acc_dtype=jnp.float32,
) -> tuple[jax.Array, jax.Array]:
    """Canonical fused pair: ``(B^(0), P = X x_{N-1} A_{N-1})`` from one
    tensor pass. ``factors`` are the N-1 non-output factors in tensor-axis
    order (axes 1..N-1). Pre-padded inputs required; both outputs are in
    ``acc_dtype``."""
    n = x.ndim
    nc = n - 1
    assert nc >= 2, "fused pair needs >= 2 contraction dims"
    assert len(factors) == nc and len(block_contract) == nc
    i_sz = x.shape[0]
    r_sz = factors[0].shape[1]
    for d, f in enumerate(factors):
        assert f.shape == (x.shape[1 + d], r_sz)
        assert x.shape[1 + d] % block_contract[d] == 0
    assert i_sz % block_i == 0 and r_sz % block_r == 0

    grid = (
        r_sz // block_r,
        i_sz // block_i,
    ) + tuple(x.shape[1 + d] // block_contract[d] for d in range(nc))

    def x_map(r, i, *cs):
        return (i,) + cs

    def f_map_for(d):
        def f_map(r, i, *cs):
            return (cs[d], r)
        return f_map

    def b0_map(r, i, *cs):
        return (i, r)

    def p_map(r, i, *cs):
        return (i,) + cs[:-1] + (r,)

    in_specs = [
        pl.BlockSpec((block_i,) + tuple(block_contract), x_map)
    ] + [
        pl.BlockSpec((block_contract[d], block_r), f_map_for(d))
        for d in range(nc)
    ]
    p_shape = (i_sz,) + tuple(x.shape[1 + d] for d in range(nc - 1)) + (r_sz,)
    p_block = (block_i,) + tuple(block_contract[:-1]) + (block_r,)
    kernel = functools.partial(
        _fused_pair_kernel, n_contract=nc, acc_dtype=acc_dtype
    )
    kwargs = {}
    cp = _compiler_params(nc)
    if cp is not None and not interpret:
        kwargs["compiler_params"] = cp
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((block_i, block_r), b0_map),
            pl.BlockSpec(p_block, p_map),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((i_sz, r_sz), acc_dtype),
            jax.ShapeDtypeStruct(p_shape, acc_dtype),
        ),
        interpret=interpret,
        **kwargs,
    )(x, *factors)


def fused_pair_canonical_pallas(
    x: jax.Array,
    fs: Sequence[jax.Array],
    *,
    plan=None,
    interpret: bool | None = None,
    out_dtype=None,
) -> tuple[jax.Array, jax.Array]:
    """Padding/un-padding wrapper around :func:`mttkrp_fused_pair_pallas`
    (mirrors :func:`repro.kernels.ops.mttkrp_canonical_pallas`).

    ``x`` already has the output mode at axis 0; ``fs`` are the N-1
    factors for axes 1..N-1 in order. Returns ``(b0, p)`` un-padded, with
    ``p`` of shape ``(I_0, I_1..I_{N-2}, R)``.
    """
    from .ops import _auto_interpret, _round_up  # local: shared idiom

    interpret = _auto_interpret() if interpret is None else interpret
    rank = fs[0].shape[1]
    orig_shape = x.shape
    if plan is None:
        from ..engine.plan import choose_sweep_blocks

        plan = choose_sweep_blocks(x.shape, rank, x.dtype.itemsize)
    tgt = plan.padded_shape(x.shape)
    r_pad = _round_up(rank, plan.block_r)
    x = jnp.pad(x, [(0, t - s) for t, s in zip(tgt, x.shape)])
    fs = [
        jnp.pad(f, ((0, tgt[1 + d] - f.shape[0]), (0, r_pad - rank)))
        for d, f in enumerate(fs)
    ]
    b0, p = mttkrp_fused_pair_pallas(
        x, fs,
        block_i=plan.block_i,
        block_contract=plan.block_contract,
        block_r=plan.block_r,
        interpret=interpret,
    )
    b0 = b0[:orig_shape[0], :rank]
    p = p[
        tuple(slice(0, s) for s in orig_shape[:-1]) + (slice(0, rank),)
    ]
    if out_dtype is not None:
        return b0.astype(out_dtype), p.astype(out_dtype)
    return b0, p
