"""CP gradient compression — the paper's insight as a DP-communication trick.

The Khatri-Rao structure means a rank-R CP representation of an
I_1×...×I_N gradient carries Σ_k I_k·R words instead of Π_k I_k. In data
parallelism we must average gradients across workers; instead of
all-reducing the full gradient we run a few *synchronized* CP-ALS sweeps in
which only the MTTKRP results are all-reduced:

    B_n = pmean(MTTKRP(g_local, factors, n))      # I_n × R words
    A_n = B_n · Γ_n^+                              # local solve

MTTKRP is linear in the tensor, so pmean(MTTKRP(g_local)) =
MTTKRP(mean g) — every worker performs *exactly* CP-ALS on the averaged
gradient while communicating only factor-sized data. Per sweep the volume is
Σ_k I_k R vs Π_k I_k for a full all-reduce (e.g. a 4096×14336 matrix at
rank 8: 147k vs 59M words, ~400×).

Error feedback (PowerSGD-style) accumulates the compression residual into
the next step's gradient so the optimizer sees an unbiased long-run signal.

Deterministic same-key initialization keeps workers in lockstep without a
broadcast.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp

from ..core.mttkrp import mttkrp
from ..core.tensor import tensor_from_factors


def pick_3way_shape(shape: Sequence[int]) -> tuple[int, int, int]:
    """Map a parameter shape to the 3-way tensor the compressor works on.

    Matrices become (d0, d1, 1) (CP == low-rank matrix factorization);
    higher-order tensors merge trailing dims; vectors are not compressed
    (callers should skip 1-D params — compression would save nothing).
    """
    dims = [int(d) for d in shape]
    if len(dims) == 1:
        return (dims[0], 1, 1)
    if len(dims) == 2:
        return (dims[0], dims[1], 1)
    if len(dims) == 3:
        return (dims[0], dims[1], dims[2])
    merged = 1
    for d in dims[2:]:
        merged *= d
    return (dims[0], dims[1], merged)


def init_factors(key: jax.Array, dims: Sequence[int], rank: int,
                 dtype=jnp.float32) -> list[jax.Array]:
    """Orthonormal-column random init (QR of a Gaussian draw).

    Correlated random columns can strand ALS in a rank-deficient local
    minimum; orthonormal starts are the standard guard. Deterministic in
    ``key`` so every DP worker initializes identically without a broadcast.
    """
    ks = jax.random.split(key, len(dims))
    out = []
    for k, d in zip(ks, dims):
        g = jax.random.normal(k, (d, rank), dtype)
        if d >= rank:
            q, _ = jnp.linalg.qr(g)
            out.append(q.astype(dtype))
        else:  # fewer rows than columns: normalize instead
            out.append(g / jnp.linalg.norm(g, axis=0, keepdims=True))
    return out


def _solve_mode(b: jax.Array, grams: list[jax.Array], mode: int,
                rank: int) -> jax.Array:
    gamma = jnp.ones((rank, rank), b.dtype)
    for k, g in enumerate(grams):
        if k != mode:
            gamma = gamma * g
    ridge = 1e-6 * jnp.trace(gamma) / rank + 1e-12
    return jnp.linalg.solve(
        gamma + ridge * jnp.eye(rank, dtype=b.dtype), b.T
    ).T


def cp_compressed_mean(
    g_local: jax.Array,
    axis_names,
    rank: int,
    sweeps: int = 2,
    key: jax.Array | None = None,
    factors: Sequence[jax.Array] | None = None,
):
    """Inside shard_map/pmap: rank-R CP-ALS of pmean(g) with factor-only
    communication. Returns (reconstruction, factors).

    ``g_local`` must be >= 2-D (reshape first via pick_3way_shape).
    """
    dims = g_local.shape
    if factors is None:
        key = key if key is not None else jax.random.PRNGKey(0)
        factors = init_factors(key, dims, rank, g_local.dtype)
    else:
        factors = list(factors)
        rank = factors[0].shape[1]
    grams = [f.T @ f for f in factors]
    for _ in range(sweeps):
        for mode in range(len(dims)):
            b_loc = mttkrp(g_local, factors, mode)
            # the ONLY cross-worker communication: I_mode x R words
            b = jax.lax.pmean(b_loc, axis_names)
            a = _solve_mode(b, grams, mode, rank)
            factors[mode] = a
            grams[mode] = a.T @ a
    return tensor_from_factors(factors), factors


@dataclass
class CompressionState:
    """Error-feedback state per compressed parameter."""
    residual: jax.Array
    factors: list[jax.Array]


def init_compression_state(
    key: jax.Array, shape: Sequence[int], rank: int, dtype=jnp.float32
) -> CompressionState:
    dims = pick_3way_shape(shape)
    return CompressionState(
        residual=jnp.zeros(dims, dtype),
        factors=init_factors(key, dims, rank, dtype),
    )


def compressed_gradient(
    g_local: jax.Array,
    state: CompressionState,
    axis_names,
    sweeps: int = 1,
) -> tuple[jax.Array, CompressionState]:
    """Error-fed compressed DP gradient (call inside shard_map over DP axes).

    Returns the approximated *mean* gradient (original shape) and the new
    state. Warm-started factors make one sweep per step sufficient in
    practice (the gradient subspace drifts slowly).
    """
    dims = pick_3way_shape(g_local.shape)
    g3 = g_local.reshape(dims) + state.residual
    recon, factors = cp_compressed_mean(
        g3, axis_names, rank=state.factors[0].shape[1],
        sweeps=sweeps, factors=state.factors,
    )
    new_state = CompressionState(residual=g3 - recon, factors=factors)
    return recon.reshape(g_local.shape), new_state


def compression_ratio(shape: Sequence[int], rank: int, sweeps: int) -> float:
    """Words all-reduced with compression vs full all-reduce (per step)."""
    dims = pick_3way_shape(shape)
    full = 1
    for d in dims:
        full *= d
    factor_words = sweeps * sum(d * rank for d in dims)
    return full / max(factor_words, 1)
