"""Distributed runtime: parallel MTTKRP algorithms, grids, HLO analysis."""

from .mesh import make_grid_mesh, mode_axis, hyperslice_axes
from .mttkrp_parallel import (
    engine_local_fn,
    mttkrp_stationary,
    mttkrp_general,
    place_inputs,
    tensor_spec,
    factor_spec,
    output_spec,
)
from .hlo import parse_collectives, collective_bytes, CollectiveSummary

__all__ = [
    "make_grid_mesh",
    "mode_axis",
    "hyperslice_axes",
    "engine_local_fn",
    "mttkrp_stationary",
    "mttkrp_general",
    "place_inputs",
    "tensor_spec",
    "factor_spec",
    "output_spec",
    "parse_collectives",
    "collective_bytes",
    "CollectiveSummary",
]
