"""Distributed runtime: parallel MTTKRP algorithms, grid selection,
the CP-ALS sweep driver, and HLO analysis."""

from .mesh import make_grid_mesh, mode_axis, hyperslice_axes, validate_grid
from .mttkrp_parallel import (
    engine_local_fn,
    gather_factor,
    gather_factors,
    mttkrp_stationary,
    mttkrp_general,
    place_inputs,
    tensor_spec,
    factor_spec,
    output_spec,
)
from .grid_select import (
    GridChoice,
    choose_cp_grid,
    select_grid,
    select_general_grid,
    select_stationary_grid,
    stationary_sweep_words,
)
from .cp_als_parallel import (
    build_cp_sweep,
    cp_als_parallel,
    place_cp_state,
)
from .hlo import parse_collectives, collective_bytes, CollectiveSummary

__all__ = [
    "make_grid_mesh",
    "mode_axis",
    "hyperslice_axes",
    "validate_grid",
    "engine_local_fn",
    "gather_factor",
    "gather_factors",
    "mttkrp_stationary",
    "mttkrp_general",
    "place_inputs",
    "tensor_spec",
    "factor_spec",
    "output_spec",
    "GridChoice",
    "choose_cp_grid",
    "select_grid",
    "select_general_grid",
    "select_stationary_grid",
    "stationary_sweep_words",
    "build_cp_sweep",
    "cp_als_parallel",
    "place_cp_state",
    "parse_collectives",
    "collective_bytes",
    "CollectiveSummary",
]
