"""Distributed runtime: parallel MTTKRP algorithms, grid selection,
the CP-ALS sweep driver, and HLO analysis."""

from .mesh import (
    make_grid_mesh,
    mode_axis,
    hyperslice_axes,
    validate_grid,
    validate_tucker_grid,
)
from .mttkrp_parallel import (
    engine_local_fn,
    gather_factor,
    gather_factors,
    mttkrp_stationary,
    mttkrp_general,
    place_inputs,
    tensor_spec,
    factor_spec,
    output_spec,
)
from .grid_select import (
    GridChoice,
    choose_cp_grid,
    choose_tucker_grid,
    select_tucker_grid,
    multi_ttm_sweep_words,
    select_grid,
    select_general_grid,
    select_stationary_grid,
    stationary_sweep_words,
)
from .cp_als_parallel import (
    build_cp_sweep,
    cp_als_parallel,
    place_cp_state,
)
from .tucker_parallel import (
    build_tucker_sweep,
    multi_ttm_stationary,
    place_multi_ttm_inputs,
    place_tucker_state,
    tucker_hooi_parallel,
)
from .hlo import parse_collectives, collective_bytes, CollectiveSummary

__all__ = [
    "make_grid_mesh",
    "mode_axis",
    "hyperslice_axes",
    "validate_grid",
    "validate_tucker_grid",
    "engine_local_fn",
    "gather_factor",
    "gather_factors",
    "mttkrp_stationary",
    "mttkrp_general",
    "place_inputs",
    "tensor_spec",
    "factor_spec",
    "output_spec",
    "GridChoice",
    "choose_cp_grid",
    "choose_tucker_grid",
    "select_tucker_grid",
    "multi_ttm_sweep_words",
    "select_grid",
    "select_general_grid",
    "select_stationary_grid",
    "stationary_sweep_words",
    "build_cp_sweep",
    "cp_als_parallel",
    "place_cp_state",
    "build_tucker_sweep",
    "multi_ttm_stationary",
    "place_multi_ttm_inputs",
    "place_tucker_state",
    "tucker_hooi_parallel",
    "parse_collectives",
    "collective_bytes",
    "CollectiveSummary",
]
