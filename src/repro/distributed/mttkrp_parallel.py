"""Parallel MTTKRP: Algorithm 3 (stationary tensor) and Algorithm 4
(general, rank-partitioned) as shard_map programs.

Collective mapping (paper -> JAX):
  All-Gather over a hyperslice   -> lax.all_gather(axis_names, tiled=True)
  Reduce-Scatter over hyperslice -> lax.psum_scatter(axis_names, tiled=True)

Data distributions follow §V-C1 / §V-D1 exactly:
  X          : block-distributed over the N-way grid, P('m0', ..., 'm{N-1}')
               (Alg 4 additionally splits mode 0 across the rank axis:
               P(('r','m0'), 'm1', ...))
  A^(k)      : rows split by m{k} into the paper's S^{(k)}_{p_k} block-rows,
               each block-row spread across its hyperslice,
               P(('m{k}', *hyperslice), ) — and columns split by 'r' for
               Alg 4, P((...), 'r').
  B^(n) (out): same layout as A^(n).

The per-processor communication volumes of these programs are *measured*
from compiled HLO (distributed/hlo.py) and checked against Eq (12)/Eq (16)
in tests/test_parallel_cost_match.py — that is the reproduction of the
paper's cost analysis, and the optimality tests compare them against the
§IV lower bounds.
"""

from __future__ import annotations

import functools
from typing import Callable, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..compat import shard_map
from .mesh import RANK_AXIS, hyperslice_axes, mode_axis, row_sharding_axes

LocalFn = Callable[[jax.Array, Sequence[jax.Array], int], jax.Array]


def engine_local_fn(
    ctx=None,
    interpret=None,
    memory=None,
    backend=None,
) -> LocalFn:
    """Per-processor MTTKRP through the engine's dispatch layer.

    This is the paper's separation of concerns made literal: Algorithms 3/4
    own the collectives; the *local* MTTKRP inside each shard is exactly the
    sequential problem, so it runs through the same engine (and, with
    ``ctx.backend == 'pallas'``, the same blocked VMEM kernels) as the
    single-device path. ``backend='auto'`` resolves against the autotuner's
    plan cache keyed by the *local shard* shape — tuned local plans apply
    inside shard_map because resolution is pure Python over static shapes
    (it happens once, at trace time; no measurement is attempted there).

    ``ctx`` is an :class:`~repro.engine.context.ExecutionContext` (its
    ``local()`` view is used — the collectives here are owned by the
    algorithms, not the engine). A legacy backend *string* first argument
    still works through the deprecation shim.
    """
    from ..engine import execute as engine_execute  # call-time: layer cycle
    from ..engine.context import UNSET, context_from_legacy

    if isinstance(ctx, str):  # old positional form: engine_local_fn("pallas")
        if backend is not None:
            raise TypeError(
                "repro.distributed.engine_local_fn: backend given both "
                "positionally and by keyword"
            )
        ctx, backend = None, ctx
    if ctx is None and (
        backend is not None or interpret is not None or memory is not None
    ):
        ctx = context_from_legacy(
            "repro.distributed.engine_local_fn", None,
            {
                "backend": backend if backend is not None else UNSET,
                "interpret": interpret if interpret is not None else UNSET,
                "memory": memory if memory is not None else UNSET,
            },
        )
    elif ctx is not None and (
        backend is not None or interpret is not None or memory is not None
    ):
        raise TypeError(
            "repro.distributed.engine_local_fn: pass either ctx= or the "
            "legacy keyword arguments (backend, interpret, memory), not "
            "both — the context already carries the full configuration"
        )
    elif ctx is None:
        from ..engine.context import ExecutionContext

        ctx = ExecutionContext.default()
    local_ctx = ctx.local()

    def fn(x, factors, mode):
        return engine_execute.mttkrp(x, factors, mode, ctx=local_ctx)

    return fn


def gather_factor(f_loc: jax.Array, ndim: int, k: int) -> jax.Array:
    """Line 4 of Alg 3/4: all-gather factor k's block-rows over the mode-k
    hyperslice, reconstructing S^{(k)}_{p_k} on every processor of it."""
    return jax.lax.all_gather(
        f_loc, hyperslice_axes(ndim, k), axis=0, tiled=True
    )


def gather_factors(
    f_locs: Sequence[jax.Array | None], ndim: int, skip: int | None = None
) -> list[jax.Array | None]:
    """Batched factor gathers: one :func:`gather_factor` per non-``skip``
    mode (``f_locs`` is indexed by mode; ``None`` entries pass through).
    The CP-ALS sweep driver and Alg 3/4 share this so every consumer emits
    identical collectives (the HLO byte accounting depends on it)."""
    return [
        None if (k == skip or f is None) else gather_factor(f, ndim, k)
        for k, f in enumerate(f_locs)
    ]


# --------------------------------------------------------------------------
# Shardings (the paper's initial/terminal data distributions)
# --------------------------------------------------------------------------

def tensor_spec(ndim: int, rank_split_mode: int | None = None) -> P:
    """X's PartitionSpec on the grid mesh (optionally splitting one mode
    across the rank axis too, for Alg 4's across-p0 partition of X)."""
    parts = []
    for k in range(ndim):
        if k == rank_split_mode:
            # m-axis major, r minor: the rank-axis all-gather then
            # reconstructs the contiguous block S^{(k)}_{p_k}
            parts.append((mode_axis(k), RANK_AXIS))
        else:
            parts.append(mode_axis(k))
    return P(*parts)


def factor_spec(ndim: int, k: int, rank_axis: bool = False) -> P:
    """A^(k)'s PartitionSpec: rows over (m{k}, hyperslice), cols over r."""
    return P(row_sharding_axes(ndim, k), RANK_AXIS if rank_axis else None)


def output_spec(ndim: int, mode: int, rank_axis: bool = False) -> P:
    return factor_spec(ndim, mode, rank_axis)


# --------------------------------------------------------------------------
# Algorithm 3: stationary-tensor MTTKRP
# --------------------------------------------------------------------------

def _stationary_local(
    x_loc: jax.Array,
    f_locs: tuple[jax.Array, ...],
    *,
    ndim: int,
    mode: int,
    local_fn: LocalFn,
) -> jax.Array:
    """Per-processor body of Algorithm 3 (runs under shard_map)."""
    by_mode: list[jax.Array | None] = [None] * ndim
    fi = 0
    for k in range(ndim):
        if k != mode:
            by_mode[k] = f_locs[fi]
            fi += 1
    # Line 4: A^(k)_{p_k} = All-Gather over the mode-k hyperslice
    gathered = gather_factors(by_mode, ndim, skip=mode)
    # Line 6: local MTTKRP
    c = local_fn(x_loc, gathered, mode)
    # Line 7: Reduce-Scatter over the mode-n hyperslice
    return jax.lax.psum_scatter(
        c, hyperslice_axes(ndim, mode), scatter_dimension=0, tiled=True
    )


def _resolve_parallel_ctx(api: str, ctx, backend, interpret):
    """Shared ctx/legacy resolution for the Alg 3/4 builders, plus the
    replication-check policy: pallas_call has no shard_map replication
    rule on older jax, so the (purely diagnostic) rep check is skipped
    when the local body may contain a kernel ("auto" can resolve to
    pallas at trace time). ``ctx.distribution.check_rep`` overrides."""
    from ..engine.context import context_from_legacy

    ctx = context_from_legacy(
        api, ctx, {"backend": backend, "interpret": interpret},
        stacklevel=4,
    )
    check_rep = ctx.backend not in ("pallas", "auto")
    if ctx.distribution is not None and ctx.distribution.check_rep is not None:
        check_rep = ctx.distribution.check_rep
    return ctx, check_rep


def mttkrp_stationary(
    mesh: jax.sharding.Mesh,
    mode: int,
    ndim: int,
    local_fn: LocalFn | None = None,
    *,
    ctx=None,
    backend=None,
    interpret=None,
):
    """Build the Alg-3 shard_map callable ``f(x, *factors_except_mode)``.

    The tensor never moves (stationary); only factor blocks are gathered and
    partial outputs reduce-scattered — per-processor volume Eq (12). The
    local MTTKRP goes through the engine under ``ctx`` (the backend selects
    einsum / blocked_host / pallas); an explicit ``local_fn`` overrides it.
    """
    from ..engine.context import UNSET

    ctx, check_rep = _resolve_parallel_ctx(
        "repro.distributed.mttkrp_stationary", ctx,
        backend if backend is not None else UNSET,
        interpret if interpret is not None else UNSET,
    )
    if local_fn is None:
        local_fn = engine_local_fn(ctx)
    in_specs = (tensor_spec(ndim),) + tuple(
        factor_spec(ndim, k) for k in range(ndim) if k != mode
    )
    fn = functools.partial(
        _stationary_local, ndim=ndim, mode=mode, local_fn=local_fn
    )

    def wrapper(x, *f_locs):
        return fn(x, f_locs)

    return jax.jit(
        shard_map(
            wrapper,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=output_spec(ndim, mode),
            check_rep=check_rep,
        )
    )


# --------------------------------------------------------------------------
# Algorithm 4: general MTTKRP (rank-partitioned)
# --------------------------------------------------------------------------

def _general_local(
    x_loc: jax.Array,
    f_locs: tuple[jax.Array, ...],
    *,
    ndim: int,
    mode: int,
    local_fn: LocalFn,
) -> jax.Array:
    """Per-processor body of Algorithm 4 (runs under shard_map)."""
    # Line 3: All-Gather the subtensor across the rank-axis fiber
    x_full = jax.lax.all_gather(x_loc, (RANK_AXIS,), axis=0, tiled=True)
    by_mode: list[jax.Array | None] = [None] * ndim
    fi = 0
    for k in range(ndim):
        if k != mode:
            by_mode[k] = f_locs[fi]
            fi += 1
    # Line 5: gather factor block-rows over the mode-k hyperslices
    # (never across r: each rank-slice keeps its own T_{p_0} columns)
    gathered = gather_factors(by_mode, ndim, skip=mode)
    # Line 7: local MTTKRP on the gathered subtensor and factor columns
    c = local_fn(x_full, gathered, mode)
    # Line 8: Reduce-Scatter over the mode-n hyperslice
    return jax.lax.psum_scatter(
        c, hyperslice_axes(ndim, mode), scatter_dimension=0, tiled=True
    )


def mttkrp_general(
    mesh: jax.sharding.Mesh,
    mode: int,
    ndim: int,
    local_fn: LocalFn | None = None,
    *,
    ctx=None,
    backend=None,
    interpret=None,
):
    """Build the Alg-4 shard_map callable ``f(x, *factors_except_mode)``.

    Requires a mesh with a leading 'r' axis (make_grid_mesh(grid, p0)).
    Alg 3 is the special case p0 == 1 (the 'r' collectives degenerate).
    The local MTTKRP goes through the engine like :func:`mttkrp_stationary`.
    """
    from ..engine.context import UNSET

    ctx, check_rep = _resolve_parallel_ctx(
        "repro.distributed.mttkrp_general", ctx,
        backend if backend is not None else UNSET,
        interpret if interpret is not None else UNSET,
    )
    if local_fn is None:
        local_fn = engine_local_fn(ctx)
    in_specs = (tensor_spec(ndim, rank_split_mode=0),) + tuple(
        factor_spec(ndim, k, rank_axis=True)
        for k in range(ndim)
        if k != mode
    )
    fn = functools.partial(
        _general_local, ndim=ndim, mode=mode, local_fn=local_fn
    )

    def wrapper(x, *f_locs):
        return fn(x, f_locs)

    return jax.jit(
        shard_map(
            wrapper,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=output_spec(ndim, mode, rank_axis=True),
            check_rep=check_rep,
        )
    )


# --------------------------------------------------------------------------
# Convenience: place global arrays per the paper's distributions
# --------------------------------------------------------------------------

def place_inputs(
    mesh: jax.sharding.Mesh,
    x: jax.Array,
    factors: Sequence[jax.Array],
    mode: int,
    rank_axis: bool = False,
):
    """Device-put X and the non-mode factors into their §V distributions."""
    ndim = x.ndim
    xs = jax.device_put(
        x,
        NamedSharding(
            mesh, tensor_spec(ndim, rank_split_mode=0 if rank_axis else None)
        ),
    )
    fs = tuple(
        jax.device_put(
            factors[k], NamedSharding(mesh, factor_spec(ndim, k, rank_axis))
        )
        for k in range(ndim)
        if k != mode
    )
    return xs, fs
