"""Collective extraction from compiled HLO *and* from jaxprs.

Two front ends, one byte-accounting currency (:class:`CollectiveOp` /
:class:`CollectiveSummary`):

* :func:`parse_collectives` extracts every collective op (all-gather /
  all-reduce / reduce-scatter / all-to-all / collective-permute) from
  ``compiled.as_text()`` — the dynamic path ``tests/dist_worker.py``
  measures on real multi-device meshes.
* :func:`jaxpr_collectives` walks a ``jax.make_jaxpr`` trace of a
  shard_map program for the same primitives — the static path
  (``repro.verify.comm``): it needs no devices at all (an
  ``AbstractMesh`` suffices), so the byte model is provable on a
  single-CPU CI host without compiling or spawning anything.

Bytes are accounted two ways:

* ``operand_bytes`` — sum of operand sizes (the roofline-term convention);
* ``ring_bytes``    — per-device link traffic under ring/bucket algorithms
                      (the paper's §V-C3 model): all-gather (q-1)·w_in,
                      reduce-scatter (q-1)·w_out, all-reduce 2(q-1)/q·w,
                      all-to-all (q-1)/q·w, collective-permute w.

SPMD HLO is a per-device program, so operand shapes are per-device shards —
exactly the paper's "w = max_p nnz" local sizes; inside a shard_map jaxpr
the avals are the same per-shard shapes, which is why both front ends
agree to the byte (``tests/test_verify.py`` pins a few points of each
against the other via the sweep model).
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Any, Iterable, Mapping

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\((.*)$"
)
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape string (possibly a tuple)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveOp:
    kind: str
    name: str
    operand_bytes: int
    output_bytes: int
    group_size: int

    @property
    def ring_bytes(self) -> int:
        q = max(self.group_size, 1)
        if self.kind == "all-gather":
            return (q - 1) * self.operand_bytes
        if self.kind == "reduce-scatter":
            return (q - 1) * self.output_bytes
        if self.kind == "all-reduce":
            return int(2 * (q - 1) / q * self.operand_bytes)
        if self.kind == "all-to-all":
            return int((q - 1) / q * self.operand_bytes)
        return self.operand_bytes  # collective-permute: one hop


@dataclass
class CollectiveSummary:
    ops: list[CollectiveOp] = field(default_factory=list)

    @property
    def operand_bytes(self) -> int:
        return sum(o.operand_bytes for o in self.ops)

    @property
    def ring_bytes(self) -> int:
        return sum(o.ring_bytes for o in self.ops)

    def by_kind(self) -> dict[str, dict[str, int]]:
        out: dict[str, dict[str, int]] = {}
        for o in self.ops:
            d = out.setdefault(o.kind, {"count": 0, "operand_bytes": 0,
                                        "ring_bytes": 0})
            d["count"] += 1
            d["operand_bytes"] += o.operand_bytes
            d["ring_bytes"] += o.ring_bytes
        return out


def parse_collectives(hlo_text: str) -> CollectiveSummary:
    """Parse collective ops out of (stable-)HLO module text.

    Handles sync and async (``-start``/``-done`` — only starts counted),
    brace and iota replica-group formats, tuple shapes, and resolves operand
    sizes through the instruction table.
    """
    sizes: dict[str, int] = {}
    summary = CollectiveSummary()
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, out_shape, opcode, rest = m.groups()
        sizes[name] = _shape_bytes(out_shape)
        base = opcode
        is_start = False
        if base.endswith("-start"):
            base, is_start = base[:-6], True
        elif base.endswith("-done"):
            continue  # counted at -start
        if base not in COLLECTIVE_KINDS:
            continue
        # resolve operand sizes from %references on the line
        operand_names = re.findall(r"%([\w\.\-]+)", rest.split("),")[0])
        operand_bytes = sum(sizes.get(n, 0) for n in operand_names)
        if operand_bytes == 0:
            # operands printed with inline shapes (unoptimized HLO)
            operand_bytes = _shape_bytes(rest.split(")")[0])
        # group size
        q = 1
        mg = _GROUPS_BRACE_RE.search(line)
        if mg:
            q = len(mg.group(1).split(","))
        else:
            mi = _GROUPS_IOTA_RE.search(line)
            if mi:
                q = int(mi.group(2))
            elif base == "collective-permute":
                q = 2
        out_bytes = sizes[name]
        if is_start and out_bytes == 0:
            out_bytes = operand_bytes
        summary.ops.append(
            CollectiveOp(base, name, operand_bytes, out_bytes, q)
        )
    return summary


def collective_bytes(compiled_or_text: Any) -> int:
    """Prompt-convention collective bytes: sum of operand sizes."""
    text = (
        compiled_or_text
        if isinstance(compiled_or_text, str)
        else compiled_or_text.as_text()
    )
    return parse_collectives(text).operand_bytes


# --------------------------------------------------------------------------
# Jaxpr front end (the static path)
# --------------------------------------------------------------------------

#: jaxpr primitive name -> HLO collective kind. ``psum`` maps to
#: all-reduce (under shard_map it lowers to one); ``psum2`` is the
#: replication-checked rewrite shard_map's ``check_rep=True`` emits on
#: jax 0.4.x — same collective, same bytes; ``ppermute`` to
#: collective-permute. ``pmean`` has no primitive of its own (it traces
#: to psum + divide), so the map is complete for this repo's programs.
JAXPR_COLLECTIVE_PRIMS: dict[str, str] = {
    "all_gather": "all-gather",
    "reduce_scatter": "reduce-scatter",
    "psum": "all-reduce",
    "psum2": "all-reduce",
    "ppermute": "collective-permute",
    "all_to_all": "all-to-all",
}


def _aval_bytes(avals: Iterable[Any]) -> int:
    total = 0
    for aval in avals:
        if not hasattr(aval, "shape"):  # e.g. AbstractToken
            continue
        import jax.numpy as jnp  # local: keep the HLO path jax-light

        total += int(math.prod(aval.shape)) * jnp.dtype(aval.dtype).itemsize
    return total


def _group_size(prim: str, params: Mapping[str, Any],
                axis_sizes: Mapping[str, int]) -> int:
    if prim in ("all_gather", "reduce_scatter", "all_to_all"):
        return int(params["axis_size"])
    if prim in ("psum", "psum2"):
        q = 1
        for a in params.get("axes", ()):
            if isinstance(a, str):
                q *= int(axis_sizes.get(a, 1))
        return q
    return 2  # ppermute: group size is unused by its ring_bytes rule


def _walk_jaxpr(jaxpr: Any, axis_sizes: Mapping[str, int],
                ops: list[CollectiveOp], repeat: int) -> None:
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim in JAXPR_COLLECTIVE_PRIMS:
            op = CollectiveOp(
                JAXPR_COLLECTIVE_PRIMS[prim],
                prim,
                _aval_bytes(v.aval for v in eqn.invars),
                _aval_bytes(v.aval for v in eqn.outvars),
                _group_size(prim, eqn.params, axis_sizes),
            )
            ops.extend([op] * repeat)
        # recurse into nested jaxprs (pjit/shard_map/cond/scan params
        # carry ClosedJaxpr, raw Jaxpr, or sequences of either)
        inner_repeat = repeat
        if prim == "scan":
            inner_repeat = repeat * int(eqn.params.get("length", 1))
        for val in eqn.params.values():
            for sub in (val if isinstance(val, (tuple, list)) else (val,)):
                if hasattr(sub, "jaxpr"):  # ClosedJaxpr
                    _walk_jaxpr(sub.jaxpr, axis_sizes, ops, inner_repeat)
                elif hasattr(sub, "eqns"):  # raw Jaxpr
                    _walk_jaxpr(sub, axis_sizes, ops, inner_repeat)


def jaxpr_collectives(closed_jaxpr: Any,
                      axis_sizes: Mapping[str, int]) -> CollectiveSummary:
    """Every collective primitive in a (closed) jaxpr, recursively.

    ``axis_sizes`` maps mesh axis names to sizes (``dict(mesh.shape)``) —
    needed because a ``psum`` eqn records axis *names*, not sizes. Avals
    inside a shard_map body are per-shard, so the resulting
    :class:`CollectiveSummary` uses exactly the same "w = local words"
    convention as the HLO front end, and ``ring_bytes`` is directly
    comparable to the §V-C3 sweep models. ``scan`` bodies are counted
    ``length`` times; this repo's sweep programs are fully unrolled, so
    the multiplier is exercised only defensively.
    """
    jaxpr = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    summary = CollectiveSummary()
    _walk_jaxpr(jaxpr, axis_sizes, summary.ops, 1)
    return summary
