"""Processor-grid selection against the paper's Eq (12)/Eq (16) cost models.

The paper chooses the N-way grid (Alg 3) and the (N+1)-way grid with a
leading rank axis P_0 (Alg 4) to *minimize* the per-processor communication
volume — §V-C3 / §V-D3 give asymptotic prescriptions, but for concrete
(dims, rank, P) the exact minimizer is an integer program over divisor
tuples.  This module solves it exactly:

* ``select_stationary_grid`` — Eq (12) minimizer over N-way grids (Alg 3),
  for a single-mode MTTKRP (``mode=k``) or for the full CP-ALS sweep
  objective (``mode=None`` — Ballard–Hayashi–Kannan, arXiv:1806.07985: the
  tensor stays stationary and every factor is gathered once and
  reduce-scattered once per sweep, so the objective is the symmetric
  all-mode sum).
* ``select_general_grid``    — Eq (16) minimizer over (P_0, grid) (Alg 4).
* ``select_grid``            — picks the cheaper of the two (``algorithm=
  "auto"``), the paper's Cor 4.2 regime decision made exact.
* ``choose_cp_grid``         — the CP-ALS driver entry: largest usable
  processor count ≤ P whose cost-minimal grid shards the tensor and the
  factor rows evenly (shard_map needs even shards), then the Eq (12)
  sweep-minimal grid for it.

The search is a branch-and-bound over divisor assignments (every Eq (12)/
Eq (16) term is nonnegative, so a partial-sum ≥ incumbent prunes the
subtree), factored as :func:`_search_separable` so new per-axis-separable
objectives reuse it: ``select_tucker_grid`` / ``choose_tucker_grid`` run
the same search over the Multi-TTM/Tucker sweep objective
(:func:`multi_ttm_sweep_words`, arXiv:2207.10437).
``brute_force_stationary`` / ``brute_force_general`` /
``brute_force_tucker`` enumerate every divisor tuple with no pruning; the
tests pin ``select_*`` against them for P ≤ 64 on 3-way and 4-way shapes.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Sequence

from ..core.bounds import par_general_cost, par_stationary_cost
from ..core.grid import _divisors, _factorization_tuples


@dataclass(frozen=True)
class GridChoice:
    """A selected processor grid and its modeled communication."""

    p0: int
    grid: tuple[int, ...]
    words: float          # per-processor words of the selected objective
    algorithm: str        # "stationary" (Alg 3) or "general" (Alg 4)
    objective: str        # "mode{k}" or "sweep"

    @property
    def procs(self) -> int:
        return self.p0 * math.prod(self.grid)


# --------------------------------------------------------------------------
# Objectives
# --------------------------------------------------------------------------

def _alg3_factor_words(d: int, pk: int, rank: int, procs: int) -> float:
    """One Eq (12) term: moving factor k's block-rows over its hyperslice.

    ``(P/P_k - 1) * w_k`` with ``w_k = ceil(I_k/P_k) * R / (P/P_k)`` — the
    cost of one all-gather of A^(k) (or, identically, one reduce-scatter of
    B^(k)) over the q = P/P_k processors of the mode-k hyperslice.
    """
    # arithmetic mirrors bounds.par_stationary_cost term-for-term so the
    # two never disagree in the last ulp (the tests compare them exactly)
    w = math.ceil(d / pk) * rank / (procs // pk)
    return (procs / pk - 1) * w


def stationary_mode_words(
    dims: Sequence[int], rank: int, grid: Sequence[int], mode: int
) -> float:
    """Eq (12): per-processor words of one Alg-3 MTTKRP in ``mode``."""
    return par_stationary_cost(dims, rank, grid, mode)


def stationary_sweep_words(
    dims: Sequence[int],
    rank: int,
    grid: Sequence[int],
    include_solve_terms: bool = True,
) -> float:
    """Per-processor words of one stationary CP-ALS sweep (all N modes).

    The sweep driver (:mod:`repro.distributed.cp_als_parallel`) keeps X
    stationary and carries each factor's gathered block-rows between mode
    updates, so per sweep every factor is all-gathered exactly once (after
    its update) and every MTTKRP output reduce-scattered exactly once:
    ``2 * sum_k (P/P_k - 1) w_k`` — versus ``N * sum_k ...`` for N
    independent Eq (12) calls.  ``include_solve_terms`` adds the R×R Gram
    all-reduces (one per mode, over the P_k-processor mode-k fiber) that the
    sharded normal-equations solve needs; they are O(R^2), asymptotically
    dominated by the factor terms.
    """
    procs = math.prod(grid)
    total = 0.0
    for d, pk in zip(dims, grid):
        total += _sweep_term(d, pk, rank, procs, include_solve_terms)
    return total


def _sweep_term(
    d: int, pk: int, rank: int, procs: int, solve: bool = True
) -> float:
    """One factor's per-sweep words (shared by the model and the search so
    their float rounding is bit-identical and tie-breaking agrees)."""
    words = 2 * _alg3_factor_words(d, pk, rank, procs)
    if solve:
        words = words + 2 * (pk - 1) / pk * rank * rank
    return words


def general_mode_words(
    dims: Sequence[int],
    rank: int,
    grid: Sequence[int],
    p0: int,
    mode: int,
) -> float:
    """Eq (16)/(28): per-processor words of one Alg-4 MTTKRP in ``mode``."""
    return par_general_cost(dims, rank, grid, p0, mode)


# --------------------------------------------------------------------------
# Shard_map feasibility (even shards)
# --------------------------------------------------------------------------

def shardable(
    dims: Sequence[int],
    rank: int,
    grid: Sequence[int],
    p0: int = 1,
) -> bool:
    """Whether the §V data distributions shard evenly on this grid.

    Delegates to :func:`repro.distributed.mesh.validate_grid` (minus the
    device-count check — selection may target more processors than this
    host exposes), so the selector and the mesh layer can never disagree
    about feasibility.
    """
    from .mesh import validate_grid  # local: mesh must not import back

    try:
        validate_grid(grid, p0, dims, rank, check_devices=False)
    except ValueError:
        return False
    return True


# --------------------------------------------------------------------------
# Branch-and-bound search
# --------------------------------------------------------------------------

def _search_separable(
    dims: Sequence[int],
    procs: int,
    term: Callable[[int, int], float],
    feasible: Callable[[tuple[int, ...]], bool] | None = None,
) -> tuple[float, tuple[int, ...]] | None:
    """The shared branch-and-bound: minimize ``sum_k term(k, p_k)`` over
    all ordered divisor tuples of ``procs`` with ``p_k <= dims[k]``.

    Every objective routed here (Eq 12 single-mode, the CP-ALS sweep sum,
    the Multi-TTM/Tucker sweep sum) is a per-axis-separable sum of
    nonnegative terms, so a partial sum >= the incumbent prunes the whole
    subtree.  ``feasible`` (if given) accepts/rejects complete grids
    (even-sharding restriction)."""
    n = len(dims)
    best: tuple[float, tuple[int, ...]] | None = None

    def recurse(
        k: int, remaining: int, partial: float, acc: list[int]
    ) -> None:
        nonlocal best
        if best is not None and partial >= best[0]:
            return  # every remaining term is >= 0
        if k == n - 1:
            if remaining > dims[k]:  # degenerate: empty processors
                return
            cand = tuple(acc + [remaining])
            if feasible is not None and not feasible(cand):
                return
            cost = partial + term(k, remaining)
            if best is None or (cost, cand) < best:
                best = (cost, cand)
            return
        for d in _divisors(remaining):
            if d > dims[k]:
                continue
            recurse(k + 1, remaining // d, partial + term(k, d), acc + [d])

    recurse(0, procs, 0.0, [])
    return best


def _search_stationary(
    dims: Sequence[int],
    rank: int,
    procs: int,
    mode: int | None,
    require_divisible: bool,
) -> GridChoice | None:
    """Minimize Eq (12) (``mode=k``) or the sweep objective (``mode=None``)
    over all N-way divisor tuples of ``procs``."""

    def term(k: int, pk: int) -> float:
        if mode is None:
            return _sweep_term(dims[k], pk, rank, procs)
        return _alg3_factor_words(dims[k], pk, rank, procs)

    feasible = (
        (lambda cand: shardable(dims, rank, cand))
        if require_divisible else None
    )
    best = _search_separable(dims, procs, term, feasible)
    if best is None:
        return None
    objective = "sweep" if mode is None else f"mode{mode}"
    return GridChoice(1, best[1], best[0], "stationary", objective)


def select_stationary_grid(
    dims: Sequence[int],
    rank: int,
    procs: int,
    mode: int | None = 0,
    require_divisible: bool = False,
) -> GridChoice | None:
    """The Eq (12)-optimal Alg-3 grid for ``procs`` processors.

    ``mode=None`` optimizes the CP-ALS sweep objective
    (:func:`stationary_sweep_words`); ``require_divisible`` restricts the
    search to grids whose §V distributions shard evenly (returns ``None``
    when no such grid exists for this processor count).
    """
    return _search_stationary(
        tuple(dims), rank, procs, mode, require_divisible
    )


def select_general_grid(
    dims: Sequence[int],
    rank: int,
    procs: int,
    mode: int = 0,
    require_divisible: bool = False,
) -> GridChoice | None:
    """The Eq (16)-optimal (P_0, grid) for Alg 4 (P_0 ≤ R, pruned search)."""
    dims = tuple(dims)
    n = len(dims)
    best: tuple[float, int, tuple[int, ...]] | None = None
    for p0 in _divisors(procs):
        if p0 > rank:
            continue
        rest = procs // p0
        base = (p0 - 1) * (math.prod(dims) / procs)  # tensor all-gather term

        def term(k: int, pk: int) -> float:
            slice_sz = procs / (p0 * pk)
            if slice_sz <= 1:
                return 0.0
            w = math.ceil(dims[k] / pk) * math.ceil(rank / p0) / slice_sz
            return (slice_sz - 1) * w

        def recurse(
            k: int, remaining: int, partial: float, acc: list[int]
        ) -> None:
            nonlocal best
            if best is not None and partial >= best[0]:
                return
            if k == n - 1:
                if remaining > dims[k]:  # degenerate: empty processors
                    return
                cand = acc + [remaining]
                if require_divisible and not shardable(
                    dims, rank, cand, p0
                ):
                    return
                cost = partial + term(k, remaining)
                if best is None or (cost, p0, tuple(cand)) < best:
                    best = (cost, p0, tuple(cand))
                return
            for d in _divisors(remaining):
                if d > dims[k]:
                    continue
                recurse(
                    k + 1, remaining // d, partial + term(k, d), acc + [d]
                )

        recurse(0, rest, base, [])
    if best is None:
        return None
    return GridChoice(best[1], best[2], best[0], "general", f"mode{mode}")


def select_grid(
    dims: Sequence[int],
    rank: int,
    procs: int,
    algorithm: str = "auto",
    mode: int | None = 0,
    require_divisible: bool = False,
) -> GridChoice:
    """Grid selection entry point.

    ``algorithm="stationary"`` / ``"general"`` force Alg 3 / Alg 4;
    ``"auto"`` returns whichever attains the lower modeled cost — the exact
    form of the paper's Cor 4.2 NR-threshold regime decision.  The sweep
    objective (``mode=None``) is stationary-only (Alg 4 moves the tensor,
    which a CP-ALS sweep never should per arXiv:1806.07985).
    """
    if algorithm not in ("auto", "stationary", "general"):
        raise ValueError(f"unknown algorithm {algorithm!r}")
    if mode is None and algorithm == "general":
        raise ValueError("the sweep objective is stationary-only (Alg 3)")
    stat = (
        select_stationary_grid(dims, rank, procs, mode, require_divisible)
        if algorithm in ("auto", "stationary")
        else None
    )
    gen = (
        select_general_grid(dims, rank, procs, mode, require_divisible)
        if algorithm in ("auto", "general") and mode is not None
        else None
    )
    candidates = [c for c in (stat, gen) if c is not None]
    if not candidates:
        raise ValueError(
            f"no feasible grid for dims={tuple(dims)}, P={procs}"
            + (" with even sharding" if require_divisible else "")
        )
    return min(candidates, key=lambda c: (c.words, c.algorithm))


def choose_cp_grid(
    dims: Sequence[int], rank: int, procs: int
) -> GridChoice:
    """Grid for the distributed CP-ALS sweep driver.

    Uses the largest processor count ≤ ``procs`` that admits an evenly-
    sharding grid (more processors shrink the per-processor tensor block —
    communication is secondary to using the machine), then the Eq (12)
    sweep-minimal grid among them.  Always succeeds: P=1 shards trivially.
    """
    for p in range(procs, 0, -1):
        choice = select_stationary_grid(
            dims, rank, p, mode=None, require_divisible=True
        )
        if choice is not None:
            return choice
    raise AssertionError("unreachable: P=1 always shards evenly")


# --------------------------------------------------------------------------
# Multi-TTM / Tucker (arXiv:2207.10437): sweep objective + grid selection
# --------------------------------------------------------------------------

def _rank_complement_products(ranks: Sequence[int]) -> list[int]:
    """R-bar_k = prod_{j != k} R_j for every mode."""
    total = math.prod(ranks)
    return [total // r for r in ranks]


def _tucker_term(d: int, pk: int, rbar: int, procs: int) -> float:
    """One mode's per-sweep words in the stationary-tensor Tucker/HOOI
    sweep (:mod:`repro.distributed.tucker_parallel`): the partial
    Y^(k) block-rows are all-reduced over the mode-k hyperslice
    (``2(q-1)/q * w`` with ``q = P/p_k``) and then all-gathered over the
    mode-k fiber (``(p_k-1) * w``), where ``w = ceil(I_k/p_k) * R-bar_k``
    is one processor's block of the kept-mode rows times the Kronecker
    rank of the other modes.  Factor matrices travel nowhere: the
    replicated eigenvector update leaves every processor holding all of
    A^(k), so there is no Eq-12-style gather term."""
    q = procs // pk
    w = math.ceil(d / pk) * rbar
    return (2 * (q - 1) / q + (pk - 1)) * w


def multi_ttm_sweep_words(
    dims: Sequence[int], ranks: Sequence[int], grid: Sequence[int]
) -> float:
    """Per-processor words of one Tucker/HOOI sweep (all N mode updates)
    on the stationary-tensor distribution — the Multi-TTM analog of
    :func:`stationary_sweep_words`, and the objective
    :func:`select_tucker_grid` minimizes.  Measured from compiled HLO in
    ``tests/dist_worker.py::check_tucker_sweep_comm_matches_model``."""
    procs = math.prod(grid)
    rbars = _rank_complement_products(ranks)
    total = 0.0
    for d, pk, rbar in zip(dims, grid, rbars):
        total += _tucker_term(d, pk, rbar, procs)
    return total


def tucker_shardable(dims: Sequence[int], grid: Sequence[int]) -> bool:
    """Whether the Tucker stationary distribution shards evenly
    (delegates to :func:`repro.distributed.mesh.validate_tucker_grid`,
    minus the device-count check)."""
    from .mesh import validate_tucker_grid  # local: mesh must not import back

    try:
        validate_tucker_grid(grid, dims, check_devices=False)
    except ValueError:
        return False
    return True


def select_tucker_grid(
    dims: Sequence[int],
    ranks: Sequence[int],
    procs: int,
    require_divisible: bool = False,
) -> GridChoice | None:
    """The grid minimizing the Multi-TTM sweep objective for ``procs``
    processors — the same branch-and-bound as the CP selectors, run over
    :func:`multi_ttm_sweep_words`'s per-axis terms."""
    dims = tuple(dims)
    ranks = tuple(ranks)
    rbars = _rank_complement_products(ranks)

    def term(k: int, pk: int) -> float:
        return _tucker_term(dims[k], pk, rbars[k], procs)

    feasible = (
        (lambda cand: tucker_shardable(dims, cand))
        if require_divisible else None
    )
    best = _search_separable(dims, procs, term, feasible)
    if best is None:
        return None
    return GridChoice(1, best[1], best[0], "tucker", "sweep")


def choose_tucker_grid(
    dims: Sequence[int], ranks: Sequence[int], procs: int
) -> GridChoice:
    """Grid for the distributed Tucker/HOOI sweep driver: the largest
    processor count ≤ ``procs`` admitting an evenly-sharding grid, then
    the sweep-minimal grid among them (the Multi-TTM mirror of
    :func:`choose_cp_grid`).  Always succeeds: P=1 shards trivially."""
    for p in range(procs, 0, -1):
        choice = select_tucker_grid(dims, ranks, p, require_divisible=True)
        if choice is not None:
            return choice
    raise AssertionError("unreachable: P=1 always shards evenly")


def brute_force_tucker(
    dims: Sequence[int],
    ranks: Sequence[int],
    procs: int,
    require_divisible: bool = False,
) -> GridChoice | None:
    """Exhaustive Multi-TTM sweep minimum over every ordered divisor
    tuple (test oracle for :func:`select_tucker_grid`; no pruning)."""
    best: tuple[float, tuple[int, ...]] | None = None
    for cand in _factorization_tuples(procs, len(dims)):
        if any(c > d for c, d in zip(cand, dims)):
            continue
        if require_divisible and not tucker_shardable(dims, cand):
            continue
        cost = multi_ttm_sweep_words(dims, ranks, cand)
        if best is None or (cost, cand) < best:
            best = (cost, cand)
    if best is None:
        return None
    return GridChoice(1, best[1], best[0], "tucker", "sweep")


# --------------------------------------------------------------------------
# Brute-force references (test oracles: no pruning, plain enumeration)
# --------------------------------------------------------------------------

def brute_force_stationary(
    dims: Sequence[int],
    rank: int,
    procs: int,
    mode: int | None = 0,
    require_divisible: bool = False,
) -> GridChoice | None:
    """Exhaustive Eq (12)/sweep minimum over every ordered divisor tuple."""
    best: tuple[float, tuple[int, ...]] | None = None
    for cand in _factorization_tuples(procs, len(dims)):
        if any(c > d for c, d in zip(cand, dims)):
            continue
        if require_divisible and not shardable(dims, rank, cand):
            continue
        cost = (
            stationary_sweep_words(dims, rank, cand)
            if mode is None
            else par_stationary_cost(dims, rank, cand, mode)
        )
        if best is None or (cost, cand) < best:
            best = (cost, cand)
    if best is None:
        return None
    objective = "sweep" if mode is None else f"mode{mode}"
    return GridChoice(1, best[1], best[0], "stationary", objective)


def brute_force_general(
    dims: Sequence[int],
    rank: int,
    procs: int,
    mode: int = 0,
    require_divisible: bool = False,
) -> GridChoice | None:
    """Exhaustive Eq (16) minimum over every (P_0 ≤ R, divisor tuple)."""
    best: tuple[float, int, tuple[int, ...]] | None = None
    for p0 in _divisors(procs):
        if p0 > rank:
            continue
        for cand in _factorization_tuples(procs // p0, len(dims)):
            if any(c > d for c, d in zip(cand, dims)):
                continue
            if require_divisible and not shardable(dims, rank, cand, p0):
                continue
            cost = par_general_cost(dims, rank, cand, p0, mode)
            if best is None or (cost, p0, cand) < best:
                best = (cost, p0, cand)
    if best is None:
        return None
    return GridChoice(best[1], best[2], best[0], "general", f"mode{mode}")
