"""Distributed CP-ALS: the stationary-tensor sweep driver.

The paper's parallel story (§V) analyzes one MTTKRP at a time; the workload
that matters (§II-A) is the CP-ALS sweep, where the tensor is needed in
*every* mode each iteration.  Ballard–Hayashi–Kannan (arXiv:1806.07985)
show the right organization: X stays stationary in the Alg-3 block
distribution for the whole decomposition, and factor communication
amortizes across the N per-mode updates.  This module implements that as
ONE shard_map program per sweep:

* X is block-distributed over the N-way grid and never moves.
* Each factor's gathered block-rows (the Alg-3 ``S^{(k)}_{p_k}``) are part
  of the carried state: they are produced by the all-gather right after
  that factor's update and *reused* by every subsequent mode update in this
  sweep and the next — so per sweep each factor is all-gathered exactly
  once and each MTTKRP output reduce-scattered exactly once (2 collectives
  per factor vs. N for independent per-mode Eq (12) calls).
* The Gram/Hadamard normal-equations solve runs on the sharded factors:
  Γ_n is the Hadamard product of carried R×R Grams (replicated), each
  processor solves its own block of rows, and the updated Gram is rebuilt
  from the gathered block-rows with a single R×R all-reduce over the
  P_n-processor mode-n fiber.  Column norms λ come from the Gram diagonal —
  no extra collective.
* The local MTTKRP inside each shard goes through
  :func:`repro.distributed.mttkrp_parallel.engine_local_fn`, so
  ``backend="pallas"`` runs the blocked VMEM kernels per shard and
  ``backend="auto"`` resolves the tune cache keyed by the *local shard*
  shapes.

Per-sweep communication is measured from compiled HLO in
``tests/dist_worker.py`` and checked to beat N independent
``mttkrp_stationary`` calls (the Eq (12) sum).
"""

from __future__ import annotations

import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..compat import shard_map
from ..core.cp_als import CPResult
from ..core.tensor import frob_norm, random_factors
from .grid_select import GridChoice, choose_cp_grid
from .mesh import (
    RANK_AXIS,
    hyperslice_axes,
    make_grid_mesh,
    mode_axis,
    validate_grid,
)
from .mttkrp_parallel import (
    LocalFn,
    engine_local_fn,
    factor_spec,
    gather_factor,
    tensor_spec,
)
from .ring import (
    arrival_source,
    ring_all_gather_parts,
    ring_assemble,
    ring_index,
    ring_reduce_scatter,
    ring_size,
)


def gathered_block_spec(k: int) -> P:
    """Spec of factor k's gathered block-rows: sharded by m{k}, replicated
    over the hyperslice (every processor of it holds S^{(k)}_{p_k})."""
    return P(mode_axis(k), None)


# --------------------------------------------------------------------------
# The per-processor sweep body
# --------------------------------------------------------------------------

def _sweep_local(
    x_loc: jax.Array,
    f_locs: tuple[jax.Array, ...],
    blocks: tuple[jax.Array, ...],
    grams: tuple[jax.Array, ...],
    normx: jax.Array,
    *,
    ndim: int,
    local_fn: LocalFn,
    compute_fit: bool,
    overlap: str = "none",
):
    """One full ALS sweep (all N mode updates) under shard_map.

    Carried state per factor k: the row shard (I_k/P rows), the gathered
    block S^{(k)}_{p_k} (I_k/P_k rows, replicated over the hyperslice), and
    the replicated Gram G_k = A_k^T A_k.  Mirrors ``core.cp_als.update``
    arithmetic exactly (same solve dtype, ridge, λ floor) so the
    distributed fits track the sequential driver to fp32 tolerance.

    ``overlap="ring"`` spells the two per-factor collectives as
    ``ppermute`` rings (:mod:`repro.distributed.ring`) and consumes factor
    ``mode-1``'s ring arrivals chunk-by-chunk inside mode ``mode``'s local
    MTTKRP: chunk t (from ring source ``(me - t) mod q``) multiplies the
    matching slice of ``x_loc`` along axis ``mode-1`` as soon as it lands,
    so each ring hop's transfer can hide behind one slice of compute.  The
    arrivals are pre-normalization (λ is not known until the Gram
    all-reduce completes, and waiting for it would re-serialize the ring),
    so the chunked MTTKRP runs on raw blocks and the result is rescaled by
    ``1/λ`` per column at the end — exact up to rounding, since the MTTKRP
    is linear in each factor column.  Total bytes are unchanged: same
    2-collectives-per-factor model, verified against compiled HLO in
    ``tests/dist_worker.py``.
    """
    ring = overlap == "ring"
    f_locs, blocks, grams = list(f_locs), list(blocks), list(grams)
    rank = f_locs[0].shape[-1]
    dtype = x_loc.dtype
    solve_dtype = jnp.float32 if dtype != jnp.float64 else dtype
    weights = jnp.ones((rank,), dtype)
    b_last = a_last = None
    pending = None  # ring arrivals of factor mode-1, consumed chunk-wise
    for mode in range(ndim):
        gamma = jnp.ones((rank, rank), grams[0].dtype)
        for k in range(ndim):
            if k != mode:
                gamma = gamma * grams[k]
        # MTTKRP: reuse the carried gathered blocks (no gathers here —
        # each was produced by the all-gather after its factor's update)
        if pending is not None:
            parts, lam_prev, q_prev, me_prev = pending
            pending = None
            prev = mode - 1
            w = x_loc.shape[prev] // q_prev
            c = None
            for t, part in enumerate(parts):
                src = arrival_source(me_prev, t, q_prev)
                x_sl = jax.lax.dynamic_slice_in_dim(
                    x_loc, src * w, w, axis=prev
                )
                mats = [
                    blocks[k] if k != mode else None for k in range(ndim)
                ]
                mats[prev] = part
                ct = local_fn(x_sl, mats, mode)
                c = ct if c is None else c + ct
            c = c / lam_prev
        else:
            c = local_fn(
                x_loc,
                [blocks[k] if k != mode else None for k in range(ndim)],
                mode,
            )
        if ring:
            b_loc = ring_reduce_scatter(c, hyperslice_axes(ndim, mode))
        else:
            b_loc = jax.lax.psum_scatter(
                c, hyperslice_axes(ndim, mode),
                scatter_dimension=0, tiled=True,
            )
        # normal-equations solve, rows local (Γ is replicated)
        gamma32 = gamma.astype(solve_dtype)
        ridge = 1e-5 * jnp.trace(gamma32) / rank + 1e-12
        a_loc = jnp.linalg.solve(
            gamma32 + ridge * jnp.eye(rank, dtype=solve_dtype),
            b_loc.astype(solve_dtype).T,
        ).T.astype(dtype)
        # the one all-gather of this factor for the sweep
        if ring:
            axes_g = hyperslice_axes(ndim, mode)
            parts = ring_all_gather_parts(a_loc, axes_g)
            blk = ring_assemble(parts, axes_g)
        else:
            blk = gather_factor(a_loc, ndim, mode)
        # full Gram from the gathered block-rows: one R x R all-reduce over
        # the mode-n fiber (q = P_n), the sweep's only solve collective
        g_raw = jax.lax.psum(blk.T @ blk, (mode_axis(mode),))
        lam = jnp.maximum(
            jnp.sqrt(jnp.maximum(jnp.diagonal(g_raw), 0.0)), 1e-30
        ).astype(dtype)
        a_loc = a_loc / lam
        blk = blk / lam
        grams[mode] = g_raw / (lam[:, None] * lam[None, :])
        f_locs[mode] = a_loc
        blocks[mode] = blk
        if ring and mode < ndim - 1:
            # hand the raw arrivals to mode+1's chunked MTTKRP; λ rides
            # along so the consumer can rescale without a ring barrier
            pending = (parts, lam, ring_size(axes_g), ring_index(axes_g))
        weights = lam
        b_last, a_last = b_loc, a_loc * lam
    if compute_fit:
        inner = jax.lax.psum(
            jnp.sum(b_last * a_last),
            tuple(mode_axis(k) for k in range(ndim)),
        )
        gram_full = jnp.ones((rank, rank), grams[0].dtype)
        for g in grams:
            gram_full = gram_full * g
        gram_full = gram_full * (weights[:, None] * weights[None, :])
        err_sq = jnp.maximum(
            normx**2 - 2 * inner + jnp.sum(gram_full), 0.0
        )
        fit = 1.0 - jnp.sqrt(err_sq) / jnp.maximum(normx, 1e-30)
    else:
        fit = jnp.zeros((), dtype)
    return tuple(f_locs), tuple(blocks), tuple(grams), weights, fit


# --------------------------------------------------------------------------
# Program construction and state placement
# --------------------------------------------------------------------------

def build_cp_sweep(
    mesh: jax.sharding.Mesh,
    ndim: int,
    *,
    ctx=None,
    backend=None,
    interpret=None,
    memory=None,
    local_fn: LocalFn | None = None,
    compute_fit: bool = True,
) -> Callable:
    """Compile-ready sweep: ``f(x, factors, blocks, grams, normx) ->
    (factors, blocks, grams, weights, fit)`` with every operand in the
    carried distributed state layout (see :func:`place_cp_state`)."""
    from ..engine.context import UNSET, context_from_legacy

    ctx = context_from_legacy(
        "repro.distributed.build_cp_sweep", ctx,
        {
            "backend": backend if backend is not None else UNSET,
            "interpret": interpret if interpret is not None else UNSET,
            "memory": memory if memory is not None else UNSET,
        },
    )
    if RANK_AXIS in mesh.axis_names:
        raise ValueError(
            "the CP-ALS sweep keeps X stationary (Algorithm 3); rank-axis "
            "(p0>1) meshes are for single-mode mttkrp_general"
        )
    if local_fn is None:
        local_fn = engine_local_fn(ctx)
    overlap = (
        ctx.distribution.overlap if ctx.distribution is not None else "none"
    )
    in_specs = (
        tensor_spec(ndim),
        tuple(factor_spec(ndim, k) for k in range(ndim)),
        tuple(gathered_block_spec(k) for k in range(ndim)),
        tuple(P(None, None) for _ in range(ndim)),
        P(),
    )
    out_specs = (
        in_specs[1],
        in_specs[2],
        in_specs[3],
        P(None),
        P(),
    )
    body = functools.partial(
        _sweep_local, ndim=ndim, local_fn=local_fn,
        compute_fit=compute_fit, overlap=overlap,
    )
    # check_rep=False: the body contains linalg.solve (no replication rule
    # on 0.4.x) and, under backend="pallas"/"auto", pallas_call
    return jax.jit(
        shard_map(
            body,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_rep=False,
        )
    )


def place_cp_state(
    mesh: jax.sharding.Mesh,
    x: jax.Array,
    factors: Sequence[jax.Array],
):
    """Device-put the sweep's carried state: X block-distributed (it never
    moves again), factor row shards, gathered block-rows (globally these
    are just the factors, sharded by m{k} only), and replicated Grams."""
    ndim = x.ndim
    xs = jax.device_put(x, NamedSharding(mesh, tensor_spec(ndim)))
    fs = tuple(
        jax.device_put(f, NamedSharding(mesh, factor_spec(ndim, k)))
        for k, f in enumerate(factors)
    )
    blocks = tuple(
        jax.device_put(f, NamedSharding(mesh, gathered_block_spec(k)))
        for k, f in enumerate(factors)
    )
    grams = tuple(
        jax.device_put(f.T @ f, NamedSharding(mesh, P(None, None)))
        for f in factors
    )
    return xs, fs, blocks, grams


# --------------------------------------------------------------------------
# The driver
# --------------------------------------------------------------------------

def cp_als_parallel(
    x: jax.Array,
    rank: int,
    n_iters: int = 20,
    *,
    key: jax.Array | None = None,
    init_factors: Sequence[jax.Array] | None = None,
    ctx=None,
    grid: Sequence[int] | None = None,
    mesh: jax.sharding.Mesh | None = None,
    procs: int | None = None,
    backend=None,
    interpret=None,
    memory=None,
    tol: float = 0.0,
    compute_fit: bool = True,
) -> CPResult:
    """Distributed CP-ALS with automatic grid selection.

    Grid resolution (all read from ``ctx.distribution``; the legacy
    ``grid``/``mesh``/``procs`` kwargs shim into one): an explicit
    ``mesh`` wins; else an explicit ``grid`` is validated against the
    tensor extents; else
    :func:`repro.distributed.grid_select.choose_cp_grid` picks the Eq (12)
    sweep-optimal evenly-sharding grid for ``procs`` (default: every
    available device).  Factors are returned in the same convention as
    :func:`repro.core.cp_als.cp_als` — column-normalized, with the scales
    in ``CPResult.weights`` (never folded in as well).
    """
    from dataclasses import replace

    from ..engine.context import (
        UNSET,
        Distribution,
        context_from_legacy,
    )

    ctx = context_from_legacy(
        "repro.distributed.cp_als_parallel", ctx,
        {
            "backend": backend if backend is not None else UNSET,
            "interpret": interpret if interpret is not None else UNSET,
            "memory": memory if memory is not None else UNSET,
            "grid": grid if grid is not None else UNSET,
            "mesh": mesh if mesh is not None else UNSET,
            "procs": procs if procs is not None else UNSET,
        },
    )
    if ctx.distribution is None:
        # this driver IS the distributed path; a plain context means
        # "select everything automatically" (re-validates, so tune=True
        # still fails loudly here)
        ctx = replace(ctx, distribution=Distribution())
    if ctx.distribution.p0 != 1:
        raise ValueError(
            "the CP-ALS sweep keeps X stationary (Algorithm 3); rank-axis "
            "(p0>1) contexts are for single-mode mttkrp_general"
        )
    ndim = x.ndim
    dist = ctx.distribution
    mesh = dist.mesh if dist is not None else None
    grid = dist.grid if dist is not None else None
    procs = dist.procs if dist is not None else None
    choice: GridChoice | None = None
    if mesh is None:
        if grid is None:
            procs = procs if procs is not None else len(jax.devices())
            choice = choose_cp_grid(x.shape, rank, procs)
            grid = choice.grid
        mesh = make_grid_mesh(grid, dims=x.shape, rank=rank)
    else:
        if RANK_AXIS in mesh.axis_names:
            raise ValueError(
                "cp_als_parallel keeps X stationary; pass a p0=1 grid mesh"
            )
        grid = tuple(
            mesh.shape[mode_axis(k)]
            for k in range(len([n for n in mesh.axis_names if n != RANK_AXIS]))
        )
        validate_grid(grid, dims=x.shape, rank=rank)
    if len(grid) != ndim:
        raise ValueError(f"grid {grid} is not {ndim}-way")

    if init_factors is not None:
        factors = [jnp.asarray(f) for f in init_factors]
    else:
        key = key if key is not None else jax.random.PRNGKey(0)
        factors = random_factors(key, x.shape, rank, x.dtype)
    normx = frob_norm(x)

    sweep = build_cp_sweep(
        mesh, ndim, ctx=ctx, compute_fit=compute_fit or tol > 0,
    )
    xs, fs, blocks, grams = place_cp_state(mesh, x, factors)
    normx_dev = jax.device_put(normx, NamedSharding(mesh, P()))

    from ..observe import trace as _otrace

    if _otrace.should_record(ctx.observe):
        # Driver level (outside the shard_map program): lower the sweep
        # once more and walk its HLO for the actual collective bytes, so
        # the trace carries a measured/modeled pair per the §V-C3 model.
        from ..observe.metrics import SWEEP_COLLECTIVE_BYTES, registry
        from .grid_select import stationary_sweep_words
        from .hlo import parse_collectives

        nproc = int(np.prod(grid))
        text = (
            sweep.lower(xs, fs, blocks, grams, normx_dev)
            .compile().as_text()
        )
        summ = parse_collectives(text)
        itemsize = int(x.dtype.itemsize)
        modeled = int(stationary_sweep_words(x.shape, rank, grid))
        fit_term = (
            int(2 * (nproc - 1) / nproc * itemsize)
            if (compute_fit or tol > 0) else 0
        )
        registry().observe(SWEEP_COLLECTIVE_BYTES, float(summ.ring_bytes))
        _otrace.record_event(
            "cp_sweep_collectives",
            shape=list(x.shape),
            rank=int(rank),
            grid=list(grid),
            procs=nproc,
            itemsize=itemsize,
            overlap=ctx.distribution.overlap,
            measured_collective_bytes=int(summ.ring_bytes),
            modeled_words=modeled,
            modeled_bytes=modeled * itemsize,
            fit_allreduce_bytes=fit_term,
            collectives_by_kind={
                k: v for k, v in summ.by_kind().items()
            },
        )

    fits: list[float] = []
    weights = jnp.ones((rank,), x.dtype)
    for it in range(n_iters):
        fs, blocks, grams, weights, fit = sweep(
            xs, fs, blocks, grams, normx_dev
        )
        if compute_fit or tol > 0:
            fits.append(float(fit))
        if tol and it > 0 and abs(fits[-1] - fits[-2]) < tol:
            break
    out_factors = [jnp.asarray(np.asarray(f)) for f in fs]
    return CPResult(out_factors, jnp.asarray(np.asarray(weights)), fits)
