"""Ring-chunked collectives: ``ppermute`` spellings of the sweep's
all-gather and reduce-scatter with *identical* ring traffic.

Why: in the stationary CP sweep every factor's all-gather serializes
against the next mode's local MTTKRP — XLA sees one monolithic collective
whose full result the next contraction consumes. Re-spelling the gather
as its own ring (q-1 ``ppermute`` steps, each moving one shard-chunk)
exposes the per-chunk dataflow: ring step t's transfer depends only on
step t-1, and a consumer that contracts chunk t as it arrives (see
``cp_als_parallel._sweep_local``'s ``overlap="ring"`` path) lets the
compiler hide each hop behind a slice of compute.

Traffic is preserved EXACTLY: an all-gather of an ``n``-word shard over
``q`` processors costs ``(q-1) * n`` words on a ring, and so do the
``q-1`` permutes of one ``n``-word chunk here; a reduce-scatter of a
``q*n``-word operand costs ``(q-1) * n``, ditto. ``tests/dist_worker.py``
pins the compiled-HLO byte counts of the ring sweep to the same
``stationary_sweep_words`` model as the monolithic one, and
``repro.verify.comm`` re-proves it statically from the jaxpr.

Linearization: multi-axis rings run over the listed mesh axes in
row-major order (first listed outermost) — the same flattening
``jax.lax.all_gather(..., tiled=True)`` and ``psum_scatter`` use, so the
assembled results are bit-compatible orderings (sums differ only in
association).

The *schedule itself is data*: :func:`ring_perm`,
:func:`arrival_source`, and :func:`reduce_chunk_index` are pure integer
functions shared by the runtime collectives below, by the overlap
consumers in ``cp_als_parallel``/``tucker_parallel``, and by the static
ring-schedule verifier (``repro.verify.comm``) — so the verifier checks
the exact arithmetic the runtime executes, not a parallel model of it.
"""

from __future__ import annotations

from typing import Sequence, Union

import jax
import jax.numpy as jnp

#: A ring position / step index: a Python int in the static verifier,
#: a traced ``jax.Array`` inside a shard_map body.
Index = Union[int, jax.Array]

AxesLike = Union[str, Sequence[str]]


def _as_axes(axes: AxesLike) -> tuple[str, ...]:
    return (axes,) if isinstance(axes, str) else tuple(axes)


def ring_size(axes: AxesLike) -> int:
    """Number of processors on the (possibly multi-axis) ring."""
    return jax.lax.psum(1, _as_axes(axes))


def ring_index(axes: AxesLike) -> jax.Array:
    """This processor's linearized position on the ring (row-major over
    the listed axes, first axis outermost — the ``tiled=True`` order)."""
    idx = None
    for name in _as_axes(axes):
        i = jax.lax.axis_index(name)
        idx = i if idx is None else idx * jax.lax.psum(1, name) + i
    assert idx is not None
    return idx


def ring_perm(q: int) -> list[tuple[int, int]]:
    """The forward-ring ``ppermute`` permutation: shard ``i`` sends to
    ``i+1 mod q`` (equivalently, shard ``j`` receives from ``j-1``).
    A single q-cycle, so every step is deadlock-free and conflict-free —
    :func:`repro.verify.comm.check_ring_permutation` proves it."""
    return [(i, (i + 1) % q) for i in range(q)]


def arrival_source(me: Index, t: Index, q: int) -> Index:
    """Ring source of the chunk that *arrives at step t* on processor
    ``me`` under :func:`ring_perm`: ``(me - t) mod q``.

    Step 0 is the local shard; each later step shifts the provenance one
    hop upstream. Both the runtime consumers and the static verifier
    index arrivals through this function.
    """
    return (me - t) % q


def reduce_chunk_index(me: Index, t: Index, q: int) -> Index:
    """Local chunk folded into the accumulator at reduce-scatter step
    ``t`` on processor ``me``: ``(me - t - 1) mod q`` — the block
    destined ``t+1`` hops downstream. Step 0 is the accumulator seed
    (no ppermute yet); steps 1..q-1 each follow one hop."""
    return (me - t - 1) % q


def ring_all_gather_parts(x: jax.Array, axes: AxesLike) -> list[jax.Array]:
    """The raw ring schedule: ``q`` chunks, where ``parts[t]`` is the chunk
    that *arrives at step t* — from ring source ``arrival_source(me, t, q)``
    (``parts[0]`` is this processor's own shard). Exposed so a consumer
    can contract each chunk as it lands; total transfer is ``(q-1)``
    chunk-hops, the exact ring all-gather volume."""
    axes = _as_axes(axes)
    q = ring_size(axes)
    parts = [x]
    if q == 1:
        return parts
    perm = ring_perm(q)
    acc = x
    for _ in range(1, q):
        acc = jax.lax.ppermute(acc, axes, perm)
        parts.append(acc)
    return parts


def ring_assemble(parts: Sequence[jax.Array], axes: AxesLike) -> jax.Array:
    """Order ring arrivals into the ``all_gather(..., axis=0, tiled=True)``
    layout. Arrival t came from source ``(me - t) mod q``; reversing the
    stack puts block u at source ``(me + 1 + u) mod q``, and rolling by
    ``me + 1`` blocks lands every source at its own index."""
    q = len(parts)
    if q == 1:
        return parts[0]
    me = ring_index(axes)
    rows = parts[0].shape[0]
    stacked = jnp.concatenate(list(parts)[::-1], axis=0)
    return jnp.roll(stacked, shift=(me + 1) * rows, axis=0)


def ring_all_gather(x: jax.Array, axes: AxesLike) -> jax.Array:
    """Drop-in for ``jax.lax.all_gather(x, axes, axis=0, tiled=True)`` as
    a ``ppermute`` ring: same result, same ring traffic, chunked
    dataflow."""
    return ring_assemble(ring_all_gather_parts(x, axes), axes)


def ring_reduce_scatter(c: jax.Array, axes: AxesLike) -> jax.Array:
    """Drop-in for ``jax.lax.psum_scatter(c, axes, scatter_dimension=0,
    tiled=True)`` as a ``ppermute`` ring.

    Each step forwards a partial sum one hop and folds in the local chunk
    :func:`reduce_chunk_index` selects; after ``q-1`` steps processor
    ``j`` holds block ``j`` fully summed. ``q-1`` hops of one output-sized
    chunk — the exact ring reduce-scatter volume. Summation order differs
    from ``psum_scatter`` (ring association), so results match to
    floating-point tolerance, not bitwise."""
    axes = _as_axes(axes)
    q = ring_size(axes)
    if q == 1:
        return c
    me = ring_index(axes)
    rows = c.shape[0] // q

    def chunk(i: Index) -> jax.Array:
        return jax.lax.dynamic_slice_in_dim(c, i * rows, rows, axis=0)

    perm = ring_perm(q)
    acc = chunk(reduce_chunk_index(me, 0, q))
    for t in range(1, q):
        acc = jax.lax.ppermute(acc, axes, perm)
        acc = acc + chunk(reduce_chunk_index(me, t, q))
    return acc
