"""Distributed Multi-TTM and the Tucker/HOOI sweep driver.

The Multi-TTM story (arXiv:2207.10437) parallelizes on the same
stationary-tensor distribution as Algorithm 3: X is block-distributed
over the N-way grid and never moves.  Two shard_map programs live here:

* :func:`multi_ttm_stationary` — one full-core Multi-TTM: matrices in
  the CP factor layout (block-rows spread over the mode hyperslices),
  gathered exactly like Alg 3's factors, then the local partial core is
  all-reduced.  Per-processor volume
  :func:`repro.core.bounds.par_multi_ttm_cost`, measured from compiled
  HLO in ``tests/dist_worker.py::check_multi_ttm_comm_matches_model``.

* :func:`build_tucker_sweep` — ONE shard_map program per HOOI sweep.
  Factor matrices are carried *replicated* (they are tall-skinny
  ``I_k x R_k``): each processor slices its own block-rows locally, runs
  the local Multi-TTM through the engine
  (:func:`repro.engine.execute.multi_ttm` — so ``backend="pallas"``
  runs the blocked Kronecker kernel per shard), all-reduces the partial
  ``Y^(k)`` block-rows over the mode-k hyperslice, all-gathers them over
  the mode-k fiber, and updates ``A_k`` by a replicated eigendecomposition
  — after which every processor again holds all of ``A_k``, so factors
  never travel in a collective at all.  Per-sweep volume
  :func:`repro.distributed.grid_select.multi_ttm_sweep_words`, measured
  in ``check_tucker_sweep_comm_matches_model``.
"""

from __future__ import annotations

import functools
import math
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..compat import shard_map
from ..core.tensor import frob_norm
from ..core.tucker import (
    TuckerResult,
    _check_ranks,
    _leading_eigvecs,
    _unfold_rows,
    hosvd_init,
)
from .grid_select import GridChoice, choose_tucker_grid
from .mesh import (
    RANK_AXIS,
    hyperslice_axes,
    make_grid_mesh,
    mode_axis,
    validate_tucker_grid,
)
from .mttkrp_parallel import factor_spec, gather_factors, tensor_spec
from .ring import ring_all_gather


def _engine_multi_ttm(ctx) -> Callable:
    """The per-shard Multi-TTM through the engine (same separation of
    concerns as ``engine_local_fn``: the programs here own the
    collectives; inside each shard the problem is exactly sequential)."""
    from ..engine import execute as engine_execute  # call-time: layer cycle
    from ..engine.context import ExecutionContext

    if ctx is None:
        ctx = ExecutionContext.default()
    local_ctx = ctx.local()

    def fn(x_loc, mats, keep):
        return engine_execute.multi_ttm(x_loc, mats, keep, ctx=local_ctx)

    return fn


# --------------------------------------------------------------------------
# One full-core Multi-TTM (matrices in the Alg-3 factor layout)
# --------------------------------------------------------------------------

def _multi_ttm_local(
    x_loc: jax.Array,
    m_locs: tuple[jax.Array, ...],
    *,
    ndim: int,
    local_fn: Callable,
) -> jax.Array:
    """Per-processor body: gather every matrix's block-rows over its mode
    hyperslice (exactly Alg 3 line 4), contract locally, all-reduce the
    partial core over the whole grid."""
    gathered = gather_factors(list(m_locs), ndim)
    core_part = local_fn(x_loc, gathered, None)
    return jax.lax.psum(
        core_part, tuple(mode_axis(k) for k in range(ndim))
    )


def multi_ttm_stationary(
    mesh: jax.sharding.Mesh,
    ndim: int,
    *,
    ctx=None,
):
    """Build the stationary-tensor full-core Multi-TTM shard_map callable
    ``f(x, *matrices) -> core`` (core replicated on every processor).

    X is block-distributed and never moves; matrices use the CP factor
    layout (:func:`repro.distributed.mttkrp_parallel.factor_spec`), so
    the gather terms are the Eq-12-shaped ones of
    :func:`repro.core.bounds.par_multi_ttm_cost`, plus one all-reduce of
    the ``prod R_k`` partial core.
    """
    local_fn = _engine_multi_ttm(ctx)
    in_specs = (tensor_spec(ndim),) + tuple(
        factor_spec(ndim, k) for k in range(ndim)
    )
    fn = functools.partial(_multi_ttm_local, ndim=ndim, local_fn=local_fn)

    def wrapper(x, *m_locs):
        return fn(x, m_locs)

    return jax.jit(
        shard_map(
            wrapper,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=P(*([None] * ndim)),
            check_rep=False,
        )
    )


def place_multi_ttm_inputs(
    mesh: jax.sharding.Mesh,
    x: jax.Array,
    matrices: Sequence[jax.Array],
):
    """Device-put X and the matrices into the stationary distribution."""
    ndim = x.ndim
    xs = jax.device_put(x, NamedSharding(mesh, tensor_spec(ndim)))
    ms = tuple(
        jax.device_put(m, NamedSharding(mesh, factor_spec(ndim, k)))
        for k, m in enumerate(matrices)
    )
    return xs, ms


# --------------------------------------------------------------------------
# The HOOI sweep (one shard_map program per sweep)
# --------------------------------------------------------------------------

def _local_rows(f_full: jax.Array, j: int, pj: int) -> jax.Array:
    """This processor's block-rows of the replicated factor j."""
    rows = f_full.shape[0] // pj
    start = jax.lax.axis_index(mode_axis(j)) * rows
    return jax.lax.dynamic_slice_in_dim(f_full, start, rows, axis=0)


def _tucker_sweep_local(
    x_loc: jax.Array,
    factors: tuple[jax.Array, ...],
    normx: jax.Array,
    *,
    ndim: int,
    ranks: tuple[int, ...],
    grid: tuple[int, ...],
    local_fn: Callable,
    compute_fit: bool,
    overlap: str = "none",
):
    """One full HOOI sweep (all N mode updates) under shard_map; factors
    are replicated, X stays put, and the only collectives are one
    hyperslice all-reduce + one fiber all-gather of the partial Y^(k)
    per mode (see :func:`multi_ttm_sweep_words`).

    ``overlap="ring"`` spells the fiber all-gather as a ``ppermute`` ring
    (:func:`repro.distributed.ring.ring_all_gather`) — same result, same
    ring bytes, but the transfer is exposed as ``P_k - 1`` chunk hops the
    scheduler can interleave with the eigendecomposition's Gram build.
    """
    factors = list(factors)
    dtype = x_loc.dtype
    zm = None
    for k in range(ndim):
        mats = [
            None if j == k else _local_rows(factors[j], j, grid[j])
            for j in range(ndim)
        ]
        z_part = local_fn(x_loc, mats, k)
        z_rows = jax.lax.psum(z_part, hyperslice_axes(ndim, k))
        zm_rows = _unfold_rows(z_rows, k)
        if overlap == "ring":
            zm = ring_all_gather(zm_rows, (mode_axis(k),))
        else:
            zm = jax.lax.all_gather(
                zm_rows, (mode_axis(k),), axis=0, tiled=True
            )
        factors[k] = _leading_eigvecs(zm @ zm.T, ranks[k]).astype(dtype)
    # the core falls out of the last mode update (mode N-1 rows of zm):
    # (R_{N-1}, prod_{j<N-1} R_j) -> (R_0, ..., R_{N-1})
    core_mat = factors[ndim - 1].T.astype(jnp.float32) @ zm.astype(jnp.float32)
    core = jnp.moveaxis(
        core_mat.reshape((ranks[ndim - 1],) + ranks[: ndim - 1]), 0,
        ndim - 1,
    ).astype(dtype)
    if compute_fit:
        err_sq = jnp.maximum(normx**2 - frob_norm(core) ** 2, 0.0)
        fit = 1.0 - jnp.sqrt(err_sq) / jnp.maximum(normx, 1e-30)
    else:
        fit = jnp.zeros((), dtype)
    return tuple(factors), core, fit


def build_tucker_sweep(
    mesh: jax.sharding.Mesh,
    ndim: int,
    ranks: Sequence[int],
    *,
    ctx=None,
    compute_fit: bool = True,
) -> Callable:
    """Compile-ready HOOI sweep: ``f(x, factors, normx) -> (factors,
    core, fit)`` with X block-distributed (:func:`place_tucker_state`)
    and the factors/core replicated."""
    ranks = tuple(int(r) for r in ranks)
    grid = tuple(
        mesh.shape[mode_axis(k)] for k in range(ndim)
    )
    local_fn = _engine_multi_ttm(ctx)
    overlap = "none"
    if ctx is not None and getattr(ctx, "distribution", None) is not None:
        overlap = ctx.distribution.overlap
    in_specs = (
        tensor_spec(ndim),
        tuple(P(None, None) for _ in range(ndim)),
        P(),
    )
    out_specs = (
        in_specs[1],
        P(*([None] * ndim)),
        P(),
    )
    body = functools.partial(
        _tucker_sweep_local, ndim=ndim, ranks=ranks, grid=grid,
        local_fn=local_fn, compute_fit=compute_fit, overlap=overlap,
    )
    # check_rep=False: the body contains eigh (no replication rule) and,
    # under backend="pallas"/"auto", pallas_call
    return jax.jit(
        shard_map(
            body,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_rep=False,
        )
    )


def place_tucker_state(
    mesh: jax.sharding.Mesh,
    x: jax.Array,
    factors: Sequence[jax.Array],
):
    """Device-put the sweep's carried state: X block-distributed (it
    never moves again) and the factors replicated."""
    ndim = x.ndim
    xs = jax.device_put(x, NamedSharding(mesh, tensor_spec(ndim)))
    fs = tuple(
        jax.device_put(f, NamedSharding(mesh, P(None, None)))
        for f in factors
    )
    return xs, fs


# --------------------------------------------------------------------------
# The driver
# --------------------------------------------------------------------------

def tucker_hooi_parallel(
    x: jax.Array,
    ranks: Sequence[int],
    n_iters: int = 10,
    *,
    ctx=None,
    init_factors: Sequence[jax.Array] | None = None,
    grid: Sequence[int] | None = None,
    mesh: jax.sharding.Mesh | None = None,
    procs: int | None = None,
    tol: float = 0.0,
    compute_fit: bool = True,
) -> TuckerResult:
    """Distributed Tucker/HOOI with automatic grid selection.

    Grid resolution (all read from ``ctx.distribution``; explicit
    ``grid``/``mesh``/``procs`` arguments override): an explicit ``mesh``
    wins; else an explicit ``grid`` is validated against the tensor
    extents; else
    :func:`repro.distributed.grid_select.choose_tucker_grid` picks the
    Multi-TTM-sweep-optimal evenly-sharding grid for ``procs`` (default:
    every available device).  Factors are returned orthonormal, the core
    replicated — the same convention as
    :func:`repro.core.tucker.tucker_hooi`.
    """
    from dataclasses import replace

    from ..engine.context import Distribution, ExecutionContext

    if ctx is None:
        ctx = ExecutionContext.default()
    if ctx.distribution is None:
        # this driver IS the distributed path; a plain context means
        # "select everything automatically" (re-validates, so tune=True
        # still fails loudly here)
        ctx = replace(ctx, distribution=Distribution())
    if ctx.distribution.p0 != 1:
        raise ValueError(
            "the Tucker sweep keeps X stationary on an N-way grid; "
            "rank-axis (p0>1) contexts are for single-mode mttkrp_general"
        )
    ndim = x.ndim
    ranks = _check_ranks(x.shape, ranks)
    dist = ctx.distribution
    mesh = mesh if mesh is not None else dist.mesh
    grid = tuple(grid) if grid is not None else dist.grid
    procs = procs if procs is not None else dist.procs
    choice: GridChoice | None = None
    if mesh is None:
        if grid is None:
            procs = procs if procs is not None else len(jax.devices())
            choice = choose_tucker_grid(x.shape, ranks, procs)
            grid = choice.grid
        validate_tucker_grid(grid, dims=x.shape)
        mesh = make_grid_mesh(grid)
    else:
        if RANK_AXIS in mesh.axis_names:
            raise ValueError(
                "tucker_hooi_parallel keeps X stationary; pass a p0=1 "
                "grid mesh"
            )
        grid = tuple(
            mesh.shape[mode_axis(k)] for k in range(len(mesh.axis_names))
        )
        validate_tucker_grid(grid, dims=x.shape)
    if len(grid) != ndim:
        raise ValueError(f"grid {grid} is not {ndim}-way")
    if math.prod(grid) > 1 and any(
        x.shape[k] % g for k, g in enumerate(grid)
    ):  # pragma: no cover - validate_tucker_grid already rejects
        raise ValueError(f"grid {grid} does not shard {x.shape} evenly")

    if init_factors is not None:
        factors = [jnp.asarray(f) for f in init_factors]
    else:
        factors = hosvd_init(x, ranks)
    if n_iters < 1:  # HOSVD only: no sweep program to run
        from ..core.tucker import tucker_hooi

        return tucker_hooi(
            x, ranks, 0, ctx=ctx.local(), init_factors=factors
        )
    normx = frob_norm(x)

    sweep = build_tucker_sweep(
        mesh, ndim, ranks, ctx=ctx, compute_fit=compute_fit or tol > 0,
    )
    xs, fs = place_tucker_state(mesh, x, factors)
    normx_dev = jax.device_put(normx, NamedSharding(mesh, P()))

    from ..observe import trace as _otrace

    if _otrace.should_record(ctx.observe):
        # Driver level: lower the sweep once more and walk its HLO for the
        # actual collective bytes next to the Multi-TTM sweep model.
        from ..observe.metrics import SWEEP_COLLECTIVE_BYTES, registry
        from .grid_select import multi_ttm_sweep_words
        from .hlo import parse_collectives

        nproc = int(math.prod(grid))
        text = sweep.lower(xs, fs, normx_dev).compile().as_text()
        summ = parse_collectives(text)
        itemsize = int(x.dtype.itemsize)
        modeled = int(multi_ttm_sweep_words(x.shape, ranks, grid))
        registry().observe(SWEEP_COLLECTIVE_BYTES, float(summ.ring_bytes))
        _otrace.record_event(
            "tucker_sweep_collectives",
            shape=list(x.shape),
            ranks=list(ranks),
            grid=list(grid),
            procs=nproc,
            itemsize=itemsize,
            measured_collective_bytes=int(summ.ring_bytes),
            modeled_words=modeled,
            modeled_bytes=modeled * itemsize,
            collectives_by_kind={
                k: v for k, v in summ.by_kind().items()
            },
        )

    fits: list[float] = []
    core = None
    for it in range(n_iters):
        fs, core, fit = sweep(xs, fs, normx_dev)
        if compute_fit or tol > 0:
            fits.append(float(fit))
        if tol and it > 0 and abs(fits[-1] - fits[-2]) < tol:
            break
    out_factors = [jnp.asarray(np.asarray(f)) for f in fs]
    return TuckerResult(jnp.asarray(np.asarray(core)), out_factors, fits)
