"""Logical processor grids for the parallel MTTKRP algorithms.

The paper organizes P processors as an N-way grid (Alg 3) or (N+1)-way grid
with a leading rank axis P_0 (Alg 4). Mode-k axes are named ``m0..m{N-1}``;
the rank axis is ``r``. A mode-k *hyperslice* (the paper's
``procs(:, ..., :, p_k, :, ..., :)``) is the set of all axes except ``m{k}``
(and except ``r`` for Alg 4 — factor gathers never cross the rank axis).
"""

from __future__ import annotations

import math
from typing import Sequence

import jax

from ..compat import make_abstract_mesh, make_mesh

#: The Alg-4 rank-axis name. Every module in ``distributed/`` must spell
#: the axis through this constant (and mode axes through
#: :func:`mode_axis`) — lint rule RV108 flags hard-coded literals, so
#: renaming an axis is a one-line change here, not a grep.
RANK_AXIS = "r"


def mode_axis(k: int) -> str:
    return f"m{k}"


def validate_grid(
    grid: Sequence[int],
    p0: int = 1,
    dims: Sequence[int] | None = None,
    rank: int | None = None,
    check_devices: bool = True,
) -> None:
    """Eagerly reject infeasible grids with actionable messages.

    Checks the grid itself (positive integer axes, P_0·ΠP_k within the
    available device count unless ``check_devices=False`` — grid *selection*
    may target more processors than this host exposes) and — when
    ``dims``/``rank`` are given — the even-sharding requirements of the §V
    data distributions: ``P_k | I_k`` (X's block distribution),
    ``(P/P_0) | I_k`` (factor rows spread over every grid axis per
    ``row_sharding_axes``), and for Alg 4 ``P_0 | R`` plus
    ``P_0·P_1 | I_1`` (X's mode-0 split across the rank axis).  This is
    the single source of feasibility: ``grid_select.shardable`` delegates
    here.
    """
    grid = tuple(grid)
    if not grid or any(g < 1 or g != int(g) for g in grid):
        raise ValueError(
            f"grid must be a non-empty tuple of positive ints, got {grid}"
        )
    if p0 < 1:
        raise ValueError(f"p0 must be >= 1, got {p0}")
    if p0 > 1 and rank is not None and rank % p0:
        raise ValueError(f"rank axis p0={p0} does not divide R={rank}")
    if dims is not None:
        dims = tuple(dims)
        if len(dims) != len(grid):
            raise ValueError(
                f"grid {grid} is {len(grid)}-way but the tensor is "
                f"{len(dims)}-way ({dims})"
            )
        mode_procs = math.prod(grid)
        for k, (d, pk) in enumerate(zip(dims, grid)):
            if d % pk:
                raise ValueError(
                    f"grid axis m{k}={pk} does not divide tensor extent "
                    f"I_{k}={d}: X cannot be block-distributed evenly"
                )
            if d % mode_procs:
                raise ValueError(
                    f"factor {k} rows (I_{k}={d}) are spread over all "
                    f"{mode_procs} grid processors but {mode_procs} does "
                    f"not divide {d}: uneven factor shards"
                )
        if p0 > 1:
            if dims[0] % (p0 * grid[0]):
                raise ValueError(
                    f"Alg 4 splits mode 0 across (r, m0) = "
                    f"{p0}x{grid[0]} but {p0 * grid[0]} does not divide "
                    f"I_0={dims[0]}"
                )
    if check_devices:
        total = p0 * math.prod(grid)
        ndev = len(jax.devices())
        if total > ndev:
            raise ValueError(
                f"grid {grid} with p0={p0} needs {total} devices but only "
                f"{ndev} are available (set "
                f"--xla_force_host_platform_device_count or shrink the "
                f"grid)"
            )


def validate_tucker_grid(
    grid: Sequence[int],
    dims: Sequence[int] | None = None,
    check_devices: bool = True,
) -> None:
    """Feasibility of the Tucker/Multi-TTM stationary distribution.

    The Tucker sweep keeps X block-distributed over the N-way grid (so
    ``P_k | I_k`` for even tensor shards) but carries the *factors
    replicated* (they are tall-skinny ``I_k x R_k``; each shard slices
    its own block rows locally), so the CP driver's factor-row-spreading
    divisibility constraints do not apply.  This is the single source of
    feasibility for ``grid_select.tucker_shardable``.
    """
    grid = tuple(grid)
    if not grid or any(g < 1 or g != int(g) for g in grid):
        raise ValueError(
            f"grid must be a non-empty tuple of positive ints, got {grid}"
        )
    if dims is not None:
        dims = tuple(dims)
        if len(dims) != len(grid):
            raise ValueError(
                f"grid {grid} is {len(grid)}-way but the tensor is "
                f"{len(dims)}-way ({dims})"
            )
        for k, (d, pk) in enumerate(zip(dims, grid)):
            if d % pk:
                raise ValueError(
                    f"grid axis m{k}={pk} does not divide tensor extent "
                    f"I_{k}={d}: X cannot be block-distributed evenly"
                )
    if check_devices:
        total = math.prod(grid)
        ndev = len(jax.devices())
        if total > ndev:
            raise ValueError(
                f"grid {grid} needs {total} devices but only {ndev} are "
                f"available (set --xla_force_host_platform_device_count "
                f"or shrink the grid)"
            )


def make_grid_mesh(
    grid: Sequence[int],
    p0: int = 1,
    dims: Sequence[int] | None = None,
    rank: int | None = None,
) -> jax.sharding.Mesh:
    """Mesh for Alg 3 (p0=1) or Alg 4 (p0>1): axes ('r',) m0, ..., m{N-1}.

    Validates eagerly (see :func:`validate_grid`); pass the tensor ``dims``
    (and ``rank`` for Alg 4) to also check the even-sharding requirements
    before any shard_map trace produces an opaque error.
    """
    validate_grid(grid, p0, dims, rank)
    shape = tuple(grid) if p0 == 1 else (p0,) + tuple(grid)
    names = tuple(mode_axis(k) for k in range(len(grid)))
    if p0 != 1:
        names = (RANK_AXIS,) + names
    return make_mesh(shape, names)


def make_abstract_grid_mesh(grid: Sequence[int], p0: int = 1):
    """Device-free twin of :func:`make_grid_mesh`: same axis names and
    sizes as a :class:`jax.sharding.AbstractMesh`.

    Skips the device-count check (there are no devices — that is the
    point): the static verifier (``repro.verify.comm``) traces the
    shard_map sweeps over grids far larger than the host exposes, and
    only ever inspects the jaxpr.
    """
    validate_grid(grid, p0, check_devices=False)
    shape = tuple(grid) if p0 == 1 else (p0,) + tuple(grid)
    names = tuple(mode_axis(k) for k in range(len(grid)))
    if p0 != 1:
        names = (RANK_AXIS,) + names
    return make_abstract_mesh(shape, names)


def hyperslice_axes(ndim: int, k: int) -> tuple[str, ...]:
    """Axes of the mode-k hyperslice: every mode axis except m{k}.

    The gather/reduce-scatter collectives of Alg 3/4 run over these axes;
    the rank axis never participates (factors are partitioned, not
    replicated, along r).
    """
    return tuple(mode_axis(j) for j in range(ndim) if j != k)


def row_sharding_axes(ndim: int, k: int) -> tuple[str, ...]:
    """PartitionSpec axes for factor k's rows: split by m{k} first (the
    paper's S^{(k)}_{p_k} block-rows), then spread across the hyperslice."""
    return (mode_axis(k),) + hyperslice_axes(ndim, k)
