"""Logical processor grids for the parallel MTTKRP algorithms.

The paper organizes P processors as an N-way grid (Alg 3) or (N+1)-way grid
with a leading rank axis P_0 (Alg 4). Mode-k axes are named ``m0..m{N-1}``;
the rank axis is ``r``. A mode-k *hyperslice* (the paper's
``procs(:, ..., :, p_k, :, ..., :)``) is the set of all axes except ``m{k}``
(and except ``r`` for Alg 4 — factor gathers never cross the rank axis).
"""

from __future__ import annotations

from typing import Sequence

import jax

from ..compat import make_mesh


def mode_axis(k: int) -> str:
    return f"m{k}"


def make_grid_mesh(grid: Sequence[int], p0: int = 1) -> jax.sharding.Mesh:
    """Mesh for Alg 3 (p0=1) or Alg 4 (p0>1): axes ('r',) m0, ..., m{N-1}."""
    shape = tuple(grid) if p0 == 1 else (p0,) + tuple(grid)
    names = tuple(mode_axis(k) for k in range(len(grid)))
    if p0 != 1:
        names = ("r",) + names
    return make_mesh(shape, names)


def hyperslice_axes(ndim: int, k: int, with_rank_axis: bool = False) -> tuple[str, ...]:
    """Axes of the mode-k hyperslice: every mode axis except m{k}.

    The gather/reduce-scatter collectives of Alg 3/4 run over these axes;
    the rank axis never participates (factors are partitioned, not
    replicated, along r).
    """
    del with_rank_axis  # rank axis never included, by construction
    return tuple(mode_axis(j) for j in range(ndim) if j != k)


def row_sharding_axes(ndim: int, k: int) -> tuple[str, ...]:
    """PartitionSpec axes for factor k's rows: split by m{k} first (the
    paper's S^{(k)}_{p_k} block-rows), then spread across the hyperslice."""
    return (mode_axis(k),) + hyperslice_axes(ndim, k)
