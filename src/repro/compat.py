"""Version-tolerant shims for jax APIs that moved between releases.

The repo targets current jax (``jax.shard_map``, ``jax.sharding.AxisType``)
but must also run on the 0.4.x jaxlib baked into the validation container,
where ``shard_map`` still lives in ``jax.experimental`` and meshes have no
``axis_types``. All mesh/shard_map construction goes through here.
"""

from __future__ import annotations

from typing import Sequence

import jax

try:  # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - version-dependent
    AxisType = None

if hasattr(jax, "shard_map"):  # jax >= 0.6
    _shard_map = jax.shard_map
    _SHARD_MAP_HAS_CHECK_REP = False
else:  # pragma: no cover - version-dependent
    from jax.experimental.shard_map import shard_map as _shard_map

    _SHARD_MAP_HAS_CHECK_REP = True


def shard_map(f, *, mesh, in_specs, out_specs, check_rep: bool = True):
    """``shard_map`` across jax versions.

    ``check_rep=False`` is needed on 0.4.x for bodies containing primitives
    whose replication rules are incomplete there (e.g. ``linalg.solve``);
    newer jax has no such knob and needs none.
    """
    if _SHARD_MAP_HAS_CHECK_REP:
        return _shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=check_rep,
        )
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict across jax versions
    (older releases return a one-element list of per-device dicts)."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def make_mesh(shape: Sequence[int], names: Sequence[str]) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if AxisType is not None:
        return jax.make_mesh(
            tuple(shape), tuple(names), axis_types=(AxisType.Auto,) * len(names)
        )
    return jax.make_mesh(tuple(shape), tuple(names))


def make_abstract_mesh(shape: Sequence[int], names: Sequence[str]):
    """Device-free ``jax.sharding.AbstractMesh`` across jax versions.

    0.4.x takes one ``((name, size), ...)`` tuple; newer releases take
    separate ``axis_sizes``/``axis_names`` tuples. An abstract mesh
    carries only the logical grid — enough to trace a ``shard_map``
    program with ``jax.make_jaxpr`` on a single-device host (the AOT
    path ``repro.verify.comm`` uses), never to run it.
    """
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(zip(names, shape)))
    except TypeError:  # pragma: no cover - version-dependent
        return AbstractMesh(tuple(shape), tuple(names))
