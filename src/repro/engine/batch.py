"""Batched execution: decompose B tensors with ONE plan and ONE program.

The paper's blocked algorithms win because factor-matrix traffic is
amortized against tensor reads (Eq 9/10).  A *batch* of tensors sharing
one :class:`~repro.engine.plan.BlockPlan` amortizes everything above the
arithmetic the same way — the plan choice, the autotune-cache lookup,
and the XLA compilation are paid once per *bucket* of identically-shaped
problems instead of once per request.  This module is the engine half of
the serving story (:mod:`repro.launch.serve` is the queue half):

* :func:`batched_choose_blocks` — the batched planner entry: the block
  choice for a stack of B tensors IS the element plan.  The batch axis
  is vmapped over, never tiled, so the Eq-9 working set (and therefore
  the chosen blocks) is B-independent by construction.  The static
  verifier (``repro.verify`` rule ``batched-plan-divergence``) proves
  this over the plan lattice.
* :func:`cp_als_batched` / :func:`tucker_hooi_batched` — vmapped sweep
  drivers over stacks of tensors: every per-mode MTTKRP / Multi-TTM of
  a sweep is ONE batched engine dispatch (``jax.vmap`` over the shared
  resolved plan — one kernel launch for B requests on the pallas
  backend), the Gram/solve/eigh tails run batched, and a per-element
  convergence mask freezes early-converged entries (their factors stop
  changing, their iteration counters stop, and the whole loop exits as
  soon as every element has converged).

The batched engine *dispatch* itself (a leading B axis on
``repro.mttkrp`` / ``repro.multi_ttm`` / ``repro.contract_partial``)
lives in :mod:`repro.engine.execute`; the drivers here consume it.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Sequence

import jax
import jax.numpy as jnp

from .plan import BlockPlan, Memory, choose_blocks

if TYPE_CHECKING:  # core <-> engine cycle stays call-time-only
    from ..core.cp_als import CPResult
    from ..core.tucker import TuckerResult
    from .context import ExecutionContext


def batched_choose_blocks(
    batch: int,
    shape: Sequence[int],
    rank: int,
    itemsize: int,
    *,
    memory: Memory | None = None,
    x_has_rank: bool = False,
) -> BlockPlan:
    """The block plan a batched dispatch of B element-problems runs under.

    Batching is ``jax.vmap`` over the element contraction: the batch
    axis becomes a kernel *grid* dimension (one program instance per
    element), so no block ever spans two elements and the per-instance
    Eq-9 working set is exactly the element working set.  The correct
    plan for any ``batch >= 1`` is therefore the element plan,
    unchanged — this function documents (and the ``repro.verify``
    ``batched-plan-divergence`` rule enforces) that batching never
    changes the block choice.
    """
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    return choose_blocks(
        shape, rank, itemsize, memory=memory, x_has_rank=x_has_rank
    )


# ---------------------------------------------------------------------------
# Batched CP-ALS
# ---------------------------------------------------------------------------

@dataclass
class BatchedCPResult:
    """B Kruskal-form decompositions from one batched run.

    ``factors[k]`` is ``(B, I_k, R)`` (column-normalized per element),
    ``weights`` is ``(B, R)`` (λ per element), ``fits`` is ``(B,)``
    (final fit per element), ``n_iters`` is ``(B,)`` (sweeps each
    element actually *updated* — a converged element's counter freezes),
    and ``converged`` is ``(B,)`` bool.  ``result(b)`` crops element
    ``b`` back out as a plain :class:`~repro.core.cp_als.CPResult`.
    """

    factors: list[jax.Array]
    weights: jax.Array
    fits: jax.Array
    n_iters: jax.Array
    converged: jax.Array
    fit_history: list[jax.Array] = field(default_factory=list)

    @property
    def batch(self) -> int:
        return int(self.weights.shape[0])

    def result(self, b: int) -> "CPResult":
        """Element ``b`` as a plain :class:`CPResult` (fit history
        truncated to the sweeps that ran before the whole batch
        stopped)."""
        from ..core.cp_als import CPResult

        return CPResult(
            [f[b] for f in self.factors],
            self.weights[b],
            [float(h[b]) for h in self.fit_history],
        )


def _batched_grams(factors: Sequence[jax.Array]) -> list[jax.Array]:
    return [jnp.einsum("bir,bis->brs", f, f) for f in factors]


def _batched_hadamard_except(
    grams: Sequence[jax.Array], skip: int
) -> jax.Array:
    rank = grams[0].shape[-1]
    out = jnp.ones((grams[0].shape[0], rank, rank), grams[0].dtype)
    for k, g in enumerate(grams):
        if k != skip:
            out = out * g
    return out


def _batched_fit(normx, b_last, a_last, gram_had_all):
    """Per-element fit via the inner-product identity (no reconstruction):
    ``1 - ||X_b - recon_b|| / ||X_b||`` for every element at once."""
    inner = jnp.sum(b_last * a_last, axis=(1, 2))
    norm_recon_sq = jnp.sum(gram_had_all, axis=(1, 2))
    err_sq = jnp.maximum(normx**2 - 2 * inner + norm_recon_sq, 0.0)
    return 1.0 - jnp.sqrt(err_sq) / jnp.maximum(normx, 1e-30)


def cp_als_batched(
    x: jax.Array,
    rank: int,
    n_iters: int = 20,
    key: jax.Array | None = None,
    init_factors: Sequence[jax.Array] | None = None,
    tol: float = 0.0,
    *,
    ctx: "ExecutionContext | None" = None,
) -> BatchedCPResult:
    """CP-ALS over a stack of B same-shaped tensors, one plan for all.

    ``x`` is ``(B, I_0, ..., I_{N-1})``.  Each sweep's per-mode MTTKRP
    is ONE batched engine dispatch (``repro.mttkrp`` with the leading
    batch axis: the ``backend="auto"`` resolution, the plan choice, and
    — on the pallas backend — the kernel launch happen once per call,
    not once per element); the Gram/solve/normalize tail runs batched
    through ``jnp.linalg``.  ``init_factors[k]`` may be ``(B, I_k, R)``
    (per-element inits) and overrides ``key``.

    ``tol`` enables per-element convergence: an element whose fit
    improvement falls below ``tol`` is *frozen* — its factors, weights,
    and fit stop changing and its ``n_iters`` counter stops — while the
    rest of the batch keeps iterating; the loop exits as soon as every
    element has converged.  Numerics match a Python loop of
    single-tensor :func:`repro.cp_als` calls with the same inits (the
    property suite in ``tests/test_batched.py`` pins this
    differentially).
    """
    from ..engine.context import ExecutionContext

    if ctx is None:
        ctx = ExecutionContext.default()
    if x.ndim < 3:
        raise ValueError(
            f"cp_als_batched needs a batch of >=2-way tensors "
            f"(B, I_0, ..., I_N-1); got shape {tuple(x.shape)}"
        )
    if ctx.is_distributed:
        raise ValueError(
            "cp_als_batched is the single-process batched driver; "
            "distributed contexts run repro.cp_als per tensor (the "
            "stationary sweep owns the collectives)"
        )
    batch, dims = x.shape[0], x.shape[1:]
    n = len(dims)
    if init_factors is not None:
        factors = [jnp.asarray(f) for f in init_factors]
        for k, f in enumerate(factors):
            if f.shape != (batch, dims[k], rank):
                raise ValueError(
                    f"init_factors[{k}] must be (B, I_k, R) = "
                    f"({batch}, {dims[k]}, {rank}), got {tuple(f.shape)}"
                )
    else:
        from ..core.tensor import random_factors

        key = key if key is not None else jax.random.PRNGKey(0)
        keys = jax.random.split(key, batch)
        factors = [
            jnp.stack(f) for f in zip(*[
                random_factors(k, dims, rank, x.dtype) for k in keys
            ])
        ]

    from ..observe import trace as _otrace
    from . import execute as engine_execute

    normx = jnp.sqrt(
        jnp.sum(jnp.square(x.astype(jnp.float32)), axis=tuple(range(1, n + 1)))
    )
    grams = _batched_grams(factors)
    weights = jnp.ones((batch, rank), x.dtype)
    converged = jnp.zeros((batch,), bool)
    iters_run = jnp.zeros((batch,), jnp.int32)
    fits = jnp.zeros((batch,), jnp.float32)
    fit_history: list[jax.Array] = []
    solve_dtype = jnp.float32 if x.dtype != jnp.float64 else x.dtype
    eye = jnp.eye(rank, dtype=solve_dtype)
    state: dict = {}

    def update(mode: int, b: jax.Array, active: jax.Array):
        """One batched mode update, frozen where ``active`` is False."""
        nonlocal weights
        gamma = _batched_hadamard_except(grams, mode).astype(solve_dtype)
        ridge = (
            1e-5 * jnp.trace(gamma, axis1=1, axis2=2) / rank + 1e-12
        )[:, None, None]
        a_new = jnp.linalg.solve(
            gamma + ridge * eye,
            jnp.swapaxes(b.astype(solve_dtype), 1, 2),
        )
        a_new = jnp.swapaxes(a_new, 1, 2).astype(x.dtype)
        lam = jnp.maximum(jnp.linalg.norm(a_new, axis=1), 1e-30)
        a_new = a_new / lam[:, None, :]
        # the convergence mask: frozen elements keep their old factors,
        # weights, and Grams bit-for-bit
        a_new = jnp.where(active[:, None, None], a_new, factors[mode])
        weights = jnp.where(
            active[:, None], lam.astype(x.dtype), weights
        )
        grams[mode] = jnp.einsum("bir,bis->brs", a_new, a_new)
        state.update(
            b_last=b, a_last=a_new * weights[:, None, :], mode=mode
        )
        return a_new

    for it in range(n_iters):
        active = ~converged
        for mode in range(n):
            # ONE batched engine dispatch for all B elements
            b = engine_execute.mttkrp(x, factors, mode, ctx=ctx)
            factors[mode] = update(mode, b, active)
        gram_full = _batched_hadamard_except(grams, -1) * jnp.einsum(
            "br,bs->brs", weights, weights
        )
        new_fits = _batched_fit(
            normx, state["b_last"], state["a_last"], gram_full
        )
        new_fits = jnp.where(active, new_fits, fits)
        delta = jnp.abs(new_fits - fits)
        fits = new_fits
        fit_history.append(fits)
        iters_run = iters_run + active.astype(jnp.int32)
        if tol and it > 0:
            converged = converged | (active & (delta < tol))
        if _otrace.should_record(ctx.observe):
            _otrace.record_event(
                "cp_als_batched_iter",
                batch=int(batch),
                shape=list(dims),
                rank=int(rank),
                it=it,
                fits=[float(f) for f in fits],
                converged=[bool(c) for c in converged],
            )
        if tol and bool(converged.all()):
            break
    return BatchedCPResult(
        factors, weights, fits, iters_run, converged, fit_history
    )


# ---------------------------------------------------------------------------
# Batched Tucker/HOOI
# ---------------------------------------------------------------------------

@dataclass
class BatchedTuckerResult:
    """B Tucker decompositions from one batched HOOI run: ``core`` is
    ``(B, R_1, ..., R_N)``, ``factors[k]`` is ``(B, I_k, R_k)``
    (orthonormal columns per element), ``fits``/``n_iters``/
    ``converged`` are per-element as in :class:`BatchedCPResult`."""

    core: jax.Array
    factors: list[jax.Array]
    fits: jax.Array
    n_iters: jax.Array
    converged: jax.Array

    @property
    def batch(self) -> int:
        return int(self.core.shape[0])

    @property
    def ranks(self) -> tuple[int, ...]:
        return tuple(self.core.shape[1:])

    def result(self, b: int) -> "TuckerResult":
        """Element ``b`` as a plain
        :class:`~repro.core.tucker.TuckerResult`."""
        from ..core.tucker import TuckerResult

        return TuckerResult(
            self.core[b], [f[b] for f in self.factors], [float(self.fits[b])]
        )


def tucker_hooi_batched(
    x: jax.Array,
    ranks: Sequence[int],
    n_iters: int = 10,
    *,
    ctx: "ExecutionContext | None" = None,
    init_factors: Sequence[jax.Array] | None = None,
    tol: float = 0.0,
) -> BatchedTuckerResult:
    """Tucker/HOOI over a stack of B same-shaped tensors, one plan for
    all.  ``x`` is ``(B, I_1, ..., I_N)``; each HOOI mode update is ONE
    batched Multi-TTM dispatch (``repro.multi_ttm`` with the leading
    batch axis) followed by a batched Gram eigendecomposition;
    initialization is per-element HOSVD (``init_factors[k]`` of shape
    ``(B, I_k, R_k)`` overrides).  ``tol`` freezes converged elements
    exactly as in :func:`cp_als_batched`.  Numerics match a loop of
    single-tensor :func:`repro.tucker_hooi` calls (pinned
    differentially in ``tests/test_batched.py``)."""
    from ..core.tucker import _check_ranks, _leading_eigvecs, hosvd_init
    from ..engine.context import ExecutionContext
    from ..observe import trace as _otrace
    from . import execute as engine_execute

    if ctx is None:
        ctx = ExecutionContext.default()
    if x.ndim < 3:
        raise ValueError(
            f"tucker_hooi_batched needs a batch of >=2-way tensors "
            f"(B, I_1, ..., I_N); got shape {tuple(x.shape)}"
        )
    if ctx.is_distributed:
        raise ValueError(
            "tucker_hooi_batched is the single-process batched driver; "
            "distributed contexts run repro.tucker_hooi per tensor"
        )
    batch, dims = x.shape[0], x.shape[1:]
    n = len(dims)
    ranks = _check_ranks(dims, ranks)
    if init_factors is not None:
        factors = [jnp.asarray(f) for f in init_factors]
        for k, f in enumerate(factors):
            if f.shape != (batch, dims[k], ranks[k]):
                raise ValueError(
                    f"init_factors[{k}] must be (B, I_k, R_k) = "
                    f"({batch}, {dims[k]}, {ranks[k]}), got {tuple(f.shape)}"
                )
    else:
        factors = jax.vmap(lambda xb: hosvd_init(xb, ranks))(x)
    normx = jnp.sqrt(
        jnp.sum(jnp.square(x.astype(jnp.float32)), axis=tuple(range(1, n + 1)))
    )
    converged = jnp.zeros((batch,), bool)
    iters_run = jnp.zeros((batch,), jnp.int32)
    fits = jnp.zeros((batch,), jnp.float32)
    core = None

    def _batched_eigvecs(ym: jax.Array, r: int) -> jax.Array:
        gram = jnp.einsum("bij,bkj->bik", ym, ym)
        return jax.vmap(lambda g: _leading_eigvecs(g, r))(gram)

    for it in range(n_iters):
        active = ~converged
        y = x
        for k in range(n):
            # ONE batched Multi-TTM dispatch for all B elements
            y = engine_execute.multi_ttm(
                x, [None if j == k else factors[j] for j in range(n)],
                keep=k, ctx=ctx,
            )
            ym = jnp.moveaxis(y, k + 1, 1).reshape(batch, dims[k], -1)
            a_new = _batched_eigvecs(ym, ranks[k]).astype(x.dtype)
            factors[k] = jnp.where(active[:, None, None], a_new, factors[k])
        # the core falls out of the last mode update (batched ttm)
        new_core = jnp.moveaxis(
            jnp.einsum("b...i,bir->b...r", jnp.moveaxis(y, n, x.ndim - 1),
                       factors[n - 1]),
            x.ndim - 1, n,
        )
        core = new_core if core is None else jnp.where(
            active.reshape((batch,) + (1,) * n), new_core, core
        )
        core_norm = jnp.sqrt(jnp.sum(
            jnp.square(core.astype(jnp.float32)),
            axis=tuple(range(1, n + 1)),
        ))
        err_sq = jnp.maximum(normx**2 - core_norm**2, 0.0)
        new_fits = 1.0 - jnp.sqrt(err_sq) / jnp.maximum(normx, 1e-30)
        new_fits = jnp.where(active, new_fits, fits)
        delta = jnp.abs(new_fits - fits)
        fits = new_fits
        iters_run = iters_run + active.astype(jnp.int32)
        if tol and it > 0:
            converged = converged | (active & (delta < tol))
        if _otrace.should_record(ctx.observe):
            _otrace.record_event(
                "tucker_batched_iter",
                batch=int(batch),
                shape=list(dims),
                ranks=list(ranks),
                it=it,
                fits=[float(f) for f in fits],
                converged=[bool(c) for c in converged],
            )
        if tol and bool(converged.all()):
            break
    return BatchedTuckerResult(core, factors, fits, iters_run, converged)
