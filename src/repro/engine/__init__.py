"""Unified MTTKRP execution engine.

One planner (``plan``), one dispatch layer (``execute``), and the
kernel-backed dimension tree (``tree``). Every consumer — the Pallas kernel
wrappers, the two-level-memory simulator, CP-ALS, the shard_map parallel
algorithms, and the benchmarks — quotes blocking decisions and traffic
numbers from the same :class:`~repro.engine.plan.BlockPlan` objects.

Layering (see docs/ARCHITECTURE.md):

    plan      — Memory descriptors, BlockPlan, choose_blocks, Eq 9/10 models
    context   — ExecutionContext: the one immutable config object + the
                validation catalog + the deprecated-kwarg shim
    execute   — mttkrp(x, factors, mode, ctx=...) + partial contractions
                (a leading batch axis vmaps B problems over ONE plan)
    batch     — cp_als_batched / tucker_hooi_batched: B decompositions
                as one vmapped sweep with per-element convergence masks
    tree      — all-mode MTTKRP / ALS sweeps over a binary dimension tree
"""

from .context import (
    VALID_BACKENDS,
    Distribution,
    ExecutionContext,
    PlanDecision,
    ProblemSpec,
    check_backend,
    check_driver_options,
)
from .plan import (
    LANE,
    SUBLANE,
    VMEM_BUDGET,
    VMEM_BYTES,
    BlockPlan,
    Memory,
    MultiTTMPlan,
    best_uniform_block,
    choose_blocks,
    choose_multi_ttm_blocks,
    mttkrp_traffic_model,
    uniform_block_feasible,
    uniform_multi_ttm_plan,
)
from .batch import (
    BatchedCPResult,
    BatchedTuckerResult,
    batched_choose_blocks,
    cp_als_batched,
    tucker_hooi_batched,
)
from .execute import mttkrp, contract_partial, multi_ttm
from .tree import all_mode_mttkrp, dimtree_als_sweep

__all__ = [
    "VALID_BACKENDS",
    "Distribution",
    "ExecutionContext",
    "PlanDecision",
    "ProblemSpec",
    "check_backend",
    "check_driver_options",
    "LANE",
    "SUBLANE",
    "VMEM_BUDGET",
    "VMEM_BYTES",
    "BlockPlan",
    "Memory",
    "MultiTTMPlan",
    "best_uniform_block",
    "choose_blocks",
    "choose_multi_ttm_blocks",
    "uniform_multi_ttm_plan",
    "mttkrp_traffic_model",
    "uniform_block_feasible",
    "mttkrp",
    "contract_partial",
    "multi_ttm",
    "BatchedCPResult",
    "BatchedTuckerResult",
    "batched_choose_blocks",
    "cp_als_batched",
    "tucker_hooi_batched",
    "all_mode_mttkrp",
    "dimtree_als_sweep",
]
