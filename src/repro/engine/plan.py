"""The planner: one source of truth for MTTKRP blocking and traffic models.

Everything the paper derives about *how to block* lives here:

  * :class:`Memory` — an explicit two-level-memory descriptor (capacity,
    lane/sublane alignment, itemsize). ``Memory.tpu_vmem()`` is the VMEM of
    the Pallas kernels; ``Memory.abstract(M)`` is the paper's §II-C abstract
    M-word fast memory (no alignment), used by the simulator.
  * :class:`BlockPlan` — block sizes for one contraction, with the Eq-9
    working-set check and the Eq-10 traffic model as *methods*, so the
    kernel wrapper, the simulator, and the benchmarks all quote the same
    numbers from the same object.
  * :func:`choose_blocks` — TPU-aligned block selection against a Memory
    budget (the paper's b ~ (alpha*M)^{1/N} with MXU/VPU alignment floors).
    ``x_has_rank=True`` plans the dimension tree's rank-augmented partial
    contractions, whose tensor tile carries an extra rank axis.
  * :func:`best_uniform_block` / :func:`uniform_block_feasible` — the
    paper's exact uniform-b selection (Eq 9), re-exported for the simulator
    so block selection has a single import path.

  * :class:`MultiTTMPlan` / :func:`choose_multi_ttm_blocks` /
    :func:`uniform_multi_ttm_plan` — the Multi-TTM (Tucker/HOSVD,
    arXiv:2207.10437) counterparts: kept-mode + contraction blocks with
    the small per-mode Tucker ranks structural (never tiled), the
    Kronecker weight block in the Eq-9-analog working set, and the
    Eq-10-analog traffic model pinned against
    ``core.bounds.multi_ttm_blocked_cost``.

Formula provenance stays in :mod:`repro.core.bounds` (the pure equation
library); this module is the only place that turns those equations into
decisions.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence

from ..core.bounds import best_block_size, blocked_feasible_b, seq_blocked_cost

LANE = 128
SUBLANE = 8
VMEM_BYTES = 16 * 2 ** 20  # v5e per-core VMEM
VMEM_BUDGET = VMEM_BYTES // 2  # leave headroom for double-buffering


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class Memory:
    """Two-level fast-memory descriptor the planner blocks against."""

    budget_bytes: int
    lane: int = 1
    sublane: int = 1
    itemsize: int = 4

    @classmethod
    def tpu_vmem(cls, budget_bytes: int = VMEM_BUDGET, itemsize: int = 4) -> "Memory":
        """The Pallas kernels' fast memory: VMEM with MXU alignment."""
        return cls(budget_bytes, lane=LANE, sublane=SUBLANE, itemsize=itemsize)

    @classmethod
    def abstract(cls, words: int, itemsize: int = 1) -> "Memory":
        """The paper's abstract M-word fast memory (§II-C): no alignment."""
        return cls(words * itemsize, lane=1, sublane=1, itemsize=itemsize)

    @property
    def budget_words(self) -> int:
        return self.budget_bytes // self.itemsize

    def with_itemsize(self, itemsize: int) -> "Memory":
        """Same memory, re-described for a different element width — the
        dtype-aware planning hook: a bf16 compute dtype halves ``itemsize``
        so ``budget_words`` doubles and every Eq-9 fit admits larger
        blocks on the *same physical budget*."""
        if itemsize == self.itemsize:
            return self
        return Memory(self.budget_bytes, self.lane, self.sublane, itemsize)


@dataclass(frozen=True)
class BlockPlan:
    """Block sizes for one (possibly rank-augmented) MTTKRP-shaped
    contraction: output rows ``block_i``, contraction dims
    ``block_contract``, rank tile ``block_r``.

    ``x_has_rank`` marks dimension-tree partial contractions whose tensor
    operand already carries the rank axis (tile holds ``bi*prod(bc)*br``
    words instead of ``bi*prod(bc)``).
    """

    block_i: int
    block_contract: tuple[int, ...]
    block_r: int
    x_has_rank: bool = False

    # -- Eq 9: working set -------------------------------------------------
    def kernel_block_words(self) -> int:
        """VMEM words held by the kernel's BlockSpec operand tiles alone:
        X tile + factor tiles + output tile.  This is the part of the Eq-9
        working set that the Pallas ``BlockSpec`` machinery stages; the
        static kernel analyzer (:mod:`repro.verify.kernels`) recomputes it
        from the captured block shapes and pins the two against each other
        via ``working_set_words() == kernel_block_words() +
        weight_scratch_words()``."""
        prod_c = math.prod(self.block_contract)
        x_tile = self.block_i * prod_c * (self.block_r if self.x_has_rank else 1)
        f_tiles = sum(c * self.block_r for c in self.block_contract)
        out = self.block_i * self.block_r
        return x_tile + f_tiles + out

    def weight_scratch_words(self) -> int:
        """VMEM words of the Khatri-Rao weight block ``prod(bc) * br`` the
        kernel builds in registers/VMEM each grid step — part of Eq 9 but
        *not* a BlockSpec operand (it never touches HBM)."""
        return math.prod(self.block_contract) * self.block_r

    def working_set_words(self, itemsize: int = 4) -> int:
        """VMEM words held per grid step (Eq 9 analogue): X tile + factor
        tiles + KRP block + output tile."""
        del itemsize  # word count is itemsize-free; kept for API stability
        return self.kernel_block_words() + self.weight_scratch_words()

    def fits(self, memory: Memory) -> bool:
        """Eq-9 feasibility against an explicit memory descriptor."""
        return self.working_set_words() * memory.itemsize <= memory.budget_bytes

    # -- shapes ------------------------------------------------------------
    def blocks_per_mode(self) -> tuple[int, ...]:
        """Per-mode block sizes with the output mode first (paper's b_k)."""
        return (self.block_i,) + tuple(self.block_contract)

    def padded_shape(self, shape: Sequence[int]) -> tuple[int, ...]:
        """Input shape rounded up to block multiples (output mode first)."""
        blocks = self.blocks_per_mode()
        return tuple(_round_up(s, b) for s, b in zip(shape, blocks))

    def grid(self, shape: Sequence[int], rank: int) -> tuple[int, ...]:
        """Pallas grid (r, i, c_1..c_{N-1}) for the padded problem."""
        padded = self.padded_shape(shape)
        r_pad = _round_up(rank, self.block_r)
        return (r_pad // self.block_r, padded[0] // self.block_i) + tuple(
            padded[1 + d] // self.block_contract[d]
            for d in range(len(self.block_contract))
        )

    # -- Eq 10: traffic ----------------------------------------------------
    def eq10_words(self, shape: Sequence[int], rank: int) -> int:
        """The paper's Eq (10) bound generalized to per-mode block sizes.

        Per block (prod_k ceil(I_k/b_k) of them), each of the R rank
        columns loads the N-1 factor subvectors (sum of their b_k) and
        loads+stores the output subvector (2*b_out); plus one pass over the
        tensor. With a uniform block b this is exactly
        ``core.bounds.seq_blocked_cost``: I + prod ceil(I_k/b) * R*(N+1)*b.
        """
        blocks = self.blocks_per_mode()
        nblocks = math.prod(
            math.ceil(s / b) for s, b in zip(shape, blocks)
        )
        per_block = rank * (sum(blocks) + blocks[0])
        return math.prod(shape) + nblocks * per_block

    def traffic_model(
        self, shape: Sequence[int], rank: int, itemsize: int = 4
    ) -> dict[str, int]:
        """Modeled HBM<->VMEM traffic of the kernel (bytes), mirroring the
        BlockSpec fetch rules: a block is re-fetched when its mapped index
        changes between consecutive grid steps.

        Grid (3-way): (i, r, j, k), k innermost. X fetched every step;
        factor k every step; factor j once per k-sweep; O written once per
        (i, r). ``eq10_bytes`` is the paper-ideal Eq-10 cost for the same
        per-mode block sizes (see :meth:`eq10_words`).
        """
        n = len(shape)
        padded = self.padded_shape(shape)
        r_pad = _round_up(rank, self.block_r)
        gi = padded[0] // self.block_i
        gr = r_pad // self.block_r
        gc = [
            padded[1 + d] // self.block_contract[d] for d in range(n - 1)
        ]
        steps = gi * gr * math.prod(gc)
        x_words = self.block_i * math.prod(self.block_contract)
        if self.x_has_rank:
            x_words *= self.block_r
        x_bytes = steps * x_words * itemsize
        f_bytes = 0
        # factor d re-fetched when (c_d, r) changes; c_d sweeps with all
        # inner dims constant-free: fetches = gi*gr*prod(gc[:d+1])
        run = gi * gr
        for d in range(n - 1):
            run *= gc[d]
            f_bytes += run * self.block_contract[d] * self.block_r * itemsize
        o_bytes = gi * gr * self.block_i * self.block_r * itemsize
        total = x_bytes + f_bytes + o_bytes
        return {
            "x_bytes": x_bytes,
            "factor_bytes": f_bytes,
            "out_bytes": o_bytes,
            "total_bytes": total,
            "eq10_bytes": self.eq10_words(shape, rank) * itemsize,
            "steps": steps,
            "working_set_bytes": self.working_set_words() * itemsize,
        }


def choose_blocks(
    shape: Sequence[int],
    rank: int,
    itemsize: int = 4,
    vmem_budget: int = VMEM_BUDGET,
    *,
    memory: Memory | None = None,
    x_has_rank: bool = False,
) -> BlockPlan:
    """Pick TPU-aligned block sizes fitting the memory budget.

    Strategy (mirrors the paper's b ~ (alpha*M)^{1/N} with TPU alignment):
    output mode and rank tiles start at MXU-friendly 128; the minor
    contraction dim at 128 (lane), other contraction dims at 8 (sublane);
    then shrink the largest contributor until the working set fits.

    Degenerate extents never over-pad: a dimension smaller than its
    alignment unit (a mode of size 1, a rank below the lane width) gets
    the *full extent* as its block — the arrays are then padded to their
    own size (no padding at all) rather than to a whole alignment tile,
    and the traffic model stops charging phantom bytes. If even the
    aligned-minimal plan exceeds the budget (only reachable for memories
    far below real VMEM, e.g. abstract/simulated budgets), alignment is
    relaxed rather than returning an Eq-9-infeasible plan.
    """
    if memory is None:
        memory = Memory.tpu_vmem(vmem_budget, itemsize)
    lane, sublane = memory.lane, memory.sublane
    n = len(shape)

    def start(extent: int, unit: int, pref: int) -> int:
        if extent <= unit:  # sub-unit dim: full extent, zero padding
            return max(1, extent)
        return min(_round_up(extent, unit), pref)

    def floor(extent: int, unit: int) -> int:
        return max(1, extent) if extent <= unit else unit

    bi = start(shape[0], sublane, 128)
    br = start(rank, lane, 512)
    bc: list[int] = []
    for d in range(1, n):
        if d == n - 1:  # minor dim: lane-aligned
            bc.append(start(shape[d], lane, 128))
        else:
            bc.append(start(shape[d], sublane, max(sublane, 8)))
    fi = floor(shape[0], sublane)
    fr = floor(rank, lane)
    fc = [
        floor(shape[d], lane if d == n - 1 else sublane) for d in range(1, n)
    ]
    plan = BlockPlan(bi, tuple(bc), br, x_has_rank)
    # shrink until it fits (keep alignment floors)
    while not plan.fits(memory):
        bi, br = plan.block_i, plan.block_r
        bc = list(plan.block_contract)
        if br > fr:
            br = max(fr, br // 2)
        elif bi > fi:
            bi = max(fi, bi // 2)
        else:
            shrunk = False
            for d in range(len(bc) - 1):  # shrink non-minor contraction dims
                if bc[d] > fc[d]:
                    bc[d] = max(fc[d], bc[d] // 2)
                    shrunk = True
                    break
            if not shrunk:
                if bc and bc[-1] > fc[-1]:
                    bc[-1] = max(fc[-1], bc[-1] // 2)
                else:
                    break  # aligned floors reached; relax below
        plan = BlockPlan(bi, tuple(bc), br, x_has_rank)
    # last resort: relax alignment (largest contributor first) so the
    # returned plan satisfies Eq 9 whenever any plan can
    while not plan.fits(memory):
        dims = [plan.block_i, *plan.block_contract, plan.block_r]
        j = max(range(len(dims)), key=lambda k: dims[k])
        if dims[j] <= 1:
            break  # all-1 blocks; nothing fits this memory
        dims[j] //= 2
        plan = BlockPlan(dims[0], tuple(dims[1:-1]), dims[-1], x_has_rank)
    return plan


# ---------------------------------------------------------------------------
# Fused-sweep planning (the arXiv:1708.08976 mode-reuse schedule)
# ---------------------------------------------------------------------------

def fused_pair_working_set_words(plan: BlockPlan) -> int:
    """Eq-9 analogue for the fused (B^(0), P) pair kernel
    (:mod:`repro.kernels.sweep`): the per-mode working set plus the
    rank-augmented partial tile ``bi * prod(bc[:-1]) * br`` that the second
    output keeps VMEM-resident across the innermost contraction sweep.

    X tile + factor tiles + KRP weight + B^(0) tile + P tile — the
    mode-reuse schedule pays one extra output tile to avoid re-streaming
    the tensor once per mode."""
    return fused_pair_kernel_block_words(plan) + plan.weight_scratch_words()


def fused_pair_kernel_block_words(plan: BlockPlan) -> int:
    """BlockSpec-operand share of :func:`fused_pair_working_set_words`:
    X tile + factor tiles + B^(0) tile + P tile, excluding the in-kernel
    KRP weight scratch (``plan.weight_scratch_words()``).  The static
    kernel analyzer pins the fused pair kernel's captured block shapes
    against this claim."""
    prod_c = math.prod(plan.block_contract)
    x_tile = plan.block_i * prod_c
    f_tiles = sum(c * plan.block_r for c in plan.block_contract)
    b0_tile = plan.block_i * plan.block_r
    p_tile = plan.block_i * math.prod(plan.block_contract[:-1]) * plan.block_r
    return x_tile + f_tiles + b0_tile + p_tile


def choose_sweep_blocks(
    shape: Sequence[int],
    rank: int,
    itemsize: int = 4,
    vmem_budget: int = VMEM_BUDGET,
    *,
    memory: Memory | None = None,
) -> BlockPlan:
    """Block selection for the fused pair kernel: start from the per-mode
    MTTKRP plan, then keep shrinking until the *fused* working set
    (:func:`fused_pair_working_set_words`) also fits — same shrink order
    as :func:`choose_blocks` (rank, then output rows, then non-minor
    contraction dims, then the minor dim, then relax alignment)."""
    if memory is None:
        memory = Memory.tpu_vmem(vmem_budget, itemsize)
    lane, sublane = memory.lane, memory.sublane
    n = len(shape)
    plan = choose_blocks(shape, rank, memory=memory)

    def fused_fits(p: BlockPlan) -> bool:
        return (
            fused_pair_working_set_words(p) * memory.itemsize
            <= memory.budget_bytes
        )

    def floor(extent: int, unit: int) -> int:
        return max(1, extent) if extent <= unit else unit

    fi = floor(shape[0], sublane)
    fr = floor(rank, lane)
    fc = [
        floor(shape[d], lane if d == n - 1 else sublane) for d in range(1, n)
    ]
    while not fused_fits(plan):
        bi, br = plan.block_i, plan.block_r
        bc = list(plan.block_contract)
        if br > fr:
            br = max(fr, br // 2)
        elif bi > fi:
            bi = max(fi, bi // 2)
        else:
            shrunk = False
            for d in range(len(bc) - 1):
                if bc[d] > fc[d]:
                    bc[d] = max(fc[d], bc[d] // 2)
                    shrunk = True
                    break
            if not shrunk:
                if bc and bc[-1] > fc[-1]:
                    bc[-1] = max(fc[-1], bc[-1] // 2)
                else:
                    break
        plan = BlockPlan(bi, tuple(bc), br)
    while not fused_fits(plan):
        dims = [plan.block_i, *plan.block_contract, plan.block_r]
        j = max(range(len(dims)), key=lambda k: dims[k])
        if dims[j] <= 1:
            break
        dims[j] //= 2
        plan = BlockPlan(dims[0], tuple(dims[1:-1]), dims[-1])
    return plan


# ---------------------------------------------------------------------------
# Multi-TTM planning (the Tucker/HOSVD kernel, arXiv:2207.10437)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MultiTTMPlan:
    """Block sizes for one canonical Multi-TTM contraction: kept mode first
    (``block_i`` rows), contracted tensor modes next (``block_contract``),
    each contracted mode paired with its small Tucker rank ``ranks[d]``.

    Unlike :class:`BlockPlan` there is no rank tile: the R_d are the
    *small* dimensions of the problem (Tucker ranks), so every tile keeps
    them whole and the Kronecker weight block
    ``W[(c_1..c_k), (r_1..r_k)] = prod_d A_d(c_d, r_d)`` is built in fast
    memory, never materialized in HBM — the Multi-TTM analog of the
    MTTKRP kernels' Khatri-Rao weight.
    """

    block_i: int
    block_contract: tuple[int, ...]
    ranks: tuple[int, ...]

    # -- Eq 9 analog: working set -----------------------------------------
    def kernel_block_words(self) -> int:
        """Fast-memory words of the kernel's BlockSpec operand tiles alone:
        tensor tile + matrix tiles + output tile.  The Kronecker weight
        block is in-kernel scratch (:meth:`weight_scratch_words`); the
        static kernel analyzer pins the captured block shapes against this
        claim."""
        prod_c = math.prod(self.block_contract)
        prod_r = math.prod(self.ranks)
        x_tile = self.block_i * prod_c
        m_tiles = sum(c * r for c, r in zip(self.block_contract, self.ranks))
        out = self.block_i * prod_r
        return x_tile + m_tiles + out

    def weight_scratch_words(self) -> int:
        """Fast-memory words of the Kronecker weight block
        ``prod(bc) * prod(R_d)`` built in VMEM each grid step (never
        materialized in HBM)."""
        return math.prod(self.block_contract) * math.prod(self.ranks)

    def working_set_words(self) -> int:
        """Fast-memory words per grid step: tensor tile + matrix tiles +
        Kronecker weight block + output tile (the Multi-TTM Eq-9 analog;
        uniform-b form in ``core.bounds.multi_ttm_blocked_feasible_b``)."""
        return self.kernel_block_words() + self.weight_scratch_words()

    def fits(self, memory: Memory) -> bool:
        return self.working_set_words() * memory.itemsize <= memory.budget_bytes

    # -- shapes ------------------------------------------------------------
    def blocks_per_mode(self) -> tuple[int, ...]:
        return (self.block_i,) + tuple(self.block_contract)

    def padded_shape(self, shape: Sequence[int]) -> tuple[int, ...]:
        blocks = self.blocks_per_mode()
        return tuple(_round_up(s, b) for s, b in zip(shape, blocks))

    def grid(self, shape: Sequence[int]) -> tuple[int, ...]:
        """Pallas grid (i, c_1..c_k) for the padded problem (no rank axis:
        the R_d stay whole per tile)."""
        padded = self.padded_shape(shape)
        return (padded[0] // self.block_i,) + tuple(
            padded[1 + d] // self.block_contract[d]
            for d in range(len(self.block_contract))
        )

    # -- Eq 10 analog: traffic --------------------------------------------
    def model_words(self, shape: Sequence[int]) -> int:
        """The blocked Multi-TTM cost generalized to per-mode block sizes:
        one pass over the tensor plus, per block, the matrix subblocks
        (sum_d b_d R_d) and one load+store of the output subblock
        (2 b_i prod R_d). With a uniform b this equals
        ``core.bounds.multi_ttm_blocked_cost`` exactly."""
        blocks = self.blocks_per_mode()
        nblocks = math.prod(
            math.ceil(s / b) for s, b in zip(shape, blocks)
        )
        per_block = sum(
            b * r for b, r in zip(self.block_contract, self.ranks)
        ) + 2 * self.block_i * math.prod(self.ranks)
        return math.prod(shape) + nblocks * per_block

    def traffic_model(
        self, shape: Sequence[int], itemsize: int = 4
    ) -> dict[str, int]:
        """Modeled HBM<->VMEM traffic (bytes) of the Multi-TTM kernel,
        mirroring its BlockSpec fetch rules: grid (i, c_1..c_k), c
        innermost; the tensor is streamed once; matrix d is re-fetched
        when c_d changes; the output tile is written once per i block
        (output-stationary). ``model_bytes`` is the paper-ideal cost for
        the same per-mode blocks (:meth:`model_words`)."""
        n = len(shape)
        padded = self.padded_shape(shape)
        gi = padded[0] // self.block_i
        gc = [
            padded[1 + d] // self.block_contract[d] for d in range(n - 1)
        ]
        steps = gi * math.prod(gc)
        x_bytes = steps * self.block_i * math.prod(self.block_contract) \
            * itemsize
        m_bytes = 0
        run = gi
        for d in range(n - 1):
            run *= gc[d]
            m_bytes += run * self.block_contract[d] * self.ranks[d] * itemsize
        o_bytes = gi * self.block_i * math.prod(self.ranks) * itemsize
        total = x_bytes + m_bytes + o_bytes
        return {
            "x_bytes": x_bytes,
            "matrix_bytes": m_bytes,
            "out_bytes": o_bytes,
            "total_bytes": total,
            "model_bytes": self.model_words(shape) * itemsize,
            "steps": steps,
            "working_set_bytes": self.working_set_words() * itemsize,
        }


def choose_multi_ttm_blocks(
    shape: Sequence[int],
    ranks: Sequence[int],
    itemsize: int = 4,
    *,
    memory: Memory | None = None,
) -> MultiTTMPlan:
    """Pick blocks for a canonical Multi-TTM (kept mode first) against a
    memory budget — the Multi-TTM counterpart of :func:`choose_blocks`.

    The Tucker ranks are never tiled (they are the small dimensions); the
    kept-mode and contraction blocks follow the same alignment-then-shrink
    strategy as the MTTKRP planner, with the same degenerate-extent and
    relax-below-budget guarantees."""
    if memory is None:
        memory = Memory.tpu_vmem(itemsize=itemsize)
    lane, sublane = memory.lane, memory.sublane
    n = len(shape)
    ranks = tuple(int(r) for r in ranks)

    def start(extent: int, unit: int, pref: int) -> int:
        if extent <= unit:
            return max(1, extent)
        return min(_round_up(extent, unit), pref)

    def floor(extent: int, unit: int) -> int:
        return max(1, extent) if extent <= unit else unit

    bi = start(shape[0], sublane, 128)
    bc: list[int] = []
    for d in range(1, n):
        if d == n - 1:
            bc.append(start(shape[d], lane, 128))
        else:
            bc.append(start(shape[d], sublane, max(sublane, 8)))
    fi = floor(shape[0], sublane)
    fc = [
        floor(shape[d], lane if d == n - 1 else sublane) for d in range(1, n)
    ]
    plan = MultiTTMPlan(bi, tuple(bc), ranks)
    while not plan.fits(memory):
        bi = plan.block_i
        bc = list(plan.block_contract)
        if bi > fi:
            bi = max(fi, bi // 2)
        else:
            shrunk = False
            for d in range(len(bc) - 1):
                if bc[d] > fc[d]:
                    bc[d] = max(fc[d], bc[d] // 2)
                    shrunk = True
                    break
            if not shrunk:
                if bc and bc[-1] > fc[-1]:
                    bc[-1] = max(fc[-1], bc[-1] // 2)
                else:
                    break
        plan = MultiTTMPlan(bi, tuple(bc), ranks)
    while not plan.fits(memory):
        dims = [plan.block_i, *plan.block_contract]
        j = max(range(len(dims)), key=lambda k: dims[k])
        if dims[j] <= 1:
            break  # all-1 blocks: the ranks alone exceed this memory
        dims[j] //= 2
        plan = MultiTTMPlan(dims[0], tuple(dims[1:]), ranks)
    return plan


def uniform_multi_ttm_plan(
    dims: Sequence[int], ranks: Sequence[int], memory: Memory | int
) -> MultiTTMPlan:
    """A :class:`MultiTTMPlan` with the paper's uniform b in every tensor
    mode; ``plan.model_words(dims)`` then equals
    ``core.bounds.multi_ttm_blocked_cost(dims, ranks, b)`` exactly."""
    from ..core.bounds import multi_ttm_best_block_size, multi_ttm_blocked_cost

    mem_words = memory.budget_words if isinstance(memory, Memory) else memory
    b = multi_ttm_best_block_size(dims, ranks, mem_words)
    plan = MultiTTMPlan(b, (b,) * (len(dims) - 1), tuple(int(r) for r in ranks))
    assert int(plan.model_words(dims)) == int(
        multi_ttm_blocked_cost(dims, ranks, b)
    )
    return plan


def mttkrp_traffic_model(
    shape: Sequence[int], rank: int, plan: BlockPlan, itemsize: int = 4
) -> dict[str, int]:
    """Back-compat functional spelling of :meth:`BlockPlan.traffic_model`."""
    return plan.traffic_model(shape, rank, itemsize)


# ---------------------------------------------------------------------------
# Uniform-b planning (the paper's exact Eq 9/10 setting; simulator + benches)
# ---------------------------------------------------------------------------

def best_uniform_block(dims: Sequence[int], memory: Memory | int) -> int:
    """Largest uniform b with b^N + N*b <= M (Eq 9); the simulator's and the
    sequential benchmarks' block selection. ``memory`` may be a word count
    or a :class:`Memory` (its word budget is used)."""
    mem_words = memory.budget_words if isinstance(memory, Memory) else memory
    return best_block_size(dims, mem_words)


def uniform_block_feasible(n: int, block: int, memory: Memory | int) -> bool:
    """Eq (9)/(20): b^N + N*b <= M, against a Memory or raw word count."""
    mem_words = memory.budget_words if isinstance(memory, Memory) else memory
    return blocked_feasible_b(n, block, mem_words)


def uniform_plan(dims: Sequence[int], rank: int, memory: Memory | int) -> BlockPlan:
    """A :class:`BlockPlan` with the paper's uniform b in every mode.

    ``plan.eq10_words(dims, rank)`` then equals
    ``core.bounds.seq_blocked_cost(dims, rank, b)`` exactly.
    """
    b = best_uniform_block(dims, memory)
    plan = BlockPlan(b, (b,) * (len(dims) - 1), rank)
    assert int(plan.eq10_words(dims, rank)) == int(
        seq_blocked_cost(dims, rank, b)
    )
    return plan
