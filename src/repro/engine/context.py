"""ExecutionContext: the one immutable configuration object for the stack.

The paper's thesis is that a single machine description — fast-memory size
M, processor count P, the processor grid — determines the optimal schedule
for *every* MTTKRP in a CP run (Eq 9/10 sequentially, Eq 12/16 in
parallel).  Three PRs in, that machine description had fragmented into a
kwarg soup: every driver (``engine.execute.mttkrp``, ``contract_partial``,
the dimension tree, ``cp_als``/``cp_gradient``, Algorithms 3/4, the
distributed sweep) re-declared and re-validated
``backend/memory/interpret/tune/check_rep/mesh/grid/procs`` with drifting
error messages.  This module replaces all of that:

* :class:`ExecutionContext` — a frozen, hashable dataclass bundling the
  full execution environment: backend choice, :class:`~.plan.Memory`,
  dtype policy, ``interpret``, the tuning policy (``tune`` + plan-cache
  handle), and a :class:`Distribution` sub-config (grid/procs/mesh,
  ``check_rep``).  Built once, validated once (eagerly, in
  ``__post_init__`` — so every construction path validates), consumed
  everywhere.
* :meth:`ExecutionContext.create` — the single constructor every driver's
  deprecated-kwarg shim routes through; *all* option validation lives
  here (one error-message catalog, see :func:`check_backend` and
  friends).
* :meth:`ExecutionContext.for_problem` — eager ``"auto"`` resolution:
  the processor grid is selected once (via
  :func:`repro.distributed.grid_select.choose_cp_grid`) and the per-mode
  plan decisions are resolved once against the tune cache, so drivers
  *replay* decisions instead of re-deriving them per mode/iteration.
* :meth:`ExecutionContext.to_json` / :meth:`~ExecutionContext.from_json`
  — a tuned/validated setup is a portable artifact: benchmarks record
  it, ``REPRO_CONTEXT`` (a path or an inline JSON string) seeds the
  default context of a fresh process, and ``from_json(to_json(ctx))``
  reproduces the identical plan resolutions.

Layering: this module may import :mod:`.plan` at module scope; everything
else (tune cache, grid selection, meshes) is imported inside methods so
``core``/``distributed``/``tune`` can keep their call-time-only imports of
the engine package.
"""

from __future__ import annotations

import json
import os
import warnings
from dataclasses import dataclass, field, fields, replace
from typing import Any, Mapping, Sequence

from .plan import BlockPlan, Memory, MultiTTMPlan

SCHEMA = "repro.ExecutionContext/1"
ENV_CONTEXT = "REPRO_CONTEXT"

#: Concrete executors plus the autotuner-resolved pseudo-backend.
VALID_BACKENDS = ("einsum", "blocked_host", "pallas", "auto")


class _Unset:
    """Sentinel distinguishing 'kwarg not passed' from an explicit value
    (needed so the deprecation shims only fire on actual legacy usage)."""

    _instance = None

    def __new__(cls):
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self):  # pragma: no cover - debug aid
        return "<unset>"


UNSET = _Unset()


# ---------------------------------------------------------------------------
# The validation catalog: ONE home for every option error in the stack
# ---------------------------------------------------------------------------

def check_backend(backend: str) -> None:
    """The single backend validator (replaces ``execute._check_backend``
    and the per-driver copies). Lists the valid values."""
    if backend not in VALID_BACKENDS:
        raise ValueError(
            f"unknown backend {backend!r}; expected one of "
            f"{VALID_BACKENDS} (einsum/blocked_host/pallas run directly, "
            f"'auto' resolves through the tune cache)"
        )


def _err_tune_distributed() -> ValueError:
    return ValueError(
        "tune=True is not supported on the distributed path "
        "(nothing can be measured under the shard_map trace); "
        "pre-tune the local shard shapes with "
        "mttkrp(..., ctx=ExecutionContext.create(backend='auto', "
        "tune=True)), then run distributed with backend='auto' to "
        "replay the cache"
    )


def _err_mttkrp_fn_distributed() -> ValueError:
    return ValueError(
        "mttkrp_fn cannot be combined with the distributed path "
        "(the sweep driver owns the collectives); drop mttkrp_fn or the "
        "distributed options (distributed/mesh/grid/procs)"
    )


def _err_dimtree_distributed() -> ValueError:
    return ValueError(
        "use_dimension_tree is not supported with distributed=True "
        "(the stationary sweep already amortizes factor gathers across "
        "all modes); drop one of the two options"
    )


def check_driver_options(
    ctx: "ExecutionContext",
    *,
    mttkrp_fn: Any = None,
    use_dimension_tree: bool = False,
) -> None:
    """Validate per-call driver arguments that are not part of the context
    (callables cannot be frozen/serialized) against it — the CP drivers'
    entire option validation, unified."""
    if ctx.is_distributed:
        if mttkrp_fn is not None:
            raise _err_mttkrp_fn_distributed()
        if use_dimension_tree:
            raise _err_dimtree_distributed()


# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Distribution:
    """The parallel-machine description (§V): processor grid, count, the
    optional rank-axis extent ``p0`` (Algorithm 4), and the shard_map
    replication-check policy.

    ``mesh`` is a process-local device handle: it is excluded from
    equality/hash/serialization (a context round-trips through JSON by its
    *grid*; the mesh is rebuilt on the target process, where the device
    topology may differ).
    """

    grid: tuple[int, ...] | None = None
    procs: int | None = None
    p0: int = 1
    check_rep: bool | None = None
    overlap: str = "none"
    mesh: Any = field(default=None, compare=False, repr=False)

    def __post_init__(self):
        if self.overlap not in ("none", "ring"):
            raise ValueError(
                f"overlap must be 'none' or 'ring' (ring = ppermute-chunked "
                f"collectives overlapping the local MTTKRP), got "
                f"{self.overlap!r}"
            )
        if self.grid is not None:
            object.__setattr__(self, "grid", tuple(int(g) for g in self.grid))
            from ..distributed.mesh import validate_grid  # layer cycle

            # device-count fit is checked when the mesh is built (the
            # context itself must stay portable across machines)
            validate_grid(self.grid, self.p0, check_devices=False)
        if self.procs is not None and self.procs < 1:
            raise ValueError(f"procs must be >= 1, got {self.procs}")
        if self.p0 < 1:
            raise ValueError(f"p0 must be >= 1, got {self.p0}")

    def to_dict(self) -> dict:
        return {
            "grid": list(self.grid) if self.grid is not None else None,
            "procs": self.procs,
            "p0": self.p0,
            "check_rep": self.check_rep,
            "overlap": self.overlap,
        }

    @classmethod
    def from_dict(cls, d: Mapping) -> "Distribution":
        grid = d.get("grid")
        return cls(
            grid=tuple(grid) if grid is not None else None,
            procs=d.get("procs"),
            p0=int(d.get("p0", 1)),
            check_rep=d.get("check_rep"),
            overlap=str(d.get("overlap", "none")),
        )


@dataclass(frozen=True)
class ProblemSpec:
    """The (shape, rank, dtype) a context's decisions were resolved for.

    ``rank`` is the CP rank (int) or — for a Multi-TTM/Tucker problem —
    the tuple of per-mode Tucker ranks ``(R_1, ..., R_N)``."""

    shape: tuple[int, ...]
    rank: int | tuple[int, ...]
    dtype: str = "float32"

    def __post_init__(self):
        object.__setattr__(self, "shape", tuple(int(s) for s in self.shape))
        if isinstance(self.rank, (tuple, list)):
            object.__setattr__(
                self, "rank", tuple(int(r) for r in self.rank)
            )

    @property
    def is_multi_ttm(self) -> bool:
        return isinstance(self.rank, tuple)

    def to_dict(self) -> dict:
        rank = list(self.rank) if isinstance(self.rank, tuple) else self.rank
        return {"shape": list(self.shape), "rank": rank,
                "dtype": self.dtype}

    @classmethod
    def from_dict(cls, d: Mapping) -> "ProblemSpec":
        rank = d["rank"]
        rank = tuple(int(r) for r in rank) if isinstance(rank, list) \
            else int(rank)
        return cls(tuple(d["shape"]), rank, str(d["dtype"]))


@dataclass(frozen=True)
class PlanDecision:
    """One replayed ``backend="auto"`` resolution: how mode ``mode`` of the
    pinned problem runs (backend, exact BlockPlan, kernel variant,
    host-blocking size), and whether it came from the tune cache."""

    mode: int
    backend: str
    plan: BlockPlan | MultiTTMPlan | None = None
    variant: str | None = None
    block: int | None = None
    cache_hit: bool = False

    def __post_init__(self):
        # a decision is a RESOLVED choice: only concrete executors are
        # legal (a corrupt/hand-edited "auto" here would otherwise fall
        # through the dispatch layer into the pallas branch)
        if self.backend not in ("einsum", "blocked_host", "pallas"):
            raise ValueError(
                f"PlanDecision backend must be a concrete executor "
                f"(einsum/blocked_host/pallas), got {self.backend!r}"
            )

    def to_dict(self) -> dict:
        # single source of plan (de)serialization: the tune cache's
        # (pinned decisions and cache entries must never drift apart)
        from ..tune.cache import plan_to_dict  # layer cycle

        return {
            "mode": self.mode,
            "backend": self.backend,
            "plan": plan_to_dict(self.plan) if self.plan is not None
            else None,
            "variant": self.variant,
            "block": self.block,
            "cache_hit": self.cache_hit,
        }

    @classmethod
    def from_dict(cls, d: Mapping) -> "PlanDecision":
        from ..tune.cache import plan_from_dict  # layer cycle

        plan = d.get("plan")
        return cls(
            mode=int(d["mode"]),
            backend=str(d["backend"]),
            plan=plan_from_dict(plan) if plan is not None else None,
            variant=d.get("variant"),
            block=d.get("block"),
            cache_hit=bool(d.get("cache_hit", False)),
        )


# ---------------------------------------------------------------------------
# The context
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ExecutionContext:
    """The full execution environment, as one immutable, hashable value.

    Prefer the constructors: :meth:`create` (validate everything eagerly),
    :meth:`for_problem` (additionally resolve every ``"auto"`` choice —
    grid, per-mode plans — exactly once), :meth:`from_json` /
    :meth:`from_env` (replay a recorded setup).  Direct construction also
    validates (``__post_init__``), so an invalid context cannot exist.
    """

    backend: str = "einsum"
    memory: Memory | None = None
    out_dtype: str | None = None
    compute_dtype: str | None = None
    interpret: bool | None = None
    tune: bool = False
    cache_path: str | None = None
    distribution: Distribution | None = None
    problem: ProblemSpec | None = None
    decisions: tuple[PlanDecision, ...] = ()
    #: Opt this context's driver calls into the observability layer
    #: (span events into the active repro.observe.Trace, per-sweep
    #: collective-bytes measurement on the distributed drivers). Off by
    #: default: the False path adds no ops and no trace-unsafe work, so
    #: compiled HLO is identical to a pre-observability build.
    observe: bool = False
    #: Directory for JAX's persistent compilation cache
    #: (``jax.experimental.compilation_cache``). When set, drivers and the
    #: serving layer call :meth:`ensure_compilation_cache` before their
    #: first dispatch, so a *second* process serving the same buckets
    #: warm-starts: XLA reloads the compiled programs from disk instead of
    #: recompiling (the cold/warm split ``benchmarks/serve.py`` measures).
    #: None (the default) leaves the process-global JAX config untouched.
    compilation_cache: str | None = None

    # -- eager validation (every construction path runs this) --------------
    def __post_init__(self):
        check_backend(self.backend)
        if self.memory is not None and not isinstance(self.memory, Memory):
            raise ValueError(
                f"memory must be a repro.Memory (e.g. Memory.tpu_vmem() or "
                f"Memory.abstract(words)), got {type(self.memory).__name__}"
            )
        if self.out_dtype is not None:
            import jax.numpy as jnp

            try:
                jnp.dtype(self.out_dtype)
            except TypeError as e:
                raise ValueError(
                    f"out_dtype {self.out_dtype!r} is not a dtype: {e}"
                ) from None
        if self.compute_dtype is not None:
            import jax.numpy as jnp

            try:
                dt = jnp.dtype(self.compute_dtype)
            except TypeError as e:
                raise ValueError(
                    f"compute_dtype {self.compute_dtype!r} is not a dtype: "
                    f"{e}"
                ) from None
            if not jnp.issubdtype(dt, jnp.floating):
                raise ValueError(
                    f"compute_dtype must be a float dtype (inputs are cast "
                    f"to it; accumulation stays fp32), got "
                    f"{self.compute_dtype!r}"
                )
        if self.tune and self.is_distributed:
            raise _err_tune_distributed()
        if self.tune and self.backend != "auto":
            raise ValueError(
                f"tune=True requires backend='auto' (the search persists "
                f"winners the auto path replays); got "
                f"backend={self.backend!r}"
            )
        object.__setattr__(self, "decisions", tuple(self.decisions))
        if self.decisions and self.problem is None:
            raise ValueError(
                "decisions without a problem spec: use for_problem(...) "
                "to pin plan resolutions"
            )
        if self.compilation_cache is not None and not isinstance(
            self.compilation_cache, str
        ):
            raise ValueError(
                f"compilation_cache must be a directory path (str) or "
                f"None, got {type(self.compilation_cache).__name__}"
            )

    def ensure_compilation_cache(self) -> str | None:
        """Point JAX's persistent compilation cache at this context's
        ``compilation_cache`` directory (no-op when the field is None).

        Sets the process-global JAX config — cache dir plus the two
        thresholds that would otherwise skip small CPU programs — so
        every compile after this call is written to (and on a warm
        start, read from) the directory. Idempotent; returns the
        directory actually configured. This is the MaxText
        microbenchmark warm-start pattern: a fresh process pays zero
        recompiles for buckets an earlier process already served.
        """
        if self.compilation_cache is None:
            return None
        import jax

        os.makedirs(self.compilation_cache, exist_ok=True)
        already = (
            jax.config.jax_compilation_cache_dir == self.compilation_cache
        )
        jax.config.update("jax_compilation_cache_dir", self.compilation_cache)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
        if not already:
            # the persistent-cache singleton is memoized at the process's
            # FIRST compile; without a reset, a dir configured after that
            # compile is silently ignored
            from jax.experimental.compilation_cache import (
                compilation_cache as _cc,
            )

            _cc.reset_cache()
        return self.compilation_cache

    # -- constructors -------------------------------------------------------
    @classmethod
    def create(
        cls,
        backend: str = "einsum",
        *,
        memory: Memory | None = None,
        out_dtype=None,
        compute_dtype=None,
        interpret: bool | None = None,
        tune: bool = False,
        cache_path: str | None = None,
        distributed: bool = False,
        mesh=None,
        grid: Sequence[int] | None = None,
        procs: int | None = None,
        p0: int = 1,
        check_rep: bool | None = None,
        overlap: str = "none",
        observe: bool = False,
        compilation_cache: str | None = None,
    ) -> "ExecutionContext":
        """Build and eagerly validate a context — THE constructor.

        Any of ``distributed=True`` / ``mesh`` / ``grid`` / ``procs``
        selects the distributed path (a :class:`Distribution` sub-config
        is attached); an explicit ``mesh`` wins over ``grid`` wins over
        automatic Eq (12) selection for ``procs`` processors.
        """
        dist = None
        if distributed or mesh is not None or grid is not None \
                or procs is not None or overlap != "none":
            if mesh is not None and grid is None:
                # derive the grid from the mesh axes (m0..m{N-1}, opt. r)
                names = [n for n in mesh.axis_names if n != "r"]
                grid = tuple(mesh.shape[n] for n in names)
                if "r" in mesh.axis_names:
                    p0 = mesh.shape["r"]
            dist = Distribution(
                grid=tuple(grid) if grid is not None else None,
                procs=procs, p0=p0, check_rep=check_rep, overlap=overlap,
                mesh=mesh,
            )
        if out_dtype is not None and not isinstance(out_dtype, str):
            import jax.numpy as jnp

            out_dtype = jnp.dtype(out_dtype).name
        if compute_dtype is not None and not isinstance(compute_dtype, str):
            import jax.numpy as jnp

            compute_dtype = jnp.dtype(compute_dtype).name
        return cls(
            backend=backend, memory=memory, out_dtype=out_dtype,
            compute_dtype=compute_dtype, interpret=interpret, tune=tune,
            cache_path=cache_path, distribution=dist,
            observe=bool(observe), compilation_cache=compilation_cache,
        )

    @classmethod
    def for_problem(
        cls,
        shape: Sequence[int],
        rank: int,
        dtype="float32",
        **kwargs,
    ) -> "ExecutionContext":
        """:meth:`create` + resolve every ``"auto"`` choice for the given
        problem, exactly once: the grid (Eq 12 sweep-optimal via
        ``choose_cp_grid``) and — for ``backend="auto"`` without ``tune``
        — the per-mode plan decisions from the tune cache (miss →
        analytic model-best). Drivers then *replay* these decisions
        instead of re-deriving them per mode/iteration. With
        ``tune=True`` decisions stay unpinned: the empirical search runs
        at the first driver call on concrete data and persists winners
        the cache then replays.

        ``rank`` may also be the tuple of per-mode Tucker ranks, pinning
        a Multi-TTM/Tucker problem (see :meth:`resolve_for`)."""
        return cls.create(**kwargs).resolve_for(shape, rank, dtype)

    def resolve_for(self, shape, rank, dtype="float32") \
            -> "ExecutionContext":
        """Pin this context to one problem: validate grid-vs-extent
        feasibility, select an unresolved grid, check memory-vs-plan
        feasibility, and resolve the per-mode ``"auto"`` decisions.

        ``rank`` is the CP rank (int) or the tuple of per-mode Tucker
        ranks — the latter pins a Multi-TTM/Tucker problem instead: the
        grid comes from the Multi-TTM sweep objective
        (``choose_tucker_grid``) and the ``"auto"`` decisions are the
        per-kept-mode ``kind="multi_ttm"`` resolutions (one per HOOI
        mode update plus one for the full core, keyed ``mode=-1``)."""
        import jax.numpy as jnp

        shape = tuple(int(s) for s in shape)
        dtype_name = jnp.dtype(dtype).name
        is_tucker = isinstance(rank, (tuple, list))
        rank = tuple(int(r) for r in rank) if is_tucker else int(rank)
        problem = ProblemSpec(shape, rank, dtype_name)
        if is_tucker and len(rank) != len(shape):
            raise ValueError(
                f"Tucker ranks {rank} must give one rank per tensor mode "
                f"({len(shape)} for shape {shape})"
            )
        dist = self.distribution
        if dist is not None and is_tucker:
            from ..distributed.grid_select import choose_tucker_grid
            from ..distributed.mesh import validate_tucker_grid

            grid = dist.grid
            if grid is None:
                procs = dist.procs
                if procs is None:
                    import jax

                    procs = len(jax.devices())
                grid = choose_tucker_grid(shape, rank, procs).grid
            validate_tucker_grid(grid, dims=shape, check_devices=False)
            dist = replace(dist, grid=tuple(grid))
        elif dist is not None:
            from ..distributed.grid_select import choose_cp_grid
            from ..distributed.mesh import validate_grid

            grid = dist.grid
            if grid is None:
                procs = dist.procs
                if procs is None:
                    import jax

                    procs = len(jax.devices())
                grid = choose_cp_grid(shape, rank, procs).grid
            validate_grid(
                grid, dist.p0, dims=shape, rank=rank, check_devices=False
            )
            dist = replace(dist, grid=tuple(grid))
        decisions: tuple[PlanDecision, ...] = ()
        if is_tucker and self.backend == "auto" and not self.tune \
                and dist is None:
            from ..tune.search import resolve_multi_ttm  # layer cycle

            cache = self.plan_cache()
            out = []
            for keep_key in (-1,) + tuple(range(len(shape))):
                lead = 0 if keep_key == -1 else keep_key
                canon = (shape[lead],) + tuple(
                    s for k, s in enumerate(shape) if k != lead
                )
                contracted = tuple(
                    r for k, r in enumerate(rank) if k != keep_key
                )
                r = resolve_multi_ttm(
                    canon, contracted, keep_key, jnp.dtype(dtype_name),
                    self.memory, cache=cache,
                )
                out.append(PlanDecision(
                    keep_key, r.backend, r.plan, r.variant, r.block,
                    r.cache_hit,
                ))
            decisions = tuple(out)
            return replace(
                self, distribution=dist, problem=problem,
                decisions=decisions,
            )
        if is_tucker:
            if self.memory is not None:
                # the budget must admit SOME plan for EVERY Multi-TTM the
                # Tucker/HOOI workload runs: each kept mode (whose kernel
                # contracts the other N-1 ranks) and the full core
                from .plan import choose_multi_ttm_blocks

                for keep_key in (-1,) + tuple(range(len(shape))):
                    lead = 0 if keep_key == -1 else keep_key
                    canon = (shape[lead],) + tuple(
                        s for k, s in enumerate(shape) if k != lead
                    )
                    kernel_ranks = tuple(
                        r for k, r in enumerate(rank) if k != lead
                    )
                    plan = choose_multi_ttm_blocks(
                        canon, kernel_ranks, self.memory.itemsize,
                        memory=self.memory,
                    )
                    if not plan.fits(self.memory):
                        what = (
                            "the full core" if keep_key == -1
                            else f"the keep={keep_key} HOOI update"
                        )
                        raise ValueError(
                            f"memory budget {self.memory.budget_bytes}B "
                            f"admits no feasible Multi-TTM plan for "
                            f"{what} of shape={shape}, ranks={rank} "
                            f"(minimal working set "
                            f"{plan.working_set_words() * self.memory.itemsize}"
                            f"B); raise the budget or shrink the ranks"
                        )
            return replace(
                self, distribution=dist, problem=problem,
                decisions=decisions,
            )
        if self.backend == "auto" and not self.tune and dist is None:
            # tune=True deliberately pins NOTHING: the empirical search
            # needs concrete data to measure, so it runs at the first
            # driver call (engine.execute's live path) and later calls
            # replay the persisted winner from the cache. Pinning here
            # would freeze the un-tuned model-best and the search would
            # silently never happen. Distributed contexts pin only the
            # grid: their engine work runs on per-SHARD shapes inside
            # shard_map, so global-shape decisions could never replay.
            from ..tune.search import resolve  # layer cycle

            cache = self.plan_cache()
            out = []
            for mode in range(len(shape)):
                perm = (shape[mode],) + tuple(
                    s for k, s in enumerate(shape) if k != mode
                )
                r = resolve(
                    perm, rank, mode, jnp.dtype(dtype_name), self.memory,
                    cache=cache,
                )
                out.append(PlanDecision(
                    mode, r.backend, r.plan, r.variant, r.block,
                    r.cache_hit,
                ))
            decisions = tuple(out)
        elif self.memory is not None:
            # memory-vs-plan feasibility: the budget must admit SOME plan
            from .plan import choose_blocks

            plan = choose_blocks(
                shape, rank, self.memory.itemsize, memory=self.memory
            )
            if not plan.fits(self.memory):
                raise ValueError(
                    f"memory budget {self.memory.budget_bytes}B admits no "
                    f"Eq-9-feasible plan for shape={shape}, rank={rank} "
                    f"(minimal working set "
                    f"{plan.working_set_words() * self.memory.itemsize}B); "
                    f"raise the budget or shrink the rank"
                )
        return replace(
            self, distribution=dist, problem=problem, decisions=decisions
        )

    # -- queries -------------------------------------------------------------
    @property
    def is_distributed(self) -> bool:
        return self.distribution is not None

    def decision_for(self, shape, rank: int, mode: int, dtype=None) \
            -> PlanDecision | None:
        """The pinned ``"auto"`` decision for ``mode`` — or None when this
        context was not resolved for exactly this (shape, rank, dtype).
        The dtype is part of the identity: a plan blocked for 4-byte items
        must not replay on 8-byte data (Eq-9 working set doubles)."""
        if self.problem is None:
            return None
        if self.problem.shape != tuple(shape) or self.problem.rank != rank:
            return None
        if dtype is not None:
            import jax.numpy as jnp

            if jnp.dtype(dtype).name != self.problem.dtype:
                return None
        for d in self.decisions:
            if d.mode == mode:
                return d
        return None

    def plan_cache(self):
        """The tune-cache handle this context reads/writes
        (``cache_path`` override, else the process default)."""
        from ..tune.cache import PlanCache, default_cache  # layer cycle

        if self.cache_path is not None:
            return PlanCache(self.cache_path)
        return default_cache()

    def local(self) -> "ExecutionContext":
        """The per-shard view of a distributed context: same engine knobs,
        no distribution (the collectives are owned by the sweep driver;
        inside each shard the problem is exactly the sequential one)."""
        if self.distribution is None:
            return self
        return replace(
            self, distribution=None, problem=None, decisions=()
        )

    def build_mesh(self, shape=None, rank: int | None = None):
        """The device mesh for the distributed path (explicit mesh wins;
        else built from the resolved grid — this is where device-count
        feasibility is enforced, since it is machine-local)."""
        if self.distribution is None:
            raise ValueError(
                "build_mesh() on a non-distributed context; pass "
                "distributed=True / grid= / procs= to create()"
            )
        if self.distribution.mesh is not None:
            return self.distribution.mesh
        if self.distribution.grid is None:
            raise ValueError(
                "no grid resolved yet: call resolve_for(shape, rank) / "
                "for_problem(...) first, or pass grid= explicitly"
            )
        from ..distributed.mesh import make_grid_mesh

        return make_grid_mesh(
            self.distribution.grid, p0=self.distribution.p0,
            dims=shape, rank=rank,
        )

    def build_abstract_mesh(self):
        """Device-free twin of :meth:`build_mesh`: an ``AbstractMesh``
        over the same grid. Enough to *trace* the distributed sweep
        (``jax.make_jaxpr``) with no devices at all — the static
        communication verifier (``repro.verify.comm``) analyzes grids
        far larger than the host this way. Never resolvable to devices;
        running a program built on it raises inside jax."""
        if self.distribution is None:
            raise ValueError(
                "build_abstract_mesh() on a non-distributed context; pass "
                "distributed=True / grid= / procs= to create()"
            )
        if self.distribution.grid is None:
            raise ValueError(
                "no grid resolved yet: call resolve_for(shape, rank) / "
                "for_problem(...) first, or pass grid= explicitly"
            )
        from ..distributed.mesh import make_abstract_grid_mesh

        return make_abstract_grid_mesh(
            self.distribution.grid, p0=self.distribution.p0
        )

    # -- serialization -------------------------------------------------------
    def to_dict(self) -> dict:
        mem = None
        if self.memory is not None:
            mem = {
                "budget_bytes": self.memory.budget_bytes,
                "lane": self.memory.lane,
                "sublane": self.memory.sublane,
                "itemsize": self.memory.itemsize,
            }
        return {
            "schema": SCHEMA,
            "backend": self.backend,
            "memory": mem,
            "out_dtype": self.out_dtype,
            "compute_dtype": self.compute_dtype,
            "interpret": self.interpret,
            "tune": self.tune,
            "cache_path": self.cache_path,
            "distribution": (
                self.distribution.to_dict()
                if self.distribution is not None else None
            ),
            "problem": (
                self.problem.to_dict() if self.problem is not None else None
            ),
            "decisions": [d.to_dict() for d in self.decisions],
            "observe": self.observe,
            "compilation_cache": self.compilation_cache,
        }

    @classmethod
    def from_dict(cls, d: Mapping) -> "ExecutionContext":
        schema = d.get("schema", SCHEMA)
        if schema != SCHEMA:
            raise ValueError(
                f"unsupported ExecutionContext schema {schema!r} "
                f"(this build reads {SCHEMA!r})"
            )
        mem = d.get("memory")
        if mem is not None:
            mem = Memory(
                budget_bytes=int(mem["budget_bytes"]),
                lane=int(mem.get("lane", 1)),
                sublane=int(mem.get("sublane", 1)),
                itemsize=int(mem.get("itemsize", 4)),
            )
        dist = d.get("distribution")
        prob = d.get("problem")
        return cls(
            backend=str(d.get("backend", "einsum")),
            memory=mem,
            out_dtype=d.get("out_dtype"),
            compute_dtype=d.get("compute_dtype"),
            interpret=d.get("interpret"),
            tune=bool(d.get("tune", False)),
            cache_path=d.get("cache_path"),
            distribution=(
                Distribution.from_dict(dist) if dist is not None else None
            ),
            problem=ProblemSpec.from_dict(prob) if prob is not None else None,
            decisions=tuple(
                PlanDecision.from_dict(x) for x in d.get("decisions", ())
            ),
            # absent in pre-observability JSON: old artifacts stay loadable
            observe=bool(d.get("observe", False)),
            # absent in pre-serving JSON: old artifacts stay loadable
            compilation_cache=d.get("compilation_cache"),
        )

    def to_json(self, *, indent: int | None = None) -> str:
        """Serialize (portably — no device handles) for recording in
        benchmark rows, files, or ``REPRO_CONTEXT``."""
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "ExecutionContext":
        """Inverse of :meth:`to_json`: ``from_json(ctx.to_json()) == ctx``
        (the mesh handle, which is process-local, excepted)."""
        return cls.from_dict(json.loads(s))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json(indent=1))

    @classmethod
    def load(cls, path: str) -> "ExecutionContext":
        with open(path) as f:
            return cls.from_json(f.read())

    @classmethod
    def from_env(cls) -> "ExecutionContext | None":
        """The ``REPRO_CONTEXT`` seed: a path to a context JSON file, or
        the JSON text itself. None when the variable is unset."""
        raw = os.environ.get(ENV_CONTEXT)
        if not raw:
            return None
        if os.path.exists(raw):
            return cls.load(raw)
        return cls.from_json(raw)

    @classmethod
    def default(cls) -> "ExecutionContext":
        """What a driver uses when handed neither ``ctx`` nor legacy
        kwargs: the ``REPRO_CONTEXT`` seed if set, else the stock einsum
        context. Memoized on the raw env value — bare driver calls in
        hot loops must not re-read files or re-parse JSON."""
        raw = os.environ.get(ENV_CONTEXT) or ""
        cached = _DEFAULT_MEMO.get(raw)
        if cached is None:
            cached = cls.from_env() or cls()
            _DEFAULT_MEMO.clear()  # env changed: old seeds are stale
            _DEFAULT_MEMO[raw] = cached
        return cached


# ---------------------------------------------------------------------------
# The deprecated-kwarg shim (one release of backward compatibility)
# ---------------------------------------------------------------------------

# memo for ExecutionContext.default(), keyed by the raw REPRO_CONTEXT value
_DEFAULT_MEMO: dict[str, "ExecutionContext"] = {}

_CREATE_KEYS = (
    {f.name for f in fields(ExecutionContext)}
    | {"distributed", "mesh", "grid", "procs", "p0", "check_rep", "overlap"}
) - {"distribution", "problem", "decisions"}


def context_from_legacy(
    api: str,
    ctx: "ExecutionContext | None",
    legacy: Mapping[str, Any],
    *,
    stacklevel: int = 3,
) -> "ExecutionContext":
    """Resolve one driver call's configuration: ``ctx`` if given, else a
    context built from the legacy kwargs (with exactly one
    :class:`DeprecationWarning` naming the new spelling), else the
    process default.

    ``legacy`` maps old kwarg names to values, with :data:`UNSET` marking
    kwargs the caller did not pass — only actually-passed kwargs trigger
    the warning, so ``mttkrp(x, factors, mode)`` stays silent.
    """
    used = {k: v for k, v in legacy.items() if v is not UNSET}
    if ctx is not None:
        if used:
            raise TypeError(
                f"{api}: pass either ctx= or the legacy keyword arguments "
                f"({', '.join(sorted(used))}), not both — the context "
                f"already carries the full configuration"
            )
        return ctx
    if not used:
        return ExecutionContext.default()
    unknown = set(used) - _CREATE_KEYS
    if unknown:  # pragma: no cover - shims only forward known keys
        raise TypeError(f"{api}: unknown options {sorted(unknown)}")
    warnings.warn(
        f"{api}: passing execution options as keyword arguments "
        f"({', '.join(sorted(used))}) is deprecated and will be removed "
        f"in the next release; build one ExecutionContext instead — "
        f"ctx = repro.ExecutionContext.create(...) and call "
        f"{api}(..., ctx=ctx)",
        DeprecationWarning,
        stacklevel=stacklevel,
    )
    return ExecutionContext.create(**used)
