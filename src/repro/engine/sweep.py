"""Fused ALS sweeps: the arXiv:1708.08976 mode-reuse schedule on the
engine's dispatch layer.

Plain Gauss-Seidel ALS re-reads the tensor once per mode (N passes per
sweep). The fused schedule reuses the contraction ``P' = X x_{N-1}
A_{N-1}`` — computed with *pre-sweep* factors — for every mode but the
last:

    P'  = X  x_{N-1} A_{N-1}       pre-sweep factors (1st tensor pass)
    B0  = P' x_{1..N-2} A_d        every dropped factor pre-sweep
    ... solve mode 0 ...; then for m = 1 .. N-2:
    B_m = P' x_{d != m} A_d        A_0..A_{m-1} updated, rest pre-sweep
    ... solve mode m ...; finally
    B_{N-1} = full MTTKRP          all factors updated (2nd tensor pass)

Two tensor passes per sweep instead of N, and every mode's update consumes
exactly the factor values plain sequential ALS would use — the sweep is
Gauss-Seidel *exact*, not an approximation (results differ only by
floating-point summation order).

On the ``pallas`` backend the opening ``(B0, P')`` pair is ONE two-output
``pallas_call`` (:mod:`repro.kernels.sweep`) that reads each X tile once —
a single dispatch replacing the first two launches of the per-mode chain,
with both accumulators VMEM-resident (the mode-reuse working set,
:func:`repro.engine.plan.fused_pair_working_set_words`). Other backends
compute the same two nodes as two ``contract_partial`` calls (still two
tensor passes total).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..observe import trace as _otrace
from .context import ExecutionContext
from .execute import _count_pallas, _span_plan, contract_partial, mttkrp


def _fused_pair(x: jax.Array, factors, ctx: ExecutionContext):
    """The sweep's opening ``(B0, P')`` pair. One pallas dispatch on the
    pallas backend; two ``contract_partial`` calls elsewhere (``auto``
    resolves each edge through the tune cache as usual)."""
    import time

    n = x.ndim
    modes = tuple(range(n))
    inner = tuple(range(n - 1))
    if ctx.backend == "pallas":
        from ..kernels.sweep import fused_pair_canonical_pallas
        from .plan import choose_sweep_blocks

        orig_dtype = x.dtype
        fs = [f for f in factors[1:]]
        if ctx.compute_dtype is not None:
            cd = jnp.dtype(ctx.compute_dtype)
            x = x.astype(cd)
            fs = [f.astype(cd) for f in fs]
        plan = None
        if ctx.memory is not None:
            mem = ctx.memory.with_itemsize(x.dtype.itemsize)
            plan = choose_sweep_blocks(
                x.shape, fs[0].shape[1], x.dtype.itemsize, memory=mem
            )
        _count_pallas()
        if not _otrace.should_record(ctx.observe, x, *fs):
            return fused_pair_canonical_pallas(
                x, fs, plan=plan, interpret=ctx.interpret,
                out_dtype=orig_dtype,
            )
        t0 = time.perf_counter()
        with _otrace.annotated("repro.fused_pair"):
            out = fused_pair_canonical_pallas(
                x, fs, plan=plan, interpret=ctx.interpret,
                out_dtype=orig_dtype,
            )
        _otrace.record_event(
            "fused_pair",
            shape=list(x.shape),
            rank=int(fs[0].shape[1]),
            backend="pallas",
            plan=_span_plan(plan),
            itemsize=int(x.dtype.itemsize),
            wall_time_us=(time.perf_counter() - t0) * 1e6,
            compute_dtype=ctx.compute_dtype,
            out_dtype=ctx.out_dtype,
        )
        return out
    p = contract_partial(x, factors, modes, (n - 1,), False, ctx=ctx)
    b0 = contract_partial(
        p, factors, inner, tuple(range(1, n - 1)), True, ctx=ctx
    )
    return b0, p


def fused_als_sweep(
    x: jax.Array,
    factors: list[jax.Array],
    update_fn: Callable[[int, jax.Array], jax.Array],
    *,
    ctx: ExecutionContext | None = None,
) -> None:
    """One Gauss-Seidel ALS sweep under the mode-reuse schedule.

    Same contract as :func:`repro.engine.tree.dimtree_als_sweep`:
    ``update_fn(mode, b)`` receives mode ``mode``'s MTTKRP computed with
    all modes < mode already updated, returns the new factor, and may keep
    its own side state; ``factors`` is updated in place. Tensors with
    fewer than 3 modes fall back to the per-mode chain (nothing to reuse).
    """
    if ctx is None:
        ctx = ExecutionContext.default()
    n = x.ndim
    if n < 3:
        for mode in range(n):
            factors[mode] = update_fn(mode, mttkrp(x, factors, mode, ctx=ctx))
        return
    inner = tuple(range(n - 1))
    b0, p = _fused_pair(x, factors, ctx)
    factors[0] = update_fn(0, b0)
    for m in range(1, n - 1):
        drop = tuple(d for d in inner if d != m)
        bm = contract_partial(p, factors, inner, drop, True, ctx=ctx)
        factors[m] = update_fn(m, bm)
    factors[n - 1] = update_fn(n - 1, mttkrp(x, factors, n - 1, ctx=ctx))
