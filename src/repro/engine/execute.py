"""The dispatch layer: one ``mttkrp`` entry point over three backends.

Backends
--------
``einsum``        — XLA's contraction (production default off-TPU).
``blocked_host``  — Algorithm 2's blocked schedule expressed as a host-level
                    reshape-einsum (:mod:`repro.core.blocked`); the
                    mid-level oracle for the kernels.
``pallas``        — the blocked VMEM/MXU kernels (Algorithm 2 on TPU),
                    planned by :mod:`repro.engine.plan`.
``auto``          — resolved through the autotuner (:mod:`repro.tune`):
                    plan-cache hit replays the tuned backend/plan exactly;
                    miss falls back to the analytic model-best
                    configuration. ``tune=True`` searches empirically on a
                    miss and persists the winner.

Configuration comes in as ONE :class:`~repro.engine.context.ExecutionContext`
(``ctx=``): backend, Memory, dtype policy, interpret, tuning policy. The
legacy per-call kwargs (``backend=``/``memory=``/``interpret=``/``tune=``)
still work for one release through the deprecation shim, which builds a
context and warns. Per-problem *overrides* (``plan``, ``block``,
``kernel_variant``, ``out_dtype``) stay explicit arguments: they pin one
contraction's execution details, not the machine.

:func:`contract_partial` is the engine's generalized contraction: any
dimension-tree node (tensor x a subset of factors, optionally carrying the
rank axis) is flattened to canonical form, planned, and dispatched through
the same backends — this is what lets the all-mode sweep run kernel-backed.

:func:`multi_ttm` is the second workload class on the same dispatch
skeleton (arXiv:2207.10437): the Tucker/HOSVD contraction of every mode
(or every mode but one) with its own small-rank matrix.  The weight is a
Kronecker product instead of a Khatri-Rao product, so the pallas path
runs the dedicated :mod:`repro.kernels.multi_ttm` kernel under a
:class:`~repro.engine.plan.MultiTTMPlan`, and ``backend="auto"``
resolves ``kind="multi_ttm"`` tune-cache keys.

The kernel imports are lazy: ``kernels.ops`` imports the planner from this
package, so importing kernels first must not re-enter ``engine``.
"""

from __future__ import annotations

import math
import time
from dataclasses import replace
from typing import Sequence

import jax
import jax.numpy as jnp

from ..core.blocked import mttkrp_blocked
from ..core.mttkrp import mttkrp as _einsum_mttkrp
from ..observe import trace as _otrace
from ..observe.metrics import PALLAS_DISPATCHES, registry
from .context import (
    UNSET,
    ExecutionContext,
    check_backend,
    context_from_legacy,
)
from .plan import (
    BlockPlan,
    Memory,
    MultiTTMPlan,
    best_uniform_block,
    choose_blocks,
    choose_multi_ttm_blocks,
)

BACKENDS = ("einsum", "blocked_host", "pallas")

_L = "abcdefghijklmnopqrstuvw"
_RANK = "z"
_RANKS = "ABCDEFGHIJ"  # per-mode Tucker rank letters (Multi-TTM einsum)


def _count_pallas() -> None:
    # instrumentation: how many contractions were dispatched to the Pallas
    # kernels (tests assert the kernel path is actually taken)
    registry().inc(PALLAS_DISPATCHES)


def _span_plan(plan) -> dict | None:
    """Serialize a plan for a span event (the tune cache's codec, so
    span plans and cached plans never drift apart)."""
    if plan is None:
        return None
    from ..tune.cache import plan_to_dict  # lazy: engine <-> tune cycle

    return plan_to_dict(plan)


def _dtype_policy(ctx: ExecutionContext) -> dict:
    return {"compute_dtype": ctx.compute_dtype, "out_dtype": ctx.out_dtype}


def _cast_compute(ctx: ExecutionContext, x, arrays, out_dtype):
    """Apply the context's mixed-precision policy: cast the tensor and the
    factor/matrix operands to ``ctx.compute_dtype`` (the bandwidth win) and
    default the output dtype to the ORIGINAL input dtype, so the policy is
    transparent end to end (bf16 streams, fp32 results). Accumulation
    stays fp32 on every backend: the pallas kernels accumulate in
    ``acc_dtype=float32`` already, and the einsum paths get
    ``preferred_element_type=float32`` when a policy is active.

    Returns ``(x, arrays, out_dtype, active)``."""
    if ctx.compute_dtype is None:
        return x, arrays, out_dtype, False
    cd = jnp.dtype(ctx.compute_dtype)
    if out_dtype is None:
        out_dtype = x.dtype
    x = x.astype(cd)
    arrays = [a.astype(cd) if a is not None else None for a in arrays]
    return x, arrays, out_dtype, True


def _einsum_mttkrp_f32acc(x, factors, mode):
    """The einsum backend under a compute-dtype policy: same contraction as
    ``core.mttkrp.mttkrp`` but with fp32 accumulation forced."""
    from ..core.mttkrp import _einsum_spec

    ins = [f for k, f in enumerate(factors) if k != mode]
    return jnp.einsum(
        _einsum_spec(x.ndim, mode), x, *ins, optimize="optimal",
        preferred_element_type=jnp.float32,
    )


def mttkrp(
    x: jax.Array,
    factors: Sequence[jax.Array],
    mode: int,
    *,
    ctx: ExecutionContext | None = None,
    plan: BlockPlan | None = None,
    block: int | None = None,
    out_dtype=None,
    kernel_variant: str | None = None,
    backend=UNSET,
    memory=UNSET,
    interpret=UNSET,
    tune=UNSET,
) -> jax.Array:
    """MTTKRP through the engine: ``B^(mode)(i, r)``.

    ``ctx`` is the execution environment (see
    :class:`~repro.engine.context.ExecutionContext`); ``plan`` pins
    explicit block sizes for the ``pallas`` backend; ``block`` sets the
    uniform host-blocking size for ``blocked_host`` (defaults to the Eq-9
    optimum for an abstract VMEM-word memory); ``kernel_variant`` forces
    the 3-way specialized vs N-way generic kernel for ``pallas``.

    ``ctx.backend == "auto"`` consults the autotuner: a context pinned via
    ``ExecutionContext.for_problem`` replays its stored decision; else a
    plan-cache hit replays the tuned configuration exactly (no re-search)
    and a miss uses the analytic model-best. ``ctx.tune`` additionally
    runs the empirical search on a miss and persists the winner (skipped
    under tracing, where nothing can be timed — resolution itself is
    trace-safe).
    """
    ctx = context_from_legacy(
        "repro.mttkrp", ctx,
        {"backend": backend, "memory": memory, "interpret": interpret,
         "tune": tune},
    )
    if x.ndim == len(factors) + 1:
        # leading batch axis: B independent MTTKRPs under ONE resolved plan
        return _mttkrp_batched(
            x, factors, mode, ctx, plan, block, out_dtype, kernel_variant,
        )
    if not _otrace.should_record(ctx.observe, x, *factors):
        return _mttkrp_impl(
            x, factors, mode, ctx, plan, block, out_dtype, kernel_variant,
        )
    span: dict = {}
    t0 = time.perf_counter()
    with _otrace.annotated(f"repro.mttkrp.mode{mode}"):
        out = _mttkrp_impl(
            x, factors, mode, ctx, plan, block, out_dtype, kernel_variant,
            _span=span,
        )
    rank = next(f.shape[-1] for k, f in enumerate(factors) if k != mode)
    _record_mttkrp_span(
        "mttkrp", ctx, tuple(x.shape), rank, mode, x.dtype.itemsize,
        span, t0,
    )
    return out


def _record_mttkrp_span(
    kind: str, ctx, shape, rank, mode, itemsize, span, t0, **extra
) -> None:
    """Emit one MTTKRP-shaped dispatch event: resolved backend/plan (as
    filled in by the impl), the Eq-10 modeled words for the plan (the
    model plan against the resolver's default memory when the backend
    carried none), and the Thm-4.1 lower bound, clamped at 0."""
    from ..core.bounds import seq_lb_memory

    mem = ctx.memory or Memory.tpu_vmem(itemsize=itemsize)
    mode_first = _mode_first(shape, mode) if kind == "mttkrp" else shape
    plan = span.get("plan")
    if not isinstance(plan, BlockPlan):
        plan = choose_blocks(
            mode_first, rank, itemsize, memory=mem,
            x_has_rank=bool(span.get("x_has_rank", False)),
        )
    event = {
        "shape": list(shape),
        "rank": int(rank),
        "mode": int(mode),
        "backend": span.get("backend"),
        "plan": _span_plan(span.get("plan")),
        "modeled_words": int(plan.eq10_words(mode_first, rank)),
        "lower_bound_words": max(
            seq_lb_memory(shape, rank, mem.budget_words), 0.0
        ),
        "memory_words": mem.budget_words,
        "itemsize": int(itemsize),
        "wall_time_us": (time.perf_counter() - t0) * 1e6,
        **_dtype_policy(ctx),
        **extra,
    }
    _otrace.record_event(kind, **event)


def _mttkrp_impl(
    x, factors, mode, ctx, plan, block, out_dtype, kernel_variant,
    _span: dict | None = None,
):
    backend = ctx.backend
    memory = ctx.memory
    interpret = ctx.interpret
    if out_dtype is None:
        out_dtype = ctx.out_dtype
    x, factors, out_dtype, mixed = _cast_compute(ctx, x, factors, out_dtype)
    if backend == "auto":
        rank = next(
            f.shape[1] for k, f in enumerate(factors) if k != mode
        )
        decision = ctx.decision_for(x.shape, rank, mode, x.dtype)
        if decision is None:
            # lazy import: engine <-> tune layer cycle
            from ..tune.search import _is_concrete, resolve, tune_mttkrp

            if ctx.tune and _is_concrete(x):
                tune_mttkrp(
                    x, factors, mode, memory=memory, interpret=interpret,
                    cache=ctx.plan_cache(),
                )
            decision = resolve(
                _mode_first(x.shape, mode), rank, mode, x.dtype, memory,
                cache=ctx.plan_cache(),
            )
        backend = decision.backend
        plan = plan if plan is not None else decision.plan
        block = block if block is not None else decision.block
        kernel_variant = kernel_variant or decision.variant
    check_backend(backend)
    if _span is not None:
        _span["backend"] = backend
    if backend == "einsum":
        out = _einsum_mttkrp_f32acc(x, factors, mode) if mixed \
            else _einsum_mttkrp(x, factors, mode)
        return out.astype(out_dtype) if out_dtype is not None else out
    if backend == "blocked_host":
        if block is None:
            mem = memory or Memory.abstract(2 ** 20)
            block = best_uniform_block(x.shape, mem)
        if _span is not None:
            _span["block"] = block
        out = mttkrp_blocked(x, factors, mode, block, f32_acc=mixed)
        return out.astype(out_dtype) if out_dtype is not None else out
    # pallas
    if x.ndim < 3:  # the kernels need >= 2 contraction dims
        out = _einsum_mttkrp_f32acc(x, factors, mode) if mixed \
            else _einsum_mttkrp(x, factors, mode)
        return out.astype(out_dtype) if out_dtype is not None else out
    from ..kernels import ops as kernel_ops  # lazy: avoids import cycle

    if plan is None and memory is not None:
        rank = next(
            f.shape[1] for k, f in enumerate(factors) if k != mode
        )
        if mixed:
            # dtype-aware planning: same physical budget, narrower items
            memory = memory.with_itemsize(x.dtype.itemsize)
        plan = choose_blocks(
            _mode_first(x.shape, mode), rank, x.dtype.itemsize,
            memory=memory,
        )
    if _span is not None:
        _span["plan"] = plan
        _span["variant"] = kernel_variant
    _count_pallas()
    return kernel_ops.mttkrp_pallas(
        x, factors, mode, plan=plan, interpret=interpret,
        out_dtype=out_dtype, variant=kernel_variant,
    )


def _mode_first(shape: Sequence[int], mode: int) -> tuple[int, ...]:
    return (shape[mode],) + tuple(
        s for k, s in enumerate(shape) if k != mode
    )


# ---------------------------------------------------------------------------
# Batched dispatch: a leading B axis, ONE plan, ONE program
# ---------------------------------------------------------------------------

def _concrete_ctx(ctx: ExecutionContext, backend: str) -> ExecutionContext:
    """The context the vmapped element dispatch runs under: the backend
    the bucket resolved to, pinned (no per-element re-resolution, no
    empirical tuning, no stale problem pinning inside the trace)."""
    if ctx.backend == backend and not ctx.tune and ctx.problem is None:
        return ctx
    return replace(
        ctx, backend=backend, tune=False, problem=None, decisions=(),
    )


def _batch_axes(
    api: str, arrays: Sequence[jax.Array | None], batch: int,
    elem_dims: Sequence[int], ranks: Sequence[object], what: str,
) -> list[int | None]:
    """vmap ``in_axes`` for the per-mode operands of a batched call:
    axis 0 for per-element ``(B, I_k, R)`` stacks, ``None`` for shared
    ``(I_k, R)`` operands (and for the ``None`` slot at a kept mode).
    ``ranks[k]`` may be ``None`` to skip the rank-extent check."""
    axes: list[int | None] = []
    for k, a in enumerate(arrays):
        if a is None:
            axes.append(None)
            continue
        want = (elem_dims[k],) if ranks[k] is None \
            else (elem_dims[k], ranks[k])
        if a.ndim == len(want) + 1 and tuple(a.shape) == (batch,) + want:
            axes.append(0)
        elif a.ndim == len(want) and tuple(a.shape) == want:
            axes.append(None)
        else:
            raise ValueError(
                f"{api}: batched call (B={batch}) needs {what} {k} of "
                f"shape {(batch,) + want} (per-element) or {want} "
                f"(shared), got {tuple(a.shape)}"
            )
    return axes


def _mttkrp_batched(
    x, factors, mode, ctx, plan, block, out_dtype, kernel_variant,
):
    """B MTTKRPs as one dispatch: ``x`` is ``(B, I_0, ..., I_{N-1})``,
    ``factors[k]`` is ``(B, I_k, R)`` (per-element) or ``(I_k, R)``
    (shared). The ``auto`` decision is resolved ONCE against the element
    shape — the same tune-cache key the unbatched call uses, so a bucket
    of B requests costs one cache lookup — and ``jax.vmap`` maps the
    element dispatch over the batch axis: the pallas backend launches
    ONE kernel (the batch axis becomes a grid dimension), not B."""
    batch = int(x.shape[0])
    elem_shape = tuple(x.shape[1:])
    rank = next(
        int(f.shape[-1]) for k, f in enumerate(factors) if k != mode
    )
    axes = _batch_axes(
        "repro.mttkrp", factors, batch, elem_shape,
        [rank] * len(factors), "factor",
    )
    backend = ctx.backend
    if backend == "auto":
        decision = ctx.decision_for(elem_shape, rank, mode, x.dtype)
        if decision is None:
            from ..tune.search import resolve  # lazy: engine <-> tune

            decision = resolve(
                _mode_first(elem_shape, mode), rank, mode, x.dtype,
                ctx.memory, cache=ctx.plan_cache(),
            )
        backend = decision.backend
        plan = plan if plan is not None else decision.plan
        block = block if block is not None else decision.block
        kernel_variant = kernel_variant or decision.variant
    ectx = _concrete_ctx(ctx, backend)

    def one(xb, *fbs):
        return _mttkrp_impl(
            xb, list(fbs), mode, ectx, plan, block, out_dtype,
            kernel_variant,
        )

    vmapped = jax.vmap(one, in_axes=(0, *axes))
    if not _otrace.should_record(ctx.observe, x, *factors):
        return vmapped(x, *factors)
    t0 = time.perf_counter()
    with _otrace.annotated(f"repro.mttkrp.batched.mode{mode}"):
        out = vmapped(x, *factors)
    span = {"backend": backend, "plan": plan}
    _record_mttkrp_span(
        "mttkrp", ectx, elem_shape, rank, mode, x.dtype.itemsize, span,
        t0, batch=batch,
    )
    return out


def contract_partial(
    node: jax.Array,
    factors: Sequence[jax.Array],
    modes: Sequence[int],
    drop: Sequence[int],
    has_rank: bool,
    *,
    ctx: ExecutionContext | None = None,
    plan: BlockPlan | None = None,
    backend=UNSET,
    memory=UNSET,
    interpret=UNSET,
    tune=UNSET,
) -> jax.Array:
    """Contract the factors for ``drop`` out of a dimension-tree ``node``.

    ``node`` carries tensor modes ``modes`` (in axis order) plus a trailing
    rank axis when ``has_rank``; ``factors`` is the full factor list indexed
    by mode. Returns the node for ``keep = modes - drop`` (rank axis last).

    Every such contraction is MTTKRP-shaped: kept modes flatten into the
    output axis, dropped modes are the contraction dims, and the dropped
    factors' Khatri-Rao structure is the weight. The ``pallas`` backend
    plans each one against ``ctx.memory`` and dispatches the blocked
    kernels (the N-way generic kernel when the node has no rank axis yet,
    the rank-augmented partial kernel otherwise). ``plan`` pins explicit
    block sizes for ``pallas``. ``ctx.backend == "auto"`` resolves each
    edge through the autotuner's plan cache (kind ``"partial"``), falling
    back to the model-best configuration on a miss; ``ctx.tune`` searches
    the edge empirically on a miss and persists the winner (skipped under
    tracing — resolution itself is trace-safe, so dimension-tree sweeps
    inside jit still work).
    """
    ctx = context_from_legacy(
        "repro.contract_partial", ctx,
        {"backend": backend, "memory": memory, "interpret": interpret,
         "tune": tune},
    )
    if node.ndim == len(modes) + int(has_rank) + 1:
        # leading batch axis: B tree-node contractions under ONE plan
        return _contract_partial_batched(
            node, factors, modes, drop, has_rank, ctx, plan,
        )
    if not _otrace.should_record(ctx.observe, node, *factors):
        return _contract_partial_impl(
            node, factors, modes, drop, has_rank, ctx, plan
        )
    span: dict = {}
    t0 = time.perf_counter()
    with _otrace.annotated("repro.contract_partial"):
        out = _contract_partial_impl(
            node, factors, modes, drop, has_rank, ctx, plan, _span=span,
        )
    modes_t, drop_t = tuple(modes), tuple(drop)
    keep = tuple(m for m in modes_t if m not in drop_t)
    pos = {m: i for i, m in enumerate(modes_t)}
    canon = (
        math.prod(node.shape[pos[m]] for m in keep) if keep else 1,
    ) + tuple(node.shape[pos[m]] for m in drop_t)
    span["x_has_rank"] = has_rank
    _record_mttkrp_span(
        "contract_partial", ctx, canon, factors[drop_t[0]].shape[1], 0,
        node.dtype.itemsize, span, t0,
        modes=list(modes_t), drop=list(drop_t), has_rank=bool(has_rank),
    )
    return out


def _contract_partial_impl(
    node, factors, modes, drop, has_rank, ctx, plan,
    _span: dict | None = None,
):
    backend = ctx.backend
    memory = ctx.memory
    interpret = ctx.interpret
    out_dtype = ctx.out_dtype  # same dtype policy as the plain path
    node, factors, out_dtype, mixed = _cast_compute(
        ctx, node, factors, out_dtype
    )
    modes = tuple(modes)
    drop = tuple(drop)
    keep = tuple(m for m in modes if m not in drop)
    auto_plan: BlockPlan | None = plan
    if backend == "auto":
        # lazy import: engine <-> tune layer cycle
        from ..tune.search import _is_concrete, resolve, tune_partial

        if ctx.tune and _is_concrete(node):
            tune_partial(
                node, factors, modes, drop, has_rank, memory=memory,
                interpret=interpret, cache=ctx.plan_cache(),
            )
        pos0 = {m: i for i, m in enumerate(modes)}
        canon_shape = (
            math.prod(node.shape[pos0[m]] for m in keep) if keep else 1,
        ) + tuple(node.shape[pos0[m]] for m in drop)
        resolved = resolve(
            canon_shape, factors[drop[0]].shape[1], 0, node.dtype, memory,
            kind="partial", x_has_rank=has_rank, cache=ctx.plan_cache(),
        )
        backend = resolved.backend
        if auto_plan is None:
            auto_plan = resolved.plan
    check_backend(backend)
    if _span is not None:
        _span["backend"] = backend
    if backend != "pallas":
        # Algorithm 2's schedule matters only below the einsum boundary
        # here; blocked_host partials fall back to einsum (the host-blocked
        # oracle exists for the full MTTKRP path).
        sub_in = "".join(_L[m] for m in modes) + (_RANK if has_rank else "")
        ops = [node]
        subs = [sub_in]
        for m in drop:
            ops.append(factors[m])
            subs.append(_L[m] + _RANK)
        sub_out = "".join(_L[m] for m in keep) + _RANK
        kw = {"preferred_element_type": jnp.float32} if mixed else {}
        out = jnp.einsum(
            ",".join(subs) + "->" + sub_out, *ops, optimize="optimal", **kw
        )
        return out.astype(out_dtype) if out_dtype is not None else out

    from ..kernels import ops as kernel_ops  # lazy: avoids import cycle

    rank = factors[drop[0]].shape[1]
    pos = {m: i for i, m in enumerate(modes)}
    keep_sizes = tuple(node.shape[pos[m]] for m in keep)
    drop_sizes = tuple(node.shape[pos[m]] for m in drop)
    # canonicalize: kept modes first (flattened), dropped modes next,
    # rank axis last
    perm = tuple(pos[m] for m in keep) + tuple(pos[m] for m in drop)
    if has_rank:
        perm = perm + (node.ndim - 1,)
    xp = jnp.transpose(node, perm)
    i_rows = math.prod(keep_sizes) if keep_sizes else 1
    fs = [factors[m] for m in drop]
    itemsize = node.dtype.itemsize
    if mixed and memory is not None:
        memory = memory.with_itemsize(itemsize)  # dtype-aware planning
    _count_pallas()
    if has_rank:
        xp = xp.reshape((i_rows,) + drop_sizes + (rank,))
        plan = auto_plan if auto_plan is not None else (
            choose_blocks(
                (i_rows,) + drop_sizes, rank, itemsize, memory=memory,
                x_has_rank=True,
            ) if memory is not None else None
        )
        if _span is not None:
            _span["plan"] = plan
        out = kernel_ops.mttkrp_partial_canonical_pallas(
            xp, fs, plan=plan, interpret=interpret,
            out_dtype=out_dtype if mixed else node.dtype,
        )
    else:
        xp = xp.reshape((i_rows,) + drop_sizes)
        plan = auto_plan if auto_plan is not None else (
            choose_blocks(
                xp.shape, rank, itemsize, memory=memory
            ) if memory is not None else None
        )
        if _span is not None:
            _span["plan"] = plan
        out = kernel_ops.mttkrp_canonical_pallas(
            xp, fs, plan=plan, interpret=interpret,
            out_dtype=out_dtype if mixed else node.dtype,
        )
    out = out.reshape(keep_sizes + (rank,))
    return out.astype(out_dtype) if out_dtype is not None else out


def _contract_partial_batched(
    node, factors, modes, drop, has_rank, ctx, plan,
):
    """B dimension-tree contractions as one dispatch: ``node`` carries a
    leading batch axis ahead of its tensor modes (and trailing rank axis
    when ``has_rank``); ``factors[m]`` for each dropped mode is
    ``(B, I_m, R)`` or shared ``(I_m, R)``. The ``auto`` resolution runs
    once against the element's canonical shape (``kind="partial"`` key),
    then the element contraction is vmapped — one pallas launch."""
    modes_t = tuple(modes)
    drop_t = tuple(drop)
    keep = tuple(m for m in modes_t if m not in drop_t)
    batch = int(node.shape[0])
    elem_shape = tuple(node.shape[1:])
    rank = int(factors[drop_t[0]].shape[-1])
    # factor list is indexed by mode; only dropped modes' factors are
    # touched, so slots for kept/absent modes batch-check only if present
    pos = {m: i for i, m in enumerate(modes_t)}
    dims, ranks = [], []
    for k, f in enumerate(factors):
        if k in pos:
            dims.append(elem_shape[pos[k]])
        else:
            dims.append(None if f is None else int(f.shape[-2]))
        ranks.append(rank)
    axes = _batch_axes(
        "repro.contract_partial", factors, batch, dims, ranks, "factor",
    )
    backend = ctx.backend
    if backend == "auto":
        from ..tune.search import resolve  # lazy: engine <-> tune

        canon_shape = (
            math.prod(elem_shape[pos[m]] for m in keep) if keep else 1,
        ) + tuple(elem_shape[pos[m]] for m in drop_t)
        resolved = resolve(
            canon_shape, rank, 0, node.dtype, ctx.memory,
            kind="partial", x_has_rank=has_rank, cache=ctx.plan_cache(),
        )
        backend = resolved.backend
        plan = plan if plan is not None else resolved.plan
    ectx = _concrete_ctx(ctx, backend)

    def one(nb, *fbs):
        return _contract_partial_impl(
            nb, list(fbs), modes_t, drop_t, has_rank, ectx, plan,
        )

    vmapped = jax.vmap(one, in_axes=(0, *axes))
    if not _otrace.should_record(ctx.observe, node, *factors):
        return vmapped(node, *factors)
    t0 = time.perf_counter()
    with _otrace.annotated("repro.contract_partial.batched"):
        out = vmapped(node, *factors)
    canon = (
        math.prod(elem_shape[pos[m]] for m in keep) if keep else 1,
    ) + tuple(elem_shape[pos[m]] for m in drop_t)
    span = {"backend": backend, "plan": plan, "x_has_rank": has_rank}
    _record_mttkrp_span(
        "contract_partial", ectx, canon, rank, 0, node.dtype.itemsize,
        span, t0, modes=list(modes_t), drop=list(drop_t),
        has_rank=bool(has_rank), batch=batch,
    )
    return out


# ---------------------------------------------------------------------------
# Multi-TTM (the Tucker/HOSVD kernel, arXiv:2207.10437)
# ---------------------------------------------------------------------------

def _multi_ttm_einsum(x, matrices, keep, f32_acc=False):
    subs, ops, out = [_L[: x.ndim]], [x], ""
    for k in range(x.ndim):
        if k == keep:
            out += _L[k]
            continue
        ops.append(matrices[k])
        subs.append(_L[k] + _RANKS[k])
        out += _RANKS[k]
    kw = {"preferred_element_type": jnp.float32} if f32_acc else {}
    return jnp.einsum(
        ",".join(subs) + "->" + out, *ops, optimize="optimal", **kw
    )


def _keep_first(shape: Sequence[int], keep: int) -> tuple[int, ...]:
    """Canonical Multi-TTM problem shape: kept mode first (mode 0 when
    the full core is computed — every mode is contracted either way)."""
    return (shape[keep],) + tuple(
        s for k, s in enumerate(shape) if k != keep
    )


def multi_ttm(
    x: jax.Array,
    matrices: Sequence[jax.Array],
    keep: int | None = None,
    *,
    ctx: ExecutionContext | None = None,
    plan: MultiTTMPlan | None = None,
    block: int | None = None,
    out_dtype=None,
) -> jax.Array:
    """Multi-TTM through the engine: contract every tensor mode (or every
    mode but ``keep``) with its matrix — the Tucker/HOSVD workhorse
    (arXiv:2207.10437).

    ``matrices[k]`` is ``(I_k, R_k)``; ``matrices[keep]`` is ignored (may
    be ``None``).  ``keep=None`` computes the full core ``G = X x_1
    A_1^T ... x_N A_N^T`` of shape ``(R_1, ..., R_N)``; ``keep=k``
    computes the HOOI workhorse ``Y^(k) = X x_{j != k} A_j^T`` with the
    kept mode staying in place: ``(R_1, ..., I_k, ..., R_N)``.

    ``ctx`` is the same :class:`~repro.engine.context.ExecutionContext`
    that drives :func:`mttkrp`: the backend selects einsum /
    blocked_host (the uniform-b Algorithm-2 schedule; ``block``
    overrides the Eq-9 optimum) / pallas (the blocked Kronecker-weight
    kernel, planned against ``ctx.memory``; ``plan`` pins explicit
    :class:`~repro.engine.plan.MultiTTMPlan` blocks) — or ``"auto"`` to
    resolve through the autotuner's plan cache under ``kind=
    "multi_ttm"`` keys (a context pinned via
    ``ExecutionContext.for_problem(shape, ranks)`` replays its stored
    decision; ``ctx.tune`` searches empirically on a miss and persists
    the winner).
    """
    if ctx is None:
        ctx = ExecutionContext.default()
    if x.ndim == len(matrices) + 1 and _looks_batched_multi_ttm(
        x, matrices, keep
    ):
        # leading batch axis: B Multi-TTMs under ONE resolved plan
        return _multi_ttm_batched(
            x, matrices, keep, ctx, plan, block, out_dtype,
        )
    n = x.ndim
    if keep is not None and not 0 <= keep < n:
        raise ValueError(f"keep mode {keep} out of range for {n}-way tensor")
    if len(matrices) != n:
        raise ValueError(
            f"multi_ttm needs one matrix per tensor mode ({n}), got "
            f"{len(matrices)} (pass None at the kept mode)"
        )
    for k, m in enumerate(matrices):
        if k == keep:
            continue
        if m is None:
            raise ValueError(
                f"matrix {k} is None but mode {k} is contracted "
                f"(only matrices[keep] may be None; keep={keep})"
            )
        if m.shape[0] != x.shape[k]:
            raise ValueError(
                f"matrix {k} has {m.shape[0]} rows but tensor mode {k} "
                f"has extent {x.shape[k]}"
            )
    concrete_mats = [m for m in matrices if m is not None]
    if not _otrace.should_record(ctx.observe, x, *concrete_mats):
        return _multi_ttm_impl(x, matrices, keep, ctx, plan, block, out_dtype)
    span: dict = {}
    t0 = time.perf_counter()
    with _otrace.annotated(f"repro.multi_ttm.keep{keep}"):
        out = _multi_ttm_impl(
            x, matrices, keep, ctx, plan, block, out_dtype, _span=span,
        )
    _record_multi_ttm_span(
        ctx, tuple(x.shape),
        tuple(m.shape[1] for k, m in enumerate(matrices) if k != keep),
        keep, x.dtype.itemsize, span, t0,
    )
    return out


def _record_multi_ttm_span(
    ctx, shape, ranks, keep, itemsize, span, t0, **extra
) -> None:
    """Emit one Multi-TTM dispatch event: resolved backend/plan, the
    blocked model words (``MultiTTMPlan.model_words``) and the HBL
    sequential lower bound, clamped at 0."""
    from ..core.bounds import multi_ttm_seq_lb_memory

    mem = ctx.memory or Memory.tpu_vmem(itemsize=itemsize)
    canon = _keep_first(shape, 0 if keep is None else keep)
    plan = span.get("plan")
    if not isinstance(plan, MultiTTMPlan):
        kernel_ranks = ranks[1:] if keep is None else ranks
        plan = choose_multi_ttm_blocks(
            canon, kernel_ranks, itemsize, memory=mem
        )
    _otrace.record_event(
        "multi_ttm",
        shape=list(shape),
        ranks=list(ranks),
        keep=keep,
        backend=span.get("backend"),
        plan=_span_plan(span.get("plan")),
        modeled_words=int(plan.model_words(canon)),
        lower_bound_words=max(
            multi_ttm_seq_lb_memory(shape, ranks, mem.budget_words), 0.0
        ),
        memory_words=mem.budget_words,
        itemsize=int(itemsize),
        wall_time_us=(time.perf_counter() - t0) * 1e6,
        **_dtype_policy(ctx),
        **extra,
    )


def _multi_ttm_impl(
    x, matrices, keep, ctx, plan, block, out_dtype,
    _span: dict | None = None,
):
    n = x.ndim
    backend = ctx.backend
    memory = ctx.memory
    interpret = ctx.interpret
    if out_dtype is None:
        out_dtype = ctx.out_dtype
    x, matrices, out_dtype, mixed = _cast_compute(
        ctx, x, matrices, out_dtype
    )
    ranks = tuple(
        m.shape[1] for k, m in enumerate(matrices) if k != keep
    )
    keep_key = -1 if keep is None else keep
    canon = _keep_first(x.shape, 0 if keep is None else keep)
    if backend == "auto":
        # pinned Tucker contexts key decisions by the FULL per-mode rank
        # tuple (the problem identity); a None matrix at the kept mode
        # hides R_keep, so such calls just resolve live instead
        decision = None
        if all(m is not None for m in matrices):
            full_ranks = tuple(m.shape[1] for m in matrices)
            decision = ctx.decision_for(
                x.shape, full_ranks, keep_key, x.dtype
            )
        if decision is None:
            # lazy import: engine <-> tune layer cycle
            from ..tune.search import (
                _is_concrete,
                resolve_multi_ttm,
                tune_multi_ttm,
            )

            if ctx.tune and _is_concrete(x):
                tune_multi_ttm(
                    x, matrices, keep, memory=memory, interpret=interpret,
                    cache=ctx.plan_cache(),
                )
            decision = resolve_multi_ttm(
                canon, ranks, keep_key, x.dtype, memory,
                cache=ctx.plan_cache(),
            )
        backend = decision.backend
        plan = plan if plan is not None else decision.plan
        block = block if block is not None else decision.block
    check_backend(backend)
    if _span is not None:
        _span["backend"] = backend
    if backend == "einsum" or (backend == "pallas" and n < 3):
        out = _multi_ttm_einsum(x, matrices, keep, f32_acc=mixed)
        return out.astype(out_dtype) if out_dtype is not None else out
    if backend == "blocked_host":
        from ..core.blocked import multi_ttm_blocked

        if block is None:
            from ..core.bounds import multi_ttm_best_block_size

            mem = memory or Memory.abstract(2 ** 20)
            # the oracle's convention is kept-mode-first (N dims, N-1
            # contracted ranks); for the full core the lead mode plays
            # the kept role, matching the pallas path's kernel_ranks
            b_ranks = ranks[1:] if keep is None else ranks
            block = multi_ttm_best_block_size(
                canon, b_ranks, mem.budget_words
            )
        out = multi_ttm_blocked(x, matrices, keep, block, f32_acc=mixed)
        return out.astype(out_dtype) if out_dtype is not None else out
    # pallas: canonicalize kept mode first (mode 0 for the full core),
    # run the blocked Kronecker kernel, then restore the mode order
    from ..kernels import ops as kernel_ops  # lazy: avoids import cycle

    lead = 0 if keep is None else keep
    perm = (lead,) + tuple(k for k in range(n) if k != lead)
    xp = jnp.transpose(x, perm)
    mats = [matrices[k] for k in perm[1:]]
    if plan is None and memory is not None:
        # the keep=None kernel contracts the trailing N-1 modes only (the
        # lead mode is contracted by the final small matmul)
        kernel_ranks = ranks[1:] if keep is None else ranks
        if mixed:
            memory = memory.with_itemsize(x.dtype.itemsize)
        plan = choose_multi_ttm_blocks(
            canon, kernel_ranks, x.dtype.itemsize, memory=memory
        )
    if _span is not None:
        _span["plan"] = plan
    _count_pallas()
    out2d = kernel_ops.multi_ttm_canonical_pallas(
        xp, mats, plan=plan, interpret=interpret
    )
    rest_ranks = tuple(m.shape[1] for m in mats)
    if keep is None:
        # contract the lead mode too: one small matmul A_0^T @ Z
        out2d = jax.lax.dot_general(
            matrices[0].astype(out2d.dtype), out2d,
            dimension_numbers=(((0,), (0,)), ((), ())),
        )
        out = out2d.reshape((matrices[0].shape[1],) + rest_ranks)
        out = out.astype(x.dtype)
        return out.astype(out_dtype) if out_dtype is not None else out
    out = out2d.reshape((x.shape[keep],) + rest_ranks)
    inv = [0] * n
    for pos, axis in enumerate(perm):
        inv[axis] = pos
    out = jnp.transpose(out, inv).astype(x.dtype)
    return out.astype(out_dtype) if out_dtype is not None else out


def _looks_batched_multi_ttm(x, matrices, keep) -> bool:
    """Disambiguate ``multi_ttm(x_{N+1-way}, N matrices)``: it is a
    batched call only when every matrix is consistent with the element
    problem ``x[b]`` — ``(B, I_k, R_k)`` per-element, ``(I_k, R_k)``
    shared, or ``None`` at the kept mode. Anything else falls through
    to the unbatched path so a short matrix list still raises the
    canonical one-matrix-per-mode error."""
    batch, elem_shape = int(x.shape[0]), tuple(x.shape[1:])
    for k, m in enumerate(matrices):
        if m is None:
            if k != keep:
                return False
            continue
        rows = (elem_shape[k],)
        if not (
            (m.ndim == 3 and tuple(m.shape[:2]) == (batch,) + rows)
            or (m.ndim == 2 and tuple(m.shape[:1]) == rows)
        ):
            return False
    return True


def _multi_ttm_batched(x, matrices, keep, ctx, plan, block, out_dtype):
    """B Multi-TTMs as one dispatch: ``x`` is ``(B, I_1, ..., I_N)``,
    ``matrices[k]`` is ``(B, I_k, R_k)`` (per-element), ``(I_k, R_k)``
    (shared), or ``None`` at the kept mode. The ``auto`` decision
    resolves ONCE against the element shape (``kind="multi_ttm"`` key)
    and the element contraction is vmapped over the batch — one pallas
    launch for all B elements."""
    n = x.ndim - 1
    batch = int(x.shape[0])
    elem_shape = tuple(x.shape[1:])
    if keep is not None and not 0 <= keep < n:
        raise ValueError(
            f"keep mode {keep} out of range for batched {n}-way tensor"
        )
    for k, m in enumerate(matrices):
        if m is None and k != keep:
            raise ValueError(
                f"matrix {k} is None but mode {k} is contracted "
                f"(only matrices[keep] may be None; keep={keep})"
            )
    axes = _batch_axes(
        "repro.multi_ttm", matrices, batch, elem_shape,
        [None if m is None else int(m.shape[-1]) for m in matrices],
        "matrix",
    )
    ranks = tuple(
        int(m.shape[-1]) for k, m in enumerate(matrices) if k != keep
    )
    keep_key = -1 if keep is None else keep
    canon = _keep_first(elem_shape, 0 if keep is None else keep)
    backend = ctx.backend
    if backend == "auto":
        decision = None
        if all(m is not None for m in matrices):
            full_ranks = tuple(int(m.shape[-1]) for m in matrices)
            decision = ctx.decision_for(
                elem_shape, full_ranks, keep_key, x.dtype
            )
        if decision is None:
            from ..tune.search import resolve_multi_ttm  # lazy cycle

            decision = resolve_multi_ttm(
                canon, ranks, keep_key, x.dtype, ctx.memory,
                cache=ctx.plan_cache(),
            )
        backend = decision.backend
        plan = plan if plan is not None else decision.plan
        block = block if block is not None else decision.block
    ectx = _concrete_ctx(ctx, backend)

    def one(xb, *ms):
        return _multi_ttm_impl(
            xb, list(ms), keep, ectx, plan, block, out_dtype,
        )

    vmapped = jax.vmap(one, in_axes=(0, *axes))
    concrete = [m for m in matrices if m is not None]
    if not _otrace.should_record(ctx.observe, x, *concrete):
        return vmapped(x, *matrices)
    t0 = time.perf_counter()
    with _otrace.annotated(f"repro.multi_ttm.batched.keep{keep}"):
        out = vmapped(x, *matrices)
    span = {"backend": backend, "plan": plan}
    _record_multi_ttm_span(
        ectx, elem_shape, ranks, keep, x.dtype.itemsize, span, t0,
        batch=batch,
    )
    return out
