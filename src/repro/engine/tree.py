"""Kernel-backed dimension trees: all-mode MTTKRP and ALS sweeps.

CP-ALS needs the MTTKRP in *every* mode each sweep. Computing them
independently costs N separate O(N*I*R) contractions; a binary dimension
tree (Phan et al. [13]; the actual CP-ALS bottleneck per Hayashi et al.,
arXiv:1708.08976) shares partial contractions: split the mode set in half,
contract the tensor once with each half's factors, and recurse.

Every tree edge is MTTKRP-shaped (tensor x a subset of the factors'
Khatri-Rao structure), so each one is planned and dispatched through
:func:`repro.engine.execute.contract_partial` under ONE
:class:`~repro.engine.context.ExecutionContext` — with
``ctx.backend == 'pallas'`` the whole sweep runs on the blocked VMEM/MXU
kernels instead of einsum, with the same blocking discipline per partial
contraction. The legacy ``backend=/memory=/interpret=/tune=`` kwargs
route through the deprecation shim.
"""

from __future__ import annotations

from typing import Callable, Dict, Sequence

import jax

from ..observe import trace as _otrace
from .context import UNSET, ExecutionContext, context_from_legacy
from .execute import contract_partial, mttkrp


def _solve_tree(
    x: jax.Array,
    factors: Sequence[jax.Array],
    leaf_fn: Callable[[int, jax.Array], None],
    ctx: ExecutionContext,
) -> None:
    """Walk the binary dimension tree, calling ``leaf_fn(mode, b)`` at each
    leaf with that mode's MTTKRP result.

    Ordering is load-bearing for Gauss-Seidel sweeps: a node's *left*
    child partial is contracted (with not-yet-updated right-half factors)
    and fully solved before the *right* child partial is formed, and
    ``contract_partial`` reads ``factors`` at call time — so if ``leaf_fn``
    updates ``factors`` in place, every leaf sees exactly the factors
    plain sequential ALS would use.
    """

    def solve(node, modes, has_rank):
        if len(modes) == 1:
            leaf_fn(modes[0], node)
            return
        half = max(1, len(modes) // 2)
        left, right = modes[:half], modes[half:]
        for child, drop in ((left, right), (right, left)):
            solve(
                contract_partial(
                    node, factors, modes, drop, has_rank, ctx=ctx
                ),
                child, True,
            )

    solve(x, tuple(range(x.ndim)), False)


def all_mode_mttkrp(
    x: jax.Array,
    factors: Sequence[jax.Array],
    *,
    method: str = "dimtree",
    ctx: ExecutionContext | None = None,
    backend=UNSET,
    memory=UNSET,
    interpret=UNSET,
    tune=UNSET,
) -> list[jax.Array]:
    """MTTKRP in every mode: ``[B^(0), ..., B^(N-1)]``.

    ``method='independent'`` runs N separate MTTKRPs (no reuse);
    ``method='dimtree'`` shares the upper-tree partial contractions
    (~2 tensor-sized contractions per sweep instead of N). Either way each
    contraction goes through the requested engine backend —
    ``ctx.backend == "auto"`` resolves every edge through the autotuner's
    plan cache (see :mod:`repro.tune`).
    """
    ctx = context_from_legacy(
        "repro.engine.tree.all_mode_mttkrp", ctx,
        {"backend": backend, "memory": memory, "interpret": interpret,
         "tune": tune},
    )
    n = x.ndim
    if method == "independent":
        return [mttkrp(x, factors, m, ctx=ctx) for m in range(n)]
    if method != "dimtree":
        raise ValueError(
            f"unknown method {method!r}; expected 'dimtree' or "
            f"'independent'"
        )
    if _otrace.should_record(ctx.observe, x, *factors):
        _otrace.record_event(
            "dimtree_sweep",
            shape=list(x.shape),
            rank=int(factors[0].shape[1]),
            backend=ctx.backend,
            n_modes=n,
        )
    results: Dict[int, jax.Array] = {}
    _solve_tree(
        x, factors, lambda mode, b: results.__setitem__(mode, b), ctx
    )
    return [results[m] for m in range(n)]


def dimtree_als_sweep(
    x: jax.Array,
    factors: list[jax.Array],
    update_fn: Callable[[int, jax.Array], jax.Array],
    *,
    ctx: ExecutionContext | None = None,
    backend=UNSET,
    memory=UNSET,
    interpret=UNSET,
    tune=UNSET,
) -> None:
    """One ALS sweep with dimension-tree reuse, *exactly* matching the
    Gauss-Seidel order of plain ALS.

    ``update_fn(mode, b)`` receives the MTTKRP result for ``mode`` computed
    with all modes < mode already updated (see :func:`_solve_tree` for the
    ordering argument), must return the new factor, and may maintain its
    own side state (grams, weights). ``factors`` is updated in place.
    """
    ctx = context_from_legacy(
        "repro.engine.tree.dimtree_als_sweep", ctx,
        {"backend": backend, "memory": memory, "interpret": interpret,
         "tune": tune},
    )

    def leaf(mode: int, b: jax.Array) -> None:
        factors[mode] = update_fn(mode, b)

    _solve_tree(x, factors, leaf, ctx)
