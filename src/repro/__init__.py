"""repro: communication-optimal MTTKRP, CP, and Tucker decomposition.

Reproduction and production-scale growth of *Communication Lower Bounds
for Matricized Tensor Times Khatri-Rao Product* (Ballard, Knight, Rouse,
cs.DC 2017) on the JAX/Pallas stack — extended to the Multi-TTM /
Tucker workload whose analogous bounds are proved in arXiv:2207.10437
(:func:`multi_ttm`, :func:`tucker_hooi`, :class:`MultiTTMPlan`,
:func:`select_tucker_grid`).

The stable public surface (see ``docs/API.md``) is context-first: one
immutable :class:`ExecutionContext` carries the full execution
environment — backend, :class:`Memory`, dtype policy, interpret mode,
tuning policy, and the :class:`Distribution` sub-config (grid / procs /
mesh) — validated once and consumed by every driver::

    import repro

    ctx = repro.ExecutionContext.create(backend="auto")
    result = repro.cp_als(x, rank=8, ctx=ctx)
    b0 = repro.mttkrp(x, result.factors, 0, ctx=ctx)

    ctx.to_json()                     # a portable, reproducible artifact
    repro.ExecutionContext.from_json(s)   # ... replayed elsewhere

Everything deeper (kernels, planner internals, the distributed shard_map
programs, the tune subsystem) remains importable under its module path
(``repro.engine``, ``repro.kernels``, ``repro.distributed``,
``repro.tune``) but is not part of the frozen surface.
"""

from .engine.batch import (
    BatchedCPResult,
    BatchedTuckerResult,
    cp_als_batched,
    tucker_hooi_batched,
)
from .engine.context import Distribution, ExecutionContext
from .engine.execute import contract_partial, mttkrp, multi_ttm
from .engine.plan import BlockPlan, Memory, MultiTTMPlan
from .core.cp_als import CPResult, cp_als, cp_gradient
from .core.tucker import TuckerResult, tucker_hooi
from .distributed.grid_select import select_grid, select_tucker_grid
from .observe.trace import Trace

__version__ = "0.7.0"

__all__ = [
    "ExecutionContext",
    "Distribution",
    "Memory",
    "BlockPlan",
    "MultiTTMPlan",
    "mttkrp",
    "contract_partial",
    "multi_ttm",
    "cp_als",
    "cp_als_batched",
    "cp_gradient",
    "CPResult",
    "BatchedCPResult",
    "tucker_hooi",
    "tucker_hooi_batched",
    "TuckerResult",
    "BatchedTuckerResult",
    "select_grid",
    "select_tucker_grid",
    "Trace",
]
