"""Render the §Dry-run / §Roofline markdown tables from results/dryrun.

    PYTHONPATH=src python -m repro.analysis.report [--dir results/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from .roofline import V5E, roofline_from_record

HBM_BYTES = 16 * 2 ** 30  # v5e


def load(results_dir: str) -> list[dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def fmt_t(x: float) -> str:
    return f"{x * 1e3:.2f}ms" if x >= 1e-4 else f"{x * 1e6:.0f}us"


def dryrun_table(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | mesh | status | mem/dev | fits v5e | FLOPs/dev "
        "| HLO bytes/dev | coll bytes/dev | collectives |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        cell = f"| {r['arch']} | {r['shape']} | {r['mesh']} "
        if r.get("status") == "skipped":
            lines.append(cell + "| skip | – | – | – | – | – | – |")
            continue
        if r.get("status") != "ok":
            lines.append(cell + "| ERROR | – | – | – | – | – | – |")
            continue
        mem = r["memory"]["peak_bytes_est"]
        kinds = r["collectives"]["by_kind"]
        ks = ",".join(
            f"{k.replace('all-', 'a').replace('reduce-scatter', 'rs')}"
            f"×{v['count']}"
            for k, v in sorted(kinds.items())
        )
        lines.append(
            cell
            + f"| ok | {mem / 2**30:.1f}GiB "
            + f"| {'Y' if mem <= HBM_BYTES else 'N'} "
            + f"| {r['cost']['flops']:.2e} | {r['cost']['bytes_accessed']:.2e} "
            + f"| {r['collectives']['operand_bytes']:.2e} | {ks} |"
        )
    return "\n".join(lines)


def roofline_table(recs: list[dict], mesh: str = "16x16") -> str:
    lines = [
        "| arch | shape | T_comp | T_mem | T_coll | bottleneck | "
        "useful (6ND/HLO) | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != mesh or r.get("status") != "ok":
            continue
        rt = roofline_from_record(r)
        # roofline fraction: model-flops-time / overlapped step bound
        ideal = rt.model_flops_total / (r["devices"] * V5E.peak_flops)
        frac = ideal / rt.step_time_overlapped if rt.step_time_overlapped else 0
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_t(rt.t_compute)} "
            f"| {fmt_t(rt.t_memory)} | {fmt_t(rt.t_collective)} "
            f"| **{rt.bottleneck}** | {rt.useful_ratio:.2f} | {frac:.3f} |"
        )
    return "\n".join(lines)


def pick_hillclimb(recs: list[dict]) -> list[tuple]:
    """(cell, reason) candidates: worst roofline fraction, most
    collective-bound, most paper-representative."""
    scored = []
    for r in recs:
        if r.get("mesh") != "16x16" or r.get("status") != "ok":
            continue
        rt = roofline_from_record(r)
        ideal = rt.model_flops_total / (r["devices"] * V5E.peak_flops)
        frac = ideal / rt.step_time_overlapped if rt.step_time_overlapped else 0
        coll_ratio = rt.t_collective / max(rt.step_time_overlapped, 1e-30)
        scored.append((r, frac, coll_ratio))
    worst = min(scored, key=lambda s: s[1] if s[1] > 0 else 1e9)
    most_coll = max(scored, key=lambda s: s[2])
    return [
        (f"{worst[0]['arch']}|{worst[0]['shape']}",
         f"worst roofline fraction {worst[1]:.3f}"),
        (f"{most_coll[0]['arch']}|{most_coll[0]['shape']}",
         f"most collective-bound (T_coll/T = {most_coll[2]:.2f})"),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    recs = load(args.dir)
    print("## Dry-run matrix\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod 16x16)\n")
    print(roofline_table(recs, args.mesh))
    print("\n## Hillclimb candidates\n")
    for cell, why in pick_hillclimb(recs):
        print(f"- {cell}: {why}")


if __name__ == "__main__":
    main()
