"""Compiled-artifact analysis: HLO cost walking + roofline model."""

from .hlo_cost import ModuleCost, analyze_module
from .roofline import RooflineTerms, roofline_from_record, V5E

__all__ = ["ModuleCost", "analyze_module", "RooflineTerms",
           "roofline_from_record", "V5E"]
