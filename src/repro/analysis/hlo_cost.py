"""Trip-count-aware HLO cost walker.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE, so any
program built on lax.scan (layers, microbatches, flash-attention chunks)
under-reports FLOPs/bytes/collectives by the trip count. This walker parses
the post-optimization HLO text, builds the computation call graph, and
aggregates

  * FLOPs        — dot ops: 2 · |output| · contraction size (matmuls are
                   >95% of model FLOPs; elementwise ignored, consistent
                   with MODEL_FLOPS = 6·N·D accounting),
  * bytes        — per top-level instruction: operands + output (XLA's own
                   bytes-accessed convention; fusion-internal traffic not
                   counted — it stays in registers/VMEM),
  * collectives  — kind/size/group, each × its loop multiplicity,

scaling while bodies by ``backend_config.known_trip_count`` (fallback: the
comparison constant in the loop condition).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_TOKEN = re.compile(r"(\w+)\[([\d,]*)\]")
_OPCODE_ARGS = re.compile(r"([\w\-]+)\((.*)$")
_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_CALLS = re.compile(r"calls=%([\w\.\-]+)")
_COND_BODY = re.compile(r"condition=%([\w\.\-]+), body=%([\w\.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_TO_APPLY = re.compile(r"to_apply=%([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_BRACE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_NO_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}

# Ops whose traffic a TPU compiler fuses into neighbours: standalone on the
# CPU backend, they'd double/TRIPLE-count HBM bytes if charged. Bytes are
# charged only at real fusion boundaries: dot/conv, fusion ops, reduces,
# gathers/scatters, dynamic slicing (cache updates), sorts, collectives.
_FUSIBLE_OPS = {
    "convert", "copy", "transpose", "broadcast", "reshape", "slice",
    "concatenate", "pad", "reverse", "add", "subtract", "multiply",
    "divide", "select", "compare", "maximum", "minimum", "exponential",
    "exponential-minus-one", "log", "log-plus-one", "rsqrt", "sqrt",
    "tanh", "negate", "abs", "power", "and", "or", "not", "xor", "sign",
    "floor", "ceil", "clamp", "is-finite", "remainder", "atan2",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "rng", "rng-bit-generator", "erf", "expm1", "log1p", "logistic",
    "cbrt", "round-nearest-afz", "round-nearest-even", "real", "imag",
    "stochastic-convert", "reduce-precision", "map", "bitcast-convert",
}


def _shape_elems_dtype(shape_str: str):
    """(elements, dtype) for a single (non-tuple) shape string."""
    m = _SHAPE_TOKEN.search(shape_str)
    if not m:
        return 0, None
    dtype, dims = m.groups()
    n = 1
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return n, dtype


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_TOKEN.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    args: str    # operand list (inside the op's parentheses)
    rest: str    # attributes after the operand list


def _parse_instr(line: str) -> Instr | None:
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq]
    rest = s[eq + 3:]
    # shape: either a balanced (tuple...) or a single token
    if rest.startswith("("):
        depth, i = 0, 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        shape, rest2 = rest[: i + 1], rest[i + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape, rest2 = rest[:sp], rest[sp + 1:]
    m = _OPCODE_ARGS.match(rest2)
    if not m:
        return None
    opcode, tail = m.groups()
    # split operand args (balanced) from trailing attributes
    depth, j = 1, len(tail)
    for j, ch in enumerate(tail):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
    args = tail[:j]
    attrs = tail[j + 1:]
    return Instr(name, shape, opcode, args, attrs)


@dataclass
class CollectiveAgg:
    kind: str
    operand_bytes: int
    output_bytes: int
    group_size: int
    count: int = 1

    @property
    def ring_bytes(self) -> int:
        q = max(self.group_size, 1)
        if self.kind == "all-gather":
            per = (q - 1) * self.operand_bytes
        elif self.kind == "reduce-scatter":
            per = (q - 1) * self.output_bytes
        elif self.kind == "all-reduce":
            per = int(2 * (q - 1) / q * self.operand_bytes)
        elif self.kind == "all-to-all":
            per = int((q - 1) / q * self.operand_bytes)
        else:
            per = self.operand_bytes
        return per * self.count


@dataclass
class ModuleCost:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: list[CollectiveAgg] = field(default_factory=list)

    @property
    def collective_operand_bytes(self) -> int:
        return int(sum(c.operand_bytes * c.count for c in self.collectives))

    @property
    def collective_ring_bytes(self) -> int:
        return int(sum(c.ring_bytes for c in self.collectives))

    def collectives_by_kind(self) -> dict:
        out: dict[str, dict] = {}
        for c in self.collectives:
            d = out.setdefault(
                c.kind, {"count": 0, "operand_bytes": 0, "ring_bytes": 0}
            )
            d["count"] += c.count
            d["operand_bytes"] += c.operand_bytes * c.count
            d["ring_bytes"] += c.ring_bytes
        return out


def parse_computations(text: str) -> dict[str, list[Instr]]:
    comps: dict[str, list[Instr]] = {}
    cur: list[Instr] | None = None
    for line in text.splitlines():
        if not line.strip():
            continue
        if not line.startswith((" ", "\t", "}")):
            m = _COMP_HEADER.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = []
                comps[m.group(1)] = cur
                continue
            cur = None
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        ins = _parse_instr(line)
        if ins is not None:
            cur.append(ins)
    return comps


def _dot_flops(instr: Instr, shapes: dict[str, str]) -> float:
    out_elems, _ = _shape_elems_dtype(instr.shape)
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rest)
    if not mc:
        return 2.0 * out_elems  # degenerate
    cdims = [int(d) for d in mc.group(1).split(",") if d]
    ops = re.findall(r"%([\w\.\-]+)", instr.args)
    if not ops:
        return 2.0 * out_elems
    lhs_shape = shapes.get(ops[0], "")
    m = _SHAPE_TOKEN.search(lhs_shape)
    if not m:
        return 2.0 * out_elems
    dims = [int(d) for d in m.group(2).split(",") if d]
    k = 1
    for c in cdims:
        if c < len(dims):
            k *= dims[c]
    return 2.0 * out_elems * k


def _fusion_io_bytes(ins: Instr, shapes: dict[str, str], comps) -> int:
    """Boundary bytes of a fusion op, slice-aware.

    A fusion that reads ONE dynamic slice of a big operand (scan carries,
    stacked weights, KV caches) must be charged the slice, not the array;
    a fusion rooted in dynamic-update-slice writes the update in place
    (aliased), not the whole buffer.
    """
    mcall = _CALLS.search(ins.rest)
    fused = comps.get(mcall.group(1), []) if mcall else []
    operand_names = re.findall(r"%([\w\.\-]+)", ins.args)
    charged = {
        i: _shape_bytes(shapes.get(n, "")) for i, n in enumerate(operand_names)
    }
    # parameter name -> operand index, within the fused computation;
    # pass-through ops (bitcast/convert/copy/reshape/transpose) resolve to
    # their source param so slice detection sees through layout wrappers
    param_idx: dict[str, int] = {}
    inner_shapes = {i.name: i.shape for i in fused}
    for inner in fused:
        if inner.opcode == "parameter":
            m = re.match(r"(\d+)", inner.args)
            if m:
                param_idx[inner.name] = int(m.group(1))
    _PASS = {"bitcast", "convert", "copy", "reshape", "transpose",
             "bitcast-convert"}
    for _ in range(3):  # chase short pass-through chains
        for inner in fused:
            if inner.opcode in _PASS and inner.name not in param_idx:
                ops = re.findall(r"%([\w\.\-]+)", inner.args)
                if ops and ops[0] in param_idx:
                    param_idx[inner.name] = param_idx[ops[0]]
    out_b = _shape_bytes(ins.shape)
    for inner in fused:
        if inner.opcode == "dynamic-slice":
            ops = re.findall(r"%([\w\.\-]+)", inner.args)
            if ops and ops[0] in param_idx:
                i = param_idx[ops[0]]
                charged[i] = min(
                    charged.get(i, 0), _shape_bytes(inner.shape)
                )
        elif inner.opcode == "dynamic-update-slice":
            ops = re.findall(r"%([\w\.\-]+)", inner.args)
            # aliased big-buffer operand: in-place, charge zero read
            if ops and ops[0] in param_idx:
                charged[param_idx[ops[0]]] = 0
            # written bytes = the update operand, not the whole buffer
            if len(ops) > 1 and inner.shape == ins.shape:
                upd_shape = inner_shapes.get(ops[1]) or shapes.get(ops[1], "")
                upd_b = _shape_bytes(upd_shape)
                if upd_b:
                    out_b = min(out_b, upd_b)
    return out_b + sum(charged.values())


def _trip_count(instr: Instr, comps, shapes) -> int:
    m = _TRIP.search(instr.rest)
    if m:
        return int(m.group(1))
    mc = _COND_BODY.search(instr.rest)
    if mc:
        cond = comps.get(mc.group(1), [])
        consts = []
        for ci in cond:
            if ci.opcode == "constant":
                mm = re.search(r"constant\((\d+)\)", ci.rest + ")")
                mm2 = re.search(r"\((\d+)\)", "(" + ci.rest)
                if mm:
                    consts.append(int(mm.group(1)))
                elif mm2:
                    consts.append(int(mm2.group(1)))
        if consts:
            return max(consts)
    return 1


def analyze_module(text: str) -> ModuleCost:
    comps = parse_computations(text)
    # global name -> output shape (first definition wins per computation;
    # lookups prefer the local computation's table)
    local_shapes: dict[str, dict[str, str]] = {
        cname: {i.name: i.shape for i in instrs}
        for cname, instrs in comps.items()
    }

    # entry = computation not referenced by any other, containing params;
    # HLO text convention: the ENTRY computation — detect via 'ENTRY' line
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HEADER.match(line[len("ENTRY"):].strip())
            if m:
                entry = m.group(1)
            break
    if entry is None:
        # fallback: computation with most instructions
        entry = max(comps, key=lambda c: len(comps[c]))

    memo: dict[tuple[str, str], ModuleCost] = {}

    def walk(cname: str, mode: str) -> ModuleCost:
        """mode: 'full' counts bytes at this level; 'fused' only flops."""
        key = (cname, mode)
        if key in memo:
            return memo[key]
        cost = ModuleCost()
        instrs = comps.get(cname, [])
        shapes = local_shapes.get(cname, {})
        for ins in instrs:
            op = ins.opcode
            base = op[:-6] if op.endswith("-start") else op
            if op.endswith("-done"):
                continue
            if op == "dot":
                cost.flops += _dot_flops(ins, shapes)
            if base in COLLECTIVES:
                operand_names = re.findall(r"%([\w\.\-]+)", ins.args)
                ob = sum(
                    _shape_bytes(shapes.get(n, "")) for n in operand_names
                )
                q = 1
                mg = _GROUPS_BRACE.search(ins.rest)
                if mg:
                    q = len(mg.group(1).split(","))
                else:
                    mi = _GROUPS_IOTA.search(ins.rest)
                    if mi:
                        q = int(mi.group(2))
                    elif base == "collective-permute":
                        q = 2
                cost.collectives.append(
                    CollectiveAgg(base, ob, _shape_bytes(ins.shape), q)
                )
            # --- nested computations
            if op == "while":
                mcb = _COND_BODY.search(ins.rest)
                if mcb:
                    trips = _trip_count(ins, comps, shapes)
                    body = walk(mcb.group(2), mode)
                    condc = walk(mcb.group(1), mode)
                    cost.flops += trips * (body.flops + condc.flops)
                    cost.bytes += trips * (body.bytes + condc.bytes)
                    for c in body.collectives + condc.collectives:
                        cost.collectives.append(
                            CollectiveAgg(
                                c.kind, c.operand_bytes, c.output_bytes,
                                c.group_size, c.count * trips,
                            )
                        )
                continue
            if op == "fusion":
                mcall = _CALLS.search(ins.rest)
                if mcall:
                    sub = walk(mcall.group(1), "fused")
                    cost.flops += sub.flops  # dots inside fusions
            elif op in ("call", "async-start", "custom-call"):
                mcall = _CALLS.search(ins.rest) or _TO_APPLY.search(ins.rest)
                if mcall:
                    sub = walk(mcall.group(1), mode)
                    cost.flops += sub.flops
                    cost.bytes += sub.bytes
                    cost.collectives.extend(sub.collectives)
            elif op == "conditional":
                mb = _BRANCHES.search(ins.rest)
                if mb:
                    subs = [
                        walk(b.strip().lstrip("%"), mode)
                        for b in mb.group(1).split(",")
                        if b.strip()
                    ]
                    if subs:
                        biggest = max(subs, key=lambda s: s.flops + s.bytes)
                        cost.flops += biggest.flops
                        cost.bytes += biggest.bytes
                        cost.collectives.extend(biggest.collectives)
            # --- bytes at the top-level stream only, fusion-boundary ops
            if (
                mode == "full"
                and op not in _NO_BYTES_OPS
                and op not in _FUSIBLE_OPS
            ):
                if op == "fusion":
                    cost.bytes += _fusion_io_bytes(ins, shapes, comps)
                elif op in ("dynamic-slice", "gather"):
                    # read the slice + indices, write the output
                    cost.bytes += 2 * _shape_bytes(ins.shape)
                elif op == "dynamic-update-slice":
                    operand_names = re.findall(r"%([\w\.\-]+)", ins.args)
                    upd = (
                        _shape_bytes(shapes.get(operand_names[1], ""))
                        if len(operand_names) > 1
                        else _shape_bytes(ins.shape)
                    )
                    cost.bytes += 2 * min(upd, _shape_bytes(ins.shape))
                else:
                    out_b = _shape_bytes(ins.shape)
                    operand_names = re.findall(
                        r"%([\w\.\-]+)", ins.args
                    )
                    in_b = sum(
                        _shape_bytes(shapes.get(n, ""))
                        for n in operand_names
                    )
                    cost.bytes += out_b + in_b
        memo[key] = cost
        return cost

    return walk(entry, "full")


def analyze_compiled(compiled) -> ModuleCost:
    return analyze_module(compiled.as_text())
