"""Three-term roofline model for TPU v5e (target hardware).

    T_compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    T_memory     = HLO_bytes / (chips × HBM_bw)
    T_collective = collective_bytes / (chips × link_bw)

HLO_FLOPs / HLO_bytes come from the trip-count-aware walker
(analysis.hlo_cost) over the per-device SPMD program — so values are
per-device already and `chips` divides only the *model-level* totals.
collective_bytes uses the prompt convention (sum of collective operand
sizes, loop-scaled); the ring-model bytes are reported alongside.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class HW:
    name: str
    peak_flops: float      # bf16 FLOP/s per chip
    hbm_bw: float          # bytes/s per chip
    link_bw: float         # bytes/s per ICI link


V5E = HW("tpu-v5e", 197e12, 819e9, 50e9)


@dataclass
class RooflineTerms:
    t_compute: float
    t_memory: float
    t_collective: float
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    model_flops_total: float
    useful_ratio: float      # MODEL_FLOPS / (HLO_FLOPs × chips)
    bottleneck: str
    hw: str = V5E.name

    @property
    def step_time(self) -> float:
        """No-overlap upper bound (the three terms fully serialized)."""
        return self.t_compute + self.t_memory + self.t_collective

    @property
    def step_time_overlapped(self) -> float:
        """Perfect-overlap lower bound (max of the three engines)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization at the overlapped bound."""
        if self.step_time_overlapped == 0:
            return 0.0
        # MFU = model_flops / (chips*peak) / step_time; chips already folded
        return self.useful_ratio * (
            self.t_compute / self.step_time_overlapped
        )


def roofline(
    flops_per_device: float,
    bytes_per_device: float,
    collective_bytes_per_device: float,
    model_flops_total: float,
    chips: int,
    hw: HW = V5E,
) -> RooflineTerms:
    t_c = flops_per_device / hw.peak_flops
    t_m = bytes_per_device / hw.hbm_bw
    t_x = collective_bytes_per_device / hw.link_bw
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    useful = (
        model_flops_total / (flops_per_device * chips)
        if flops_per_device
        else 0.0
    )
    return RooflineTerms(
        t_compute=t_c,
        t_memory=t_m,
        t_collective=t_x,
        flops_per_device=flops_per_device,
        bytes_per_device=bytes_per_device,
        collective_bytes_per_device=collective_bytes_per_device,
        model_flops_total=model_flops_total,
        useful_ratio=useful,
        bottleneck=bottleneck,
        hw=hw.name,
    )


def roofline_from_record(record: dict, hw: HW = V5E) -> RooflineTerms:
    """Build terms from a dry-run JSON record (see launch/dryrun.py)."""
    return roofline(
        flops_per_device=record["cost"]["flops"],
        bytes_per_device=record["cost"]["bytes_accessed"],
        collective_bytes_per_device=record["collectives"]["operand_bytes"],
        model_flops_total=record.get("model_flops", 0.0),
        chips=record.get("devices", 256),
        hw=hw,
    )
