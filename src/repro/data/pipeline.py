"""Counter-indexed synthetic LM data (see package docstring).

The "corpus" is a fixed random Markov-ish token process: token t+1 depends
on token t through a seeded hash — giving the model actual structure to
learn (bigram statistics) so example training runs show decreasing loss,
while remaining fully deterministic and storage-free.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    structure: float = 0.8  # probability a token follows the bigram chain


def _bigram_table(vocab: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, vocab, size=(vocab,), dtype=np.int32)


def synthetic_batch(cfg: DataConfig, step: int) -> dict:
    """Global batch for `step` (pure function of (cfg.seed, step))."""
    key = jax.random.PRNGKey(cfg.seed)
    key = jax.random.fold_in(key, step)
    k1, k2, k3 = jax.random.split(key, 3)
    b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    table = jnp.asarray(_bigram_table(v, cfg.seed))
    start = jax.random.randint(k1, (b,), 0, v)
    noise = jax.random.randint(k2, (b, s), 0, v)
    use_chain = jax.random.bernoulli(k3, cfg.structure, (b, s))

    def step_fn(tok, inp):
        nz, uc = inp
        nxt = jnp.where(uc, table[tok], nz)
        return nxt, nxt

    _, toks = jax.lax.scan(
        step_fn, start, (noise.T, use_chain.T)
    )
    tokens = toks.T  # (B, S)
    labels = jnp.concatenate(
        [tokens[:, 1:], tokens[:, :1]], axis=1
    )  # next-token targets (wrap at end)
    return {"tokens": tokens, "labels": labels}


def batch_iterator(
    cfg: DataConfig, start_step: int = 0
) -> Iterator[tuple[int, dict]]:
    """Resumable iterator: pass the restored step after a restart."""
    step = start_step
    while True:
        yield step, synthetic_batch(cfg, step)
        step += 1
