"""Data pipeline: deterministic, counter-indexed synthetic token streams.

Every batch is a pure function of (seed, step) — exactly resumable after
restart and re-shardable to any DP width (the global batch is generated
logically and each host/device slice is a view), which is what elastic
restarts need. A real deployment swaps `synthetic_batch` for a tokenized
shard reader with the same (seed, step) -> global batch contract.
"""

from .pipeline import DataConfig, batch_iterator, synthetic_batch

__all__ = ["DataConfig", "batch_iterator", "synthetic_batch"]
