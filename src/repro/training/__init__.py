"""Training/serving substrate: jit step builders with production sharding,
microbatch accumulation, CP-compressed DP gradients, fault-tolerant loop."""

from .steps import (
    TrainState,
    build_serve_step,
    build_train_step,
    init_train_state,
    train_state_specs,
)
from .loop import TrainLoop, LoopConfig

__all__ = [
    "TrainState",
    "build_serve_step",
    "build_train_step",
    "init_train_state",
    "train_state_specs",
    "TrainLoop",
    "LoopConfig",
]
