"""Fault-tolerant training loop.

Responsibilities (DESIGN.md §5):
  * resume-from-latest on start (elastic: any mesh);
  * periodic async checkpointing (overlapped with training);
  * failure handling: a step that raises is retried from the last
    checkpoint up to `max_restarts` times (on real fleets the launcher
    restarts the process; this loop implements the same state machine
    in-process so it is testable);
  * straggler monitor: per-step wall-time EMA; steps slower than
    `straggler_factor`× the EMA are counted and surfaced in metrics —
    hooks for requeue/abort decisions.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from ..checkpoint import CheckpointManager
from ..data import DataConfig, synthetic_batch


@dataclass
class LoopConfig:
    total_steps: int
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    max_restarts: int = 3
    straggler_factor: float = 3.0
    log_every: int = 10


@dataclass
class LoopStats:
    steps_done: int = 0
    restarts: int = 0
    stragglers: int = 0
    losses: list = field(default_factory=list)
    step_times: list = field(default_factory=list)


class TrainLoop:
    def __init__(
        self,
        step_fn: Callable,          # (state, batch) -> (state, metrics)
        data_cfg: DataConfig,
        loop_cfg: LoopConfig,
        batch_fn: Callable | None = None,
        place_batch: Callable | None = None,
    ):
        self.step_fn = step_fn
        self.data_cfg = data_cfg
        self.cfg = loop_cfg
        self.batch_fn = batch_fn or synthetic_batch
        self.place_batch = place_batch or (lambda b: b)
        self.ckpt = CheckpointManager(loop_cfg.ckpt_dir, keep=loop_cfg.keep)
        self.stats = LoopStats()

    def run(self, state, mesh=None, spec_tree=None,
            fail_injector: Callable | None = None):
        """Run to total_steps with restart-on-failure. `fail_injector(step)`
        raising simulates node failures (used by tests)."""
        cfg = self.cfg
        start, restored = self.ckpt.restore_latest(
            state, mesh=mesh, spec_tree=spec_tree
        )
        if restored is not None:
            state = restored
            step = start
        else:
            step = 0
        ema = None
        while step < cfg.total_steps:
            try:
                batch = self.place_batch(self.batch_fn(self.data_cfg, step))
                t0 = time.perf_counter()
                if fail_injector is not None:
                    fail_injector(step)
                state, metrics = self.step_fn(state, batch)
                loss = float(metrics["loss"])
                if not np.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at {step}")
                dt = time.perf_counter() - t0
                ema = dt if ema is None else 0.9 * ema + 0.1 * dt
                if dt > self.cfg.straggler_factor * ema:
                    self.stats.stragglers += 1
                self.stats.losses.append(loss)
                self.stats.step_times.append(dt)
                step += 1
                self.stats.steps_done += 1
                if step % cfg.ckpt_every == 0 or step == cfg.total_steps:
                    self.ckpt.save_async(step, state)
            except Exception:
                self.stats.restarts += 1
                if self.stats.restarts > cfg.max_restarts:
                    raise
                self.ckpt.wait()
                restored_step, restored = self.ckpt.restore_latest(
                    state, mesh=mesh, spec_tree=spec_tree
                )
                if restored is None:
                    step = 0  # no checkpoint yet: restart from scratch
                else:
                    state, step = restored, restored_step
        self.ckpt.wait()
        return state, self.stats
