"""jit-able train_step / serve_step builders.

train_step(state, batch) -> (state, metrics)
  * microbatch gradient accumulation (lax.scan over microbatches): bounds
    activation memory AND overlaps each microbatch's gradient reduction
    with the next microbatch's compute under XLA's latency-hiding scheduler;
  * AdamW update with f32 ZeRO-sharded moments;
  * optional CP-compressed DP gradient exchange (distributed/compression) —
    the paper's Khatri-Rao insight applied to data-parallel training.

serve_step(params, decode_state, tokens) -> (logits, decode_state)
  one-token decode against the KV/SSM caches.

All sharding is expressed as PartitionSpecs (params via models.param_specs,
activations via internal constraints), so the same builders drive the
single-pod and multi-pod production meshes and the dry-run.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models import (
    ArchConfig,
    Sharding,
    cache_specs,
    decode_step,
    init_params,
    loss_fn,
    param_specs,
)
from ..optim import adamw_init, adamw_update, opt_state_specs
from ..optim.schedule import cosine_schedule


class TrainState(NamedTuple):
    params: Any
    opt: Any
    step: jax.Array


def init_train_state(
    key, cfg: ArchConfig, moment_dtype=jnp.float32
) -> TrainState:
    params = init_params(key, cfg)
    return TrainState(
        params=params,
        opt=adamw_init(params, moment_dtype=moment_dtype),
        step=jnp.zeros((), jnp.int32),
    )


def train_state_specs(state: TrainState, cfg: ArchConfig, sh: Sharding):
    pspecs = param_specs(state.params, cfg, sh)
    return TrainState(
        params=pspecs, opt=opt_state_specs(pspecs), step=P()
    )


def batch_specs(cfg: ArchConfig, sh: Sharding) -> dict:
    """Global batches are sharded over DP on the batch dim."""
    spec2 = sh.spec("dp", None)
    spec3 = sh.spec("dp", None, None)
    out = {}
    if cfg.frontend != "none":
        out["embeds"] = spec3
    else:
        out["tokens"] = spec2
    if cfg.is_encdec:
        out["dec_tokens"] = spec2
        out["dec_labels"] = spec2
    else:
        out["labels"] = spec2
    return out


def build_train_step(
    cfg: ArchConfig,
    sh: Sharding,
    *,
    microbatches: int = 1,
    lr_fn: Callable | None = None,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
    accum_dtype=jnp.float32,
    opt_math_dtype=None,
):
    """Returns train_step(state, batch) -> (state, metrics)."""
    lr_fn = lr_fn or (lambda s: cosine_schedule(s, 3e-4, 100, 10_000))

    def loss_wrapped(params, mb):
        return loss_fn(params, cfg, mb, sh)

    grad_fn = jax.value_and_grad(loss_wrapped, has_aux=True)

    def train_step(state: TrainState, batch: dict):
        if microbatches <= 1:
            (loss, metrics), grads = grad_fn(state.params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape(
                    (microbatches, b // microbatches) + x.shape[1:]
                )

            mbs = jax.tree.map(split, batch)

            def accum(carry, mb):
                g_acc, l_acc = carry
                (loss, _), g = grad_fn(state.params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype), g_acc, g
                )
                return (g_acc, l_acc + loss), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), state.params
            )
            (grads, loss_sum), _ = jax.lax.scan(
                accum, (g0, jnp.zeros((), jnp.float32)), mbs
            )
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
            metrics = {}
        lr = lr_fn(state.step)
        params, opt, opt_metrics = adamw_update(
            state.params, grads, state.opt, lr,
            weight_decay=weight_decay, clip_norm=clip_norm,
            math_dtype=opt_math_dtype,
        )
        metrics = {
            "loss": loss,
            "lr": lr,
            **{k: v for k, v in opt_metrics.items()},
        }
        return TrainState(params, opt, state.step + 1), metrics

    return train_step


def build_serve_step(cfg: ArchConfig, sh: Sharding):
    """Returns serve_step(params, state, tokens) -> (logits, state)."""

    def serve_step(params, state, tokens):
        return decode_step(params, cfg, state, tokens, sh)

    return serve_step


# --------------------------------------------------------------------------
# jit wiring (shardings attached) — used by launch/ and the dry-run
# --------------------------------------------------------------------------

def jit_train_step(cfg: ArchConfig, sh: Sharding, state: TrainState,
                   microbatches: int = 1, accum_dtype=jnp.float32):
    step = build_train_step(
        cfg, sh, microbatches=microbatches, accum_dtype=accum_dtype
    )
    if sh.mesh is None:
        return jax.jit(step)
    sspecs = train_state_specs(state, cfg, sh)
    bspecs = batch_specs(cfg, sh)
    def to_sharding(tree):
        return jax.tree.map(
            lambda s: NamedSharding(sh.mesh, s), tree,
            is_leaf=lambda x: isinstance(x, P),
        )
    return jax.jit(
        step,
        in_shardings=(to_sharding(sspecs), to_sharding(bspecs)),
        out_shardings=(to_sharding(sspecs), None),
        donate_argnums=(0,),
    )


def jit_serve_step(cfg: ArchConfig, sh: Sharding, params, decode_state):
    step = build_serve_step(cfg, sh)
    if sh.mesh is None:
        return jax.jit(step)
    pspecs = param_specs(params, cfg, sh)
    cspecs = cache_specs(decode_state, cfg, sh)
    def to_sharding(tree):
        return jax.tree.map(
            lambda s: NamedSharding(sh.mesh, s), tree,
            is_leaf=lambda x: isinstance(x, P),
        )
    tok_sharding = NamedSharding(sh.mesh, sh.spec("dp", None))
    return jax.jit(
        step,
        in_shardings=(
            to_sharding(pspecs), to_sharding(cspecs), tok_sharding
        ),
        out_shardings=(None, to_sharding(cspecs)),
        donate_argnums=(1,),
    )
