"""Tucker decomposition via Multi-TTM on the unified engine.

The second workload the engine serves (after CP/MTTKRP): every HOOI mode
update is a Multi-TTM ``Y^(k) = X x_{j != k} A_j^T`` — the kernel whose
communication lower bounds arXiv:2207.10437 proves.  This example
decomposes an exact multilinear-rank tensor through three backends
(einsum, the blocked host schedule, the Pallas Kronecker kernel in
interpret mode), prints the paper-style sequential accounting and the
distributed grid selection, and shows the tuned context round-tripping
through JSON.

    PYTHONPATH=src python examples/tucker.py

Set ``REPRO_EX_TINY=1`` for the CI-sized problem.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

import repro
from repro.core import bounds
from repro.core.tensor import random_tucker_tensor
from repro.distributed.grid_select import (
    multi_ttm_sweep_words,
    select_tucker_grid,
)

TINY = os.environ.get("REPRO_EX_TINY") == "1"


def main():
    dims = (12, 10, 8) if TINY else (40, 36, 32)
    ranks = (4, 3, 2) if TINY else (8, 6, 4)
    n_iters = 3 if TINY else 8
    print(f"tensor {dims}, Tucker ranks {ranks}")
    x, _, _ = random_tucker_tensor(jax.random.PRNGKey(0), dims, ranks)

    # one context per backend; the same ctx drives every Multi-TTM of the
    # run (HOSVD init, each HOOI mode update, and the core contraction)
    contexts = {
        "einsum": repro.ExecutionContext.create(backend="einsum"),
        "blocked_host": repro.ExecutionContext.create(
            backend="blocked_host"
        ),
        "pallas_kronecker": repro.ExecutionContext.create(
            backend="pallas", interpret=True
        ),
    }
    for name, ctx in contexts.items():
        res = repro.tucker_hooi(x, ranks, n_iters=n_iters, ctx=ctx)
        print(f"  backend={name:18s} fit={res.final_fit:.5f}")

    # the Multi-TTM sequential accounting (arXiv:2207.10437): pick a fast
    # memory far smaller than the tensor so blocking matters
    mem = 1024 if TINY else 4096
    canon = dims  # kept-mode-first canonical problem (keep mode 0)
    cranks = ranks[1:]
    b = bounds.multi_ttm_best_block_size(canon, cranks, mem)
    print(f"\nsequential Multi-TTM model (fast memory M = {mem} words):")
    print(f"  lower bound (HBL + trivial I/O): "
          f"{bounds.multi_ttm_seq_lb(canon, cranks, mem):,.0f} words")
    print(f"  blocked schedule (b={b}):         "
          f"{bounds.multi_ttm_blocked_cost(canon, cranks, b):,.0f} words")
    print(f"  unblocked:                       "
          f"{bounds.multi_ttm_unblocked_cost(canon, cranks):,.0f} words")

    # distributed grid selection over the Multi-TTM sweep objective —
    # the same branch-and-bound the CP driver uses, new cost terms
    for procs in (4, 8):
        choice = select_tucker_grid(dims, ranks, procs)
        print(f"  P={procs}: sweep-optimal grid {choice.grid} "
              f"({choice.words:,.0f} words/processor/sweep; model "
              f"{multi_ttm_sweep_words(dims, ranks, choice.grid):,.0f})")

    # a pinned Tucker context is a portable artifact, exactly like CP:
    # for_problem with a rank TUPLE resolves the kind="multi_ttm"
    # decisions (one per HOOI mode update, one for the core) exactly once
    ctx = repro.ExecutionContext.for_problem(dims, ranks, backend="auto")
    print("\npinned multi_ttm decisions:",
          [(d.mode, d.backend, d.cache_hit) for d in ctx.decisions])
    ctx2 = repro.ExecutionContext.from_json(ctx.to_json())
    assert ctx2 == ctx and ctx2.decisions == ctx.decisions
    res = repro.tucker_hooi(x, ranks, n_iters=2, ctx=ctx2)
    print(f"  tucker_hooi(ctx from JSON) fit={res.final_fit:.5f} "
          f"({len(ctx.to_json())} bytes round-tripped)")


if __name__ == "__main__":
    main()
