"""Distributed CP-ALS with the paper's parallel MTTKRP algorithms.

Runs on 8 XLA host devices (set below, BEFORE jax import): the tensor is
block-distributed over a 2x2x2 grid (Algorithm 3, stationary) or a
rank-partitioned 2x(2,2,1) grid (Algorithm 4), factors live in the paper's
§V data distributions, and each ALS mode update calls the shard_map MTTKRP.
Prints the measured per-processor collective bytes against Eq (12)/(16).

    PYTHONPATH=src python examples/cp_parallel.py
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bounds import par_general_cost, par_stationary_cost
from repro.core.cp_als import _grams, _hadamard_except  # noqa
from repro.core.tensor import frob_norm, random_low_rank_tensor
from repro.distributed import (
    make_grid_mesh,
    mttkrp_general,
    mttkrp_stationary,
    parse_collectives,
    place_inputs,
)


def distributed_cp_als(x, rank, grid, p0=1, iters=10):
    """CP-ALS where every MTTKRP runs distributed (Alg 3 if p0==1 else
    Alg 4); Gram solves are tiny (R x R) and run replicated."""
    mesh = make_grid_mesh(grid, p0=p0)
    ndim = x.ndim
    key = jax.random.PRNGKey(1)
    factors = [
        jax.random.normal(jax.random.fold_in(key, k), (d, rank)) /
        jnp.sqrt(rank)
        for k, d in enumerate(x.shape)
    ]
    build = mttkrp_general if p0 > 1 else mttkrp_stationary
    fns = [build(mesh, mode, ndim) for mode in range(ndim)]
    comm_bytes = []
    for mode in range(ndim):
        xs, fl = place_inputs(mesh, x, factors, mode, rank_axis=p0 > 1)
        comm_bytes.append(
            parse_collectives(
                fns[mode].lower(xs, *fl).compile().as_text()
            ).ring_bytes
        )
    normx = frob_norm(x)
    fit = None
    for it in range(iters):
        for mode in range(ndim):
            xs, fl = place_inputs(mesh, x, factors, mode, rank_axis=p0 > 1)
            b = np.asarray(fns[mode](xs, *fl))  # gather (host does solve)
            grams = [f.T @ f for f in factors]
            gamma = jnp.ones((rank, rank))
            for k in range(ndim):
                if k != mode:
                    gamma = gamma * grams[k]
            ridge = 1e-6 * jnp.trace(gamma) / rank
            a = jnp.linalg.solve(
                gamma + ridge * jnp.eye(rank), jnp.asarray(b).T
            ).T
            factors[mode] = a
        # fit via implicit identity
        b_last = jnp.asarray(b)
        gram_full = jnp.ones((rank, rank))
        for f in factors:
            gram_full = gram_full * (f.T @ f)
        inner = jnp.sum(b_last * factors[ndim - 1])
        err = jnp.sqrt(
            jnp.maximum(normx ** 2 - 2 * inner + jnp.sum(gram_full), 0.0)
        )
        fit = float(1 - err / normx)
    return fit, comm_bytes


def main():
    dims, rank = (16, 16, 16), 4
    x, _ = random_low_rank_tensor(jax.random.PRNGKey(0), dims, rank)
    print(f"devices: {len(jax.devices())}; tensor {dims}, rank {rank}\n")

    fit3, comm3 = distributed_cp_als(x, rank, (2, 2, 2), p0=1)
    pred3 = [par_stationary_cost(dims, rank, (2, 2, 2), m) * 4
             for m in range(3)]
    print(f"Algorithm 3 (stationary, grid 2x2x2):  fit={fit3:.5f}")
    for m, (got, want) in enumerate(zip(comm3, pred3)):
        print(f"  mode {m}: measured {got}B vs Eq(12) {want:.0f}B")

    fit4, comm4 = distributed_cp_als(x, rank, (2, 2, 1), p0=2)
    pred4 = [par_general_cost(dims, rank, (2, 2, 1), 2, m) * 4
             for m in range(3)]
    print(f"\nAlgorithm 4 (general, P0=2, grid 2x2x1): fit={fit4:.5f}")
    for m, (got, want) in enumerate(zip(comm4, pred4)):
        print(f"  mode {m}: measured {got}B vs Eq(16) {want:.0f}B")


if __name__ == "__main__":
    main()
