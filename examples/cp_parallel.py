"""Distributed CP-ALS with the paper's parallel MTTKRP algorithms.

Runs on 8 XLA host devices (set below, BEFORE jax import).  Three parts:

1. Automatic grid selection: ``grid_select`` minimizes the Eq (12)/(16)
   per-processor communication exactly (vs. the paper's asymptotic rule).
2. The stationary CP-ALS sweep driver: X block-distributed over the
   selected grid, one shard_map program per sweep (factor gathers
   amortized across all N mode updates, Ballard–Hayashi–Kannan style),
   with the measured per-sweep collective bytes against the sweep model
   and against N independent Alg-3 calls.
3. Single-mode Algorithm 4 (rank-partitioned) for the large-NR regime.

    PYTHONPATH=src python examples/cp_parallel.py
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import ExecutionContext
from repro.core.bounds import par_general_cost, par_stationary_cost
from repro.core.cp_als import cp_als
from repro.core.mttkrp import mttkrp
from repro.core.tensor import (
    frob_norm,
    random_factors,
    random_low_rank_tensor,
    relative_error,
    tensor_from_factors,
)
from repro.distributed import (
    build_cp_sweep,
    choose_cp_grid,
    make_grid_mesh,
    mttkrp_general,
    parse_collectives,
    place_cp_state,
    place_inputs,
    select_grid,
    stationary_sweep_words,
)


def grid_selection_demo(dims, rank):
    procs = len(jax.devices())
    choice = choose_cp_grid(dims, rank, procs)
    print(f"sweep-optimal grid for {dims}, R={rank}, P={procs}: "
          f"{'x'.join(map(str, choice.grid))} "
          f"({choice.words:.0f} words/processor/sweep)")
    big = select_grid(dims, 4096, 512, algorithm="auto", mode=0)
    print(f"large-NR regime (R=4096, P=512): Alg {'4' if big.p0 > 1 else '3'}"
          f" with p0={big.p0}, grid {'x'.join(map(str, big.grid))}\n")
    return choice


def sweep_driver_demo(x, rank, choice):
    dims = x.shape
    ndim = x.ndim
    # the context-first API: ONE ExecutionContext carries the whole
    # distributed environment; for_problem resolves + validates the grid
    # eagerly and the context is the portable record of the setup
    ctx = ExecutionContext.for_problem(
        dims, rank, distributed=True, procs=len(jax.devices())
    )
    print(f"context grid: {'x'.join(map(str, ctx.distribution.grid))} "
          f"(round-trips via to_json: "
          f"{ExecutionContext.from_json(ctx.to_json()) == ctx})")
    mesh = ctx.build_mesh(dims, rank)
    # measure one compiled sweep's collective bytes
    sweep = build_cp_sweep(mesh, ndim, ctx=ctx)
    factors = random_factors(jax.random.PRNGKey(1), dims, rank)
    xs, fs, blocks, grams = place_cp_state(mesh, x, factors)
    normx = jax.device_put(frob_norm(x), NamedSharding(mesh, P()))
    co = sweep.lower(xs, fs, blocks, grams, normx).compile()
    measured = parse_collectives(co.as_text()).ring_bytes
    model = stationary_sweep_words(dims, rank, choice.grid) * 4
    indep = sum(
        par_stationary_cost(dims, rank, choice.grid, m) for m in range(ndim)
    ) * 4
    print(f"per-sweep collective bytes: measured {measured}B, "
          f"model {model:.0f}B (+1 fit all-reduce), "
          f"N independent Eq(12) calls {indep:.0f}B")
    # the actual decomposition through the core driver, same context
    res = cp_als(x, rank, n_iters=20, key=jax.random.PRNGKey(2), ctx=ctx)
    recon = tensor_from_factors(res.factors, res.weights)
    print(f"distributed CP-ALS: fit={res.final_fit:.5f}, "
          f"recon rel-err={float(relative_error(x, recon)):.2e}\n")


def alg4_demo(x, rank):
    dims = x.shape
    p0, grid = 2, (2, 2, 1)
    mesh = make_grid_mesh(grid, p0=p0, dims=dims, rank=rank)
    fs = random_factors(jax.random.PRNGKey(3), dims, rank)
    print(f"Algorithm 4 (general, P0={p0}, grid "
          f"{'x'.join(map(str, grid))}):")
    for mode in range(3):
        f4 = mttkrp_general(mesh, mode, 3)
        xs, fl = place_inputs(mesh, x, fs, mode, rank_axis=True)
        got = parse_collectives(
            f4.lower(xs, *fl).compile().as_text()
        ).ring_bytes
        want = par_general_cost(dims, rank, grid, p0, mode) * 4
        ref = mttkrp(x, fs, mode)
        err = float(np.max(np.abs(np.asarray(f4(xs, *fl)) - np.asarray(ref))))
        print(f"  mode {mode}: measured {got}B vs Eq(16) {want:.0f}B, "
              f"max|err|={err:.1e}")


def main():
    dims, rank = (16, 16, 16), 4
    x, _ = random_low_rank_tensor(jax.random.PRNGKey(0), dims, rank)
    print(f"devices: {len(jax.devices())}; tensor {dims}, rank {rank}\n")
    choice = grid_selection_demo(dims, rank)
    sweep_driver_demo(x, rank, choice)
    alg4_demo(x, rank)


if __name__ == "__main__":
    main()
