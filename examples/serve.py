"""Decomposition-as-a-service demo: batched engine + request queue.

Three steps:

1. The batched engine path — ``repro.mttkrp`` with a leading batch axis
   is ONE dispatch for B tensors (same answer as a Python loop), and
   ``repro.cp_als_batched`` runs B decompositions as one vmapped sweep
   with per-element convergence masks.
2. The serving layer — a ``DecompositionServer`` buckets mixed-shape
   requests by tune-cache key, pads within each bucket (exactly — the
   cropped result matches the unpadded run bit-for-bit), and executes
   one batched call per bucket.
3. Warm starts — a context with ``compilation_cache=<dir>`` persists
   every compiled program, so the next process serving the same buckets
   skips recompilation.

    PYTHONPATH=src python examples/serve.py
    REPRO_EX_TINY=1 PYTHONPATH=src python examples/serve.py   # CI smoke
"""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

import repro
from repro.core.tensor import random_low_rank_tensor
from repro.launch.serve import DecompositionServer


def main():
    tiny = os.environ.get("REPRO_EX_TINY") == "1"
    dims, rank = ((10, 8, 6) if tiny else (20, 16, 12)), 3
    batch = 3 if tiny else 6
    n_iters = 4 if tiny else 12

    # 1. the batched engine path: one dispatch, B answers
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (batch,) + dims)
    factors = [
        jax.random.normal(jax.random.PRNGKey(k + 1), (batch, d, rank))
        for k, d in enumerate(dims)
    ]
    batched = repro.mttkrp(x, factors, 0)  # leading B axis -> batched
    looped = jnp.stack([
        repro.mttkrp(x[b], [f[b] for f in factors], 0)
        for b in range(batch)
    ])
    print(f"batched MTTKRP over B={batch}: max |batched - looped| = "
          f"{float(jnp.max(jnp.abs(batched - looped))):.2e}")

    res = repro.cp_als_batched(x, rank, n_iters=n_iters, tol=1e-4)
    print(f"cp_als_batched: fits={[f'{f:.3f}' for f in res.fits]} "
          f"iters={[int(i) for i in res.n_iters]}")

    # 2. the serving layer: mixed shapes, one batched call per bucket
    with tempfile.TemporaryDirectory() as cache_dir:
        # 3. warm starts: compiled programs persist in cache_dir
        ctx = repro.ExecutionContext.create(
            backend="auto", compilation_cache=cache_dir
        )
        server = DecompositionServer(ctx, n_iters=n_iters, tol=1e-4)
        for i in range(batch):
            shape = tuple(d - i for d in dims)  # jitter: same bucket
            t, _ = random_low_rank_tensor(
                jax.random.PRNGKey(10 + i), shape, rank
            )
            server.submit(t, rank, request_id=f"req{i}")
        results = server.flush()
        buckets = {r.bucket for r in results.values()}
        print(f"served {len(results)} mixed-shape requests in "
              f"{len(buckets)} bucket(s):")
        for rid in sorted(results):
            r = results[rid]
            print(f"  {rid}: shape->crop fit={r.fit:.4f} "
                  f"iters={r.n_iters} batch={r.batch} "
                  f"{'cold' if r.cold else 'warm'}")
        n_cached = sum(len(fs) for _, _, fs in os.walk(cache_dir))
        print(f"persistent compilation cache: {n_cached} program(s) "
              f"saved for the next process")


if __name__ == "__main__":
    main()
